"""Pure-jnp oracle: standard masked decode attention on the *logical* KV.

The strongest possible oracle — it never sees the banked/coded layout, so it
also proves the reconstruction is lossless end-to-end.
"""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, seq_len):
    """q (B,H,D); k,v (B,T,Hkv,D); seq_len (B,) -> (B,H,D) in q.dtype."""
    b, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, g, hkv, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bgkd,btkd->bgkt", qf, kf) * (d ** -0.5)
    mask = jnp.arange(k.shape[1])[None, None, None, :] < seq_len[:, None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgkt,btkd->bgkd", p, vf)
    return out.reshape(b, h, d).astype(q.dtype)
