"""Public wrappers: pack a logical KV cache into coded banks + decode op."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import uint_view_dtype
from repro.kernels.coded_kv_decode.kernel import coded_kv_decode_pallas


def pack_kv_banks(
    k: jnp.ndarray,  # (B, T, Hkv, D)
    v: jnp.ndarray,
    n_banks: int,
    page: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Stripe KV pages over ``n_banks`` banks + pairwise XOR parity banks.

    Page ``t`` lives in bank ``t % n_banks`` slot ``t // n_banks``; parity
    group ``g`` holds ``bank[2g] ^ bank[2g+1]``. Returns uint-lane arrays
    (k_banks, v_banks, k_par, v_par) and the page count. T must divide into
    ``n_banks * page`` supersteps (pad upstream; padded tokens are masked by
    ``seq_len`` at decode time).
    """
    assert n_banks % 2 == 0, "pairwise parity needs even bank count"
    b, t, hkv, d = k.shape
    assert t % (n_banks * page) == 0, (t, n_banks, page)
    n_pages = t // page
    slots = n_pages // n_banks
    u = uint_view_dtype(k.dtype)
    ku = jax.lax.bitcast_convert_type(k, u)
    vu = jax.lax.bitcast_convert_type(v, u)
    # (B, slots, NB, page, Hkv, D) -> (B, NB, slots, page, Hkv, D)
    ku = ku.reshape(b, slots, n_banks, page, hkv, d).transpose(0, 2, 1, 3, 4, 5)
    vu = vu.reshape(b, slots, n_banks, page, hkv, d).transpose(0, 2, 1, 3, 4, 5)
    k_par = ku[:, 0::2] ^ ku[:, 1::2]
    v_par = vu[:, 0::2] ^ vu[:, 1::2]
    return ku, vu, k_par, v_par, n_pages


def coded_kv_decode(
    q: jnp.ndarray,
    k_banks: jnp.ndarray,
    v_banks: jnp.ndarray,
    k_par: jnp.ndarray,
    v_par: jnp.ndarray,
    use_parity: jnp.ndarray,  # (B, n_pages) bool/int
    seq_len: jnp.ndarray,     # (B,) int32
    *,
    value_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Decode attention over the coded banked KV cache (one new token)."""
    if value_dtype is None:
        value_dtype = q.dtype
    return coded_kv_decode_pallas(
        q, k_banks, v_banks, k_par, v_par,
        use_parity.astype(jnp.int32), seq_len.astype(jnp.int32),
        value_dtype=jnp.dtype(value_dtype), interpret=interpret,
    )
