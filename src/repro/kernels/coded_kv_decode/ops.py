"""Public wrappers: pack a logical KV cache into coded banks + decode op."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import uint_view_dtype
from repro.kernels.coded_kv_decode.kernel import (
    coded_kv_decode_pallas,
    gather_pool_pallas,
)


def pack_kv_banks(
    k: jnp.ndarray,  # (B, T, Hkv, D)
    v: jnp.ndarray,
    n_banks: int,
    page: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Stripe KV pages over ``n_banks`` banks + pairwise XOR parity banks.

    Page ``t`` lives in bank ``t % n_banks`` slot ``t // n_banks``; parity
    group ``g`` holds ``bank[2g] ^ bank[2g+1]``. Returns uint-lane arrays
    (k_banks, v_banks, k_par, v_par) and the page count. T must divide into
    ``n_banks * page`` supersteps (pad upstream; padded tokens are masked by
    ``seq_len`` at decode time).
    """
    assert n_banks % 2 == 0, "pairwise parity needs even bank count"
    b, t, hkv, d = k.shape
    assert t % (n_banks * page) == 0, (t, n_banks, page)
    n_pages = t // page
    slots = n_pages // n_banks
    u = uint_view_dtype(k.dtype)
    ku = jax.lax.bitcast_convert_type(k, u)
    vu = jax.lax.bitcast_convert_type(v, u)
    # (B, slots, NB, page, Hkv, D) -> (B, NB, slots, page, Hkv, D)
    ku = ku.reshape(b, slots, n_banks, page, hkv, d).transpose(0, 2, 1, 3, 4, 5)
    vu = vu.reshape(b, slots, n_banks, page, hkv, d).transpose(0, 2, 1, 3, 4, 5)
    k_par = ku[:, 0::2] ^ ku[:, 1::2]
    v_par = vu[:, 0::2] ^ vu[:, 1::2]
    return ku, vu, k_par, v_par, n_pages


def gather_pool_layer(
    k_banks: jnp.ndarray,   # (NB, slots, page, Hkv, D) uint lanes
    v_banks: jnp.ndarray,
    k_par: jnp.ndarray,     # (NG, slots, page, Hkv, D); NG == 0 ⇒ uncoded
    v_par: jnp.ndarray,
    page_table: jnp.ndarray,  # (B, MP) int32 physical page id, -1 free
    use_parity: jnp.ndarray,  # (B, MP) bool
    value_dtype,
    kernel: str = "reference",
    interpret=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize one layer's logical (B, MP*page, Hkv, D) K/V from the
    serving pool via the planned mix of direct and degraded
    (sibling ^ parity) reads — the pool-indirected coded_kv_decode
    datapath. Bit-exact reconstruction; unallocated pages read as zero.

    ``kernel`` selects the datapath: ``"reference"`` is the jnp anchor,
    ``"pallas"`` dispatches to ``gather_pool_pallas`` — bit-exact vs the
    anchor (pure uint select/XOR on both sides), so serving output is
    token-identical either way (docs/kernels.md)."""
    nb = k_banks.shape[0]
    b, mp = page_table.shape
    if kernel == "pallas":
        ko, vo = gather_pool_pallas(
            k_banks, v_banks, k_par, v_par,
            page_table.astype(jnp.int32), use_parity,
            interpret=interpret,
        )
        pg, hkv, d = ko.shape[-3:]
        return (
            jax.lax.bitcast_convert_type(
                ko.reshape(b, mp * pg, hkv, d), value_dtype),
            jax.lax.bitcast_convert_type(
                vo.reshape(b, mp * pg, hkv, d), value_dtype),
        )
    if kernel != "reference":
        raise ValueError(f"unknown gather kernel: {kernel!r}")
    phys = jnp.maximum(page_table, 0)
    bank = phys % nb
    slot = phys // nb
    alloc = page_table >= 0

    def one(banks, par):
        direct = banks[bank, slot]                    # (B, MP, pg, Hkv, D)
        if par.shape[0] > 0:
            deg = banks[bank ^ 1, slot] ^ par[bank // 2, slot]
            out = jnp.where(use_parity[..., None, None, None], deg, direct)
        else:
            out = direct
        out = jnp.where(alloc[..., None, None, None], out, 0)
        pg, hkv, d = out.shape[-3:]
        return jax.lax.bitcast_convert_type(
            out.reshape(b, mp * pg, hkv, d), value_dtype)

    return one(k_banks, k_par), one(v_banks, v_par)


def coded_kv_decode_pool(
    q: jnp.ndarray,           # (B, H, D)
    k_banks: jnp.ndarray,
    v_banks: jnp.ndarray,
    k_par: jnp.ndarray,
    v_par: jnp.ndarray,
    page_table: jnp.ndarray,  # (B, MP)
    use_parity: jnp.ndarray,  # (B, MP)
    seq_len: jnp.ndarray,     # (B,) int32
    *,
    value_dtype=None,
) -> jnp.ndarray:
    """Decode attention over the SERVING pool layout (shared page table,
    per-layer banks) — reference-math anchor for the pooled serve step."""
    from repro.kernels.coded_kv_decode.ref import decode_attention_ref
    if value_dtype is None:
        value_dtype = q.dtype
    k, v = gather_pool_layer(k_banks, v_banks, k_par, v_par,
                             page_table, use_parity, jnp.dtype(value_dtype))
    return decode_attention_ref(q, k, v, seq_len.astype(jnp.int32))


def coded_kv_decode(
    q: jnp.ndarray,
    k_banks: jnp.ndarray,
    v_banks: jnp.ndarray,
    k_par: jnp.ndarray,
    v_par: jnp.ndarray,
    use_parity: jnp.ndarray,  # (B, n_pages) bool/int
    seq_len: jnp.ndarray,     # (B,) int32
    *,
    value_dtype=None,
    interpret=None,
) -> jnp.ndarray:
    """Decode attention over the coded banked KV cache (one new token)."""
    if value_dtype is None:
        value_dtype = q.dtype
    return coded_kv_decode_pallas(
        q, k_banks, v_banks, k_par, v_par,
        use_parity.astype(jnp.int32), seq_len.astype(jnp.int32),
        value_dtype=jnp.dtype(value_dtype), interpret=interpret,
    )
