"""Pallas TPU kernel: decode attention over a *banked, coded* paged KV cache.

The TPU adaptation of the paper's §IV read path for serving: KV pages are
striped across ``NB`` single-ported banks (page ``t`` → bank ``t % NB``,
slot ``t // NB``); bank pairs ``(2g, 2g+1)`` carry an XOR parity bank
(Scheme-I pairwise code, locality 2). When the per-step page schedule marks a
page as conflicted (its bank's DMA queue is over-subscribed), the kernel
reconstructs that page from its *pair sibling* + the parity page instead of
touching the hot bank — trading a hot-bank read for two idle-bank reads,
exactly the paper's degraded read.

All KV lanes enter as raw ``uint16``/``uint32`` bits (bit-exact coding);
they are bitcast to the compute dtype after reconstruction. Softmax is
accumulated flash-style in f32 over pages.

Grid ``(B,)``; per-sequence blocks: q ``(1, H, D)``, banks
``(1, NB, S, P, Hkv, D)``, parity ``(1, NB/2, S, P, Hkv, D)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kv_decode_kernel(q_ref, kb_ref, vb_ref, kp_ref, vp_ref, upar_ref,
                      slen_ref, out_ref, *, value_dtype, n_pages, nb, page):
    h, d = q_ref.shape[1], q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32)                       # (H, D)
    hkv = kb_ref.shape[4]
    g = h // hkv
    qr = q.reshape(g, hkv, d)
    slen = slen_ref[0]

    m = jnp.full((g, hkv), -jnp.inf, jnp.float32)
    s = jnp.zeros((g, hkv), jnp.float32)
    acc = jnp.zeros((g, hkv, d), jnp.float32)

    for t in range(n_pages):
        bank = t % nb
        slot = t // nb
        sib = bank ^ 1
        grp = bank // 2
        use_par = upar_ref[0, t] > 0
        k_dir = kb_ref[0, bank, slot]                      # (P, Hkv, D) uint
        k_rec = kb_ref[0, sib, slot] ^ kp_ref[0, grp, slot]
        v_dir = vb_ref[0, bank, slot]
        v_rec = vb_ref[0, sib, slot] ^ vp_ref[0, grp, slot]
        k_bits = jnp.where(use_par, k_rec, k_dir)
        v_bits = jnp.where(use_par, v_rec, v_dir)
        k = jax.lax.bitcast_convert_type(k_bits, value_dtype).astype(jnp.float32)
        v = jax.lax.bitcast_convert_type(v_bits, value_dtype).astype(jnp.float32)
        # scores (G, Hkv, P)
        logits = jax.lax.dot_general(
            qr, k, (((2,), (2,)), ((1,), (1,))),
            preferred_element_type=jnp.float32,
        )  # dims: contract D, batch Hkv -> (Hkv, G, P)
        logits = jnp.transpose(logits, (1, 0, 2)) * (d ** -0.5)  # (G, Hkv, P)
        tok = t * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
        logits = jnp.where(tok < slen, logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        probs = jnp.exp(logits - m_new[..., None])
        probs = jnp.where(tok < slen, probs, 0.0)
        s = s * alpha + jnp.sum(probs, axis=-1)
        # pv: (G, Hkv, P) x (P, Hkv, D) -> (G, Hkv, D)
        pv = jax.lax.dot_general(
            probs, v, (((2,), (0,)), ((1,), (1,))),
            preferred_element_type=jnp.float32,
        )  # (Hkv, G, D)
        acc = acc * alpha[..., None] + jnp.transpose(pv, (1, 0, 2))
        m = m_new

    out = acc / jnp.maximum(s, 1e-30)[..., None]
    out_ref[0] = out.reshape(h, d).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("value_dtype", "interpret")
)
def coded_kv_decode_pallas(
    q: jnp.ndarray,        # (B, H, D) value dtype
    k_banks: jnp.ndarray,  # (B, NB, S, P, Hkv, D) uint lanes
    v_banks: jnp.ndarray,
    k_par: jnp.ndarray,    # (B, NB//2, S, P, Hkv, D) uint lanes
    v_par: jnp.ndarray,
    use_parity: jnp.ndarray,  # (B, n_pages) int32
    seq_len: jnp.ndarray,     # (B,) int32
    *,
    value_dtype=jnp.float32,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, d = q.shape
    _, nb, s_, p_, hkv, _ = k_banks.shape
    n_pages = use_parity.shape[1]
    assert n_pages <= nb * s_
    kernel = functools.partial(
        _kv_decode_kernel, value_dtype=jnp.dtype(value_dtype),
        n_pages=n_pages, nb=nb, page=p_,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, nb, s_, p_, hkv, d), lambda i: (i, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, nb, s_, p_, hkv, d), lambda i: (i, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, nb // 2, s_, p_, hkv, d), lambda i: (i, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, nb // 2, s_, p_, hkv, d), lambda i: (i, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, n_pages), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(q, k_banks, v_banks, k_par, v_par, use_parity, seq_len)
