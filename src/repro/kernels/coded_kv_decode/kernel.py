"""Pallas TPU kernels: decode attention over a *banked, coded* paged KV cache.

The TPU adaptation of the paper's §IV read path for serving: KV pages are
striped across ``NB`` single-ported banks (page ``t`` → bank ``t % NB``,
slot ``t // NB``); bank pairs ``(2g, 2g+1)`` carry an XOR parity bank
(Scheme-I pairwise code, locality 2). When the per-step page schedule marks a
page as conflicted (its bank's DMA queue is over-subscribed), the kernel
reconstructs that page from its *pair sibling* + the parity page instead of
touching the hot bank — trading a hot-bank read for two idle-bank reads,
exactly the paper's degraded read.

All KV lanes enter as raw ``uint16``/``uint32`` bits (bit-exact coding);
they are bitcast to the compute dtype after reconstruction. Softmax is
accumulated flash-style in f32 over pages; the page walk is a
``fori_loop`` with dynamic bank/slot addressing, so the traced program —
and the compile time — is O(1) in the page count (docs/kernels.md).

Two kernels share the layout:

* ``coded_kv_decode_pallas`` — full attention over per-sequence banks,
  grid ``(B,)``; per-sequence blocks q ``(1, H, D)``, banks
  ``(1, NB, S, P, Hkv, D)``, parity ``(1, NB/2, S, P, Hkv, D)``.
* ``gather_pool_pallas`` — the SERVING pool gather (shared pool, per-batch
  page table), grid ``(B, MP)``: one logical page reconstructed per step,
  bit-exact vs ``ops.gather_pool_layer`` (the reference anchor), so the
  ``ServeConfig(kernel="pallas")`` switch is token-identical by
  construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _kv_decode_kernel(q_ref, kb_ref, vb_ref, kp_ref, vp_ref, upar_ref,
                      slen_ref, out_ref, *, value_dtype, n_pages, nb, page):
    h, d = q_ref.shape[1], q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32)                       # (H, D)
    hkv = kb_ref.shape[4]
    g = h // hkv
    qr = q.reshape(g, hkv, d)
    slen = slen_ref[0]

    def load_page(ref, b_, s_):
        return pl.load(ref, (pl.dslice(0, 1), pl.dslice(b_, 1),
                             pl.dslice(s_, 1), slice(None), slice(None),
                             slice(None)))[0, 0, 0]

    def step(t, carry):
        m, s, acc = carry
        bank = t % nb
        slot = t // nb
        sib = bank ^ 1
        grp = bank // 2
        use_par = pl.load(upar_ref, (pl.dslice(0, 1), pl.dslice(t, 1)))[0, 0] > 0
        k_dir = load_page(kb_ref, bank, slot)              # (P, Hkv, D) uint
        k_rec = load_page(kb_ref, sib, slot) ^ load_page(kp_ref, grp, slot)
        v_dir = load_page(vb_ref, bank, slot)
        v_rec = load_page(vb_ref, sib, slot) ^ load_page(vp_ref, grp, slot)
        k_bits = jnp.where(use_par, k_rec, k_dir)
        v_bits = jnp.where(use_par, v_rec, v_dir)
        k = jax.lax.bitcast_convert_type(k_bits, value_dtype).astype(jnp.float32)
        v = jax.lax.bitcast_convert_type(v_bits, value_dtype).astype(jnp.float32)
        # scores (G, Hkv, P)
        logits = jax.lax.dot_general(
            qr, k, (((2,), (2,)), ((1,), (1,))),
            preferred_element_type=jnp.float32,
        )  # dims: contract D, batch Hkv -> (Hkv, G, P)
        logits = jnp.transpose(logits, (1, 0, 2)) * (d ** -0.5)  # (G, Hkv, P)
        tok = t * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
        logits = jnp.where(tok < slen, logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        probs = jnp.exp(logits - m_new[..., None])
        probs = jnp.where(tok < slen, probs, 0.0)
        s = s * alpha + jnp.sum(probs, axis=-1)
        # pv: (G, Hkv, P) x (P, Hkv, D) -> (G, Hkv, D)
        pv = jax.lax.dot_general(
            probs, v, (((2,), (0,)), ((1,), (1,))),
            preferred_element_type=jnp.float32,
        )  # (Hkv, G, D)
        acc = acc * alpha[..., None] + jnp.transpose(pv, (1, 0, 2))
        return m_new, s, acc

    m0 = jnp.full((g, hkv), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((g, hkv), jnp.float32)
    a0 = jnp.zeros((g, hkv, d), jnp.float32)
    m, s, acc = jax.lax.fori_loop(0, n_pages, step, (m0, s0, a0))

    out = acc / jnp.maximum(s, 1e-30)[..., None]
    out_ref[0] = out.reshape(h, d).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("value_dtype", "interpret")
)
def coded_kv_decode_pallas(
    q: jnp.ndarray,        # (B, H, D) value dtype
    k_banks: jnp.ndarray,  # (B, NB, S, P, Hkv, D) uint lanes
    v_banks: jnp.ndarray,
    k_par: jnp.ndarray,    # (B, NB//2, S, P, Hkv, D) uint lanes
    v_par: jnp.ndarray,
    use_parity: jnp.ndarray,  # (B, n_pages) int32
    seq_len: jnp.ndarray,     # (B,) int32
    *,
    value_dtype=jnp.float32,
    interpret=None,
) -> jnp.ndarray:
    b, h, d = q.shape
    _, nb, s_, p_, hkv, _ = k_banks.shape
    n_pages = use_parity.shape[1]
    assert n_pages <= nb * s_
    interpret = resolve_interpret(interpret)
    kernel = functools.partial(
        _kv_decode_kernel, value_dtype=jnp.dtype(value_dtype),
        n_pages=n_pages, nb=nb, page=p_,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, nb, s_, p_, hkv, d), lambda i: (i, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, nb, s_, p_, hkv, d), lambda i: (i, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, nb // 2, s_, p_, hkv, d), lambda i: (i, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, nb // 2, s_, p_, hkv, d), lambda i: (i, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, n_pages), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(q, k_banks, v_banks, k_par, v_par, use_parity, seq_len)


# ---------------------------------------------------------------------------
# Serving pool gather: pool-indirected page reconstruction
# ---------------------------------------------------------------------------

def _load_pool_page(ref, b_, s_):
    return pl.load(ref, (pl.dslice(b_, 1), pl.dslice(s_, 1),
                         slice(None), slice(None), slice(None)))[0, 0]


def _pool_gather_kernel(pt_ref, up_ref, kb_ref, vb_ref, kp_ref, vp_ref,
                        ko_ref, vo_ref, *, nb):
    phys = pt_ref[0, 0]
    alloc = phys >= 0
    ph = jnp.maximum(phys, 0)
    bank = ph % nb
    slot = ph // nb
    use_par = up_ref[0, 0] > 0
    k_dir = _load_pool_page(kb_ref, bank, slot)            # (P, Hkv, D)
    v_dir = _load_pool_page(vb_ref, bank, slot)
    k_rec = _load_pool_page(kb_ref, bank ^ 1, slot) \
        ^ _load_pool_page(kp_ref, bank // 2, slot)
    v_rec = _load_pool_page(vb_ref, bank ^ 1, slot) \
        ^ _load_pool_page(vp_ref, bank // 2, slot)
    k = jnp.where(use_par, k_rec, k_dir)
    v = jnp.where(use_par, v_rec, v_dir)
    ko_ref[0, 0] = jnp.where(alloc, k, 0)
    vo_ref[0, 0] = jnp.where(alloc, v, 0)


def _pool_gather_uncoded_kernel(pt_ref, kb_ref, vb_ref, ko_ref, vo_ref,
                                *, nb):
    phys = pt_ref[0, 0]
    alloc = phys >= 0
    ph = jnp.maximum(phys, 0)
    bank = ph % nb
    slot = ph // nb
    ko_ref[0, 0] = jnp.where(alloc, _load_pool_page(kb_ref, bank, slot), 0)
    vo_ref[0, 0] = jnp.where(alloc, _load_pool_page(vb_ref, bank, slot), 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_pool_pallas(
    k_banks: jnp.ndarray,     # (NB, S, P, Hkv, D) uint lanes (shared pool)
    v_banks: jnp.ndarray,
    k_par: jnp.ndarray,       # (NG, S, P, Hkv, D); NG == 0 ⇒ uncoded
    v_par: jnp.ndarray,
    page_table: jnp.ndarray,  # (B, MP) int32 physical page id, -1 free
    use_parity: jnp.ndarray,  # (B, MP) bool/int
    *,
    interpret=None,
):
    """Pool-indirected coded page gather: (B, MP, P, Hkv, D) uint K/V.

    Grid ``(B, MP)`` — one logical page per step, reconstructed with the
    planned direct or degraded (sibling ^ parity) read. Pure uint
    select/XOR, so the result is bit-exact vs the reference
    ``gather_pool_layer`` for any plan; unallocated pages read as zero.
    The uncoded pool (NG == 0) compiles a kernel with no parity operands.
    """
    interpret = resolve_interpret(interpret)
    nb, s_, pg, hkv, d = k_banks.shape
    b, mp = page_table.shape
    ng = k_par.shape[0]
    grid = (b, mp)
    bank_spec = pl.BlockSpec((nb, s_, pg, hkv, d),
                             lambda i, p: (0, 0, 0, 0, 0))
    tab_spec = pl.BlockSpec((1, 1), lambda i, p: (i, p))
    out_spec = pl.BlockSpec((1, 1, pg, hkv, d), lambda i, p: (i, p, 0, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((b, mp, pg, hkv, d), k_banks.dtype)] * 2
    if ng == 0:
        return pl.pallas_call(
            functools.partial(_pool_gather_uncoded_kernel, nb=nb),
            out_shape=out_shape,
            grid=grid,
            in_specs=[tab_spec, bank_spec, bank_spec],
            out_specs=[out_spec, out_spec],
            interpret=interpret,
        )(page_table, k_banks, v_banks)
    par_spec = pl.BlockSpec((ng, s_, pg, hkv, d),
                            lambda i, p: (0, 0, 0, 0, 0))
    return pl.pallas_call(
        functools.partial(_pool_gather_kernel, nb=nb),
        out_shape=out_shape,
        grid=grid,
        in_specs=[tab_spec, tab_spec, bank_spec, bank_spec,
                  par_spec, par_spec],
        out_specs=[out_spec, out_spec],
        interpret=interpret,
    )(page_table, use_parity.astype(jnp.int32), k_banks, v_banks,
      k_par, v_par)
