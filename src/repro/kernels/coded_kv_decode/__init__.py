from repro.kernels.coded_kv_decode.ops import coded_kv_decode, pack_kv_banks  # noqa: F401
from repro.kernels.coded_kv_decode.ref import decode_attention_ref  # noqa: F401
