"""Pure-jnp oracle for the XOR parity encoder."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import uint_view_dtype


def encode_parities_ref(banks: jnp.ndarray, members: jnp.ndarray) -> jnp.ndarray:
    """banks (n_data, L, W), members (n_par, k) -> raw-bit parities.

    Matches ops.encode_parities: output is the unsigned lane view dtype.
    """
    if jnp.issubdtype(banks.dtype, jnp.floating):
        banks = jax.lax.bitcast_convert_type(banks, uint_view_dtype(banks.dtype))
    n_par = members.shape[0]
    _, L, W = banks.shape
    out = jnp.zeros((n_par, L, W), banks.dtype)
    for mm in range(members.shape[1]):
        m = members[:, mm]                                  # (n_par,)
        slab = banks[jnp.maximum(m, 0)]                      # (n_par, L, W)
        slab = jnp.where((m >= 0)[:, None, None], slab, jnp.zeros_like(slab))
        out = out ^ slab
    return out
