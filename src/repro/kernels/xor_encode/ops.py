"""Jitted public wrapper for the XOR parity encoder."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import uint_view_dtype
from repro.kernels.xor_encode.kernel import encode_parities_pallas


def encode_parities(
    banks: jnp.ndarray,
    members,
    *,
    block_rows: int = 128,
    interpret=None,
) -> jnp.ndarray:
    """Encode parity banks ``p_j = XOR_m banks[m]`` (bit-exact, any dtype).

    Float banks are bitcast to their unsigned lane view; the returned parity
    banks are *raw bits* (uint dtype) — they are code symbols, not numbers.
    ``members`` may be a numpy/int list table of shape (n_par, <=3); it is
    padded to width 3 with -1.
    """
    members = np.asarray(members, np.int32)
    if members.ndim != 2:
        raise ValueError("members must be (n_par, k)")
    if members.shape[1] < 3:
        pad = np.full((members.shape[0], 3 - members.shape[1]), -1, np.int32)
        members = np.concatenate([members, pad], axis=1)
    if jnp.issubdtype(banks.dtype, jnp.floating):
        banks = jax.lax.bitcast_convert_type(banks, uint_view_dtype(banks.dtype))
    return encode_parities_pallas(
        banks, jnp.asarray(members), block_rows=block_rows, interpret=interpret
    )
