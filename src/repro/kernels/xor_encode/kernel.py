"""Pallas TPU kernel: XOR parity encode (the ReCoding-unit datapath, §IV-D).

Given stacked data banks ``(n_data, L, W)`` and a member table
``(n_par, 3)`` (-1 padded), produce parity banks ``(n_par, L, W)`` with
``p_j(i) = XOR_{m in members_j} bank_m(i)``.

Tiling: grid ``(L / BL, n_par)``; each step holds a ``(n_data, BL, W)``
slab of all data banks in VMEM (the encode reads every member anyway, and
row tiles are reused across the ``n_par`` inner grid dimension so the slab
is fetched once per row tile, not once per parity) and writes one
``(1, BL, W)`` parity tile. ``W`` should be a multiple of 128 (VPU lanes)
and ``BL`` a multiple of 8 (f32 sublanes; 16 for bf16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _encode_kernel(members_ref, banks_ref, out_ref):
    j = pl.program_id(1)
    acc = None
    for mm in range(members_ref.shape[1]):
        m = members_ref[j, mm]
        slab = pl.load(banks_ref, (pl.dslice(jnp.maximum(m, 0), 1), slice(None), slice(None)))
        slab = jnp.where(m >= 0, slab, jnp.zeros_like(slab))
        acc = slab if acc is None else acc ^ slab
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def encode_parities_pallas(
    banks: jnp.ndarray,     # (n_data, L, W) unsigned-int lane view
    members: jnp.ndarray,   # (n_par, 3) int32, -1 padded
    *,
    block_rows: int = 128,
    interpret=None,
) -> jnp.ndarray:
    """Integer-lane parity encode. Callers bitcast float banks to their uint
    lane view first (see ops.encode_parities): parity banks are raw bits, not
    numbers, and float ops on CPU/TPU may canonicalize NaN payloads.
    ``interpret=None`` resolves from the backend (docs/kernels.md)."""
    assert jnp.issubdtype(banks.dtype, jnp.integer), banks.dtype
    interpret = resolve_interpret(interpret)
    n_data, L, W = banks.shape
    n_par = members.shape[0]
    bl = min(block_rows, L)
    assert L % bl == 0, (L, bl)
    grid = (L // bl, n_par)
    return pl.pallas_call(
        _encode_kernel,
        out_shape=jax.ShapeDtypeStruct((n_par, L, W), banks.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_par, members.shape[1]), lambda t, j: (0, 0)),
            pl.BlockSpec((n_data, bl, W), lambda t, j: (0, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, bl, W), lambda t, j: (j, t, 0)),
        interpret=interpret,
    )(members, banks)
