from repro.kernels.xor_encode.ops import encode_parities  # noqa: F401
from repro.kernels.xor_encode.ref import encode_parities_ref  # noqa: F401
