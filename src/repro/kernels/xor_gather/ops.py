"""Public wrapper: coded gather + the controller-plan → kernel-plan bridge."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.codes import MAX_OPTS, CodeTables
from repro.core.controller import MODE_OPT0, MODE_REDIRECT, ReadPlan
from repro.kernels.common import uint_view_dtype
from repro.kernels.xor_gather.kernel import gather_decode_pallas


class PlanColumns(NamedTuple):
    bank: jnp.ndarray
    row: jnp.ndarray
    mode: jnp.ndarray
    par: jnp.ndarray
    prow: jnp.ndarray
    sib0: jnp.ndarray
    sib1: jnp.ndarray


def plan_columns(
    tables: CodeTables,
    plan: ReadPlan,
    cand_bank: jnp.ndarray,
    cand_row: jnp.ndarray,
    region_slot: jnp.ndarray,
    region_size: int,
    fresh_loc: jnp.ndarray,
) -> PlanColumns:
    """Expand a controller ReadPlan into the kernel's per-request columns."""
    b = jnp.maximum(cand_bank, 0)
    i = jnp.maximum(cand_row, 0)
    opt_parity = jnp.asarray(tables.opt_parity)
    opt_sibs = jnp.asarray(tables.opt_sibs)
    k = jnp.clip(plan.mode - MODE_OPT0, 0, MAX_OPTS - 1)
    is_opt = (plan.mode >= MODE_OPT0) & (plan.mode < MODE_REDIRECT)
    is_rd = plan.mode == MODE_REDIRECT
    j_opt = opt_parity[b, k]
    j_rd = jnp.maximum(fresh_loc[b, i] - 1, 0)
    par = jnp.where(is_opt, j_opt, jnp.where(is_rd, j_rd, 0))
    slot = region_slot[i // region_size]
    prow = jnp.maximum(slot, 0) * region_size + i % region_size
    sib0 = jnp.where(is_opt, opt_sibs[b, k, 0], -1)
    sib1 = jnp.where(is_opt, opt_sibs[b, k, 1], -1)
    mode = jnp.where(plan.served, plan.mode, -1)
    return PlanColumns(b.astype(jnp.int32), i.astype(jnp.int32), mode,
                       par.astype(jnp.int32), prow.astype(jnp.int32),
                       sib0.astype(jnp.int32), sib1.astype(jnp.int32))


def gather_decode(
    banks: jnp.ndarray,
    parities: jnp.ndarray,
    cols: PlanColumns,
    *,
    req_block: int = 8,
    interpret=None,
    value_dtype=None,
) -> jnp.ndarray:
    """Serve one cycle's read pattern. Returns (N, W) rows in ``value_dtype``
    (defaults to ``banks.dtype``); unserved entries are zero-filled. Any N
    is accepted, including an empty plan — the pallas wrapper pads requests
    to a full tile with -1 and strips the pad on return."""
    if value_dtype is None:
        value_dtype = banks.dtype
    if jnp.issubdtype(banks.dtype, jnp.floating):
        banks = jax.lax.bitcast_convert_type(banks, uint_view_dtype(banks.dtype))
    if jnp.issubdtype(parities.dtype, jnp.floating):
        parities = jax.lax.bitcast_convert_type(parities, uint_view_dtype(parities.dtype))
    if parities.dtype != banks.dtype:
        raise TypeError(f"lane dtype mismatch: {banks.dtype} vs {parities.dtype}")
    out = gather_decode_pallas(
        banks, parities, cols.bank, cols.row, cols.mode, cols.par, cols.prow,
        cols.sib0, cols.sib1, req_block=req_block, interpret=interpret,
    )
    if jnp.dtype(value_dtype) != out.dtype:
        out = jax.lax.bitcast_convert_type(out, value_dtype)
    return out
