from repro.kernels.xor_gather.ops import gather_decode, plan_columns  # noqa: F401
from repro.kernels.xor_gather.ref import gather_decode_ref  # noqa: F401
