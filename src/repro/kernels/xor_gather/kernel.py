"""Pallas TPU kernel: coded row gather (the read-pattern datapath, §IV-B).

Executes one memory cycle's read pattern against streamed bank row tiles:
each request is served either directly (``banks[bank, row]``), by a degraded
read (``parities[par, prow] ^ banks[sib0, row] ^ banks[sib1, row]``), or by a
redirect of a parked value (``parities[par, prow]``). All lanes are unsigned
integers (raw bits); callers bitcast float data outside.

Tiling (docs/kernels.md): grid ``(N / RB, L / BT)`` — request tiles in the
outer dimension, data-bank row tiles streamed through VMEM in the inner
dimension, so the data banks never live whole in VMEM. Requests bucket to
row tiles by compare (a request only contributes lanes from the tile that
holds its row), and the out tile XOR-accumulates across the inner grid
dimension. The parity banks — the small arrays, and reachable from any row
tile via redirects — stay VMEM-resident and contribute on the first tile.

The request lane is fully vectorized (no scalar per-request loop): one-hot
masks over the ``(ND, BT)`` tile select only the lanes each mode needs —
the direct lane for modes 0/1, the two sibling lanes for degraded options,
the parity lane for options and redirects — and the XOR of the selected
lanes IS the decode.

Mode encoding matches repro.core.controller: 0 FROM_SYM, 1 DIRECT,
2..2+MAX_OPTS-1 degraded options, 2+MAX_OPTS REDIRECT; -1 entries yield 0.
Served requests carry in-range lane indices by contract (``plan_columns``
clamps); -1 padding rows added by the wrapper select nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codes import MAX_OPTS
from repro.kernels.common import resolve_interpret

MODE_REDIRECT = 2 + MAX_OPTS


def _lane_xor(sel, tile):
    """XOR of the selected lanes: ``sel`` (RB, NB, BT) marks at most one row
    per (request, bank), so the row reduction is an exact select via sum;
    banks fold with XOR (a degraded read keeps two sibling lanes live)."""
    picked = jnp.where(sel[..., None], tile[None], 0)
    per_bank = jnp.sum(picked, axis=2, dtype=tile.dtype)    # (RB, NB, W)
    acc = per_bank[:, 0]
    for bi in range(1, per_bank.shape[1]):
        acc = acc ^ per_bank[:, bi]
    return acc


def _gather_kernel(bank_ref, row_ref, mode_ref, par_ref, prow_ref,
                   sib0_ref, sib1_ref, banks_ref, par_banks_ref, out_ref):
    rt = pl.program_id(1)
    rb = bank_ref.shape[0]
    nd, bt, _ = banks_ref.shape
    n_par, lp, _ = par_banks_ref.shape

    mode = mode_ref[:]
    served = mode >= 0
    is_opt = (mode >= 2) & (mode < MODE_REDIRECT)
    need_dir = served & (mode < 2)           # FROM_SYM / DIRECT lane
    need_par = served & (mode >= 2)          # degraded options + redirect

    # data lanes: this row tile covers rows [rt*BT, rt*BT + BT)
    row = row_ref[:] - rt * bt               # tile-local request row
    b_ids = jax.lax.broadcasted_iota(jnp.int32, (rb, nd, bt), 1)
    r_ids = jax.lax.broadcasted_iota(jnp.int32, (rb, nd, bt), 2)
    at_row = r_ids == row[:, None, None]
    sel = need_dir[:, None, None] & at_row \
        & (b_ids == bank_ref[:][:, None, None])
    sel |= ((is_opt & (sib0_ref[:] >= 0))[:, None, None] & at_row
            & (b_ids == sib0_ref[:][:, None, None]))
    sel |= ((is_opt & (sib1_ref[:] >= 0))[:, None, None] & at_row
            & (b_ids == sib1_ref[:][:, None, None]))
    acc = _lane_xor(sel, banks_ref[:])

    # parity lane (VMEM-resident block): contribute on the first tile only,
    # so accumulation over row tiles never double-XORs it
    p_ids = jax.lax.broadcasted_iota(jnp.int32, (rb, n_par, lp), 1)
    q_ids = jax.lax.broadcasted_iota(jnp.int32, (rb, n_par, lp), 2)
    psel = ((need_par & (rt == 0))[:, None, None]
            & (p_ids == par_ref[:][:, None, None])
            & (q_ids == prow_ref[:][:, None, None]))
    acc = acc ^ _lane_xor(psel, par_banks_ref[:])

    @pl.when(rt == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(rt > 0)
    def _fold():
        out_ref[...] ^= acc


def _row_tile(n_rows: int, want: int) -> int:
    """Largest divisor of ``n_rows`` that is <= ``want`` (at least 1)."""
    bt = max(1, min(want, n_rows))
    while n_rows % bt:
        bt -= 1
    return bt


@functools.partial(
    jax.jit, static_argnames=("req_block", "row_block", "interpret"))
def gather_decode_pallas(
    banks: jnp.ndarray,      # (n_data, L, W) uint lanes
    parities: jnp.ndarray,   # (n_par, Lp, W) uint lanes
    bank: jnp.ndarray,       # (N,) int32
    row: jnp.ndarray,        # (N,) int32
    mode: jnp.ndarray,       # (N,) int32
    par: jnp.ndarray,        # (N,) int32 logical parity index
    prow: jnp.ndarray,       # (N,) int32 parity row
    sib0: jnp.ndarray,       # (N,) int32
    sib1: jnp.ndarray,       # (N,) int32
    *,
    req_block: int = 8,
    row_block: int = 128,
    interpret=None,
) -> jnp.ndarray:
    """(N, W) gathered rows for any N — requests are padded to a full
    request tile with -1 (mode -1 selects nothing) and the pad is stripped
    on return, so direct callers never hit a tile-divisibility assert. An
    empty plan (N=0) short-circuits without tracing the kernel (a 0-size
    grid would divide by zero)."""
    assert jnp.issubdtype(banks.dtype, jnp.integer), banks.dtype
    interpret = resolve_interpret(interpret)
    n_data, L, W = banks.shape
    n_par, Lp, _ = parities.shape
    n = bank.shape[0]
    if n == 0:
        return jnp.zeros((0, W), banks.dtype)
    rb = min(req_block, n)
    pad = (-n) % rb
    cols = (bank, row, mode, par, prow, sib0, sib1)
    if pad:
        cols = tuple(jnp.pad(c, (0, pad), constant_values=-1) for c in cols)
    n_pad = n + pad
    bt = _row_tile(L, row_block)
    grid = (n_pad // rb, L // bt)
    col_spec = pl.BlockSpec((rb,), lambda t, r: (t,))
    out = pl.pallas_call(
        _gather_kernel,
        out_shape=jax.ShapeDtypeStruct((n_pad, W), banks.dtype),
        grid=grid,
        in_specs=[col_spec] * 7 + [
            pl.BlockSpec((n_data, bt, W), lambda t, r: (0, r, 0)),
            pl.BlockSpec((n_par, Lp, W), lambda t, r: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, W), lambda t, r: (t, 0)),
        interpret=interpret,
    )(*cols, banks, parities)
    return out[:n] if pad else out
