"""Pallas TPU kernel: coded row gather (the read-pattern datapath, §IV-B).

Executes one memory cycle's read pattern against VMEM-resident bank tiles:
each request is served either directly (``banks[bank, row]``), by a degraded
read (``parities[par, prow] ^ banks[sib0, row] ^ banks[sib1, row]``), or by a
redirect of a parked value (``parities[par, prow]``). All lanes are unsigned
integers (raw bits); callers bitcast float data outside.

Tiling: grid ``(N / RB,)`` over request tiles; banks/parities are held as
whole VMEM blocks (the "row buffer" of the adapted design — for larger banks
the production layout streams row tiles via a second grid dimension and
buckets requests per tile; see DESIGN.md §3). Request columns are scalar
int32 vectors of length RB per step.

Mode encoding matches repro.core.controller: 0 FROM_SYM, 1 DIRECT,
2..2+MAX_OPTS-1 degraded options, 2+MAX_OPTS REDIRECT; -1 entries yield 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codes import MAX_OPTS

MODE_REDIRECT = 2 + MAX_OPTS


def _gather_kernel(bank_ref, row_ref, mode_ref, par_ref, prow_ref,
                   sib0_ref, sib1_ref, banks_ref, par_banks_ref, out_ref):
    rb = bank_ref.shape[0]
    for q in range(rb):
        mode = mode_ref[q]
        b = jnp.maximum(bank_ref[q], 0)
        i = jnp.maximum(row_ref[q], 0)
        j = jnp.maximum(par_ref[q], 0)
        pr = jnp.maximum(prow_ref[q], 0)
        s0 = sib0_ref[q]
        s1 = sib1_ref[q]
        direct = pl.load(banks_ref, (pl.dslice(b, 1), pl.dslice(i, 1), slice(None)))[0, 0]
        pline = pl.load(par_banks_ref, (pl.dslice(j, 1), pl.dslice(pr, 1), slice(None)))[0, 0]
        v0 = pl.load(banks_ref, (pl.dslice(jnp.maximum(s0, 0), 1), pl.dslice(i, 1), slice(None)))[0, 0]
        v1 = pl.load(banks_ref, (pl.dslice(jnp.maximum(s1, 0), 1), pl.dslice(i, 1), slice(None)))[0, 0]
        zero = jnp.zeros_like(direct)
        dec = pline ^ jnp.where(s0 >= 0, v0, zero) ^ jnp.where(s1 >= 0, v1, zero)
        is_opt = (mode >= 2) & (mode < MODE_REDIRECT)
        val = jnp.where(
            mode == MODE_REDIRECT, pline, jnp.where(is_opt, dec, direct)
        )
        val = jnp.where(mode >= 0, val, zero)
        out_ref[q, :] = val


@functools.partial(jax.jit, static_argnames=("req_block", "interpret"))
def gather_decode_pallas(
    banks: jnp.ndarray,      # (n_data, L, W) uint lanes
    parities: jnp.ndarray,   # (n_par, Lp, W) uint lanes
    bank: jnp.ndarray,       # (N,) int32
    row: jnp.ndarray,        # (N,) int32
    mode: jnp.ndarray,       # (N,) int32
    par: jnp.ndarray,        # (N,) int32 logical parity index
    prow: jnp.ndarray,       # (N,) int32 parity row
    sib0: jnp.ndarray,       # (N,) int32
    sib1: jnp.ndarray,       # (N,) int32
    *,
    req_block: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    assert jnp.issubdtype(banks.dtype, jnp.integer), banks.dtype
    n_data, L, W = banks.shape
    n_par, Lp, _ = parities.shape
    n = bank.shape[0]
    rb = min(req_block, n)
    assert n % rb == 0, (n, rb)
    grid = (n // rb,)
    col = lambda g: pl.BlockSpec((rb,), lambda t: (t,))  # noqa: E731
    return pl.pallas_call(
        _gather_kernel,
        out_shape=jax.ShapeDtypeStruct((n, W), banks.dtype),
        grid=grid,
        in_specs=[col(0)] * 7 + [
            pl.BlockSpec((n_data, L, W), lambda t: (0, 0, 0)),
            pl.BlockSpec((n_par, Lp, W), lambda t: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, W), lambda t: (t, 0)),
        interpret=interpret,
    )(bank, row, mode, par, prow, sib0, sib1, banks, parities)
