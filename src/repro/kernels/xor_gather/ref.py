"""Pure-jnp oracle for the coded row gather."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codes import MAX_OPTS
from repro.kernels.common import uint_view_dtype

MODE_REDIRECT = 2 + MAX_OPTS


def gather_decode_ref(banks, parities, bank, row, mode, par, prow, sib0, sib1):
    """Vectorized reference; same raw-bit (uint) semantics as the kernel."""
    if jnp.issubdtype(banks.dtype, jnp.floating):
        banks = jax.lax.bitcast_convert_type(banks, uint_view_dtype(banks.dtype))
    if jnp.issubdtype(parities.dtype, jnp.floating):
        parities = jax.lax.bitcast_convert_type(parities, uint_view_dtype(parities.dtype))
    b = jnp.maximum(bank, 0)
    i = jnp.maximum(row, 0)
    j = jnp.maximum(par, 0)
    pr = jnp.maximum(prow, 0)
    direct = banks[b, i]                      # (N, W)
    pline = parities[j, pr]                   # (N, W)
    zero = jnp.zeros_like(direct)
    v0 = jnp.where((sib0 >= 0)[:, None], banks[jnp.maximum(sib0, 0), i], zero)
    v1 = jnp.where((sib1 >= 0)[:, None], banks[jnp.maximum(sib1, 0), i], zero)
    dec = pline ^ v0 ^ v1
    is_opt = ((mode >= 2) & (mode < MODE_REDIRECT))[:, None]
    val = jnp.where((mode == MODE_REDIRECT)[:, None], pline, jnp.where(is_opt, dec, direct))
    return jnp.where((mode >= 0)[:, None], val, zero)
