"""Pallas TPU kernels for the coded-memory datapath (VMEM-tiled, validated
against pure-jnp oracles in interpret mode; TPU is the target).

  xor_encode      — parity encode (ReCoding unit datapath)
  xor_gather      — coded row gather incl. degraded reads (read datapath)
  coded_kv_decode — decode attention over a banked, pair-parity KV cache
"""
