"""Shared helpers for the coded-memory Pallas kernels.

XOR parity over floating-point rows is done on bitcast unsigned views so the
coding is *bit-exact* for any dtype (the paper XORs raw DRAM words; on TPU we
XOR the 16-/32-bit lanes of the row's vector registers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_UINT_OF = {
    jnp.dtype(jnp.bfloat16): jnp.uint16,
    jnp.dtype(jnp.float16): jnp.uint16,
    jnp.dtype(jnp.float32): jnp.uint32,
    jnp.dtype(jnp.int32): jnp.uint32,
    jnp.dtype(jnp.uint32): jnp.uint32,
    jnp.dtype(jnp.int16): jnp.uint16,
    jnp.dtype(jnp.uint16): jnp.uint16,
    jnp.dtype(jnp.int8): jnp.uint8,
    jnp.dtype(jnp.uint8): jnp.uint8,
}


def resolve_interpret(interpret=None) -> bool:
    """Kernel interpret-mode policy (docs/kernels.md).

    ``None`` resolves from the backend: compile natively on TPU, fall back
    to the Pallas interpreter everywhere else (CI stays hardware-free). An
    explicit bool always wins — tests pin ``True``, hardware benchmarks may
    pin ``False`` to fail loudly on an unexpected backend. Non-test call
    sites must not hard-code ``interpret=True`` (the ``kernel-interpret``
    analysis rule), or hardware runs silently execute the CPU interpreter.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def uint_view_dtype(dtype) -> jnp.dtype:
    d = jnp.dtype(dtype)
    if d not in _UINT_OF:
        raise TypeError(f"no XOR lane type for dtype {d}")
    return jnp.dtype(_UINT_OF[d])


def bxor(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Bit-exact XOR of two same-dtype arrays (float dtypes via bitcast)."""
    if jnp.issubdtype(a.dtype, jnp.integer):
        return a ^ b
    u = uint_view_dtype(a.dtype)
    au = jax.lax.bitcast_convert_type(a, u)
    bu = jax.lax.bitcast_convert_type(b, u)
    return jax.lax.bitcast_convert_type(au ^ bu, a.dtype)
