"""Fault model: per-bank erasure schedules and transient port stutters.

A *fault plan* is a static schedule attached to one simulation run:

* **Bank erasure** — data bank ``b`` fails at ``fail_at[b]`` (its single
  port becomes permanently busy; its stored rows become unreadable) and
  optionally begins recovery at ``recover_at[b]``. A recovering bank's rows
  are rebuilt through the ReCoding ring (see ``repro.faults.inject`` and
  ``repro.core.recoding``); the bank rejoins normal service only once the
  rebuild sweep completes (``rebuilt[b]`` latches). Only data banks fail —
  parity banks are the redundancy the paper's schemes spend area on, and a
  lost parity is silent (never read unless degraded) rather than
  availability-relevant.
* **Port stutter** — port ``q`` (data or parity) is transiently busy one
  cycle out of every ``stutter_period[q]`` (at phase ``stutter_phase[q]``),
  modelling refresh/calibration hiccups. Stutters never lose data.

The schedule and the mutable progress/counters ride the scan carry as a
``FaultState`` leaf of ``MemState`` behind the static ``MemParams.faults``
flag: faults off ⇒ the leaf is ``None`` (an empty pytree node) and the
compiled program is bit-identical to one built before faults existed — the
exact gating trick ``telemetry`` and ``traced_geometry`` use. Carrying the
(constant) schedule arrays in the state is what lets a vmapped sweep batch
*different* fault plans through one compiled program.

This module must stay importable by ``repro.core.state`` (the leaf type),
so it imports **nothing from repro** — only jax/numpy. The NumPy golden
model re-derives every rule independently in ``repro.oracle.model``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

INT32_MAX = np.iinfo(np.int32).max
NEVER = INT32_MAX   # fail_at / recover_at sentinel: the event never happens


class FaultState(NamedTuple):
    """Per-point fault schedule + progress (jnp arrays; a scan-carry leaf).

    The schedule half (``fail_at`` … ``stutter_phase``) is constant over a
    run; the rest mutates each cycle. All derived per-cycle predicates
    (down / rebuilding / stutter) are pure functions of this leaf and the
    cycle counter — see ``bank_down`` etc. below.
    """

    fail_at: jnp.ndarray         # (n_data,) int32; NEVER = no failure
    recover_at: jnp.ndarray      # (n_data,) int32; NEVER = no recovery
    stutter_period: jnp.ndarray  # (n_ports,) int32; 0 = no stutter
    stutter_phase: jnp.ndarray   # (n_ports,) int32
    rebuilt: jnp.ndarray         # (n_data,) bool — rebuild-complete latch
    rebuild_ptr: jnp.ndarray     # () int32 — flat (bank*n_rows+row) sweep
                                 # cursor of the online rebuild scanner
    unserved_reads: jnp.ndarray  # () int32 — reads failed fast (no serving
                                 # option exists under the current faults)
    lost_writes: jnp.ndarray     # () int32 — writes to a down bank with no
                                 # parity coverage to park into (data loss)
    fault_degraded: jnp.ndarray  # () int32 — reads degraded *because* their
                                 # bank is down (subset of degraded_reads)
    dead_cycles: jnp.ndarray     # (n_data,) uint32 — cycles spent down


def init_fault_state(n_data: int, n_ports: int) -> FaultState:
    """The no-fault schedule (nothing ever fails or stutters)."""
    return FaultState(
        fail_at=jnp.full((n_data,), NEVER, jnp.int32),
        recover_at=jnp.full((n_data,), NEVER, jnp.int32),
        stutter_period=jnp.zeros((n_ports,), jnp.int32),
        stutter_phase=jnp.zeros((n_ports,), jnp.int32),
        rebuilt=jnp.zeros((n_data,), bool),
        rebuild_ptr=jnp.int32(0),
        unserved_reads=jnp.int32(0),
        lost_writes=jnp.int32(0),
        fault_degraded=jnp.int32(0),
        dead_cycles=jnp.zeros((n_data,), jnp.uint32),
    )


# --------------------------------------------------- per-cycle predicates
def bank_down(f: FaultState, cycle) -> jnp.ndarray:
    """(n_data,) — failed and not yet fully rebuilt (dead OR rebuilding);
    the pattern builders treat a down bank's port as permanently busy."""
    return (f.fail_at <= cycle) & ~f.rebuilt


def bank_rebuilding(f: FaultState, cycle) -> jnp.ndarray:
    """(n_data,) — recovery has begun but the rebuild sweep hasn't finished.
    The bank stays down for the builders; only the ReCoding unit may use
    its port (restoring parked rows / recomputing stale parities)."""
    return bank_down(f, cycle) & (f.recover_at <= cycle)


def stutter_busy(f: FaultState, cycle) -> jnp.ndarray:
    """(n_ports,) — transiently busy ports this cycle."""
    per = f.stutter_period
    return (per > 0) & (cycle % jnp.maximum(per, 1) == f.stutter_phase)


# ------------------------------------------------------- host-side plans
@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Hashable host-side fault schedule (the sweep-axis value).

    ``bank_faults`` — ``(bank, fail_at, recover_at)`` triples; ``recover_at
    < 0`` means the bank never recovers. ``stutters`` — ``(port, period,
    phase)`` triples. Build from a flat spec tuple (the ``SweepPoint.faults``
    grammar) with ``from_spec``; lower to the device leaf with ``state()``.
    """

    n_data: int
    n_ports: int
    bank_faults: Tuple[Tuple[int, int, int], ...] = ()
    stutters: Tuple[Tuple[int, int, int], ...] = ()

    def __post_init__(self):
        for b, fail, rec in self.bank_faults:
            if not 0 <= b < self.n_data:
                raise ValueError(f"fault bank {b} out of range "
                                 f"[0, {self.n_data})")
            if fail < 0:
                raise ValueError(f"bank {b}: fail_at={fail} < 0")
            if 0 <= rec <= fail:
                raise ValueError(
                    f"bank {b}: recover_at={rec} <= fail_at={fail}")
        seen = set()
        for b, _, _ in self.bank_faults:
            if b in seen:
                raise ValueError(f"bank {b} listed twice in bank_faults")
            seen.add(b)
        for q, per, ph in self.stutters:
            if not 0 <= q < self.n_ports:
                raise ValueError(f"stutter port {q} out of range "
                                 f"[0, {self.n_ports})")
            if per <= 0 or not 0 <= ph < per:
                raise ValueError(
                    f"port {q}: need period > 0 and 0 <= phase < period "
                    f"(got period={per}, phase={ph})")

    @staticmethod
    def from_spec(spec: Tuple, n_data: int, n_ports: int) -> "FaultPlan":
        """Parse the flat ``SweepPoint.faults`` grammar:
        ``("bank", b, fail_at[, recover_at])`` and
        ``("stutter", port, period[, phase])`` entries."""
        banks, stutters = [], []
        for entry in spec:
            kind, rest = entry[0], entry[1:]
            if kind == "bank":
                b, fail = int(rest[0]), int(rest[1])
                rec = int(rest[2]) if len(rest) > 2 else -1
                banks.append((b, fail, rec))
            elif kind == "stutter":
                q, per = int(rest[0]), int(rest[1])
                ph = int(rest[2]) if len(rest) > 2 else 0
                stutters.append((q, per, ph))
            else:
                raise ValueError(f"unknown fault spec entry kind {kind!r} "
                                 "(want 'bank' or 'stutter')")
        return FaultPlan(n_data=n_data, n_ports=n_ports,
                         bank_faults=tuple(banks), stutters=tuple(stutters))

    # ---- numpy schedule arrays (shared with the oracle's mirror)
    def schedule_arrays(self):
        fail = np.full(self.n_data, NEVER, np.int32)
        rec = np.full(self.n_data, NEVER, np.int32)
        per = np.zeros(self.n_ports, np.int32)
        ph = np.zeros(self.n_ports, np.int32)
        for b, f_at, r_at in self.bank_faults:
            fail[b] = f_at
            rec[b] = r_at if r_at >= 0 else NEVER
        for q, p_, ph_ in self.stutters:
            per[q] = p_
            ph[q] = ph_
        return fail, rec, per, ph

    def state(self) -> FaultState:
        fail, rec, per, ph = self.schedule_arrays()
        return init_fault_state(self.n_data, self.n_ports)._replace(
            fail_at=jnp.asarray(fail), recover_at=jnp.asarray(rec),
            stutter_period=jnp.asarray(per), stutter_phase=jnp.asarray(ph))


def plan_from_spec(spec: Optional[Tuple], n_data: int,
                   n_ports: int) -> Optional[FaultPlan]:
    """None/() → None (no plan); otherwise ``FaultPlan.from_spec``."""
    if not spec:
        return None
    return FaultPlan.from_spec(tuple(spec), n_data, n_ports)
