"""Bank-fault injection, erasure-degraded serving, and online rebuild."""
from repro.faults.plan import (NEVER, FaultPlan, FaultState, bank_down,
                               bank_rebuilding, init_fault_state,
                               plan_from_spec, stutter_busy)

__all__ = [
    "NEVER", "FaultPlan", "FaultState", "bank_down", "bank_rebuilding",
    "init_fault_state", "plan_from_spec", "stutter_busy",
]
