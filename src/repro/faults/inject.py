"""Device-side fault hooks for ``CodedMemorySystem.cycle_fn``.

Three pieces, called in cycle order (all behind the static
``MemParams.faults`` flag, so the faults-off program is untouched):

1. ``drop_unservable`` — *fail-fast semantics*. A queued request that can
   never be served under the current hard failures is dropped and counted
   (``unserved_reads`` / ``lost_writes``) instead of occupying its queue
   slot forever: a read of a hard-down bank whose fresh value is in-bank
   and which no valid parity option can decode (every option is invalid or
   needs another hard-down sibling), and a write to a hard-down bank with
   no parity coverage to park into. Deliberately *non-speculative*: a
   hard-down bank with a recovery scheduled in the future still fails its
   requests fast — the controller doesn't model "wait for repair" QoS (see
   docs/faults.md). Rebuilding banks are exempt (service is imminent).
2. Port seeding — a down bank's data port reads busy to both pattern
   builders; stuttering ports likewise (done inline in ``cycle_fn``).
3. ``rebuild_scan`` — *online rebuild*. While any bank is rebuilding, a
   flat cursor sweeps every (bank, row) cell at ``recode_budget`` cells
   per cycle, pushing cells that are parked elsewhere or have a stale
   covering parity into the recode ring; the ReCoding unit then restores /
   recomputes them under its normal port and budget discipline (with the
   rebuilding bank's own port granted back to it). The bank rejoins —
   ``rebuilt`` latches, clearing ``down`` — only when the sweep has
   finished and no restorable work remains anywhere.

Every rule here is re-derived sequentially by the NumPy golden model
(``repro.oracle.model``) and enforced bit-exactly by the chaos-conformance
suite (tests/test_faults.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.controller import _rc_push
from repro.faults.plan import FaultState, NEVER


def drop_unservable(p, t, down_hard, rq_row, rq_valid, wq_row, wq_valid,
                    fresh_loc, parity_valid, region_slot, rs_active):
    """Clear queue slots whose requests are unservable under ``down_hard``.

    Returns ``(rq_valid, wq_valid, n_unserved, n_lost)``. Pure per-cell
    predicate (no cross-candidate interaction), so the vectorized form is
    trivially order-independent and the oracle's loop matches it exactly.
    """
    rs = p.region_size
    dq = p.queue_depth
    cb = jnp.repeat(jnp.arange(p.n_data, dtype=jnp.int32), dq)

    def read_side(rows, valid):
        i = jnp.maximum(rows.reshape(-1), 0)
        slot = region_slot[i // rs_active]
        coded = slot >= 0
        pr = jnp.maximum(slot, 0) * rs + i % rs_active
        optj = t.opt_parity[cb]                              # (N, K)
        optjj = jnp.maximum(optj, 0)
        opt_ok = (optj >= 0) & coded[:, None] & parity_valid[optjj, pr[:, None]]
        sibs = t.opt_sibs[cb]                                # (N, K, S)
        sib_dead = jnp.any((sibs >= 0) & down_hard[jnp.maximum(sibs, 0)],
                           axis=2)
        return valid.reshape(-1), i, coded, opt_ok & ~sib_dead

    rv, ri, _, viable = read_side(rq_row, rq_valid)
    drop_r = (rv & down_hard[cb] & (fresh_loc[cb, ri] == 0)
              & ~jnp.any(viable, axis=1))

    wv = wq_valid.reshape(-1)
    wi = jnp.maximum(wq_row.reshape(-1), 0)
    w_coded = region_slot[wi // rs_active] >= 0
    drop_w = wv & down_hard[cb] & (~w_coded | (t.opt_n[cb] == 0))

    return (rq_valid & ~drop_r.reshape(p.n_data, dq),
            wq_valid & ~drop_w.reshape(p.n_data, dq),
            jnp.sum(drop_r).astype(jnp.int32),
            jnp.sum(drop_w).astype(jnp.int32))


def rebuild_scan(p, t, fault: FaultState, cycle, rebuilding, down_hard,
                 fresh_loc, parity_valid, region_slot, rc_bank, rc_row,
                 rc_valid, rs_active, nr_active):
    """Advance the online-rebuild sweep; latch ``rebuilt`` on completion.

    Runs after the ReCoding unit (pushes become retirable next cycle). The
    cursor walks cells ``0 .. n_data*n_rows`` at ``recode_budget`` cells
    per cycle and resets to 0 whenever a bank's recovery begins, so a
    recovery arriving mid-sweep always gets a full pass. A cell is pushed
    when its fresh value is parked elsewhere or any covering parity is
    stale (reads of never-rewritten rows must not wait on the bank's
    direct port forever); the push stalls the cursor when the ring is
    momentarily full. Cells outside the point's active geometry are
    untouched by construction and skipped. Completion requires the sweep
    done, the ring drained, and no parked cell left on any bank that is
    not still hard-down (a hard-down bank's parked rows are *its* future
    rebuild's work, not this one's).
    """
    total = p.n_data * p.n_rows
    any_rb = jnp.any(rebuilding)
    newly = jnp.any((fault.recover_at == cycle) & (fault.fail_at <= cycle)
                    & ~fault.rebuilt)
    ptr = jnp.where(newly, 0, fault.rebuild_ptr)
    rs = p.region_size

    def body(_, carry):
        ptr, rc_bank, rc_row, rc_valid = carry
        cell = jnp.minimum(ptr, total - 1)
        x = cell // p.n_rows
        i = cell % p.n_rows
        in_range = any_rb & (ptr < total)
        region = i // rs_active
        in_geom = (region < nr_active) & (i % rs_active < rs_active)
        slot = region_slot[jnp.minimum(region, region_slot.shape[0] - 1)]
        coded = slot >= 0
        pr = jnp.maximum(slot, 0) * rs + i % rs_active
        optj = t.opt_parity[x]
        stale = jnp.any((optj >= 0) & coded
                        & ~parity_valid[jnp.maximum(optj, 0), pr])
        need = in_range & in_geom & ((fresh_loc[x, i] > 0) | stale)
        rc_bank, rc_row, rc_valid, ok = _rc_push(
            rc_bank, rc_row, rc_valid, x, i, need)
        advance = in_range & (~need | ok)
        return ptr + advance.astype(jnp.int32), rc_bank, rc_row, rc_valid

    ptr, rc_bank, rc_row, rc_valid = jax.lax.fori_loop(
        0, p.recode_budget, body, (ptr, rc_bank, rc_row, rc_valid))

    pending_park = jnp.any(jnp.any(fresh_loc > 0, axis=1) & ~down_hard)
    complete = (ptr >= total) & ~jnp.any(rc_valid) & ~pending_park
    rebuilt = fault.rebuilt | (rebuilding & complete)
    return rc_bank, rc_row, rc_valid, fault._replace(
        rebuilt=rebuilt, rebuild_ptr=ptr)


def quiescent_fault_pending(fault: FaultState, cycle) -> jnp.ndarray:
    """True while a scheduled fault event can still change observable state
    — an un-failed bank with a failure pending, or a failed bank with a
    recovery scheduled (its rebuild must finish before the run's fixed
    point is reached). Used by ``system.quiescent``; works on single and
    batched states (trailing-axis reduction)."""
    cyc = jnp.asarray(cycle)[..., None]
    down = (fault.fail_at <= cyc) & ~fault.rebuilt
    pending = (((fault.fail_at > cyc) & (fault.fail_at < NEVER))
               | (down & (fault.recover_at < NEVER)))
    return jnp.any(pending, axis=-1)
