"""Reference pattern builders: the sequential greedy matchers (paper Fig 11).

These are the original ``lax.fori_loop`` implementations — one iteration per
candidate, oldest first, scatters in every step. They define the scheduling
semantics; ``repro.core.controller`` re-implements them as compacted,
work-proportional builders that must produce bit-identical plans (see
tests/test_scheduler_equiv.py and docs/performance.md for the equivalence
contract). Select them end-to-end with ``make_params(scheduler="reference")``.

DEPRECATED: the reference scheduler exists only as the soak oracle for the
vectorized builders; ``make_params(scheduler="reference")`` emits a
``DeprecationWarning``, and this module will be removed once the ROADMAP's
soak period ends (equivalence suites opt in explicitly via filterwarnings).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codes import MAX_OPTS, MAX_SIBS
from repro.core.state import MemParams

from repro.core.controller import (  # noqa: F401  (shared constants/plans)
    INF_SCORE,
    INT32_MAX,
    JTables,
    MODE_FROM_SYM,
    MODE_OPT0,
    MODE_REDIRECT,
    MODE_UNSERVED,
    ReadPlan,
    WMODE_PARK0,
    WMODE_UNSERVED,
    WritePlan,
    _rc_push,
)


def build_read_pattern_ref(
    p: MemParams,
    t: JTables,
    cand_bank: jnp.ndarray,
    cand_row: jnp.ndarray,
    cand_age: jnp.ndarray,
    cand_valid: jnp.ndarray,
    port_busy: jnp.ndarray,
    fresh_loc: jnp.ndarray,
    parity_valid: jnp.ndarray,
    region_slot: jnp.ndarray,
    rs_active=None,
) -> ReadPlan:
    n = cand_bank.shape[0]
    rs = p.region_size
    rs_a = rs if rs_active is None else rs_active
    order = jnp.argsort(jnp.where(cand_valid, cand_age, INT32_MAX))

    served0 = jnp.zeros((n,), bool)
    mode0 = jnp.full((n,), MODE_UNSERVED, jnp.int32)
    sym_bank0 = jnp.full((p.max_syms,), -1, jnp.int32)
    sym_row0 = jnp.full((p.max_syms,), -1, jnp.int32)

    def body(k, carry):
        port_busy, served, mode, sym_bank, sym_row, sym_cnt = carry
        c = order[k]
        b = jnp.maximum(cand_bank[c], 0)
        i = jnp.maximum(cand_row[c], 0)
        valid = cand_valid[c]

        fl = fresh_loc[b, i]
        fresh_in_bank = fl == 0
        slot = region_slot[i // rs_a]
        coded = slot >= 0
        pr = jnp.maximum(slot, 0) * rs + i % rs_a
        arange_s = jnp.arange(p.max_syms)

        def has_sym(x):
            return jnp.any((sym_bank == x) & (sym_row == i) & (arange_s < sym_cnt))

        # --- score every action ------------------------------------------
        # action 0: from-symbol (chained decode reuse)
        f_sym = valid & fresh_in_bank & has_sym(b) & bool(p.coalesce)
        # action 1: direct
        f_dir = valid & fresh_in_bank & ~port_busy[b]
        # actions 2..2+MAX_OPTS-1: degraded read via option k
        opt_scores = []
        opt_feas = []
        opt_need0 = []
        opt_need1 = []
        for kk in range(MAX_OPTS):
            j = t.opt_parity[b, kk]
            jj = jnp.maximum(j, 0)
            pv = (j >= 0) & coded & parity_valid[jj, pr]
            pfree = ~port_busy[t.par_port[jj]]
            s0 = t.opt_sibs[b, kk, 0]
            s1 = t.opt_sibs[b, kk, 1]
            sa0 = has_sym(s0) & (s0 >= 0)
            sa1 = has_sym(s1) & (s1 >= 0)
            ok0 = (s0 < 0) | sa0 | ~port_busy[jnp.maximum(s0, 0)]
            ok1 = (s1 < 0) | sa1 | ~port_busy[jnp.maximum(s1, 0)]
            need0 = (s0 >= 0) & ~sa0
            need1 = (s1 >= 0) & ~sa1
            feas = valid & fresh_in_bank & pv & pfree & ok0 & ok1
            cost = 1 + need0.astype(jnp.int32) + need1.astype(jnp.int32)
            opt_feas.append(feas)
            opt_scores.append(2 * cost)
            opt_need0.append(need0)
            opt_need1.append(need1)
        # last action: redirect (fresh value parked in parity fl-1)
        hold_port = t.par_port[jnp.maximum(fl - 1, 0)]
        f_rd = valid & (fl > 0) & ~port_busy[hold_port]

        scores = jnp.stack(
            [jnp.where(f_sym, 0, INF_SCORE), jnp.where(f_dir, 3, INF_SCORE)]
            + [jnp.where(f, s, INF_SCORE) for f, s in zip(opt_feas, opt_scores)]
            + [jnp.where(f_rd, 2, INF_SCORE)]
        )
        act = jnp.argmin(scores).astype(jnp.int32)
        found = scores[act] < INF_SCORE

        is_dir = found & (act == 1)
        is_opt = found & (act >= 2) & (act < 2 + MAX_OPTS)
        is_rd = found & (act == 2 + MAX_OPTS)
        k_sel = jnp.clip(act - 2, 0, MAX_OPTS - 1)
        need0_sel = jnp.stack(opt_need0)[k_sel]
        need1_sel = jnp.stack(opt_need1)[k_sel]
        j_sel = t.opt_parity[b, k_sel]
        sib0 = t.opt_sibs[b, k_sel, 0]
        sib1 = t.opt_sibs[b, k_sel, 1]

        nop = jnp.int32(p.n_ports)  # dummy sink slot
        p_dir = jnp.where(is_dir, b, nop)
        p_par = jnp.where(
            is_opt, t.par_port[jnp.maximum(j_sel, 0)], jnp.where(is_rd, hold_port, nop)
        )
        p_s0 = jnp.where(is_opt & need0_sel, jnp.maximum(sib0, 0), nop)
        p_s1 = jnp.where(is_opt & need1_sel, jnp.maximum(sib1, 0), nop)
        port_busy = (
            port_busy.at[p_dir].set(True)
            .at[p_par].set(True)
            .at[p_s0].set(True)
            .at[p_s1].set(True)
        )
        # materialized symbols this cycle (enable chained decodes)
        def app(sb, sr, cnt, bank, do):
            do = do & (cnt < p.max_syms)
            idx = jnp.minimum(cnt, p.max_syms - 1)
            sb = sb.at[idx].set(jnp.where(do, bank, sb[idx]))
            sr = sr.at[idx].set(jnp.where(do, i, sr[idx]))
            return sb, sr, cnt + do.astype(jnp.int32)

        sym_bank, sym_row, sym_cnt = app(sym_bank, sym_row, sym_cnt, b, is_dir | is_opt)
        sym_bank, sym_row, sym_cnt = app(
            sym_bank, sym_row, sym_cnt, jnp.maximum(sib0, 0), is_opt & need0_sel
        )
        sym_bank, sym_row, sym_cnt = app(
            sym_bank, sym_row, sym_cnt, jnp.maximum(sib1, 0), is_opt & need1_sel
        )

        served = served.at[c].set(found)
        mode = mode.at[c].set(jnp.where(found, act - 0, MODE_UNSERVED))
        return port_busy, served, mode, sym_bank, sym_row, sym_cnt

    carry = (port_busy, served0, mode0, sym_bank0, sym_row0, jnp.int32(0))
    port_busy, served, mode, _, _, _ = jax.lax.fori_loop(0, n, body, carry)
    # mode indices: 0 from_sym, 1 direct, 2..5 options, 6 redirect — map to
    # public constants (identical numbering by construction).
    n_served = jnp.sum(served).astype(jnp.int32)
    n_degraded = jnp.sum(
        served & ((mode == MODE_FROM_SYM) | ((mode >= MODE_OPT0) & (mode < MODE_REDIRECT)))
    ).astype(jnp.int32)
    return ReadPlan(served, mode, port_busy, n_served, n_degraded)


def build_write_pattern_ref(
    p: MemParams,
    t: JTables,
    cand_bank: jnp.ndarray,
    cand_row: jnp.ndarray,
    cand_age: jnp.ndarray,
    cand_valid: jnp.ndarray,
    port_busy: jnp.ndarray,
    fresh_loc: jnp.ndarray,
    parity_valid: jnp.ndarray,
    region_slot: jnp.ndarray,
    parked_count: jnp.ndarray,
    rc_bank: jnp.ndarray,
    rc_row: jnp.ndarray,
    rc_valid: jnp.ndarray,
    rs_active=None,
) -> WritePlan:
    n = cand_bank.shape[0]
    rs = p.region_size
    rs_a = rs if rs_active is None else rs_active
    order = jnp.argsort(jnp.where(cand_valid, cand_age, INT32_MAX))
    served0 = jnp.zeros((n,), bool)
    mode0 = jnp.full((n,), WMODE_UNSERVED, jnp.int32)

    def body(k, carry):
        (port_busy, served, mode, fresh_loc, parity_valid, parked_count,
         rc_bank, rc_row, rc_valid, dropped) = carry
        c = order[k]
        b = jnp.maximum(cand_bank[c], 0)
        i = jnp.maximum(cand_row[c], 0)
        valid = cand_valid[c]
        region = i // rs_a
        slot = region_slot[region]
        coded = slot >= 0
        pr = jnp.maximum(slot, 0) * rs + i % rs_a
        fl = fresh_loc[b, i]
        rc_space = jnp.any(~rc_valid)

        # direct write (score 1)
        f_dir = valid & ~port_busy[b]
        # park into parity option k (score 2 + k): requires coded region,
        # parity port free, slot row not already parked by a *different*
        # member, recode space.
        park_feas = []
        for kk in range(MAX_OPTS):
            j = t.opt_parity[b, kk]
            jj = jnp.maximum(j, 0)
            pfree = ~port_busy[t.par_port[jj]]
            # another member of j parked here?
            occ = jnp.zeros((), bool)
            for mm in range(MAX_SIBS + 1):
                m = t.par_members[jj, mm]
                occ = occ | ((m >= 0) & (m != b) & (fresh_loc[jnp.maximum(m, 0), i] == jj + 1))
            park_feas.append(valid & (j >= 0) & coded & pfree & ~occ & rc_space)
        scores = jnp.stack(
            [jnp.where(f_dir, 1, INF_SCORE)]
            + [jnp.where(f, 2 + kk, INF_SCORE) for kk, f in enumerate(park_feas)]
        )
        act = jnp.argmin(scores).astype(jnp.int32)
        found = scores[act] < INF_SCORE
        is_dir = found & (act == 0)
        is_park = found & (act >= 1)
        k_sel = jnp.clip(act - 1, 0, MAX_OPTS - 1)
        j_sel = jnp.maximum(t.opt_parity[b, k_sel], 0)

        nop = jnp.int32(p.n_ports)
        port_busy = port_busy.at[jnp.where(is_dir, b, nop)].set(True)
        port_busy = port_busy.at[jnp.where(is_park, t.par_port[j_sel], nop)].set(True)

        # freshness bookkeeping -------------------------------------------
        was_parked = fl > 0
        # direct: fresh -> bank; all covering parities of b become stale
        new_fl = jnp.where(is_dir, 0, jnp.where(is_park, j_sel + 1, fl))
        fresh_loc = fresh_loc.at[b, i].set(new_fl)
        # parked_count delta for this row's region
        delta = (
            is_park.astype(jnp.int32) * (~was_parked).astype(jnp.int32)
            - is_dir.astype(jnp.int32) * was_parked.astype(jnp.int32)
        )
        parked_count = parked_count.at[region].add(delta)
        # parity invalidation
        for kk in range(MAX_OPTS):
            j = t.opt_parity[b, kk]
            jj = jnp.maximum(j, 0)
            inv = (j >= 0) & coded & (is_dir | (is_park & (jj == j_sel)))
            parity_valid = parity_valid.at[jj, pr].set(
                jnp.where(inv, False, parity_valid[jj, pr])
            )
        # recode request so freshness is eventually restored
        need_rc = (is_dir & coded & (t.opt_n[b] > 0)) | is_park
        rc_bank, rc_row, rc_valid, ok = _rc_push(rc_bank, rc_row, rc_valid, b, i, need_rc)
        dropped = dropped + (need_rc & ~ok).astype(jnp.int32)

        served = served.at[c].set(found)
        mode = mode.at[c].set(jnp.where(found, act, WMODE_UNSERVED))
        return (port_busy, served, mode, fresh_loc, parity_valid, parked_count,
                rc_bank, rc_row, rc_valid, dropped)

    carry = (port_busy, served0, mode0, fresh_loc, parity_valid, parked_count,
             rc_bank, rc_row, rc_valid, jnp.int32(0))
    out = jax.lax.fori_loop(0, n, body, carry)
    (port_busy, served, mode, fresh_loc, parity_valid, parked_count,
     rc_bank, rc_row, rc_valid, dropped) = out
    n_served = jnp.sum(served).astype(jnp.int32)
    n_parked = jnp.sum(served & (mode >= WMODE_PARK0)).astype(jnp.int32)
    return WritePlan(served, mode, port_busy, fresh_loc, parity_valid,
                     parked_count, rc_bank, rc_row, rc_valid, n_served,
                     n_parked, dropped)
