"""Pytree state for the coded memory system (controller + banks).

Freshness model (a bit-exact refinement of the paper's 2-bit code status
table, §IV-A):

  * ``fresh_loc[b, i]`` — where the logically-fresh value of data bank ``b``
    row ``i`` lives: ``0`` = in the data bank; ``j+1`` = *parked* raw in
    logical parity bank ``j``'s row slot (paper status ``10``).
  * ``parity_valid[j, r]`` — logical parity ``j``'s slot row ``r`` currently
    equals the XOR of its members' *data-bank-stored* rows. Cleared by any
    member direct-write (paper status ``01``) or by parking (status ``10``);
    restored by the ReCoding unit or by a fresh region encode.

  Degraded read of ``(b, i)`` via parity ``j`` therefore requires
  ``parity_valid[j, r(i)]`` *and* ``fresh_loc[b, i] == 0``. Sibling rows are
  read from their data banks; their XOR with the parity reconstructs the
  data-bank value of ``b`` exactly even if a sibling's own fresh value is
  parked elsewhere (the parity was computed from data-bank contents).

Dynamic coding (§IV-E): rows are grouped into ``n_regions`` regions of
``region_size`` rows; ``region_slot[g]`` maps region ``g`` to a parity slot
(or -1), giving parity row ``r(i) = region_slot[i // rs] * rs + i % rs``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.codes import CodeTables
from repro.faults.plan import FaultPlan, FaultState, init_fault_state
from repro.obs.planes import Telemetry, init_telemetry

NOP_PORT_PAD = 1  # port_busy has one trailing dummy slot used as a no-op sink


class MemParams(NamedTuple):
    """Static geometry (python ints; hashable, used as jit static args).

    Anything that is a *value* rather than a *shape* — the write-drain
    thresholds and the dynamic-coding selection period — lives in
    ``TunableParams`` instead, so sweeps can batch over it without
    recompiling (one compiled program serves a whole tunable grid).

    ``region_size`` / ``n_regions`` / ``n_slots`` are *allocation* shapes:
    a sweep group may pad them up to the group maximum and run each point
    at its own traced geometry (``TunableParams.region_size_active`` /
    ``n_regions_active`` / ``n_slots_active``, see ``active_geometry``).
    ``n_active`` is the allocation's true parity-slot budget — it can be 0
    (α < r: the point is uncoded) even though storage keeps a ≥1 floor.
    """

    n_data: int
    n_parities: int
    n_ports: int          # data + physical parity banks
    n_rows: int           # L, rows per data bank
    region_size: int      # rs (allocated stride of one parity slot)
    n_regions: int        # ceil(L / rs) (allocated)
    n_slots: int          # parity slots = floor(alpha / r), capped at
                          # n_regions; ≥1 storage floor (allocated)
    n_active: int         # slots usable for coded regions (0 when α < r)
    queue_depth: int
    recode_cap: int
    max_syms: int         # symbol bit-matrix capacity bound; must cover
                          # n_ports (enforced by ``make_params``) so the
                          # per-cycle symbol set can never saturate
    recode_budget: int    # max recode entries retired per cycle
    coalesce: bool        # allow FROM_SYM / chained-decode reuse (off for the
                          # uncoded Ramulator-like baseline)
    encode_rows_per_cycle: int = 64  # encoder bandwidth; the traced
                                     # per-point encode latency is
                                     # max(1, region_size_active // this)
    traced_geometry: bool = False    # True: region indexing uses the traced
                                     # TunableParams.*_active geometry (a
                                     # padded multi-geometry sweep group);
                                     # False: the allocation IS the geometry
                                     # and indexing stays static (no traced
                                     # divisions — the exact pre-masking
                                     # program)
    telemetry: bool = False          # True: carry repro.obs.planes metric
                                     # planes through the cycle loop (stall/
                                     # wait attribution, provenance, queue
                                     # HWMs, latency histograms). False: the
                                     # ``tele`` leaf is None and the traced
                                     # program is bit-identical to one built
                                     # before the flag existed (same gating
                                     # style as ``traced_geometry``)
    faults: bool = False             # True: carry a repro.faults.FaultState
                                     # leaf (bank-erasure schedule, rebuild
                                     # progress, availability counters) and
                                     # weave the fault hooks into cycle_fn.
                                     # False: the ``fault`` leaf is None and
                                     # the program is bit-identical to the
                                     # pre-fault one (same gating style as
                                     # ``telemetry``)


class TunableParams(NamedTuple):
    """Per-point scalar knobs (traced jnp arrays; a ``vmap`` batch axis).

    These affect only data values inside the cycle engine, never array
    shapes, so a batch of configurations differing in nothing but these
    can share one compiled program. ``repro.sweep`` exploits exactly that.

    The three ``*_active`` fields carry a point's own α/r geometry inside a
    padded group allocation (``make_params``'s ``*_alloc`` arguments):
    indexing uses the traced values, extra slots/regions/rows are masked
    off. Defaults of INT32_MAX clamp to the allocation (exact geometry).
    """

    select_period: jnp.ndarray  # () int32 — T, dynamic re-selection period
    wq_hi: jnp.ndarray          # () int32 — write-drain hysteresis thresholds
    wq_lo: jnp.ndarray          # () int32
    n_slots_active: jnp.ndarray  # () int32 — parity-slot budget this point may
                                 # use (≤ MemParams.n_active; lets an α axis
                                 # batch over one max-α allocation)
    region_size_active: jnp.ndarray  # () int32 — this point's own rs
    n_regions_active: jnp.ndarray    # () int32 — this point's own ⌈L/rs⌉


def make_tunables(
    queue_depth: int = 10,
    select_period: int = 512,
    wq_hi: int = 8,
    wq_lo: int = 2,
    n_slots_active: int = jnp.iinfo(jnp.int32).max,
    region_size_active: int = jnp.iinfo(jnp.int32).max,
    n_regions_active: int = jnp.iinfo(jnp.int32).max,
) -> TunableParams:
    hi = min(int(wq_hi), queue_depth - 1)
    return TunableParams(
        select_period=jnp.int32(max(int(select_period), 1)),
        wq_hi=jnp.int32(hi),
        # crossed hysteresis thresholds (lo > hi) would flap write_mode every
        # cycle: entering write mode at occupancy >= hi and staying only
        # while occupancy > lo > hi means no state is ever stable
        wq_lo=jnp.int32(min(int(wq_lo), hi)),
        n_slots_active=jnp.int32(n_slots_active),
        region_size_active=jnp.int32(region_size_active),
        n_regions_active=jnp.int32(n_regions_active),
    )


def active_geometry(p: MemParams, tn: TunableParams):
    """(region_size_active, n_regions_active) for this point.

    With ``p.traced_geometry`` these are traced int32 scalars — the tunable
    defaults (INT32_MAX) clamp to the allocation, a padded group allocation
    sees each point's own geometry. Without it they are the static python
    ints themselves (a single-geometry system compiles with no traced
    divisions at all; any ``*_active`` tunables are ignored by
    construction because they equal the allocation). Parity row addressing
    always keeps the *allocated* slot stride: row ``i`` of a slot lives at
    ``slot * p.region_size + i % region_size_active``."""
    if not p.traced_geometry:
        return p.region_size, p.n_regions
    rs_a = jnp.minimum(tn.region_size_active, p.region_size)
    nr_a = jnp.minimum(tn.n_regions_active, p.n_regions)
    return rs_a, nr_a


# --------------------------------------------------------------- wide counters
# 64-bit statistics accumulators as (lo, hi) uint32 limb pairs. jnp.int64
# silently degrades to int32 unless the global ``jax_enable_x64`` flag is on
# (which would flip default dtypes across the whole program), so the wide
# counters emulate 64-bit exactly with explicit 32-bit dtypes instead —
# independent of the flag.

def wide_zero() -> jnp.ndarray:
    """A zeroed 64-bit accumulator: shape (2,) uint32 = (lo, hi) limbs."""
    return jnp.zeros((2,), jnp.uint32)


def wide_add(acc: jnp.ndarray, inc) -> jnp.ndarray:
    """``acc + inc`` for a non-negative scalar ``inc`` < 2**32."""
    lo = acc[0] + jnp.asarray(inc).astype(jnp.uint32)
    return jnp.stack([lo, acc[1] + (lo < acc[0]).astype(jnp.uint32)])


def wide_total(acc) -> int:
    """Host-side python int value of a wide accumulator."""
    a = np.asarray(acc)
    return int(a[..., 0]) + (int(a[..., 1]) << 32)


def derive_geometry(n_rows: int, alpha: float, r: float):
    """(region_size, n_regions, n_slots) implied by an (n_rows, α, r) point.

    Shared by ``make_params`` and ``repro.sweep.grid.static_signature`` so the
    sweep layer can reason about which points share compiled shapes.

    ``n_slots`` is 0 when α < r: the parity budget cannot hold even one
    region, so the point is explicitly uncoded (no free slot is granted —
    that would overstate coverage at tiny α).
    """
    region_size = max(1, int(round(n_rows * r)))
    n_regions = -(-n_rows // region_size)
    n_slots = min(int(np.floor(alpha / r + 1e-9)), n_regions)
    return region_size, n_regions, max(n_slots, 0)


def make_params(
    tables: CodeTables,
    n_rows: int,
    alpha: float,
    r: float,
    queue_depth: int = 10,
    recode_cap: int = 64,
    max_syms: int = 96,
    encode_rows_per_cycle: int = 64,
    recode_budget: int = 4,
    coalesce: bool = True,
    n_slots_alloc: Optional[int] = None,
    region_size_alloc: Optional[int] = None,
    n_regions_alloc: Optional[int] = None,
    traced_geometry: bool = False,
    telemetry: bool = False,
    faults: bool = False,
) -> MemParams:
    if max_syms < tables.n_ports:
        # the builders' O(1) symbol bit-matrix has true set semantics; the
        # scheduling contract (plans equal the sequential golden model's)
        # additionally requires that a capacity-bounded symbol list could
        # never saturate, which holds when max_syms covers the per-cycle
        # port-claim bound. Reject configurations below it instead of
        # silently changing chained-decode behaviour.
        raise ValueError(
            f"max_syms={max_syms} < n_ports={tables.n_ports}: the symbol "
            "capacity must cover the per-cycle port-claim bound (see "
            "docs/testing.md)")
    region_size, n_regions, n_slots = derive_geometry(n_rows, alpha, r)
    full = n_slots >= n_regions
    # ---- group allocation: a sweep batches several α/r geometries over one
    # compiled shape by padding region/parity state to the group maxima; the
    # per-point geometry rides in ``TunableParams.{region_size,n_regions,
    # n_slots}_active`` and masks the padding off.
    if region_size_alloc is not None:
        if region_size_alloc < region_size:
            raise ValueError(f"region_size_alloc={region_size_alloc} < "
                             f"derived region_size={region_size}")
        region_size = region_size_alloc
    if n_regions_alloc is not None:
        if n_regions_alloc < n_regions:
            raise ValueError(f"n_regions_alloc={n_regions_alloc} < "
                             f"derived n_regions={n_regions}")
        n_regions = n_regions_alloc
    # §IV-E says "up to α/r − 1 regions" with one reserved for staging, but the
    # paper's own experiment discussion (§V-C: "⌊α/r⌋ = 2 … we can select 2
    # regions" at α=0.1, r=0.05) uses ⌊α/r⌋ active regions; we follow §V-C and
    # model staging as the in-flight slot being unusable during its encode.
    n_active = n_slots
    if n_slots_alloc is not None:
        if n_slots_alloc < n_slots:
            raise ValueError(
                f"n_slots_alloc={n_slots_alloc} < derived n_slots={n_slots}")
        if (n_slots_alloc >= n_regions) != full:
            raise ValueError(
                "n_slots_alloc must not change full-coverage status "
                f"(alloc {n_slots_alloc}, derived {n_slots}, regions {n_regions})")
        n_slots = n_active = n_slots_alloc
    return MemParams(
        n_data=tables.n_data,
        n_parities=max(tables.n_parities, 1),
        n_ports=tables.n_ports,
        n_rows=n_rows,
        region_size=region_size,
        n_regions=n_regions,
        n_slots=max(n_slots, 1),   # storage floor; the true budget is n_active
        n_active=n_active,
        queue_depth=queue_depth,
        recode_cap=recode_cap,
        max_syms=max_syms,
        recode_budget=recode_budget,
        coalesce=coalesce if tables.n_parities > 0 else False,
        encode_rows_per_cycle=encode_rows_per_cycle,
        traced_geometry=traced_geometry,
        telemetry=telemetry,
        faults=faults,
    )


class MemState(NamedTuple):
    """Dynamic controller state (all jnp arrays; a scan carry)."""

    # freshness / code status
    fresh_loc: jnp.ndarray      # (n_data, L) int32
    parity_valid: jnp.ndarray   # (n_par, n_slots * rs) bool
    # dynamic coding
    region_slot: jnp.ndarray    # (n_regions,) int32, -1 = uncoded
    slot_region: jnp.ndarray    # (n_slots,) int32, -1 = free/staging
    access_count: jnp.ndarray   # (n_regions,) int32 (windowed)
    parked_count: jnp.ndarray   # (n_regions,) int32
    enc_region: jnp.ndarray     # () int32, -1 = idle
    enc_remaining: jnp.ndarray  # () int32
    enc_slot: jnp.ndarray       # () int32 slot being encoded (-1 idle)
    switches: jnp.ndarray       # () int32
    # recode ring buffer
    rc_bank: jnp.ndarray        # (RC,) int32
    rc_row: jnp.ndarray         # (RC,) int32
    rc_valid: jnp.ndarray       # (RC,) bool
    # read/write queues (per data bank)
    rq_row: jnp.ndarray         # (n_data, D) int32
    rq_age: jnp.ndarray         # (n_data, D) int32 (issue cycle; INT32_MAX empty)
    rq_valid: jnp.ndarray       # (n_data, D) bool
    wq_row: jnp.ndarray
    wq_age: jnp.ndarray
    wq_valid: jnp.ndarray
    wq_data: jnp.ndarray        # (n_data, D) int32 write payloads
    write_mode: jnp.ndarray     # () bool (write-drain hysteresis)
    cycle: jnp.ndarray          # () int32
    # data-carrying banks (scalar word per row; the datapath reference and
    # the substrate for the correctness invariants in tests)
    banks_data: jnp.ndarray     # (n_data, L) int32
    parity_data: jnp.ndarray    # (n_par, n_slots * rs) int32
    golden: jnp.ndarray         # (n_data, L) int32 memory-order reference
    # stats (event counters are int32 — bounded by trace size; the
    # per-cycle-growing accumulators are wide (lo, hi) uint32 pairs, see
    # ``wide_zero``: they overflow int32 on long traces)
    served_reads: jnp.ndarray   # () int32
    served_writes: jnp.ndarray  # () int32
    degraded_reads: jnp.ndarray  # () int32 (reads served via parity/symbols)
    parked_writes: jnp.ndarray  # () int32
    read_latency_sum: jnp.ndarray  # (2,) uint32 wide accumulator
    write_latency_sum: jnp.ndarray  # (2,) uint32 wide accumulator
    stall_cycles: jnp.ndarray   # (2,) uint32 wide (core-stall events)
    rc_dropped: jnp.ndarray     # () int32 (recode requests lost to a full ring)
    # opt-in leaves: None unless the matching MemParams flag is set — a None
    # leaf is an empty pytree node, so the flags-off carry has exactly the
    # pre-flag tree structure and the compiled program is unchanged. These
    # MUST stay the trailing fields, in this order (older pickled/positional
    # states keep their layout; new opt-in leaves append after ``fault``).
    tele: Optional[Telemetry] = None
    # fault-injection schedule + progress (repro.faults): None unless
    # MemParams.faults
    fault: Optional[FaultState] = None


def _concrete_int(x) -> Optional[int]:
    """Host value of ``x``, or None when it is a tracer (vmap/jit)."""
    try:
        return int(x)
    except Exception:
        return None


def init_state(p: MemParams, tn: Optional[TunableParams] = None,
               region_priors=None, n_cores: int = 8,
               fault_plan: Optional[FaultPlan] = None) -> MemState:
    """Initial controller state.

    With ``tn`` (the batched-sweep path), the point's *active* geometry
    shapes the initial region map and parity validity inside the allocated
    arrays: padded regions/slots stay unmapped (-1) and padded parity rows
    stay invalid, so a padded program is bit-identical per point to an
    exactly allocated one. Without ``tn``, the allocation is the geometry.

    ``region_priors`` (sub-coverage systems only) warm-starts the dynamic
    coding unit: a ranked int32 array of hot region ids (-1 padded) — e.g.
    ``repro.traces.profiler.TraceProfile.region_priors`` — whose leading
    entries are pre-mapped into parity slots with their parities already
    valid (all banks are zero at init, so the all-zero parity rows are the
    true XOR of their members). See ``repro.core.dynamic.priors_layout``.

    ``n_cores`` only sizes the telemetry provenance planes; the
    telemetry-off state does not depend on it.

    ``fault_plan`` (a ``repro.faults.FaultPlan``) installs a bank-erasure /
    port-stutter schedule; requires ``MemParams.faults``. With the flag on
    but no plan, the no-fault schedule is carried (nothing ever fails) —
    same compiled program, schedule-only difference.
    """
    if fault_plan is not None and not p.faults:
        raise ValueError("init_state got a fault_plan but the system was "
                         "built without make_params(faults=True) — the "
                         "schedule would be silently ignored")
    if fault_plan is not None and (fault_plan.n_data != p.n_data
                                   or fault_plan.n_ports != p.n_ports):
        raise ValueError(
            f"FaultPlan geometry ({fault_plan.n_data} data banks, "
            f"{fault_plan.n_ports} ports) does not match MemParams "
            f"({p.n_data}, {p.n_ports})")
    if tn is not None and not p.traced_geometry:
        # a non-traced system ignores the geometry actives entirely — reject
        # explicit values that disagree with the allocation instead of
        # silently simulating a hybrid configuration (tracers are exempt:
        # the sweep engine only builds non-traced systems for uniform
        # batches whose actives equal the allocation)
        sentinel = jnp.iinfo(jnp.int32).max
        for v, alloc, name in ((tn.region_size_active, p.region_size,
                                "region_size_active"),
                               (tn.n_regions_active, p.n_regions,
                                "n_regions_active")):
            cv = _concrete_int(v)
            # host-only: _concrete_int returns None for tracers, so the
            # second clause never sees one  # analysis: tracer-branch
            if cv is not None and cv not in (alloc, sentinel):
                raise ValueError(
                    f"TunableParams.{name}={cv} differs from the allocation "
                    f"({alloc}) but the system was built without "
                    "make_params(traced_geometry=True) — the traced value "
                    "would be silently ignored")
    n_slot_rows = p.n_slots * p.region_size
    if p.n_active >= p.n_regions:
        # static full coverage: identity region->slot map, all (active)
        # parities valid — the dynamic unit never remaps
        if tn is None or not p.traced_geometry:
            region_slot = jnp.arange(p.n_regions, dtype=jnp.int32)
            slot_region = jnp.arange(p.n_slots, dtype=jnp.int32)
            parity_valid = jnp.ones((p.n_parities, n_slot_rows), bool)
        else:
            rs_a, nr_a = active_geometry(p, tn)
            rid = jnp.arange(p.n_regions, dtype=jnp.int32)
            region_slot = jnp.where(rid < nr_a, rid, -1)
            sid = jnp.arange(p.n_slots, dtype=jnp.int32)
            slot_region = jnp.where(sid < nr_a, sid, -1)
            row = jnp.arange(n_slot_rows, dtype=jnp.int32)
            # storage-layout walk at the allocated parity-row stride, not a
            # data-row region lookup  # analysis: static-geometry
            active = (row // p.region_size < nr_a) & (row % p.region_size < rs_a)
            parity_valid = jnp.broadcast_to(active, (p.n_parities, n_slot_rows))
    elif region_priors is not None:
        from repro.core.dynamic import priors_layout
        region_slot, slot_region, parity_valid = priors_layout(
            p, tn, region_priors)
    else:
        region_slot = jnp.full((p.n_regions,), -1, jnp.int32)
        slot_region = jnp.full((p.n_slots,), -1, jnp.int32)
        parity_valid = jnp.zeros((p.n_parities, n_slot_rows), bool)
    z = jnp.int32(0)
    return MemState(
        fresh_loc=jnp.zeros((p.n_data, p.n_rows), jnp.int32),
        parity_valid=parity_valid,
        region_slot=region_slot,
        slot_region=slot_region,
        access_count=jnp.zeros((p.n_regions,), jnp.int32),
        parked_count=jnp.zeros((p.n_regions,), jnp.int32),
        enc_region=jnp.int32(-1),
        enc_remaining=z,
        enc_slot=jnp.int32(-1),
        switches=z,
        rc_bank=jnp.full((p.recode_cap,), -1, jnp.int32),
        rc_row=jnp.full((p.recode_cap,), -1, jnp.int32),
        rc_valid=jnp.zeros((p.recode_cap,), bool),
        rq_row=jnp.full((p.n_data, p.queue_depth), -1, jnp.int32),
        rq_age=jnp.full((p.n_data, p.queue_depth), jnp.iinfo(jnp.int32).max, jnp.int32),
        rq_valid=jnp.zeros((p.n_data, p.queue_depth), bool),
        wq_row=jnp.full((p.n_data, p.queue_depth), -1, jnp.int32),
        wq_age=jnp.full((p.n_data, p.queue_depth), jnp.iinfo(jnp.int32).max, jnp.int32),
        wq_valid=jnp.zeros((p.n_data, p.queue_depth), bool),
        wq_data=jnp.zeros((p.n_data, p.queue_depth), jnp.int32),
        write_mode=jnp.array(False),
        cycle=z,
        banks_data=jnp.zeros((p.n_data, p.n_rows), jnp.int32),
        parity_data=jnp.zeros((p.n_parities, n_slot_rows), jnp.int32),
        golden=jnp.zeros((p.n_data, p.n_rows), jnp.int32),
        served_reads=z,
        served_writes=z,
        degraded_reads=z,
        parked_writes=z,
        read_latency_sum=wide_zero(),
        write_latency_sum=wide_zero(),
        stall_cycles=wide_zero(),
        rc_dropped=z,
        tele=(init_telemetry(p.n_data, n_cores, p.queue_depth)
              if p.telemetry else None),
        fault=((fault_plan.state() if fault_plan is not None
                else init_fault_state(p.n_data, p.n_ports))
               if p.faults else None),
    )
