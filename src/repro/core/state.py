"""Pytree state for the coded memory system (controller + banks).

Freshness model (a bit-exact refinement of the paper's 2-bit code status
table, §IV-A):

  * ``fresh_loc[b, i]`` — where the logically-fresh value of data bank ``b``
    row ``i`` lives: ``0`` = in the data bank; ``j+1`` = *parked* raw in
    logical parity bank ``j``'s row slot (paper status ``10``).
  * ``parity_valid[j, r]`` — logical parity ``j``'s slot row ``r`` currently
    equals the XOR of its members' *data-bank-stored* rows. Cleared by any
    member direct-write (paper status ``01``) or by parking (status ``10``);
    restored by the ReCoding unit or by a fresh region encode.

  Degraded read of ``(b, i)`` via parity ``j`` therefore requires
  ``parity_valid[j, r(i)]`` *and* ``fresh_loc[b, i] == 0``. Sibling rows are
  read from their data banks; their XOR with the parity reconstructs the
  data-bank value of ``b`` exactly even if a sibling's own fresh value is
  parked elsewhere (the parity was computed from data-bank contents).

Dynamic coding (§IV-E): rows are grouped into ``n_regions`` regions of
``region_size`` rows; ``region_slot[g]`` maps region ``g`` to a parity slot
(or -1), giving parity row ``r(i) = region_slot[i // rs] * rs + i % rs``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.codes import CodeTables

NOP_PORT_PAD = 1  # port_busy has one trailing dummy slot used as a no-op sink


class MemParams(NamedTuple):
    """Static geometry (python ints; hashable, used as jit static args).

    Anything that is a *value* rather than a *shape* — the write-drain
    thresholds and the dynamic-coding selection period — lives in
    ``TunableParams`` instead, so sweeps can batch over it without
    recompiling (one compiled program serves a whole tunable grid).
    """

    n_data: int
    n_parities: int
    n_ports: int          # data + physical parity banks
    n_rows: int           # L, rows per data bank
    region_size: int      # rs
    n_regions: int        # L // rs
    n_slots: int          # parity slots = floor(alpha / r), capped at n_regions
    n_active: int         # slots usable for coded regions (reserve 1 staging)
    queue_depth: int
    recode_cap: int
    max_syms: int
    encode_cycles: int    # cycles to encode one region into the staging slot
    recode_budget: int    # max recode entries retired per cycle
    coalesce: bool        # allow FROM_SYM / chained-decode reuse (off for the
                          # uncoded Ramulator-like baseline)
    scheduler: str = "vectorized"  # "vectorized" (compacted-walk builders) or
                                   # "reference" (the sequential greedy loops)


class TunableParams(NamedTuple):
    """Per-point scalar knobs (traced jnp arrays; a ``vmap`` batch axis).

    These affect only data values inside the cycle engine, never array
    shapes, so a batch of configurations differing in nothing but these
    can share one compiled program. ``repro.sweep`` exploits exactly that.
    """

    select_period: jnp.ndarray  # () int32 — T, dynamic re-selection period
    wq_hi: jnp.ndarray          # () int32 — write-drain hysteresis thresholds
    wq_lo: jnp.ndarray          # () int32
    n_slots_active: jnp.ndarray  # () int32 — parity-slot budget this point may
                                 # use (≤ MemParams.n_slots; lets an α axis
                                 # batch over one max-α allocation)


def make_tunables(
    queue_depth: int = 10,
    select_period: int = 512,
    wq_hi: int = 8,
    wq_lo: int = 2,
    n_slots_active: int = jnp.iinfo(jnp.int32).max,
) -> TunableParams:
    return TunableParams(
        select_period=jnp.int32(max(int(select_period), 1)),
        wq_hi=jnp.int32(min(int(wq_hi), queue_depth - 1)),
        wq_lo=jnp.int32(wq_lo),
        n_slots_active=jnp.int32(n_slots_active),
    )


def derive_geometry(n_rows: int, alpha: float, r: float):
    """(region_size, n_regions, n_slots) implied by an (n_rows, α, r) point.

    Shared by ``make_params`` and ``repro.sweep.grid.static_signature`` so the
    sweep layer can reason about which points share compiled shapes.
    """
    region_size = max(1, int(round(n_rows * r)))
    n_regions = -(-n_rows // region_size)
    n_slots = min(int(np.floor(alpha / r + 1e-9)), n_regions)
    return region_size, n_regions, max(n_slots, 1)


def make_params(
    tables: CodeTables,
    n_rows: int,
    alpha: float,
    r: float,
    queue_depth: int = 10,
    recode_cap: int = 64,
    max_syms: int = 96,
    encode_rows_per_cycle: int = 64,
    recode_budget: int = 4,
    coalesce: bool = True,
    scheduler: str = "vectorized",
    n_slots_alloc: Optional[int] = None,
) -> MemParams:
    region_size, n_regions, n_slots = derive_geometry(n_rows, alpha, r)
    if n_slots_alloc is not None:
        # Over-allocate parity state (a sweep batches several α budgets over
        # one compiled shape); the per-point budget rides in
        # ``TunableParams.n_slots_active`` and masks the extra slots off.
        if n_slots_alloc < n_slots:
            raise ValueError(
                f"n_slots_alloc={n_slots_alloc} < derived n_slots={n_slots}")
        if (n_slots_alloc >= n_regions) != (n_slots >= n_regions):
            raise ValueError(
                "n_slots_alloc must not change full-coverage status "
                f"(alloc {n_slots_alloc}, derived {n_slots}, regions {n_regions})")
        n_slots = n_slots_alloc
    # §IV-E says "up to α/r − 1 regions" with one reserved for staging, but the
    # paper's own experiment discussion (§V-C: "⌊α/r⌋ = 2 … we can select 2
    # regions" at α=0.1, r=0.05) uses ⌊α/r⌋ active regions; we follow §V-C and
    # model staging as the in-flight slot being unusable during its encode.
    n_active = n_slots
    return MemParams(
        n_data=tables.n_data,
        n_parities=max(tables.n_parities, 1),
        n_ports=tables.n_ports,
        n_rows=n_rows,
        region_size=region_size,
        n_regions=n_regions,
        n_slots=n_slots,
        n_active=n_active,
        queue_depth=queue_depth,
        recode_cap=recode_cap,
        max_syms=max_syms,
        encode_cycles=max(1, region_size // encode_rows_per_cycle),
        recode_budget=recode_budget,
        coalesce=coalesce if tables.n_parities > 0 else False,
        scheduler=scheduler,
    )


class MemState(NamedTuple):
    """Dynamic controller state (all jnp arrays; a scan carry)."""

    # freshness / code status
    fresh_loc: jnp.ndarray      # (n_data, L) int32
    parity_valid: jnp.ndarray   # (n_par, n_slots * rs) bool
    # dynamic coding
    region_slot: jnp.ndarray    # (n_regions,) int32, -1 = uncoded
    slot_region: jnp.ndarray    # (n_slots,) int32, -1 = free/staging
    access_count: jnp.ndarray   # (n_regions,) int32 (windowed)
    parked_count: jnp.ndarray   # (n_regions,) int32
    enc_region: jnp.ndarray     # () int32, -1 = idle
    enc_remaining: jnp.ndarray  # () int32
    enc_slot: jnp.ndarray       # () int32 slot being encoded (-1 idle)
    switches: jnp.ndarray       # () int32
    # recode ring buffer
    rc_bank: jnp.ndarray        # (RC,) int32
    rc_row: jnp.ndarray         # (RC,) int32
    rc_valid: jnp.ndarray       # (RC,) bool
    # read/write queues (per data bank)
    rq_row: jnp.ndarray         # (n_data, D) int32
    rq_age: jnp.ndarray         # (n_data, D) int32 (issue cycle; INT32_MAX empty)
    rq_valid: jnp.ndarray       # (n_data, D) bool
    wq_row: jnp.ndarray
    wq_age: jnp.ndarray
    wq_valid: jnp.ndarray
    wq_data: jnp.ndarray        # (n_data, D) int32 write payloads
    write_mode: jnp.ndarray     # () bool (write-drain hysteresis)
    cycle: jnp.ndarray          # () int32
    # data-carrying banks (scalar word per row; the datapath reference and
    # the substrate for the correctness invariants in tests)
    banks_data: jnp.ndarray     # (n_data, L) int32
    parity_data: jnp.ndarray    # (n_par, n_slots * rs) int32
    golden: jnp.ndarray         # (n_data, L) int32 memory-order reference
    # stats
    served_reads: jnp.ndarray   # () int32
    served_writes: jnp.ndarray  # () int32
    degraded_reads: jnp.ndarray  # () int32 (reads served via parity/symbols)
    parked_writes: jnp.ndarray  # () int32
    read_latency_sum: jnp.ndarray  # () int64-ish int32
    write_latency_sum: jnp.ndarray
    stall_cycles: jnp.ndarray   # () int32 (core-stall events)
    rc_dropped: jnp.ndarray     # () int32 (recode requests lost to a full ring)


def init_state(p: MemParams) -> MemState:
    n_slot_rows = p.n_slots * p.region_size
    if p.n_slots >= p.n_regions:
        # static full coverage: identity region->slot map, all parities valid
        region_slot = jnp.arange(p.n_regions, dtype=jnp.int32)
        slot_region = jnp.arange(p.n_slots, dtype=jnp.int32)
        parity_valid = jnp.ones((p.n_parities, n_slot_rows), bool)
    else:
        region_slot = jnp.full((p.n_regions,), -1, jnp.int32)
        slot_region = jnp.full((p.n_slots,), -1, jnp.int32)
        parity_valid = jnp.zeros((p.n_parities, n_slot_rows), bool)
    z = jnp.int32(0)
    return MemState(
        fresh_loc=jnp.zeros((p.n_data, p.n_rows), jnp.int32),
        parity_valid=parity_valid,
        region_slot=region_slot,
        slot_region=slot_region,
        access_count=jnp.zeros((p.n_regions,), jnp.int32),
        parked_count=jnp.zeros((p.n_regions,), jnp.int32),
        enc_region=jnp.int32(-1),
        enc_remaining=z,
        enc_slot=jnp.int32(-1),
        switches=z,
        rc_bank=jnp.full((p.recode_cap,), -1, jnp.int32),
        rc_row=jnp.full((p.recode_cap,), -1, jnp.int32),
        rc_valid=jnp.zeros((p.recode_cap,), bool),
        rq_row=jnp.full((p.n_data, p.queue_depth), -1, jnp.int32),
        rq_age=jnp.full((p.n_data, p.queue_depth), jnp.iinfo(jnp.int32).max, jnp.int32),
        rq_valid=jnp.zeros((p.n_data, p.queue_depth), bool),
        wq_row=jnp.full((p.n_data, p.queue_depth), -1, jnp.int32),
        wq_age=jnp.full((p.n_data, p.queue_depth), jnp.iinfo(jnp.int32).max, jnp.int32),
        wq_valid=jnp.zeros((p.n_data, p.queue_depth), bool),
        wq_data=jnp.zeros((p.n_data, p.queue_depth), jnp.int32),
        write_mode=jnp.array(False),
        cycle=z,
        banks_data=jnp.zeros((p.n_data, p.n_rows), jnp.int32),
        parity_data=jnp.zeros((p.n_parities, n_slot_rows), jnp.int32),
        golden=jnp.zeros((p.n_data, p.n_rows), jnp.int32),
        served_reads=z,
        served_writes=z,
        degraded_reads=z,
        parked_writes=z,
        read_latency_sum=z,
        write_latency_sum=z,
        stall_cycles=z,
        rc_dropped=z,
    )
