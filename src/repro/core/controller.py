"""Read/write pattern builders (paper §IV-B, §IV-C; Figs 11-14).

Both builders are *greedy matchers*: candidates (queued requests) are
visited oldest-first; each is assigned the cheapest feasible serving action
this memory cycle, where cost counts the single-port banks the action
consumes. Ties prefer parity-based service over direct reads so that data
ports remain available for rows without parity coverage — this reproduces
the paper's best-case chained-decode schedules (§III-B) to within one
request.

Read actions (cost → score = 2*cost + is_direct):
  * FROM_SYM  — the row was already fetched/decoded this cycle (chained
                decode, free).
  * DIRECT    — read the data bank.
  * OPT(k)    — degraded read via logical parity k-th option: parity port +
                any sibling rows not already materialized this cycle.
  * REDIRECT  — the fresh value is parked in a parity bank (status ``10``);
                read it from the parity's port.

Write actions:
  * DIRECT    — write the data bank; invalidates covering parities; enqueues
                a recode request.
  * PARK(k)   — write the raw value into the corresponding row of parity
                option k (paper Fig 14); sets ``fresh_loc = j+1``; enqueues a
                recode request. Requires recode-queue space so the parked
                value can always be drained back.

Scheduling algorithm (the per-cycle hot path)
---------------------------------------------
A naive matcher walks **all** N = ``n_data × queue_depth`` candidate slots
sequentially and re-scans a symbol list per candidate — an O(N · max_syms)
chain per simulated cycle, paid in full even when every queue is empty,
that neither ``vmap`` nor sharding can hide. The builders here implement
the same greedy semantics with cost that tracks the work a cycle actually
contains:

  * **compacted trip count** — candidates are age-sorted with invalid slots
    keyed to +inf, and the walk stops after the last valid position
    (`lax.while_loop`). Idle queues cost zero iterations; the engine's
    post-drain cycles and the off-duty builder of each read/write cycle
    (see ``CodedMemorySystem.cycle_fn``) collapse to the fixed setup cost.
  * **O(1) symbol set** — the chained-decode symbols materialized this
    cycle live in an (n_data, n_rows) bit-matrix with scalar lookups: true
    set semantics, no capacity. ``make_params`` still bounds ``max_syms``
    from below (>= ``n_ports``) so that a capacity-bounded implementation
    of the same semantics could never saturate — the per-cycle symbol
    count is bounded by port claims.
  * **hoisted candidate tables** — per-candidate geometry (freshness,
    parity options, validity, sibling/port ids) is gathered once, outside
    the walk; each iteration is ~30 scalar ops against it.

The greedy semantics are genuinely sequential only across candidates that
contend (same ports, or symbols on the same row of one parity group), so
serving decisions cannot simply be computed independently — but everything
*around* that chain is vectorized: the core arbiter ranks cores per
destination queue and scatters once, the write datapath commits via an
age-rank scatter-max, and the ReCoding unit retires ring entries in
budget-bounded parallel rounds (see ``system.py`` / ``recoding.py``).

Correctness contract: plans are **bit-identical** to the pure-NumPy golden
model in ``repro.oracle`` — an independent, sequential re-derivation of
the paper's matcher that shares no code with this package. The
differential suite in tests/test_conformance.py enforces it on randomized
states and full workloads; see docs/testing.md.

Region geometry is traced, not static: both builders take an optional
``rs_active`` (the point's own region size inside a padded sweep
allocation, see ``state.active_geometry``). Region lookups use it;
parity-row addressing keeps the *allocated* ``p.region_size`` stride so
padded slots never alias. ``None`` (the default) means the allocation is
the geometry.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.codes import MAX_OPTS, CodeTables
from repro.core.state import MemParams

INT32_MAX = jnp.iinfo(jnp.int32).max
INF_SCORE = jnp.int32(1 << 30)

# read modes (reported per candidate)
MODE_UNSERVED = -1
MODE_FROM_SYM = 0
MODE_DIRECT = 1
MODE_OPT0 = 2                      # MODE_OPT0 + k  for option k
MODE_REDIRECT = MODE_OPT0 + MAX_OPTS

# write modes
WMODE_UNSERVED = -1
WMODE_DIRECT = 0
WMODE_PARK0 = 1                    # WMODE_PARK0 + k


class JTables(NamedTuple):
    """Device copies of the static code tables (a pytree)."""

    par_members: jnp.ndarray   # (n_par, MAX_SIBS+1)
    par_port: jnp.ndarray      # (n_par,)
    opt_parity: jnp.ndarray    # (n_data, MAX_OPTS)
    opt_sibs: jnp.ndarray      # (n_data, MAX_OPTS, MAX_SIBS)
    opt_n: jnp.ndarray         # (n_data,)


def jtables(tables: CodeTables) -> JTables:
    return JTables(
        par_members=jnp.asarray(tables.par_members),
        par_port=jnp.asarray(tables.par_port),
        opt_parity=jnp.asarray(tables.opt_parity),
        opt_sibs=jnp.asarray(tables.opt_sibs),
        opt_n=jnp.asarray(tables.opt_n),
    )


class ReadPlan(NamedTuple):
    served: jnp.ndarray      # (N,) bool
    mode: jnp.ndarray        # (N,) int32
    port_busy: jnp.ndarray   # (n_ports+1,) bool (updated)
    n_served: jnp.ndarray    # () int32
    n_degraded: jnp.ndarray  # () int32 — served via parity/symbol reuse


class WritePlan(NamedTuple):
    served: jnp.ndarray       # (N,) bool
    mode: jnp.ndarray         # (N,) int32
    port_busy: jnp.ndarray
    fresh_loc: jnp.ndarray
    parity_valid: jnp.ndarray
    parked_count: jnp.ndarray
    rc_bank: jnp.ndarray
    rc_row: jnp.ndarray
    rc_valid: jnp.ndarray
    n_served: jnp.ndarray
    n_parked: jnp.ndarray
    n_rc_dropped: jnp.ndarray  # () int32 — recode requests lost to a full ring


def _walk_bounds(cand_age, cand_valid):
    """Age order + trip bound covering every valid candidate.

    Invalid slots sort to the back via an +inf key; the walk only needs to
    reach the last position holding a valid candidate (invalid ones are
    no-ops in the body, so skipping the tail is unobservable)."""
    n = cand_age.shape[0]
    order = jnp.argsort(jnp.where(cand_valid, cand_age, INT32_MAX))
    last = jnp.max(jnp.where(cand_valid[order],
                             jnp.arange(n, dtype=jnp.int32), -1))
    return order, last + 1


def build_read_pattern(
    p: MemParams,
    t: JTables,
    cand_bank: jnp.ndarray,
    cand_row: jnp.ndarray,
    cand_age: jnp.ndarray,
    cand_valid: jnp.ndarray,
    port_busy: jnp.ndarray,
    fresh_loc: jnp.ndarray,
    parity_valid: jnp.ndarray,
    region_slot: jnp.ndarray,
    rs_active=None,
) -> ReadPlan:
    import jax

    n = cand_bank.shape[0]
    rs = p.region_size
    rs_a = rs if rs_active is None else rs_active
    order, n_trips = _walk_bounds(cand_age, cand_valid)
    nop = jnp.int32(p.n_ports)

    # ---- per-candidate tables, gathered once (read state is loop-invariant)
    b = jnp.maximum(cand_bank, 0)
    i = jnp.maximum(cand_row, 0)
    fl = fresh_loc[b, i]
    fresh_in_bank = fl == 0
    slot = region_slot[i // rs_a]
    coded = slot >= 0
    pr = jnp.maximum(slot, 0) * rs + i % rs_a
    hold_port = t.par_port[jnp.maximum(fl - 1, 0)]
    # a negative hold_port (scheme with no parities) points the REDIRECT
    # gather/claim at the dummy sink slot
    hold_idx = jnp.where(hold_port < 0, nop, hold_port)
    optj = t.opt_parity[b]                    # (N, K)
    optjj = jnp.maximum(optj, 0)
    opt_pv = (optj >= 0) & coded[:, None] & parity_valid[optjj, pr[:, None]]
    opt_pport = t.par_port[optjj]
    s0 = t.opt_sibs[b][:, :, 0]
    s1 = t.opt_sibs[b][:, :, 1]
    s0c = jnp.maximum(s0, 0)
    s1c = jnp.maximum(s1, 0)
    may_serve = cand_valid & fresh_in_bank
    can_rd = cand_valid & (fl > 0)
    opt_may = may_serve[:, None] & opt_pv

    served0 = jnp.zeros((n,), bool)
    mode0 = jnp.full((n,), MODE_UNSERVED, jnp.int32)
    sym0 = jnp.zeros((p.n_data, p.n_rows), bool)   # materialized this cycle

    def cond(carry):
        return carry[0] < n_trips

    def body(carry):
        k, port_busy, sym, served, mode = carry
        c = order[k]
        bc = b[c]
        ic = i[c]

        # --- score every action ------------------------------------------
        f_sym = may_serve[c] & sym[bc, ic] & bool(p.coalesce)
        f_dir = may_serve[c] & ~port_busy[bc]
        s0r, s1r = s0[c], s1[c]                  # (K,)
        s0cr, s1cr = s0c[c], s1c[c]
        sa0 = sym[s0cr, ic] & (s0r >= 0)
        sa1 = sym[s1cr, ic] & (s1r >= 0)
        ok0 = (s0r < 0) | sa0 | ~port_busy[s0cr]
        ok1 = (s1r < 0) | sa1 | ~port_busy[s1cr]
        need0 = (s0r >= 0) & ~sa0
        need1 = (s1r >= 0) & ~sa1
        feas = opt_may[c] & ~port_busy[opt_pport[c]] & ok0 & ok1
        cost = 1 + need0.astype(jnp.int32) + need1.astype(jnp.int32)
        f_rd = can_rd[c] & ~port_busy[hold_idx[c]]
        scores = jnp.concatenate([
            jnp.where(f_sym, 0, INF_SCORE)[None],
            jnp.where(f_dir, 3, INF_SCORE)[None],
            jnp.where(feas, 2 * cost, INF_SCORE),
            jnp.where(f_rd, 2, INF_SCORE)[None],
        ])
        act = jnp.argmin(scores).astype(jnp.int32)
        found = scores[act] < INF_SCORE

        is_dir = found & (act == 1)
        is_opt = found & (act >= 2) & (act < 2 + MAX_OPTS)
        is_rd = found & (act == 2 + MAX_OPTS)
        k_sel = jnp.clip(act - 2, 0, MAX_OPTS - 1)
        need0_sel = need0[k_sel]
        need1_sel = need1[k_sel]
        sib0 = s0cr[k_sel]
        sib1 = s1cr[k_sel]

        # --- claim ports (the nop scatters mark the sink, as the ref does)
        p_dir = jnp.where(is_dir, bc, nop)
        p_par = jnp.where(is_opt, opt_pport[c, k_sel],
                          jnp.where(is_rd, hold_idx[c], nop))
        p_s0 = jnp.where(is_opt & need0_sel, sib0, nop)
        p_s1 = jnp.where(is_opt & need1_sel, sib1, nop)
        port_busy = (port_busy.at[p_dir].set(True).at[p_par].set(True)
                     .at[p_s0].set(True).at[p_s1].set(True))

        # --- materialize symbols (true set semantics, see module docstring)
        oob = jnp.int32(p.n_data)
        sym = sym.at[jnp.where(is_dir | is_opt, bc, oob), ic].set(
            True, mode="drop")
        sym = sym.at[jnp.where(is_opt & need0_sel, sib0, oob), ic].set(
            True, mode="drop")
        sym = sym.at[jnp.where(is_opt & need1_sel, sib1, oob), ic].set(
            True, mode="drop")

        served = served.at[c].set(found)
        mode = mode.at[c].set(jnp.where(found, act, MODE_UNSERVED))
        return k + 1, port_busy, sym, served, mode

    carry = (jnp.int32(0), port_busy, sym0, served0, mode0)
    _, port_busy, _, served, mode = jax.lax.while_loop(cond, body, carry)
    # the masked no-op claims land on the sink slot; mark it busy even when
    # the walk never reaches a valid candidate, so its state is
    # deterministic for downstream consumers
    port_busy = port_busy.at[p.n_ports].set(True)
    n_served = jnp.sum(served).astype(jnp.int32)
    n_degraded = jnp.sum(
        served & ((mode == MODE_FROM_SYM) | ((mode >= MODE_OPT0) & (mode < MODE_REDIRECT)))
    ).astype(jnp.int32)
    return ReadPlan(served, mode, port_busy, n_served, n_degraded)


def build_write_pattern(
    p: MemParams,
    t: JTables,
    cand_bank: jnp.ndarray,
    cand_row: jnp.ndarray,
    cand_age: jnp.ndarray,
    cand_valid: jnp.ndarray,
    port_busy: jnp.ndarray,
    fresh_loc: jnp.ndarray,
    parity_valid: jnp.ndarray,
    region_slot: jnp.ndarray,
    parked_count: jnp.ndarray,
    rc_bank: jnp.ndarray,
    rc_row: jnp.ndarray,
    rc_valid: jnp.ndarray,
    rs_active=None,
    down=None,
) -> WritePlan:
    import jax

    n = cand_bank.shape[0]
    rs = p.region_size
    rs_a = rs if rs_active is None else rs_active
    order, n_trips = _walk_bounds(cand_age, cand_valid)
    nop = jnp.int32(p.n_ports)

    # ---- per-candidate tables, gathered once ---------------------------
    b = jnp.maximum(cand_bank, 0)
    i = jnp.maximum(cand_row, 0)
    region = i // rs_a
    slot = region_slot[region]
    coded = slot >= 0
    pr = jnp.maximum(slot, 0) * rs + i % rs_a
    optj = t.opt_parity[b]                    # (N, K)
    optjj = jnp.maximum(optj, 0)
    opt_pport = t.par_port[optjj]
    mem = t.par_members[optjj]                # (N, K, MAX_SIBS+1)
    memc = jnp.maximum(mem, 0)
    park_possible = cand_valid[:, None] & (optj >= 0) & coded[:, None]
    need_rc_dir = coded & (t.opt_n[b] > 0)
    park_base = 2 + jnp.arange(MAX_OPTS, dtype=jnp.int32)
    # ---- degraded-write mode (``down`` = currently-down data banks).
    # A candidate is *sticky* when its own bank is down or any parity
    # option covering it has a down member: its park stays parked (no
    # recode request) until the rebuild sweep drains it — retiring the park
    # early would rewrite a member bank and strand the down-covering
    # parities invalid, killing the down bank's degraded readability. The
    # scoring shift prefers (a) normal parks, (b) parks into parities
    # whose members are all alive, (c) parks into down-covering parities,
    # (d) a direct write (which invalidates EVERY covering parity row) —
    # strictly last for a sticky-but-alive bank. Sticky parks also waive
    # the recode-queue-space requirement (they don't enqueue).
    if down is not None:
        opt_down = jnp.any((mem >= 0) & (mem != b[:, None, None])
                           & down[memc], axis=2)             # (N, K)
        sticky = down[b] | jnp.any((optj >= 0) & coded[:, None] & opt_down,
                                   axis=1)
        dir_score = jnp.where(sticky, 2 + 2 * MAX_OPTS + 2, 1)
        park_shift = jnp.where(opt_down, MAX_OPTS + 2, 0)

    served0 = jnp.zeros((n,), bool)
    mode0 = jnp.full((n,), WMODE_UNSERVED, jnp.int32)

    def cond(carry):
        return carry[0] < n_trips

    def body(carry):
        (k, port_busy, served, mode, fresh_loc, parity_valid, parked_count,
         rc_bank, rc_row, rc_valid, dropped) = carry
        c = order[k]
        bc = b[c]
        ic = i[c]
        flc = fresh_loc[bc, ic]
        rc_space = jnp.any(~rc_valid)

        # --- score direct + park options ---------------------------------
        f_dir = cand_valid[c] & ~port_busy[bc]
        occ = jnp.any(
            (mem[c] >= 0) & (mem[c] != bc)
            & (fresh_loc[memc[c], ic] == optjj[c][:, None] + 1), axis=1)
        if down is None:
            park_feas = (park_possible[c] & ~port_busy[opt_pport[c]] & ~occ
                         & rc_space)
            scores = jnp.concatenate([
                jnp.where(f_dir, 1, INF_SCORE)[None],
                jnp.where(park_feas, park_base, INF_SCORE),
            ])
        else:
            park_feas = (park_possible[c] & ~port_busy[opt_pport[c]] & ~occ
                         & (rc_space | sticky[c]))
            scores = jnp.concatenate([
                jnp.where(f_dir, dir_score[c], INF_SCORE)[None],
                jnp.where(park_feas, park_base + park_shift[c], INF_SCORE),
            ])
        act = jnp.argmin(scores).astype(jnp.int32)
        found = scores[act] < INF_SCORE
        is_dir = found & (act == 0)
        is_park = found & (act >= 1)
        k_sel = jnp.clip(act - 1, 0, MAX_OPTS - 1)
        j_sel = optjj[c, k_sel]

        port_busy = port_busy.at[jnp.where(is_dir, bc, nop)].set(True)
        port_busy = port_busy.at[
            jnp.where(is_park, opt_pport[c, k_sel], nop)].set(True)

        # --- freshness bookkeeping ---------------------------------------
        was_parked = flc > 0
        new_fl = jnp.where(is_dir, 0, jnp.where(is_park, j_sel + 1, flc))
        fresh_loc = fresh_loc.at[bc, ic].set(new_fl)
        delta = (
            is_park.astype(jnp.int32) * (~was_parked).astype(jnp.int32)
            - is_dir.astype(jnp.int32) * was_parked.astype(jnp.int32)
        )
        parked_count = parked_count.at[region[c]].add(delta)
        # parity invalidation
        inv = ((optj[c] >= 0) & coded[c]
               & (is_dir | (is_park & (optjj[c] == j_sel))))
        parity_valid = parity_valid.at[
            jnp.where(inv, optjj[c], parity_valid.shape[0]), pr[c]].set(
                False, mode="drop")
        # recode request so freshness is eventually restored (a sticky park
        # stays parked — the rebuild sweep enqueues it once its down
        # parity-group member is recovering, see repro.faults.inject)
        if down is None:
            need_rc = (is_dir & need_rc_dir[c]) | is_park
        else:
            need_rc = (is_dir & need_rc_dir[c]) | (is_park & ~sticky[c])
        rc_bank, rc_row, rc_valid, ok = _rc_push(
            rc_bank, rc_row, rc_valid, bc, ic, need_rc)
        dropped = dropped + (need_rc & ~ok).astype(jnp.int32)

        served = served.at[c].set(found)
        mode = mode.at[c].set(jnp.where(found, act, WMODE_UNSERVED))
        return (k + 1, port_busy, served, mode, fresh_loc, parity_valid,
                parked_count, rc_bank, rc_row, rc_valid, dropped)

    carry = (jnp.int32(0), port_busy, served0, mode0, fresh_loc,
             parity_valid, parked_count, rc_bank, rc_row, rc_valid,
             jnp.int32(0))
    out = jax.lax.while_loop(cond, body, carry)
    (_, port_busy, served, mode, fresh_loc, parity_valid, parked_count,
     rc_bank, rc_row, rc_valid, dropped) = out
    port_busy = port_busy.at[p.n_ports].set(True)   # deterministic sink
    n_served = jnp.sum(served).astype(jnp.int32)
    n_parked = jnp.sum(served & (mode >= WMODE_PARK0)).astype(jnp.int32)
    return WritePlan(served, mode, port_busy, fresh_loc, parity_valid,
                     parked_count, rc_bank, rc_row, rc_valid, n_served,
                     n_parked, dropped)


def _rc_push(rc_bank, rc_row, rc_valid, b, i, do):
    """Push (b, i) into the recode ring unless present; returns ok flag."""
    dup = jnp.any(rc_valid & (rc_bank == b) & (rc_row == i))
    free = ~rc_valid
    has_free = jnp.any(free)
    idx = jnp.argmax(free)  # first free slot
    do_ins = do & ~dup & has_free
    rc_bank = rc_bank.at[idx].set(jnp.where(do_ins, b, rc_bank[idx]))
    rc_row = rc_row.at[idx].set(jnp.where(do_ins, i, rc_row[idx]))
    rc_valid = rc_valid.at[idx].set(jnp.where(do_ins, True, rc_valid[idx]))
    ok = dup | has_free
    return rc_bank, rc_row, rc_valid, ok
