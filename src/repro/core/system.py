"""The coded memory system: core arbiter + bank queues + access scheduler.

One ``cycle_fn`` call = one memory clock cycle (paper Fig 2 / §IV):

  1. **Core arbiter** — each core's pending request is pushed into its
     destination bank's read/write queue; a full queue stalls the core.
  2. **Access scheduler** — a write-drain hysteresis picks read or write mode
     (the paper serves writes "only when the write bank queues are nearly
     full"); the corresponding pattern builder schedules this cycle's
     accesses across data + parity ports.
  3. **Datapath** — served reads return values (direct / XOR-decode /
     redirect); served writes commit payloads to data banks or park them in
     parity rows. ``golden`` tracks memory order for the test invariants.
  4. **ReCoding unit** — retires stale-parity work using leftover ports.
  5. **Dynamic coding unit** — hot-region selection / encode / evict.

``run()`` wraps ``cycle_fn`` in a ``lax.scan`` for trace-driven simulation
(the Ramulator-replacement used by the benchmarks). ``run_chunk()`` advances
an explicit ``SimState`` carry over a fixed-shape staged chunk of a longer
stream — the device half of ``repro.traces.stream.stream_replay``, which
replays arbitrarily long traces under a constant device-memory footprint.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as ctl
from repro.core.codes import MAX_OPTS, MAX_SIBS, CodeTables
from repro.core.dynamic import dynamic_step
from repro.core.recoding import recode_step
from repro.core.state import (MemParams, MemState, TunableParams,
                              active_geometry, init_state, make_tunables,
                              wide_add, wide_total)
from repro.faults import inject as finject
from repro.faults import plan as fplan
from repro.obs import planes as obs

INT32_MAX = jnp.iinfo(jnp.int32).max


class Trace(NamedTuple):
    """Per-core request streams. Invalid entries are idle cycles."""

    bank: jnp.ndarray      # (n_cores, T) int32
    row: jnp.ndarray       # (n_cores, T) int32
    is_write: jnp.ndarray  # (n_cores, T) bool
    data: jnp.ndarray      # (n_cores, T) int32 write payloads
    valid: jnp.ndarray     # (n_cores, T) bool


def drain_bound(n_cores: int, length: int, backlog: int = 0) -> int:
    """Worst-case cycle budget to drain ``length`` requests per core.

    Derivation: the system serves at least one access per cycle whenever any
    queue is non-empty (the write-drain hysteresis always picks a non-empty
    side), so ``n_cores * length`` requests fully serialized on a single
    port need at most ``n_cores * length`` service cycles. The 1.5 factor
    covers cycles where a request is in flight but its queue push stalled on
    a full destination queue (a stalled core retries every cycle, and every
    such cycle is also a service cycle for the queue blocking it — 0.5 per
    request over-counts this deliberately), and the +64 constant covers the
    cold start (empty queues) and the post-drain settling of the recoding /
    dynamic-coding units.

    ``backlog`` adds carried-over work that is *already queued* when the
    budget starts — the chunked-replay case (``CodedMemorySystem.run_chunk``),
    where up to ``2 * n_data * queue_depth`` requests from the previous chunk
    may still occupy the read+write queues. It is counted like any other
    request (one service cycle each).

    This is the single shared bound for the looped (``sim.ramulator``),
    batched (``repro.sweep``) and streamed (``repro.traces``) paths — do not
    re-derive it inline.
    """
    return int((n_cores * length + backlog) * 1.5) + 64


class SimState(NamedTuple):
    mem: MemState
    core_ptr: jnp.ndarray   # (n_cores,) int32
    done_cycle: jnp.ndarray  # () int32, -1 until the workload drains


def quiescent(st: "SimState") -> jnp.ndarray:
    """Per-point observable fixed point: workload drained (``done_cycle``
    latched), encoder idle, recode ring empty. After this, every further
    cycle is an observable no-op (the dynamic unit starts nothing new after
    drain — ``dynamic_step``'s ``quiesce``), which is what makes every
    early exit bit-identical to running a bound out. The ONE definition
    shared by the sweep engine's batched early exit, ``run_chunk``'s
    chunk-exit, and the streaming drivers — new drain conditions must land
    here, not in per-caller copies. Works on single and batched states
    (trailing-axis reduction over the ring).

    With fault injection on, a point also isn't quiescent while a
    scheduled fault event (a pending failure, or a failure with a recovery
    whose rebuild hasn't completed) can still change observable state —
    see ``repro.faults.inject.quiescent_fault_pending``."""
    m = st.mem
    q = ((st.done_cycle >= 0) & (m.enc_region < 0)
         & ~jnp.any(m.rc_valid, axis=-1))
    if m.fault is not None:
        q = q & ~finject.quiescent_fault_pending(m.fault, m.cycle)
    return q


class CycleOut(NamedTuple):
    """Per-cycle introspection (read datapath results for invariant tests)."""

    r_served: jnp.ndarray  # (N,) bool
    r_bank: jnp.ndarray    # (N,) int32
    r_row: jnp.ndarray     # (N,) int32
    r_value: jnp.ndarray   # (N,) int32
    n_served: jnp.ndarray  # () int32 (reads+writes)


class SimResult(NamedTuple):
    cycles: int
    completed: bool
    served_reads: int
    served_writes: int
    degraded_reads: int
    parked_writes: int
    switches: int
    recode_backlog: int
    stall_cycles: int
    avg_read_latency: float
    avg_write_latency: float
    rc_dropped: int = 0   # recode requests lost to a full ring (write path)
    # per-window critical-word latency stats, filled by the streaming replay
    # driver (``repro.traces.stream``): one (n_served, avg_latency) pair per
    # replay window. Empty for single-shot runs, so equality comparisons
    # between engine paths are unaffected; strip with
    # ``repro.traces.stream.strip_windows`` before comparing streamed vs
    # single-shot results.
    window_read_latency: tuple = ()
    window_write_latency: tuple = ()
    # fault-injection availability stats (repro.faults); all 0 when the
    # ``faults`` flag is off, so pre-fault result comparisons are unaffected
    unserved_reads: int = 0      # reads fail-fast-dropped (unservable)
    lost_writes: int = 0         # writes dropped with no parity coverage
    fault_degraded_reads: int = 0  # reads served degraded because their
                                   # bank was down (subset of degraded_reads)
    dead_bank_cycles: int = 0    # sum over banks of cycles spent down
                                 # (counted until the workload drains)


def result_from_host(m: MemState, done_cycle) -> SimResult:
    """One point's SimResult from host-side (numpy) MemState leaves — the
    single assembly point shared by ``CodedMemorySystem.summarize`` and the
    sweep engine's ``summarize_batch`` (new stats get wired exactly once)."""
    dc = int(done_cycle)
    sr = int(m.served_reads)
    sw = int(m.served_writes)
    f = m.fault
    return SimResult(
        cycles=dc if dc >= 0 else int(m.cycle),
        completed=dc >= 0,
        served_reads=sr,
        served_writes=sw,
        degraded_reads=int(m.degraded_reads),
        parked_writes=int(m.parked_writes),
        switches=int(m.switches),
        recode_backlog=int(np.sum(m.rc_valid)),
        stall_cycles=wide_total(m.stall_cycles),
        avg_read_latency=wide_total(m.read_latency_sum) / max(sr, 1),
        avg_write_latency=wide_total(m.write_latency_sum) / max(sw, 1),
        rc_dropped=int(m.rc_dropped),
        unserved_reads=int(f.unserved_reads) if f is not None else 0,
        lost_writes=int(f.lost_writes) if f is not None else 0,
        fault_degraded_reads=int(f.fault_degraded) if f is not None else 0,
        dead_bank_cycles=int(np.sum(f.dead_cycles)) if f is not None else 0,
    )


class CodedMemorySystem:
    """Facade owning the static tables/params; methods are jit-compiled.

    ``tunables`` holds the default traced knobs (write-drain thresholds,
    selection period); each ``cycle_fn``/``run`` call may override them with
    an explicit ``TunableParams`` — that is how ``repro.sweep`` batches a
    grid of tunables through one compiled program.
    """

    def __init__(self, tables: CodeTables, params: MemParams, n_cores: int = 8,
                 tunables: Optional[TunableParams] = None):
        self.tables = tables
        self.p = params
        self.t = ctl.jtables(tables)
        self.n_cores = n_cores
        self.tunables = (tunables if tunables is not None
                         else make_tunables(queue_depth=params.queue_depth))

    # ------------------------------------------------------------------ init
    def init(self, tn: Optional[TunableParams] = None,
             region_priors=None, fault_plan=None) -> SimState:
        """Initial state; ``tn`` masks a padded group allocation down to the
        point's active geometry (see ``init_state``). ``region_priors`` is a
        ranked array of hot region ids (e.g. from
        ``repro.traces.profiler``) pre-mapped into parity slots so the
        dynamic coding unit starts warm instead of cold. ``fault_plan``
        installs a ``repro.faults.FaultPlan`` erasure/stutter schedule
        (requires ``make_params(faults=True)``)."""
        return SimState(
            mem=init_state(self.p, tn, region_priors=region_priors,
                           n_cores=self.n_cores, fault_plan=fault_plan),
            core_ptr=jnp.zeros((self.n_cores,), jnp.int32),
            done_cycle=jnp.int32(-1),
        )

    # --------------------------------------------------------------- arbiter
    def _arbiter(self, st: SimState, trace: Trace, rs_a, stream_end=None):
        """Push each core's pending request into its destination queue.

        Vectorized: cores are ranked within their destination (bank, r/w)
        queue by core index — the service order a sequential walk takes —
        and all pushes land in one scatter. The first ``rank`` free slots of
        a queue go to the first ``rank`` ranked cores, so slot assignment,
        full-queue stalls and pointer advances are bit-identical to the
        sequential golden model (``repro.oracle``, conformance-tested).

        ``stream_end`` (chunked replay): per-core count of staged requests —
        a core whose pointer reaches its stream end has consumed its whole
        request stream; INT32_MAX marks "more data beyond this chunk" (the
        chunk driver exits before such a core can over-run the staging
        buffer). ``None`` (single-shot) means the trace length is the end
        for every core — the exact pre-chunking program.
        """
        p = self.p
        m = st.mem
        tlen = trace.bank.shape[1]
        nc = self.n_cores
        car = jnp.arange(nc)

        pos = st.core_ptr
        in_range = pos < (tlen if stream_end is None else stream_end)
        pc = jnp.minimum(pos, tlen - 1)
        v = trace.valid[car, pc] & in_range
        b = jnp.maximum(trace.bank[car, pc], 0)
        i = jnp.maximum(trace.row[car, pc], 0)
        isw = trace.is_write[car, pc]
        payload = trace.data[car, pc]

        older = jnp.tril(jnp.ones((nc, nc), bool), k=-1)
        same_bank = b[:, None] == b[None, :]
        want_r = v & ~isw
        want_w = v & isw
        rank_r = jnp.sum(same_bank & older & want_r[None, :], axis=1)
        rank_w = jnp.sum(same_bank & older & want_w[None, :], axis=1)
        free_r = jnp.sum(~m.rq_valid, axis=1)
        free_w = jnp.sum(~m.wq_valid, axis=1)
        full = jnp.where(isw, rank_w >= free_w[b], rank_r >= free_r[b])
        push = v & ~full
        pr_ = push & ~isw
        pw_ = push & isw

        def rank_to_slot(valid):
            """(n_data, D) queue validity → map[bank, rank] = rank-th free slot."""
            d = valid.shape[1]
            fr = ~valid
            free_rank = jnp.cumsum(fr, axis=1) - 1
            return jnp.full((p.n_data, d), d, jnp.int32).at[
                jnp.arange(p.n_data)[:, None],
                jnp.where(fr, free_rank, d)
            ].set(jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32),
                                   (p.n_data, d)), mode="drop")

        dq = p.queue_depth
        slot_r = rank_to_slot(m.rq_valid)[b, jnp.minimum(rank_r, dq - 1)]
        slot_w = rank_to_slot(m.wq_valid)[b, jnp.minimum(rank_w, dq - 1)]
        oob = jnp.int32(p.n_data)
        br = jnp.where(pr_, b, oob)
        bw = jnp.where(pw_, b, oob)
        cyc = jnp.broadcast_to(m.cycle, (nc,))
        rq_row = m.rq_row.at[br, slot_r].set(i, mode="drop")
        rq_age = m.rq_age.at[br, slot_r].set(cyc, mode="drop")
        rq_valid = m.rq_valid.at[br, slot_r].set(True, mode="drop")
        wq_row = m.wq_row.at[bw, slot_w].set(i, mode="drop")
        wq_age = m.wq_age.at[bw, slot_w].set(cyc, mode="drop")
        wq_valid = m.wq_valid.at[bw, slot_w].set(True, mode="drop")
        wq_data = m.wq_data.at[bw, slot_w].set(payload, mode="drop")
        access_count = m.access_count.at[
            jnp.where(push, i // rs_a, p.n_regions)].add(1, mode="drop")
        stalls = wide_add(m.stall_cycles, jnp.sum(v & full))
        ptr = pos + (in_range & (push | ~v)).astype(jnp.int32)

        tele = m.tele
        if p.telemetry:
            # the full-queue rejection above is the ONLY core-stall source,
            # so this per-bank per-cause plane sums exactly to stall_cycles
            stall = v & full
            stall_cause = tele.stall_cause.at[
                jnp.where(stall, b, oob), isw.astype(jnp.int32)
            ].add(1, mode="drop")
            # provenance carriers: the core id lands in the SAME slot the
            # request scatter above picked, so the serve step can attribute
            # each served candidate to its issuing core
            car32 = car.astype(jnp.int32)
            tele = tele._replace(
                stall_cause=stall_cause,
                rq_core=tele.rq_core.at[br, slot_r].set(car32, mode="drop"),
                wq_core=tele.wq_core.at[bw, slot_w].set(car32, mode="drop"),
            )
        mem = m._replace(
            rq_row=rq_row, rq_age=rq_age, rq_valid=rq_valid, wq_row=wq_row,
            wq_age=wq_age, wq_valid=wq_valid, wq_data=wq_data,
            access_count=access_count, stall_cycles=stalls, tele=tele,
        )
        return st._replace(mem=mem, core_ptr=ptr)

    # ----------------------------------------------------------- read values
    def _read_values(self, m: MemState, plan: ctl.ReadPlan, cb, ci, rs_a):
        """Vectorized XOR-decode datapath for the served reads."""
        p, t = self.p, self.t
        rs = p.region_size
        b = jnp.maximum(cb, 0)
        i = jnp.maximum(ci, 0)
        slot = m.region_slot[i // rs_a]
        pr = jnp.maximum(slot, 0) * rs + i % rs_a
        direct_val = m.banks_data[b, i]
        fl = m.fresh_loc[b, i]
        holder = jnp.maximum(fl - 1, 0)
        redirect_val = m.parity_data[holder, pr]
        k = jnp.clip(plan.mode - ctl.MODE_OPT0, 0, MAX_OPTS - 1)
        j = jnp.maximum(t.opt_parity[b, k], 0)
        dec = m.parity_data[j, pr]
        for mm in range(MAX_SIBS):
            s = t.opt_sibs[b, k, mm]
            dec = dec ^ jnp.where(s >= 0, m.banks_data[jnp.maximum(s, 0), i], 0)
        val = jnp.where(
            plan.mode == ctl.MODE_REDIRECT, redirect_val,
            jnp.where((plan.mode >= ctl.MODE_OPT0) & (plan.mode < ctl.MODE_REDIRECT),
                      dec, direct_val),
        )
        return jnp.where(plan.served, val, 0)

    # ------------------------------------------------------- write datapath
    def _commit_writes(self, m: MemState, plan: ctl.WritePlan, cb, ci_, ca,
                       cv, cd, rs_a):
        """Commit served write payloads in age order (last write wins).

        Vectorized: rather than walking candidates in a fori_loop, the
        age-order position of each candidate is scatter-maxed into its target
        cell; only the positionally-latest (youngest) served write per cell
        lands — the same value the sequential walk leaves behind.
        """
        p, t = self.p, self.t
        rs = p.region_size
        b = jnp.maximum(cb, 0)
        i = jnp.maximum(ci_, 0)
        n = cb.shape[0]
        order = jnp.argsort(jnp.where(cv, ca, INT32_MAX))
        pos = jnp.zeros((n,), jnp.int32).at[order].set(
            jnp.arange(n, dtype=jnp.int32))
        slot = m.region_slot[i // rs_a]
        pr = jnp.maximum(slot, 0) * rs + i % rs_a
        kk = jnp.clip(plan.mode - ctl.WMODE_PARK0, 0, MAX_OPTS - 1)
        j = jnp.maximum(t.opt_parity[b, kk], 0)
        is_dir = plan.served & (plan.mode == ctl.WMODE_DIRECT)
        is_park = plan.served & (plan.mode >= ctl.WMODE_PARK0)
        oob_b = jnp.int32(p.n_data)
        oob_j = jnp.int32(m.parity_data.shape[0])

        def winners(mask, rows, cols, shape, oob):
            best = jnp.full(shape, -1, jnp.int32).at[
                jnp.where(mask, rows, oob), cols].max(pos, mode="drop")
            return mask & (best[rows, cols] == pos)

        win_d = winners(is_dir, b, i, m.banks_data.shape, oob_b)
        banks_data = m.banks_data.at[
            jnp.where(win_d, b, oob_b), i].set(cd, mode="drop")
        win_p = winners(is_park, j, pr, m.parity_data.shape, oob_j)
        parity_data = m.parity_data.at[
            jnp.where(win_p, j, oob_j), pr].set(cd, mode="drop")
        win_g = winners(plan.served, b, i, m.golden.shape, oob_b)
        golden = m.golden.at[
            jnp.where(win_g, b, oob_b), i].set(cd, mode="drop")
        return banks_data, parity_data, golden

    # ------------------------------------------------------------- one cycle
    @functools.partial(jax.jit, static_argnums=0)
    def cycle_fn(self, st: SimState, trace: Trace,
                 tn: Optional[TunableParams] = None,
                 stream_end: Optional[jnp.ndarray] = None):
        p, t = self.p, self.t
        if tn is None:
            tn = self.tunables
        # the point's own region geometry (== the allocation unless this
        # program serves a padded sweep group, see state.active_geometry)
        rs_a, nr_a = active_geometry(p, tn)
        # once the workload has drained there is no traffic to react to: the
        # dynamic unit stops starting encodes, so the system reaches a
        # quiescent fixed point (done + recode empty + encoder idle) that
        # lets the sweep engine cut trailing dead cycles without changing
        # any observable statistic.
        was_done = st.done_cycle >= 0
        st = self._arbiter(st, trace, rs_a, stream_end)
        m = st.mem
        if p.telemetry:
            # post-arbiter occupancy is the per-cycle maximum (slots only
            # free up in the serve step below)
            m = m._replace(tele=m.tele._replace(
                rq_hwm=jnp.maximum(m.tele.rq_hwm,
                                   jnp.sum(m.rq_valid, axis=1, dtype=jnp.int32)),
                wq_hwm=jnp.maximum(m.tele.wq_hwm,
                                   jnp.sum(m.wq_valid, axis=1, dtype=jnp.int32)),
            ))
        n_cand = p.n_data * p.queue_depth
        port_busy0 = jnp.zeros((p.n_ports + 1,), bool)
        bank_ids = jnp.repeat(jnp.arange(p.n_data, dtype=jnp.int32), p.queue_depth)

        # ---- fault injection (repro.faults): derive this cycle's fault
        # predicates, count dead cycles, fail-fast-drop unservable queue
        # entries, and seed the builders' port mask so a down bank's port
        # reads permanently busy (and stuttering ports transiently busy).
        # Ordering matters and is mirrored exactly by the oracle: drops land
        # after the arbiter (the request was accepted and counted) and
        # before the write-drain hysteresis reads queue occupancy.
        if p.faults:
            fs = m.fault
            down = fplan.bank_down(fs, m.cycle)
            rebuilding = fplan.bank_rebuilding(fs, m.cycle)
            down_hard = down & ~rebuilding
            stut = fplan.stutter_busy(fs, m.cycle)
            # dead cycles are counted until the workload drains (afterwards
            # a permanently-dead bank would count forever, breaking the
            # quiescent fixed point the early-exit paths rely on)
            dead_inc = (down & ~was_done).astype(jnp.uint32)
            rq_v2, wq_v2, n_uns, n_lost = finject.drop_unservable(
                p, t, down_hard, m.rq_row, m.rq_valid, m.wq_row, m.wq_valid,
                m.fresh_loc, m.parity_valid, m.region_slot, rs_a)
            fs = fs._replace(
                dead_cycles=fs.dead_cycles + dead_inc,
                unserved_reads=fs.unserved_reads + n_uns,
                lost_writes=fs.lost_writes + n_lost)
            m = m._replace(rq_valid=rq_v2, wq_valid=wq_v2, fault=fs)
            if p.telemetry:
                m = m._replace(tele=m.tele._replace(
                    dead_cycles=m.tele.dead_cycles + dead_inc))
            port_busy0 = port_busy0.at[: p.n_data].set(down)
            port_busy0 = port_busy0.at[: p.n_ports].set(
                port_busy0[: p.n_ports] | stut)

        # write-drain hysteresis
        wq_occ = jnp.max(jnp.sum(m.wq_valid, axis=1))
        any_r = jnp.any(m.rq_valid)
        any_w = jnp.any(m.wq_valid)
        wm = jnp.where(m.write_mode, wq_occ > tn.wq_lo, wq_occ >= tn.wq_hi)
        serve_writes = (wm | (~any_r & any_w)) & any_w

        def do_reads(m, active=True):
            cb = bank_ids
            ci_ = m.rq_row.reshape(-1)
            ca = m.rq_age.reshape(-1)
            cv = m.rq_valid.reshape(-1) & active
            plan = ctl.build_read_pattern(
                p, t, cb, ci_, ca, cv, port_busy0, m.fresh_loc, m.parity_valid,
                m.region_slot, rs_a,
            )
            vals = self._read_values(m, plan, cb, ci_, rs_a)
            lat = jnp.sum(jnp.where(plan.served, m.cycle - ca, 0))
            tele = m.tele
            if p.telemetry:
                # provenance class from the plan's action id; latency
                # histogram over served candidates; unserved-but-valid
                # candidates count a read-conflict wait cycle on their bank.
                # (With ``active=False`` — the masked off-duty branch — cv
                # and plan.served are all False, so every scatter here drops
                # and the merged ``pick`` takes the other branch's updates.)
                cls = jnp.where(
                    plan.mode == ctl.MODE_DIRECT, 0,
                    jnp.where(plan.mode == ctl.MODE_FROM_SYM, 1,
                              jnp.where(plan.mode >= ctl.MODE_REDIRECT, 3, 2)))
                if p.faults:
                    # degraded serves whose cause is a down bank get their
                    # own provenance class (redirects to a parked copy are
                    # a freshness artifact, not a fault symptom — class 3)
                    cls = jnp.where(down[cb] & ((cls == 1) | (cls == 2)),
                                    4, cls)
                core = jnp.where(plan.served, tele.rq_core.reshape(-1),
                                 jnp.int32(self.n_cores))
                tele = tele._replace(
                    read_mode_core=tele.read_mode_core.at[core, cls].add(
                        1, mode="drop"),
                    lat_hist_read=tele.lat_hist_read.at[
                        jnp.where(plan.served, obs.lat_bin(m.cycle - ca),
                                  obs.HIST_BINS)].add(1, mode="drop"),
                    wait_cause=tele.wait_cause.at[
                        jnp.where(cv & ~plan.served, cb, jnp.int32(p.n_data)),
                        obs.WAIT_READ].add(1, mode="drop"),
                )
            fault = m.fault
            if p.faults:
                deg_f = plan.served & down[cb] & (
                    (plan.mode == ctl.MODE_FROM_SYM)
                    | ((plan.mode >= ctl.MODE_OPT0)
                       & (plan.mode < ctl.MODE_REDIRECT)))
                fault = fault._replace(
                    fault_degraded=fault.fault_degraded
                    + jnp.sum(deg_f).astype(jnp.int32))
            m = m._replace(
                rq_valid=m.rq_valid & ~plan.served.reshape(p.n_data, p.queue_depth),
                served_reads=m.served_reads + plan.n_served,
                degraded_reads=m.degraded_reads + plan.n_degraded,
                read_latency_sum=wide_add(m.read_latency_sum, lat),
                tele=tele,
                fault=fault,
            )
            out = CycleOut(plan.served, cb, ci_, vals, plan.n_served)
            return m, plan.port_busy, out

        def do_writes(m, active=True):
            cb = bank_ids
            ci_ = m.wq_row.reshape(-1)
            ca = m.wq_age.reshape(-1)
            cv = m.wq_valid.reshape(-1) & active
            cd = m.wq_data.reshape(-1)
            plan = ctl.build_write_pattern(
                p, t, cb, ci_, ca, cv, port_busy0, m.fresh_loc, m.parity_valid,
                m.region_slot, m.parked_count, m.rc_bank, m.rc_row, m.rc_valid,
                rs_a, down=down if p.faults else None,
            )
            banks_data, parity_data, golden = self._commit_writes(
                m, plan, cb, ci_, ca, cv, cd, rs_a)
            lat = jnp.sum(jnp.where(plan.served, m.cycle - ca, 0))
            tele = m.tele
            if p.telemetry:
                cls = (plan.mode >= ctl.WMODE_PARK0).astype(jnp.int32)
                core = jnp.where(plan.served, tele.wq_core.reshape(-1),
                                 jnp.int32(self.n_cores))
                tele = tele._replace(
                    write_mode_core=tele.write_mode_core.at[core, cls].add(
                        1, mode="drop"),
                    lat_hist_write=tele.lat_hist_write.at[
                        jnp.where(plan.served, obs.lat_bin(m.cycle - ca),
                                  obs.HIST_BINS)].add(1, mode="drop"),
                    wait_cause=tele.wait_cause.at[
                        jnp.where(cv & ~plan.served, cb, jnp.int32(p.n_data)),
                        obs.WAIT_WRITE].add(1, mode="drop"),
                )
            m = m._replace(
                tele=tele,
                wq_valid=m.wq_valid & ~plan.served.reshape(p.n_data, p.queue_depth),
                fresh_loc=plan.fresh_loc,
                parity_valid=plan.parity_valid,
                parked_count=plan.parked_count,
                rc_bank=plan.rc_bank, rc_row=plan.rc_row, rc_valid=plan.rc_valid,
                served_writes=m.served_writes + plan.n_served,
                parked_writes=m.parked_writes + plan.n_parked,
                rc_dropped=m.rc_dropped + plan.n_rc_dropped,
                write_latency_sum=wide_add(m.write_latency_sum, lat),
                banks_data=banks_data, parity_data=parity_data, golden=golden,
            )
            out = CycleOut(
                jnp.zeros((n_cand,), bool), cb, ci_, jnp.zeros((n_cand,), jnp.int32),
                plan.n_served,
            )
            return m, plan.port_busy, out

        # Under vmap, ``lax.cond`` would evaluate both branches for every
        # point anyway — at the full cost of each builder's walk over loaded
        # queues. Instead run both branches with the off-duty builder's
        # candidates masked invalid (its compacted walk exits immediately)
        # and select per point. The selected branch saw exactly the
        # candidates a ``cond`` would hand it, so results are bit-identical;
        # the discarded branch is discarded either way.
        m_r, pb_r, out_r = do_reads(m, active=~serve_writes)
        m_w, pb_w, out_w = do_writes(m, active=serve_writes)
        pick = lambda w, r: jax.tree.map(                  # noqa: E731
            lambda x, y: jnp.where(serve_writes, x, y), w, r)
        m, port_busy, out = pick(m_w, m_r), pick(pb_w, pb_r), pick(out_w, out_r)
        m = m._replace(write_mode=wm)

        # recoding unit uses leftover ports. A REBUILDING bank's port is
        # granted back to it here (and only here): the builders saw it
        # busy, so the rebuild's restores/recomputes get the port the bank
        # cannot yet use for service. Stutter still applies.
        if p.faults:
            rc_pb = port_busy.at[: p.n_data].set(
                jnp.where(rebuilding, stut[: p.n_data],
                          port_busy[: p.n_data]))
        else:
            rc_pb = port_busy
        rc = recode_step(
            p, t, rc_pb, m.fresh_loc, m.parity_valid, m.parked_count,
            m.rc_bank, m.rc_row, m.rc_valid, m.region_slot, m.banks_data,
            m.parity_data, rs_a, down=down_hard if p.faults else None,
        )
        m = m._replace(
            fresh_loc=rc.fresh_loc, parity_valid=rc.parity_valid,
            parked_count=rc.parked_count, rc_valid=rc.rc_valid,
            banks_data=rc.banks_data, parity_data=rc.parity_data,
        )
        if p.telemetry:
            # ring entries still pending after the recode unit ran charge a
            # recode-budget/port-starvation wait cycle to their bank
            tele = m.tele
            m = m._replace(tele=tele._replace(
                recode_retired=tele.recode_retired
                + rc.n_recoded.astype(jnp.uint32),
                wait_cause=tele.wait_cause.at[
                    jnp.where(m.rc_valid, jnp.maximum(m.rc_bank, 0),
                              jnp.int32(p.n_data)),
                    obs.WAIT_RECODE].add(1, mode="drop"),
            ))
        # online rebuild: sweep cells into the recode ring while any bank
        # is rebuilding; latch ``rebuilt`` (the bank rejoins) on completion
        if p.faults:
            rb_bank, rb_row, rb_valid, fs2 = finject.rebuild_scan(
                p, t, m.fault, m.cycle, rebuilding, down_hard, m.fresh_loc,
                m.parity_valid, m.region_slot, m.rc_bank, m.rc_row,
                m.rc_valid, rs_a, nr_a)
            m = m._replace(rc_bank=rb_bank, rc_row=rb_row,
                           rc_valid=rb_valid, fault=fs2)
        # dynamic coding unit
        dy = dynamic_step(
            p, t, tn, m.cycle, m.region_slot, m.slot_region, m.access_count,
            m.parked_count, m.parity_valid, m.parity_data, m.banks_data,
            m.enc_region, m.enc_remaining, m.enc_slot, m.switches,
            quiesce=was_done,
        )
        m = m._replace(
            region_slot=dy.region_slot, slot_region=dy.slot_region,
            access_count=dy.access_count, parity_valid=dy.parity_valid,
            parity_data=dy.parity_data, enc_region=dy.enc_region,
            enc_remaining=dy.enc_remaining, enc_slot=dy.enc_slot,
            switches=dy.switches,
        )
        # completion bookkeeping: a core is consumed once its pointer passes
        # its stream end (the full trace length in single-shot mode; the
        # staged request count for a chunk whose stream is exhausted;
        # never, for a chunk with more data behind it — INT32_MAX)
        tlen = trace.bank.shape[1]
        consumed = jnp.all(
            st.core_ptr >= (tlen if stream_end is None else stream_end))
        drained = ~jnp.any(m.rq_valid) & ~jnp.any(m.wq_valid)
        done = consumed & drained
        done_cycle = jnp.where((st.done_cycle < 0) & done, m.cycle, st.done_cycle)
        m = m._replace(cycle=m.cycle + 1)
        return SimState(m, st.core_ptr, done_cycle), out

    # ------------------------------------------------------------------- run
    @functools.partial(jax.jit, static_argnums=(0, 3))
    def _run(self, st: SimState, trace: Trace, n_cycles: int,
             tn: Optional[TunableParams] = None):
        def body(st, _):
            st, out = self.cycle_fn(st, trace, tn)
            return st, out.n_served

        return jax.lax.scan(body, st, None, length=n_cycles)

    def run(self, trace: Trace, n_cycles: int,
            tn: Optional[TunableParams] = None,
            st: Optional[SimState] = None,
            fault_plan=None) -> SimResult:
        """Single-shot replay; ``st`` carries in an explicit initial state
        (the chunked-replay driver threads states the same way).
        ``fault_plan`` installs an erasure/stutter schedule on the fresh
        initial state (ignored when ``st`` is given — put the plan in the
        state you pass)."""
        tn = tn if tn is not None else self.tunables
        st, _ = self._run(
            st if st is not None else self.init(tn, fault_plan=fault_plan),
            trace, n_cycles, tn)
        return self.summarize(st)

    # ----------------------------------------------------------- chunked run
    # NOTE: the SimState carry is deliberately NOT donated (unlike the sweep
    # engine's _scan_batch): a fresh init_state aliases one zero scalar
    # across several leaves (and priors/traced inits hold broadcast views),
    # and donating an aliased buffer twice is a runtime error on the first
    # chunk. The state is a small constant per chunk; the footprint bound
    # comes from the fixed staging-buffer shape.
    @functools.partial(jax.jit, static_argnums=(0, 4))
    def run_chunk(self, st: SimState, trace: Trace, stream_end: jnp.ndarray,
                  n_cycles: int, tn: Optional[TunableParams] = None) -> SimState:
        """One streaming-replay step: advance ``st`` over a staged chunk.

        ``trace`` is a fixed-shape staging buffer holding the next (up to)
        ``tlen`` requests of each core's stream, starting at each core's own
        global position; ``stream_end[c]`` is the number of staged requests
        for core ``c`` if its stream ends inside this buffer, else INT32_MAX.
        Runs cycles until (a) some core with more data behind the buffer has
        consumed all its staged requests (*starved* — the driver restages and
        calls again; the exit happens between cycles, so every executed cycle
        sees exactly the requests the single-shot program would), (b) the
        system is fully quiescent (workload done, recode ring empty, encoder
        idle — the same observable fixed point the sweep engine's early exit
        uses), or (c) the per-chunk ``drain_bound`` budget runs out.

        One compiled program serves the whole stream: the chunk shape, the
        budget and the tunables treedef are the only compile keys.
        """
        tlen = trace.bank.shape[1]

        def cond(carry):
            st, i = carry
            starved = jnp.any((st.core_ptr >= tlen) & (stream_end > tlen))
            return (i < n_cycles) & ~starved & ~quiescent(st)

        def body(carry):
            st, i = carry
            st, _ = self.cycle_fn(st, trace, tn, stream_end)
            return st, i + 1

        st, _ = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
        return st

    def summarize(self, st: SimState) -> SimResult:
        host = jax.device_get(st)
        return result_from_host(host.mem, host.done_cycle)
