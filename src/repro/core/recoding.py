"""ReCoding unit (paper §IV-D).

A ring of pending recode requests ``(bank, row)``. Every cycle, after the
pattern builders have claimed their ports, the unit retires up to
``recode_budget`` entries whose required ports are all idle. Retiring an
entry for ``(b, i)``:

  * if the fresh value is parked in parity ``j`` (``fresh_loc == j+1``),
    reads it from ``j``'s port and writes it back to data bank ``b``;
  * re-computes every stale parity covering ``b`` at row ``i`` by reading all
    member data banks and writing the parity banks;
  * restores ``fresh_loc = 0`` and ``parity_valid = True``.

All port charges for one entry land in a single cycle (the paper does not
specify the recode micro-schedule; this charges the same port-cycles).
Entries whose region is currently uncoded are dropped — nothing to restore
(region eviction is blocked while any row is parked, see dynamic.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.codes import MAX_OPTS, MAX_SIBS
from repro.core.controller import JTables
from repro.core.state import MemParams


class RecodeOut(NamedTuple):
    port_busy: jnp.ndarray
    fresh_loc: jnp.ndarray
    parity_valid: jnp.ndarray
    parked_count: jnp.ndarray
    rc_valid: jnp.ndarray
    banks_data: jnp.ndarray
    parity_data: jnp.ndarray
    n_recoded: jnp.ndarray


def recode_step(
    p: MemParams,
    t: JTables,
    port_busy: jnp.ndarray,
    fresh_loc: jnp.ndarray,
    parity_valid: jnp.ndarray,
    parked_count: jnp.ndarray,
    rc_bank: jnp.ndarray,
    rc_row: jnp.ndarray,
    rc_valid: jnp.ndarray,
    region_slot: jnp.ndarray,
    banks_data: jnp.ndarray,
    parity_data: jnp.ndarray,
    rs_active=None,
    down=None,
) -> RecodeOut:
    """Retire up to ``recode_budget`` ring entries whose ports are all idle.

    Vectorized as a *cursor walk*: only retirements mutate shared state
    (moot removals clear just the entry's own slot), so the sequential scan
    collapses to at most ``recode_budget + 1`` trips. Each trip evaluates
    every remaining entry's work set and port needs in parallel under the
    current state, retires the first feasible one past the cursor, and
    removes the moot entries the scan passed over on the way (their view is
    unchanged within a trip — nothing between two retirements mutates
    state). Retirement order, port charges, budget accounting and the ring
    left behind are bit-identical to a sequential scan — enforced against
    the golden model's (``repro.oracle.recode_step``) by
    tests/test_conformance.py; an empty or workless ring costs one trip.

    ``down`` (fault injection, repro.faults): hard-down data banks. A
    parity recompute that would read a hard-down member is *blocked* (the
    bank's stored rows are unreadable) — on a parked retire the blocked
    parity is invalidated rather than recomputed, exactly like the
    member-parked blocking above, so a dead bank's covering parities never
    re-validate with unreadable inputs. Entries whose OWN bank is
    hard-down become moot and are dropped — they could otherwise pin the
    ring forever; the rebuild sweep re-enqueues their cells once the bank
    recovers.
    """
    rs = p.region_size
    rs_a = rs if rs_active is None else rs_active
    cap = rc_valid.shape[0]
    b = jnp.maximum(rc_bank, 0)                 # (E,)
    i = jnp.maximum(rc_row, 0)
    region = i // rs_a
    slot = region_slot[region]
    coded = slot >= 0
    pr = jnp.maximum(slot, 0) * rs + i % rs_a
    optj = t.opt_parity[b]                      # (E, K)
    optjj = jnp.maximum(optj, 0)
    opt_pport = t.par_port[optjj]
    mem = t.par_members[optjj]                  # (E, K, MAX_SIBS+1)
    memc = jnp.maximum(mem, 0)
    epos = jnp.arange(cap, dtype=jnp.int32)
    nsink = jnp.int32(p.n_ports)     # masked-index slot: never busy/claimed
    oob_j = jnp.int32(parity_valid.shape[0])
    if down is not None:
        # fault-blocking is loop-invariant: down membership doesn't change
        # within a cycle
        blocked_f = jnp.any((mem >= 0) & (mem != b[:, None, None])
                            & down[memc], axis=2)            # (E, K)
        self_down = down[b]                                  # (E,)

    def cond(carry):
        cursor, budget = carry[0], carry[1]
        return (budget > 0) & (cursor < cap)

    def body(carry):
        (cursor, budget, port_busy, fresh_loc, parity_valid, parked_count,
         rc_valid, banks_data, parity_data) = carry
        # ---- per-entry work set under the current state ------------------
        fl = fresh_loc[b, i]
        parked = fl > 0
        holder = jnp.maximum(fl - 1, 0)
        blocked = jnp.any(
            (mem >= 0) & (mem != b[:, None, None])
            & (fresh_loc[memc, i[:, None, None]] == optjj[:, :, None] + 1),
            axis=2)                                              # (E, K)
        if down is not None:
            blocked = blocked | blocked_f
        need = (optj >= 0) & coded[:, None] & (
            ~parity_valid[optjj, pr[:, None]] | parked[:, None])
        recompute = need & ~blocked
        blocked_l = need & blocked
        has_work = parked | jnp.any(recompute, axis=1)
        if down is not None:
            has_work = has_work & ~self_down
        pending = rc_valid & (epos > cursor)
        work = pending & coded & has_work
        moot = pending & ~(coded & has_work)

        # needed ports as an (E, 2 + K + K*(MAX_SIBS+1)) index matrix;
        # masked entries point at the never-busy sink gather slot
        rc_k = recompute & work[:, None]
        needed_idx = jnp.concatenate([
            jnp.where(work, b, nsink)[:, None],
            jnp.where(work & parked, t.par_port[holder], nsink)[:, None],
            jnp.where(rc_k, opt_pport, nsink),
            jnp.where(rc_k[:, :, None] & (mem >= 0), memc,
                      nsink).reshape(cap, -1),
        ], axis=1)
        pb_ext = jnp.concatenate([port_busy[: p.n_ports],
                                  jnp.zeros((1,), bool)])
        tf = work & ~jnp.any(pb_ext[needed_idx], axis=1)

        # ---- retire the first feasible entry past the cursor -------------
        any_tf = jnp.any(tf)
        e = jnp.argmax(tf).astype(jnp.int32)     # first True (0 if none)
        seg_end = jnp.where(any_tf, e, cap)
        # moot entries the scan walked past are dropped (budget still > 0
        # at their turn — cond guarantees it, and nothing in the segment
        # between two retirements mutates their inputs)
        rc_valid = rc_valid & ~(moot & (epos < seg_end))
        rc_valid = rc_valid.at[e].set(jnp.where(any_tf, False, rc_valid[e]))

        idxs = needed_idx[e]
        port_busy = port_busy.at[
            jnp.where(any_tf & (idxs < p.n_ports), idxs,
                      p.n_ports + 1)].set(True, mode="drop")
        eb, ei, epr = b[e], i[e], pr[e]
        e_parked = parked[e]
        restored = jnp.where(any_tf & e_parked,
                             parity_data[holder[e], epr], banks_data[eb, ei])
        banks_data = banks_data.at[eb, ei].set(restored)
        fresh_loc = fresh_loc.at[eb, ei].set(
            jnp.where(any_tf, 0, fresh_loc[eb, ei]))
        parked_count = parked_count.at[region[e]].add(
            -(any_tf & e_parked).astype(jnp.int32))
        do_k = recompute[e] & any_tf                       # (K,)
        inv_k = blocked_l[e] & any_tf & e_parked
        val = jnp.zeros((MAX_OPTS,), jnp.int32)
        for mm in range(MAX_SIBS + 1):
            mv = mem[e, :, mm]
            val = val ^ jnp.where(mv >= 0, banks_data[memc[e, :, mm], ei], 0)
        parity_data = parity_data.at[
            jnp.where(do_k, optjj[e], oob_j), epr].set(val, mode="drop")
        parity_valid = parity_valid.at[
            jnp.where(do_k | inv_k, optjj[e], oob_j), epr].set(
                do_k, mode="drop")

        cursor = jnp.where(any_tf, e, jnp.int32(cap))
        budget = budget - any_tf.astype(jnp.int32)
        return (cursor, budget, port_busy, fresh_loc, parity_valid,
                parked_count, rc_valid, banks_data, parity_data)

    carry = (jnp.int32(-1), jnp.int32(p.recode_budget), port_busy, fresh_loc,
             parity_valid, parked_count, rc_valid, banks_data, parity_data)
    out = jax.lax.while_loop(cond, body, carry)
    (_, budget, port_busy, fresh_loc, parity_valid, parked_count, rc_valid,
     banks_data, parity_data) = out
    return RecodeOut(port_busy, fresh_loc, parity_valid, parked_count,
                     rc_valid, banks_data, parity_data,
                     jnp.int32(p.recode_budget) - budget)
