"""ReCoding unit (paper §IV-D).

A ring of pending recode requests ``(bank, row)``. Every cycle, after the
pattern builders have claimed their ports, the unit retires up to
``recode_budget`` entries whose required ports are all idle. Retiring an
entry for ``(b, i)``:

  * if the fresh value is parked in parity ``j`` (``fresh_loc == j+1``),
    reads it from ``j``'s port and writes it back to data bank ``b``;
  * re-computes every stale parity covering ``b`` at row ``i`` by reading all
    member data banks and writing the parity banks;
  * restores ``fresh_loc = 0`` and ``parity_valid = True``.

All port charges for one entry land in a single cycle (the paper does not
specify the recode micro-schedule; this charges the same port-cycles).
Entries whose region is currently uncoded are dropped — nothing to restore
(region eviction is blocked while any row is parked, see dynamic.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.codes import MAX_OPTS, MAX_SIBS
from repro.core.controller import JTables
from repro.core.state import MemParams


class RecodeOut(NamedTuple):
    port_busy: jnp.ndarray
    fresh_loc: jnp.ndarray
    parity_valid: jnp.ndarray
    parked_count: jnp.ndarray
    rc_valid: jnp.ndarray
    banks_data: jnp.ndarray
    parity_data: jnp.ndarray
    n_recoded: jnp.ndarray


def recode_step(
    p: MemParams,
    t: JTables,
    port_busy: jnp.ndarray,
    fresh_loc: jnp.ndarray,
    parity_valid: jnp.ndarray,
    parked_count: jnp.ndarray,
    rc_bank: jnp.ndarray,
    rc_row: jnp.ndarray,
    rc_valid: jnp.ndarray,
    region_slot: jnp.ndarray,
    banks_data: jnp.ndarray,
    parity_data: jnp.ndarray,
) -> RecodeOut:
    rs = p.region_size
    nop = jnp.int32(p.n_ports)

    def body(e, carry):
        (port_busy, fresh_loc, parity_valid, parked_count, rc_valid,
         banks_data, parity_data, budget) = carry
        b = jnp.maximum(rc_bank[e], 0)
        i = jnp.maximum(rc_row[e], 0)
        active = rc_valid[e] & (budget > 0)
        region = i // rs
        slot = region_slot[region]
        coded = slot >= 0
        pr = jnp.maximum(slot, 0) * rs + i % rs
        fl = fresh_loc[b, i]
        parked = fl > 0
        holder = jnp.maximum(fl - 1, 0)

        # Which covering parities need recomputation?
        #  * stale ones, and
        #  * when (b,i) is parked: ALL covering parities — restoring changes
        #    banks_data[b,i], so even currently-valid ones go inconsistent.
        # A parity j is BLOCKED if another member's fresh value is parked in
        # j's row — recomputing would destroy that parked value; that
        # member's own recode entry restores it and then recomputes j.
        recompute = []
        blocked_l = []
        for kk in range(MAX_OPTS):
            j = t.opt_parity[b, kk]
            jj = jnp.maximum(j, 0)
            blocked = jnp.zeros((), bool)
            for mm in range(MAX_SIBS + 1):
                m = t.par_members[jj, mm]
                blocked = blocked | ((m >= 0) & (m != b) &
                                     (fresh_loc[jnp.maximum(m, 0), i] == jj + 1))
            need = (j >= 0) & coded & (~parity_valid[jj, pr] | parked)
            recompute.append(need & ~blocked)
            blocked_l.append(need & blocked)
        has_work = parked | jnp.stack(recompute).any()
        work = active & coded & has_work
        moot = active & (~coded | ~has_work)

        # required ports: b, holder parity (if parked), each recomputed
        # parity and all of its members
        needed = jnp.zeros((p.n_ports + 1,), bool)
        needed = needed.at[jnp.where(work, b, nop)].set(True)
        needed = needed.at[jnp.where(work & parked, t.par_port[holder], nop)].set(True)
        for kk in range(MAX_OPTS):
            j = t.opt_parity[b, kk]
            jj = jnp.maximum(j, 0)
            rc_ = recompute[kk] & work
            needed = needed.at[jnp.where(rc_, t.par_port[jj], nop)].set(True)
            for mm in range(MAX_SIBS + 1):
                m = t.par_members[jj, mm]
                needed = needed.at[jnp.where(rc_ & (m >= 0), jnp.maximum(m, 0), nop)].set(True)
        needed = needed.at[p.n_ports].set(False)
        feasible = work & ~jnp.any(needed & port_busy[: p.n_ports + 1])

        # ---- execute -----------------------------------------------------
        port_busy = port_busy | jnp.where(feasible, needed, False)
        # restore parked value to the data bank
        restored = jnp.where(
            feasible & parked, parity_data[holder, pr], banks_data[b, i]
        )
        banks_data = banks_data.at[b, i].set(restored)
        fresh_loc = fresh_loc.at[b, i].set(jnp.where(feasible, 0, fl))
        parked_count = parked_count.at[region].add(
            -(feasible & parked).astype(jnp.int32)
        )
        # recompute from the (now canonical) data banks; blocked parities are
        # explicitly invalidated (bank value changed under them)
        for kk in range(MAX_OPTS):
            j = t.opt_parity[b, kk]
            jj = jnp.maximum(j, 0)
            do = recompute[kk] & feasible
            inv = blocked_l[kk] & feasible & parked
            val = jnp.zeros((), jnp.int32)
            for mm in range(MAX_SIBS + 1):
                m = t.par_members[jj, mm]
                val = val ^ jnp.where(m >= 0, banks_data[jnp.maximum(m, 0), i], 0)
            parity_data = parity_data.at[jj, pr].set(
                jnp.where(do, val, parity_data[jj, pr])
            )
            parity_valid = parity_valid.at[jj, pr].set(
                jnp.where(do, True, jnp.where(inv, False, parity_valid[jj, pr]))
            )
        rc_valid = rc_valid.at[e].set(jnp.where(feasible | moot, False, rc_valid[e]))
        budget = budget - feasible.astype(jnp.int32)
        return (port_busy, fresh_loc, parity_valid, parked_count, rc_valid,
                banks_data, parity_data, budget)

    carry = (port_busy, fresh_loc, parity_valid, parked_count, rc_valid,
             banks_data, parity_data, jnp.int32(p.recode_budget))
    out = jax.lax.fori_loop(0, p.recode_cap, body, carry)
    (port_busy, fresh_loc, parity_valid, parked_count, rc_valid,
     banks_data, parity_data, budget) = out
    return RecodeOut(port_busy, fresh_loc, parity_valid, parked_count, rc_valid,
                     banks_data, parity_data, jnp.int32(p.recode_budget) - budget)
