"""The paper's primary contribution: coded emulation of multi-port memory.

Public surface:
  codes      — Scheme I/II/III + replication/uncoded baselines (§III)
  state      — MemParams/MemState pytrees (code status table refinement, §IV-A)
  controller — read/write pattern builders (§IV-B/C), work-proportional
  recoding   — ReCoding unit (§IV-D)
  dynamic    — dynamic coding unit (§IV-E)
  system     — CodedMemorySystem cycle engine + trace-driven run()

The scheduler hot path (pattern builders, write commit, core arbiter, recode
scan) is the vectorized, work-proportional implementation described in
docs/performance.md. Its ground truth is the independent pure-NumPy golden
model in ``repro.oracle``: plans and end-to-end simulation state must be
bit-identical to it — enforced by tests/test_conformance.py, see
docs/testing.md. There is deliberately no second jax implementation.
"""
from repro.core.codes import (  # noqa: F401
    MAX_OPTS,
    MAX_SIBS,
    CodeScheme,
    CodeTables,
    SCHEMES,
    get_tables,
    replication,
    scheme_i,
    scheme_ii,
    scheme_iii,
    uncoded,
)
from repro.core.controller import (  # noqa: F401
    MODE_DIRECT,
    MODE_FROM_SYM,
    MODE_OPT0,
    MODE_REDIRECT,
    MODE_UNSERVED,
    WMODE_DIRECT,
    WMODE_PARK0,
    WMODE_UNSERVED,
    JTables,
    ReadPlan,
    WritePlan,
    build_read_pattern,
    build_write_pattern,
    jtables,
)
from repro.core.state import (  # noqa: F401
    MemParams,
    MemState,
    TunableParams,
    active_geometry,
    derive_geometry,
    init_state,
    make_params,
    make_tunables,
    wide_add,
    wide_total,
    wide_zero,
)
from repro.core.system import (  # noqa: F401
    CodedMemorySystem,
    CycleOut,
    SimResult,
    SimState,
    Trace,
)
