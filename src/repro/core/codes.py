"""Code schemes from the paper (§III) as static, table-driven descriptions.

A *scheme* is a set of logical parity banks over ``n_data`` single-port data
banks. Each logical parity stores, for every covered row ``i``,
``XOR_{m in members} bank_m(i)``; ``members`` of size 1 denotes a straight
duplicate (Scheme II's second code region, and the replication baselines).

Logical parities are mapped onto *physical* parity banks (``phys``). Two
logical parities packed into the same physical bank (Scheme II stores two
``αL`` half-regions in one ``2αL`` bank) share that bank's single port.

The schemes (paper §III-B):
  * Scheme I   — 8 data banks in two groups of 4; all C(4,2)=6 pairwise
                 parities per group, each its own shallow bank (12 total).
                 Rate 2/(2+3α); locality 2.
  * Scheme II  — Scheme I's pairs plus a duplicate of every data bank,
                 packed two-halves-per-physical-bank (10 physical banks of
                 2αL rows). Rate 2/(2+5α); locality 2 (or 1 via duplicate).
  * Scheme III — 9 data banks in a 3×3 grid; 9 parities = 3 row XORs,
                 3 column XORs, 3 broken-diagonal XORs; locality 3.
                 Rate 1/(1+α). The 8-bank variant omits the final bank from
                 encoding (paper Remark 5).
  * replication(k) — the uncoded k-replication baseline of §II-A1.
  * uncoded()      — plain banked memory (no parities).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Tuple

import numpy as np

MAX_SIBS = 2  # max locality-1 across supported schemes (Scheme III = 3 banks)
MAX_OPTS = 4  # max non-direct serving options for one data bank


@dataclasses.dataclass(frozen=True)
class CodeScheme:
    """Static description of a coding scheme."""

    name: str
    n_data: int
    # members[j] = data banks XORed into logical parity j (len 1 == duplicate)
    members: Tuple[Tuple[int, ...], ...]
    # phys[j] = physical parity bank hosting logical parity j
    phys: Tuple[int, ...]

    @property
    def n_parities(self) -> int:
        return len(self.members)

    @property
    def n_phys(self) -> int:
        return 0 if not self.phys else max(self.phys) + 1

    @property
    def n_ports(self) -> int:
        """Total single-port units: data banks + physical parity banks."""
        return self.n_data + self.n_phys

    def storage_overhead(self, alpha: float) -> float:
        """Parity storage in units of one data bank (αL rows each logical)."""
        # Scheme II physical banks hold 2αL rows but that's exactly the sum of
        # their two logical halves, so logical count * alpha is exact.
        return self.n_parities * alpha

    def rate(self, alpha: float) -> float:
        """Information rate = data / (data + parity) storage (paper §III-B)."""
        return self.n_data / (self.n_data + self.storage_overhead(alpha))

    def locality(self) -> int:
        """Worst-case degraded-read locality (banks touched per degraded read)."""
        return max((len(m) for m in self.members), default=1)

    # --------------------------------------------------- erasure tolerance
    def serving_recoverable(self, lost) -> bool:
        """True when every data bank in ``lost`` stays readable under the
        controller's *degraded serving* rule: one parity option per read,
        all of whose other members are alive. (Parity banks never fail in
        the fault model — they are the redundancy itself; see
        docs/faults.md.) This is deliberately the single-decode rule the
        pattern builders implement — not full GF(2) elimination — so it is
        exactly the set the simulator can serve through; a bank-loss set
        rejected here is what ``repro.faults`` fail-fast-drops."""
        ls = frozenset(lost)
        for b in ls:
            if not 0 <= b < self.n_data:
                raise ValueError(f"lost bank {b} out of range "
                                 f"[0, {self.n_data})")
            if not any(b in ms and not (frozenset(ms) - {b}) & ls
                       for ms in self.members):
                return False
        return True

    def erasure_tolerance(self, max_losses: int = 2):
        """{k: tuple of k-subsets of data banks that remain fully readable}
        for k = 1 .. ``max_losses``, under ``serving_recoverable``. Checked
        exhaustively against an independent value-level NumPy decoder in
        tests/test_faults.py (the erasure-tolerance matrix)."""
        return {
            k: tuple(lost for lost
                     in itertools.combinations(range(self.n_data), k)
                     if self.serving_recoverable(lost))
            for k in range(1, max_losses + 1)
        }


def scheme_i(n_data: int = 8) -> CodeScheme:
    assert n_data % 4 == 0, "Scheme I groups data banks by 4"
    members = []
    for g in range(n_data // 4):
        base = 4 * g
        for a, b in itertools.combinations(range(base, base + 4), 2):
            members.append((a, b))
    phys = tuple(range(len(members)))  # one shallow physical bank per parity
    return CodeScheme("scheme_i", n_data, tuple(members), phys)


def scheme_ii(n_data: int = 8) -> CodeScheme:
    assert n_data % 4 == 0, "Scheme II groups data banks by 4"
    members = []
    phys = []
    phys_base = 0
    for g in range(n_data // 4):
        base = 4 * g
        pairs = list(itertools.combinations(range(base, base + 4), 2))  # 6
        dups = [(base + k,) for k in range(4)]  # 4
        # Pack 10 logical halves into 5 physical banks of 2αL rows each.
        # The two halves sharing a physical bank share its single port, so
        # the packing must keep each half-pair *member-disjoint* or some
        # data bank loses one of its 5 simultaneous reads (paper §III-B2)
        # to a port conflict: pair each pairwise parity with its complement
        # and the duplicates with each other. The GF(2) scheme verifier
        # (repro.analysis.schemes) proves read_degree_min == 5 holds.
        packing = [
            (pairs[0], pairs[5]),   # (0,1) + (2,3)
            (pairs[1], pairs[4]),   # (0,2) + (1,3)
            (pairs[2], pairs[3]),   # (0,3) + (1,2)
            (dups[0], dups[1]),
            (dups[2], dups[3]),
        ]
        for k, (h0, h1) in enumerate(packing):
            members.append(h0)
            phys.append(phys_base + k)
            members.append(h1)
            phys.append(phys_base + k)
        phys_base += 5
    return CodeScheme("scheme_ii", n_data, tuple(members), tuple(phys))


def scheme_iii(n_data: int = 9) -> CodeScheme:
    """3×3 grid code: rows / columns / broken diagonals; locality 3.

    With ``n_data == 8`` the 9th bank is omitted from the encoding (paper
    Remark 5): parities that referenced it simply drop that member.
    """
    assert n_data in (8, 9)
    grid = np.arange(9).reshape(3, 3)
    members = []
    for r in range(3):  # rows
        members.append(tuple(int(x) for x in grid[r]))
    for c in range(3):  # columns
        members.append(tuple(int(x) for x in grid[:, c]))
    for d in range(3):  # broken diagonals
        members.append(tuple(int(grid[k, (k + d) % 3]) for k in range(3)))
    if n_data == 8:
        members = [tuple(m for m in ms if m != 8) for ms in members]
    phys = tuple(range(len(members)))
    return CodeScheme("scheme_iii", n_data, tuple(members), phys)


def replication(n_data: int = 8, copies: int = 2) -> CodeScheme:
    """k-replication baseline (§II-A1): copies-1 duplicates per data bank."""
    members = []
    phys = []
    p = 0
    for _ in range(copies - 1):
        for b in range(n_data):
            members.append((b,))
            phys.append(p)
            p += 1
    return CodeScheme(f"replication_{copies}", n_data, tuple(members), tuple(phys))


def uncoded(n_data: int = 8) -> CodeScheme:
    return CodeScheme("uncoded", n_data, (), ())


SCHEMES = {
    "uncoded": uncoded,
    "scheme_i": scheme_i,
    "scheme_ii": scheme_ii,
    "scheme_iii": scheme_iii,
    "replication_2": lambda n_data=8: replication(n_data, 2),
    "replication_4": lambda n_data=8: replication(n_data, 4),
}


@dataclasses.dataclass(frozen=True)
class CodeTables:
    """Dense numpy lookup tables consumed by the jitted pattern builders.

    All arrays use -1 padding. ``opt_*`` enumerate the *non-direct* serving
    options of each data bank: option k of bank b reads logical parity
    ``opt_parity[b, k]`` plus sibling data banks ``opt_sibs[b, k, :]``
    (-1 padded; a duplicate option has no siblings).
    """

    scheme: CodeScheme
    n_data: int
    n_parities: int
    n_phys: int
    n_ports: int
    par_members: np.ndarray  # (n_par, MAX_SIBS+1) int32, -1 pad
    par_phys: np.ndarray     # (n_par,) int32  physical parity bank
    par_port: np.ndarray     # (n_par,) int32  global port id (n_data + phys)
    opt_parity: np.ndarray   # (n_data, MAX_OPTS) int32, -1 pad
    opt_sibs: np.ndarray     # (n_data, MAX_OPTS, MAX_SIBS) int32, -1 pad
    opt_n: np.ndarray        # (n_data,) int32 number of valid options

    @staticmethod
    def build(scheme: CodeScheme) -> "CodeTables":
        nd, npar = scheme.n_data, scheme.n_parities
        par_members = np.full((max(npar, 1), MAX_SIBS + 1), -1, np.int32)
        par_phys = np.full((max(npar, 1),), -1, np.int32)
        for j, ms in enumerate(scheme.members):
            assert len(ms) <= MAX_SIBS + 1
            par_members[j, : len(ms)] = ms
            par_phys[j] = scheme.phys[j]
        par_port = np.where(par_phys >= 0, nd + par_phys, -1).astype(np.int32)

        opt_parity = np.full((nd, MAX_OPTS), -1, np.int32)
        opt_sibs = np.full((nd, MAX_OPTS, MAX_SIBS), -1, np.int32)
        opt_n = np.zeros((nd,), np.int32)
        for b in range(nd):
            k = 0
            for j, ms in enumerate(scheme.members):
                if b in ms:
                    assert k < MAX_OPTS, f"bank {b}: more than {MAX_OPTS} options"
                    opt_parity[b, k] = j
                    sibs = [m for m in ms if m != b]
                    opt_sibs[b, k, : len(sibs)] = sibs
                    k += 1
            opt_n[b] = k
        return CodeTables(
            scheme=scheme,
            n_data=nd,
            n_parities=npar,
            n_phys=scheme.n_phys,
            n_ports=scheme.n_ports,
            par_members=par_members,
            par_phys=par_phys,
            par_port=par_port,
            opt_parity=opt_parity,
            opt_sibs=opt_sibs,
            opt_n=opt_n,
        )


def get_tables(name: str, n_data: int = 8) -> CodeTables:
    if name not in SCHEMES:
        raise KeyError(f"unknown scheme {name!r}; have {sorted(SCHEMES)}")
    if name == "scheme_iii" and n_data == 8:
        return CodeTables.build(scheme_iii(8))
    return CodeTables.build(SCHEMES[name](n_data=n_data))
