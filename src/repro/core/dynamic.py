"""Dynamic coding unit (paper §IV-E).

Rows are partitioned into ``n_regions`` regions of ``region_size`` rows;
parity banks can hold ``n_slots = ⌊α/r⌋`` coded regions (capped at
``n_regions``; at α=1 everything is coded statically and this unit is a
no-op — reproducing the paper's "zero switches at α=1").

Every ``select_period`` cycles the unit compares the hottest *uncoded*
region's (windowed) access count against the coldest *coded* region:

  * if a parity slot is free, the hottest uncoded region with any accesses is
    encoded into it;
  * otherwise, if the hottest uncoded region is strictly hotter than the
    coldest coded region (LFU), the LFU region is evicted — unless it holds
    parked writes (``parked_count > 0``), which must drain first — and the
    hot region is encoded into the freed slot.

Encoding takes ``max(1, region_size_active // encode_rows_per_cycle)``
cycles (the point's own region size, not the allocation); the slot is
unusable in flight
(the paper's "reserved staging region"). Completion writes the parity data
(XOR of member data banks over the whole region), marks ``parity_valid`` and
counts one *switch* (the Fig-18 bar metric). Counts decay by half each
period (windowed LFU).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.codes import MAX_SIBS
from repro.core.controller import JTables
from repro.core.state import MemParams, TunableParams, active_geometry

INT32_MAX = jnp.iinfo(jnp.int32).max


class DynOut(NamedTuple):
    region_slot: jnp.ndarray
    slot_region: jnp.ndarray
    access_count: jnp.ndarray
    parity_valid: jnp.ndarray
    parity_data: jnp.ndarray
    enc_region: jnp.ndarray
    enc_remaining: jnp.ndarray
    enc_slot: jnp.ndarray
    switches: jnp.ndarray


def _encode_region_data(
    p: MemParams, t: JTables, banks_data: jnp.ndarray, parity_data: jnp.ndarray,
    region: jnp.ndarray, slot: jnp.ndarray, rs_a: jnp.ndarray,
) -> jnp.ndarray:
    """Write XOR parities of ``region``'s rows into ``slot``'s parity rows.

    ``rs_a`` is the point's traced region size; slot stride stays the
    allocated ``p.region_size``, and padded lanes (offset ≥ rs_a) write 0
    into parity rows that no read/recode ever addresses."""
    rs = p.region_size
    off = jnp.arange(rs)
    rows = jnp.clip(region * rs_a + off, 0, p.n_rows - 1)  # (rs,)
    vals = jnp.zeros((p.n_parities, rs), jnp.int32)
    for mm in range(MAX_SIBS + 1):
        m = t.par_members[:, mm]  # (n_par,)
        gathered = banks_data[jnp.maximum(m, 0)][:, rows]  # (n_par, rs)
        vals = vals ^ jnp.where((m >= 0)[:, None], gathered, 0)
    vals = jnp.where((off < rs_a)[None, :], vals, 0)
    start = jnp.maximum(slot, 0) * rs
    return jax.lax.dynamic_update_slice(parity_data, vals, (0, start))


def priors_layout(p: MemParams, tn, priors):
    """(region_slot, slot_region, parity_valid) pre-mapping profiled hot
    regions into parity slots — the warm start ``init_state`` applies when a
    trace profile's region-priors are available.

    ``priors`` is a ranked int32 array of *distinct* region ids, hottest
    first, -1 padded (``repro.traces.profiler.TraceProfile.region_priors``
    emits exactly this). The leading entries fill parity slots 0.. up to the
    point's slot budget; ids outside the active region range and -1 padding
    are skipped without shifting later entries into their slots. Parity rows
    of the mapped slots are marked valid: at init every data bank is zero,
    so the all-zero parity rows already equal the XOR of their members —
    the same consistency argument the full-coverage identity map relies on.

    From here the unit proceeds exactly as from a cold start: the seeded
    regions are ordinary coded regions (evictable by LFU once colder than
    the hottest uncoded region), so a stale prior costs at most one
    re-selection period — the cold start pays that period anyway.
    """
    rs = p.region_size
    if tn is None:
        rs_a, nr_a = p.region_size, p.n_regions
        budget = jnp.int32(p.n_active)
    else:
        rs_a, nr_a = active_geometry(p, tn)
        budget = jnp.minimum(tn.n_slots_active, p.n_active)
    pr = jnp.asarray(priors, jnp.int32).reshape(-1)
    k = pr.shape[0]
    if k == 0:
        return (jnp.full((p.n_regions,), -1, jnp.int32),
                jnp.full((p.n_slots,), -1, jnp.int32),
                jnp.zeros((p.n_parities, p.n_slots * rs), bool))
    sid = jnp.arange(p.n_slots)
    cand = jnp.where(sid < k, pr[jnp.minimum(sid, k - 1)], -1)
    ok = (sid < budget) & (cand >= 0) & (cand < nr_a)
    slot_region = jnp.where(ok, cand, -1).astype(jnp.int32)
    region_slot = jnp.full((p.n_regions,), -1, jnp.int32).at[
        jnp.where(ok, cand, p.n_regions)].set(
        sid.astype(jnp.int32), mode="drop")
    row = jnp.arange(p.n_slots * rs)
    # parity rows are *stored* at the allocated stride (slot * rs_alloc +
    # i % rs_active); this walks that storage layout, not a region id
    active = ok[row // rs] & (row % rs < rs_a)  # analysis: static-geometry
    parity_valid = jnp.broadcast_to(active, (p.n_parities, p.n_slots * rs))
    return region_slot, slot_region, parity_valid


def dynamic_step(
    p: MemParams,
    t: JTables,
    tn: TunableParams,
    cycle: jnp.ndarray,
    region_slot: jnp.ndarray,
    slot_region: jnp.ndarray,
    access_count: jnp.ndarray,
    parked_count: jnp.ndarray,
    parity_valid: jnp.ndarray,
    parity_data: jnp.ndarray,
    banks_data: jnp.ndarray,
    enc_region: jnp.ndarray,
    enc_remaining: jnp.ndarray,
    enc_slot: jnp.ndarray,
    switches: jnp.ndarray,
    quiesce=None,
) -> DynOut:
    if p.n_active >= p.n_regions:  # static full coverage: unit disabled
        return DynOut(region_slot, slot_region, access_count, parity_valid,
                      parity_data, enc_region, enc_remaining, enc_slot, switches)
    rs = p.region_size
    rs_a, nr_a = active_geometry(p, tn)

    # ---- encode in flight ---------------------------------------------------
    in_flight = enc_region >= 0
    enc_remaining = jnp.where(in_flight, enc_remaining - 1, 0)
    complete = in_flight & (enc_remaining <= 0)
    # completion: install mapping, write parity data, validate rows
    parity_data = jnp.where(
        complete,
        _encode_region_data(p, t, banks_data, parity_data, enc_region,
                            enc_slot, rs_a),
        parity_data,
    )
    off = jnp.arange(rs)
    slot_rows = jnp.maximum(enc_slot, 0) * rs + off
    pv_rows = jnp.zeros_like(parity_valid).at[:, slot_rows].set((off < rs_a))
    parity_valid = jnp.where(complete, parity_valid | pv_rows, parity_valid)
    region_slot = region_slot.at[jnp.maximum(enc_region, 0)].set(
        jnp.where(complete, enc_slot, region_slot[jnp.maximum(enc_region, 0)])
    )
    slot_region = slot_region.at[jnp.maximum(enc_slot, 0)].set(
        jnp.where(complete, enc_region, slot_region[jnp.maximum(enc_slot, 0)])
    )
    switches = switches + complete.astype(jnp.int32)
    enc_region = jnp.where(complete, -1, enc_region)
    enc_slot = jnp.where(complete, -1, enc_slot)

    # ---- periodic selection --------------------------------------------------
    # ``quiesce``: the workload already drained — no traffic left to adapt
    # to, so no new encodes start (in-flight ones still complete above).
    period = (cycle % tn.select_period == 0) & (cycle > 0)
    select = period & (enc_region < 0)
    if quiesce is not None:
        select = select & ~quiesce
    coded = region_slot >= 0
    # hottest uncoded *active* region (padded regions past the point's own
    # n_regions never exist: their counts stay 0 and they are masked here)
    region_active = jnp.arange(p.n_regions) < nr_a
    cand_counts = jnp.where(coded | ~region_active, -1, access_count)
    cand = jnp.argmax(cand_counts).astype(jnp.int32)
    cand_count = cand_counts[cand]
    # coldest coded, evictable (no parked rows) region
    evict_counts = jnp.where(coded & (parked_count == 0), access_count, INT32_MAX)
    victim = jnp.argmin(evict_counts).astype(jnp.int32)
    victim_count = evict_counts[victim]
    # slots at or past the point's traced budget are never offered as free:
    # a sweep can allocate parity state once at the grid's max ⌊α/r⌋ and let
    # each point use only its own budget (repro.sweep batches α this way).
    # p.n_active caps it statically — 0 for an α < r (uncoded) allocation.
    budget = jnp.minimum(tn.n_slots_active, p.n_active)
    free_slot_mask = (slot_region < 0) & (jnp.arange(p.n_slots) < budget)
    has_free = jnp.any(free_slot_mask)
    free_slot = jnp.argmax(free_slot_mask).astype(jnp.int32)

    start_free = select & has_free & (cand_count > 0)
    start_evict = select & ~has_free & (cand_count > victim_count) & (victim_count < INT32_MAX)

    # eviction: clear victim's slot + validity (whole allocated stride —
    # padded rows are invalid anyway)
    vslot = jnp.maximum(region_slot[victim], 0)
    vrows = vslot * rs + jnp.arange(rs)
    pv_clear = jnp.ones_like(parity_valid).at[:, vrows].set(False)
    parity_valid = jnp.where(start_evict, parity_valid & pv_clear, parity_valid)
    region_slot = region_slot.at[victim].set(
        jnp.where(start_evict, -1, region_slot[victim])
    )
    slot_region = slot_region.at[vslot].set(
        jnp.where(start_evict, -1, slot_region[vslot])
    )

    start = start_free | start_evict
    tgt_slot = jnp.where(start_evict, vslot, free_slot)
    enc_region = jnp.where(start, cand, enc_region)
    enc_slot = jnp.where(start, tgt_slot, enc_slot)
    # encode latency follows the point's own region size, not the allocation
    enc_cycles = jnp.maximum(1, rs_a // p.encode_rows_per_cycle).astype(jnp.int32)
    enc_remaining = jnp.where(start, enc_cycles, enc_remaining)

    # windowed counts decay each period
    access_count = jnp.where(period, access_count // 2, access_count)
    return DynOut(region_slot, slot_region, access_count, parity_valid,
                  parity_data, enc_region, enc_remaining, enc_slot, switches)
