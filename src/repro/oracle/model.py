"""Pure-NumPy golden model of the coded memory system.

This is the **oracle** the production (vectorized, jax) scheduler is checked
against: a deliberately dumb, one-request-at-a-time re-derivation of the
paper's cycle semantics (§IV, Algorithms/Figs 9–14). Every structure is a
plain python loop over small numpy arrays; there are **no jax imports and no
code shared with** ``repro.core`` — the point of the oracle is to catch a
misconception both jax implementations could share (the differential-testing
pattern used to validate algorithmic multi-port designs against RTL golden
models).

One ``cycle()`` call = one memory clock cycle:

1. **Core arbiter** — cores in index order push their pending request into
   the destination bank's read/write queue (first free slot); a full queue
   stalls the core and counts a stall cycle.
2. **Write-drain hysteresis** — serve writes when the fullest write queue
   crosses ``wq_hi`` (staying in write mode while above ``wq_lo``), or when
   only writes are pending; otherwise serve reads.
3. **Pattern builder** — candidates are visited oldest-first (stable on
   queue position); each takes the cheapest feasible action, where cost
   counts the single-port banks claimed and parity-based service is
   preferred over a direct read on cost ties:
   reads — reuse a row already materialized this cycle (free, chained
   decode) / degraded read via a parity option (parity port + missing
   siblings) / redirect to the parked fresh copy / direct read;
   writes — direct (preferred when the bank port is free) / park the raw
   value into a covering parity row.
4. **Datapath** — served reads return the direct / XOR-decoded / redirected
   value; served writes commit oldest-first (last write wins), parking into
   parity rows when chosen. ``golden`` records memory order.
5. **ReCoding unit** — scans the pending ring in order and retires up to
   ``recode_budget`` entries whose ports are all idle: restore a parked
   value to its data bank, recompute the stale covering parities from the
   data banks (skipping parities blocked by *another* member's parked
   value, which are invalidated instead when the restore changed the bank).
6. **Dynamic coding unit** — in-flight encode countdown and completion;
   every ``select_period`` cycles encode the hottest uncoded region into a
   free slot, or evict the coldest coded region (LFU, blocked while it
   holds parked writes) when strictly colder; windowed counts halve each
   period. Quiesces after the workload drains.

The model runs at a *point's own* geometry inside an optionally padded
allocation (``region_size/n_regions/n_slots`` vs the ``*_active`` values),
mirroring the sweep engine's masked α×r batching, so padded grid points can
be conformance-checked too.

``tests/test_conformance.py`` holds the differential suite; see
``docs/testing.md`` for the contract.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple, Union

import numpy as np

from repro.oracle.codes import MAX_OPTS, OracleScheme, oracle_scheme

INT32_MAX = np.iinfo(np.int32).max

# read action numbering (shared contract with the production scheduler's
# ReadPlan.mode; asserted equal by the conformance suite)
MODE_UNSERVED = -1
MODE_FROM_SYM = 0
MODE_DIRECT = 1
MODE_OPT0 = 2
MODE_REDIRECT = MODE_OPT0 + MAX_OPTS
# write action numbering
WMODE_UNSERVED = -1
WMODE_DIRECT = 0
WMODE_PARK0 = 1


@dataclasses.dataclass(frozen=True)
class OracleParams:
    """Static + per-point knobs of the golden model (plain python ints)."""

    n_data: int
    n_rows: int
    region_size: int        # allocated parity-slot stride
    n_regions: int          # allocated
    n_slots: int            # allocated (>= 1 storage floor)
    n_active: int           # true parity-slot budget (0 when alpha < r)
    queue_depth: int = 10
    recode_cap: int = 64
    recode_budget: int = 4
    coalesce: bool = True
    encode_rows_per_cycle: int = 64
    # the point's own geometry inside the allocation
    region_size_active: int = 0     # 0 -> the allocation is the geometry
    n_regions_active: int = 0
    n_slots_active: int = INT32_MAX
    # tunables (the write-drain hysteresis + dynamic selection period)
    select_period: int = 512
    wq_hi: int = 8
    wq_lo: int = 2
    # mirror of MemParams.telemetry: carry independently-derived metric
    # planes (OracleTelemetry) so the conformance suite can assert the
    # production planes against a second implementation
    telemetry: bool = False
    # mirror of MemParams.faults: carry an independently-derived fault
    # schedule/progress leaf (OracleFaultState) re-deriving every rule of
    # repro.faults sequentially — erasure-degraded serving, fail-fast
    # drops, port stutters and the online rebuild sweep
    faults: bool = False

    @property
    def rs_active(self) -> int:
        return self.region_size_active or self.region_size

    @property
    def nr_active(self) -> int:
        return self.n_regions_active or self.n_regions

    @property
    def slot_budget(self) -> int:
        return min(self.n_slots_active, self.n_active)

    @staticmethod
    def derive(n_rows: int, alpha: float, r: float, *,
               region_size_alloc: Optional[int] = None,
               n_regions_alloc: Optional[int] = None,
               n_slots_alloc: Optional[int] = None,
               n_data: int = 8, **kw) -> "OracleParams":
        """Geometry implied by an (n_rows, α, r) point (paper §IV-E):
        regions of ``round(L·r)`` rows, a parity budget of ``⌊α/r⌋`` slots
        (0 when α < r — the point is uncoded), optionally inside a padded
        group allocation whose active geometry stays the derived one."""
        rs = max(1, int(round(n_rows * r)))
        nr = -(-n_rows // rs)
        ns = max(min(int(np.floor(alpha / r + 1e-9)), nr), 0)
        alloc_rs = region_size_alloc if region_size_alloc is not None else rs
        alloc_nr = n_regions_alloc if n_regions_alloc is not None else nr
        alloc_ns = n_slots_alloc if n_slots_alloc is not None else ns
        return OracleParams(
            n_data=n_data, n_rows=n_rows,
            region_size=alloc_rs, n_regions=alloc_nr,
            n_slots=max(alloc_ns, 1), n_active=alloc_ns,
            region_size_active=rs, n_regions_active=nr, n_slots_active=ns,
            **kw)


class OracleReadPlan(NamedTuple):
    served: np.ndarray
    mode: np.ndarray
    port_busy: np.ndarray
    n_served: int
    n_degraded: int


class OracleWritePlan(NamedTuple):
    served: np.ndarray
    mode: np.ndarray
    port_busy: np.ndarray
    fresh_loc: np.ndarray
    parity_valid: np.ndarray
    parked_count: np.ndarray
    rc_bank: np.ndarray
    rc_row: np.ndarray
    rc_valid: np.ndarray
    n_served: int
    n_parked: int
    n_rc_dropped: int


class OracleRecodeOut(NamedTuple):
    port_busy: np.ndarray
    fresh_loc: np.ndarray
    parity_valid: np.ndarray
    parked_count: np.ndarray
    rc_valid: np.ndarray
    banks_data: np.ndarray
    parity_data: np.ndarray
    n_recoded: int


class OracleResult(NamedTuple):
    """Field-for-field the production ``SimResult`` (same tuple layout, so
    ``strip_windows(sim_result) == oracle_result`` compares directly)."""

    cycles: int
    completed: bool
    served_reads: int
    served_writes: int
    degraded_reads: int
    parked_writes: int
    switches: int
    recode_backlog: int
    stall_cycles: int
    avg_read_latency: float
    avg_write_latency: float
    rc_dropped: int = 0
    window_read_latency: tuple = ()
    window_write_latency: tuple = ()
    # fault-injection availability stats (mirrors SimResult; 0 = faults off)
    unserved_reads: int = 0
    lost_writes: int = 0
    fault_degraded_reads: int = 0
    dead_bank_cycles: int = 0


# telemetry histogram geometry — independently fixed here (NOT imported from
# repro.obs; the oracle shares no code with the production path)
ORACLE_HIST_BINS = 16


def _lat_bin(lat: int) -> int:
    """log2 latency bin: 0→0, 1→1, [2,3]→2, [4,7]→3, … — ``bit_length`` is
    an independent derivation of the production threshold-count binning."""
    return min(int(lat).bit_length(), ORACLE_HIST_BINS - 1)


@dataclasses.dataclass
class OracleTelemetry:
    """Golden-model metric planes (fields named like the production
    ``repro.obs.planes.Telemetry`` leaves, so conformance compares by
    name). All plain int64 numpy — magnitudes are trace-bounded."""

    stall_cause: np.ndarray       # (n_data, 2) {read,write}-queue-full
    wait_cause: np.ndarray        # (n_data, 3) {read,write,recode} waits
    read_mode_core: np.ndarray    # (n_cores, 5) {direct,from_sym,parity,
                                  #               redirect,degraded_fault}
    write_mode_core: np.ndarray   # (n_cores, 2) {direct, parked}
    rq_hwm: np.ndarray            # (n_data,) post-arbiter high-water marks
    wq_hwm: np.ndarray
    lat_hist_read: np.ndarray     # (ORACLE_HIST_BINS,)
    lat_hist_write: np.ndarray
    recode_retired: int
    rq_core: np.ndarray           # (n_data, D) issuing-core provenance
    wq_core: np.ndarray
    dead_cycles: np.ndarray       # (n_data,) per-bank cycles spent down


def _init_oracle_telemetry(n_data: int, n_cores: int,
                           queue_depth: int) -> OracleTelemetry:
    z = lambda *s: np.zeros(s, np.int64)                      # noqa: E731
    return OracleTelemetry(
        stall_cause=z(n_data, 2), wait_cause=z(n_data, 3),
        read_mode_core=z(n_cores, 5), write_mode_core=z(n_cores, 2),
        rq_hwm=z(n_data), wq_hwm=z(n_data),
        lat_hist_read=z(ORACLE_HIST_BINS), lat_hist_write=z(ORACLE_HIST_BINS),
        recode_retired=0,
        rq_core=np.full((n_data, queue_depth), -1, np.int64),
        wq_core=np.full((n_data, queue_depth), -1, np.int64),
        dead_cycles=z(n_data),
    )


@dataclasses.dataclass
class OracleFaultState:
    """Golden-model fault schedule + progress (fields named like the
    production ``repro.faults.plan.FaultState`` leaf, so conformance
    compares by name). The schedule half is constant over a run; the rest
    mutates each cycle. Semantics are re-derived sequentially in
    ``OracleMemorySystem.cycle`` — only the *schedule arrays* come from the
    host-side plan (input data, like the trace), never the rules."""

    fail_at: np.ndarray          # (n_data,) int32; INT32_MAX = never
    recover_at: np.ndarray       # (n_data,) int32; INT32_MAX = never
    stutter_period: np.ndarray   # (n_ports,) int32; 0 = no stutter
    stutter_phase: np.ndarray    # (n_ports,) int32
    rebuilt: np.ndarray          # (n_data,) bool — rebuild-complete latch
    rebuild_ptr: int             # flat (bank*n_rows+row) sweep cursor
    unserved_reads: int          # reads failed fast (no serving option)
    lost_writes: int             # writes dropped with no parity coverage
    fault_degraded: int          # reads degraded *because* bank down
    dead_cycles: np.ndarray      # (n_data,) cycles spent down


def _init_oracle_fault(n_data: int, n_ports: int,
                       fault_plan=None) -> OracleFaultState:
    """No-fault schedule, or the one in ``fault_plan`` (duck-typed: any
    object with a numpy ``schedule_arrays()`` — the production
    ``repro.faults.FaultPlan``; the oracle imports nothing from it)."""
    if fault_plan is not None:
        fail, rec, per, ph = (np.array(a, np.int32)
                              for a in fault_plan.schedule_arrays())
    else:
        fail = np.full(n_data, INT32_MAX, np.int32)
        rec = np.full(n_data, INT32_MAX, np.int32)
        per = np.zeros(n_ports, np.int32)
        ph = np.zeros(n_ports, np.int32)
    return OracleFaultState(
        fail_at=fail, recover_at=rec, stutter_period=per, stutter_phase=ph,
        rebuilt=np.zeros(n_data, bool), rebuild_ptr=0,
        unserved_reads=0, lost_writes=0, fault_degraded=0,
        dead_cycles=np.zeros(n_data, np.int64),
    )


@dataclasses.dataclass
class OracleState:
    """Mutable model state (numpy arrays named like the production
    ``MemState``/``SimState`` leaves, so conformance compares by name)."""

    fresh_loc: np.ndarray
    parity_valid: np.ndarray
    region_slot: np.ndarray
    slot_region: np.ndarray
    access_count: np.ndarray
    parked_count: np.ndarray
    enc_region: int
    enc_remaining: int
    enc_slot: int
    switches: int
    rc_bank: np.ndarray
    rc_row: np.ndarray
    rc_valid: np.ndarray
    rq_row: np.ndarray
    rq_age: np.ndarray
    rq_valid: np.ndarray
    wq_row: np.ndarray
    wq_age: np.ndarray
    wq_valid: np.ndarray
    wq_data: np.ndarray
    write_mode: bool
    cycle: int
    banks_data: np.ndarray
    parity_data: np.ndarray
    golden: np.ndarray
    served_reads: int
    served_writes: int
    degraded_reads: int
    parked_writes: int
    read_latency_sum: int
    write_latency_sum: int
    stall_cycles: int
    rc_dropped: int
    core_ptr: np.ndarray
    done_cycle: int
    tele: Optional[OracleTelemetry] = None
    fault: Optional[OracleFaultState] = None


class OracleCycleOut(NamedTuple):
    """Per-cycle read-datapath view (mirrors the production ``CycleOut``)."""

    r_served: np.ndarray
    r_bank: np.ndarray
    r_row: np.ndarray
    r_value: np.ndarray
    n_served: int


def _stable_age_order(age, valid) -> np.ndarray:
    """Oldest-first candidate order, stable on queue position; invalid
    entries sort to the back (they are no-ops in every walk)."""
    return np.argsort(np.where(valid, age, INT32_MAX), kind="stable")


def build_read_plan(sys: "OracleMemorySystem", cand_bank, cand_row, cand_age,
                    cand_valid, port_busy, fresh_loc, parity_valid,
                    region_slot, rs_active: Optional[int] = None
                    ) -> OracleReadPlan:
    """Greedy oldest-first read matcher (paper Fig 11 / §IV-B)."""
    p, sch = sys.p, sys.scheme
    rs = p.region_size
    rs_a = rs if rs_active is None else int(rs_active)
    n = len(cand_bank)
    port_busy = np.array(port_busy, bool)
    served = np.zeros(n, bool)
    mode = np.full(n, MODE_UNSERVED, np.int32)
    syms = set()                        # (bank, row) materialized this cycle
    for c in _stable_age_order(cand_age, cand_valid):
        if not cand_valid[c]:
            continue
        b = max(int(cand_bank[c]), 0)
        i = max(int(cand_row[c]), 0)
        fl = int(fresh_loc[b, i])
        slot = int(region_slot[i // rs_a])
        pr = max(slot, 0) * rs + i % rs_a
        # (score, action, payload) — ties resolve to the lowest action id,
        # which orders parity options before the redirect exactly as the
        # production builder's action stack does
        acts: List[Tuple[int, int, object]] = []
        if fl == 0:                                     # fresh value in bank
            if p.coalesce and (b, i) in syms:
                acts.append((0, MODE_FROM_SYM, None))
            if not port_busy[b]:
                acts.append((3, MODE_DIRECT, None))
            for k, (j, sibs) in enumerate(sys.options[b]):
                if slot < 0 or not parity_valid[j, pr]:
                    continue
                if port_busy[sch.par_port(j)]:
                    continue
                need = [s for s in sibs if (s, i) not in syms]
                if any(port_busy[s] for s in need):
                    continue
                acts.append((2 * (1 + len(need)), MODE_OPT0 + k, (j, need)))
        else:                                           # parked in parity fl-1
            hp = sch.par_port(fl - 1)
            if not port_busy[hp]:
                acts.append((2, MODE_REDIRECT, hp))
        if not acts:
            continue
        _, act, payload = min(acts, key=lambda a: (a[0], a[1]))
        served[c] = True
        mode[c] = act
        if act == MODE_DIRECT:
            port_busy[b] = True
            syms.add((b, i))
        elif act == MODE_REDIRECT:
            port_busy[payload] = True
        elif act >= MODE_OPT0:
            j, need = payload
            port_busy[sch.par_port(j)] = True
            for s in need:
                port_busy[s] = True
                syms.add((s, i))
            syms.add((b, i))
        # MODE_FROM_SYM is free: no ports, row already materialized
    port_busy[sch.n_ports] = True       # the builders' no-op sink slot
    n_served = int(served.sum())
    n_degraded = int((served & ((mode == MODE_FROM_SYM)
                                | ((mode >= MODE_OPT0)
                                   & (mode < MODE_REDIRECT)))).sum())
    return OracleReadPlan(served, mode, port_busy, n_served, n_degraded)


def _rc_push(rc_bank, rc_row, rc_valid, b: int, i: int) -> bool:
    """Queue (b, i) for recoding unless already pending; False = ring full."""
    if bool((rc_valid & (rc_bank == b) & (rc_row == i)).any()):
        return True
    free = np.flatnonzero(~rc_valid)
    if free.size == 0:
        return False
    k = int(free[0])
    rc_bank[k] = b
    rc_row[k] = i
    rc_valid[k] = True
    return True


def build_write_plan(sys: "OracleMemorySystem", cand_bank, cand_row, cand_age,
                     cand_valid, port_busy, fresh_loc, parity_valid,
                     region_slot, parked_count, rc_bank, rc_row, rc_valid,
                     rs_active: Optional[int] = None,
                     down=None) -> OracleWritePlan:
    """Greedy oldest-first write matcher (paper Fig 14 / §IV-C).

    ``down`` (fault injection): currently-down data banks. A candidate is
    *sticky* when its own bank is down or a covering parity has a down
    member — its park stays parked (no recode request) until the rebuild
    sweep drains it, and scoring prefers (a) normal parks, (b) parks into
    all-alive parities, (c) parks into down-covering parities, (d) a direct
    write, strictly last for a sticky-but-alive bank. Sticky parks waive
    the recode-space requirement. Mirrors the production builder's
    degraded-write mode (``repro.core.controller``)."""
    p, sch = sys.p, sys.scheme
    rs = p.region_size
    rs_a = rs if rs_active is None else int(rs_active)
    n = len(cand_bank)
    port_busy = np.array(port_busy, bool)
    fresh_loc = np.array(fresh_loc, np.int32)
    parity_valid = np.array(parity_valid, bool)
    parked_count = np.array(parked_count, np.int32)
    rc_bank = np.array(rc_bank, np.int32)
    rc_row = np.array(rc_row, np.int32)
    rc_valid = np.array(rc_valid, bool)
    served = np.zeros(n, bool)
    mode = np.full(n, WMODE_UNSERVED, np.int32)
    dropped = 0
    for c in _stable_age_order(cand_age, cand_valid):
        if not cand_valid[c]:
            continue
        b = max(int(cand_bank[c]), 0)
        i = max(int(cand_row[c]), 0)
        region = i // rs_a
        slot = int(region_slot[region])
        coded = slot >= 0
        pr = max(slot, 0) * rs + i % rs_a
        fl = int(fresh_loc[b, i])
        rc_space = bool((~rc_valid).any())
        sticky = False
        if down is not None:
            sticky = bool(down[b]) or (coded and any(
                any(down[m] for m in sch.members[j] if m != b)
                for j, _s in sys.options[b]))
        acts: List[Tuple[int, int, int]] = []
        if not port_busy[b]:
            acts.append((2 + 2 * MAX_OPTS + 2 if sticky else 1,
                         WMODE_DIRECT, -1))
        for k, (j, _sibs) in enumerate(sys.options[b]):
            # park the raw value into parity j's row: region coded, parity
            # port free, the row slot not held by ANOTHER member's parked
            # value, and recode space so it can always drain back (sticky
            # parks don't enqueue, so they waive the space requirement)
            if not coded or port_busy[sch.par_port(j)]:
                continue
            if not (rc_space or sticky):
                continue
            if any(fresh_loc[m, i] == j + 1
                   for m in sch.members[j] if m != b):
                continue
            shift = 0
            if down is not None and any(down[m] for m in sch.members[j]
                                        if m != b):
                shift = MAX_OPTS + 2
            acts.append((2 + k + shift, WMODE_PARK0 + k, j))
        if not acts:
            continue
        _, act, j_sel = min(acts, key=lambda a: (a[0], a[1]))
        served[c] = True
        mode[c] = act
        was_parked = fl > 0
        if act == WMODE_DIRECT:
            port_busy[b] = True
            fresh_loc[b, i] = 0
            if was_parked:
                parked_count[region] -= 1
            if coded:                  # every covering parity goes stale
                for j, _ in sys.options[b]:
                    parity_valid[j, pr] = False
            need_rc = coded and len(sys.options[b]) > 0
        else:
            port_busy[sch.par_port(j_sel)] = True
            fresh_loc[b, i] = j_sel + 1
            if not was_parked:
                parked_count[region] += 1
            parity_valid[j_sel, pr] = False
            # a sticky park stays parked: the rebuild sweep enqueues it
            # once its down parity-group member is recovering
            need_rc = not sticky
        if need_rc and not _rc_push(rc_bank, rc_row, rc_valid, b, i):
            dropped += 1
    port_busy[sch.n_ports] = True
    n_served = int(served.sum())
    n_parked = int((served & (mode >= WMODE_PARK0)).sum())
    return OracleWritePlan(served, mode, port_busy, fresh_loc, parity_valid,
                           parked_count, rc_bank, rc_row, rc_valid, n_served,
                           n_parked, dropped)


def recode_step(sys: "OracleMemorySystem", port_busy, fresh_loc, parity_valid,
                parked_count, rc_bank, rc_row, rc_valid, region_slot,
                banks_data, parity_data,
                rs_active: Optional[int] = None,
                down=None) -> OracleRecodeOut:
    """Sequential ring scan retiring ≤ ``recode_budget`` entries (§IV-D).

    ``down`` (fault injection): *hard-down* data banks. A recompute that
    would read a hard-down member is blocked (invalidated instead of
    recomputed on a parked retire); entries whose own bank is hard-down
    are moot and dropped — the rebuild sweep re-enqueues them on recovery.
    Mirrors ``repro.core.recoding``."""
    p, sch = sys.p, sys.scheme
    rs = p.region_size
    rs_a = rs if rs_active is None else int(rs_active)
    port_busy = np.array(port_busy, bool)
    fresh_loc = np.array(fresh_loc, np.int32)
    parity_valid = np.array(parity_valid, bool)
    parked_count = np.array(parked_count, np.int32)
    rc_valid = np.array(rc_valid, bool)
    banks_data = np.array(banks_data, np.int32)
    parity_data = np.array(parity_data, np.int32)
    budget = p.recode_budget
    for e in range(p.recode_cap):
        if budget <= 0:
            break
        if not rc_valid[e]:
            continue
        b = max(int(rc_bank[e]), 0)
        i = max(int(rc_row[e]), 0)
        region = i // rs_a
        slot = int(region_slot[region])
        coded = slot >= 0
        pr = max(slot, 0) * rs + i % rs_a
        fl = int(fresh_loc[b, i])
        parked = fl > 0
        # stale covering parities need recomputation — and when (b, i) is
        # parked, ALL covering parities do (restoring changes the bank row
        # under them). A parity holding ANOTHER member's parked value is
        # blocked: recomputing would destroy that value; that member's own
        # entry restores it first. Blocked parities are invalidated instead
        # when this restore changed the bank value.
        recompute: List[int] = []
        blocked_l: List[int] = []
        if coded:
            for j, _sibs in sys.options[b]:
                blocked = any(fresh_loc[m, i] == j + 1
                              for m in sch.members[j] if m != b)
                if down is not None:
                    blocked = blocked or any(down[m] for m in sch.members[j]
                                             if m != b)
                if not parity_valid[j, pr] or parked:
                    (blocked_l if blocked else recompute).append(j)
        self_down = down is not None and bool(down[b])
        if not coded or not (parked or recompute) or self_down:
            rc_valid[e] = False                       # moot: nothing to do
            continue
        needed = {b}
        if parked:
            needed.add(sch.par_port(fl - 1))
        for j in recompute:
            needed.add(sch.par_port(j))
            needed.update(sch.members[j])
        if any(port_busy[x] for x in needed):
            continue                                  # stays pending
        for x in needed:
            port_busy[x] = True
        if parked:
            banks_data[b, i] = parity_data[fl - 1, pr]
            parked_count[region] -= 1
        fresh_loc[b, i] = 0
        for j in recompute:
            val = 0
            for m in sch.members[j]:
                val ^= int(banks_data[m, i])
            parity_data[j, pr] = np.int32(val)
            parity_valid[j, pr] = True
        if parked:
            for j in blocked_l:
                parity_valid[j, pr] = False
        rc_valid[e] = False
        budget -= 1
    return OracleRecodeOut(port_busy, fresh_loc, parity_valid, parked_count,
                           rc_valid, banks_data, parity_data,
                           p.recode_budget - budget)


class OracleMemorySystem:
    """The golden model: an independent, sequential coded memory system."""

    def __init__(self, scheme: Union[str, OracleScheme], params: OracleParams,
                 n_cores: int = 8):
        self.scheme = (oracle_scheme(scheme, params.n_data)
                       if isinstance(scheme, str) else scheme)
        # hysteresis sanity: thresholds clamp into the queue and must not
        # cross (lo > hi would flap write mode every cycle); chained-decode
        # reuse is meaningless without parities
        hi = min(params.wq_hi, params.queue_depth - 1)
        params = dataclasses.replace(
            params, wq_hi=hi, wq_lo=min(params.wq_lo, hi),
            select_period=max(params.select_period, 1),
            coalesce=params.coalesce and self.scheme.n_parities > 0)
        self.p = params
        self.n_cores = n_cores
        # per-bank serving options, resolved once
        self.options = [self.scheme.options(b) for b in range(params.n_data)]

    # ------------------------------------------------------------------ init
    def init_state(self, region_priors=None, fault_plan=None) -> OracleState:
        p = self.p
        if fault_plan is not None and not p.faults:
            raise ValueError("fault_plan given but OracleParams.faults off")
        n_par = max(self.scheme.n_parities, 1)
        n_slot_rows = p.n_slots * p.region_size
        rs_a, nr_a = p.rs_active, p.nr_active
        if p.n_active >= p.n_regions:
            # static full coverage: identity map over the point's own
            # regions; active parity rows valid (all banks zero at init)
            rid = np.arange(p.n_regions, dtype=np.int32)
            region_slot = np.where(rid < nr_a, rid, -1).astype(np.int32)
            sid = np.arange(p.n_slots, dtype=np.int32)
            slot_region = np.where(sid < nr_a, sid, -1).astype(np.int32)
            row = np.arange(n_slot_rows)
            active = (row // p.region_size < nr_a) & (row % p.region_size < rs_a)
            parity_valid = np.broadcast_to(active, (n_par, n_slot_rows)).copy()
        elif region_priors is not None:
            region_slot, slot_region, parity_valid = self._priors_layout(
                region_priors, n_par, n_slot_rows)
        else:
            region_slot = np.full(p.n_regions, -1, np.int32)
            slot_region = np.full(p.n_slots, -1, np.int32)
            parity_valid = np.zeros((n_par, n_slot_rows), bool)
        return OracleState(
            fresh_loc=np.zeros((p.n_data, p.n_rows), np.int32),
            parity_valid=parity_valid,
            region_slot=region_slot,
            slot_region=slot_region,
            access_count=np.zeros(p.n_regions, np.int32),
            parked_count=np.zeros(p.n_regions, np.int32),
            enc_region=-1, enc_remaining=0, enc_slot=-1, switches=0,
            rc_bank=np.full(p.recode_cap, -1, np.int32),
            rc_row=np.full(p.recode_cap, -1, np.int32),
            rc_valid=np.zeros(p.recode_cap, bool),
            rq_row=np.full((p.n_data, p.queue_depth), -1, np.int32),
            rq_age=np.full((p.n_data, p.queue_depth), INT32_MAX, np.int32),
            rq_valid=np.zeros((p.n_data, p.queue_depth), bool),
            wq_row=np.full((p.n_data, p.queue_depth), -1, np.int32),
            wq_age=np.full((p.n_data, p.queue_depth), INT32_MAX, np.int32),
            wq_valid=np.zeros((p.n_data, p.queue_depth), bool),
            wq_data=np.zeros((p.n_data, p.queue_depth), np.int32),
            write_mode=False, cycle=0,
            banks_data=np.zeros((p.n_data, p.n_rows), np.int32),
            parity_data=np.zeros((n_par, n_slot_rows), np.int32),
            golden=np.zeros((p.n_data, p.n_rows), np.int32),
            served_reads=0, served_writes=0, degraded_reads=0,
            parked_writes=0, read_latency_sum=0, write_latency_sum=0,
            stall_cycles=0, rc_dropped=0,
            core_ptr=np.zeros(self.n_cores, np.int32),
            done_cycle=-1,
            tele=(_init_oracle_telemetry(p.n_data, self.n_cores,
                                         p.queue_depth)
                  if p.telemetry else None),
            fault=(_init_oracle_fault(p.n_data, self.scheme.n_ports,
                                      fault_plan)
                   if p.faults else None),
        )

    def _priors_layout(self, priors, n_par: int, n_slot_rows: int):
        """Warm start: ranked distinct hot regions pre-mapped into slots 0..
        up to the point's budget; out-of-range / -1 entries skipped without
        shifting later entries into their slots (the zeroed parity rows are
        the true XOR of the all-zero banks, so they start valid)."""
        p = self.p
        pr = np.asarray(priors, np.int32).reshape(-1)
        rs = p.region_size
        region_slot = np.full(p.n_regions, -1, np.int32)
        slot_region = np.full(p.n_slots, -1, np.int32)
        parity_valid = np.zeros((n_par, n_slot_rows), bool)
        budget = p.slot_budget
        for sid in range(min(pr.size, p.n_slots)):
            cand = int(pr[sid])
            if sid >= budget or cand < 0 or cand >= p.nr_active:
                continue
            slot_region[sid] = cand
            region_slot[cand] = sid
            parity_valid[:, sid * rs: sid * rs + p.rs_active] = True
        return region_slot, slot_region, parity_valid

    # --------------------------------------------------------------- arbiter
    def _arbiter(self, st: OracleState, trace, stream_end):
        """Cores in index order push into their destination queue."""
        p = self.p
        bank, row, is_write, data, valid = trace
        tlen = bank.shape[1]
        rs_a = p.rs_active
        for c in range(self.n_cores):
            pos = int(st.core_ptr[c])
            end = tlen if stream_end is None else int(stream_end[c])
            in_range = pos < end
            pc = min(pos, tlen - 1)
            v = bool(valid[c, pc]) and in_range
            if not v:
                if in_range:
                    st.core_ptr[c] = pos + 1          # idle slot: consume it
                continue
            b = max(int(bank[c, pc]), 0)
            i = max(int(row[c, pc]), 0)
            w = bool(is_write[c, pc])
            if w:
                q_valid, q_row, q_age = st.wq_valid, st.wq_row, st.wq_age
            else:
                q_valid, q_row, q_age = st.rq_valid, st.rq_row, st.rq_age
            free = np.flatnonzero(~q_valid[b])
            if free.size == 0:
                st.stall_cycles += 1                  # full queue: stall
                if st.tele is not None:
                    st.tele.stall_cause[b, 1 if w else 0] += 1
                continue
            s = int(free[0])
            q_row[b, s] = i
            q_age[b, s] = st.cycle
            q_valid[b, s] = True
            if st.tele is not None:
                (st.tele.wq_core if w else st.tele.rq_core)[b, s] = c
            if w:
                st.wq_data[b, s] = data[c, pc]
            region = i // rs_a
            if region < p.n_regions:
                st.access_count[region] += 1
            st.core_ptr[c] = pos + 1

    # -------------------------------------------------------------- datapath
    def _read_value(self, st: OracleState, b: int, i: int, mode: int) -> int:
        """Value a served read returns (direct / XOR-decode / redirect)."""
        p = self.p
        rs, rs_a = p.region_size, p.rs_active
        slot = int(st.region_slot[i // rs_a])
        pr = max(slot, 0) * rs + i % rs_a
        if mode == MODE_REDIRECT:
            holder = max(int(st.fresh_loc[b, i]) - 1, 0)
            return int(st.parity_data[holder, pr])
        if MODE_OPT0 <= mode < MODE_REDIRECT:
            j, sibs = self.options[b][mode - MODE_OPT0]
            val = int(st.parity_data[j, pr])
            for s in sibs:
                val ^= int(st.banks_data[s, i])
            return val
        return int(st.banks_data[b, i])               # direct / from-symbol

    def _commit_writes(self, st: OracleState, plan: OracleWritePlan,
                       cb, ci, ca, cv, cd):
        """Oldest-first commit: the youngest served write to a cell wins."""
        p = self.p
        rs, rs_a = p.region_size, p.rs_active
        for c in _stable_age_order(ca, cv):
            if not plan.served[c]:
                continue
            b = max(int(cb[c]), 0)
            i = max(int(ci[c]), 0)
            m = int(plan.mode[c])
            if m == WMODE_DIRECT:
                st.banks_data[b, i] = cd[c]
            else:
                slot = int(st.region_slot[i // rs_a])
                pr = max(slot, 0) * rs + i % rs_a
                j, _ = self.options[b][m - WMODE_PARK0]
                st.parity_data[j, pr] = cd[c]
            st.golden[b, i] = cd[c]

    # --------------------------------------------------------------- dynamic
    def _dynamic_step(self, st: OracleState, quiesce: bool):
        p, sch = self.p, self.scheme
        if p.n_active >= p.n_regions:                 # statically full: off
            return
        rs, rs_a, nr_a = p.region_size, p.rs_active, p.nr_active
        n_par = max(sch.n_parities, 1)
        # ---- in-flight encode countdown / completion
        in_flight = st.enc_region >= 0
        st.enc_remaining = st.enc_remaining - 1 if in_flight else 0
        if in_flight and st.enc_remaining <= 0:
            region, slot = st.enc_region, st.enc_slot
            for off in range(rs):
                i = min(max(region * rs_a + off, 0), p.n_rows - 1)
                for j in range(n_par):
                    val = 0
                    if off < rs_a and j < sch.n_parities:
                        for m in sch.members[j]:
                            val ^= int(st.banks_data[m, i])
                    st.parity_data[j, slot * rs + off] = np.int32(val)
                if off < rs_a:
                    st.parity_valid[:, slot * rs + off] = True
            st.region_slot[region] = slot
            st.slot_region[slot] = region
            st.switches += 1
            st.enc_region = -1
            st.enc_slot = -1
        # ---- periodic selection (skipped once the workload has drained)
        period = st.cycle > 0 and st.cycle % p.select_period == 0
        if period and st.enc_region < 0 and not quiesce:
            coded = st.region_slot >= 0
            active = np.arange(p.n_regions) < nr_a
            cand_counts = np.where(coded | ~active, -1, st.access_count)
            cand = int(np.argmax(cand_counts))
            cand_count = int(cand_counts[cand])
            evict_counts = np.where(coded & (st.parked_count == 0),
                                    st.access_count, INT32_MAX)
            victim = int(np.argmin(evict_counts))
            victim_count = int(evict_counts[victim])
            budget = p.slot_budget
            free = [s for s in range(min(p.n_slots, budget))
                    if st.slot_region[s] < 0]
            start_free = bool(free) and cand_count > 0
            start_evict = (not free and cand_count > victim_count
                           and victim_count < INT32_MAX)
            if start_evict:
                vslot = max(int(st.region_slot[victim]), 0)
                st.parity_valid[:, vslot * rs: (vslot + 1) * rs] = False
                st.region_slot[victim] = -1
                st.slot_region[vslot] = -1
            if start_free or start_evict:
                st.enc_region = cand
                st.enc_slot = vslot if start_evict else free[0]
                st.enc_remaining = max(1, rs_a // p.encode_rows_per_cycle)
        if period:
            st.access_count //= 2

    # ------------------------------------------------------------- one cycle
    def cycle(self, st: OracleState, trace, stream_end=None) -> OracleCycleOut:
        p = self.p
        rs_a = p.rs_active
        was_done = st.done_cycle >= 0
        self._arbiter(st, trace, stream_end)
        if st.tele is not None:
            np.maximum(st.tele.rq_hwm, st.rq_valid.sum(axis=1),
                       out=st.tele.rq_hwm)
            np.maximum(st.tele.wq_hwm, st.wq_valid.sum(axis=1),
                       out=st.tele.wq_hwm)

        # ---- fault injection: this cycle's predicates, dead-cycle counts,
        # fail-fast drops of unservable queue entries (mirrors the
        # production hook order exactly: after the arbiter + HWM, before
        # the hysteresis reads queue occupancy — repro.faults.inject)
        down = rebuilding = down_hard = stut = None
        fs = st.fault
        if p.faults:
            cyc = st.cycle
            down = (fs.fail_at <= cyc) & ~fs.rebuilt
            rebuilding = down & (fs.recover_at <= cyc)
            down_hard = down & ~rebuilding
            per = fs.stutter_period
            stut = (per > 0) & (cyc % np.maximum(per, 1) == fs.stutter_phase)
            if not was_done:   # counted until the workload drains
                fs.dead_cycles += down.astype(np.int64)
                if st.tele is not None:
                    st.tele.dead_cycles += down.astype(np.int64)
            for b in range(p.n_data):
                if not down_hard[b]:
                    continue
                for s in range(p.queue_depth):
                    if st.rq_valid[b, s]:
                        i = max(int(st.rq_row[b, s]), 0)
                        slot = int(st.region_slot[i // rs_a])
                        pr = max(slot, 0) * p.region_size + i % rs_a
                        viable = slot >= 0 and any(
                            st.parity_valid[j, pr]
                            and not any(down_hard[x] for x in sibs)
                            for j, sibs in self.options[b])
                        if int(st.fresh_loc[b, i]) == 0 and not viable:
                            st.rq_valid[b, s] = False
                            fs.unserved_reads += 1
                    if st.wq_valid[b, s]:
                        i = max(int(st.wq_row[b, s]), 0)
                        coded = int(st.region_slot[i // rs_a]) >= 0
                        if not coded or not self.options[b]:
                            st.wq_valid[b, s] = False
                            fs.lost_writes += 1

        # write-drain hysteresis
        wq_occ = int(st.wq_valid.sum(axis=1).max())
        any_r = bool(st.rq_valid.any())
        any_w = bool(st.wq_valid.any())
        wm = (wq_occ > p.wq_lo) if st.write_mode else (wq_occ >= p.wq_hi)
        serve_writes = (wm or (not any_r and any_w)) and any_w

        n = p.n_data * p.queue_depth
        bank_ids = np.repeat(np.arange(p.n_data, dtype=np.int32),
                             p.queue_depth)
        port_busy0 = np.zeros(self.scheme.n_ports + 1, bool)
        if p.faults:
            # a down bank's port reads permanently busy to both builders;
            # stuttering ports transiently so
            port_busy0[: p.n_data] |= down
            port_busy0[: self.scheme.n_ports] |= stut
        if serve_writes:
            cb, ci = bank_ids, st.wq_row.reshape(-1)
            ca, cv = st.wq_age.reshape(-1), st.wq_valid.reshape(-1)
            cd = st.wq_data.reshape(-1)
            plan = build_write_plan(
                self, cb, ci, ca, cv, port_busy0, st.fresh_loc,
                st.parity_valid, st.region_slot, st.parked_count,
                st.rc_bank, st.rc_row, st.rc_valid, rs_a, down=down)
            self._commit_writes(st, plan, cb, ci, ca, cv, cd)
            lat = int(np.where(plan.served, st.cycle - ca, 0).sum())
            if st.tele is not None:
                te = st.tele
                for c in range(n):
                    if plan.served[c]:
                        core = int(te.wq_core[c // p.queue_depth,
                                              c % p.queue_depth])
                        cls = 0 if int(plan.mode[c]) == WMODE_DIRECT else 1
                        te.write_mode_core[core, cls] += 1
                        te.lat_hist_write[_lat_bin(st.cycle - int(ca[c]))] += 1
                    elif cv[c]:           # valid but unserved: a wait cycle
                        te.wait_cause[int(cb[c]), 1] += 1
            st.wq_valid &= ~plan.served.reshape(p.n_data, p.queue_depth)
            st.fresh_loc = plan.fresh_loc
            st.parity_valid = plan.parity_valid
            st.parked_count = plan.parked_count
            st.rc_bank, st.rc_row, st.rc_valid = (plan.rc_bank, plan.rc_row,
                                                  plan.rc_valid)
            st.served_writes += plan.n_served
            st.parked_writes += plan.n_parked
            st.rc_dropped += plan.n_rc_dropped
            st.write_latency_sum += lat
            port_busy = plan.port_busy
            out = OracleCycleOut(np.zeros(n, bool), cb, ci,
                                 np.zeros(n, np.int32), plan.n_served)
        else:
            cb, ci = bank_ids, st.rq_row.reshape(-1)
            ca, cv = st.rq_age.reshape(-1), st.rq_valid.reshape(-1)
            plan = build_read_plan(
                self, cb, ci, ca, cv, port_busy0, st.fresh_loc,
                st.parity_valid, st.region_slot, rs_a)
            vals = np.zeros(n, np.int32)
            for c in np.flatnonzero(plan.served):
                vals[c] = self._read_value(st, max(int(cb[c]), 0),
                                           max(int(ci[c]), 0),
                                           int(plan.mode[c]))
            lat = int(np.where(plan.served, st.cycle - ca, 0).sum())
            if p.faults:
                # reads served degraded *because* their bank is down (a
                # redirect to a parked copy is a freshness artifact, not a
                # fault symptom)
                for c in np.flatnonzero(plan.served):
                    m = int(plan.mode[c])
                    if down[max(int(cb[c]), 0)] and (
                            m == MODE_FROM_SYM
                            or MODE_OPT0 <= m < MODE_REDIRECT):
                        fs.fault_degraded += 1
            if st.tele is not None:
                te = st.tele
                for c in range(n):
                    m = int(plan.mode[c])
                    if plan.served[c]:
                        core = int(te.rq_core[c // p.queue_depth,
                                              c % p.queue_depth])
                        cls = (0 if m == MODE_DIRECT else
                               1 if m == MODE_FROM_SYM else
                               3 if m >= MODE_REDIRECT else 2)
                        if (p.faults and cls in (1, 2)
                                and down[max(int(cb[c]), 0)]):
                            cls = 4
                        te.read_mode_core[core, cls] += 1
                        te.lat_hist_read[_lat_bin(st.cycle - int(ca[c]))] += 1
                    elif cv[c]:
                        te.wait_cause[int(cb[c]), 0] += 1
            st.rq_valid &= ~plan.served.reshape(p.n_data, p.queue_depth)
            st.served_reads += plan.n_served
            st.degraded_reads += plan.n_degraded
            st.read_latency_sum += lat
            port_busy = plan.port_busy
            out = OracleCycleOut(plan.served, cb, ci, vals, plan.n_served)
        st.write_mode = wm

        # recoding unit uses the cycle's leftover ports. A REBUILDING
        # bank's port is granted back to it here (and only here); stutter
        # still applies.
        if p.faults:
            rc_pb = np.array(port_busy, bool)
            rc_pb[: p.n_data] = np.where(rebuilding, stut[: p.n_data],
                                         port_busy[: p.n_data])
        else:
            rc_pb = port_busy
        rc = recode_step(self, rc_pb, st.fresh_loc, st.parity_valid,
                         st.parked_count, st.rc_bank, st.rc_row, st.rc_valid,
                         st.region_slot, st.banks_data, st.parity_data, rs_a,
                         down=down_hard)
        st.fresh_loc, st.parity_valid = rc.fresh_loc, rc.parity_valid
        st.parked_count, st.rc_valid = rc.parked_count, rc.rc_valid
        st.banks_data, st.parity_data = rc.banks_data, rc.parity_data
        if st.tele is not None:
            st.tele.recode_retired += rc.n_recoded
            for e in np.flatnonzero(st.rc_valid):     # still pending: waits
                st.tele.wait_cause[max(int(st.rc_bank[e]), 0), 2] += 1

        # online rebuild: a flat cursor sweeps every (bank, row) cell at
        # recode_budget cells per cycle while any bank is rebuilding,
        # pushing cells parked elsewhere or with a stale covering parity
        # into the recode ring; ``rebuilt`` latches — the bank rejoins —
        # when the sweep is done, the ring drained, and no parked cell
        # remains on a bank that is not still hard-down. Mirrors
        # ``repro.faults.inject.rebuild_scan``.
        if p.faults:
            total = p.n_data * p.n_rows
            any_rb = bool(rebuilding.any())
            newly = bool(((fs.recover_at == st.cycle)
                          & (fs.fail_at <= st.cycle) & ~fs.rebuilt).any())
            ptr = 0 if newly else int(fs.rebuild_ptr)
            for _ in range(p.recode_budget):
                cell = min(ptr, total - 1)
                x, i = cell // p.n_rows, cell % p.n_rows
                in_range = any_rb and ptr < total
                region = i // rs_a
                in_geom = region < p.nr_active
                slot = int(st.region_slot[min(region, p.n_regions - 1)])
                pr = max(slot, 0) * p.region_size + i % rs_a
                stale = slot >= 0 and any(not st.parity_valid[j, pr]
                                          for j, _s in self.options[x])
                need = in_range and in_geom and (
                    int(st.fresh_loc[x, i]) > 0 or stale)
                ok = True
                if need:
                    ok = _rc_push(st.rc_bank, st.rc_row, st.rc_valid, x, i)
                if in_range and (not need or ok):
                    ptr += 1
            fs.rebuild_ptr = ptr
            pending_park = bool(((st.fresh_loc > 0).any(axis=1)
                                 & ~down_hard).any())
            if ptr >= total and not st.rc_valid.any() and not pending_park:
                fs.rebuilt |= rebuilding

        # dynamic coding unit
        self._dynamic_step(st, quiesce=was_done)

        # completion bookkeeping
        tlen = trace[0].shape[1]
        ends = (np.full(self.n_cores, tlen) if stream_end is None
                else np.asarray(stream_end))
        consumed = bool((st.core_ptr >= ends).all())
        drained = not st.rq_valid.any() and not st.wq_valid.any()
        if st.done_cycle < 0 and consumed and drained:
            st.done_cycle = st.cycle
        st.cycle += 1
        return out

    # ------------------------------------------------------------------- run
    def quiescent(self, st: OracleState) -> bool:
        """Observable fixed point: workload drained, encoder idle, recode
        ring empty — every further cycle is an observable no-op. With
        faults on, also no scheduled fault event (a pending failure, or a
        failure with a recovery whose rebuild hasn't completed) that could
        still change observable state."""
        q = (st.done_cycle >= 0 and st.enc_region < 0
             and not st.rc_valid.any())
        if q and st.fault is not None:
            fs, cyc = st.fault, st.cycle
            down = (fs.fail_at <= cyc) & ~fs.rebuilt
            pending = (((fs.fail_at > cyc) & (fs.fail_at < INT32_MAX))
                       | (down & (fs.recover_at < INT32_MAX)))
            q = not bool(pending.any())
        return q

    def run(self, trace, n_cycles: int, st: Optional[OracleState] = None,
            stream_end=None, stop_when_quiescent: bool = False
            ) -> OracleState:
        """Advance ``n_cycles`` over a (n_cores, T) trace.

        ``stop_when_quiescent`` cuts the trailing no-op cycles (what the
        production sweep engine's early exit does); leave it off when the
        final *state* — including the free-running cycle counter and the
        windowed access-count decay — must match a fixed-length run."""
        if st is None:
            st = self.init_state()
        trace = tuple(np.asarray(x) for x in trace)
        for _ in range(n_cycles):
            if stop_when_quiescent and self.quiescent(st):
                break
            self.cycle(st, trace, stream_end)
        return st

    def result(self, st: OracleState) -> OracleResult:
        sr, sw = st.served_reads, st.served_writes
        return OracleResult(
            cycles=st.done_cycle if st.done_cycle >= 0 else st.cycle,
            completed=st.done_cycle >= 0,
            served_reads=sr,
            served_writes=sw,
            degraded_reads=st.degraded_reads,
            parked_writes=st.parked_writes,
            switches=st.switches,
            recode_backlog=int(st.rc_valid.sum()),
            stall_cycles=st.stall_cycles,
            avg_read_latency=st.read_latency_sum / max(sr, 1),
            avg_write_latency=st.write_latency_sum / max(sw, 1),
            rc_dropped=st.rc_dropped,
            unserved_reads=(st.fault.unserved_reads
                            if st.fault is not None else 0),
            lost_writes=(st.fault.lost_writes
                         if st.fault is not None else 0),
            fault_degraded_reads=(st.fault.fault_degraded
                                  if st.fault is not None else 0),
            dead_bank_cycles=(int(st.fault.dead_cycles.sum())
                              if st.fault is not None else 0),
        )
