"""Golden model for the serving KV pool: plan, latency, and telemetry
recompute in plain NumPy + Python loops.

``runtime/kvbank.py`` builds its read plans and critical-word latencies with
vectorized one-hot/cumsum tricks inside jit; this module re-derives every
number the serving telemetry plane reports with the dumbest possible
sequential walk, so the two implementations cannot share a misconception.
The conformance tests and ``repro.obs.report --serve`` refuse to render any
metric that disagrees with this recompute.

Model (mirrors kvbank's contract, derived from the paper's §IV controller):

* physical page ``p`` lives in bank ``p % n_banks``, slot ``p // n_banks``;
  parity group ``g`` protects banks ``(2g, 2g+1)`` on its own port.
* a decode step reads every allocated logical page of every active
  sequence once; requests are ordered batch-major over ``(B, max_pages)``.
* for each bank hotter than its pair sibling, every second fresh-parity
  read (ranks 1, 3, … below ``2 * ⌊(load−sib)/2⌋``) goes degraded.
* each bank port serves its direct reads first in request order, then
  lends cycles to its sibling's degraded reads; each parity port serves
  its group's degraded reads in request order. A degraded read completes
  when both words have arrived.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

HIST_BINS = 16  # matches repro.obs.planes.HIST_BINS


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def lat_bin(lat: int) -> int:
    """log2 histogram bin: 0 → 0, otherwise 1 + floor(log2(lat))."""
    return min(int(lat).bit_length(), HIST_BINS - 1)


def page_requests(n_banks: int, page: int, page_table: np.ndarray,
                  length: np.ndarray) -> List[Tuple[int, int, int, int]]:
    """This step's page reads in request (batch-major) order:
    ``[(seq, logical_page, bank, slot), ...]``."""
    out = []
    for b in range(page_table.shape[0]):
        for m in range(ceil_div(int(length[b]), page)):
            phys = int(page_table[b, m])
            if phys >= 0:
                out.append((b, m, phys % n_banks, phys // n_banks))
    return out


def plan_reads(n_banks: int, page: int, page_table: np.ndarray,
               length: np.ndarray,
               parity_fresh: Optional[np.ndarray]) -> dict:
    """Re-derive the controller's degraded-read plan sequentially."""
    reqs = page_requests(n_banks, page, page_table, length)
    load = np.zeros(n_banks, np.int64)
    for _, _, bank, _ in reqs:
        load[bank] += 1
    k_bank = np.maximum(load - load[np.arange(n_banks) ^ 1], 0) // 2

    use_parity = np.zeros(page_table.shape, bool)
    rank = np.zeros(n_banks, np.int64)      # fresh-parity requests seen so far
    for b, m, bank, slot in reqs:
        fresh = parity_fresh is not None and bool(parity_fresh[bank // 2, slot])
        if not fresh:
            continue
        r, rank[bank] = rank[bank], rank[bank] + 1
        if r % 2 == 1 and r < 2 * k_bank[bank]:
            use_parity[b, m] = True

    d_load = np.zeros(n_banks, np.int64)    # direct reads per bank port
    s_load = np.zeros(n_banks, np.int64)    # degraded shares per sibling port
    p_load = np.zeros(n_banks // 2, np.int64)
    for b, m, bank, _ in reqs:
        if use_parity[b, m]:
            s_load[bank ^ 1] += 1
            p_load[bank // 2] += 1
        else:
            d_load[bank] += 1
    coded = max(int(np.max(d_load + s_load)), int(np.max(p_load))) \
        if reqs else 0
    return {"load": load, "use_parity": use_parity,
            "uncoded_cycles": int(np.max(load)) if reqs else 0,
            "coded_cycles": coded}


def read_latencies(n_banks: int, page: int, page_table: np.ndarray,
                   length: np.ndarray, use_parity: np.ndarray) -> np.ndarray:
    """Critical-word latency per page read, sequential port walk."""
    reqs = page_requests(n_banks, page, page_table, length)
    d_count = np.zeros(n_banks, np.int64)
    for b, m, bank, _ in reqs:
        if not use_parity[b, m]:
            d_count[bank] += 1

    lat = np.zeros(page_table.shape, np.int64)
    d_next = np.zeros(n_banks, np.int64)         # direct cycles handed out
    s_next = d_count.copy()                      # sibling port cursor
    p_next = np.zeros(n_banks // 2, np.int64)    # parity port cursor
    for b, m, bank, _ in reqs:
        if use_parity[b, m]:
            sib, grp = bank ^ 1, bank // 2
            s_next[sib] += 1
            p_next[grp] += 1
            lat[b, m] = max(int(s_next[sib]), int(p_next[grp]))
        else:
            d_next[bank] += 1
            lat[b, m] = int(d_next[bank])
    return lat


def write_targets(n_banks: int, page: int, page_table: np.ndarray,
                  length: np.ndarray,
                  active: np.ndarray) -> List[Tuple[int, int, int]]:
    """(seq, bank, slot) for this step's one-token appends."""
    out = []
    max_pages = page_table.shape[1]
    for b in range(page_table.shape[0]):
        if not active[b]:
            continue
        lpage = int(length[b]) // page
        if lpage >= max_pages:
            continue
        phys = int(page_table[b, lpage])
        if phys >= 0:
            out.append((b, phys % n_banks, phys // n_banks))
    return out


def recode_select(parity_fresh: np.ndarray,
                  budget: Optional[int]) -> np.ndarray:
    """Rows the budgeted ReCoding walk refreshes this step (row-major
    order over the status table, first ``budget`` stale rows)."""
    stale = ~parity_fresh
    if budget is None:
        return stale
    if budget < 0:
        return np.zeros_like(stale)
    take = np.zeros_like(stale)
    left = budget
    for g in range(stale.shape[0]):
        for s in range(stale.shape[1]):
            if stale[g, s] and left > 0:
                take[g, s] = True
                left -= 1
    return take


@dataclasses.dataclass
class StepExpectation:
    """Every serving-plane increment one decode step should produce."""
    appended: int
    load: np.ndarray                 # (NB,)
    use_parity: np.ndarray           # (B, MP) bool
    latencies: np.ndarray            # (B, MP)
    uncoded_cycles: int
    coded_cycles: int
    bank_load_bins: np.ndarray       # (NB, HIST_BINS)
    read_mode_bank: np.ndarray       # (NB, 2) direct / degraded by home bank
    port_lat_hist: np.ndarray        # (NB, HIST_BINS) by serving port
    stale_before: int                # after this step's writes, before recode
    recoded: int
    parity_fresh_after: Optional[np.ndarray]


def expected_step(n_banks: int, page: int, page_table: np.ndarray,
                  length: np.ndarray, parity_fresh: Optional[np.ndarray],
                  active: np.ndarray,
                  recode_budget: Optional[int] = None) -> StepExpectation:
    """Replay one pooled decode step on the host: write marks → plan →
    latencies → recode, returning the exact plane increments."""
    page_table = np.asarray(page_table)
    length = np.asarray(length)
    active = np.asarray(active)
    writes = write_targets(n_banks, page, page_table, length, active)

    fresh = None
    if parity_fresh is not None:
        fresh = np.array(parity_fresh, copy=True)
        for _, bank, slot in writes:
            fresh[bank // 2, slot] = False

    len_eff = length + active.astype(length.dtype)
    plan = plan_reads(n_banks, page, page_table, len_eff, fresh)
    lat = read_latencies(n_banks, page, page_table, len_eff,
                         plan["use_parity"])

    bank_load_bins = np.zeros((n_banks, HIST_BINS), np.int64)
    for bank in range(n_banks):
        bank_load_bins[bank, lat_bin(int(plan["load"][bank]))] += 1
    read_mode = np.zeros((n_banks, 2), np.int64)
    port_hist = np.zeros((n_banks, HIST_BINS), np.int64)
    for b, m, bank, _ in page_requests(n_banks, page, page_table, len_eff):
        deg = bool(plan["use_parity"][b, m])
        read_mode[bank, 1 if deg else 0] += 1
        port_hist[bank ^ 1 if deg else bank, lat_bin(int(lat[b, m]))] += 1

    stale_before = recoded = 0
    fresh_after = fresh
    if fresh is not None:
        stale_before = int(np.sum(~fresh))
        take = recode_select(fresh, recode_budget)
        recoded = int(np.sum(take))
        fresh_after = fresh | take
    return StepExpectation(
        appended=len(writes), load=plan["load"],
        use_parity=plan["use_parity"], latencies=lat,
        uncoded_cycles=plan["uncoded_cycles"],
        coded_cycles=plan["coded_cycles"],
        bank_load_bins=bank_load_bins, read_mode_bank=read_mode,
        port_lat_hist=port_hist, stale_before=stale_before,
        recoded=recoded, parity_fresh_after=fresh_after)


@dataclasses.dataclass
class PlaneTotals:
    """Accumulated expectations over a run — compare against a
    ``repro.obs.serve`` snapshot field-by-field, exactly."""
    bank_load_hist: np.ndarray
    read_mode_bank: np.ndarray
    port_lat_hist: np.ndarray
    stale_backlog: int = 0
    stale_hwm: int = 0
    recoded_rows: int = 0
    decode_steps: int = 0
    appended_tokens: int = 0
    uncoded_cycles: int = 0
    coded_cycles: int = 0

    def add(self, e: StepExpectation) -> None:
        self.bank_load_hist += e.bank_load_bins
        self.read_mode_bank += e.read_mode_bank
        self.port_lat_hist += e.port_lat_hist
        self.stale_backlog += e.stale_before - e.recoded
        self.stale_hwm = max(self.stale_hwm, e.stale_before)
        self.recoded_rows += e.recoded
        self.decode_steps += 1
        self.appended_tokens += e.appended
        self.uncoded_cycles += e.uncoded_cycles
        self.coded_cycles += e.coded_cycles


def plane_totals(n_banks: int) -> PlaneTotals:
    return PlaneTotals(
        bank_load_hist=np.zeros((n_banks, HIST_BINS), np.int64),
        read_mode_bank=np.zeros((n_banks, 2), np.int64),
        port_lat_hist=np.zeros((n_banks, HIST_BINS), np.int64))
