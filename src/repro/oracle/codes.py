"""Coding schemes for the golden model, re-derived from the paper (§III).

This module deliberately shares **no code** with ``repro.core.codes``: the
oracle exists to catch a shared misconception, so even the scheme tables are
derived independently from the paper text. Conformance between the two
derivations is itself asserted by ``tests/test_conformance.py``.

A scheme is a list of *logical parity banks* over ``n_data`` single-port
data banks. Logical parity ``j`` stores, for every covered row ``i``,
``XOR_{m in members[j]} bank_m(i)`` (a single-member parity is a plain
duplicate). Each logical parity is hosted on a *physical* parity bank
(``phys[j]``); two logical parities packed onto one physical bank share its
single port (Scheme II packs two ``αL`` halves into one ``2αL`` bank).

Schemes (paper §III-B):

* **Scheme I** — data banks in groups of 4; all 6 pairwise XOR parities per
  group, one shallow physical bank each.
* **Scheme II** — Scheme I's pairs plus one duplicate per data bank, packed
  two *member-disjoint* halves per physical bank (complementary pairs
  share a bank, duplicates share a bank) so no data bank's serving options
  collide on one port.
* **Scheme III** — 9 data banks on a 3×3 grid; parities are the 3 row XORs,
  3 column XORs and 3 broken-diagonal XORs. With 8 data banks the 9th bank
  is simply omitted from every parity (paper Remark 5).
* **replication(k)** — ``k-1`` duplicates of every bank (§II-A1 baseline).
* **uncoded** — no parities.

Caps shared with the mode numbering: across the supported schemes a data
bank appears in at most ``MAX_OPTS = 4`` parities (Scheme II: 3 pairs + 1
duplicate) and a parity has at most ``MAX_SIBS = 2`` siblings per member
(Scheme III rows of 3). These bounds define the read/write action
numbering of the golden model (direct / option-k / redirect).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

MAX_SIBS = 2
MAX_OPTS = 4


@dataclasses.dataclass(frozen=True)
class OracleScheme:
    """Independent static description of one coding scheme."""

    name: str
    n_data: int
    members: Tuple[Tuple[int, ...], ...]   # logical parity -> data banks
    phys: Tuple[int, ...]                  # logical parity -> physical bank

    @property
    def n_parities(self) -> int:
        return len(self.members)

    @property
    def n_phys(self) -> int:
        return 0 if not self.phys else max(self.phys) + 1

    @property
    def n_ports(self) -> int:
        return self.n_data + self.n_phys

    def par_port(self, j: int) -> int:
        """Global single-port id charged by logical parity ``j``."""
        return self.n_data + self.phys[j]

    def options(self, b: int) -> List[Tuple[int, Tuple[int, ...]]]:
        """Non-direct serving options of data bank ``b``, in parity order:
        ``(parity j, sibling banks)`` — read parity ``j`` plus the siblings,
        XOR them to reconstruct ``b``'s row (no siblings = duplicate)."""
        opts = []
        for j, ms in enumerate(self.members):
            if b in ms:
                opts.append((j, tuple(m for m in ms if m != b)))
        assert len(opts) <= MAX_OPTS
        return opts


def _pairs(lo: int) -> List[Tuple[int, int]]:
    """All 6 unordered pairs of the 4-bank group starting at ``lo``, in
    lexicographic order."""
    g = range(lo, lo + 4)
    return [(a, b) for a in g for b in g if a < b]


def _scheme_i(n_data: int) -> OracleScheme:
    if n_data % 4:
        raise ValueError("Scheme I groups data banks by 4")
    members: List[Tuple[int, ...]] = []
    for g in range(0, n_data, 4):
        members.extend(_pairs(g))
    return OracleScheme("scheme_i", n_data, tuple(members),
                        tuple(range(len(members))))


def _scheme_ii(n_data: int) -> OracleScheme:
    if n_data % 4:
        raise ValueError("Scheme II groups data banks by 4")
    members: List[Tuple[int, ...]] = []
    phys: List[int] = []
    pbase = 0
    for g in range(0, n_data, 4):
        pairs = _pairs(g)
        dups = [(g + k,) for k in range(4)]
        # Each physical bank's two halves must cover disjoint data banks,
        # or the shared port costs some bank one of its 5 simultaneous
        # reads (§III-B2): complementary pairs together, duplicates
        # together.
        halves = [(pairs[0], pairs[5]), (pairs[1], pairs[4]),
                  (pairs[2], pairs[3]),
                  (dups[0], dups[1]), (dups[2], dups[3])]
        for k, (h0, h1) in enumerate(halves):
            members.extend([h0, h1])
            phys.extend([pbase + k, pbase + k])
        pbase += 5
    return OracleScheme("scheme_ii", n_data, tuple(members), tuple(phys))


def _scheme_iii(n_data: int) -> OracleScheme:
    if n_data not in (8, 9):
        raise ValueError("Scheme III uses a 3x3 grid (8 or 9 data banks)")
    grid = [[3 * r + c for c in range(3)] for r in range(3)]
    members: List[Tuple[int, ...]] = []
    members.extend(tuple(grid[r]) for r in range(3))                 # rows
    members.extend(tuple(grid[r][c] for r in range(3))               # columns
                   for c in range(3))
    members.extend(tuple(grid[k][(k + d) % 3] for k in range(3))     # diagonals
                   for d in range(3))
    if n_data == 8:
        members = [tuple(m for m in ms if m != 8) for ms in members]
    return OracleScheme("scheme_iii", n_data, tuple(members),
                        tuple(range(len(members))))


def _replication(n_data: int, copies: int) -> OracleScheme:
    members: List[Tuple[int, ...]] = []
    phys: List[int] = []
    for c in range(copies - 1):
        for b in range(n_data):
            members.append((b,))
            phys.append(c * n_data + b)
    return OracleScheme(f"replication_{copies}", n_data, tuple(members),
                        tuple(phys))


def oracle_scheme(name: str, n_data: int = 8) -> OracleScheme:
    """Build the named scheme's tables from the paper's definitions."""
    if name == "uncoded":
        return OracleScheme("uncoded", n_data, (), ())
    if name == "scheme_i":
        return _scheme_i(n_data)
    if name == "scheme_ii":
        return _scheme_ii(n_data)
    if name == "scheme_iii":
        return _scheme_iii(n_data)
    if name.startswith("replication_"):
        return _replication(n_data, int(name.split("_")[-1]))
    raise KeyError(f"unknown scheme {name!r}")


ORACLE_SCHEMES: Dict[str, str] = {
    name: name for name in ("uncoded", "scheme_i", "scheme_ii", "scheme_iii",
                            "replication_2", "replication_4")
}
