"""The NumPy golden model ("oracle") of the coded memory system.

An independent, deliberately dumb re-derivation of the paper's cycle
semantics used as the sole ground truth for the production (vectorized,
jax) scheduler — see ``docs/testing.md`` and ``tests/test_conformance.py``.
No jax anywhere in this package, and no code shared with ``repro.core``.

Public surface:
  codes — scheme tables re-derived from the paper (§III)
  model — ``OracleMemorySystem`` (cycle engine, plan builders, recode,
          dynamic coding), ``OracleParams.derive``, ``OracleResult``
  kvpool — serving KV-pool plan/latency/telemetry recompute (the golden
          model behind ``repro.obs.serve`` and ``bench_serve``)
"""
from repro.oracle.codes import (  # noqa: F401
    MAX_OPTS,
    MAX_SIBS,
    ORACLE_SCHEMES,
    OracleScheme,
    oracle_scheme,
)
from repro.oracle.kvpool import (  # noqa: F401
    PlaneTotals,
    StepExpectation,
    expected_step,
    plane_totals,
)
from repro.oracle.model import (  # noqa: F401
    MODE_DIRECT,
    MODE_FROM_SYM,
    MODE_OPT0,
    MODE_REDIRECT,
    MODE_UNSERVED,
    WMODE_DIRECT,
    WMODE_PARK0,
    WMODE_UNSERVED,
    OracleCycleOut,
    OracleMemorySystem,
    OracleParams,
    OracleReadPlan,
    OracleRecodeOut,
    OracleResult,
    OracleState,
    OracleTelemetry,
    OracleWritePlan,
    build_read_plan,
    build_write_plan,
    recode_step,
)
