"""Serving runtime: continuous batching over the canonical prefill/decode
steps, with the coded banked KV cache as the storage backend.

Request lifecycle: queued -> prefill (one jit call per admitted request,
padded to ``max_prompt``) -> decode slot (joins the batched decode step) ->
finished (EOS / max_new_tokens). Slots are fixed (``n_slots``) so the decode
step compiles once; free slots decode garbage that is masked out — the
standard continuous-batching trick (vLLM-style, static-shape variant).

Fault tolerance: the server state (cache + slot table) is device-resident;
``snapshot()``/``restore_snapshot()`` round-trips it through host memory so
a serving node can be replaced mid-stream (exercised in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.runtime import steps as steps_mod


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 4
    max_prompt: int = 64
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stop early


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg: ModelConfig, sc: ServeConfig, params):
        self.cfg, self.sc = cfg, sc
        # ring-buffer slot mapping must agree between prefill and decode
        # caches: any attention window must fit inside max_prompt.
        for w in (cfg.sliding_window, cfg.local_window):
            assert w == 0 or w <= sc.max_prompt, (w, sc.max_prompt)
        self.params = params
        self.decode = jax.jit(steps_mod.make_serve_step(cfg))
        self.prefill = jax.jit(steps_mod.make_prefill_step(cfg))
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * sc.n_slots
        b = sc.n_slots
        self.cache = lm.cache_spec(cfg, b, sc.max_seq)
        self.tokens = jnp.zeros((b,), jnp.int32)
        self.steps_run = 0

    # ------------------------------------------------------------- admission
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = req.prompt[-self.sc.max_prompt:]
            pad = self.sc.max_prompt - len(prompt)
            toks = jnp.asarray([[0] * pad + prompt], jnp.int32)
            batch = {"tokens": toks}
            if self.cfg.is_encdec:
                batch["frames"] = jnp.zeros(
                    (1, max(self.cfg.enc_frames, 8), self.cfg.d_model),
                    jnp.dtype(self.cfg.compute_dtype))
            if self.cfg.frontend == "vision_stub" and self.cfg.n_patches:
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.n_patches, self.cfg.d_model),
                    jnp.dtype(self.cfg.compute_dtype))
            tok, cache1 = self.prefill(self.params, batch)
            self._install(i, tok, cache1)
            req.out.append(int(tok[0]))
            self.slots[i] = req

    def _install(self, i: int, tok, cache1):
        """Copy a 1-batch prefill cache into slot i of the decode cache."""
        def put(dst, src):
            # dst (B, ...) or (L, B, ...); src has batch 1 in the same spot
            if dst.ndim >= 2 and src.shape[0] == dst.shape[0] and dst.ndim > 1 \
               and src.shape[1] == 1 and dst.shape[0] != 1:
                # (L, 1, ...) -> slot i of (L, B, ...), seq-padded
                pads = [(0, 0)] * src.ndim
                for ax in range(2, src.ndim):
                    pads[ax] = (0, dst.shape[ax] - src.shape[ax])
                src = jnp.pad(src, pads)
                return dst.at[:, i].set(src[:, 0])
            # (1, ...) -> slot i of (B, ...)
            pads = [(0, 0)] * src.ndim
            for ax in range(1, src.ndim):
                pads[ax] = (0, dst.shape[ax] - src.shape[ax])
            src = jnp.pad(src, pads)
            return dst.at[i].set(src[0])

        self.cache = jax.tree.map(put, self.cache, cache1)
        self.tokens = self.tokens.at[i].set(tok[0])

    # ----------------------------------------------------------------- step
    def step(self):
        self._admit()
        if not any(s is not None for s in self.slots):
            return
        self.tokens, self.cache = self.decode(self.params, self.tokens, self.cache)
        self.steps_run += 1
        toks = np.asarray(self.tokens)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(toks[i])
            req.out.append(t)
            if (self.sc.eos_id >= 0 and t == self.sc.eos_id) or \
               len(req.out) >= self.sc.max_new_tokens:
                req.done = True
                self.slots[i] = None

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen: set = set()
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(s is None for s in self.slots):
                break
        return finished

    # -------------------------------------------------------- fault recovery
    def snapshot(self) -> Dict[str, Any]:
        return {
            "cache": jax.tree.map(lambda a: np.asarray(a), self.cache),
            "tokens": np.asarray(self.tokens),
            "slots": [(r.rid, list(r.prompt), list(r.out)) if r else None
                      for r in self.slots],
        }

    def restore_snapshot(self, snap: Dict[str, Any]):
        self.cache = jax.tree.map(jnp.asarray, snap["cache"])
        self.tokens = jnp.asarray(snap["tokens"])
        self.slots = [Request(rid=s[0], prompt=s[1], out=s[2]) if s else None
                      for s in snap["slots"]]
