"""Serving runtime: continuous batching over the canonical prefill/decode
steps, with the coded banked KV cache as the storage backend.

Request lifecycle: queued -> prefill (one jit call per admitted request,
padded to ``max_prompt``) -> decode slot (joins the batched decode step) ->
finished (EOS / max_new_tokens). Slots are fixed (``n_slots``) so the decode
step compiles once; free slots decode garbage that is masked out — the
standard continuous-batching trick (vLLM-style, static-shape variant).

Storage backend: when the model config declares KV banks
(``cfg.kv_banks > 0``, global-attention decoder families), decode runs over
the coded KV page pool (``runtime/kvbank.PooledKV``): admission assigns
physical pages from a FIFO free list (freed pages recycle at the tail, so a
long-running server naturally churns placement), appends mark the code
status table, reads follow ``plan_reads``' degraded-read plan through the
pool-indirected ``coded_kv_decode`` gather, and the ReCoding unit refreshes
parity between steps. ``ServeConfig.coded=False`` switches to the uncoded
pool (zero-size parity arrays — a genuinely different compiled program),
and ``ServeConfig.telemetry=True`` rides the ``repro.obs.serve`` metric
planes in the decode cache. Every request's lifecycle is spanned host-side
in a ``repro.obs.serve.ServeLog``.

Fault tolerance: the server state (cache + slot table + page accounting) is
``snapshot()``/``restore_snapshot()`` round-tripped through host memory so
a serving node can be replaced mid-stream (exercised in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.obs import serve as obs_serve
from repro.runtime import kvbank as kb
from repro.runtime import steps as steps_mod


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 4
    max_prompt: int = 64
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stop early
    # ---- coded KV page pool (active when cfg.kv_banks > 0) ----
    coded: bool = True          # False: uncoded pool (no parity arrays)
    telemetry: bool = False     # device serve metric planes on the carry
    recode_budget: Optional[int] = None  # None: full recode; -1: never
    page: int = 0               # tokens per page; 0 -> cfg.kv_page
    pool_pages: int = 0         # physical pool size; 0 -> 2x working set
    kernel: str = "reference"   # pool gather datapath: "reference"|"pallas"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _wants_pool(cfg: ModelConfig) -> bool:
    # vision prefixes make the prefill cache longer than max_prompt, so the
    # page-table sizing below would not cover them — keep vlm on the ring.
    return (cfg.kv_banks > 0 and cfg.family in ("dense", "moe")
            and not cfg.is_encdec and cfg.sliding_window == 0
            and cfg.frontend == "none")


class Server:
    def __init__(self, cfg: ModelConfig, sc: ServeConfig, params, clock=None):
        self.cfg, self.sc = cfg, sc
        # ring-buffer slot mapping must agree between prefill and decode
        # caches: any attention window must fit inside max_prompt.
        for w in (cfg.sliding_window, cfg.local_window):
            assert w == 0 or w <= sc.max_prompt, (w, sc.max_prompt)
        self.params = params
        self.prefill = jax.jit(steps_mod.make_prefill_step(cfg))
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * sc.n_slots
        self.log = obs_serve.ServeLog(clock=clock)
        b = sc.n_slots
        self.pooled = _wants_pool(cfg)
        if self.pooled:
            page = sc.page or cfg.kv_page
            mp = -(-sc.max_seq // page)
            need = b * mp
            pool_pages = sc.pool_pages or -(-2 * need // cfg.kv_banks) \
                * cfg.kv_banks
            assert pool_pages % cfg.kv_banks == 0, (pool_pages, cfg.kv_banks)
            assert pool_pages >= need, (pool_pages, need)
            self.kvcfg = kb.KVBankConfig(
                n_banks=cfg.kv_banks, page=page, pool_pages=pool_pages,
                max_pages=mp)
            pool = kb.pool_init(self.kvcfg, cfg.n_layers, b, cfg.n_kv,
                                cfg.head_dim, jnp.dtype(cfg.compute_dtype),
                                coded=sc.coded)
            tele = (obs_serve.init_serve_telemetry(cfg.kv_banks)
                    if sc.telemetry else None)
            self.cache: Dict[str, Any] = {"pool": pool, "tele": tele}
            self.free_pages: List[int] = list(range(pool_pages))
            self.slot_pages: List[List[int]] = [[] for _ in range(b)]
            self.decode = jax.jit(steps_mod.make_pooled_serve_step(
                cfg, self.kvcfg, recode_budget=sc.recode_budget,
                kernel=sc.kernel))
            # encode-on-write at install matches the fused decode path (the
            # status table still goes stale-then-fresh identically)
            fuse = sc.coded and sc.recode_budget is None
            self._install_pool = jax.jit(
                lambda pool, i, k, v: kb.pool_install(self.kvcfg, pool,
                                                      i, k, v,
                                                      fuse_encode=fuse))
        else:
            self.decode = jax.jit(steps_mod.make_serve_step(cfg))
            self.cache = lm.cache_spec(cfg, b, sc.max_seq)
        self.tokens = jnp.zeros((b,), jnp.int32)
        self.steps_run = 0

    # ------------------------------------------------------------- admission
    def submit(self, req: Request):
        self.log.submit(req.rid)
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = req.prompt[-self.sc.max_prompt:]
            self.log.admit(req.rid, i, len(prompt))
            pad = self.sc.max_prompt - len(prompt)
            toks = jnp.asarray([[0] * pad + prompt], jnp.int32)
            batch = {"tokens": toks}
            if self.cfg.is_encdec:
                batch["frames"] = jnp.zeros(
                    (1, max(self.cfg.enc_frames, 8), self.cfg.d_model),
                    jnp.dtype(self.cfg.compute_dtype))
            if self.cfg.frontend == "vision_stub" and self.cfg.n_patches:
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.n_patches, self.cfg.d_model),
                    jnp.dtype(self.cfg.compute_dtype))
            tok, cache1 = self.prefill(self.params, batch)
            self._install(i, tok, cache1)
            req.out.append(int(tok[0]))
            self.log.prefill_done(req.rid)
            self.slots[i] = req

    def _install(self, i: int, tok, cache1):
        if self.pooled:
            self._install_pooled(i, tok, cache1)
            return
        self._install_ring(i, tok, cache1)

    def _install_ring(self, i: int, tok, cache1):
        """Copy a 1-batch prefill cache into slot i of the decode cache."""
        def put(dst, src):
            # dst (B, ...) or (L, B, ...); src has batch 1 in the same spot
            if dst.ndim >= 2 and src.shape[0] == dst.shape[0] and dst.ndim > 1 \
               and src.shape[1] == 1 and dst.shape[0] != 1:
                # (L, 1, ...) -> slot i of (L, B, ...), seq-padded
                pads = [(0, 0)] * src.ndim
                for ax in range(2, src.ndim):
                    pads[ax] = (0, dst.shape[ax] - src.shape[ax])
                src = jnp.pad(src, pads)
                return dst.at[:, i].set(src[:, 0])
            # (1, ...) -> slot i of (B, ...)
            pads = [(0, 0)] * src.ndim
            for ax in range(1, src.ndim):
                pads[ax] = (0, dst.shape[ax] - src.shape[ax])
            src = jnp.pad(src, pads)
            return dst.at[i].set(src[0])

        self.cache = jax.tree.map(put, self.cache, cache1)
        self.tokens = self.tokens.at[i].set(tok[0])

    def _install_pooled(self, i: int, tok, cache1):
        """Assign pool pages to slot i and install the prefilled KV."""
        need = self.kvcfg.max_pages
        assert len(self.free_pages) >= need, "pool sized below working set"
        phys = [self.free_pages.pop(0) for _ in range(need)]
        pool = self.cache["pool"]
        pool = pool._replace(
            page_table=pool.page_table.at[i].set(
                jnp.asarray(phys, jnp.int32)))
        pool = self._install_pool(pool, jnp.int32(i),
                                  cache1["k"][:, 0], cache1["v"][:, 0])
        self.cache["pool"] = pool
        self.slot_pages[i] = phys
        self.tokens = self.tokens.at[i].set(tok[0])

    def _retire(self, i: int):
        if not self.pooled:
            return
        self.free_pages.extend(self.slot_pages[i])
        self.slot_pages[i] = []
        pool = self.cache["pool"]
        self.cache["pool"] = pool._replace(
            page_table=pool.page_table.at[i].set(-1),
            length=pool.length.at[i].set(0))

    # ----------------------------------------------------------------- step
    def step(self):
        self._admit()
        self.step_decode()

    def step_decode(self):
        """One batched decode step (no admission) — exposed so telemetry
        conformance checks can observe the pool between admit and decode."""
        if not any(s is not None for s in self.slots):
            return
        self.tokens, self.cache = self.decode(self.params, self.tokens,
                                              self.cache)
        self.steps_run += 1
        toks = np.asarray(self.tokens)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(toks[i])
            req.out.append(t)
            self.log.token(req.rid)
            if (self.sc.eos_id >= 0 and t == self.sc.eos_id) or \
               len(req.out) >= self.sc.max_new_tokens:
                req.done = True
                self.log.finish(req.rid)
                self.slots[i] = None
                self._retire(i)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen: set = set()
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(s is None for s in self.slots):
                break
        return finished

    # ------------------------------------------------------------ telemetry
    def serve_snapshot(self) -> Optional[obs_serve.ServeSnapshot]:
        """Host view of the device serve planes (None when telemetry off)."""
        tele = self.cache.get("tele") if self.pooled else None
        return None if tele is None else obs_serve.snapshot(tele)

    def permute_pool(self, perm):
        """Relocate physical pages (placement churn / defrag model): page p
        moves to ``perm[p]``; tables, free list and parity follow, so decode
        output is invariant."""
        assert self.pooled, "permute_pool requires the paged pool backend"
        perm = np.asarray(perm)
        self.cache["pool"] = kb.pool_permute(
            self.kvcfg, self.cache["pool"], jnp.asarray(perm, jnp.int32))
        self.free_pages = [int(perm[p]) for p in self.free_pages]
        self.slot_pages = [[int(perm[p]) for p in pp]
                           for pp in self.slot_pages]

    # -------------------------------------------------------- fault recovery
    def snapshot(self) -> Dict[str, Any]:
        snap = {
            "cache": jax.tree.map(lambda a: np.asarray(a), self.cache),
            "tokens": np.asarray(self.tokens),
            "slots": [(r.rid, list(r.prompt), list(r.out)) if r else None
                      for r in self.slots],
        }
        if self.pooled:
            snap["free_pages"] = list(self.free_pages)
            snap["slot_pages"] = [list(p) for p in self.slot_pages]
        return snap

    def restore_snapshot(self, snap: Dict[str, Any]):
        self.cache = jax.tree.map(jnp.asarray, snap["cache"])
        self.tokens = jnp.asarray(snap["tokens"])
        self.slots = [Request(rid=s[0], prompt=s[1], out=s[2]) if s else None
                      for s in snap["slots"]]
        if self.pooled:
            self.free_pages = list(snap["free_pages"])
            self.slot_pages = [list(p) for p in snap["slot_pages"]]
