"""Distributed runtime: canonical step functions, fault-tolerant trainer,
continuous-batching server with the paper's coded KV banks."""
