"""Canonical step functions — the single definition used by the trainer, the
server, the dry-run and the benchmarks, so the compiled artifact analysed in
EXPERIMENTS.md is exactly what runs.

``train_step``  : fwd+bwd+AdamW update (+ optional microbatch gradient
                  accumulation via lax.scan, f32 accumulators).
``prefill_step``: prompt processing -> (last logits, KV/state cache).
``serve_step``  : one greedy decode token against the cache.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optim.adamw import OptConfig, OptState, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, *,
                    unroll: int = 1, remat: bool = True, q_chunk: int = 0,
                    n_micro: int = 1, chunk_unroll: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics)."""

    def lfn(params, batch):
        return lm.loss_fn(cfg, params, batch, unroll=unroll, remat=remat,
                          q_chunk=q_chunk, chunk_unroll=chunk_unroll)

    def train_step(params, opt_state: OptState, batch: Dict[str, jnp.ndarray]):
        if n_micro <= 1:
            loss, grads = jax.value_and_grad(lfn)(params, batch)
        else:
            def resh(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(resh, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(lfn)(params, mb)
                gsum = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                    gsum, g)
                return (gsum, lsum + l), None

            (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        new_params, new_opt, gnorm = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr_step": new_opt.step.astype(jnp.float32)}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, unroll: int = 1, q_chunk: int = 0,
                      chunk_unroll: int = 1):
    def prefill_step(params, batch: Dict[str, jnp.ndarray]):
        logits, cache = lm.prefill(cfg, params, batch, unroll=unroll,
                                   q_chunk=q_chunk, chunk_unroll=chunk_unroll)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, unroll: int = 1):
    """One greedy decode step: (params, token (B,), cache) -> (token', cache')."""

    def serve_step(params, token: jnp.ndarray, cache):
        logits, cache = lm.decode_step(cfg, params, token, cache, unroll=unroll)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def make_pooled_serve_step(cfg: ModelConfig, kvcfg, *, unroll: int = 1,
                           recode_budget=None, kernel: str = "reference"):
    """Greedy decode step over the coded KV page pool.

    ``(params, token (B,), cache) -> (token', cache')`` where the cache is
    ``{"pool": runtime.kvbank.PooledKV, "tele": ServeTelemetry | None}`` —
    the same calling convention as ``make_serve_step`` so the server's
    continuous-batching loop is pool-agnostic. ``tele=None`` compiles the
    exact same program as a telemetry-free build (locked by
    ``repro.analysis.jaxpr.lint_serve_step``). ``kernel`` selects the pool
    gather datapath (``"reference"`` jnp anchor / ``"pallas"`` kernel —
    bit-exact, so served tokens are identical; docs/kernels.md)."""

    def pooled_serve_step(params, token: jnp.ndarray, cache):
        logits, pool, tele = lm.decode_step_pooled(
            cfg, kvcfg, params, token, cache["pool"], cache["tele"],
            unroll=unroll, recode_budget=recode_budget, kernel=kernel)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, {"pool": pool, "tele": tele}

    return pooled_serve_step
