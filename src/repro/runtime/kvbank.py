"""Paged, banked + coded KV cache — the paper's multi-port-memory emulation
applied to a serving engine's KV page pool.

Design (vLLM-style paging, TPU-banked):

  * A GLOBAL pool of KV pages striped over ``n_banks`` single-ported HBM
    banks: physical page ``p`` lives in bank ``p % n_banks``, slot
    ``p // n_banks``. Each sequence owns a *block table* mapping its logical
    pages to pool pages, allocated in arrival order.
  * The B concurrent decode streams are the paper's N cores; the banks are
    shared hardware. Because allocation order interleaves across sequences,
    a sequence that decodes far past its batch-mates gets pages that stride
    the pool — its pages cluster on few banks (with 8 active sequences and 8
    banks, in lockstep each sequence's pages all land in ONE bank). Those
    banks become hot exactly like the paper's conflicted DRAM banks.
  * Pairwise XOR parity banks (Scheme-I group structure, rate 2/3) let the
    planner serve every second read of an over-loaded bank from
    (pair-sibling bank ^ parity bank) — a degraded read; idle ports become
    extra read ports (paper Fig 3).
  * Appends write the data bank and mark the touched pair row stale in the
    code status table (paper §IV-A); a background ``recode`` pass re-encodes
    stale rows (the ReCoding unit, §IV-D). Stale parity rows are never used
    for degraded reads.

``coded_kv_decode`` (src/repro/kernels/coded_kv_decode) is the Pallas
datapath consuming ``plan_reads``' page plan on the packed bank layout.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import uint_view_dtype


@dataclasses.dataclass(frozen=True)
class KVBankConfig:
    n_banks: int = 8            # data banks (parity pairs: 2g, 2g+1)
    page: int = 16              # tokens per page
    pool_pages: int = 1024      # physical pages in the pool
    max_pages: int = 256        # logical pages per sequence (block table width)


class BankedKVState(NamedTuple):
    k_banks: jnp.ndarray        # (NB, slots, page, Hkv, D) uint lanes (pool)
    v_banks: jnp.ndarray
    k_par: jnp.ndarray          # (NB/2, slots, page, Hkv, D)
    v_par: jnp.ndarray
    parity_fresh: jnp.ndarray   # (NB/2, slots) bool — code status table
    page_table: jnp.ndarray     # (B, max_pages) int32 physical page id, -1 free
    length: jnp.ndarray         # (B,) tokens present
    next_page: jnp.ndarray      # () int32 pool allocation cursor


class ReadPlan(NamedTuple):
    use_parity: jnp.ndarray      # (B, max_pages) bool
    uncoded_cycles: jnp.ndarray  # () int32 — max bank load, whole step
    coded_cycles: jnp.ndarray    # () int32 — port cycles with parity serving


def init_state(cfg: KVBankConfig, batch: int, n_kv: int, head_dim: int,
               dtype) -> BankedKVState:
    u = uint_view_dtype(dtype)
    nb, pg = cfg.n_banks, cfg.page
    slots = cfg.pool_pages // nb
    shape = (nb, slots, pg, n_kv, head_dim)
    pshape = (nb // 2, slots, pg, n_kv, head_dim)
    return BankedKVState(
        k_banks=jnp.zeros(shape, u), v_banks=jnp.zeros(shape, u),
        k_par=jnp.zeros(pshape, u), v_par=jnp.zeros(pshape, u),
        parity_fresh=jnp.ones((nb // 2, slots), bool),
        page_table=jnp.full((batch, cfg.max_pages), -1, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
        next_page=jnp.int32(0),
    )


def append_token(cfg: KVBankConfig, st: BankedKVState, k_new: jnp.ndarray,
                 v_new: jnp.ndarray,
                 active: Optional[jnp.ndarray] = None) -> BankedKVState:
    """Append one token's (B, Hkv, D) KV for every ``active`` sequence.
    Allocates a fresh pool page at page boundaries (arrival-order allocation
    — the realistic continuous-batching pattern). Touched pair parity rows
    go stale (paper §IV-A status 01)."""
    b = st.length.shape[0]
    if active is None:
        active = jnp.ones((b,), bool)
    u = st.k_banks.dtype
    ku = jax.lax.bitcast_convert_type(k_new, u) if k_new.dtype != u else k_new
    vu = jax.lax.bitcast_convert_type(v_new, u) if v_new.dtype != u else v_new

    pos = st.length
    lpage = pos // cfg.page
    in_page = pos % cfg.page
    need_alloc = active & (in_page == 0)
    offs = jnp.cumsum(need_alloc.astype(jnp.int32)) - need_alloc
    new_phys = st.next_page + offs
    bi = jnp.arange(b)
    page_table = st.page_table.at[bi, lpage].set(
        jnp.where(need_alloc, new_phys, st.page_table[bi, lpage]))
    next_page = st.next_page + need_alloc.astype(jnp.int32).sum()

    phys = page_table[bi, lpage]
    nop_bank = cfg.n_banks          # out-of-range sink for inactive lanes
    bank = jnp.where(active, phys % cfg.n_banks, nop_bank)
    slot = jnp.maximum(phys // cfg.n_banks, 0)
    k_banks = st.k_banks.at[bank, slot, in_page].set(ku, mode="drop")
    v_banks = st.v_banks.at[bank, slot, in_page].set(vu, mode="drop")
    parity_fresh = st.parity_fresh.at[
        jnp.where(active, bank // 2, cfg.n_banks), slot].set(False, mode="drop")
    return st._replace(k_banks=k_banks, v_banks=v_banks,
                       parity_fresh=parity_fresh, page_table=page_table,
                       length=pos + active.astype(jnp.int32),
                       next_page=next_page)


def recode(cfg: KVBankConfig, st: BankedKVState,
           budget: Optional[int] = None) -> BankedKVState:
    """ReCoding unit: refresh stale parity rows (all when budget is None)."""
    k_par = st.k_banks[0::2] ^ st.k_banks[1::2]
    v_par = st.v_banks[0::2] ^ st.v_banks[1::2]
    if budget is None:
        return st._replace(k_par=k_par, v_par=v_par,
                           parity_fresh=jnp.ones_like(st.parity_fresh))
    stale = ~st.parity_fresh
    order = jnp.cumsum(stale.reshape(-1).astype(jnp.int32)).reshape(stale.shape)
    take = stale & (order <= budget)
    t5 = take[..., None, None, None]
    return st._replace(
        k_par=jnp.where(t5, k_par, st.k_par),
        v_par=jnp.where(t5, v_par, st.v_par),
        parity_fresh=st.parity_fresh | take,
    )


def plan_reads(cfg: KVBankConfig, st: BankedKVState) -> ReadPlan:
    """Build this step's page-read plan (vectorized pattern builder).

    Port contention is accounted across the WHOLE batch (shared banks).
    For every bank hotter than its pair sibling, up to ⌊(load−sib)/2⌋ of its
    fresh-parity reads are sent down the degraded path (sibling ^ parity) —
    alternating ranks, the controller's round-robin. Balanced loads get no
    degraded reads (no idle ports — the paper's worst case)."""
    b, mp = st.page_table.shape
    nb = cfg.n_banks
    needed = (jnp.arange(mp)[None, :] < -(-st.length[:, None] // cfg.page)) \
        & (st.page_table >= 0)                      # (B, MP)
    phys = jnp.maximum(st.page_table, 0)
    bank = phys % nb                                # (B, MP)
    slot = phys // nb
    fresh = st.parity_fresh[bank // 2, slot]        # (B, MP)

    load = jnp.zeros((nb,), jnp.int32).at[
        jnp.where(needed, bank, nb)].add(1, mode="drop")
    sib_load = load[jnp.arange(nb) ^ 1]
    k_bank = jnp.maximum(load - sib_load, 0) // 2   # beneficial moves per bank

    # rank of each request within its bank, batch-major over (B, MP)
    oh = (needed & fresh)[..., None] * jax.nn.one_hot(bank, nb, dtype=jnp.int32)
    flat = oh.reshape(b * mp, nb)
    rank = (jnp.cumsum(flat, axis=0) - flat).reshape(b, mp, nb)
    my_rank = jnp.take_along_axis(rank, bank[..., None], -1)[..., 0]
    use_parity = (needed & fresh & ((my_rank % 2) == 1)
                  & (my_rank < 2 * k_bank[bank]))

    direct = needed & ~use_parity
    d_bank = jnp.zeros((nb,), jnp.int32).at[
        jnp.where(direct, bank, nb)].add(1, mode="drop")
    s_bank = jnp.zeros((nb,), jnp.int32).at[
        jnp.where(use_parity, bank ^ 1, nb)].add(1, mode="drop")
    p_bank = jnp.zeros((nb // 2,), jnp.int32).at[
        jnp.where(use_parity, bank // 2, nb // 2)].add(1, mode="drop")
    coded = jnp.maximum(jnp.max(d_bank + s_bank), jnp.max(p_bank))
    return ReadPlan(use_parity=use_parity,
                    uncoded_cycles=jnp.max(load),
                    coded_cycles=coded)


def gather_kv(cfg: KVBankConfig, st: BankedKVState, plan: ReadPlan,
              dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize the logical (B, T, Hkv, D) K/V via the planned mix of
    direct and degraded (sibling ^ parity) reads — bit-exact reconstruction.
    Unallocated logical pages come back zero."""
    b, mp = st.page_table.shape
    nb = cfg.n_banks
    phys = jnp.maximum(st.page_table, 0)
    bank = phys % nb
    slot = phys // nb
    alloc = st.page_table >= 0

    def one(banks, par):
        direct = banks[bank, slot]                     # (B, MP, pg, Hkv, D)
        deg = banks[bank ^ 1, slot] ^ par[bank // 2, slot]
        up = plan.use_parity[..., None, None, None]
        out = jnp.where(up, deg, direct)
        out = jnp.where(alloc[..., None, None, None], out, 0)
        pg, hkv, d = out.shape[-3:]
        return out.reshape(b, mp * pg, hkv, d)

    k = one(st.k_banks, st.k_par)
    v = one(st.v_banks, st.v_par)
    k = jax.lax.bitcast_convert_type(k, dtype) if k.dtype != dtype else k
    v = jax.lax.bitcast_convert_type(v, dtype) if v.dtype != dtype else v
    return k, v
