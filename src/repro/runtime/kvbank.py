"""Paged, banked + coded KV cache — the paper's multi-port-memory emulation
applied to a serving engine's KV page pool.

Design (vLLM-style paging, TPU-banked):

  * A GLOBAL pool of KV pages striped over ``n_banks`` single-ported HBM
    banks: physical page ``p`` lives in bank ``p % n_banks``, slot
    ``p // n_banks``. Each sequence owns a *block table* mapping its logical
    pages to pool pages, allocated in arrival order.
  * The B concurrent decode streams are the paper's N cores; the banks are
    shared hardware. Because allocation order interleaves across sequences,
    a sequence that decodes far past its batch-mates gets pages that stride
    the pool — its pages cluster on few banks (with 8 active sequences and 8
    banks, in lockstep each sequence's pages all land in ONE bank). Those
    banks become hot exactly like the paper's conflicted DRAM banks.
  * Pairwise XOR parity banks (Scheme-I group structure, rate 2/3) let the
    planner serve every second read of an over-loaded bank from
    (pair-sibling bank ^ parity bank) — a degraded read; idle ports become
    extra read ports (paper Fig 3).
  * Appends write the data bank and mark the touched pair row stale in the
    code status table (paper §IV-A); a background ``recode`` pass re-encodes
    stale rows (the ReCoding unit, §IV-D). Stale parity rows are never used
    for degraded reads.

``coded_kv_decode`` (src/repro/kernels/coded_kv_decode) is the Pallas
datapath consuming ``plan_reads``' page plan on the packed bank layout.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import uint_view_dtype


@dataclasses.dataclass(frozen=True)
class KVBankConfig:
    n_banks: int = 8            # data banks (parity pairs: 2g, 2g+1)
    page: int = 16              # tokens per page
    pool_pages: int = 1024      # physical pages in the pool
    max_pages: int = 256        # logical pages per sequence (block table width)


class BankedKVState(NamedTuple):
    k_banks: jnp.ndarray        # (NB, slots, page, Hkv, D) uint lanes (pool)
    v_banks: jnp.ndarray
    k_par: jnp.ndarray          # (NB/2, slots, page, Hkv, D)
    v_par: jnp.ndarray
    parity_fresh: jnp.ndarray   # (NB/2, slots) bool — code status table
    page_table: jnp.ndarray     # (B, max_pages) int32 physical page id, -1 free
    length: jnp.ndarray         # (B,) tokens present
    next_page: jnp.ndarray      # () int32 pool allocation cursor


class ReadPlan(NamedTuple):
    use_parity: jnp.ndarray      # (B, max_pages) bool
    uncoded_cycles: jnp.ndarray  # () int32 — max bank load, whole step
    coded_cycles: jnp.ndarray    # () int32 — port cycles with parity serving
    load: jnp.ndarray            # (n_banks,) int32 — needed pages per bank


class PooledKV(NamedTuple):
    """Layered serving pool: one shared page table over per-layer banks.

    The serving path's decode step reads EVERY layer's KV through the same
    logical pages, so the block table, code-status table and plan are
    shared across layers while the payload arrays carry a leading layer
    axis. ``k_par.shape[1] == 0`` IS the uncoded-pool config switch: the
    parity arrays (and the status table) are zero-size, the planner never
    produces degraded reads, and the compiled program carries no parity
    traffic at all.
    """

    k_banks: jnp.ndarray        # (L, NB, slots, page, Hkv, D) uint lanes
    v_banks: jnp.ndarray
    k_par: jnp.ndarray          # (L, NB/2, slots, page, Hkv, D); (L, 0, ...)
    v_par: jnp.ndarray          #   when the pool is uncoded
    parity_fresh: jnp.ndarray   # (NB/2, slots) bool — shared status table
    page_table: jnp.ndarray     # (B, max_pages) int32 physical id, -1 free
    length: jnp.ndarray         # (B,) int32 tokens present (= decode pos)


def init_state(cfg: KVBankConfig, batch: int, n_kv: int, head_dim: int,
               dtype) -> BankedKVState:
    u = uint_view_dtype(dtype)
    nb, pg = cfg.n_banks, cfg.page
    slots = cfg.pool_pages // nb
    shape = (nb, slots, pg, n_kv, head_dim)
    pshape = (nb // 2, slots, pg, n_kv, head_dim)
    return BankedKVState(
        k_banks=jnp.zeros(shape, u), v_banks=jnp.zeros(shape, u),
        k_par=jnp.zeros(pshape, u), v_par=jnp.zeros(pshape, u),
        parity_fresh=jnp.ones((nb // 2, slots), bool),
        page_table=jnp.full((batch, cfg.max_pages), -1, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
        next_page=jnp.int32(0),
    )


def append_token(cfg: KVBankConfig, st: BankedKVState, k_new: jnp.ndarray,
                 v_new: jnp.ndarray,
                 active: Optional[jnp.ndarray] = None) -> BankedKVState:
    """Append one token's (B, Hkv, D) KV for every ``active`` sequence.
    Allocates a fresh pool page at page boundaries (arrival-order allocation
    — the realistic continuous-batching pattern). Touched pair parity rows
    go stale (paper §IV-A status 01)."""
    b = st.length.shape[0]
    if active is None:
        active = jnp.ones((b,), bool)
    u = st.k_banks.dtype
    ku = jax.lax.bitcast_convert_type(k_new, u) if k_new.dtype != u else k_new
    vu = jax.lax.bitcast_convert_type(v_new, u) if v_new.dtype != u else v_new

    pos = st.length
    lpage = pos // cfg.page
    in_page = pos % cfg.page
    need_alloc = active & (in_page == 0)
    offs = jnp.cumsum(need_alloc.astype(jnp.int32)) - need_alloc
    new_phys = st.next_page + offs
    bi = jnp.arange(b)
    page_table = st.page_table.at[bi, lpage].set(
        jnp.where(need_alloc, new_phys, st.page_table[bi, lpage]))
    next_page = st.next_page + need_alloc.astype(jnp.int32).sum()

    phys = page_table[bi, lpage]
    nop_bank = cfg.n_banks          # out-of-range sink for inactive lanes
    bank = jnp.where(active, phys % cfg.n_banks, nop_bank)
    slot = jnp.maximum(phys // cfg.n_banks, 0)
    k_banks = st.k_banks.at[bank, slot, in_page].set(ku, mode="drop")
    v_banks = st.v_banks.at[bank, slot, in_page].set(vu, mode="drop")
    parity_fresh = st.parity_fresh.at[
        jnp.where(active, bank // 2, cfg.n_banks), slot].set(False, mode="drop")
    return st._replace(k_banks=k_banks, v_banks=v_banks,
                       parity_fresh=parity_fresh, page_table=page_table,
                       length=pos + active.astype(jnp.int32),
                       next_page=next_page)


def _budget_rows(parity_fresh: jnp.ndarray, budget: int):
    """Pick the first ``budget`` stale parity rows in raster (cumsum) order.

    Returns ``(take, idx, valid)``: the taken-row mask (identical to the
    historical masked-recompute take set), the flat (group*slots) indices of
    up to ``cap = min(budget, rows)`` rows to re-encode, and which of those
    gathered rows are really stale (the rest scatter to an out-of-range sink
    with ``mode="drop"``). This is the row-gather form of budgeted recode:
    only the taken rows' member banks are read, not the whole pool."""
    ng, slots = parity_fresh.shape
    stale = ~parity_fresh
    order = jnp.cumsum(stale.reshape(-1).astype(jnp.int32)).reshape(stale.shape)
    take = stale & (order <= budget)
    # `budget` is a host int by contract (compile-time)  # analysis: tracer-branch
    cap = max(0, min(int(budget), ng * slots))
    flat_take = take.reshape(-1)
    key = jnp.where(flat_take, order.reshape(-1), jnp.iinfo(jnp.int32).max)
    idx = jnp.argsort(key)[:cap]
    return take, idx, flat_take[idx]


def recode(cfg: KVBankConfig, st: BankedKVState,
           budget: Optional[int] = None) -> BankedKVState:
    """ReCoding unit: refresh stale parity rows (all when budget is None).
    The budgeted path gathers only the taken rows' member banks (row-gather)
    instead of re-encoding the whole pool and masking."""
    if budget is None:
        return st._replace(k_par=st.k_banks[0::2] ^ st.k_banks[1::2],
                           v_par=st.v_banks[0::2] ^ st.v_banks[1::2],
                           parity_fresh=jnp.ones_like(st.parity_fresh))
    take, idx, valid = _budget_rows(st.parity_fresh, budget)
    ng, slots = st.parity_fresh.shape
    # `budget` is a host int by contract (compile-time)  # analysis: tracer-branch
    if idx.shape[0] == 0:
        return st
    g, s = idx // slots, idx % slots
    new_k = st.k_banks[2 * g, s] ^ st.k_banks[2 * g + 1, s]
    new_v = st.v_banks[2 * g, s] ^ st.v_banks[2 * g + 1, s]
    sidx = jnp.where(valid, idx, ng * slots)
    tail = st.k_par.shape[2:]
    k_par = st.k_par.reshape((ng * slots,) + tail).at[sidx].set(
        new_k, mode="drop").reshape(st.k_par.shape)
    v_par = st.v_par.reshape((ng * slots,) + tail).at[sidx].set(
        new_v, mode="drop").reshape(st.v_par.shape)
    return st._replace(k_par=k_par, v_par=v_par,
                       parity_fresh=st.parity_fresh | take)


def pool_read_sets(cfg: KVBankConfig, page_table: jnp.ndarray,
                   length: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(needed, bank) tables for a step's page reads over shared tables."""
    mp = page_table.shape[1]
    needed = (jnp.arange(mp)[None, :] < -(-length[:, None] // cfg.page)) \
        & (page_table >= 0)                         # (B, MP)
    bank = jnp.maximum(page_table, 0) % cfg.n_banks
    return needed, bank


def plan_reads(cfg: KVBankConfig, st: BankedKVState) -> ReadPlan:
    """Build this step's page-read plan (vectorized pattern builder).

    Port contention is accounted across the WHOLE batch (shared banks).
    For every bank hotter than its pair sibling, up to ⌊(load−sib)/2⌋ of its
    fresh-parity reads are sent down the degraded path (sibling ^ parity) —
    alternating ranks, the controller's round-robin. Balanced loads get no
    degraded reads (no idle ports — the paper's worst case)."""
    return _plan_from_tables(cfg, st.page_table, st.length, st.parity_fresh)


def _plan_from_tables(cfg: KVBankConfig, page_table: jnp.ndarray,
                      length: jnp.ndarray,
                      parity_fresh: Optional[jnp.ndarray]) -> ReadPlan:
    """plan_reads over bare tables; ``parity_fresh=None`` plans an uncoded
    pool (no degraded reads, coded == uncoded cycles)."""
    b, mp = page_table.shape
    nb = cfg.n_banks
    needed, bank = pool_read_sets(cfg, page_table, length)
    slot = jnp.maximum(page_table, 0) // nb
    if parity_fresh is None:
        fresh = jnp.zeros((b, mp), bool)
    else:
        fresh = parity_fresh[bank // 2, slot]       # (B, MP)

    load = jnp.zeros((nb,), jnp.int32).at[
        jnp.where(needed, bank, nb)].add(1, mode="drop")
    sib_load = load[jnp.arange(nb) ^ 1]
    k_bank = jnp.maximum(load - sib_load, 0) // 2   # beneficial moves per bank

    # rank of each request within its bank, batch-major over (B, MP)
    oh = (needed & fresh)[..., None] * jax.nn.one_hot(bank, nb, dtype=jnp.int32)
    flat = oh.reshape(b * mp, nb)
    rank = (jnp.cumsum(flat, axis=0) - flat).reshape(b, mp, nb)
    my_rank = jnp.take_along_axis(rank, bank[..., None], -1)[..., 0]
    use_parity = (needed & fresh & ((my_rank % 2) == 1)
                  & (my_rank < 2 * k_bank[bank]))

    direct = needed & ~use_parity
    d_bank = jnp.zeros((nb,), jnp.int32).at[
        jnp.where(direct, bank, nb)].add(1, mode="drop")
    s_bank = jnp.zeros((nb,), jnp.int32).at[
        jnp.where(use_parity, bank ^ 1, nb)].add(1, mode="drop")
    p_bank = jnp.zeros((nb // 2,), jnp.int32).at[
        jnp.where(use_parity, bank // 2, nb // 2)].add(1, mode="drop")
    coded = jnp.maximum(jnp.max(d_bank + s_bank), jnp.max(p_bank))
    return ReadPlan(use_parity=use_parity,
                    uncoded_cycles=jnp.max(load),
                    coded_cycles=coded,
                    load=load)


def gather_kv(cfg: KVBankConfig, st: BankedKVState, plan: ReadPlan,
              dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize the logical (B, T, Hkv, D) K/V via the planned mix of
    direct and degraded (sibling ^ parity) reads — bit-exact reconstruction.
    Unallocated logical pages come back zero."""
    b, mp = st.page_table.shape
    nb = cfg.n_banks
    phys = jnp.maximum(st.page_table, 0)
    bank = phys % nb
    slot = phys // nb
    alloc = st.page_table >= 0

    def one(banks, par):
        direct = banks[bank, slot]                     # (B, MP, pg, Hkv, D)
        deg = banks[bank ^ 1, slot] ^ par[bank // 2, slot]
        up = plan.use_parity[..., None, None, None]
        out = jnp.where(up, deg, direct)
        out = jnp.where(alloc[..., None, None, None], out, 0)
        pg, hkv, d = out.shape[-3:]
        return out.reshape(b, mp * pg, hkv, d)

    k = one(st.k_banks, st.k_par)
    v = one(st.v_banks, st.v_par)
    # host-passed target dtype: static by contract  # analysis: tracer-branch
    k = jax.lax.bitcast_convert_type(k, dtype) if k.dtype != dtype else k
    # host-passed target dtype: static by contract  # analysis: tracer-branch
    v = jax.lax.bitcast_convert_type(v, dtype) if v.dtype != dtype else v
    return k, v


def read_latencies(cfg: KVBankConfig, page_table: jnp.ndarray,
                   length: jnp.ndarray,
                   use_parity: jnp.ndarray) -> jnp.ndarray:
    """Per-page critical-word latency (port cycles) under the planned serving
    order, (B, max_pages) int32, 0 for pages not read this step.

    Deterministic serialization matching ``plan_reads``' cycle accounting:
    each bank port serves its DIRECT reads first in request (batch-major)
    order, then lends cycles to its pair sibling's degraded reads; each
    parity port serves its group's degraded reads in request order. A
    degraded read completes when both its sibling word and its parity word
    have arrived, so the max latency over the step equals
    ``plan.coded_cycles`` (and equals ``plan.uncoded_cycles`` when
    ``use_parity`` is all-False)."""
    b, mp = page_table.shape
    nb = cfg.n_banks
    needed, bank = pool_read_sets(cfg, page_table, length)
    direct = needed & ~use_parity
    deg = needed & use_parity

    def rank_of(mask, idx, n):
        oh = mask[..., None] * jax.nn.one_hot(idx, n, dtype=jnp.int32)
        flat = oh.reshape(b * mp, n)
        r = (jnp.cumsum(flat, axis=0) - flat).reshape(b, mp, n)
        return jnp.take_along_axis(r, idx[..., None], -1)[..., 0]

    d_rank = rank_of(direct, bank, nb)
    s_rank = rank_of(deg, bank, nb)          # degraded share one sibling port
    p_rank = rank_of(deg, bank // 2, nb // 2)
    d_bank = jnp.zeros((nb,), jnp.int32).at[
        jnp.where(direct, bank, nb)].add(1, mode="drop")
    lat_direct = 1 + d_rank
    lat_deg = 1 + jnp.maximum(d_bank[bank ^ 1] + s_rank, p_rank)
    lat = jnp.where(deg, lat_deg, jnp.where(direct, lat_direct, 0))
    return lat.astype(jnp.int32)


def parity_members(n_banks: int):
    """The pool's parity layout as explicit (members, phys) tables: group g
    protects data banks (2g, 2g+1) behind its own physical parity port.
    Single source for the ``repro.analysis`` certificate cross-check."""
    members = [[2 * g, 2 * g + 1] for g in range(n_banks // 2)]
    return members, list(range(n_banks // 2))


# ---------------------------------------------------------------------------
# Layered pool used by the serving decode step (runtime/server.py)
# ---------------------------------------------------------------------------

def pool_init(cfg: KVBankConfig, n_layers: int, batch: int, n_kv: int,
              head_dim: int, dtype, coded: bool = True) -> PooledKV:
    u = uint_view_dtype(dtype)
    nb, pg = cfg.n_banks, cfg.page
    slots = cfg.pool_pages // nb
    ng = (nb // 2) if coded else 0
    shape = (n_layers, nb, slots, pg, n_kv, head_dim)
    pshape = (n_layers, ng, slots, pg, n_kv, head_dim)
    return PooledKV(
        k_banks=jnp.zeros(shape, u), v_banks=jnp.zeros(shape, u),
        k_par=jnp.zeros(pshape, u), v_par=jnp.zeros(pshape, u),
        parity_fresh=jnp.ones((ng, slots), bool),
        page_table=jnp.full((batch, cfg.max_pages), -1, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def pool_coded(pool: PooledKV) -> bool:
    return pool.k_par.shape[1] > 0


def pool_write_index(cfg: KVBankConfig, pool: PooledKV,
                     active: jnp.ndarray):
    """(bank, slot, in_page) targets for this step's one-token write per
    sequence; inactive (or table-exhausted) lanes get the out-of-range bank
    sink so drop-mode scatters skip them."""
    b = pool.length.shape[0]
    pos = pool.length
    lpage = pos // cfg.page
    in_page = pos % cfg.page
    phys = pool.page_table[jnp.arange(b), jnp.minimum(lpage, cfg.max_pages - 1)]
    ok = active & (lpage < cfg.max_pages) & (phys >= 0)
    bank = jnp.where(ok, phys % cfg.n_banks, cfg.n_banks)
    slot = jnp.maximum(phys // cfg.n_banks, 0)
    return bank, slot, in_page


def pool_mark_stale(cfg: KVBankConfig, pool: PooledKV, widx) -> PooledKV:
    """Code-status update for this step's writes (paper §IV-A status 01)."""
    ng = pool.parity_fresh.shape[0]
    if ng == 0:
        return pool
    bank, slot, _ = widx
    grp = jnp.where(bank < cfg.n_banks, bank // 2, ng)
    fresh = pool.parity_fresh.at[grp, slot].set(False, mode="drop")
    return pool._replace(parity_fresh=fresh)


def pool_write_layer(cfg: KVBankConfig, k_bank: jnp.ndarray,
                     v_bank: jnp.ndarray, widx, k_new: jnp.ndarray,
                     v_new: jnp.ndarray):
    """Write one token's (B, Hkv, D) K/V into ONE layer's bank arrays."""
    u = k_bank.dtype
    ku = jax.lax.bitcast_convert_type(k_new, u) if k_new.dtype != u else k_new
    vu = jax.lax.bitcast_convert_type(v_new, u) if v_new.dtype != u else v_new
    bank, slot, in_page = widx
    return (k_bank.at[bank, slot, in_page].set(ku, mode="drop"),
            v_bank.at[bank, slot, in_page].set(vu, mode="drop"))


def pool_write_layer_fused(cfg: KVBankConfig, k_bank: jnp.ndarray,
                           v_bank: jnp.ndarray, k_par: jnp.ndarray,
                           v_par: jnp.ndarray, widx, k_new: jnp.ndarray,
                           v_new: jnp.ndarray):
    """Encode-on-write: write one token's (B, Hkv, D) K/V into one layer's
    banks AND delta-maintain the pair parity in the same pass
    (``par' = par ^ old ^ new``), instead of re-reading whole banks at
    recode time (the fused ReCoding datapath, docs/kernels.md).

    The parity scatter runs in two passes split by bank parity: within one
    pass, two lanes hitting the same parity element would need the same
    (bank, slot, in_page) — i.e. the same physical page element, which
    distinct sequences never share — so plain set-scatters cannot collide.
    Across passes (pair siblings touching one parity element) the second
    pass re-reads the parity the first wrote."""
    u = k_bank.dtype
    ku = jax.lax.bitcast_convert_type(k_new, u) if k_new.dtype != u else k_new
    vu = jax.lax.bitcast_convert_type(v_new, u) if v_new.dtype != u else v_new
    bank, slot, in_page = widx
    nb = cfg.n_banks
    ng = k_par.shape[0]
    bc = jnp.minimum(bank, nb - 1)
    dk = k_bank[bc, slot, in_page] ^ ku             # (B, Hkv, D) bit delta
    dv = v_bank[bc, slot, in_page] ^ vu
    k_out = k_bank.at[bank, slot, in_page].set(ku, mode="drop")
    v_out = v_bank.at[bank, slot, in_page].set(vu, mode="drop")
    grp = bank // 2
    for phase in (0, 1):
        sel = (bank < nb) & (bank % 2 == phase)
        gi = jnp.where(sel, grp, ng)                # sink for the other phase
        gc = jnp.minimum(gi, ng - 1)
        k_par = k_par.at[gi, slot, in_page].set(
            k_par[gc, slot, in_page] ^ dk, mode="drop")
        v_par = v_par.at[gi, slot, in_page].set(
            v_par[gc, slot, in_page] ^ dv, mode="drop")
    return k_out, v_out, k_par, v_par


def pool_plan(cfg: KVBankConfig, pool: PooledKV,
              length: Optional[jnp.ndarray] = None) -> ReadPlan:
    """Shared read plan for every layer of a pooled decode step."""
    fresh = pool.parity_fresh if pool.parity_fresh.shape[0] > 0 else None
    return _plan_from_tables(cfg, pool.page_table,
                             pool.length if length is None else length, fresh)


def pool_install(cfg: KVBankConfig, pool: PooledKV, slot_i: jnp.ndarray,
                 k_seq: jnp.ndarray, v_seq: jnp.ndarray,
                 fuse_encode: bool = False) -> PooledKV:
    """Install a prefilled prompt's (L, T, Hkv, D) K/V into sequence slot
    ``slot_i`` whose page-table row was assigned host-side. Sets the slot
    length to T and marks every touched parity row stale.

    ``fuse_encode=True`` additionally delta-maintains the pair parity for
    every written token (encode-on-write; same two-pass collision-free
    scatter as ``pool_write_layer_fused`` — within a pass, one parity
    element maps to one (phys page, in_page) element, hence one token).
    The status table still evolves identically (touched rows marked stale),
    so plans — and serving output — match the unfused path bit-for-bit."""
    u = pool.k_banks.dtype
    ku = jax.lax.bitcast_convert_type(k_seq, u) if k_seq.dtype != u else k_seq
    vu = jax.lax.bitcast_convert_type(v_seq, u) if v_seq.dtype != u else v_seq
    t = k_seq.shape[1]
    j = jnp.arange(t)
    phys = pool.page_table[slot_i, j // cfg.page]   # (T,)
    bank = jnp.where(phys >= 0, phys % cfg.n_banks, cfg.n_banks)
    slot = jnp.maximum(phys // cfg.n_banks, 0)
    in_page = j % cfg.page
    ng = pool.parity_fresh.shape[0]
    k_par, v_par = pool.k_par, pool.v_par
    # host bool flag: compile-time path select  # analysis: tracer-branch
    if fuse_encode and ng > 0:
        bc = jnp.minimum(bank, cfg.n_banks - 1)
        dk = pool.k_banks[:, bc, slot, in_page] ^ ku    # (L, T, Hkv, D)
        dv = pool.v_banks[:, bc, slot, in_page] ^ vu
        grp = bank // 2
        for phase in (0, 1):
            sel = (bank < cfg.n_banks) & (bank % 2 == phase)
            gi = jnp.where(sel, grp, ng)
            gc = jnp.minimum(gi, ng - 1)
            k_par = k_par.at[:, gi, slot, in_page].set(
                k_par[:, gc, slot, in_page] ^ dk, mode="drop")
            v_par = v_par.at[:, gi, slot, in_page].set(
                v_par[:, gc, slot, in_page] ^ dv, mode="drop")
    k_banks = pool.k_banks.at[:, bank, slot, in_page].set(ku, mode="drop")
    v_banks = pool.v_banks.at[:, bank, slot, in_page].set(vu, mode="drop")
    out = pool._replace(k_banks=k_banks, v_banks=v_banks,
                        k_par=k_par, v_par=v_par,
                        length=pool.length.at[slot_i].set(t))
    if ng == 0:
        return out
    grp = jnp.where(bank < cfg.n_banks, bank // 2, ng)
    fresh = pool.parity_fresh.at[grp, slot].set(False, mode="drop")
    return out._replace(parity_fresh=fresh)


def pool_recode(cfg: KVBankConfig, pool: PooledKV,
                budget: Optional[int] = None):
    """ReCoding over the shared status table — all layers of a stale row
    refresh together. Returns ``(pool, n_recoded)``; ``budget < 0`` disables
    recoding entirely, ``None`` refreshes everything."""
    ng = pool.k_par.shape[1]
    # `budget` is a host int by contract (compile-time)  # analysis: tracer-branch
    if ng == 0 or (budget is not None and budget < 0):
        return pool, jnp.int32(0)
    stale = ~pool.parity_fresh
    if budget is None:
        n = jnp.sum(stale.astype(jnp.int32))
        return pool._replace(
            k_par=pool.k_banks[:, 0::2] ^ pool.k_banks[:, 1::2],
            v_par=pool.v_banks[:, 0::2] ^ pool.v_banks[:, 1::2],
            parity_fresh=jnp.ones_like(pool.parity_fresh)), n
    take, idx, valid = _budget_rows(pool.parity_fresh, budget)
    n = jnp.sum(take.astype(jnp.int32))
    # `budget` is a host int by contract (compile-time)  # analysis: tracer-branch
    if idx.shape[0] == 0:
        return pool, n
    slots = pool.parity_fresh.shape[1]
    g, s = idx // slots, idx % slots
    new_k = pool.k_banks[:, 2 * g, s] ^ pool.k_banks[:, 2 * g + 1, s]
    new_v = pool.v_banks[:, 2 * g, s] ^ pool.v_banks[:, 2 * g + 1, s]
    sidx = jnp.where(valid, idx, ng * slots)
    lead = pool.k_par.shape[:1]
    tail = pool.k_par.shape[3:]
    k_par = pool.k_par.reshape(lead + (ng * slots,) + tail).at[:, sidx].set(
        new_k, mode="drop").reshape(pool.k_par.shape)
    v_par = pool.v_par.reshape(lead + (ng * slots,) + tail).at[:, sidx].set(
        new_v, mode="drop").reshape(pool.v_par.shape)
    return pool._replace(k_par=k_par, v_par=v_par,
                         parity_fresh=pool.parity_fresh | take), n


def pool_permute(cfg: KVBankConfig, pool: PooledKV,
                 perm: jnp.ndarray) -> PooledKV:
    """Relocate physical pages: page p moves to physical id ``perm[p]``
    (churned free-list placement, or a defrag/migration pass). Page tables
    are remapped and parity fully rebuilt, so decode output is invariant."""

    def move(banks):
        lead = banks.shape[:1]
        x = jnp.moveaxis(banks, 1, 2)               # (L, slots, NB, ...)
        flat = x.reshape(lead + (-1,) + x.shape[3:])  # phys p at slot*NB+bank
        y = jnp.zeros_like(flat).at[:, perm].set(flat)
        y = y.reshape(x.shape)
        return jnp.moveaxis(y, 2, 1)

    pt = jnp.where(pool.page_table >= 0,
                   perm[jnp.maximum(pool.page_table, 0)], -1).astype(jnp.int32)
    out = pool._replace(k_banks=move(pool.k_banks), v_banks=move(pool.v_banks),
                        page_table=pt)
    if pool.parity_fresh.shape[0] == 0:
        return out
    out, _ = pool_recode(cfg, out, budget=None)
    return out
