"""Fault-tolerant training runtime.

Production-shaped loop over the canonical ``train_step``:

  * **Sharded end-to-end** — params/opt/batch placed via the launch-layer
    rules; the step is jit-compiled once with explicit in/out shardings and
    donated state.
  * **Checkpoint/restart** — async sharded checkpoints every
    ``ckpt_every``; on crash (or injected fault) the loop restores the last
    committed step and replays — the data pipeline is a pure function of the
    step index, so restart is bit-deterministic.
  * **Straggler mitigation** — per-step wall time is tracked with an EMA
    watermark; steps slower than ``straggler_factor``× the watermark are
    logged as straggler events with the slow host (in a real multi-host job
    this feeds the controller's replace-node decision; here it is surfaced
    as a metric and exercised by fault-injection tests).
  * **Elastic re-mesh** — ``restore`` re-places leaves with the current
    mesh's shardings, so resuming on a different device count works (tested
    1 ↔ 2×2 debug meshes in tests/test_runtime.py).
  * **Fault injection** — ``FaultPlan`` raises synthetic failures at chosen
    steps to exercise the recovery path deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.axes import use_mesh
from repro.checkpoint.checkpoint import CheckpointManager, latest_step, restore
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, Prefetcher, TokenStream
from repro.launch import sharding as shd
from repro.models import lm
from repro.optim.adamw import OptConfig, abstract_opt, adamw_init
from repro.runtime import steps as steps_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 256
    n_micro: int = 1
    q_chunk: int = 0
    remat: bool = True
    unroll: int = 1
    straggler_factor: float = 3.0
    ema: float = 0.9


class FaultPlan:
    """Deterministic synthetic failures: raise at the given steps, once each."""

    def __init__(self, fail_at: List[int]):
        self.pending = set(fail_at)

    def check(self, step: int):
        if step in self.pending:
            self.pending.discard(step)
            raise RuntimeError(f"injected fault at step {step}")


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig, mesh,
                 opt_cfg: Optional[OptConfig] = None):
        self.cfg, self.tc, self.mesh = cfg, tc, mesh
        self.opt_cfg = opt_cfg or OptConfig(total_steps=tc.steps)
        self.data_cfg = DataConfig(vocab=cfg.vocab, batch=tc.global_batch,
                                   seq_len=tc.seq_len, seed=tc.seed)
        self.stream = TokenStream(self.data_cfg)
        self.prefetcher = Prefetcher(self.stream)
        self.ckpt = CheckpointManager(tc.ckpt_dir, keep=tc.keep)
        self.metrics_log: List[Dict[str, float]] = []
        self.events: List[str] = []
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg, tc = self.cfg, self.tc
        abstract = lm.abstract_params(cfg, max_seq=tc.seq_len)
        self.p_sh = shd.param_shardings(cfg, abstract, self.mesh)
        self.o_sh = shd.opt_shardings(self.p_sh, self.mesh)
        step_fn = steps_mod.make_train_step(
            cfg, self.opt_cfg, unroll=tc.unroll, remat=tc.remat,
            q_chunk=tc.q_chunk, n_micro=tc.n_micro)

        def jit_step():
            return jax.jit(step_fn,
                           in_shardings=(self.p_sh, self.o_sh, None),
                           out_shardings=(self.p_sh, self.o_sh, None),
                           donate_argnums=(0, 1))

        self.train_step = jit_step()

    def _init_state(self):
        with use_mesh(self.mesh):
            params = jax.jit(
                lambda k: lm.init_params(self.cfg, k, max_seq=self.tc.seq_len),
                out_shardings=self.p_sh,
            )(jax.random.key(self.tc.seed))
            opt = jax.jit(adamw_init, out_shardings=self.o_sh)(params)
        return params, opt

    def _restore_or_init(self):
        step = latest_step(self.tc.ckpt_dir)
        if step is None:
            params, opt = self._init_state()
            return 0, params, opt
        abstract = lm.abstract_params(self.cfg, max_seq=self.tc.seq_len)
        like = {"params": abstract, "opt": abstract_opt(abstract)}
        shards = {"params": self.p_sh, "opt": self.o_sh}
        state = restore(self.tc.ckpt_dir, like, step=step, shardings=shards)
        self.events.append(f"restored step {step}")
        return step, state["params"], state["opt"]

    # ------------------------------------------------------------------
    def run(self, fault_plan: Optional[FaultPlan] = None,
            max_restarts: int = 3) -> Dict[str, Any]:
        restarts = 0
        while True:
            try:
                return self._run_once(fault_plan)
            except RuntimeError as e:
                if "injected fault" not in str(e) or restarts >= max_restarts:
                    raise
                restarts += 1
                self.events.append(f"recovering ({e})")
                self.prefetcher.stop()

    def _run_once(self, fault_plan: Optional[FaultPlan]) -> Dict[str, Any]:
        tc = self.tc
        start, params, opt = self._restore_or_init()
        ema_t: Optional[float] = None
        stragglers = 0
        with use_mesh(self.mesh):
            for step in range(start, tc.steps):
                if fault_plan:
                    fault_plan.check(step)
                batch = self.prefetcher.get(step)
                batch = {k: jax.device_put(np.asarray(v)) for k, v in batch.items()}
                t0 = time.perf_counter()
                params, opt, metrics = self.train_step(params, opt, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                if step == start:
                    # first step includes jit compile — never seeds the
                    # straggler watermark
                    dt_for_ema = None
                else:
                    dt_for_ema = dt
                if ema_t is not None and dt > tc.straggler_factor * ema_t:
                    stragglers += 1
                    self.events.append(
                        f"straggler step={step} dt={dt:.3f}s ema={ema_t:.3f}s")
                if dt_for_ema is not None:
                    ema_t = (dt_for_ema if ema_t is None
                             else tc.ema * ema_t + (1 - tc.ema) * dt_for_ema)
                metrics.update(step=step, wall_s=dt)
                self.metrics_log.append(metrics)
                if step % tc.log_every == 0:
                    print(f"[train] step={step:5d} loss={metrics['loss']:.4f} "
                          f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.0f}ms")
                if tc.ckpt_every and (step + 1) % tc.ckpt_every == 0:
                    self.ckpt.save_async(step + 1, {"params": params, "opt": opt})
        self.ckpt.wait()
        self.prefetcher.stop()
        return {
            "params": params, "opt": opt,
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "stragglers": stragglers,
            "events": list(self.events),
        }
