"""Checkpointing substrate (orbax-free, dependency-light).

Layout per step::

    <dir>/step_000123/
        manifest.json        # treedef, leaf paths, shapes, dtypes, step
        host_000.npz         # this host's leaf shards (full leaves when 1 host)
    <dir>/step_000123.tmp... # staging dir, atomically renamed on commit

Properties required at scale and how they are provided here:

  * **Atomicity** — writes go to ``step_k.tmp``; ``os.rename`` to the final
    name is the commit point, so a killed writer never leaves a readable
    half-checkpoint. ``latest_step`` only considers committed dirs.
  * **Async** — ``CheckpointManager.save_async`` snapshots leaves to host
    memory (jax.device_get) synchronously — cheap — then writes in a
    background thread so the train loop is not blocked on disk.
  * **Re-mesh on restore** — ``restore(..., shardings=...)`` places every
    leaf with the *target* sharding via ``jax.device_put``, so a checkpoint
    written on one mesh restores onto another (elastic resume).
  * **Self-describing** — manifest stores the flattened key paths, so a
    checkpoint can be inspected/migrated without the model code.
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")

_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16", "int8",
           "uint64", "uint32", "uint16", "uint8", "bool"}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    if str(arr.dtype) in _NATIVE:
        return arr
    return arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(arr.dtype) == dtype_name:
        return arr
    import ml_dtypes  # noqa: F401 — registers bfloat16/fp8 numpy dtypes
    return arr.view(np.dtype(dtype_name))


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return "/".join(out)


def save(step: int, tree: Any, directory: str, host_id: int = 0) -> str:
    """Blocking save. Returns the committed directory path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + f".tmp{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = [_path_str(p) for p, _ in flat]
    leaves = [np.asarray(jax.device_get(v)) for _, v in flat]
    dtypes = [str(l.dtype) for l in leaves]
    # npz round-trips non-native dtypes (bfloat16, fp8) as opaque void —
    # store them as raw uint views; the manifest keeps the logical dtype.
    stored = [_to_storable(l) for l in leaves]
    arrays = {f"leaf_{i:05d}": l for i, l in enumerate(stored)}
    np.savez(os.path.join(tmp, f"host_{host_id:03d}.npz"), **arrays)
    manifest = {
        "step": step,
        "names": names,
        "shapes": [list(l.shape) for l in leaves],
        "dtypes": dtypes,
        "n_hosts": 1,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):  # idempotent re-save
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)      # commit point
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of Sharding (or None
    leaves) — leaves are device_put with the target sharding (re-mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "host_000.npz")) as z:
        leaves = [_from_storable(z[f"leaf_{i:05d}"], dt)
                  for i, dt in enumerate(manifest["dtypes"])]

    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree.structure(like)
    names_like = [_path_str(p) for p, _ in flat_like]
    by_name = dict(zip(manifest["names"], leaves))
    missing = [n for n in names_like if n not in by_name]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    ordered = [by_name[n] for n in names_like]

    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(ordered))
    out = []
    for arr, (path, proto), sh in zip(ordered, flat_like, shard_leaves):
        want = np.dtype(getattr(proto, "dtype", arr.dtype))
        if arr.dtype != want:
            arr = np.asarray(jax.numpy.asarray(arr).astype(want))
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async writer + retention. One in-flight save at a time (the next save
    joins the previous thread first — bounded memory)."""

    def __init__(self, directory: str, keep: int = 3, host_id: int = 0):
        self.directory = directory
        self.keep = keep
        self.host_id = host_id
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save_async(self, step: int, tree: Any):
        self.wait()
        # snapshot to host memory synchronously (consistent view)
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        treedef = jax.tree.structure(tree)
        host_leaves = [np.asarray(jax.device_get(v)) for _, v in flat]
        snap = jax.tree.unflatten(treedef, host_leaves)

        def work():
            save(step, snap, self.directory, self.host_id)
            self._gc()
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (_STEP_RE.match(d) for d in os.listdir(self.directory)) if m
        )
        import shutil
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
