"""Sharded, atomic, async checkpointing with restore-time re-mesh."""
from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    latest_step,
    restore,
    save,
)
