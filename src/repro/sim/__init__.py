"""Trace-driven evaluation substrate (the paper's gem5 + Ramulator stage).

``trace`` generates synthetic multi-core memory traces with the access-
pattern structure the paper observes in PARSEC (persistent sequential bands,
Fig 15) and its two augmentations (split bands, Fig 16; linear ramp, Fig 17).
``ramulator`` drives ``repro.core.CodedMemorySystem`` over a trace and
compares coded schemes against the uncoded baseline.
"""
from repro.sim.trace import (  # noqa: F401
    TraceSpec,
    banded_trace,
    ramp_trace,
    split_band_trace,
    uniform_trace,
    zipf_trace,
)
from repro.sim.ramulator import compare_schemes, simulate, sweep_alpha  # noqa: F401
