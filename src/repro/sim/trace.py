"""Synthetic multi-core memory traces with PARSEC-like structure (§V-A).

The paper generates traces from PARSEC via gem5 and observes that the
benchmarks' accesses "occupy consistent bands of sequential memory
addresses" (Fig 15). We parameterize exactly that structure:

  * ``banded_trace``     — dedup-like: a few persistent address bands; each
                           core walks a band sequentially with noise.
  * ``split_band_trace`` — Fig 16 augmentation: the bands are split into many
                           narrower bands.
  * ``ramp_trace``       — Fig 17 augmentation: band centers drift linearly
                           over time.
  * ``uniform_trace``    — unstructured worst case (§III worst-case analysis).
  * ``zipf_trace``       — hot-row skew (the TPU coded-lookup workload).

Addresses are linear; ``bank = addr % n_banks``, ``row = (addr // n_banks)
% n_rows`` (DRAM low-bit interleaving). Bands are contiguous in address
space, hence contiguous in *row* space — which is what makes the dynamic
coding unit's region selection meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.system import Trace


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    n_cores: int = 8
    length: int = 512          # requests per core (incl. idle gaps)
    n_banks: int = 8
    n_rows: int = 512          # rows per bank
    issue_prob: float = 1.0    # request density (parallel-region PARSEC)
    write_frac: float = 0.3
    seed: int = 0


def addr_to_bank_row(addr: np.ndarray, n_banks: int, n_rows: int):
    """DRAM low-bit interleaving: consecutive linear addresses round-robin
    the banks, ``bank = addr % n_banks``, ``row = (addr // n_banks) %
    n_rows``. The single mapping shared by the synthetic generators here and
    external-trace ingestion (``repro.traces.formats``)."""
    bank = (addr % n_banks).astype(np.int32)
    row = ((addr // n_banks) % n_rows).astype(np.int32)
    return bank, row


def _pack(spec: TraceSpec, addr: np.ndarray, rng: np.random.Generator) -> Trace:
    """addr (n_cores, T) linear addresses (−1 = idle) → Trace pytree."""
    valid = (addr >= 0) & (rng.random(addr.shape) < spec.issue_prob)
    addr = np.maximum(addr, 0)
    bank, row = addr_to_bank_row(addr, spec.n_banks, spec.n_rows)
    is_write = rng.random(addr.shape) < spec.write_frac
    data = rng.integers(1, 1 << 30, addr.shape).astype(np.int32)
    return Trace(
        bank=jnp.asarray(bank),
        row=jnp.asarray(row),
        is_write=jnp.asarray(is_write & valid),
        data=jnp.asarray(data),
        valid=jnp.asarray(valid),
    )


def _band_walk(
    spec: TraceSpec,
    centers: np.ndarray,        # (n_bands,) band centers in address space
    width: int,
    rng: np.random.Generator,
    drift_per_cycle: float = 0.0,
    band_weights: Optional[np.ndarray] = None,
    strides: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """Each core walks inside one (weighted-random) band with its stride.

    Stride 1 = sequential scan (row-major, round-robins the banks);
    stride ``n_banks`` = column-major walk, hammering a single bank — the
    bank-conflict pattern multi-port memory exists for. The default core mix
    is half sequential, a quarter stride-2, a quarter column walkers.
    """
    n_bands = len(centers)
    space = spec.n_banks * spec.n_rows
    if band_weights is None:
        band_weights = np.ones(n_bands) / n_bands
    if strides is None:
        base = [1, 1, 1, 1, 2, 2, spec.n_banks, spec.n_banks]
        strides = [base[c % len(base)] for c in range(spec.n_cores)]
    addr = np.full((spec.n_cores, spec.length), -1, np.int64)
    for c in range(spec.n_cores):
        stride = int(strides[c])
        band = rng.choice(n_bands, p=band_weights)
        pos = int(centers[band] - width // 2 + rng.integers(0, max(width, 1)))
        for t in range(spec.length):
            # occasional band switch / random jump (locality noise)
            u = rng.random()
            if u < 0.02:
                band = rng.choice(n_bands, p=band_weights)
                pos = int(centers[band] - width // 2 + rng.integers(0, max(width, 1)))
            elif u < 0.05:
                pos += int(rng.integers(-8, 9))
            center = centers[band] + drift_per_cycle * t
            lo = int(center - width // 2)
            hi = lo + max(width, 1)
            if pos < lo or pos >= hi:
                pos = lo + (pos - lo) % max(width, 1)
            addr[c, t] = pos % space
            pos += stride
    return addr


def banded_trace(spec: TraceSpec, n_bands: int = 2, band_width: Optional[int] = None) -> Trace:
    """Dedup-like (Fig 15): a few persistent hot bands of sequential addrs.

    Bands are NARROW (~3% of the address space each, as in the paper's
    Fig 15 plots) — narrow enough that a small dynamic-coding budget
    (α=0.1, r=0.05 ⇒ 10% of rows codable) covers the primary bands."""
    rng = np.random.default_rng(spec.seed)
    space = spec.n_banks * spec.n_rows
    if band_width is None:
        band_width = max(space // 32, spec.n_banks * 4)
    centers = (np.arange(n_bands) + 0.5) * (space / n_bands)
    # two dominant bands (the paper's dedup/vips show 2 primary bands)
    w = np.ones(n_bands)
    w[: min(2, n_bands)] = 4.0
    w /= w.sum()
    addr = _band_walk(spec, centers.astype(np.int64), band_width, rng, 0.0, w)
    return _pack(spec, addr, rng)


def split_band_trace(spec: TraceSpec, n_bands: int = 8) -> Trace:
    """Fig 16: the primary bands split into many narrower bands."""
    rng = np.random.default_rng(spec.seed)
    space = spec.n_banks * spec.n_rows
    band_width = max(space // (4 * n_bands), spec.n_banks)
    centers = ((np.arange(n_bands) + 0.5) * (space / n_bands)).astype(np.int64)
    addr = _band_walk(spec, centers, band_width, rng)
    return _pack(spec, addr, rng)


def ramp_trace(spec: TraceSpec, n_bands: int = 2, drift_total: Optional[float] = None) -> Trace:
    """Fig 17: band centers ramp linearly across the address space."""
    rng = np.random.default_rng(spec.seed)
    space = spec.n_banks * spec.n_rows
    band_width = max(space // 16, spec.n_banks * 4)
    centers = ((np.arange(n_bands) + 0.5) * (space / n_bands)).astype(np.int64)
    if drift_total is None:
        drift_total = space / 2  # crosses half the address space over the trace
    drift = drift_total / max(spec.length, 1)
    addr = _band_walk(spec, centers, band_width, rng, drift_per_cycle=drift)
    return _pack(spec, addr, rng)


def uniform_trace(spec: TraceSpec) -> Trace:
    """Unstructured random accesses (the schemes' worst case, §III-B)."""
    rng = np.random.default_rng(spec.seed)
    space = spec.n_banks * spec.n_rows
    addr = rng.integers(0, space, (spec.n_cores, spec.length)).astype(np.int64)
    return _pack(spec, addr, rng)


def zipf_trace(spec: TraceSpec, a: float = 1.2, hot_banks: Sequence[int] = (0, 1)) -> Trace:
    """Zipf-skewed rows concentrated on a subset of banks (lookup workload)."""
    rng = np.random.default_rng(spec.seed)
    rows = np.minimum(rng.zipf(a, (spec.n_cores, spec.length)) - 1, spec.n_rows - 1)
    banks = rng.choice(np.asarray(hot_banks), (spec.n_cores, spec.length))
    addr = rows * spec.n_banks + banks
    return _pack(spec, addr.astype(np.int64), rng)


TRACES = {
    "banded": banded_trace,
    "split": split_band_trace,
    "ramp": ramp_trace,
    "uniform": uniform_trace,
    "zipf": zipf_trace,
}
