"""Trace-driven evaluation driver (the paper's modified-Ramulator stage, §V-B).

``simulate`` runs one (scheme, α, r) configuration over a trace and returns a
``SimResult`` — the looped per-point reference path. ``compare_schemes`` and
``sweep_alpha`` reproduce the paper's figure axes (CPU cycles and
dynamic-coding region switches vs α, per scheme, against the uncoded
baseline) and are thin wrappers over the batched ``repro.sweep`` engine:
points sharing a static shape run as one compiled program.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

from repro.core.codes import get_tables
from repro.core.state import make_params, make_tunables
from repro.core.system import CodedMemorySystem, SimResult, Trace, drain_bound


def default_n_cycles(trace: Trace) -> int:
    """Cycle budget for a materialized trace — a thin shape adapter over
    ``repro.core.system.drain_bound``, the single home of the bound's
    formula and derivation (chunked replay derives its per-chunk budget
    from the same helper via ``repro.traces.stream.chunk_bound``)."""
    return drain_bound(int(trace.bank.shape[0]), int(trace.bank.shape[1]))


def simulate(
    scheme: str,
    trace: Trace,
    n_rows: int,
    alpha: float = 1.0,
    r: float = 0.05,
    n_data: int = 8,
    n_cycles: Optional[int] = None,
    select_period: int = 256,
    wq_hi: int = 8,
    wq_lo: int = 2,
    **kw,
) -> SimResult:
    """Looped reference path: one fresh compile + scan per configuration.

    ``repro.sweep.engine`` is the batched production path; this stays as the
    per-point reference the engine is validated against (bit-identical
    results, see tests/test_sweep.py).
    """
    tables = get_tables(scheme, n_data=n_data)
    p = make_params(tables, n_rows=n_rows, alpha=alpha, r=r, **kw)
    tn = make_tunables(queue_depth=p.queue_depth, select_period=select_period,
                       wq_hi=wq_hi, wq_lo=wq_lo)
    sys = CodedMemorySystem(tables, p, n_cores=trace.bank.shape[0], tunables=tn)
    if n_cycles is None:
        n_cycles = default_n_cycles(trace)
    return sys.run(trace, n_cycles)


def sweep_point(
    scheme: str,
    trace: Trace,
    n_rows: int,
    alpha: float = 1.0,
    r: float = 0.05,
    n_data: int = 8,
    n_cycles: Optional[int] = None,
    select_period: int = 256,
    wq_hi: int = 8,
    wq_lo: int = 2,
    **kw,
):
    """Map ``simulate``-style kwargs + a materialized trace to a SweepPoint.

    ``**kw`` forwards the remaining ``make_params`` knobs (queue_depth,
    coalesce, recode_cap, max_syms, encode_rows_per_cycle, recode_budget),
    which are all SweepPoint fields.
    """
    from repro.sweep.grid import SweepPoint
    n_cores, length = (int(d) for d in trace.bank.shape)
    return SweepPoint(
        scheme=scheme, n_rows=n_rows, alpha=alpha, r=r, n_data=n_data,
        n_cores=n_cores, length=length,
        n_cycles=n_cycles if n_cycles is not None else default_n_cycles(trace),
        trace="custom", select_period=select_period, wq_hi=wq_hi, wq_lo=wq_lo,
        **kw,
    )


def compare_schemes(
    trace: Trace,
    n_rows: int,
    alpha: float = 1.0,
    r: float = 0.05,
    schemes: Iterable[str] = ("uncoded", "scheme_i", "scheme_ii", "scheme_iii"),
    **kw,
) -> Dict[str, SimResult]:
    from repro.sweep.engine import run_points
    schemes = list(schemes)
    pts = [sweep_point(s, trace, n_rows, alpha=alpha, r=r, **kw)
           for s in schemes]
    return dict(zip(schemes, run_points(pts, traces=[trace] * len(pts))))


def sweep_alpha(
    scheme: str,
    trace: Trace,
    n_rows: int,
    alphas: Iterable[float] = (0.05, 0.1, 0.25, 0.5, 1.0),
    r: float = 0.05,
    **kw,
) -> Dict[float, SimResult]:
    from repro.sweep.engine import run_points
    alphas = list(alphas)
    pts = [sweep_point(scheme, trace, n_rows, alpha=a, r=r, **kw)
           for a in alphas]
    return dict(zip(alphas, run_points(pts, traces=[trace] * len(pts))))


def cycle_reduction(baseline: SimResult, coded: SimResult) -> float:
    """Fractional CPU-cycle reduction vs the uncoded baseline (Fig 18 axis)."""
    return 1.0 - coded.cycles / max(baseline.cycles, 1)
