"""Trace-driven evaluation driver (the paper's modified-Ramulator stage, §V-B).

``simulate`` runs one (scheme, α, r) configuration over a trace and returns a
``SimResult``; ``compare_schemes``/``sweep_alpha`` reproduce the paper's
figure axes (CPU cycles and dynamic-coding region switches vs α, per scheme,
against the uncoded baseline with identical queues/arbitration).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

from repro.core.codes import get_tables
from repro.core.state import make_params
from repro.core.system import CodedMemorySystem, SimResult, Trace


def simulate(
    scheme: str,
    trace: Trace,
    n_rows: int,
    alpha: float = 1.0,
    r: float = 0.05,
    n_data: int = 8,
    n_cycles: Optional[int] = None,
    select_period: int = 256,
    **kw,
) -> SimResult:
    tables = get_tables(scheme, n_data=n_data)
    p = make_params(tables, n_rows=n_rows, alpha=alpha, r=r,
                    select_period=select_period, **kw)
    sys = CodedMemorySystem(tables, p, n_cores=trace.bank.shape[0])
    if n_cycles is None:
        # generous drain bound: every request could serialize on one port
        n_cycles = int(trace.bank.shape[0] * trace.bank.shape[1] * 1.5) + 64
    return sys.run(trace, n_cycles)


def compare_schemes(
    trace: Trace,
    n_rows: int,
    alpha: float = 1.0,
    r: float = 0.05,
    schemes: Iterable[str] = ("uncoded", "scheme_i", "scheme_ii", "scheme_iii"),
    **kw,
) -> Dict[str, SimResult]:
    return {s: simulate(s, trace, n_rows, alpha=alpha, r=r, **kw) for s in schemes}


def sweep_alpha(
    scheme: str,
    trace: Trace,
    n_rows: int,
    alphas: Iterable[float] = (0.05, 0.1, 0.25, 0.5, 1.0),
    r: float = 0.05,
    **kw,
) -> Dict[float, SimResult]:
    return {a: simulate(scheme, trace, n_rows, alpha=a, r=r, **kw) for a in alphas}


def cycle_reduction(baseline: SimResult, coded: SimResult) -> float:
    """Fractional CPU-cycle reduction vs the uncoded baseline (Fig 18 axis)."""
    return 1.0 - coded.cycles / max(baseline.cycles, 1)
