"""Static invariant verification for the coded-memory reproduction.

Three layers, one CLI (``python -m repro.analysis``), one CI gate:

* ``repro.analysis.schemes`` — GF(2) proofs over every coding scheme in
  ``repro.core.codes``: erasure tolerance, per-row read degree (disjoint
  recovery sets), locality, parity-stride alias freedom, and the signed
  certificate (``certificates.json``) the test suite consumes.
* ``repro.analysis.jaxpr``   — abstract-eval lint of the compiled
  programs: compile-key completeness per ``static_signature`` class,
  scan-carry structural stability, flag-off jaxpr identity.
* ``repro.analysis.rules``   — AST lint of repo conventions: oracle
  purity, tracer-safe branching, active-geometry indexing, wide-counter
  accumulation, bench-manifest contracts.

``repro.analysis.guard`` is the runtime complement: a ``recompile_guard``
context manager asserting a code region compiled nothing new.

See docs/analysis.md for what each layer proves and how to extend it.
"""
from repro.analysis.base import Finding, format_findings
from repro.analysis.guard import (GuardRecord, RecompileError, available,
                                  cache_size, recompile_guard)

__all__ = [
    "Finding", "format_findings",
    "GuardRecord", "RecompileError", "available", "cache_size",
    "recompile_guard",
]
