"""CLI: ``python -m repro.analysis [--strict] [--layers ...]``.

Runs the three analysis layers and prints findings one per line
(``[rule] location: message``). Exit status is 0 when clean; with
``--strict`` any finding exits 1 — that is the CI gate.

``--write-certificates`` regenerates ``certificates.json`` from the live
scheme tables (required after any deliberate change to
``repro.core.codes``; the schemes layer fails while the checked-in
certificate disagrees with the code).

The jaxpr layer traces real programs (abstract eval only, no device
execution) and takes ~1–2 minutes; ``--layers schemes rules`` gives the
sub-second source-only subset (what the pre-commit hook runs).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

from repro.analysis.base import Finding, format_findings

LAYERS = ("schemes", "jaxpr", "rules")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant verification (see docs/analysis.md)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any finding (the CI gate)")
    ap.add_argument("--layers", nargs="+", choices=LAYERS, default=None,
                    help="subset of layers to run (default: all)")
    ap.add_argument("--write-certificates", action="store_true",
                    help="regenerate repro/analysis/certificates.json from "
                         "the live scheme tables, then verify")
    args = ap.parse_args(argv)

    if args.write_certificates:
        from repro.analysis import schemes
        doc = schemes.write_certificates()
        print(f"wrote {schemes.CERT_PATH} "
              f"({len(doc['schemes'])} schemes, k<={doc['max_k']})")

    layers = args.layers or list(LAYERS)
    findings: List[Finding] = []
    for layer in layers:
        t0 = time.time()
        if layer == "schemes":
            from repro.analysis import schemes as mod
        elif layer == "jaxpr":
            from repro.analysis import jaxpr as mod      # type: ignore
        else:
            from repro.analysis import rules as mod      # type: ignore
        got = mod.run(strict=args.strict)
        findings.extend(got)
        print(f"-- {layer}: {len(got)} finding(s) "
              f"[{time.time() - t0:.1f}s]", file=sys.stderr)

    if findings:
        print(format_findings(findings))
    else:
        print(f"analysis clean ({', '.join(layers)})")
    return 1 if (args.strict and findings) else 0


if __name__ == "__main__":
    sys.exit(main())
