"""Runtime recompile guard: assert a code region compiled nothing new.

The static side of the compile-key story lives in
``repro.analysis.jaxpr`` (jaxpr-hash equality across a signature class);
this module is the runtime complement — a context manager that watches the
jit caches of the repo's long-lived compiled entry points and fails if a
region of code triggered more compilations than it budgeted for:

    with recompile_guard("sweep") as g:
        engine.run_points(grid(base, r=(0.05, 0.1, 0.2), seed=range(4)))
    assert g.compiles() == 1          # ONE program for the whole grid

    with recompile_guard("kernels.xor_encode", max_compiles=1):
        for seed in range(8):         # same shapes: first call compiles,
            encode_parities(...)      # the rest must hit the cache

Budgets are *upper bounds* checked at context exit (``max_compiles=None``
disables the check and just records); exact-count assertions use
``g.compiles()``. Relies on jit's ``_cache_size()`` introspection — when a
jax version drops it, ``available()`` turns False and the tests using the
guard skip rather than fail (the conftest fixtures do this).

Guarded entry points are *named* so tests don't import engine internals;
``GUARDED`` maps a stable name to a lazy import of the jitted callable.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, List, Optional, Tuple, Union


def _sweep_scan():
    from repro.sweep import engine
    return engine._scan_batch


def _stream_chunk():
    from repro.traces import stream
    return stream._run_chunk_batch


def _k_xor_encode():
    from repro.kernels.xor_encode import kernel
    return kernel.encode_parities_pallas


def _k_xor_gather():
    from repro.kernels.xor_gather import kernel
    return kernel.gather_decode_pallas


def _k_kv_decode():
    from repro.kernels.coded_kv_decode import kernel
    return kernel.coded_kv_decode_pallas


def _k_pool_gather():
    from repro.kernels.coded_kv_decode import kernel
    return kernel.gather_pool_pallas


GUARDED: Dict[str, Callable[[], Callable]] = {
    "sweep": _sweep_scan,
    "stream": _stream_chunk,
    "kernels.xor_encode": _k_xor_encode,
    "kernels.xor_gather": _k_xor_gather,
    "kernels.coded_kv_decode": _k_kv_decode,
    "kernels.pool_gather": _k_pool_gather,
}


def resolve(target: Union[str, Callable]) -> Callable:
    if callable(target):
        return target
    try:
        return GUARDED[target]()
    except KeyError:
        raise KeyError(f"unknown guarded entry point {target!r}; "
                       f"have {sorted(GUARDED)}") from None


def cache_size(target: Union[str, Callable]) -> Optional[int]:
    """Compiled-program count of a jitted callable, or None when this jax
    version does not expose jit cache introspection."""
    fn = resolve(target)
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    return probe()


def available(target: Union[str, Callable] = "sweep") -> bool:
    return cache_size(target) is not None


class RecompileError(AssertionError):
    """A guarded region compiled more programs than it budgeted for."""


class GuardRecord:
    """Per-target compile deltas of one guarded region (filled on exit;
    ``compiles()`` may also be read mid-region)."""

    def __init__(self, targets: List[Tuple[str, Callable, int]]):
        self._targets = targets

    def deltas(self) -> Dict[str, int]:
        return {name: cache_size(fn) - before
                for name, fn, before in self._targets}

    def compiles(self) -> int:
        return sum(self.deltas().values())


@contextlib.contextmanager
def recompile_guard(*targets: Union[str, Callable],
                    max_compiles: Optional[int] = 0):
    """Fail (``RecompileError``) if the region compiles more than
    ``max_compiles`` new programs across ``targets`` (default: none —
    everything must hit existing caches). Targets are ``GUARDED`` names or
    jitted callables; no targets means all ``GUARDED`` entry points.

    Raises ``RuntimeError`` when jit cache introspection is unavailable —
    call ``available()`` first (or use the conftest fixtures, which skip).
    """
    names = list(targets) if targets else sorted(GUARDED)
    resolved: List[Tuple[str, Callable, int]] = []
    for t in names:
        fn = resolve(t)
        before = cache_size(fn)
        if before is None:
            raise RuntimeError(
                "jit._cache_size() not available in this jax version — "
                "gate with repro.analysis.guard.available()")
        label = t if isinstance(t, str) else getattr(t, "__name__", str(t))
        resolved.append((label, fn, before))
    rec = GuardRecord(resolved)
    yield rec
    if max_compiles is not None:
        deltas = rec.deltas()
        total = sum(deltas.values())
        if total > max_compiles:
            grown = {k: v for k, v in deltas.items() if v}
            raise RecompileError(
                f"guarded region compiled {total} new program(s) "
                f"(budget {max_compiles}): {grown} — a static argument is "
                "leaking into the compile key (see docs/analysis.md)")
