"""Layer 1 — GF(2) scheme verifier: prove each code design's claims.

Every scheme in ``repro.core.codes.SCHEMES`` is admitted to the simulator
only through a *certificate* proved here from the scheme's parity matrix
itself (not from running the simulator):

* **Erasure tolerance** — for k = 1, 2, every k-subset of data banks is
  classified as servable/unservable under the controller's single-decode
  serving rule (one parity option per read, all other members alive),
  re-derived here from the members matrix alone; and cross-checked against
  plain GF(2) rank analysis (a servable loss set MUST be information-
  theoretically recoverable — the serving rule can never beat linear
  algebra). ``DECLARED`` pins each scheme's claimed full-tolerance level;
  a scheme whose matrix doesn't deliver its claim fails verification.
* **Read degree** — each data row's serving options (1 direct + its parity
  options) and the *simultaneous* read capacity: the maximum set of
  pairwise port-disjoint recovery sets per row, proved by exhaustive
  subset search over the ≤ ``MAX_OPTS`` options (this is the paper's
  "reads per bank per cycle" §III-B claim).
* **Slot-stride aliasing** — under a padded sweep geometry, parity row
  addressing is ``slot * rs_alloc + (i mod rs_active)`` with
  ``rs_active ≤ rs_alloc``; distinct slots must never alias. Verified
  exhaustively over a geometry grid covering every padded combination the
  engine can build (offset < rs_active ≤ rs_alloc keeps each slot inside
  its own stride window — the check would catch any future indexing scheme
  that breaks this).
* **Table hash** — a canonical SHA-256 of the (members, phys) tables. The
  oracle's independently derived tables must hash identically; on
  divergence ``diff_tables`` names the scheme and the exact field (see
  tests/test_conformance.py), instead of a bare assert.

``certify()`` emits the machine-readable certificate document;
``verify_certificates()`` recomputes it and diffs against the checked-in
``certificates.json`` (the CI gate: a scheme change without a matching
certificate regeneration fails). New schemes (e.g. the ROADMAP's LVT/ILVT
multi-write designs) are admitted by adding a ``DECLARED`` entry and
regenerating: ``python -m repro.analysis --write-certificates``.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.base import Finding

CERT_PATH = os.path.join(os.path.dirname(__file__), "certificates.json")
CERT_VERSION = 1

# Declared design claims, pinned per scheme (paper §III-B). "full_k" is the
# largest k ≤ MAX_K such that EVERY k-subset of data banks stays servable;
# "read_degree" counts simultaneous port-disjoint reads of one row
# (1 direct + disjoint parity options); "locality" is the worst-case bank
# count touched by one degraded read. A new scheme enters the simulator by
# adding its row here and regenerating certificates — no entry, no admit.
MAX_K = 2
DECLARED: Dict[str, Dict[str, int]] = {
    "uncoded": {"full_k": 0, "read_degree": 1, "locality": 1},
    "scheme_i": {"full_k": 2, "read_degree": 4, "locality": 2},
    # serving KV pool (runtime/kvbank.PooledKV): pairwise parities, one per
    # bank pair — a subcode of scheme_i (cross-checked by
    # ``check_pool_subcode``), so one degraded read per group per cycle.
    "kv_pool": {"full_k": 1, "read_degree": 2, "locality": 2},
    "scheme_ii": {"full_k": 2, "read_degree": 5, "locality": 2},
    "scheme_iii": {"full_k": 2, "read_degree": 4, "locality": 3},
    "replication_2": {"full_k": 2, "read_degree": 2, "locality": 1},
    "replication_4": {"full_k": 2, "read_degree": 4, "locality": 1},
}


# ------------------------------------------------------------------ GF(2)
def gf2_span_contains(rows: Sequence[int], target: int) -> bool:
    """True when ``target`` (a column bitmask) lies in the GF(2) row span."""
    basis: List[int] = []
    for r in rows:
        for b in basis:
            r = min(r, r ^ b)
        if r:
            basis.append(r)
            basis.sort(reverse=True)
    for b in basis:
        target = min(target, target ^ b)
    return target == 0


def gf2_recoverable(members: Sequence[Sequence[int]], n_data: int,
                    lost: Sequence[int]) -> bool:
    """Information-theoretic recoverability of ``lost`` data banks: the span
    of the alive unit vectors plus ALL parity rows must contain every lost
    unit vector (full elimination — strictly more powerful than the
    controller's single-decode serving rule)."""
    ls = set(lost)
    rows = [1 << m for m in range(n_data) if m not in ls]
    rows += [sum(1 << m for m in ms) for ms in members]
    return all(gf2_span_contains(rows, 1 << b) for b in ls)


def serving_recoverable(members: Sequence[Sequence[int]],
                        lost: Sequence[int]) -> bool:
    """The controller's degraded-serving rule, re-derived from the members
    matrix alone: each lost bank needs one parity whose other members are
    all alive (parity banks never fail — they are the redundancy; see
    docs/faults.md). Deliberately independent of
    ``CodeScheme.serving_recoverable`` so the two derivations check each
    other through the certificate."""
    ls = frozenset(lost)
    return all(
        any(b in ms and not (frozenset(ms) - {b}) & ls for ms in members)
        for b in ls)


# ----------------------------------------------------------- read capacity
def disjoint_read_capacity(members: Sequence[Sequence[int]],
                           phys: Sequence[int], n_data: int,
                           bank: int) -> int:
    """1 + the size of the largest set of pairwise port-disjoint parity
    options of ``bank`` (each option claims its physical parity port plus
    its sibling data-bank ports; the direct read claims only ``bank``'s own
    port, which no option touches). Exhaustive over ≤ MAX_OPTS options."""
    opts = []
    for j, ms in enumerate(members):
        if bank in ms:
            opts.append(frozenset({n_data + phys[j]})
                        | frozenset(m for m in ms if m != bank))
    best = 0
    for size in range(len(opts), 0, -1):
        for combo in itertools.combinations(opts, size):
            if len(frozenset().union(*combo)) == sum(len(o) for o in combo):
                best = size
                break
        if best:
            break
    return 1 + best


# ------------------------------------------------------------- table hash
def table_hash(members: Sequence[Sequence[int]],
               phys: Sequence[int]) -> str:
    """Canonical SHA-256 of a scheme's (members, phys) tables. Both the
    production tables and the oracle's independent derivation must hash to
    the same value (asserted via the certificate in conformance tests)."""
    doc = {"members": [sorted(int(m) for m in ms) for ms in members],
           "phys": [int(p) for p in phys]}
    blob = json.dumps(doc, separators=(",", ":"), sort_keys=True)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def diff_tables(name: str, core_members, core_phys,
                other_members, other_phys, other_label: str = "oracle"
                ) -> List[str]:
    """Human-readable field-level diff between two table derivations of one
    scheme — the error body when hashes diverge (names the scheme and the
    first differing parity instead of a bare assert)."""
    diffs: List[str] = []
    cm = [tuple(sorted(ms)) for ms in core_members]
    om = [tuple(sorted(ms)) for ms in other_members]
    if len(cm) != len(om):
        diffs.append(f"{name}: n_parities core={len(cm)} "
                     f"{other_label}={len(om)}")
    for j, (a, b) in enumerate(zip(cm, om)):
        if a != b:
            diffs.append(f"{name}: parity {j} members core={a} "
                         f"{other_label}={b}")
    cp, op = list(core_phys), list(other_phys)
    if cp != op:
        for j, (a, b) in enumerate(zip(cp, op)):
            if a != b:
                diffs.append(f"{name}: parity {j} phys core={a} "
                             f"{other_label}={b}")
        if len(cp) != len(op):
            diffs.append(f"{name}: phys length core={len(cp)} "
                         f"{other_label}={len(op)}")
    return diffs


# --------------------------------------------------------- stride aliasing
def stride_alias_free(rs_alloc: int, rs_active: int, n_slots: int,
                      n_rows: int) -> bool:
    """No two (slot, row) parity cells collide under padded addressing."""
    seen: Dict[int, Tuple[int, int]] = {}
    for slot in range(n_slots):
        for i in range(n_rows):
            pr = slot * rs_alloc + i % rs_active
            key = (slot, i % rs_active)
            prev = seen.get(pr)
            if prev is not None and prev != key:
                return False
            seen[pr] = key
            if not slot * rs_alloc <= pr < (slot + 1) * rs_alloc:
                return False
    return True


def check_stride_grid(max_rs: int = 8, max_slots: int = 4,
                      n_rows: int = 24) -> List[Finding]:
    """Exhaustive alias check over every padded geometry shape class the
    engine can produce: rs_active ≤ rs_alloc (group-max padding), any slot
    count. The row range covers several wrap-arounds of each stride."""
    out: List[Finding] = []
    for rs_alloc in range(1, max_rs + 1):
        for rs_active in range(1, rs_alloc + 1):
            for n_slots in range(1, max_slots + 1):
                if not stride_alias_free(rs_alloc, rs_active, n_slots, n_rows):
                    out.append(Finding(
                        "scheme-stride-alias",
                        f"geometry(rs_alloc={rs_alloc}, "
                        f"rs_active={rs_active}, n_slots={n_slots})",
                        "padded parity addressing aliases two slots"))
    return out


# ------------------------------------------------------------ certificates
def _scheme_tables(name: str):
    from repro.core.codes import get_tables
    t = get_tables(name)
    return t.scheme.members, t.scheme.phys, t.n_data


def analyze_scheme(name: str,
                   members: Optional[Sequence[Sequence[int]]] = None,
                   phys: Optional[Sequence[int]] = None,
                   n_data: Optional[int] = None) -> Dict:
    """Full certificate entry for one scheme (from ``core.codes`` by default;
    explicit tables support analyzing candidate schemes before admission)."""
    if members is None:
        members, phys, n_data = _scheme_tables(name)
    assert phys is not None and n_data is not None
    serving: Dict[str, List[List[int]]] = {}
    gf2_counts: Dict[str, int] = {}
    full_k = 0
    for k in range(1, MAX_K + 1):
        servable = [list(lost) for lost
                    in itertools.combinations(range(n_data), k)
                    if serving_recoverable(members, lost)]
        serving[str(k)] = servable
        gf2_counts[str(k)] = sum(
            1 for lost in itertools.combinations(range(n_data), k)
            if gf2_recoverable(members, n_data, lost))
        if len(servable) == math.comb(n_data, k) and full_k == k - 1:
            full_k = k
    read_degree = [disjoint_read_capacity(members, phys, n_data, b)
                   for b in range(n_data)]
    locality = max((len(ms) for ms in members), default=1)
    return {
        "n_data": n_data,
        "n_parities": len(members),
        "n_phys": (max(phys) + 1) if phys else 0,
        "table_sha256": table_hash(members, phys),
        "read_degree": read_degree,
        "read_degree_min": min(read_degree),
        "locality": locality,
        "serving_tolerance": serving,
        "serving_tolerance_counts": {k: len(v) for k, v in serving.items()},
        "gf2_tolerance_counts": gf2_counts,
        "full_tolerance_k": full_k,
    }


def verify_scheme_claims(name: str, entry: Dict,
                         declared: Optional[Dict[str, int]] = None
                         ) -> List[Finding]:
    """Prove one analyzed scheme delivers its declared claims; and that the
    serving rule never claims more than GF(2) rank allows."""
    out: List[Finding] = []
    decl = declared if declared is not None else DECLARED.get(name)
    if decl is None:
        out.append(Finding(
            "scheme-undeclared", f"scheme:{name}",
            "no DECLARED claims entry — a scheme is admitted only with "
            "pinned erasure-tolerance/read-degree claims "
            "(repro.analysis.schemes.DECLARED)"))
        return out
    if entry["full_tolerance_k"] < decl["full_k"]:
        missing = next(
            (lost for k in range(1, decl["full_k"] + 1)
             for lost in itertools.combinations(range(entry["n_data"]), k)
             if list(lost) not in entry["serving_tolerance"][str(k)]),
            None)
        out.append(Finding(
            "scheme-under-tolerant", f"scheme:{name}",
            f"declared full erasure tolerance k={decl['full_k']} but the "
            f"parity matrix only delivers k={entry['full_tolerance_k']} "
            f"(first unservable loss set: {missing})"))
    if entry["read_degree_min"] != decl["read_degree"]:
        out.append(Finding(
            "scheme-read-degree", f"scheme:{name}",
            f"declared read degree {decl['read_degree']} but the proven "
            f"port-disjoint capacity is {entry['read_degree_min']}"))
    if entry["locality"] != decl["locality"]:
        out.append(Finding(
            "scheme-locality", f"scheme:{name}",
            f"declared locality {decl['locality']} but the widest parity "
            f"touches {entry['locality']} banks"))
    # serving rule must be information-theoretically sound
    for k, servable in entry["serving_tolerance"].items():
        if len(servable) > entry["gf2_tolerance_counts"][k]:
            out.append(Finding(
                "scheme-serving-unsound", f"scheme:{name}",
                f"serving rule claims {len(servable)} recoverable "
                f"{k}-loss sets but GF(2) rank admits only "
                f"{entry['gf2_tolerance_counts'][k]}"))
    return out


KV_POOL_BANKS = 8


def pool_tables(n_banks: int = KV_POOL_BANKS):
    """(members, phys, n_data) of the serving KV pool's pairwise-parity
    layout, taken from the production table builder
    (``runtime.kvbank.parity_members``) so the certificate proves the code
    the server actually runs."""
    from repro.runtime.kvbank import parity_members
    members, phys = parity_members(n_banks)
    return members, phys, n_banks


def check_pool_subcode(n_banks: int = KV_POOL_BANKS,
                       parent: str = "scheme_i") -> List[Finding]:
    """The KV pool's parity layout must be a subcode of the core parent
    scheme: every pool parity group appears verbatim in the parent's
    members table (so the pool inherits the parent's certified claims
    restricted to those rows), and the groups partition the data banks."""
    out: List[Finding] = []
    members, _phys, nd = pool_tables(n_banks)
    pm, _pp, pn = _scheme_tables(parent)
    if pn != nd:
        out.append(Finding(
            "pool-subcode", f"kv_pool:{parent}",
            f"pool spans {nd} data banks but {parent} certifies {pn}"))
        return out
    parent_pairs = {tuple(sorted(ms)) for ms in pm}
    for g, ms in enumerate(members):
        if tuple(sorted(ms)) not in parent_pairs:
            out.append(Finding(
                "pool-subcode", f"kv_pool:parity{g}",
                f"pool parity group {tuple(ms)} is not a parity of "
                f"{parent} — the pool layout must be a subcode of the "
                "certified core scheme"))
    cover = sorted(m for ms in members for m in ms)
    if cover != list(range(nd)):
        out.append(Finding(
            "pool-subcode", "kv_pool:partition",
            f"pool parity groups must partition the data banks exactly "
            f"once; covered={cover}"))
    return out


def certify(names: Optional[Sequence[str]] = None) -> Dict:
    """The full certificate document: ``core.codes.SCHEMES`` plus the
    serving KV pool's pairwise layout (``kv_pool``)."""
    from repro.core.codes import SCHEMES
    names = list(names) if names is not None \
        else sorted(SCHEMES) + ["kv_pool"]
    entries = {}
    for name in names:
        if name == "kv_pool":
            entries[name] = analyze_scheme(name, *pool_tables())
        else:
            entries[name] = analyze_scheme(name)
    return {
        "version": CERT_VERSION,
        "max_k": MAX_K,
        "schemes": entries,
    }


def load_certificates(path: str = CERT_PATH) -> Dict:
    with open(path) as f:
        return json.load(f)


def write_certificates(path: str = CERT_PATH) -> Dict:
    doc = certify()
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return doc


def verify_certificates(path: str = CERT_PATH) -> List[Finding]:
    """The gate: recompute every certificate and diff against the checked-in
    document; then prove every scheme's declared claims. A scheme edit
    without ``--write-certificates`` (or an under-delivering new scheme)
    fails here with the divergent scheme named."""
    out: List[Finding] = []
    live = certify()
    try:
        saved = load_certificates(path)
    except (OSError, ValueError) as e:
        return [Finding("scheme-cert-missing", path,
                        f"unreadable certificate document ({e}); run "
                        "python -m repro.analysis --write-certificates")]
    if saved.get("version") != live["version"]:
        out.append(Finding("scheme-cert-stale", path,
                           f"certificate version {saved.get('version')} != "
                           f"analyzer version {live['version']}"))
    saved_schemes = saved.get("schemes", {})
    for name, entry in live["schemes"].items():
        have = saved_schemes.get(name)
        if have is None:
            out.append(Finding(
                "scheme-cert-stale", f"scheme:{name}",
                "no certificate for this scheme — run "
                "python -m repro.analysis --write-certificates"))
            continue
        if have != entry:
            keys = sorted(k for k in entry
                          if have.get(k) != entry[k])
            out.append(Finding(
                "scheme-cert-stale", f"scheme:{name}",
                f"checked-in certificate diverges from the live tables in "
                f"{keys} (table hash live={entry['table_sha256'][:12]} "
                f"saved={str(have.get('table_sha256'))[:12]}); regenerate "
                "with python -m repro.analysis --write-certificates"))
    for name in saved_schemes:
        if name not in live["schemes"]:
            out.append(Finding(
                "scheme-cert-stale", f"scheme:{name}",
                "certificate exists for a scheme no longer in "
                "core.codes.SCHEMES"))
    for name, entry in live["schemes"].items():
        out.extend(verify_scheme_claims(name, entry))
    return out


def run(strict: bool = False) -> List[Finding]:
    """Layer entry point: certificates + claims + stride-alias grid +
    KV-pool subcode cross-check."""
    del strict
    return (verify_certificates() + check_stride_grid()
            + check_pool_subcode())
