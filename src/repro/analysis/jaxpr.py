"""Layer 2 — jaxpr lint: prove compile-key and carry invariants statically.

The sweep engine's batching story rests on three invariants that used to be
re-proved by hand (or by counting live compiles) every time a flag or axis
landed:

* **Compile-key completeness** — ``repro.sweep.grid.static_signature`` must
  be a *complete* compile key: any two points in one signature class must
  trace to byte-identical jaxprs through the engine's program
  (``cycle_fn`` over the class's shared allocation). A static argument
  leaking into the traced program (a python int baked in from the point,
  a shape derived from α/r outside the masked geometry) shows up here as a
  jaxpr hash split within one class — without running a sweep or counting
  compiles.
* **Carry stability** — the scan carry must be a structural fixed point:
  ``cycle_fn``'s output state must have exactly the input state's pytree
  structure and per-leaf shape/dtype/weak_type. Any drift (a counter
  promoted by a stray python scalar, a new leaf appearing under a flag)
  would re-trace every chunk of a streamed replay.
* **Flag-off identity** — with ``telemetry=False``/``faults=False`` the
  carry must hold ``tele is None``/``fault is None`` (an absent pytree
  node, not a zeroed plane) and the jaxpr must be byte-identical whether
  the flags are passed explicitly or defaulted — the static gating trick
  (``MemParams.telemetry``/``faults``/``traced_geometry``) that keeps
  flags-off programs bit-identical to the pre-flag baseline. A flag that
  starts leaking traced ops into the off path splits these jaxprs.

Everything here is abstract evaluation: ``jax.make_jaxpr`` /
``jax.eval_shape`` only — no device program ever runs, so the lint is fast
enough for the fast CI tier. The runtime complement is
``repro.analysis.guard.recompile_guard`` (live compile counting in tests).
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis.base import Finding


# ---------------------------------------------------------------- helpers
def _avalize(tree):
    """Concrete pytree -> ShapeDtypeStruct pytree (weak_type preserved)."""
    def conv(x):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            return jax.ShapeDtypeStruct(
                x.shape, x.dtype, weak_type=bool(getattr(x, "weak_type",
                                                         False)))
        return x
    return jax.tree.map(conv, tree)


def _aval_fingerprint(tree) -> str:
    """Stable string of a pytree's structure + per-leaf aval."""
    leaves, treedef = jax.tree.flatten(tree)
    parts = [str(treedef)]
    for leaf in leaves:
        parts.append(f"{getattr(leaf, 'shape', ())}/"
                     f"{getattr(leaf, 'dtype', type(leaf).__name__)}/"
                     f"w{int(bool(getattr(leaf, 'weak_type', False)))}")
    return ";".join(parts)


def jaxpr_hash(fn, *avals) -> str:
    """SHA-256 of the closed jaxpr ``fn`` traces to on ``avals``."""
    jpr = jax.make_jaxpr(fn)(*avals)
    return hashlib.sha256(str(jpr).encode("utf-8")).hexdigest()


def _point_program_inputs(pt, sys):
    """(state, trace, tunables) aval trees exactly as the engine would trace
    them for ``pt`` on the shared system ``sys``."""
    from repro.sweep import engine, workloads

    tn = engine.stack_tunables([pt], sys.p.queue_depth)
    tn1 = jax.tree.map(lambda x: x[0], tn)
    st = sys.init(tn1)
    if sys.p.faults:
        fault = jax.tree.map(lambda x: x[0],
                             engine._stack_faults([pt], sys.p))
        st = st._replace(mem=st.mem._replace(fault=fault))
    trace = workloads.build_trace(pt)
    return _avalize(st), _avalize(trace), _avalize(tn1)


# ------------------------------------------------- compile-key completeness
def lint_program_class(label: str, programs: Sequence[Tuple]) -> List[Finding]:
    """Core compile-key check, program-agnostic (fixture-testable): each
    entry of ``programs`` is ``(fn, input_trees...)`` claiming membership
    in ONE compile class; all must produce identical input avals and an
    identical jaxpr, or the class would compile more than one program."""
    fingerprints: Dict[str, int] = {}
    hashes: Dict[str, int] = {}
    for k, (fn, *inputs) in enumerate(programs):
        fingerprints.setdefault(_aval_fingerprint(tuple(inputs)), k)
        hashes.setdefault(jaxpr_hash(fn, *inputs), k)
    if len(fingerprints) > 1:
        ks = sorted(fingerprints.values())
        return [Finding(
            "jaxpr-static-leak", label,
            f"members {ks[0]} and {ks[1]} of one compile class trace "
            "different program-input shapes/dtypes — a static coordinate "
            "is leaking out of the class key (the class would compile "
            "more than one program)")]
    if len(hashes) > 1:
        ks = sorted(hashes.values())
        return [Finding(
            "jaxpr-static-leak", label,
            f"members {ks[0]} and {ks[1]} of one compile class trace "
            "different jaxprs despite identical input avals — a python "
            "value is baked into the traced program")]
    return []


def lint_signature_classes(points: Sequence) -> List[Finding]:
    """Every point of one ``static_signature`` class must produce identical
    program-input avals and an identical ``cycle_fn`` jaxpr on the class's
    shared group allocation — the static proof behind 'one program per
    grid'."""
    from repro.sweep import engine
    from repro.sweep.grid import batch_geometry_alloc, partition

    out: List[Finding] = []
    for batch in partition(list(points)):
        pts = batch.points
        traced = len({pt.derived_slots()[:2] for pt in pts}) > 1
        sys = engine.system_for(pts[0],
                                geometry_alloc=batch_geometry_alloc(pts),
                                traced_geometry=traced)
        programs = [(sys.cycle_fn, *_point_program_inputs(pt, sys))
                    for pt in pts]
        out.extend(lint_program_class(f"signature:{batch.signature}",
                                      programs))
    return out


def count_distinct_programs(points: Sequence) -> int:
    """Distinct (signature, cycle_fn jaxpr) programs a sweep would compile —
    the static analogue of the ``sweep_compile_count`` fixture delta."""
    from repro.sweep import engine
    from repro.sweep.grid import batch_geometry_alloc, partition

    seen = set()
    for batch in partition(list(points)):
        pts = batch.points
        traced = len({pt.derived_slots()[:2] for pt in pts}) > 1
        sys = engine.system_for(pts[0],
                                geometry_alloc=batch_geometry_alloc(pts),
                                traced_geometry=traced)
        st_a, tr_a, tn_a = _point_program_inputs(pts[0], sys)
        seen.add(jaxpr_hash(sys.cycle_fn, st_a, tr_a, tn_a))
    return len(seen)


# --------------------------------------------------------- carry stability
def lint_carry_stability(pt=None) -> List[Finding]:
    """``cycle_fn`` must map its carry to an identical-structure carry:
    same treedef, same shape/dtype/weak_type per leaf. Checked on
    representative systems: flags off, telemetry on, faults on, and a
    traced-geometry padded allocation."""
    from repro.sweep.grid import SweepPoint

    base = pt if pt is not None else SweepPoint(n_rows=32, length=8,
                                                alpha=0.5, r=0.25)
    variants = [
        ("flags-off", base),
        ("telemetry", base.replace(telemetry=True)),
        ("faults", base.replace(faults=(("bank", 0, 2, 5),))),
    ]
    out: List[Finding] = []
    for label, vpt in variants:
        out.extend(_carry_findings(label, vpt))
    out.extend(_carry_findings(
        "traced-geometry", base,
        geometry_alloc=tuple(2 * g for g in base.derived_slots()),
        traced=True))
    return out


def lint_carry(label: str, fn, carry, *args, pick=None) -> List[Finding]:
    """Core carry-stability check, program-agnostic (fixture-testable):
    abstract-eval ``fn(carry, *args)`` and require the output carry to
    match ``carry`` exactly in treedef and per-leaf shape/dtype/weak_type.
    ``pick`` extracts the carry from the output (default: the output
    itself, or element 0 of a tuple — the ``(state, emit)`` convention)."""
    out = jax.eval_shape(fn, carry, *args)
    if pick is not None:
        out = pick(out)
    elif isinstance(out, tuple) and len(out) == 2:
        out = out[0]
    if _aval_fingerprint(carry) != _aval_fingerprint(out):
        drift = _first_leaf_drift(carry, out)
        return [Finding(
            "jaxpr-carry-drift", label,
            f"scan carry is not structurally stable: {drift} — every "
            "chunk/scan step would re-trace (dtype/shape/weak_type drift "
            "in the carry)")]
    return []


def _carry_findings(label: str, pt, geometry_alloc=None,
                    traced: bool = False) -> List[Finding]:
    from repro.sweep import engine

    sys = engine.system_for(pt, geometry_alloc=geometry_alloc,
                            traced_geometry=traced)
    st_a, tr_a, tn_a = _point_program_inputs(pt, sys)
    return lint_carry(f"cycle_fn[{label}]", sys.cycle_fn, st_a, tr_a, tn_a)


def _first_leaf_drift(a, b) -> str:
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    if str(ta) != str(tb):
        return f"treedef changed: {ta} -> {tb}"
    for i, (x, y) in enumerate(zip(la, lb)):
        sx = (getattr(x, "shape", None), getattr(x, "dtype", None),
              bool(getattr(x, "weak_type", False)))
        sy = (getattr(y, "shape", None), getattr(y, "dtype", None),
              bool(getattr(y, "weak_type", False)))
        if sx != sy:
            return f"leaf {i}: {sx} -> {sy}"
    return "unknown drift"


# --------------------------------------------------------- flag-off identity
def lint_flag_identity(pt=None) -> List[Finding]:
    """Flags-off must mean *absent*, not zeroed: the off-state carries
    ``tele is None`` / ``fault is None``, the off-jaxpr is byte-identical
    whether flags are defaulted or passed explicitly False, and turning a
    flag on genuinely changes the program (the flag is load-bearing)."""
    from repro.core.codes import get_tables
    from repro.core.state import make_params
    from repro.core.system import CodedMemorySystem
    from repro.sweep import engine
    from repro.sweep.grid import SweepPoint

    base = pt if pt is not None else SweepPoint(n_rows=32, length=8,
                                                alpha=0.5, r=0.25)
    out: List[Finding] = []
    sys_off = engine.system_for(base)
    st = sys_off.init()
    if st.mem.tele is not None or st.mem.fault is not None:
        out.append(Finding(
            "jaxpr-flag-leak", "MemState[flags-off]",
            "telemetry/fault leaves present with the flags off — the "
            "flags-off carry must have the pre-flag tree structure "
            "(tele=None, fault=None)"))
        return out
    st_a, tr_a, tn_a = _point_program_inputs(base, sys_off)
    h_off = jaxpr_hash(sys_off.cycle_fn, st_a, tr_a, tn_a)

    # an explicitly-flagged-off system must trace the identical program
    tables = get_tables(base.scheme, n_data=base.n_data)
    params = make_params(tables, n_rows=base.n_rows, alpha=base.alpha,
                         r=base.r, queue_depth=base.queue_depth,
                         telemetry=False, faults=False)
    sys_explicit = CodedMemorySystem(tables, params, n_cores=base.n_cores)
    h_explicit = jaxpr_hash(sys_explicit.cycle_fn, st_a, tr_a, tn_a)
    if h_off != h_explicit:
        out.append(Finding(
            "jaxpr-flag-leak", "cycle_fn[flags-off]",
            "explicit telemetry=False/faults=False traces a different "
            "jaxpr than the defaulted flags — the off path is not the "
            "pre-flag baseline program"))

    # each flag alone must change the traced program (it is load-bearing —
    # a flag whose on-jaxpr equals the off-jaxpr does nothing)
    for label, vpt in (("telemetry", base.replace(telemetry=True)),
                       ("faults", base.replace(faults=(("bank", 0, 2),)))):
        sys_on = engine.system_for(vpt)
        o_st, o_tr, o_tn = _point_program_inputs(vpt, sys_on)
        h_on = jaxpr_hash(sys_on.cycle_fn, o_st, o_tr, o_tn)
        if h_on == h_off:
            out.append(Finding(
                "jaxpr-flag-leak", f"cycle_fn[{label}-on]",
                f"{label}=True traces the same jaxpr as the off program — "
                "the flag no longer gates any computation"))
    return out


# ------------------------------------------------- pooled serve-step lints
def lint_serve_step() -> List[Finding]:
    """The pooled decode step's observability contract, proved statically:

    * **tele-off absence** — with ``tele=None`` the cache carries an absent
      leaf (not a zeroed plane) and the step is a structural fixed point of
      its carry; the telemetry plane must never change the pool avals.
    * **tele is load-bearing** — turning the plane on must change the
      traced program (otherwise the metrics cost nothing because they
      measure nothing).
    * **coded is a compile switch** — the uncoded pool (zero-size parity
      arrays) must trace a genuinely different program, not a masked
      branch of the coded one; same for disabling the ReCoding unit
      (``recode_budget=-1``)."""
    import dataclasses

    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import lm
    from repro.obs.serve import init_serve_telemetry
    from repro.runtime import kvbank as kb
    from repro.runtime.steps import make_pooled_serve_step

    cfg = dataclasses.replace(get_config("qwen2.5-3b").reduced(), kv_page=4)
    kvcfg = kb.KVBankConfig(n_banks=cfg.kv_banks, page=4,
                            pool_pages=4 * cfg.kv_banks, max_pages=4)
    b = 2
    params_a = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.key(0), max_seq=16))
    tok_a = jax.ShapeDtypeStruct((b,), jnp.int32)

    def pool_aval(coded):
        return jax.eval_shape(lambda: kb.pool_init(
            kvcfg, cfg.n_layers, b, cfg.n_kv, cfg.head_dim,
            jnp.dtype(cfg.compute_dtype), coded=coded))

    tele_a = jax.eval_shape(
        lambda: init_serve_telemetry(kvcfg.n_banks))
    step = make_pooled_serve_step(cfg, kvcfg)
    variants = {
        "off": (step, {"pool": pool_aval(True), "tele": None}),
        "tele-on": (step, {"pool": pool_aval(True), "tele": tele_a}),
        "uncoded": (step, {"pool": pool_aval(False), "tele": None}),
        "no-recode": (make_pooled_serve_step(cfg, kvcfg, recode_budget=-1),
                      {"pool": pool_aval(True), "tele": None}),
    }
    out: List[Finding] = []
    hashes: Dict[str, str] = {}
    for label, (fn, cache_a) in variants.items():
        out.extend(lint_carry(
            f"pooled_serve_step[{label}]",
            lambda carry, p, _fn=fn: _fn(p, *carry),
            (tok_a, cache_a), params_a, pick=lambda o: o))
        hashes[label] = jaxpr_hash(fn, params_a, tok_a, cache_a)
    for label, why in (
            ("tele-on", "the serve metric planes no longer measure "
                        "anything"),
            ("uncoded", "the coded/uncoded pool switch no longer selects "
                        "a different compiled program"),
            ("no-recode", "recode_budget=-1 no longer disables the "
                          "ReCoding unit")):
        if hashes[label] == hashes["off"]:
            out.append(Finding(
                "jaxpr-flag-leak", f"pooled_serve_step[{label}]",
                f"traces the same jaxpr as the baseline step — {why}"))
    return out


# ------------------------------------------------------------- layer entry
def default_lint_points() -> List:
    """The representative grid the CLI lints: an α×r×scheme×tunable spread
    exercising every signature-class mechanism (masked r axis, sub/full
    coverage split, telemetry and fault programs)."""
    from repro.sweep.grid import SweepPoint, grid

    base = SweepPoint(n_rows=32, length=8)
    pts = grid(base, scheme=("scheme_i", "uncoded"),
               alpha=(0.25, 0.5), r=(0.125, 0.25),
               seed=(0, 1), select_period=(64, 128))
    pts += grid(base, alpha=(1.0,), r=(0.25,), seed=(0, 1))   # full coverage
    pts += [base.replace(telemetry=True),
            base.replace(faults=(("bank", 0, 2, 5),)),
            base.replace(faults=(("stutter", 1, 3),))]
    return pts


def run(strict: bool = False,
        points: Optional[Sequence] = None) -> List[Finding]:
    del strict
    pts = list(points) if points is not None else default_lint_points()
    out = lint_signature_classes(pts)
    out += lint_carry_stability()
    out += lint_flag_identity()
    out += lint_serve_step()
    return out
