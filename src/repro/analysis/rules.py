"""Layer 3 — repo-rule AST lint: project conventions proved from source.

Four conventions keep the simulator correct and the oracle honest; each is
encoded here as an AST rule so violations surface at lint time instead of
as conformance drift or silent recompiles:

* **oracle-purity** — ``repro.oracle`` exists to catch shared
  misconceptions, so it must not import jax (or any non-oracle ``repro``
  module): a jax import would let the golden model inherit the very code
  paths it is supposed to check.
* **tracer-branch** — inside *traced* functions (the ones that run under
  ``jit``/``vmap``/``scan``), Python ``if``/``while`` and ``int()``/
  ``float()``/``bool()`` must only touch *static* values (params,
  shapes, ``x is None`` structure checks). Anything else is a
  ``TracerBoolConversionError`` at best and a silent
  concretization/recompile at worst.
* **static-geometry** — row→region/slot indexing in traced code must
  divide by the *active* geometry (``active_geometry``/
  ``TunableParams.*_active``), never ``// p.region_size`` on the
  allocated fields: under a padded group allocation the allocated stride
  is the *storage* layout, and using it to derive a region id silently
  mis-addresses every sub-allocation point. (Parity-row addressing
  ``slot * rs_alloc + i % rs_active`` legitimately *multiplies* by the
  allocated stride — only ``//`` and ``%`` by an allocated field are
  flagged, and the two intentional storage-layout sites carry waivers.)
* **narrow-counter** — the wide (lo, hi) uint32 counters
  (``stall_cycles``, ``read/write_latency_sum``) saturate silently if
  accumulated with ``+`` in a scan body; accumulation must go through
  ``repro.core.state.wide_add``.

Classification is explicit: every function in the scanned files must be
listed as TRACED or HOST below (wildcards ``Class.*`` / ``*`` cover
all-host modules). An unlisted function is itself a finding — new traced
code cannot silently skip the lint.

A finding can be waived where the code is right and the rule is
conservative: put ``# analysis: <rule-id>`` on the offending line (or the
line above) with a neighbouring comment saying why.

``scripts/check_bench_manifests.py`` is folded in as the
**bench-manifest** rule so ``python -m repro.analysis --strict`` covers
benchmark-contract drift too.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.base import Finding, REPO_ROOT, python_files, rel

# --------------------------------------------------------------- rule scope
# traced-code rules apply to the cycle-engine surface: everything the
# compiled programs are built from
TRACED_SCOPE = ("src/repro/core", "src/repro/faults", "src/repro/obs/planes.py",
                "src/repro/obs/serve.py", "src/repro/runtime/kvbank.py")
ORACLE_SCOPE = "src/repro/oracle"

# modules the oracle may import: stdlib + numpy, and its own package
ORACLE_ALLOWED_ROOTS = {
    "numpy", "dataclasses", "itertools", "typing", "collections", "math",
    "functools", "enum", "__future__", "repro.oracle",
}

GEOM_FIELDS = {"region_size", "n_regions", "n_slots"}
WIDE_FIELDS = {"stall_cycles", "read_latency_sum", "write_latency_sum"}

# names whose attributes are static (host-side) by contract: params and
# scheme tables are plain python/numpy containers, never tracers
STATIC_ROOTS = {"p", "params", "self", "t", "tables", "fault_plan", "plan"}
# attributes that are static on *any* object (array metadata)
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type"}
# calls that yield static values when their arguments are static;
# _concrete_int is static unconditionally (it is the sanctioned probe that
# returns None for tracers)
STATIC_CALLS = {"len", "isinstance", "hasattr", "callable", "min", "max",
                "round", "tuple", "sorted", "range", "getattr", "type"}
ALWAYS_STATIC_CALLS = {"_concrete_int"}

# ------------------------------------------------- function classification
# every function in TRACED_SCOPE must appear in exactly one of these maps
# (qualified as "func" or "Class.method"; "Class.*" and "*" are wildcards).
TRACED_FUNCTIONS: Dict[str, Set[str]] = {
    "src/repro/core/controller.py": {
        "_walk_bounds", "build_read_pattern", "build_write_pattern",
        "_rc_push"},
    "src/repro/core/recoding.py": {"recode_step"},
    "src/repro/core/dynamic.py": {
        "_encode_region_data", "priors_layout", "dynamic_step"},
    "src/repro/core/state.py": {
        "active_geometry", "wide_zero", "wide_add", "init_state"},
    "src/repro/core/system.py": {
        "quiescent", "CodedMemorySystem._arbiter",
        "CodedMemorySystem._read_values", "CodedMemorySystem._commit_writes",
        "CodedMemorySystem.cycle_fn", "CodedMemorySystem._run",
        "CodedMemorySystem.run_chunk"},
    "src/repro/faults/plan.py": {
        "init_fault_state", "bank_down", "bank_rebuilding", "stutter_busy"},
    "src/repro/faults/inject.py": {
        "drop_unservable", "rebuild_scan", "quiescent_fault_pending"},
    "src/repro/obs/planes.py": {"init_telemetry", "lat_bin"},
    "src/repro/obs/serve.py": {
        "init_serve_telemetry", "update_serve_telemetry"},
    "src/repro/runtime/kvbank.py": {
        "init_state", "append_token", "recode", "_budget_rows",
        "pool_read_sets", "plan_reads", "_plan_from_tables", "gather_kv",
        "read_latencies", "pool_write_index", "pool_mark_stale",
        "pool_write_layer", "pool_write_layer_fused", "pool_plan",
        "pool_install", "pool_recode", "pool_permute"},
}
HOST_FUNCTIONS: Dict[str, Set[str]] = {
    "src/repro/core/__init__.py": {"*"},
    "src/repro/core/codes.py": {"*"},
    "src/repro/core/controller.py": {"jtables"},
    "src/repro/core/state.py": {
        "make_tunables", "wide_total", "derive_geometry", "make_params",
        "_concrete_int"},
    "src/repro/core/system.py": {
        "drain_bound", "result_from_host", "CodedMemorySystem.__init__",
        "CodedMemorySystem.init", "CodedMemorySystem.run",
        "CodedMemorySystem.summarize"},
    "src/repro/faults/__init__.py": {"*"},
    "src/repro/faults/plan.py": {"FaultPlan.*", "plan_from_spec"},
    "src/repro/obs/planes.py": {
        "TelemetrySnapshot.*", "_find_tele", "snapshot"},
    "src/repro/obs/serve.py": {
        "ServeSnapshot.*", "ServeLog.*", "_Req.*", "snapshot",
        "format_summary"},
    "src/repro/runtime/kvbank.py": {
        "pool_init", "pool_coded", "parity_members"},
}

_WAIVER_RE = re.compile(r"#\s*analysis:\s*([\w-]+)")


def _waivers(source: str) -> Dict[int, Set[str]]:
    """{line (1-based): waived rule ids} — a waiver also covers the line
    directly below it, so it can sit above a long statement."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        for m in _WAIVER_RE.finditer(line):
            out.setdefault(i, set()).add(m.group(1))
            out.setdefault(i + 1, set()).add(m.group(1))
    return out


def _matches(qualname: str, names: Set[str]) -> bool:
    if "*" in names or qualname in names:
        return True
    cls = qualname.split(".")[0]
    return f"{cls}.*" in names and "." in qualname


# --------------------------------------------------------- oracle purity
def check_oracle_purity(root: Optional[str] = None) -> List[Finding]:
    base = root if root is not None else f"{REPO_ROOT}/{ORACLE_SCOPE}"
    out: List[Finding] = []
    for path in python_files(base):
        tree = _parse(path, out)
        if tree is None:
            continue
        for node in ast.walk(tree):
            mods: List[str] = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                mods = [node.module or ""]
            for mod in mods:
                if not _oracle_import_ok(mod):
                    out.append(Finding(
                        "oracle-purity", f"{rel(path)}:{node.lineno}",
                        f"oracle module imports {mod!r} — the golden model "
                        "must stay pure NumPy/stdlib (no jax, no shared "
                        "repro code) so it cannot inherit a core "
                        "misconception", line=node.lineno))
    return out


def _oracle_import_ok(mod: str) -> bool:
    return any(mod == allowed or mod.startswith(allowed + ".")
               for allowed in ORACLE_ALLOWED_ROOTS)


# ------------------------------------------------------- traced-code rules
def check_traced_rules(paths: Optional[Iterable[str]] = None,
                       traced: Optional[Set[str]] = None,
                       host: Optional[Set[str]] = None) -> List[Finding]:
    """tracer-branch + static-geometry + narrow-counter + classification
    completeness over the traced scope. Explicit ``traced``/``host`` sets
    override the per-file classification maps (used by the analyzer's own
    fixture tests)."""
    if paths is None:
        paths = _traced_scope_files()
    out: List[Finding] = []
    for path in paths:
        out.extend(_check_traced_file(path, traced=traced, host=host))
    return out


def _traced_scope_files() -> List[str]:
    files: List[str] = []
    for entry in TRACED_SCOPE:
        full = f"{REPO_ROOT}/{entry}"
        if entry.endswith(".py"):
            files.append(full)
        else:
            files.extend(python_files(full))
    return files


def _parse(path: str, out: List[Finding]):
    try:
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        return ast.parse(source, filename=path)
    except (OSError, SyntaxError) as e:
        out.append(Finding("parse-error", rel(path), str(e)))
        return None


def _check_traced_file(path: str, traced: Optional[Set[str]] = None,
                       host: Optional[Set[str]] = None) -> List[Finding]:
    out: List[Finding] = []
    try:
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        tree = ast.parse(source, filename=path)
    except (OSError, SyntaxError) as e:
        return [Finding("parse-error", rel(path), str(e))]
    rpath = rel(path)
    if traced is None:
        traced = TRACED_FUNCTIONS.get(rpath, set())
    if host is None:
        host = HOST_FUNCTIONS.get(rpath, set())
    waivers = _waivers(source)

    def visit_scope(body, prefix: str):
        for node in body:
            if isinstance(node, ast.ClassDef):
                visit_scope(node.body, f"{node.name}.")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = prefix + node.name
                is_traced = _matches(qual, traced)
                is_host = _matches(qual, host)
                if not is_traced and not is_host:
                    out.append(Finding(
                        "rule-classification", f"{rpath}:{node.lineno}",
                        f"function {qual!r} is not classified as TRACED or "
                        "HOST in repro.analysis.rules — new functions in "
                        "the cycle-engine surface must be classified so "
                        "the tracer rules cover them", line=node.lineno))
                elif is_traced:
                    _FunctionLint(rpath, qual, waivers, out).run(node)
                # host functions: no tracer rules, but nested defs under a
                # classified function inherit its classification, so stop.

    visit_scope(tree.body, "")
    return out


class _FunctionLint:
    """Single-pass lint of one traced function's body.

    Tracks two alias sets as assignments are encountered in source order:
    names bound to *static* expressions (usable in branches/casts) and
    names bound to *allocated-geometry* fields (illegal as ``//``/``%``
    divisors). Conditional (``IfExp``) binds deliberately do not propagate
    allocated-ness: ``rs if rs_active is None else rs_active`` is the
    sanctioned static-indexing fallback, not a stride leak.
    """

    def __init__(self, rpath: str, qual: str,
                 waivers: Dict[int, Set[str]], out: List[Finding]):
        self.rpath = rpath
        self.qual = qual
        self.waivers = waivers
        self.out = out
        self.static_names: Set[str] = set()
        self.geom_names: Set[str] = set()

    # ------------------------------------------------------------ plumbing
    def run(self, fn) -> None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                self._track_assign(node)
            elif isinstance(node, (ast.If, ast.While)):
                self._check_branch(node.test, kind=type(node).__name__)
            elif isinstance(node, ast.IfExp):
                self._check_branch(node.test, kind="conditional expression")
            elif isinstance(node, ast.Call):
                self._check_cast(node)
                self._check_wide_kwargs(node)
            elif isinstance(node, ast.BinOp):
                self._check_geometry(node)
                self._check_wide_binop(node)
            elif isinstance(node, ast.AugAssign):
                self._check_wide_augassign(node)

    def _flag(self, rule: str, node, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if rule in self.waivers.get(line, ()):
            return
        self.out.append(Finding(
            rule, f"{self.rpath}:{line}",
            f"in traced function {self.qual!r}: {message}", line=line))

    # ----------------------------------------------------- alias tracking
    def _track_assign(self, node: ast.Assign) -> None:
        targets = node.targets[0]
        if isinstance(targets, ast.Tuple) and isinstance(node.value, ast.Tuple) \
                and len(targets.elts) == len(node.value.elts):
            pairs = list(zip(targets.elts, node.value.elts))
        else:
            pairs = [(targets, node.value)]
        for tgt, val in pairs:
            if not isinstance(tgt, ast.Name):
                continue
            if self._is_static(val):
                self.static_names.add(tgt.id)
            else:
                self.static_names.discard(tgt.id)
            if self._is_alloc_geometry(val):
                self.geom_names.add(tgt.id)
            else:
                self.geom_names.discard(tgt.id)

    # ------------------------------------------------- static-test grammar
    def _is_static(self, node) -> bool:
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            return node.id in self.static_names
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return True
            root = node
            while isinstance(root, ast.Attribute):
                root = root.value
            return isinstance(root, ast.Name) and root.id in STATIC_ROOTS
        if isinstance(node, ast.Subscript):
            return self._is_static(node.value) and self._is_static(node.slice)
        if isinstance(node, (ast.Tuple, ast.List)):
            return all(self._is_static(e) for e in node.elts)
        if isinstance(node, ast.Compare):
            # pytree-structure checks (`x is None`) are static regardless
            # of what x holds — None-ness is resolved at trace time
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                    and all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators):
                return True
            return (self._is_static(node.left)
                    and all(self._is_static(c) for c in node.comparators))
        if isinstance(node, ast.BoolOp):
            return all(self._is_static(v) for v in node.values)
        if isinstance(node, ast.UnaryOp):
            return self._is_static(node.operand)
        if isinstance(node, ast.BinOp):
            return self._is_static(node.left) and self._is_static(node.right)
        if isinstance(node, ast.IfExp):
            return (self._is_static(node.test) and self._is_static(node.body)
                    and self._is_static(node.orelse))
        if isinstance(node, ast.Call):
            fname = node.func.id if isinstance(node.func, ast.Name) else None
            if fname in ALWAYS_STATIC_CALLS:
                return True
            if fname in STATIC_CALLS or fname in ("int", "float", "bool"):
                return all(self._is_static(a) for a in node.args)
            return False
        return False

    def _check_branch(self, test, kind: str) -> None:
        if not self._is_static(test):
            self._flag(
                "tracer-branch", test,
                f"python {kind} on a value that is not statically "
                "resolvable (params/shapes/`is None`) — on a tracer this "
                "is a TracerBoolConversionError or a silent "
                "concretization; use jnp.where/lax.cond")

    def _check_cast(self, node: ast.Call) -> None:
        fname = node.func.id if isinstance(node.func, ast.Name) else None
        if fname in ("int", "float", "bool") and node.args \
                and not self._is_static(node.args[0]):
            self._flag(
                "tracer-branch", node,
                f"{fname}() on a value that is not statically resolvable — "
                "concretizes a tracer (use .astype / _concrete_int on the "
                "host side)")

    # --------------------------------------------------- static geometry
    def _is_alloc_geometry(self, node) -> bool:
        if isinstance(node, ast.Attribute):
            return (node.attr in GEOM_FIELDS
                    and isinstance(node.value, ast.Name)
                    and node.value.id in STATIC_ROOTS)
        if isinstance(node, ast.Name):
            return node.id in self.geom_names
        return False

    def _check_geometry(self, node: ast.BinOp) -> None:
        if not isinstance(node.op, (ast.FloorDiv, ast.Mod)):
            return
        if self._is_alloc_geometry(node.right):
            opname = "//" if isinstance(node.op, ast.FloorDiv) else "%"
            field = (node.right.attr if isinstance(node.right, ast.Attribute)
                     else node.right.id)
            self._flag(
                "static-geometry", node,
                f"`{opname} {field}` divides by the *allocated* geometry — "
                "under a padded group allocation this mis-addresses every "
                "sub-allocation point; index with the active geometry "
                "(active_geometry / TunableParams.*_active)")

    # ----------------------------------------------------- narrow counter
    def _contains_plain_add(self, node) -> bool:
        return any(isinstance(n, ast.BinOp)
                   and isinstance(n.op, (ast.Add, ast.Sub))
                   for n in ast.walk(node))

    def _check_wide_binop(self, node: ast.BinOp) -> None:
        if not isinstance(node.op, (ast.Add, ast.Sub)):
            return
        for side in (node.left, node.right):
            if isinstance(side, ast.Attribute) and side.attr in WIDE_FIELDS:
                self._flag(
                    "narrow-counter", node,
                    f"`{side.attr}` is a wide (lo, hi) counter — plain "
                    "`+`/`-` corrupts the limb pair (and a narrow uint32 "
                    "would overflow in long scans); accumulate with "
                    "repro.core.state.wide_add")

    def _check_wide_augassign(self, node: ast.AugAssign) -> None:
        tgt = node.target
        if isinstance(tgt, ast.Attribute) and tgt.attr in WIDE_FIELDS:
            self._flag(
                "narrow-counter", node,
                f"augmented assignment to wide counter `{tgt.attr}` — "
                "accumulate with repro.core.state.wide_add")

    def _check_wide_kwargs(self, node: ast.Call) -> None:
        for kw in node.keywords:
            if kw.arg in WIDE_FIELDS and self._contains_plain_add(kw.value):
                self._flag(
                    "narrow-counter", kw.value,
                    f"`{kw.arg}=` is built with plain `+`/`-` — wide "
                    "counters must be accumulated with "
                    "repro.core.state.wide_add")


# ------------------------------------------------------- kernel interpret
# non-test code that pins the Pallas interpreter: the production default is
# interpret=None (resolved from the backend by kernels.common.resolve_interpret)
KERNEL_INTERPRET_SCOPE = ("src/repro", "benchmarks")


def check_kernel_interpret(
        roots: Optional[Iterable[str]] = None) -> List[Finding]:
    """Flag ``interpret=True`` hard-coded at non-test kernel call sites.

    The kernel wrappers default to ``interpret=None``, which resolves to
    native compilation on TPU and the Pallas interpreter elsewhere
    (``repro.kernels.common.resolve_interpret``). A call site that pins
    ``True`` silently runs the CPU interpreter on hardware — tests may pin
    it (they are not scanned); anything else needs an
    ``# analysis: kernel-interpret`` waiver."""
    bases = (list(roots) if roots is not None
             else [f"{REPO_ROOT}/{e}" for e in KERNEL_INTERPRET_SCOPE])
    out: List[Finding] = []
    for base in bases:
        paths = [base] if os.path.isfile(base) else python_files(base)
        for path in paths:
            out.extend(_check_interpret_file(path))
    return out


def _check_interpret_file(path: str) -> List[Finding]:
    out: List[Finding] = []
    try:
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        tree = ast.parse(source, filename=path)
    except (OSError, SyntaxError) as e:
        return [Finding("parse-error", rel(path), str(e))]
    waivers = _waivers(source)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg != "interpret":
                continue
            if not (isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                continue
            line = kw.value.lineno
            if "kernel-interpret" in (waivers.get(line, set())
                                      | waivers.get(node.lineno, set())):
                continue
            out.append(Finding(
                "kernel-interpret", f"{rel(path)}:{line}",
                "kernel call hard-codes interpret=True — on TPU this "
                "silently executes the Pallas CPU interpreter; pass "
                "interpret=None and let resolve_interpret pick the "
                "backend (tests may pin True)", line=line))
    return out


# -------------------------------------------------------- bench manifests
def check_bench_manifests() -> List[Finding]:
    """Fold scripts/check_bench_manifests.py in as an analysis rule."""
    import importlib.util

    path = f"{REPO_ROOT}/scripts/check_bench_manifests.py"
    spec = importlib.util.spec_from_file_location("check_bench_manifests",
                                                  path)
    if spec is None or spec.loader is None:          # pragma: no cover
        return [Finding("bench-manifest", rel(path),
                        "cannot load scripts/check_bench_manifests.py")]
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return [Finding("bench-manifest", rel(path), problem)
            for problem in mod.check(REPO_ROOT)]


# ------------------------------------------------------------- layer entry
def run(strict: bool = False,
        paths: Optional[Iterable[str]] = None) -> List[Finding]:
    del strict
    out = check_oracle_purity()
    out += check_traced_rules(paths)
    out += check_kernel_interpret()
    out += check_bench_manifests()
    return out
