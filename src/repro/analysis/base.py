"""Shared vocabulary of the static-analysis layers.

A *finding* is one violated invariant, anchored to a file/line when the
analyzer works from source (the AST rules) or to a logical location (a
scheme name, a sweep-point signature) when it works from live objects (the
GF(2) verifier, the jaxpr lint). Analyzers return ``list[Finding]`` —
empty means the invariant holds; the CLI turns a non-empty list into a
non-zero exit under ``--strict``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterable, List, Optional

# repo-root anchor: src/repro/analysis/base.py -> repo root three dirs up
REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
SRC_ROOT = os.path.join(REPO_ROOT, "src")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant."""

    rule: str                    # stable rule id, e.g. "oracle-purity"
    location: str                # "path:line" or a logical anchor
    message: str                 # what is wrong and why it matters
    line: Optional[int] = None   # 1-based, when source-anchored

    def __str__(self) -> str:
        return f"[{self.rule}] {self.location}: {self.message}"


def rel(path: str) -> str:
    """Repo-relative form of ``path`` for stable finding locations."""
    try:
        return os.path.relpath(path, REPO_ROOT)
    except ValueError:                                    # pragma: no cover
        return path


def format_findings(findings: Iterable[Finding]) -> str:
    return "\n".join(str(f) for f in findings)


def python_files(root: str) -> List[str]:
    """All ``.py`` files under ``root``, sorted for deterministic output."""
    out: List[str] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in ("__pycache__", ".git")]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".py"))
    return sorted(out)
