"""Deterministic synthetic data pipeline (host-sharded, prefetched)."""
from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    Prefetcher,
    TokenStream,
    make_batch,
)
