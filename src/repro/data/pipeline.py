"""Deterministic synthetic token pipeline.

Design goals (what a production input pipeline must provide, scaled down to
a synthetic source):

  * **Determinism & restartability** — ``make_batch(step)`` is a pure
    function of ``(seed, step, host_id)``. After a restart from step k the
    stream continues bit-identically; no iterator state to checkpoint.
  * **Host sharding** — each host materializes only its
    ``global_batch / n_hosts`` slice (the arrays fed to jit carry the global
    batch dimension only logically; here on one host we build the full batch
    for simplicity when n_hosts == 1).
  * **Prefetch** — a double-buffered background thread overlaps host batch
    synthesis with device compute.

The token source is a noisy affine Markov chain over an effective vocab:
``x[t+1] = (a * x[t] + b + eps) mod V_eff`` with P(eps != 0) = noise. An LM
can learn it quickly (loss → the noise entropy), which gives the end-to-end
training example a verifiable learning signal.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int                 # global batch (sequences per step)
    seq_len: int
    seed: int = 0
    noise: float = 0.1         # P(next token is uniform-random)
    v_eff: int = 0             # effective vocab of the chain (0 = min(V, 4096))
    n_hosts: int = 1
    host_id: int = 0


def _chain_params(seed: int, v_eff: int):
    rng = np.random.default_rng(seed ^ 0x5EED)
    # multiplier coprime with v_eff so the chain cycles through the vocab
    a = int(rng.integers(3, max(v_eff - 1, 4)) | 1)
    while np.gcd(a, v_eff) != 1:
        a += 2
    b = int(rng.integers(1, v_eff))
    return a, b


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function (cfg, step) -> {"tokens": (local_batch, seq_len) int32}."""
    v_eff = cfg.v_eff or min(cfg.vocab, 4096)
    a, b = _chain_params(cfg.seed, v_eff)
    local = cfg.batch // cfg.n_hosts
    rng = np.random.default_rng(
        (cfg.seed * 1_000_003 + step) * 131 + cfg.host_id
    )
    x = np.empty((local, cfg.seq_len), np.int64)
    x[:, 0] = rng.integers(0, v_eff, local)
    noise_mask = rng.random((local, cfg.seq_len)) < cfg.noise
    noise_tok = rng.integers(0, v_eff, (local, cfg.seq_len))
    for t in range(1, cfg.seq_len):
        nxt = (a * x[:, t - 1] + b) % v_eff
        x[:, t] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
    return {"tokens": x.astype(np.int32)}


class TokenStream:
    """Stateless stream facade: ``stream[step]`` or iteration from ``start``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def __getitem__(self, step: int) -> Dict[str, np.ndarray]:
        return make_batch(self.cfg, step)

    def iterate(self, start: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start
        while True:
            yield make_batch(self.cfg, step)
            step += 1


class Prefetcher:
    """Double-buffered background prefetch over a TokenStream.

    ``get(step)`` returns the batch for ``step`` and kicks off synthesis of
    ``step+1`` in the background. Out-of-order access (restart) is handled by
    discarding the stale buffer — determinism comes from make_batch purity.
    """

    def __init__(self, stream: TokenStream, depth: int = 2):
        self.stream = stream
        self.depth = depth
        self._q: "queue.Queue[tuple[int, Dict[str, np.ndarray]]]" = queue.Queue(depth)
        self._next = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def _worker(self, start: int):
        step = start
        while not self._stop.is_set():
            batch = self.stream[step]
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self, step: int = 0):
        self.stop()
        self._stop.clear()
        self._q = queue.Queue(self.depth)
        self._next = step
        self._thread = threading.Thread(target=self._worker, args=(step,), daemon=True)
        self._thread.start()

    def get(self, step: int) -> Dict[str, np.ndarray]:
        if self._thread is None or step != self._next:
            self.start(step)                     # restart / random access
        got_step, batch = self._q.get()
        assert got_step == step, (got_step, step)
        self._next = step + 1
        return batch

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None
