"""Logical activation-axis sharding constraints (MaxText-style rules).

GSPMD propagates shardings well through matmuls but gives up (replicates)
through gathers, cumsums and some reshapes — one replicated activation then
poisons everything downstream. The model code therefore pins *logical* axes
at a few key points (``shard(x, "batch", None, "vocab")``); the mapping from
logical names to mesh axes lives here, and is a no-op outside a mesh context
(unit tests, single-device examples).

Logical names:
  batch   -> ("pod", "data")     (whichever exist in the mesh)
  vocab / heads / ff / embed_row / width -> "model"
  seq     -> "model"             (sequence/context parallelism, opt-in)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_mesh", default=None)

_RULES = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "ff": ("model",),
    "width": ("model",),
    "embed_row": ("model",),
    "seq": ("model",),
    "experts": ("model",),
}


@contextlib.contextmanager
def use_mesh(mesh):
    tok = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(tok)


def current_mesh():
    return _MESH.get()


def _resolve(mesh, name: Optional[str], dim: int):
    if name is None:
        return None
    axes = tuple(a for a in _RULES[name] if a in mesh.axis_names)
    if not axes:
        return None
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if size <= 1 or dim % size != 0:
        # try single-axis fallback for composite rules
        for a in axes:
            if mesh.shape[a] > 1 and dim % mesh.shape[a] == 0:
                return a
        return None
    return axes if len(axes) > 1 else axes[0]


def shard(x, *names: Optional[str]):
    """Constrain ``x`` so dim i is sharded per logical axis ``names[i]``.
    Identity when no mesh is active. Divisibility-checked per dim."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"rank mismatch: {len(names)} names for {x.shape}")
    spec = P(*[_resolve(mesh, n, d) for n, d in zip(names, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
