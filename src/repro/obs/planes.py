"""Device-side telemetry metric planes (the ``MemParams.telemetry`` payload).

A *plane* is a small counter array carried inside the scan state and updated
in the same masked scatters the cycle engine already uses, so telemetry-on
runs stay one compiled program with no host round trips. The planes answer
the questions the three opaque aggregates (``stall_cycles``,
``read/write_latency_sum``) cannot: *which bank* stalled a core, *why* a
queued request waited, *how* each core's reads were served (direct vs
parity-decoded), how deep the queues ran, and how latency distributes — the
paper's Fig 18-20 evaluation axes, per cause instead of in aggregate.

This module must stay importable by ``repro.core.state`` (the planes are
``MemState`` leaves), so it imports **nothing from repro** — only jax/numpy.
The NumPy golden model re-derives every counter independently in
``repro.oracle.model`` and the conformance suite asserts equality, so the
planes are ground-truthed, not decorative.

Cause taxonomy (see docs/observability.md):

* ``stall_cause[b, c]`` — arbiter stalls by destination data bank ``b``:
  ``c=0`` read queue full, ``c=1`` write queue full. The arbiter's
  full-queue rejection is the ONLY core-stall source, so
  ``stall_cause.sum() == stall_cycles`` exactly.
* ``wait_cause[b, c]`` — per-cycle pending-work attribution by bank:
  ``c=0`` a valid read candidate went unserved in a read cycle (bank
  conflict / port contention), ``c=1`` a valid write went unserved in a
  write cycle, ``c=2`` a recode-ring entry for bank ``b`` was still pending
  at cycle end (recode-budget / port starvation). These are wait *cycles*
  (one count per request per cycle spent waiting), not events.
* ``read_mode_core[core, k]`` — served-read provenance per issuing core:
  ``k=0`` direct, ``k=1`` chained-decode reuse (FROM_SYM), ``k=2``
  parity-decoded (degraded), ``k=3`` redirect to a parked copy.
  ``read_mode_core.sum() == served_reads``; columns 1+2 sum to
  ``degraded_reads``.
* ``write_mode_core[core, k]`` — ``k=0`` direct commit, ``k=1`` parked
  into a parity row. Sums to ``served_writes`` / ``parked_writes``.
* ``rq_hwm`` / ``wq_hwm`` — post-arbiter per-bank queue-depth high-water
  marks.
* ``lat_hist_read`` / ``lat_hist_write`` — log2-binned critical-word
  latency histograms over served requests: bin 0 holds latency 0, bin k
  holds [2^(k-1), 2^k), the last bin is open-ended.
* ``recode_retired`` — total recode-ring retirements.
* ``rq_core`` / ``wq_core`` — provenance carriers, not counters: the core
  id occupying each queue slot, written by the arbiter in the same scatter
  as the slot itself, read back by the serve step to attribute provenance.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

STALL_CAUSES = ("read_queue_full", "write_queue_full")
WAIT_CAUSES = ("read_conflict", "write_conflict", "recode_pending")
# ``degraded_fault``: a from_sym/parity-decode serve whose cause is a DOWN
# bank (fault injection, repro.faults) rather than ordinary port contention
READ_CLASSES = ("direct", "from_sym", "parity_decode", "redirect",
                "degraded_fault")
WRITE_CLASSES = ("direct", "parked")
WAIT_READ, WAIT_WRITE, WAIT_RECODE = range(len(WAIT_CAUSES))
HIST_BINS = 16


class Telemetry(NamedTuple):
    """Per-point metric planes (jnp arrays; ride the scan carry)."""

    stall_cause: jnp.ndarray      # (n_data, 2) uint32
    wait_cause: jnp.ndarray       # (n_data, 3) uint32
    read_mode_core: jnp.ndarray   # (n_cores, 5) uint32
    write_mode_core: jnp.ndarray  # (n_cores, 2) uint32
    rq_hwm: jnp.ndarray           # (n_data,) int32
    wq_hwm: jnp.ndarray           # (n_data,) int32
    lat_hist_read: jnp.ndarray    # (HIST_BINS,) uint32
    lat_hist_write: jnp.ndarray   # (HIST_BINS,) uint32
    recode_retired: jnp.ndarray   # () uint32
    rq_core: jnp.ndarray          # (n_data, queue_depth) int32 provenance
    wq_core: jnp.ndarray          # (n_data, queue_depth) int32 provenance
    # per-bank cycles spent down (fault injection; mirrors
    # FaultState.dead_cycles exactly — all-zero when faults are off)
    dead_cycles: jnp.ndarray      # (n_data,) uint32


def init_telemetry(n_data: int, n_cores: int, queue_depth: int) -> Telemetry:
    return Telemetry(
        stall_cause=jnp.zeros((n_data, len(STALL_CAUSES)), jnp.uint32),
        wait_cause=jnp.zeros((n_data, len(WAIT_CAUSES)), jnp.uint32),
        read_mode_core=jnp.zeros((n_cores, len(READ_CLASSES)), jnp.uint32),
        write_mode_core=jnp.zeros((n_cores, len(WRITE_CLASSES)), jnp.uint32),
        rq_hwm=jnp.zeros((n_data,), jnp.int32),
        wq_hwm=jnp.zeros((n_data,), jnp.int32),
        lat_hist_read=jnp.zeros((HIST_BINS,), jnp.uint32),
        lat_hist_write=jnp.zeros((HIST_BINS,), jnp.uint32),
        recode_retired=jnp.zeros((), jnp.uint32),
        rq_core=jnp.full((n_data, queue_depth), -1, jnp.int32),
        wq_core=jnp.full((n_data, queue_depth), -1, jnp.int32),
        dead_cycles=jnp.zeros((n_data,), jnp.uint32),
    )


def lat_bin(lat: jnp.ndarray) -> jnp.ndarray:
    """log2 histogram bin of a latency: 0→0, 1→1, [2,3]→2, [4,7]→3, …,
    clamped into the open-ended last bin. Integer-exact (a threshold-count,
    no float log), so the NumPy oracle's independent ``bit_length``
    derivation matches bit for bit."""
    lat = jnp.asarray(lat)
    thresholds = jnp.asarray([1 << k for k in range(HIST_BINS - 1)],
                             dtype=lat.dtype)
    return jnp.sum(lat[..., None] >= thresholds, axis=-1).astype(jnp.int32)


# ------------------------------------------------------------- host snapshot
class TelemetrySnapshot:
    """Host-side (numpy) view of one point's planes, plus derived totals.

    Build with ``snapshot(state_or_telemetry[, point])``; every plane is a
    plain int64 numpy array named like its ``Telemetry`` field.
    """

    def __init__(self, tele):
        for name in Telemetry._fields:
            setattr(self, name, np.asarray(
                getattr(tele, name)).astype(np.int64))

    # ---- derived totals (the cross-checks the tests assert against
    # SimResult aggregates)
    def stall_total(self) -> int:
        return int(self.stall_cause.sum())

    def stall_by_cause(self) -> dict:
        return {c: int(self.stall_cause[:, k].sum())
                for k, c in enumerate(STALL_CAUSES)}

    def wait_by_cause(self) -> dict:
        return {c: int(self.wait_cause[:, k].sum())
                for k, c in enumerate(WAIT_CAUSES)}

    def reads_by_class(self) -> dict:
        return {c: int(self.read_mode_core[:, k].sum())
                for k, c in enumerate(READ_CLASSES)}

    def writes_by_class(self) -> dict:
        return {c: int(self.write_mode_core[:, k].sum())
                for k, c in enumerate(WRITE_CLASSES)}

    def served_reads(self) -> int:
        return int(self.read_mode_core.sum())

    def served_writes(self) -> int:
        return int(self.write_mode_core.sum())

    def degraded_reads(self) -> int:
        by = self.reads_by_class()
        return by["from_sym"] + by["parity_decode"] + by["degraded_fault"]

    def fault_degraded_reads(self) -> int:
        return self.reads_by_class()["degraded_fault"]

    def dead_bank_cycles(self) -> int:
        return int(self.dead_cycles.sum())

    def parked_writes(self) -> int:
        return self.writes_by_class()["parked"]

    def as_dict(self) -> dict:
        """JSON-serializable dump (counter planes + derived totals; the
        provenance carriers are transient state, not metrics — skipped)."""
        out = {name: getattr(self, name).tolist()
               for name in Telemetry._fields
               if name not in ("rq_core", "wq_core")}
        out["recode_retired"] = int(self.recode_retired)
        out["derived"] = {
            "stall_total": self.stall_total(),
            "served_reads": self.served_reads(),
            "served_writes": self.served_writes(),
            "degraded_reads": self.degraded_reads(),
            "parked_writes": self.parked_writes(),
            "stall_by_cause": self.stall_by_cause(),
            "wait_by_cause": self.wait_by_cause(),
            "reads_by_class": self.reads_by_class(),
            "writes_by_class": self.writes_by_class(),
            "fault_degraded_reads": self.fault_degraded_reads(),
            "dead_bank_cycles": self.dead_bank_cycles(),
        }
        return out


def _find_tele(obj):
    if obj is None or isinstance(obj, Telemetry):
        return obj
    t = getattr(obj, "tele", None)
    if t is not None:
        return t
    m = getattr(obj, "mem", None)
    return getattr(m, "tele", None) if m is not None else None


def snapshot(obj, point: Optional[int] = None) -> Optional[TelemetrySnapshot]:
    """Host snapshot of the planes in ``obj`` — a ``Telemetry``, a
    ``MemState`` or a ``SimState`` (duck-typed to avoid importing
    repro.core). ``point`` indexes the leading batch axis of a batched
    (vmapped sweep) state. Returns None when telemetry is off."""
    tele = _find_tele(obj)
    if tele is None:
        return None
    if point is not None:
        tele = Telemetry(*(np.asarray(leaf)[point] for leaf in tele))
    return TelemetrySnapshot(tele)
