"""repro.obs — opt-in observability: telemetry planes, timelines, manifests.

Layers (see docs/observability.md):

* ``planes``   — device-side metric planes gated by ``MemParams.telemetry``
                 (per-bank per-cause stalls/waits, per-core read/write
                 provenance, queue high-water marks, latency histograms).
* ``timeline`` — Chrome-trace/Perfetto JSON export of replay decisions
                 (write-mode flips, region re-selections, recode backlog,
                 arbiter grants) for ``chrome://tracing`` / ui.perfetto.dev.
* ``runlog``   — structured run manifests (config + static signature, git
                 SHA, device topology, wall times) attached to every
                 ``BENCH_*.json`` by ``benchmarks.common.emit``.
* ``report``   — stall-attribution markdown reports (per-bank heatmap
                 tables, coded vs uncoded) for the fig18/19/20 suites,
                 plus the ``--serve`` request-path section.
* ``serve``    — serving metric planes for the coded KV page pool (bank
                 load/latency histograms, read provenance, recode backlog)
                 and host-side request lifecycle spans (ServeLog).

``core/state.py`` imports ``repro.obs.planes``; everything else here pulls
in the sweep layer, so the submodules load lazily to keep the core import
graph acyclic.
"""
from repro.obs.planes import (HIST_BINS, READ_CLASSES, STALL_CAUSES,
                              WAIT_CAUSES, WRITE_CLASSES, Telemetry,
                              TelemetrySnapshot, init_telemetry, lat_bin,
                              snapshot)

__all__ = [
    "HIST_BINS", "READ_CLASSES", "STALL_CAUSES", "WAIT_CAUSES",
    "WRITE_CLASSES", "Telemetry", "TelemetrySnapshot", "init_telemetry",
    "lat_bin", "snapshot", "timeline", "runlog", "report", "serve",
]


def __getattr__(name):
    if name in ("timeline", "runlog", "report", "serve"):
        import importlib
        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
