"""Scheduler timeline export: replay decisions as Chrome-trace JSON.

``record_timeline`` re-runs a workload through ``cycle_fn`` one cycle at a
time (host-stepped — the per-cycle readback is the point, not speed) and
emits the decisions the batched paths fold away: write-drain mode spans,
dynamic-coding encode spans / region switches / evictions, recode-backlog
bursts, per-cycle arbiter grants and queue occupancy, and chunked-stream
restage points. The output is the Chrome trace-event format (one JSON
object per event), viewable in ``chrome://tracing`` or https://ui.perfetto.dev
— load the file ``export_chrome_trace`` writes. One simulated cycle maps to
one microsecond of trace time.

Works on any system (telemetry planes not required): every signal here is
read from the ordinary ``MemState`` scalars between cycles.

CLI::

    PYTHONPATH=src python -m repro.obs.timeline \
        --scheme scheme_i --alpha 0.25 --r 0.05 --length 96 \
        --chunk-len 32 --out experiments/obs/timeline.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

# pid/tid layout of the exported trace (Perfetto groups rows by these)
PID = 0
TID_SCHED, TID_DYNAMIC, TID_RECODE, TID_QUEUES = 0, 1, 2, 3
_THREADS = {TID_SCHED: "scheduler", TID_DYNAMIC: "dynamic coding",
            TID_RECODE: "recoding", TID_QUEUES: "queues"}


def _meta_events() -> List[dict]:
    ev = [{"name": "process_name", "ph": "M", "pid": PID,
           "args": {"name": "coded-memory-system"}}]
    for tid, name in _THREADS.items():
        ev.append({"name": "thread_name", "ph": "M", "pid": PID, "tid": tid,
                   "args": {"name": name}})
    return ev


def record_timeline(system, source, *, chunk_len: Optional[int] = None,
                    tn=None, region_priors=None,
                    max_cycles: int = 4096) -> List[dict]:
    """Replay ``source`` through ``system`` cycle by cycle, returning
    Chrome-trace events.

    ``source`` is anything ``repro.traces.source.as_source`` accepts (an
    in-memory ``Trace``, chunk iterable, or ``TraceSource``); ``chunk_len``
    stages it like ``stream_replay`` (None = one staging window sized to
    the default chunk length). ``max_cycles`` bounds the host-stepped loop
    — a timeline is a magnifying glass, not a bulk instrument.
    """
    from repro.core.system import quiescent
    from repro.traces.source import as_source
    from repro.traces.stream import DEFAULT_CHUNK_LEN, chunk_bound

    src = as_source(source)
    clen = chunk_len if chunk_len is not None else DEFAULT_CHUNK_LEN
    tn = tn if tn is not None else system.tunables
    st = system.init(tn, region_priors=region_priors)
    bound = chunk_bound(system, clen)
    pos = np.zeros(system.n_cores, np.int64)

    events = _meta_events()
    open_spans: Dict[int, str] = {}     # tid -> open B-span name

    def begin(tid, name, ts, **args):
        open_spans[tid] = name
        events.append({"name": name, "ph": "B", "ts": ts, "pid": PID,
                       "tid": tid, "args": args})

    def end(tid, ts):
        name = open_spans.pop(tid, None)
        if name is not None:
            events.append({"name": name, "ph": "E", "ts": ts, "pid": PID,
                           "tid": tid})

    def instant(tid, name, ts, **args):
        events.append({"name": name, "ph": "i", "s": "t", "ts": ts,
                       "pid": PID, "tid": tid, "args": args})

    def counter(name, ts, values):
        events.append({"name": name, "ph": "C", "ts": ts, "pid": PID,
                       "args": values})

    prev_wm, prev_enc, prev_sw, prev_rc = False, -1, 0, 0
    prev_stalls = 0
    total_cycles = 0
    while total_cycles < max_cycles:
        chunk, stream_end = src.stage(pos, clen)
        st = st._replace(core_ptr=jnp.zeros_like(st.core_ptr))
        staged = np.asarray(jax.device_get(stream_end))
        instant(TID_SCHED, "chunk restage", int(jax.device_get(st.mem.cycle)),
                pos=[int(x) for x in pos],
                staged=[int(x) for x in np.minimum(staged, clen)])
        chunk_cycles = 0
        while total_cycles < max_cycles and chunk_cycles < bound:
            st, out = system.cycle_fn(st, chunk, tn, stream_end)
            (cyc, wm, enc_region, enc_slot, switches, rc_backlog, n_served,
             rq_occ, wq_occ, stalls_lo, ptr, quiet) = jax.device_get((
                 st.mem.cycle, st.mem.write_mode, st.mem.enc_region,
                 st.mem.enc_slot, st.mem.switches,
                 jnp.sum(st.mem.rc_valid), out.n_served,
                 jnp.sum(st.mem.rq_valid), jnp.sum(st.mem.wq_valid),
                 st.mem.stall_cycles[0], st.core_ptr, quiescent(st)))
            ts = int(cyc)           # post-increment: the cycle just executed
            total_cycles += 1
            chunk_cycles += 1
            wm, enc_region, switches = bool(wm), int(enc_region), int(switches)
            rc_backlog, stalls = int(rc_backlog), int(stalls_lo)

            if wm and not prev_wm:
                begin(TID_SCHED, "write drain", ts)
            elif prev_wm and not wm:
                end(TID_SCHED, ts)
            if enc_region >= 0 and prev_enc < 0:
                begin(TID_DYNAMIC, f"encode region {enc_region}", ts,
                      region=enc_region, slot=int(enc_slot))
            elif prev_enc >= 0 and enc_region < 0:
                end(TID_DYNAMIC, ts)
            if switches > prev_sw:
                instant(TID_DYNAMIC, "region switch", ts, total=switches)
            if rc_backlog < prev_rc:
                instant(TID_RECODE, "recode burst", ts,
                        retired=prev_rc - rc_backlog)
            counter("queue occupancy", ts, {"read": int(rq_occ),
                                            "write": int(wq_occ)})
            counter("arbiter grants", ts, {"served": int(n_served)})
            counter("recode backlog", ts, {"pending": rc_backlog})
            if stalls != prev_stalls:
                counter("stalled cores", ts,
                        {"stalls": stalls - prev_stalls})
            prev_wm, prev_enc, prev_sw = wm, enc_region, switches
            prev_rc, prev_stalls = rc_backlog, stalls

            tlen = chunk.bank.shape[1]
            starved = bool(np.any((np.asarray(ptr) >= tlen)
                                  & (staged > tlen)))
            if starved or bool(quiet):
                break
        moved = np.asarray(jax.device_get(st.core_ptr), np.int64)
        pos += moved
        if src.exhausted(pos) and bool(jax.device_get(quiescent(st))):
            break
        if not moved.any():
            break                      # no progress: budget exhausted
    ts_end = int(jax.device_get(st.mem.cycle))
    for tid in list(open_spans):
        end(tid, ts_end)
    return events


def export_chrome_trace(events: List[dict], path: str,
                        manifest: Optional[dict] = None) -> str:
    """Write events as a Chrome-trace JSON file (Perfetto-loadable)."""
    from repro.obs.runlog import run_manifest
    blob = {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"manifest": manifest or run_manifest(),
                          "time_unit": "1 us = 1 simulated cycle"}}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(blob, f, default=float)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scheme", default="scheme_i")
    ap.add_argument("--trace", default="banded",
                    help="trace generator (repro.sim.trace.TRACES)")
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--r", type=float, default=0.05)
    ap.add_argument("--n-rows", type=int, default=128)
    ap.add_argument("--length", type=int, default=96)
    ap.add_argument("--chunk-len", type=int, default=32)
    ap.add_argument("--select-period", type=int, default=32)
    ap.add_argument("--max-cycles", type=int, default=4096)
    ap.add_argument("--out", default="experiments/obs/timeline.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI artifact smoke)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.length, args.n_rows, args.max_cycles = 32, 64, 512

    from repro.sweep.engine import system_for
    from repro.sweep.grid import SweepPoint
    from repro.sweep.workloads import build_trace
    pt = SweepPoint(scheme=args.scheme, trace=args.trace, alpha=args.alpha,
                    r=args.r, n_rows=args.n_rows, length=args.length,
                    select_period=args.select_period)
    system = system_for(pt)
    from repro.sweep.engine import stack_tunables
    tn = jax.tree.map(lambda x: x[0], stack_tunables([pt],
                                                     system.p.queue_depth))
    events = record_timeline(system, build_trace(pt),
                             chunk_len=args.chunk_len, tn=tn,
                             max_cycles=args.max_cycles)
    from repro.obs.runlog import run_manifest
    path = export_chrome_trace(events, args.out,
                               manifest=run_manifest(config=pt))
    n_real = sum(1 for e in events if e["ph"] != "M")
    print(f"wrote {path}: {len(events)} events ({n_real} non-metadata) — "
          f"open in chrome://tracing or ui.perfetto.dev")
    return 0 if n_real > 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
