"""Request-path observability for the coded KV serving stack.

Two halves, mirroring ``obs/planes.py``'s split:

* **Device planes** (``ServeTelemetry``): uint32 counters updated inside
  the jitted pooled decode step — per-bank load histograms, direct vs
  degraded read provenance, per-bank port-cycle (critical-word) latency
  log2 histograms, and the stale-parity/ReCoding backlog. Telemetry off is
  a ``None`` leaf in the serve cache: the carry structure and the compiled
  program are bit-identical to a build that never heard of telemetry
  (locked by ``repro.analysis.jaxpr.lint_serve_step``).
* **Host spans** (``ServeLog``): per-request lifecycle events
  (queued → prefill → decode slot → finished) with admission wait, TTFT
  and inter-token latency, exported through ``obs/timeline.py``'s
  Chrome-trace layer and summarized by ``repro.obs.report --serve``.

Every device counter has an independent pure-NumPy recompute in
``repro.oracle.kvpool``; ``ServeSnapshot.check_against`` compares them
exactly and raises on any mismatch.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs.planes import HIST_BINS, lat_bin

# Chrome-trace thread ids for the serving rows (timeline.py owns 1..4)
TID_SERVE_QUEUE = 10       # admission waits
TID_SERVE_SLOT0 = 11       # decode slots: TID_SERVE_SLOT0 + slot index


class ServeTelemetry(NamedTuple):
    """Device-side serving metric planes (all uint32)."""
    bank_load_hist: jnp.ndarray   # (NB, HIST_BINS) per-step load histogram
    read_mode_bank: jnp.ndarray   # (NB, 2) [direct, degraded] by home bank
    port_lat_hist: jnp.ndarray    # (NB, HIST_BINS) critical-word latency,
    #                               attributed to the port that served it
    stale_backlog: jnp.ndarray    # () post-recode stale-row integral
    stale_hwm: jnp.ndarray        # () stale-row high-water mark
    recoded_rows: jnp.ndarray     # () rows the ReCoding unit refreshed
    decode_steps: jnp.ndarray     # ()
    appended_tokens: jnp.ndarray  # ()
    uncoded_cycles: jnp.ndarray   # () sum of per-step uncoded port cycles
    coded_cycles: jnp.ndarray     # () sum of per-step coded port cycles


def init_serve_telemetry(n_banks: int) -> ServeTelemetry:
    u = jnp.uint32
    z = jnp.zeros
    return ServeTelemetry(
        bank_load_hist=z((n_banks, HIST_BINS), u),
        read_mode_bank=z((n_banks, 2), u),
        port_lat_hist=z((n_banks, HIST_BINS), u),
        stale_backlog=z((), u), stale_hwm=z((), u), recoded_rows=z((), u),
        decode_steps=z((), u), appended_tokens=z((), u),
        uncoded_cycles=z((), u), coded_cycles=z((), u))


def update_serve_telemetry(tele: ServeTelemetry, *, load, needed, bank,
                           use_parity, latencies, stale_before, recoded,
                           appended, uncoded_cycles,
                           coded_cycles) -> ServeTelemetry:
    """Fold one pooled decode step's plan into the planes (traced)."""
    nb = tele.bank_load_hist.shape[0]
    direct = needed & ~use_parity
    deg = needed & use_parity
    u32 = jnp.uint32
    loads = tele.bank_load_hist.at[jnp.arange(nb), lat_bin(load)].add(1)
    modes = tele.read_mode_bank.at[
        jnp.where(direct, bank, nb), 0].add(1, mode="drop")
    modes = modes.at[jnp.where(deg, bank, nb), 1].add(1, mode="drop")
    port = jnp.where(deg, bank ^ 1, bank)
    hist = tele.port_lat_hist.at[
        jnp.where(needed, port, nb), lat_bin(latencies)].add(1, mode="drop")
    sb = stale_before.astype(u32)
    rc = recoded.astype(u32)
    return tele._replace(
        bank_load_hist=loads, read_mode_bank=modes, port_lat_hist=hist,
        stale_backlog=tele.stale_backlog + sb - rc,
        stale_hwm=jnp.maximum(tele.stale_hwm, sb),
        recoded_rows=tele.recoded_rows + rc,
        decode_steps=tele.decode_steps + 1,
        appended_tokens=tele.appended_tokens + appended.astype(u32),
        uncoded_cycles=tele.uncoded_cycles + uncoded_cycles.astype(u32),
        coded_cycles=tele.coded_cycles + coded_cycles.astype(u32))


class ServeSnapshot:
    """Host-side view of the serving planes with derived aggregates."""

    def __init__(self, tele: ServeTelemetry):
        self.bank_load_hist = np.asarray(tele.bank_load_hist, np.int64)
        self.read_mode_bank = np.asarray(tele.read_mode_bank, np.int64)
        self.port_lat_hist = np.asarray(tele.port_lat_hist, np.int64)
        self.stale_backlog = int(tele.stale_backlog)
        self.stale_hwm = int(tele.stale_hwm)
        self.recoded_rows = int(tele.recoded_rows)
        self.decode_steps = int(tele.decode_steps)
        self.appended_tokens = int(tele.appended_tokens)
        self.uncoded_cycles = int(tele.uncoded_cycles)
        self.coded_cycles = int(tele.coded_cycles)

    # ------------------------------------------------------------ derived
    @property
    def direct_reads(self) -> int:
        return int(self.read_mode_bank[:, 0].sum())

    @property
    def degraded_reads(self) -> int:
        return int(self.read_mode_bank[:, 1].sum())

    @property
    def served_pages(self) -> int:
        return self.direct_reads + self.degraded_reads

    @property
    def cycles_saved(self) -> int:
        return self.uncoded_cycles - self.coded_cycles

    def as_dict(self) -> Dict:
        return {
            "bank_load_hist": self.bank_load_hist.tolist(),
            "read_mode_bank": self.read_mode_bank.tolist(),
            "port_lat_hist": self.port_lat_hist.tolist(),
            "stale_backlog": self.stale_backlog,
            "stale_hwm": self.stale_hwm,
            "recoded_rows": self.recoded_rows,
            "decode_steps": self.decode_steps,
            "appended_tokens": self.appended_tokens,
            "uncoded_cycles": self.uncoded_cycles,
            "coded_cycles": self.coded_cycles,
            "direct_reads": self.direct_reads,
            "degraded_reads": self.degraded_reads,
            "served_pages": self.served_pages,
            "cycles_saved": self.cycles_saved,
        }

    def check_against(self, totals) -> None:
        """Exact conformance vs ``repro.oracle.kvpool.PlaneTotals``;
        raises AssertionError on the first disagreeing counter."""
        for field in ("bank_load_hist", "read_mode_bank", "port_lat_hist"):
            dev, exp = getattr(self, field), getattr(totals, field)
            if not np.array_equal(dev, np.asarray(exp)):
                raise AssertionError(
                    f"serve plane {field!r} disagrees with the oracle "
                    f"recompute:\ndevice=\n{dev}\noracle=\n{exp}")
        for field in ("stale_backlog", "stale_hwm", "recoded_rows",
                      "decode_steps", "appended_tokens", "uncoded_cycles",
                      "coded_cycles"):
            dev, exp = getattr(self, field), int(getattr(totals, field))
            if dev != exp:
                raise AssertionError(
                    f"serve counter {field!r}: device={dev} oracle={exp}")


def snapshot(tele: ServeTelemetry) -> ServeSnapshot:
    return ServeSnapshot(tele)


# ---------------------------------------------------------------------------
# Host-side request lifecycle spans
# ---------------------------------------------------------------------------

class _Req:
    __slots__ = ("rid", "submit", "admit", "prefill_done", "slot",
                 "prompt_len", "tokens", "finish")

    def __init__(self, rid, now):
        self.rid = rid
        self.submit = now
        self.admit = None
        self.prefill_done = None
        self.slot = None
        self.prompt_len = 0
        self.tokens: List[float] = []   # decode-token completion times
        self.finish = None


class ServeLog:
    """Per-request lifecycle spans, recorded host-side by the server.

    The clock is injectable so tests can drive it deterministically; the
    default is ``time.perf_counter``.
    """

    def __init__(self, clock=None):
        if clock is None:
            import time
            clock = time.perf_counter
        self._clock = clock
        self._t0 = clock()
        self._reqs: Dict[int, _Req] = {}

    def _now(self) -> float:
        return self._clock() - self._t0

    def _get(self, rid: int) -> _Req:
        # requests restored from another node's snapshot were never
        # submitted here — adopt them with submit = now
        if rid not in self._reqs:
            self._reqs[rid] = _Req(rid, self._now())
        return self._reqs[rid]

    # ------------------------------------------------------------- events
    def submit(self, rid: int) -> None:
        self._reqs[rid] = _Req(rid, self._now())

    def admit(self, rid: int, slot: int, prompt_len: int) -> None:
        r = self._get(rid)
        r.admit, r.slot, r.prompt_len = self._now(), slot, prompt_len

    def prefill_done(self, rid: int) -> None:
        self._get(rid).prefill_done = self._now()

    def token(self, rid: int) -> None:
        self._get(rid).tokens.append(self._now())

    def finish(self, rid: int) -> None:
        self._get(rid).finish = self._now()

    # ------------------------------------------------------------ queries
    def spans(self) -> List[Dict]:
        out = []
        for r in sorted(self._reqs.values(), key=lambda r: r.rid):
            ticks = ([r.prefill_done] if r.prefill_done is not None else []) \
                + r.tokens
            itl = [b - a for a, b in zip(ticks, ticks[1:])]
            out.append({
                "rid": r.rid, "slot": r.slot, "prompt_len": r.prompt_len,
                "submit_s": r.submit, "admit_s": r.admit,
                "finish_s": r.finish,
                "admission_wait_s":
                    None if r.admit is None else r.admit - r.submit,
                "ttft_s": None if r.prefill_done is None
                    else r.prefill_done - r.submit,
                "n_tokens": len(ticks),
                "inter_token_s": itl,
            })
        return out

    def summary(self) -> Dict:
        spans = self.spans()
        ttfts = [s["ttft_s"] for s in spans if s["ttft_s"] is not None]
        waits = [s["admission_wait_s"] for s in spans
                 if s["admission_wait_s"] is not None]
        itl = [x for s in spans for x in s["inter_token_s"]]
        pct = (lambda xs, q:
               float(np.percentile(np.asarray(xs), q)) if xs else None)
        return {
            "requests": len(spans),
            "finished": sum(s["finish_s"] is not None for s in spans),
            "tokens": sum(s["n_tokens"] for s in spans),
            "ttft_p50_s": pct(ttfts, 50), "ttft_p99_s": pct(ttfts, 99),
            "admission_wait_p50_s": pct(waits, 50),
            "inter_token_p50_s": pct(itl, 50),
            "inter_token_p99_s": pct(itl, 99),
        }

    # ------------------------------------------------------ chrome export
    def to_chrome_events(self) -> List[Dict]:
        """Serving rows for ``obs.timeline.export_chrome_trace``: one
        "queue" row plus one row per decode slot."""
        us = 1e6
        ev: List[Dict] = [
            {"name": "thread_name", "ph": "M", "pid": 0,
             "tid": TID_SERVE_QUEUE, "args": {"name": "serve queue"}},
        ]
        slots = sorted({r.slot for r in self._reqs.values()
                        if r.slot is not None})
        for s in slots:
            ev.append({"name": "thread_name", "ph": "M", "pid": 0,
                       "tid": TID_SERVE_SLOT0 + s,
                       "args": {"name": f"serve slot {s}"}})
        for r in sorted(self._reqs.values(), key=lambda r: r.rid):
            if r.admit is not None:
                ev.append({"name": f"queued req {r.rid}", "ph": "X",
                           "pid": 0, "tid": TID_SERVE_QUEUE,
                           "ts": r.submit * us,
                           "dur": (r.admit - r.submit) * us,
                           "args": {"rid": r.rid}})
            if r.admit is None or r.slot is None:
                continue
            end = r.finish if r.finish is not None else (
                r.tokens[-1] if r.tokens else r.admit)
            ev.append({"name": f"req {r.rid}", "ph": "X", "pid": 0,
                       "tid": TID_SERVE_SLOT0 + r.slot, "ts": r.admit * us,
                       "dur": (end - r.admit) * us,
                       "args": {"rid": r.rid,
                                "prompt_len": r.prompt_len,
                                "n_tokens": len(r.tokens) + 1}})
            if r.prefill_done is not None:
                ev.append({"name": f"first token req {r.rid}", "ph": "i",
                           "pid": 0, "tid": TID_SERVE_SLOT0 + r.slot,
                           "ts": r.prefill_done * us, "s": "t"})
        return ev

    def export_chrome_trace(self, path: str,
                            manifest: Optional[Dict] = None) -> str:
        from repro.obs import timeline
        return timeline.export_chrome_trace(
            self.to_chrome_events(), path, manifest=manifest)


def format_summary(snap: ServeSnapshot) -> str:
    """One-paragraph console summary (used by launch/serve.py)."""
    lines = [
        f"serve planes: {snap.decode_steps} decode steps, "
        f"{snap.appended_tokens} tokens appended, "
        f"{snap.served_pages} page reads "
        f"({snap.degraded_reads} degraded)",
        f"  port cycles: coded {snap.coded_cycles} vs uncoded "
        f"{snap.uncoded_cycles} (saved {snap.cycles_saved})",
        f"  recode: {snap.recoded_rows} rows refreshed, backlog integral "
        f"{snap.stale_backlog}, high-water {snap.stale_hwm} stale rows",
    ]
    return "\n".join(lines)
