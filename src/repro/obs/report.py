"""Stall-attribution reports: where the cycles went, per bank and per cause.

``stall_report`` runs a paper suite (fig18/19/20) with telemetry planes on,
cross-checks the planes against the ``SimResult`` aggregates (the report
refuses to render numbers that disagree with the engine), and writes a
markdown report plus a machine-readable JSON twin into
``experiments/obs/``:

* a per-point summary table — stalls split by cause, wait cycles split by
  cause, served-read provenance (direct vs degraded) — the coded columns of
  Fig 18-20 with their *why* attached;
* a coded-vs-uncoded comparison for the suite's baseline pair;
* a per-bank heatmap for a coded exemplar (stalls, waits, queue high-water
  marks by bank) — the spatial view the aggregates flatten away;
* log2-binned read/write latency histograms for the same exemplar.

CLI::

    PYTHONPATH=src python -m repro.obs.report --suite paper_fig18 --smoke
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import numpy as np

from repro.obs import planes

# trimmed suite axes for --smoke (CI artifact job): one coded scheme, one α
_SMOKE_KW = {
    "paper_fig18": dict(schemes=("scheme_i",), alphas=(0.25,)),
    "paper_fig19": dict(rs=(0.05,), alphas=(0.25,)),
    "paper_fig20": dict(drifts=(0.0, 1.0), alphas=(0.25,)),
}


def _bar(v: int, vmax: int, width: int = 10) -> str:
    if vmax <= 0:
        return ""
    return "#" * max(int(round(width * v / vmax)), 1 if v else 0)


def _pct(num: int, den: int) -> str:
    return f"{100.0 * num / den:.1f}%" if den else "-"


def _md_table(headers: List[str], rows: List[List[str]]) -> List[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    out += ["| " + " | ".join(str(c) for c in row) + " |" for row in rows]
    return out


def _check_against_result(pt, res, snap) -> None:
    """The planes must sum exactly to the engine's own aggregates — a
    report built on disagreeing numbers is worse than no report."""
    pairs = [
        ("stall_cycles", snap.stall_total(), res.stall_cycles),
        ("served_reads", snap.served_reads(), res.served_reads),
        ("served_writes", snap.served_writes(), res.served_writes),
        ("degraded_reads", snap.degraded_reads(), res.degraded_reads),
        ("parked_writes", snap.parked_writes(), res.parked_writes),
        ("fault_degraded_reads", snap.fault_degraded_reads(),
         res.fault_degraded_reads),
        ("dead_bank_cycles", snap.dead_bank_cycles(), res.dead_bank_cycles),
    ]
    for name, plane, agg in pairs:
        if int(plane) != int(agg):
            raise AssertionError(
                f"telemetry plane disagrees with SimResult on {name} for "
                f"{pt.scheme} alpha={pt.alpha} r={pt.r}: plane sum "
                f"{int(plane)} != aggregate {int(agg)}")


def _point_row(pt, res, snap) -> List[str]:
    st = snap.stall_by_cause()
    wt = snap.wait_by_cause()
    return [
        pt.scheme, f"{pt.alpha:g}", f"{pt.r:g}", str(res.cycles),
        str(res.served_reads), str(res.served_writes),
        str(snap.stall_total()),
        str(st["read_queue_full"]), str(st["write_queue_full"]),
        str(wt["read_conflict"]), str(wt["write_conflict"]),
        str(wt["recode_pending"]),
        _pct(snap.degraded_reads(), res.served_reads),
        _pct(snap.parked_writes(), res.served_writes),
    ]


def _bank_heatmap(snap) -> List[str]:
    n_data = snap.stall_cause.shape[0]
    rows = []
    hw = np.maximum(snap.rq_hwm, 0)
    for b in range(n_data):
        rows.append([
            str(b),
            str(int(snap.stall_cause[b, 0])), str(int(snap.stall_cause[b, 1])),
            str(int(snap.wait_cause[b, 0])), str(int(snap.wait_cause[b, 1])),
            str(int(snap.wait_cause[b, 2])),
            str(int(hw[b])), str(int(max(snap.wq_hwm[b], 0))),
            _bar(int(snap.wait_cause[b].sum()),
                 int(max(snap.wait_cause.sum(axis=1).max(), 1))),
        ])
    return _md_table(
        ["bank", "stall:rq_full", "stall:wq_full", "wait:read", "wait:write",
         "wait:recode", "rq hwm", "wq hwm", "wait load"], rows)


def _latency_section(snap) -> List[str]:
    lines = ["| bin | latency | reads | writes | |", "|---|---|---|---|---|"]
    vmax = int(max(snap.lat_hist_read.max(), snap.lat_hist_write.max(), 1))
    for k in range(planes.HIST_BINS):
        r, w = int(snap.lat_hist_read[k]), int(snap.lat_hist_write[k])
        if r == 0 and w == 0:
            continue
        lo = 0 if k == 0 else 1 << (k - 1)
        hi = "inf" if k == planes.HIST_BINS - 1 else (1 << k) - 1
        span = str(lo) if hi != "inf" and lo == int(hi) else f"{lo}-{hi}"
        lines.append(f"| {k} | {span} | {r} | {w} | {_bar(r + w, 2 * vmax)} |")
    return lines


def stall_report(suite_name: str = "paper_fig18", *,
                 base=None, out_dir: str = "experiments/obs",
                 smoke: bool = False, **suite_kw) -> Dict:
    """Run ``suite_name`` with telemetry on and write the attribution report.

    Returns ``{"md_path", "json_path", "points", "results", "snapshots"}``
    so tests and callers can assert on the numbers without re-parsing."""
    from repro.obs.runlog import run_manifest
    from repro.sweep.engine import run_points
    from repro.sweep.grid import SweepPoint
    from repro.sweep.workloads import build_trace, suite

    if base is None:
        base = SweepPoint(length=32, n_rows=64) if smoke else \
            SweepPoint(length=96, n_rows=128)
    kw = dict(_SMOKE_KW.get(suite_name, {})) if smoke else {}
    kw.update(suite_kw)
    pts = [pt.replace(telemetry=True) for pt in suite(suite_name, base, **kw)]
    traces = [build_trace(pt, index=i) for i, pt in enumerate(pts)]
    results, snaps = run_points(pts, traces=traces, collect_telemetry=True)
    for pt, res, snap in zip(pts, results, snaps):
        if snap is None:
            raise AssertionError(f"telemetry-on point returned no snapshot: "
                                 f"{pt.scheme} alpha={pt.alpha}")
        _check_against_result(pt, res, snap)

    manifest = run_manifest(config={"suite": suite_name, "smoke": smoke,
                                    "n_points": len(pts)})
    # exemplar: the busiest coded point (most wait cycles) gets the
    # per-bank and latency deep dives; uncoded is the comparison anchor
    coded = [i for i, pt in enumerate(pts) if pt.scheme != "uncoded"]
    uncoded = [i for i, pt in enumerate(pts) if pt.scheme == "uncoded"]
    ex = max(coded, key=lambda i: int(snaps[i].wait_cause.sum())) \
        if coded else 0

    lines = [f"# Stall attribution — {suite_name}", "",
             f"git `{manifest['git_sha'][:12]}` · "
             f"{manifest['created_iso']} · "
             f"{manifest['devices']['backend']} backend · "
             f"{len(pts)} points" + (" · smoke" if smoke else ""), "",
             "Planes cross-checked against `SimResult` aggregates "
             "(stalls, served, degraded, parked) — exact equality "
             "asserted before rendering.", "", "## Per-point summary", ""]
    lines += _md_table(
        ["scheme", "alpha", "r", "cycles", "reads", "writes", "stalls",
         "rq full", "wq full", "wait rd", "wait wr", "wait rc",
         "degraded", "parked"],
        [_point_row(pt, res, snap)
         for pt, res, snap in zip(pts, results, snaps)])

    if coded and uncoded:
        u, c = uncoded[0], ex
        ur, cr = results[u], results[c]
        lines += ["", "## Coded vs uncoded", "",
                  f"Exemplar: `{pts[c].scheme}` alpha={pts[c].alpha:g} "
                  f"r={pts[c].r:g} vs `uncoded`.", ""]
        lines += _md_table(
            ["metric", "uncoded", pts[c].scheme],
            [["cycles", str(ur.cycles), str(cr.cycles)],
             ["stall cycles", str(ur.stall_cycles), str(cr.stall_cycles)],
             ["wait cycles (all causes)",
              str(int(snaps[u].wait_cause.sum())),
              str(int(snaps[c].wait_cause.sum()))],
             ["degraded reads", _pct(snaps[u].degraded_reads(),
                                     ur.served_reads),
              _pct(snaps[c].degraded_reads(), cr.served_reads)],
             ["parked writes", _pct(snaps[u].parked_writes(),
                                    ur.served_writes),
              _pct(snaps[c].parked_writes(), cr.served_writes)]])

    expt = pts[ex]
    lines += ["", f"## Per-bank heatmap — `{expt.scheme}` "
              f"alpha={expt.alpha:g} r={expt.r:g}", ""]
    lines += _bank_heatmap(snaps[ex])
    lines += ["", "## Latency histograms (log2 bins, cycles) — exemplar", ""]
    lines += _latency_section(snaps[ex])
    lines.append("")

    os.makedirs(out_dir, exist_ok=True)
    md_path = os.path.join(out_dir, f"stall_report_{suite_name}.md")
    with open(md_path, "w") as f:
        f.write("\n".join(lines))
    json_path = os.path.join(out_dir, f"stall_report_{suite_name}.json")
    blob = {"suite": suite_name, "manifest": manifest,
            "points": [{"scheme": pt.scheme, "alpha": pt.alpha, "r": pt.r,
                        "seed": pt.seed, "label": pt.label,
                        "cycles": int(res.cycles),
                        "stall_cycles": int(res.stall_cycles),
                        "telemetry": snap.as_dict()}
                       for pt, res, snap in zip(pts, results, snaps)]}
    with open(json_path, "w") as f:
        json.dump(blob, f, default=float)
    return {"md_path": md_path, "json_path": json_path, "points": pts,
            "results": results, "snapshots": snaps}


def availability_report(suite_name: str = "paper_fig18", *,
                        faults=(("bank", 0, 0),), base=None,
                        out_dir: str = "experiments/obs",
                        smoke: bool = False, **suite_kw) -> Dict:
    """Degraded-serving report: run ``suite_name`` with a fault plan
    installed on every point (default: data bank 0 dead from cycle 0) and
    telemetry on, and render the availability view — reads served vs
    failed fast, writes lost, the fault-degraded share, and per-bank
    dead-cycle counters. The planes are cross-checked against the
    ``SimResult`` aggregates exactly like ``stall_report``. Returns the
    same ``{"md_path", "json_path", "points", "results", "snapshots"}``."""
    from repro.obs.runlog import run_manifest
    from repro.sweep.engine import run_points
    from repro.sweep.grid import SweepPoint
    from repro.sweep.workloads import build_trace, suite

    if base is None:
        base = SweepPoint(length=32, n_rows=64) if smoke else \
            SweepPoint(length=96, n_rows=128)
    kw = dict(_SMOKE_KW.get(suite_name, {})) if smoke else {}
    kw.update(suite_kw)
    pts = [pt.replace(telemetry=True, faults=tuple(faults))
           for pt in suite(suite_name, base, **kw)]
    traces = [build_trace(pt, index=i) for i, pt in enumerate(pts)]
    results, snaps = run_points(pts, traces=traces, collect_telemetry=True)
    for pt, res, snap in zip(pts, results, snaps):
        if snap is None:
            raise AssertionError(f"telemetry-on point returned no snapshot: "
                                 f"{pt.scheme} alpha={pt.alpha}")
        _check_against_result(pt, res, snap)

    manifest = run_manifest(config={"suite": suite_name, "smoke": smoke,
                                    "faults": list(map(list, faults)),
                                    "n_points": len(pts)})
    lines = [f"# Fault availability — {suite_name}", "",
             f"git `{manifest['git_sha'][:12]}` · "
             f"{manifest['created_iso']} · "
             f"{manifest['devices']['backend']} backend · "
             f"{len(pts)} points · fault plan `{tuple(faults)}`"
             + (" · smoke" if smoke else ""), "",
             "A read is *unserved* when the fail-fast drop found no serving "
             "option under the failures; a write is *lost* when its bank is "
             "down with no parity coverage to park into. *Fault-degraded* "
             "reads were served through parity because their bank was down "
             "— availability the coding bought.", "",
             "## Per-point availability", ""]
    rows = []
    for pt, res, snap in zip(pts, results, snaps):
        issued_r = res.served_reads + res.unserved_reads
        rows.append([
            pt.scheme, f"{pt.alpha:g}", f"{pt.r:g}", str(res.cycles),
            _pct(res.served_reads, issued_r), str(res.unserved_reads),
            str(res.lost_writes),
            _pct(snap.fault_degraded_reads(), res.served_reads),
            str(res.dead_bank_cycles),
        ])
    lines += _md_table(
        ["scheme", "alpha", "r", "cycles", "reads served", "unserved",
         "lost wr", "fault-degraded", "dead cycles"], rows)

    # per-bank dead-cycle heat for the point with the most dead cycles
    ex = max(range(len(pts)),
             key=lambda i: int(snaps[i].dead_cycles.sum()))
    expt, snap = pts[ex], snaps[ex]
    lines += ["", f"## Per-bank dead cycles — `{expt.scheme}` "
              f"alpha={expt.alpha:g} r={expt.r:g}", ""]
    vmax = int(max(snap.dead_cycles.max(), 1))
    lines += _md_table(
        ["bank", "dead cycles", ""],
        [[str(b), str(int(snap.dead_cycles[b])),
          _bar(int(snap.dead_cycles[b]), vmax)]
         for b in range(snap.dead_cycles.shape[0])])
    lines.append("")

    os.makedirs(out_dir, exist_ok=True)
    md_path = os.path.join(out_dir, f"availability_{suite_name}.md")
    with open(md_path, "w") as f:
        f.write("\n".join(lines))
    json_path = os.path.join(out_dir, f"availability_{suite_name}.json")
    blob = {"suite": suite_name, "manifest": manifest,
            "points": [{"scheme": pt.scheme, "alpha": pt.alpha, "r": pt.r,
                        "seed": pt.seed, "label": pt.label,
                        "cycles": int(res.cycles),
                        "unserved_reads": int(res.unserved_reads),
                        "lost_writes": int(res.lost_writes),
                        "fault_degraded_reads": int(res.fault_degraded_reads),
                        "dead_bank_cycles": int(res.dead_bank_cycles),
                        "telemetry": snap.as_dict()}
                       for pt, res, snap in zip(pts, results, snaps)]}
    with open(json_path, "w") as f:
        json.dump(blob, f, default=float)
    return {"md_path": md_path, "json_path": json_path, "points": pts,
            "results": results, "snapshots": snaps}


def drive_serve_with_oracle(srv, reqs, max_steps: int = 1000,
                            churn_every: int = 0, churn_rng=None):
    """Drive a pooled server to drain while replaying every decode step in
    the ``repro.oracle.kvpool`` golden model. Returns the accumulated
    ``PlaneTotals``; also asserts the device code-status table tracks the
    oracle's replay exactly after every step. ``churn_every`` applies a
    seeded physical-page permutation every k steps (placement churn — the
    regime where degraded reads pay off)."""
    from repro.oracle import kvpool

    totals = kvpool.plane_totals(srv.kvcfg.n_banks)
    for r in reqs:
        srv.submit(r)
    for step in range(max_steps):
        srv._admit()
        if churn_every and step and step % churn_every == 0:
            srv.permute_pool(churn_rng.permutation(srv.kvcfg.pool_pages))
        if not any(s is not None for s in srv.slots):
            break
        pool = srv.cache["pool"]
        pt = np.asarray(pool.page_table)
        length = np.asarray(pool.length)
        fresh = np.asarray(pool.parity_fresh) \
            if pool.parity_fresh.shape[0] else None
        active = (pt[:, 0] >= 0) & (length > 0)
        exp = kvpool.expected_step(srv.kvcfg.n_banks, srv.kvcfg.page, pt,
                                   length, fresh, active,
                                   srv.sc.recode_budget)
        totals.add(exp)
        srv.step_decode()
        if fresh is not None:
            post = np.asarray(srv.cache["pool"].parity_fresh)
            if not np.array_equal(post, exp.parity_fresh_after):
                raise AssertionError(
                    "code-status table diverged from the oracle replay")
    return totals


def _serve_lifecycle_table(spans) -> List[str]:
    rows = []
    for s in spans:
        ms = (lambda x: f"{1e3 * x:.1f}" if x is not None else "-")
        itl = s["inter_token_s"]
        rows.append([
            str(s["rid"]), str(s["slot"]), str(s["prompt_len"]),
            ms(s["admission_wait_s"]), ms(s["ttft_s"]), str(s["n_tokens"]),
            ms(float(np.mean(itl)) if itl else None),
        ])
    return _md_table(
        ["req", "slot", "prompt", "wait ms", "ttft ms", "tokens",
         "mean itl ms"], rows)


def serve_report(*, out_dir: str = "experiments/obs", smoke: bool = False,
                 seed: int = 0) -> Dict:
    """Run a small continuous-batching workload over the coded KV pool with
    the serve metric planes on, cross-check every counter against the
    ``repro.oracle.kvpool`` recompute (exact equality — the report refuses
    to render numbers that disagree), and write the request-path report:
    markdown + JSON twin + a Chrome-trace of the request lifecycle spans."""
    import dataclasses as _dc

    import jax

    from repro.configs import get_config
    from repro.models import lm as lm_mod
    from repro.obs.runlog import run_manifest
    from repro.runtime.server import Request, ServeConfig, Server

    cfg = _dc.replace(get_config("qwen2.5-3b").reduced(), kv_page=4)
    n_req = 5 if smoke else 10
    sc = ServeConfig(n_slots=4, max_prompt=16, max_seq=64,
                     max_new_tokens=6 if smoke else 16, telemetry=True)
    params = lm_mod.init_params(cfg, jax.random.key(seed), max_seq=sc.max_seq)
    srv = Server(cfg, sc, params)
    assert srv.pooled, "serve_report needs the coded KV pool backend"
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=[int(x) for x in
                            rng.integers(1, cfg.vocab, size=6 + i % 8)])
            for i in range(n_req)]
    totals = drive_serve_with_oracle(srv, reqs, churn_every=2,
                                     churn_rng=np.random.default_rng(seed))
    snap = srv.serve_snapshot()
    assert snap is not None
    snap.check_against(totals)          # exact equality or AssertionError
    spans = srv.log.spans()
    summary = srv.log.summary()

    manifest = run_manifest(config={
        "model": cfg.name, "smoke": smoke, "n_requests": n_req,
        "n_slots": sc.n_slots, "page": srv.kvcfg.page,
        "n_banks": srv.kvcfg.n_banks, "pool_pages": srv.kvcfg.pool_pages})
    os.makedirs(out_dir, exist_ok=True)
    trace_path = os.path.join(out_dir, "serve_trace.json")
    srv.log.export_chrome_trace(trace_path, manifest=manifest)

    lines = ["# Serving request path — coded KV pool", "",
             f"git `{manifest['git_sha'][:12]}` · "
             f"{manifest['created_iso']} · "
             f"{manifest['devices']['backend']} backend · "
             f"{n_req} requests, {sc.n_slots} slots, "
             f"{srv.kvcfg.n_banks} banks, page {srv.kvcfg.page}"
             + (" · smoke" if smoke else ""), "",
             "Device planes cross-checked against the pure-NumPy "
             "`repro.oracle.kvpool` recompute — exact equality asserted "
             "before rendering.", "", "## Serving planes", ""]
    lines += _md_table(["metric", "value"], [
        ["decode steps", str(snap.decode_steps)],
        ["tokens appended", str(snap.appended_tokens)],
        ["page reads", str(snap.served_pages)],
        ["degraded reads", f"{snap.degraded_reads} "
         f"({_pct(snap.degraded_reads, snap.served_pages)})"],
        ["port cycles coded / uncoded",
         f"{snap.coded_cycles} / {snap.uncoded_cycles} "
         f"(saved {snap.cycles_saved})"],
        ["recoded rows", str(snap.recoded_rows)],
        ["stale backlog integral / high-water",
         f"{snap.stale_backlog} / {snap.stale_hwm}"],
    ])
    lines += ["", "## Per-bank read provenance", ""]
    vmax = int(max(snap.read_mode_bank.sum(axis=1).max(), 1))
    lines += _md_table(
        ["bank", "direct", "degraded", "load"],
        [[str(b), str(int(snap.read_mode_bank[b, 0])),
          str(int(snap.read_mode_bank[b, 1])),
          _bar(int(snap.read_mode_bank[b].sum()), vmax)]
         for b in range(snap.read_mode_bank.shape[0])])
    lines += ["", "## Critical-word latency (log2 bins, port cycles)", ""]
    agg = snap.port_lat_hist.sum(axis=0)
    hmax = int(max(agg.max(), 1))
    lines += ["| bin | latency | reads | |", "|---|---|---|---|"]
    for k in range(planes.HIST_BINS):
        if int(agg[k]) == 0:
            continue
        lo = 0 if k == 0 else 1 << (k - 1)
        hi = "inf" if k == planes.HIST_BINS - 1 else (1 << k) - 1
        span = str(lo) if hi != "inf" and lo == int(hi) else f"{lo}-{hi}"
        lines.append(f"| {k} | {span} | {int(agg[k])} | "
                     f"{_bar(int(agg[k]), hmax)} |")
    lines += ["", "## Request lifecycle", ""]
    lines += _serve_lifecycle_table(spans)
    ttft = summary["ttft_p50_s"]
    lines += ["", f"TTFT p50 {1e3 * ttft:.1f} ms · "
              f"admission wait p50 "
              f"{1e3 * (summary['admission_wait_p50_s'] or 0):.1f} ms · "
              f"spans exported to `{trace_path}`"
              if ttft is not None else "", ""]

    md_path = os.path.join(out_dir, "serve_report.md")
    with open(md_path, "w") as f:
        f.write("\n".join(lines))
    json_path = os.path.join(out_dir, "serve_report.json")
    blob = {"manifest": manifest, "planes": snap.as_dict(),
            "lifecycle": {"summary": summary, "spans": spans},
            "trace_path": trace_path}
    with open(json_path, "w") as f:
        json.dump(blob, f, default=float)
    return {"md_path": md_path, "json_path": json_path,
            "trace_path": trace_path, "snapshot": snap, "totals": totals,
            "spans": spans, "summary": summary}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--suite", default="paper_fig18",
                    choices=("paper_fig18", "paper_fig19", "paper_fig20"))
    ap.add_argument("--out-dir", default="experiments/obs")
    ap.add_argument("--smoke", action="store_true",
                    help="trimmed axes + tiny trace (CI artifact smoke)")
    ap.add_argument("--availability", action="store_true",
                    help="fault-availability report (repro.faults) instead "
                         "of stall attribution")
    ap.add_argument("--serve", action="store_true",
                    help="request-path report for the coded KV serving "
                         "stack (repro.obs.serve) instead of a sim suite")
    args = ap.parse_args(argv)
    if args.serve:
        out = serve_report(out_dir=args.out_dir, smoke=args.smoke)
        print(f"wrote {out['md_path']}, {out['json_path']} and "
              f"{out['trace_path']} ({len(out['spans'])} requests, "
              "planes == oracle verified)")
        return 0
    fn = availability_report if args.availability else stall_report
    out = fn(args.suite, out_dir=args.out_dir, smoke=args.smoke)
    n = len(out["points"])
    print(f"wrote {out['md_path']} and {out['json_path']} ({n} points, "
          f"planes == aggregates verified)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
