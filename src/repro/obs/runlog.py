"""Structured run manifests: what produced an artifact, pinned in the blob.

Every ``BENCH_*.json`` (and the obs reports/timelines) carries a
``manifest`` block answering the questions a perf-trajectory reader asks a
week later: which commit, which device topology, which jax, which config
(including the sweep layer's ``static_signature`` when the run came from a
``SweepPoint``), and how long compile vs warm execution took. The schema is
documented in docs/observability.md; ``scripts/check_bench_manifests.py``
fails CI when a root ``BENCH_*.json`` is missing its block.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import sys
import time
from typing import Any, Dict, Optional

MANIFEST_SCHEMA = 1


def git_sha(repo_root: Optional[str] = None) -> str:
    """HEAD commit of ``repo_root`` (default: this file's repo), or
    "unknown" outside a git checkout / without a git binary."""
    root = repo_root or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=root,
                             capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def device_topology() -> Dict[str, Any]:
    """Backend platform + per-device kinds (lazy jax import: manifests must
    be writable from tooling that never initializes a backend)."""
    try:
        import jax
        devs = jax.devices()
        return {
            "backend": jax.default_backend(),
            "n_devices": len(devs),
            "device_kinds": sorted({d.device_kind for d in devs}),
            "process_count": jax.process_count(),
        }
    except Exception:                                     # pragma: no cover
        return {"backend": "unavailable", "n_devices": 0,
                "device_kinds": [], "process_count": 0}


def _versions() -> Dict[str, str]:
    v = {"python": platform.python_version()}
    try:
        import jax
        v["jax"] = jax.__version__
    except Exception:                                     # pragma: no cover
        v["jax"] = "unavailable"
    import numpy
    v["numpy"] = numpy.__version__
    return v


def point_config(pt) -> Dict[str, Any]:
    """A ``SweepPoint`` as a manifest config block: its coordinates plus the
    engine's compile key (``static_signature``)."""
    from repro.sweep.grid import static_signature
    cfg = dataclasses.asdict(pt)
    cfg["static_signature"] = list(static_signature(pt))
    return cfg


def run_manifest(config: Optional[Any] = None,
                 timings: Optional[Dict[str, float]] = None,
                 extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The manifest block attached to result artifacts.

    ``config`` may be a ``SweepPoint`` (expanded via ``point_config``), a
    dict, or any JSON-serializable value; ``timings`` holds wall times in
    seconds keyed by phase (e.g. ``compile_s``, ``warm_s``)."""
    if config is not None and dataclasses.is_dataclass(config) \
            and hasattr(config, "derived_slots"):
        config = point_config(config)
    now = time.time()
    man: Dict[str, Any] = {
        "schema": MANIFEST_SCHEMA,
        "created_unix": round(now, 3),
        "created_iso": time.strftime("%Y-%m-%dT%H:%M:%S%z",
                                     time.localtime(now)),
        "git_sha": git_sha(),
        "argv": list(sys.argv),
        "versions": _versions(),
        "devices": device_topology(),
    }
    if config is not None:
        man["config"] = config
    if timings:
        man["timings"] = {k: round(float(v), 4) for k, v in timings.items()}
    if extra:
        man.update(extra)
    return man


def write_manifest(path: str, **kw) -> str:
    """Standalone manifest file (for artifacts that are not JSON blobs)."""
    man = run_manifest(**kw)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(man, f, indent=1, default=str)
    return path
