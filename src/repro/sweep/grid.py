"""Config-grid layer: sweep points, static-shape partitioning, grid helpers.

A design-space sweep (scheme × α × r × trace-shape × seed × tunables) mixes
two kinds of coordinates:

  * **static** coordinates that change array *shapes* inside the simulator —
    scheme tables, ``n_rows``, α/r (via ``n_slots``/``region_size``), queue
    depths, trace geometry. Points differing here need separate compiled
    programs.
  * **batchable** coordinates that only change array *values* — seeds, trace
    generator + its kwargs, write fractions, ``select_period``/``wq_lo``/
    ``wq_hi``. Points differing *only* here can share one compiled program
    with the point index as a ``vmap`` batch axis.

α sits in between: it only enters the simulator through the parity-slot
count ``n_slots = ⌊α/r⌋``, which *is* a shape — but a maskable one. Points
that share every structural coordinate (scheme, rows, ``r``-derived region
geometry) and are all below full coverage get their parity state allocated
at the **largest** ``n_slots`` in the group, and each point's own budget
rides along as the traced ``TunableParams.n_slots_active``. An α×r grid
therefore partitions per-``r`` (and full-coverage α=1 separately), not per
(α, r) pair.

``partition`` groups points by their static signature so the engine runs a
whole sweep as ``len(partition(points))`` device programs instead of
``len(points)``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.state import derive_geometry
from repro.core.system import drain_bound


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One configuration in a design-space sweep (all plain python values)."""

    # ---- static: memory-system geometry (separate compile per distinct value)
    scheme: str = "scheme_i"
    n_rows: int = 320
    alpha: float = 1.0
    r: float = 0.05
    n_data: int = 8
    queue_depth: int = 10
    coalesce: bool = True
    recode_cap: int = 64
    max_syms: int = 96
    encode_rows_per_cycle: int = 64
    recode_budget: int = 4
    # ---- static: trace geometry
    n_cores: int = 8
    n_banks: int = 8
    length: int = 96
    n_cycles: Optional[int] = None   # None = drain bound from length/n_cores
    # ---- batchable: trace contents
    trace: str = "banded"            # name in repro.sim.trace.TRACES
    trace_kwargs: Tuple[Tuple[str, Any], ...] = ()
    seed: int = 0
    write_frac: float = 0.3
    issue_prob: float = 1.0
    # ---- batchable: tunables (traced scalars in the cycle engine)
    select_period: int = 256
    wq_hi: int = 8
    wq_lo: int = 2
    # ---- static: scheduler implementation (vectorized | reference)
    scheduler: str = "vectorized"
    # free-form tag carried through to result rows
    label: str = ""

    def derived_slots(self) -> Tuple[int, int, int]:
        """(region_size, n_regions, n_slots) this point's α/r imply."""
        return derive_geometry(self.n_rows, self.alpha, self.r)

    def full_coverage(self) -> bool:
        _, n_regions, n_slots = self.derived_slots()
        return n_slots >= n_regions

    def replace(self, **kw) -> "SweepPoint":
        return dataclasses.replace(self, **kw)

    def resolved_cycles(self) -> int:
        if self.n_cycles is not None:
            return int(self.n_cycles)
        return drain_bound(self.n_cores, self.length)


def static_signature(pt: SweepPoint) -> Tuple:
    """Hashable key of everything that forces a distinct compiled program.

    α is deliberately *not* part of the key below full coverage: its only
    shape effect, ``n_slots``, is allocated at the group max and masked per
    point (``TunableParams.n_slots_active``). Full-coverage points (static
    identity region map, dynamic unit disabled) keep their own key.
    """
    region_size, n_regions, n_slots = pt.derived_slots()
    full = n_slots >= n_regions
    return (pt.scheme, pt.n_data, pt.n_rows, region_size, n_regions, full,
            pt.queue_depth, pt.coalesce, pt.recode_cap, pt.max_syms,
            pt.encode_rows_per_cycle, pt.recode_budget, pt.scheduler,
            pt.n_cores, pt.n_banks, pt.length, pt.resolved_cycles())


def batch_slot_alloc(points: Sequence[SweepPoint]) -> Optional[int]:
    """Parity-slot allocation for one shape-compatible batch: ``None`` for
    full-coverage groups (exact identity allocation), else the group max."""
    if points[0].full_coverage():
        return None
    return max(pt.derived_slots()[2] for pt in points)


@dataclasses.dataclass
class GridBatch:
    """All shape-compatible points of one sweep, plus their original indices."""

    signature: Tuple
    indices: List[int]
    points: List[SweepPoint]

    def __len__(self) -> int:
        return len(self.points)


def partition(points: Sequence[SweepPoint]) -> List[GridBatch]:
    """Group points by static signature, preserving first-seen batch order."""
    batches: Dict[Tuple, GridBatch] = {}
    for i, pt in enumerate(points):
        sig = static_signature(pt)
        b = batches.get(sig)
        if b is None:
            b = batches[sig] = GridBatch(sig, [], [])
        b.indices.append(i)
        b.points.append(pt)
    return list(batches.values())


def grid(base: Optional[SweepPoint] = None, **axes: Iterable) -> List[SweepPoint]:
    """Cartesian product over SweepPoint fields.

    >>> grid(alpha=(0.1, 0.25), seed=range(4))        # 8 points
    Axis order follows kwargs order; the last axis varies fastest.
    """
    base = base or SweepPoint()
    names = list(axes)
    bad = [n for n in names if n not in SweepPoint.__dataclass_fields__]
    if bad:
        raise ValueError(f"unknown SweepPoint fields: {bad}")
    values = [list(axes[n]) for n in names]
    return [base.replace(**dict(zip(names, combo)))
            for combo in itertools.product(*values)]
