"""Config-grid layer: sweep points, static-shape partitioning, grid helpers.

A design-space sweep (scheme × α × r × trace-shape × seed × tunables) mixes
two kinds of coordinates:

  * **static** coordinates that change array *shapes* inside the simulator —
    scheme tables, ``n_rows``, α/r (via ``n_slots``/``region_size``), queue
    depths, trace geometry. Points differing here need separate compiled
    programs.
  * **batchable** coordinates that only change array *values* — seeds, trace
    generator + its kwargs, write fractions, ``select_period``/``wq_lo``/
    ``wq_hi``. Points differing *only* here can share one compiled program
    with the point index as a ``vmap`` batch axis.

α and r sit in between: they only enter the simulator through the parity
slot count ``n_slots = ⌊α/r⌋`` and the region geometry
``region_size``/``n_regions`` — shapes, but *maskable* ones. Points that
share every other structural coordinate (and full-coverage status) get
region/parity state allocated at the **group maxima** of all three, and
each point's own geometry rides along as the traced
``TunableParams.{n_slots,region_size,n_regions}_active`` — indexing uses
the traced values and the padding is masked off. An α×r grid therefore
partitions per *(scheme, full-coverage)* group, not per r and not per
(α, r) pair.

``partition`` groups points by their static signature so the engine runs a
whole sweep as ``len(partition(points))`` device programs instead of
``len(points)``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.state import derive_geometry
from repro.core.system import drain_bound


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One configuration in a design-space sweep (all plain python values)."""

    # ---- static: memory-system geometry (separate compile per distinct value)
    scheme: str = "scheme_i"
    n_rows: int = 320
    alpha: float = 1.0
    r: float = 0.05
    n_data: int = 8
    queue_depth: int = 10
    coalesce: bool = True
    recode_cap: int = 64
    max_syms: int = 96
    encode_rows_per_cycle: int = 64
    recode_budget: int = 4
    # ---- static: trace geometry
    n_cores: int = 8
    n_banks: int = 8
    length: int = 96
    n_cycles: Optional[int] = None   # None = drain bound from length/n_cores
    # ---- static: observability (a telemetry-on point carries the
    # repro.obs metric planes through its scan carry — a different compiled
    # program from the telemetry-off one, see MemParams.telemetry)
    telemetry: bool = False
    # ---- fault injection (repro.faults): flat spec tuple in the
    # ``FaultPlan.from_spec`` grammar — ("bank", b, fail_at[, recover_at])
    # and ("stutter", port, period[, phase]) entries; () = no faults. The
    # *presence* of a plan is static (the fault hooks compile in, a
    # different program); the schedule values ride the carry and batch, so
    # points differing only in schedules share one compiled program.
    faults: Tuple[Tuple, ...] = ()
    # ---- batchable: trace contents
    trace: str = "banded"            # name in repro.sim.trace.TRACES, or
                                     # "file:<path>" for an ingested on-disk
                                     # trace (repro.traces.formats; see
                                     # workloads.file_point)
    trace_kwargs: Tuple[Tuple[str, Any], ...] = ()
    seed: int = 0
    write_frac: float = 0.3
    issue_prob: float = 1.0
    # ---- batchable: tunables (traced scalars in the cycle engine)
    select_period: int = 256
    wq_hi: int = 8
    wq_lo: int = 2
    # free-form tag carried through to result rows
    label: str = ""
    # provenance metadata (not a simulation coordinate): the registry suite
    # that produced this point, stamped by ``workloads.suite`` so error
    # messages and result rows can name their origin
    suite: str = ""

    def derived_slots(self) -> Tuple[int, int, int]:
        """(region_size, n_regions, n_slots) this point's α/r imply."""
        return derive_geometry(self.n_rows, self.alpha, self.r)

    def full_coverage(self) -> bool:
        _, n_regions, n_slots = self.derived_slots()
        return n_slots >= n_regions

    def replace(self, **kw) -> "SweepPoint":
        return dataclasses.replace(self, **kw)

    def resolved_cycles(self) -> int:
        if self.n_cycles is not None:
            return int(self.n_cycles)
        return drain_bound(self.n_cores, self.length)


def static_signature(pt: SweepPoint) -> Tuple:
    """Hashable key of everything that forces a distinct compiled program.

    α and r are deliberately *not* part of the key: their shape effects
    (``n_slots`` and ``region_size``/``n_regions``) are allocated at the
    group maxima and masked per point via the traced
    ``TunableParams.{n_slots,region_size,n_regions}_active``. Only the
    full-coverage *status* stays in the key — full-coverage points run with
    the dynamic-coding unit statically disabled (identity region map), a
    genuinely different program.
    """
    _, n_regions, n_slots = pt.derived_slots()
    full = n_slots >= n_regions
    return (pt.scheme, pt.n_data, pt.n_rows, full,
            pt.queue_depth, pt.coalesce, pt.recode_cap, pt.max_syms,
            pt.encode_rows_per_cycle, pt.recode_budget,
            pt.n_cores, pt.n_banks, pt.length, pt.resolved_cycles(),
            pt.telemetry, bool(pt.faults))


def batch_geometry_alloc(points: Sequence[SweepPoint]) -> Tuple[int, int, int]:
    """(region_size, n_regions, n_slots) allocation for one shape-compatible
    batch: the per-coordinate maxima over the group (for a single-geometry
    group this is exactly the derived geometry — zero padding)."""
    geoms = [pt.derived_slots() for pt in points]
    return (max(g[0] for g in geoms), max(g[1] for g in geoms),
            max(g[2] for g in geoms))


@dataclasses.dataclass
class GridBatch:
    """All shape-compatible points of one sweep, plus their original indices."""

    signature: Tuple
    indices: List[int]
    points: List[SweepPoint]

    def __len__(self) -> int:
        return len(self.points)


def partition(points: Sequence[SweepPoint]) -> List[GridBatch]:
    """Group points by static signature, preserving first-seen batch order."""
    batches: Dict[Tuple, GridBatch] = {}
    for i, pt in enumerate(points):
        sig = static_signature(pt)
        b = batches.get(sig)
        if b is None:
            b = batches[sig] = GridBatch(sig, [], [])
        b.indices.append(i)
        b.points.append(pt)
    return list(batches.values())


def grid(base: Optional[SweepPoint] = None, **axes: Iterable) -> List[SweepPoint]:
    """Cartesian product over SweepPoint fields.

    >>> grid(alpha=(0.1, 0.25), seed=range(4))        # 8 points
    Axis order follows kwargs order; the last axis varies fastest.
    """
    base = base or SweepPoint()
    names = list(axes)
    bad = [n for n in names if n not in SweepPoint.__dataclass_fields__]
    if bad:
        raise ValueError(f"unknown SweepPoint fields: {bad}")
    values = [list(axes[n]) for n in names]
    return [base.replace(**dict(zip(names, combo)))
            for combo in itertools.product(*values)]
