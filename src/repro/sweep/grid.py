"""Config-grid layer: sweep points, static-shape partitioning, grid helpers.

A design-space sweep (scheme × α × r × trace-shape × seed × tunables) mixes
two kinds of coordinates:

  * **static** coordinates that change array *shapes* inside the simulator —
    scheme tables, ``n_rows``, α/r (via ``n_slots``/``region_size``), queue
    depths, trace geometry. Points differing here need separate compiled
    programs.
  * **batchable** coordinates that only change array *values* — seeds, trace
    generator + its kwargs, write fractions, ``select_period``/``wq_lo``/
    ``wq_hi``. Points differing *only* here can share one compiled program
    with the point index as a ``vmap`` batch axis.

``partition`` groups points by their static signature so the engine runs a
whole sweep as ``len(partition(points))`` device programs instead of
``len(points)``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.system import drain_bound


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One configuration in a design-space sweep (all plain python values)."""

    # ---- static: memory-system geometry (separate compile per distinct value)
    scheme: str = "scheme_i"
    n_rows: int = 320
    alpha: float = 1.0
    r: float = 0.05
    n_data: int = 8
    queue_depth: int = 10
    coalesce: bool = True
    recode_cap: int = 64
    max_syms: int = 96
    encode_rows_per_cycle: int = 64
    recode_budget: int = 4
    # ---- static: trace geometry
    n_cores: int = 8
    n_banks: int = 8
    length: int = 96
    n_cycles: Optional[int] = None   # None = drain bound from length/n_cores
    # ---- batchable: trace contents
    trace: str = "banded"            # name in repro.sim.trace.TRACES
    trace_kwargs: Tuple[Tuple[str, Any], ...] = ()
    seed: int = 0
    write_frac: float = 0.3
    issue_prob: float = 1.0
    # ---- batchable: tunables (traced scalars in the cycle engine)
    select_period: int = 256
    wq_hi: int = 8
    wq_lo: int = 2
    # free-form tag carried through to result rows
    label: str = ""

    def replace(self, **kw) -> "SweepPoint":
        return dataclasses.replace(self, **kw)

    def resolved_cycles(self) -> int:
        if self.n_cycles is not None:
            return int(self.n_cycles)
        return drain_bound(self.n_cores, self.length)


def static_signature(pt: SweepPoint) -> Tuple:
    """Hashable key of everything that forces a distinct compiled program."""
    return (pt.scheme, pt.n_data, pt.n_rows, pt.alpha, pt.r, pt.queue_depth,
            pt.coalesce, pt.recode_cap, pt.max_syms, pt.encode_rows_per_cycle,
            pt.recode_budget, pt.n_cores, pt.n_banks, pt.length,
            pt.resolved_cycles())


@dataclasses.dataclass
class GridBatch:
    """All shape-compatible points of one sweep, plus their original indices."""

    signature: Tuple
    indices: List[int]
    points: List[SweepPoint]

    def __len__(self) -> int:
        return len(self.points)


def partition(points: Sequence[SweepPoint]) -> List[GridBatch]:
    """Group points by static signature, preserving first-seen batch order."""
    batches: Dict[Tuple, GridBatch] = {}
    for i, pt in enumerate(points):
        sig = static_signature(pt)
        b = batches.get(sig)
        if b is None:
            b = batches[sig] = GridBatch(sig, [], [])
        b.indices.append(i)
        b.points.append(pt)
    return list(batches.values())


def grid(base: Optional[SweepPoint] = None, **axes: Iterable) -> List[SweepPoint]:
    """Cartesian product over SweepPoint fields.

    >>> grid(alpha=(0.1, 0.25), seed=range(4))        # 8 points
    Axis order follows kwargs order; the last axis varies fastest.
    """
    base = base or SweepPoint()
    names = list(axes)
    bad = [n for n in names if n not in SweepPoint.__dataclass_fields__]
    if bad:
        raise ValueError(f"unknown SweepPoint fields: {bad}")
    values = [list(axes[n]) for n in names]
    return [base.replace(**dict(zip(names, combo)))
            for combo in itertools.product(*values)]
