"""Results store: flat per-point tables, JSON/CSV export, baseline columns.

``SweepResultSet`` holds (SweepPoint, SimResult) records in sweep order and
renders them as flat rows — config coordinates first, then every SimResult
field — plus optional baseline-normalized columns (``baseline_cycles``,
``speedup``, ``cycle_reduction_%``) computed by matching each point to the
baseline record that shares its workload coordinates.
"""
from __future__ import annotations

import csv
import dataclasses
import json
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.system import SimResult
from repro.sweep.grid import SweepPoint

POINT_COLS: Tuple[str, ...] = (
    "label", "scheme", "alpha", "r", "n_rows", "trace", "seed", "write_frac",
    "issue_prob", "n_cores", "n_banks", "length", "queue_depth",
    "select_period", "wq_hi", "wq_lo", "suite",
)
RESULT_COLS: Tuple[str, ...] = SimResult._fields
BASELINE_COLS: Tuple[str, ...] = ("baseline_cycles", "speedup",
                                  "cycle_reduction_%")

# workload coordinates a baseline must share to normalize a point
DEFAULT_MATCH: Tuple[str, ...] = (
    "trace", "trace_kwargs", "seed", "write_frac", "issue_prob", "n_rows",
    "n_cores", "n_banks", "length",
)


def _is_uncoded(pt: SweepPoint) -> bool:
    return pt.scheme == "uncoded"


@dataclasses.dataclass(frozen=True)
class SweepRecord:
    point: SweepPoint
    result: SimResult

    def row(self) -> Dict:
        r = {c: getattr(self.point, c) for c in POINT_COLS}
        if self.point.trace_kwargs:
            r["trace_kwargs"] = json.dumps(dict(self.point.trace_kwargs))
        r.update({c: getattr(self.result, c) for c in RESULT_COLS})
        return r


class SweepResultSet:
    def __init__(self, records: Sequence[SweepRecord]):
        self.records: List[SweepRecord] = list(records)

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self):
        return iter(self.records)

    # ------------------------------------------------------------------ rows
    def rows(self, baseline: Optional[Callable[[SweepPoint], bool]] = _is_uncoded,
             match: Sequence[str] = DEFAULT_MATCH) -> List[Dict]:
        """Flat rows; when any baseline records exist, each row that has a
        workload-matched baseline gains the normalized speedup columns.

        Raises ``ValueError`` if several distinct baseline records share one
        match key (which baseline to normalize against would be arbitrary) —
        extend ``match`` with the coordinate that distinguishes them.
        """
        rows = [rec.row() for rec in self.records]
        if baseline is None:
            return rows
        key = lambda pt: tuple(getattr(pt, c) for c in match)  # noqa: E731
        base_cycles: Dict[Tuple, int] = {}
        for rec in self.records:
            if baseline(rec.point):
                k = key(rec.point)
                if k in base_cycles and base_cycles[k] != rec.result.cycles:
                    raise ValueError(
                        f"ambiguous baseline for match key {dict(zip(match, k))}: "
                        f"multiple baseline records with different cycles — "
                        f"add the distinguishing coordinate to `match`")
                base_cycles[k] = rec.result.cycles
        for rec, row in zip(self.records, rows):
            b = base_cycles.get(key(rec.point))
            if b is None:
                continue
            row["baseline_cycles"] = b
            row["speedup"] = round(b / max(rec.result.cycles, 1), 4)
            row["cycle_reduction_%"] = round(
                100.0 * (1.0 - rec.result.cycles / max(b, 1)), 2)
        return rows

    # ---------------------------------------------------------------- export
    def to_json(self, path: str, meta: Optional[Dict] = None, **rows_kw) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"meta": meta or {}, "rows": self.rows(**rows_kw)}, f,
                      indent=1, default=float)
        return path

    def to_csv(self, path: str, **rows_kw) -> str:
        rows = self.rows(**rows_kw)
        cols: List[str] = []
        for r in rows:
            for c in r:
                if c not in cols:
                    cols.append(c)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols, restval="")
            w.writeheader()
            w.writerows(rows)
        return path

    # --------------------------------------------------------------- lookups
    def by(self, **coords) -> List[SweepRecord]:
        """Records whose point matches every given coordinate exactly."""
        return [rec for rec in self.records
                if all(getattr(rec.point, k) == v for k, v in coords.items())]

    def one(self, **coords) -> SweepRecord:
        hits = self.by(**coords)
        if len(hits) != 1:
            raise KeyError(f"{coords} matched {len(hits)} records")
        return hits[0]
