"""Batched sweep engine: one compiled program per static shape, not per point.

The looped reference path (``repro.sim.ramulator.simulate``) pays a fresh
``jax.jit`` trace + compile, a full ``lax.scan`` launch and a host↔device
sync for every sweep point. This engine instead:

  1. partitions the sweep by static signature (``repro.sweep.grid``),
  2. ``vmap``s ``CodedMemorySystem.cycle_fn`` over the point axis of each
     partition — seeds, trace contents and ``TunableParams`` all batch —
  3. runs one ``lax.scan`` over cycles for the whole partition, and
  4. summarizes with a single device→host transfer per partition.

Per-point results are bit-identical to the looped path (the cycle engine is
pure integer arithmetic; ``vmap`` of ``cond`` evaluates both branches and
selects, which cannot change the selected values). tests/test_sweep.py and
benchmarks/bench_sweep.py both verify this.

With more than one device, the batch's point axis is padded with masked
dummy points (replicas of the last real point, stripped again in
``summarize_batch``) up to the next device-count multiple and sharded
across a 1-D "sweep" mesh (``repro.launch.mesh.make_sweep_mesh``); ``jit``
then partitions the scan across devices automatically — on every real
grid, not just ones whose size happens to divide the device count.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codes import get_tables
from repro.core.state import TunableParams, make_params, make_tunables
from repro.core.system import (CodedMemorySystem, SimResult, SimState, Trace,
                               quiescent, result_from_host)
from repro.launch.mesh import make_sweep_mesh
from repro.sweep import workloads
from repro.sweep.grid import (GridBatch, SweepPoint, batch_geometry_alloc,
                              partition, static_signature)

# One system (= one set of jit caches) per (static signature, geometry
# allocation), so re-running a suite — or growing it along batchable axes —
# never recompiles.
_SYSTEMS: Dict[Tuple, CodedMemorySystem] = {}


def system_for(pt: SweepPoint,
               geometry_alloc: Optional[Tuple[int, int, int]] = None,
               traced_geometry: bool = False) -> CodedMemorySystem:
    # static_signature deliberately drops α and r, so the cache must key on
    # the actual (region_size, n_regions, n_slots) allocation — two
    # geometries must not share an exactly-allocated system (an explicit
    # alloc equal to the derived geometry builds identical params, so one
    # key covers both). ``traced_geometry`` keys too: a single-geometry
    # batch compiles the cheaper static-indexing program.
    alloc = geometry_alloc if geometry_alloc is not None else pt.derived_slots()
    sig = (static_signature(pt), alloc, traced_geometry)
    sys = _SYSTEMS.get(sig)
    if sys is None:
        rs_alloc, nr_alloc, ns_alloc = alloc
        tables = get_tables(pt.scheme, n_data=pt.n_data)
        params = make_params(tables, n_rows=pt.n_rows, alpha=pt.alpha, r=pt.r,
                             queue_depth=pt.queue_depth, coalesce=pt.coalesce,
                             recode_cap=pt.recode_cap, max_syms=pt.max_syms,
                             encode_rows_per_cycle=pt.encode_rows_per_cycle,
                             recode_budget=pt.recode_budget,
                             n_slots_alloc=ns_alloc,
                             region_size_alloc=rs_alloc,
                             n_regions_alloc=nr_alloc,
                             traced_geometry=traced_geometry,
                             telemetry=pt.telemetry,
                             faults=bool(pt.faults))
        sys = CodedMemorySystem(tables, params, n_cores=pt.n_cores)
        _SYSTEMS[sig] = sys
    return sys


def stack_tunables(points: Sequence[SweepPoint],
                   queue_depth: int) -> TunableParams:
    tns = []
    for pt in points:
        rs, nr, ns = pt.derived_slots()
        tns.append(make_tunables(queue_depth=queue_depth,
                                 select_period=pt.select_period,
                                 wq_hi=pt.wq_hi, wq_lo=pt.wq_lo,
                                 n_slots_active=ns,
                                 region_size_active=rs,
                                 n_regions_active=nr))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *tns)


def _batched_init(sys: CodedMemorySystem, tn_b: TunableParams,
                  priors_b=None) -> SimState:
    """Per-point initial states: each point's active geometry masks the
    shared allocation (identity region maps sized to *its* n_regions, etc.).
    ``priors_b`` (B, K) optionally warm-starts each point's dynamic coding
    unit with profiled hot regions (``repro.traces.profiler``)."""
    if priors_b is None:
        return jax.vmap(sys.init)(tn_b)
    return jax.vmap(sys.init)(tn_b, priors_b)


def _stack_faults(points: Sequence[SweepPoint], p):
    """Per-point fault schedules → one batched FaultState (the schedule is
    carry data, so points with *different* plans batch through one compiled
    program — same trick as the tunables)."""
    from repro.faults.plan import init_fault_state, plan_from_spec

    states = []
    for pt in points:
        plan = plan_from_spec(pt.faults, p.n_data, p.n_ports)
        states.append(plan.state() if plan is not None
                      else init_fault_state(p.n_data, p.n_ports))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _pad_points(n_points: int) -> int:
    """Rows of padding needed to land on a device-count multiple (0 if the
    size already divides, or on a single device)."""
    n_dev = len(jax.devices())
    if n_dev <= 1:
        return 0
    return (-n_points) % n_dev


def _replicate_tail(tree, pad: int):
    """Append ``pad`` copies of the last point along the batch axis. The
    replicas quiesce exactly when their original does, so they never extend
    the early-exit while_loop; ``summarize_batch`` strips their rows."""
    return jax.tree.map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]), tree)


def _maybe_shard(trees, n_points: int):
    """Lay the (already padded) point axis across devices."""
    n_dev = len(jax.devices())
    if n_dev <= 1 or n_points % n_dev != 0:
        return trees
    from jax.sharding import NamedSharding, PartitionSpec as P
    sharding = NamedSharding(make_sweep_mesh(), P("sweep"))
    return tuple(jax.device_put(t, sharding) for t in trees)


def _all_quiescent(st_b: SimState) -> jnp.ndarray:
    """True when no point can change any observable statistic anymore (the
    shared ``repro.core.system.quiescent`` fixed point, over the batch)."""
    return jnp.all(quiescent(st_b))


@functools.partial(jax.jit, static_argnums=(0, 4), donate_argnums=(1,))
def _scan_batch(sys: CodedMemorySystem, st_b: SimState, trace_b: Trace,
                tn_b: TunableParams, n_cycles: int) -> SimState:
    vstep = jax.vmap(sys.cycle_fn)

    # while_loop instead of a fixed-length scan: the drain bound ``n_cycles``
    # is a worst case (full serialization on one port); real sweeps quiesce
    # far earlier, and post-quiescence cycles are observable no-ops, so
    # early exit is bit-identical to running the bound out.
    def cond(carry):
        st, i = carry
        return (i < n_cycles) & ~_all_quiescent(st)

    def body(carry):
        st, i = carry
        st, _out = vstep(st, trace_b, tn_b)
        return st, i + 1

    st, _ = jax.lax.while_loop(cond, body, (st_b, jnp.int32(0)))
    return st


def summarize_batch(st_b: SimState,
                    n_points: Optional[int] = None) -> List[SimResult]:
    """Batched SimState → per-point SimResults in one device→host transfer.

    ``n_points`` strips the masked dummy rows a padded-for-sharding batch
    carries past the real points."""
    host = jax.device_get(st_b)
    n = np.asarray(host.done_cycle).shape[0] if n_points is None else n_points
    return [result_from_host(jax.tree.map(lambda x: x[b], host.mem),
                             host.done_cycle[b])
            for b in range(n)]


def _stack_priors(priors: Sequence, n_points: int):
    """Ragged per-point region-prior arrays → one -1-padded (B, K) array."""
    arrs = [np.asarray(pr if pr is not None else [], np.int32).reshape(-1)
            for pr in priors]
    k = max((a.size for a in arrs), default=0)
    if k == 0:
        return None
    out = np.full((n_points, k), -1, np.int32)
    for b, a in enumerate(arrs):
        out[b, :a.size] = a
    return jnp.asarray(out)


def run_batch(batch: GridBatch, traces: Optional[Sequence[Trace]] = None,
              shard: bool = True,
              region_priors: Optional[Sequence] = None,
              collect_telemetry: bool = False):
    """Evaluate one shape-compatible batch as a single device program.

    With ``collect_telemetry`` the return is ``(results, snapshots)`` where
    ``snapshots`` aligns with the batch points: a
    ``repro.obs.planes.TelemetrySnapshot`` per telemetry-on point, None for
    telemetry-off ones (the planes ride the same device program; collecting
    them costs one extra host transfer of a few small arrays per point)."""
    pts = batch.points
    # geometry indexing is traced only when this batch actually mixes
    # (region_size, n_regions) geometries; a uniform batch (trace/seed/
    # tunable/α sweeps at one r) compiles the static-indexing program —
    # masking costs nothing unless it is used
    traced = len({pt.derived_slots()[:2] for pt in pts}) > 1
    sys = system_for(pts[0], geometry_alloc=batch_geometry_alloc(pts),
                     traced_geometry=traced)
    if traces is None:
        traces = [workloads.build_trace(pt, index=i)
                  for i, pt in zip(batch.indices, pts)]
    for pt, tr in zip(pts, traces):
        if tuple(tr.bank.shape) != (pt.n_cores, pt.length):
            raise ValueError(
                f"trace shape {tuple(tr.bank.shape)} does not match point "
                f"geometry ({pt.n_cores}, {pt.length})")
    trace_b = workloads.stack_traces(traces)
    tn_b = stack_tunables(pts, sys.p.queue_depth)
    priors_b = (_stack_priors(region_priors, len(pts))
                if region_priors is not None else None)
    fault_b = _stack_faults(pts, sys.p) if sys.p.faults else None
    pad = _pad_points(len(pts)) if shard else 0
    if pad:
        trace_b = _replicate_tail(trace_b, pad)
        tn_b = _replicate_tail(tn_b, pad)
        if priors_b is not None:
            priors_b = _replicate_tail(priors_b, pad)
        if fault_b is not None:
            fault_b = _replicate_tail(fault_b, pad)
    st_b = _batched_init(sys, tn_b, priors_b)
    if fault_b is not None:
        # install the per-point schedules over the vmapped init's no-fault
        # default (vmap can't thread the host-side plans themselves)
        st_b = st_b._replace(mem=st_b.mem._replace(fault=fault_b))
    if shard:
        st_b, trace_b, tn_b = _maybe_shard((st_b, trace_b, tn_b),
                                           len(pts) + pad)
    st = _scan_batch(sys, st_b, trace_b, tn_b, pts[0].resolved_cycles())
    results = summarize_batch(st, n_points=len(pts))
    if not collect_telemetry:
        return results
    from repro.obs.planes import snapshot
    host = jax.device_get(st)
    snaps = [snapshot(host, point=b) if host.mem.tele is not None else None
             for b in range(len(pts))]
    return results, snaps


def run_points(points: Sequence[SweepPoint],
               traces: Optional[Sequence[Trace]] = None,
               shard: bool = True,
               region_priors: Optional[Sequence] = None,
               collect_telemetry: bool = False):
    """Evaluate an arbitrary sweep; results align with ``points`` order.

    ``region_priors`` aligns 1:1 with ``points``: each entry is None (cold
    start) or a ranked hot-region array warm-starting that point's dynamic
    coding unit (``repro.traces.profiler.TraceProfile.region_priors``).

    ``collect_telemetry`` returns ``(results, snapshots)`` — a per-point
    ``TelemetrySnapshot`` (None for telemetry-off points); see ``run_batch``.
    """
    if traces is not None and len(traces) != len(points):
        raise ValueError("traces must align 1:1 with points")
    if region_priors is not None and len(region_priors) != len(points):
        raise ValueError("region_priors must align 1:1 with points")
    results: List[Optional[SimResult]] = [None] * len(points)
    snaps: List = [None] * len(points)
    for batch in partition(points):
        btraces = ([traces[i] for i in batch.indices]
                   if traces is not None else None)
        bpriors = ([region_priors[i] for i in batch.indices]
                   if region_priors is not None else None)
        out = run_batch(batch, btraces, shard, bpriors,
                        collect_telemetry=collect_telemetry)
        bres, bsnaps = out if collect_telemetry else (out, None)
        for k, i in enumerate(batch.indices):
            results[i] = bres[k]
            if bsnaps is not None:
                snaps[i] = bsnaps[k]
    if collect_telemetry:
        return results, snaps
    return results  # type: ignore[return-value]


def run_sweep(points: Sequence[SweepPoint],
              traces: Optional[Sequence[Trace]] = None,
              shard: bool = True,
              region_priors: Optional[Sequence] = None):
    """Evaluate a sweep and wrap it in a ``SweepResultSet`` (results store)."""
    from repro.sweep.results import SweepRecord, SweepResultSet
    res = run_points(points, traces=traces, shard=shard,
                     region_priors=region_priors)
    return SweepResultSet([SweepRecord(pt, r) for pt, r in zip(points, res)])


def clear_caches():
    """Drop memoized systems (and their jit caches) — mainly for tests."""
    _SYSTEMS.clear()
