"""Batched sweep engine: one compiled program per static shape, not per point.

The looped reference path (``repro.sim.ramulator.simulate``) pays a fresh
``jax.jit`` trace + compile, a full ``lax.scan`` launch and a host↔device
sync for every sweep point. This engine instead:

  1. partitions the sweep by static signature (``repro.sweep.grid``),
  2. ``vmap``s ``CodedMemorySystem.cycle_fn`` over the point axis of each
     partition — seeds, trace contents and ``TunableParams`` all batch —
  3. runs one ``lax.scan`` over cycles for the whole partition, and
  4. summarizes with a single device→host transfer per partition.

Per-point results are bit-identical to the looped path (the cycle engine is
pure integer arithmetic; ``vmap`` of ``cond`` evaluates both branches and
selects, which cannot change the selected values). tests/test_sweep.py and
benchmarks/bench_sweep.py both verify this.

With more than one device, batches whose size divides the device count are
sharded across a 1-D "sweep" mesh (``repro.launch.mesh.make_sweep_mesh``);
``jit`` then partitions the scan across devices automatically.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codes import get_tables
from repro.core.state import TunableParams, make_params, make_tunables
from repro.core.system import CodedMemorySystem, SimResult, SimState, Trace
from repro.launch.mesh import make_sweep_mesh
from repro.sweep import workloads
from repro.sweep.grid import (GridBatch, SweepPoint, batch_slot_alloc,
                              partition, static_signature)

# One system (= one set of jit caches) per (static signature, slot
# allocation), so re-running a suite — or growing it along batchable axes —
# never recompiles.
_SYSTEMS: Dict[Tuple, CodedMemorySystem] = {}


def system_for(pt: SweepPoint,
               n_slots_alloc: Optional[int] = None) -> CodedMemorySystem:
    # static_signature deliberately drops α below full coverage, so the
    # cache must key on the actual slot allocation — two α values must not
    # share an exactly-allocated system (an explicit alloc equal to the
    # derived count builds identical params, so one key covers both)
    sig = (static_signature(pt),
           n_slots_alloc if n_slots_alloc is not None
           else pt.derived_slots()[2])
    sys = _SYSTEMS.get(sig)
    if sys is None:
        tables = get_tables(pt.scheme, n_data=pt.n_data)
        params = make_params(tables, n_rows=pt.n_rows, alpha=pt.alpha, r=pt.r,
                             queue_depth=pt.queue_depth, coalesce=pt.coalesce,
                             recode_cap=pt.recode_cap, max_syms=pt.max_syms,
                             encode_rows_per_cycle=pt.encode_rows_per_cycle,
                             recode_budget=pt.recode_budget,
                             scheduler=pt.scheduler,
                             n_slots_alloc=n_slots_alloc)
        sys = CodedMemorySystem(tables, params, n_cores=pt.n_cores)
        _SYSTEMS[sig] = sys
    return sys


def stack_tunables(points: Sequence[SweepPoint],
                   queue_depth: int) -> TunableParams:
    tns = [make_tunables(queue_depth=queue_depth,
                         select_period=pt.select_period,
                         wq_hi=pt.wq_hi, wq_lo=pt.wq_lo,
                         n_slots_active=pt.derived_slots()[2])
           for pt in points]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *tns)


def _batched_init(sys: CodedMemorySystem, n: int) -> SimState:
    st0 = sys.init()
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), st0)


def _maybe_shard(trees, n_points: int):
    """Lay the point axis across devices when it divides the device count."""
    n_dev = len(jax.devices())
    if n_dev <= 1 or n_points % n_dev != 0:
        return trees
    from jax.sharding import NamedSharding, PartitionSpec as P
    sharding = NamedSharding(make_sweep_mesh(), P("sweep"))
    return tuple(jax.device_put(t, sharding) for t in trees)


def _all_quiescent(st_b: SimState) -> jnp.ndarray:
    """True when no point can change any observable statistic anymore:
    workload drained + recode ring empty + encoder idle (the dynamic unit
    starts nothing new after drain — see ``dynamic_step``'s ``quiesce``)."""
    m = st_b.mem
    q = ((st_b.done_cycle >= 0) & (m.enc_region < 0)
         & ~jnp.any(m.rc_valid, axis=-1))
    return jnp.all(q)


@functools.partial(jax.jit, static_argnums=(0, 4), donate_argnums=(1,))
def _scan_batch(sys: CodedMemorySystem, st_b: SimState, trace_b: Trace,
                tn_b: TunableParams, n_cycles: int) -> SimState:
    vstep = jax.vmap(sys.cycle_fn)

    # while_loop instead of a fixed-length scan: the drain bound ``n_cycles``
    # is a worst case (full serialization on one port); real sweeps quiesce
    # far earlier, and post-quiescence cycles are observable no-ops, so
    # early exit is bit-identical to running the bound out.
    def cond(carry):
        st, i = carry
        return (i < n_cycles) & ~_all_quiescent(st)

    def body(carry):
        st, i = carry
        st, _out = vstep(st, trace_b, tn_b)
        return st, i + 1

    st, _ = jax.lax.while_loop(cond, body, (st_b, jnp.int32(0)))
    return st


def summarize_batch(st_b: SimState) -> List[SimResult]:
    """Batched SimState → per-point SimResults in one device→host transfer."""
    host = jax.device_get(st_b)
    m = host.mem
    out = []
    for b in range(np.asarray(host.done_cycle).shape[0]):
        dc = int(host.done_cycle[b])
        sr = int(m.served_reads[b])
        sw = int(m.served_writes[b])
        out.append(SimResult(
            cycles=dc if dc >= 0 else int(m.cycle[b]),
            completed=dc >= 0,
            served_reads=sr,
            served_writes=sw,
            degraded_reads=int(m.degraded_reads[b]),
            parked_writes=int(m.parked_writes[b]),
            switches=int(m.switches[b]),
            recode_backlog=int(np.sum(m.rc_valid[b])),
            stall_cycles=int(m.stall_cycles[b]),
            avg_read_latency=float(m.read_latency_sum[b]) / max(sr, 1),
            avg_write_latency=float(m.write_latency_sum[b]) / max(sw, 1),
            rc_dropped=int(m.rc_dropped[b]),
        ))
    return out


def run_batch(batch: GridBatch, traces: Optional[Sequence[Trace]] = None,
              shard: bool = True) -> List[SimResult]:
    """Evaluate one shape-compatible batch as a single device program."""
    pts = batch.points
    sys = system_for(pts[0], n_slots_alloc=batch_slot_alloc(pts))
    if traces is None:
        traces = [workloads.build_trace(pt) for pt in pts]
    for pt, tr in zip(pts, traces):
        if tuple(tr.bank.shape) != (pt.n_cores, pt.length):
            raise ValueError(
                f"trace shape {tuple(tr.bank.shape)} does not match point "
                f"geometry ({pt.n_cores}, {pt.length})")
    trace_b = workloads.stack_traces(traces)
    tn_b = stack_tunables(pts, sys.p.queue_depth)
    st_b = _batched_init(sys, len(pts))
    if shard:
        st_b, trace_b, tn_b = _maybe_shard((st_b, trace_b, tn_b), len(pts))
    st = _scan_batch(sys, st_b, trace_b, tn_b, pts[0].resolved_cycles())
    return summarize_batch(st)


def run_points(points: Sequence[SweepPoint],
               traces: Optional[Sequence[Trace]] = None,
               shard: bool = True) -> List[SimResult]:
    """Evaluate an arbitrary sweep; results align with ``points`` order."""
    if traces is not None and len(traces) != len(points):
        raise ValueError("traces must align 1:1 with points")
    results: List[Optional[SimResult]] = [None] * len(points)
    for batch in partition(points):
        btraces = ([traces[i] for i in batch.indices]
                   if traces is not None else None)
        for i, res in zip(batch.indices, run_batch(batch, btraces, shard)):
            results[i] = res
    return results  # type: ignore[return-value]


def run_sweep(points: Sequence[SweepPoint],
              traces: Optional[Sequence[Trace]] = None,
              shard: bool = True):
    """Evaluate a sweep and wrap it in a ``SweepResultSet`` (results store)."""
    from repro.sweep.results import SweepRecord, SweepResultSet
    res = run_points(points, traces=traces, shard=shard)
    return SweepResultSet([SweepRecord(pt, r) for pt, r in zip(points, res)])


def clear_caches():
    """Drop memoized systems (and their jit caches) — mainly for tests."""
    _SYSTEMS.clear()
