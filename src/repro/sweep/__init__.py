"""Batched sweep-and-replay engine: whole design-space sweeps as a handful
of device programs.

  grid      — SweepPoint coordinates + static-shape partitioning (one
              compiled program per partition, vmap batch axis within)
  workloads — named scenario suites; trace materialization + pytree stacking
  engine    — vmapped ``CodedMemorySystem`` scan, optional device sharding
  results   — flat result tables, JSON/CSV export, baseline normalization

Quickstart (see docs/sweeps.md):

    from repro.sweep import SweepPoint, grid, run_sweep
    pts = grid(SweepPoint(scheme="scheme_i", alpha=0.25, r=0.125,
                          n_rows=128, length=64),
               trace=("banded", "uniform"), seed=range(4))
    rs = run_sweep(pts)          # one compile, one scan — not 8
    rs.to_csv("sweep.csv")
"""
from repro.sweep.grid import (  # noqa: F401
    GridBatch,
    SweepPoint,
    grid,
    partition,
    static_signature,
)
from repro.sweep.workloads import (  # noqa: F401
    SUITES,
    build_trace,
    stack_traces,
    suite,
)
from repro.sweep.engine import (  # noqa: F401
    run_batch,
    run_points,
    run_sweep,
    stack_tunables,
    summarize_batch,
    system_for,
)
from repro.sweep.results import (  # noqa: F401
    SweepRecord,
    SweepResultSet,
)
