"""Workload registry: named scenario suites + trace materialization/stacking.

A suite is a function returning a list of ``SweepPoint``s; ``build_trace``
materializes one point's trace via the ``repro.sim.trace`` generators or —
for ``trace="file:<path>"`` points — via ``repro.traces.formats`` ingestion
(``file_point`` sizes a point to an on-disk trace), and ``stack_traces``
turns shape-compatible traces into one batch-ready ``Trace`` pytree with a
leading point axis (what the engine ``vmap``s over).

Trace generation is seeded NumPy, so every suite is deterministic per seed
(tests/test_sweep.py locks this in).
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sim.trace import TRACES, TraceSpec
from repro.core.system import Trace
from repro.sweep.grid import SweepPoint, grid


def _point_name(pt: SweepPoint, index: Optional[int]) -> str:
    """Human-readable identity of a failing point: its suite (when stamped
    by ``suite()``) and sweep index, plus the distinguishing coordinates —
    a bare trace-key error is unattributable in a many-point sweep."""
    where = pt.suite or "<ad-hoc sweep>"
    idx = f"[{index}]" if index is not None else ""
    tag = f" label={pt.label!r}" if pt.label else ""
    return (f"SweepPoint {where}{idx}{tag} (scheme={pt.scheme}, "
            f"trace={pt.trace!r}, seed={pt.seed})")


def build_trace(pt: SweepPoint, *, index: Optional[int] = None) -> Trace:
    """Materialize one sweep point's request streams.

    ``pt.trace`` is either a generator name from ``repro.sim.trace.TRACES``
    or ``"file:<path>"`` for an on-disk trace ingested via
    ``repro.traces.formats.load_trace`` (``trace_kwargs`` forwards the
    mapping options — ``format``, ``line_bytes``; bank/row geometry comes
    from the point). ``index`` is the point's position in its sweep, used
    to attribute errors.
    """
    if pt.trace.startswith("file:"):
        from repro.traces.formats import load_trace
        path = pt.trace[len("file:"):]
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"{_point_name(pt, index)}: trace file {path!r} not found")
        try:
            tr = load_trace(path, n_cores=pt.n_cores, n_banks=pt.n_banks,
                            n_rows=pt.n_rows, length=pt.length,
                            **dict(pt.trace_kwargs))
        except ValueError as e:      # e.g. the file outgrows pt.length
            raise ValueError(f"{_point_name(pt, index)}: {e}") from None
        got = tuple(int(d) for d in tr.bank.shape)
        if got != (pt.n_cores, pt.length):
            raise ValueError(
                f"{_point_name(pt, index)}: file trace shape {got} does not "
                f"match the point geometry ({pt.n_cores}, {pt.length}) — "
                f"size the point with workloads.file_point()")
        # an .npz carries pre-mapped bank/row streams: a file saved from a
        # different memory geometry would index out of range inside jit,
        # where clamping silently produces wrong results instead of failing
        max_b = int(np.max(np.asarray(tr.bank), initial=0))
        max_r = int(np.max(np.asarray(tr.row), initial=0))
        if max_b >= pt.n_banks or max_r >= pt.n_rows:
            raise ValueError(
                f"{_point_name(pt, index)}: file trace addresses bank "
                f"{max_b}/row {max_r} but the point geometry is n_banks="
                f"{pt.n_banks}, n_rows={pt.n_rows} — the file was mapped "
                f"for a different memory geometry")
        return tr
    gen = TRACES.get(pt.trace)
    if gen is None:
        raise KeyError(f"{_point_name(pt, index)}: unknown trace generator "
                       f"{pt.trace!r}; have {sorted(TRACES)} or 'file:<path>'")
    spec = TraceSpec(n_cores=pt.n_cores, length=pt.length, n_banks=pt.n_banks,
                     n_rows=pt.n_rows, issue_prob=pt.issue_prob,
                     write_frac=pt.write_frac, seed=pt.seed)
    return gen(spec, **dict(pt.trace_kwargs))


def file_point(path: str, base: SweepPoint = SweepPoint(), **kw) -> SweepPoint:
    """A SweepPoint sized to an on-disk ``.npz`` trace: ``n_cores``/``length``
    are probed from the file so the batched engine's shape check passes."""
    from repro.traces.formats import probe
    n_cores, length = probe(path)
    return base.replace(trace=f"file:{path}", n_cores=n_cores, length=length,
                        **kw)


def text_file_point(path: str, base: SweepPoint = SweepPoint(), *,
                    line_bytes: int = 1, format: Optional[str] = None,
                    **kw) -> SweepPoint:
    """A SweepPoint sized to a Ramulator/gem5 *text* trace: the request
    count is probed (one lazy parse) and ``length`` set to the per-core
    columns the round-robin deal needs under ``base.n_cores``; the mapping
    options ride ``trace_kwargs`` into ingestion."""
    from repro.traces.formats import count_requests
    n = count_requests(path, format=format)
    tkw = [("line_bytes", line_bytes)]
    if format is not None:
        tkw.append(("format", format))
    return base.replace(trace=f"file:{path}", length=-(-n // base.n_cores),
                        trace_kwargs=tuple(tkw), **kw)


def stack_traces(traces: Sequence[Trace]) -> Trace:
    """Stack shape-compatible traces along a new leading batch axis."""
    shapes = {t.bank.shape for t in traces}
    if len(shapes) != 1:
        raise ValueError(f"cannot batch traces of mixed shapes: {shapes}")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *traces)


# --------------------------------------------------------------------- suites
def trace_zoo(base: SweepPoint = SweepPoint(), *,
              seeds: Sequence[int] = (0, 1),
              traces: Sequence[str] = ("banded", "split", "ramp", "uniform",
                                       "zipf")) -> List[SweepPoint]:
    """Every trace generator × seed on one memory configuration — the
    one-batch scenario spread (all points are shape-compatible)."""
    return grid(base, trace=traces, seed=seeds)


def multi_seed(base: SweepPoint = SweepPoint(), *,
               n_seeds: int = 8) -> List[SweepPoint]:
    """Seed replication of a single scenario (confidence intervals)."""
    return grid(base, seed=range(n_seeds))


def tunable_grid(base: SweepPoint = SweepPoint(), *,
                 select_periods: Sequence[int] = (32, 64, 256),
                 wq_his: Sequence[int] = (4, 8)) -> List[SweepPoint]:
    """Controller-knob exploration — one batch, one compile."""
    return grid(base, select_period=select_periods, wq_hi=wq_his)


def paper_fig18(base: SweepPoint = SweepPoint(), *,
                schemes: Sequence[str] = ("scheme_i", "scheme_ii",
                                          "scheme_iii"),
                alphas: Sequence[float] = (0.05, 0.1, 0.25, 0.5, 1.0),
                r: float = 0.05) -> List[SweepPoint]:
    """Fig 18 axes: scheme × α on the dedup-like banded trace, plus the
    uncoded baseline. Each (scheme, α) is its own static shape; the engine
    still amortizes everything sharing a shape (e.g. seed replicates)."""
    base = base.replace(trace="banded", r=r)
    pts = [base.replace(scheme="uncoded", alpha=1.0)]
    pts += grid(base, scheme=schemes, alpha=alphas)
    return pts


def paper_fig19(base: SweepPoint = SweepPoint(), *,
                rs: Sequence[float] = (0.05, 0.125, 0.25),
                alphas: Sequence[float] = (0.1, 0.25, 0.5, 1.0),
                n_bands: int = 8) -> List[SweepPoint]:
    """Fig 19 axes: α × r for scheme I on the split-band augmentation."""
    base = base.replace(trace="split", trace_kwargs=(("n_bands", n_bands),),
                        scheme="scheme_i")
    pts = [base.replace(scheme="uncoded", alpha=1.0, r=0.05)]
    pts += grid(base, r=rs, alpha=alphas)
    return pts


def drift_label(drift: float) -> str:
    """Label every ``paper_fig20`` point carries; consumers (fig20_ramp)
    select records with this instead of re-deriving the format."""
    return f"drift={drift}"


def paper_fig20(base: SweepPoint = SweepPoint(), *,
                drifts: Sequence[float] = (0.0, 0.25, 1.0),
                alphas: Sequence[float] = (0.1, 0.25)) -> List[SweepPoint]:
    """Fig 20 axes: band drift × α (static bands vs slow/fast linear ramp).
    All points — including drift=0 — are labeled ``drift_label(drift)``."""
    pts: List[SweepPoint] = []
    for drift in drifts:
        space = base.n_banks * base.n_rows
        tbase = (base.replace(trace="banded") if drift == 0.0 else
                 base.replace(trace="ramp",
                              trace_kwargs=(("drift_total", space * drift),)))
        tbase = tbase.replace(label=drift_label(drift))
        pts.append(tbase.replace(scheme="uncoded", alpha=1.0))
        pts += grid(tbase.replace(scheme="scheme_i"), alpha=alphas)
    return pts


SCENARIO_EXTENSIONS = (".trace", ".gem5", ".csv", ".npz")


def scenario_pack(base: SweepPoint = SweepPoint(), *,
                  directory: Optional[str] = None,
                  line_bytes: int = 64,
                  alphas: Sequence[float] = (0.25,)) -> List[SweepPoint]:
    """Checked-in real-trace excerpts as sweep points: every supported trace
    file under ``directory`` (sorted; Ramulator/gem5 text and canonical
    ``.npz``) × α, each point sized to its file and labeled with the file
    stem. The repo ships a pack under ``tests/data/scenarios/`` (gem5- and
    Ramulator-style excerpts with the paper's banded access structure);
    point ``directory`` at any folder of traces to make it a suite."""
    if directory is None:
        raise ValueError(
            "scenario_pack needs directory=<folder of trace files> "
            "(the checked-in pack lives in tests/data/scenarios/)")
    paths = sorted(
        os.path.join(directory, f) for f in os.listdir(directory)
        if f.endswith(SCENARIO_EXTENSIONS))
    if not paths:
        raise ValueError(f"no trace files under {directory!r} "
                         f"(looked for {SCENARIO_EXTENSIONS})")
    pts: List[SweepPoint] = []
    for path in paths:
        stem = os.path.splitext(os.path.basename(path))[0]
        if path.endswith(".npz"):
            pt = file_point(path, base, label=stem)
        else:
            pt = text_file_point(path, base, line_bytes=line_bytes,
                                 label=stem)
        pts.extend(pt.replace(alpha=a) for a in alphas)
    return pts


SUITES: Dict[str, Callable[..., List[SweepPoint]]] = {
    "trace_zoo": trace_zoo,
    "multi_seed": multi_seed,
    "tunable_grid": tunable_grid,
    "paper_fig18": paper_fig18,
    "paper_fig19": paper_fig19,
    "paper_fig20": paper_fig20,
    "scenario_pack": scenario_pack,
}


def suite(name: str, base: SweepPoint = SweepPoint(), **kw) -> List[SweepPoint]:
    if name not in SUITES:
        raise KeyError(f"unknown suite {name!r}; have {sorted(SUITES)}")
    # stamp provenance so downstream errors/result rows can name the suite
    return [pt.replace(suite=name) for pt in SUITES[name](base, **kw)]
