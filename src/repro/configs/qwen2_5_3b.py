"""qwen2.5-3b — dense GQA decoder with QKV bias [hf:Qwen/Qwen2.5 family].

36L, d_model=2048, 16 heads (GQA kv=2), d_ff=11008, vocab=151936 (large,
tied) — the big vocab makes it a coded-embedding arch.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv=2,
    d_ff=11008,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    coded_embedding=True,
    kv_banks=8,
))
