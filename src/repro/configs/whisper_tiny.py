"""whisper-tiny — enc-dec audio transformer [arXiv:2212.04356].

4L enc + 4L dec, d_model=384, 6 heads (kv=6), d_ff=1536, vocab=51865.
Conv audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, 1500, 384). LayerNorm + GELU + plain MLP + QKV bias + learned
positions (decoder) / sinusoidal (encoder).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_ff=1536,
    vocab=51865,
    mlp_gated=False,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    pos="learned",
    enc_layers=4,
    enc_frames=1500,
    frontend="audio_stub",
    kv_banks=4,
))
