"""mixtral-8x7b — sparse MoE decoder with SWA [arXiv:2401.04088].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336 per expert, 8 experts
top-2, sliding window 4096, vocab=32000. SWA makes decode state O(window),
so mixtral runs the long_500k shape.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32000,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    kv_banks=8,
))
