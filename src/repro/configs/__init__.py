"""Architecture configs (one module per assigned arch + the paper's own
memory-system config). Importing `load_all()` populates the registry."""
import importlib

_MODULES = (
    "whisper_tiny", "qwen2_5_3b", "granite_20b", "stablelm_12b", "yi_6b",
    "mixtral_8x7b", "olmoe_1b_7b", "recurrentgemma_9b", "phi3_vision_4_2b",
    "mamba2_2_7b",
)


def load_all():
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


from repro.configs.base import ModelConfig, all_configs, get_config  # noqa: E402,F401
