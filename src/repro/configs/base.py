"""Architecture config system. One frozen dataclass covers all 10 assigned
architectures; each ``configs/<id>.py`` instantiates its exact published
hyper-parameters, and ``reduced()`` derives the CPU smoke-test variant.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    mlp_gated: bool = True      # SwiGLU vs plain (GELU) MLP
    qkv_bias: bool = False
    pos: str = "rope"           # rope | learned | sinusoidal
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "silu"           # silu | gelu
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 2048       # tokens per dispatch group
    # attention windows
    sliding_window: int = 0     # >0: SWA for all attention layers (mixtral)
    local_window: int = 0       # >0: window of "local attention" layers (griffin)
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn") for hybrid
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # encoder-decoder (whisper) — n_layers is the DECODER depth
    enc_layers: int = 0
    enc_frames: int = 0         # encoder input length (stub frame embeddings)
    # modality frontends are stubs: input_specs() provides embeddings
    frontend: str = "none"      # none | audio_stub | vision_stub
    n_patches: int = 0          # vision_stub prefix length
    # coded-memory integration (the paper's technique)
    coded_embedding: bool = False
    embed_banks: int = 8        # data banks for the coded vocab table
    kv_banks: int = 0           # >0: banked+parity KV cache in serving path
    kv_page: int = 64
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # perf knobs (§Perf hillclimb variants; defaults = paper-faithful baseline)
    attn_av_bf16: bool = False   # softmax stays f32; AV matmul reads bf16
    moe_ep: bool = False         # expert parallelism (experts over `model`)
    rg_scan_bf16: bool = False   # RG-LRU associative scan on bf16 (a, w)
    remat_policy: str = "full"   # full | dots (save matmul outputs)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        assert self.n_heads == 0 or self.n_heads % max(self.n_kv, 1) == 0

    # ------------------------------------------------------------------
    @property
    def vocab_pad(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/logit dim
        shards evenly over any mesh axis ≤256 (jit in_shardings require
        divisibility) and stays 128-lane aligned for the TPU MXU. Logits for
        padded ids are masked to -inf; tokens never reference them."""
        return -(-self.vocab // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports O(1)-state or windowed decode at 500k context."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def n_params(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and reports)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
        mlp = (3 if self.mlp_gated else 2) * d * f
        if self.family == "moe":
            mlp = self.n_experts * mlp
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di = self.ssm_expand * d
            nh = di // self.ssm_headdim
            per = d * (2 * di + 2 * self.ssm_state + nh) + di * d + di
            return self.n_layers * per + emb
        if self.family == "hybrid":
            n_attn = sum(1 for i in range(self.n_layers)
                         if self.block_pattern[i % len(self.block_pattern)] == "attn")
            n_rec = self.n_layers - n_attn
            rec = 2 * d * d + d * d + 3 * d  # RG-LRU block approx (in/out + gates)
            return n_attn * (attn + mlp) + n_rec * (rec + mlp) + emb
        layers = self.n_layers + self.enc_layers
        return layers * (attn + mlp) + emb

    def n_active_params(self) -> int:
        """Active (per-token) parameters — differs for MoE."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        total = self.n_params()
        mlp_all = self.n_layers * self.n_experts * (3 if self.mlp_gated else 2) * d * f
        mlp_act = self.n_layers * self.top_k * (3 if self.mlp_gated else 2) * d * f
        return total - mlp_all + mlp_act

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, len(self.block_pattern) or 2),
            d_model=128,
            n_heads=4,
            n_kv=2 if 0 < self.n_kv < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_group=64,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            local_window=min(self.local_window, 16) if self.local_window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else 64,
            enc_layers=min(self.enc_layers, 2),
            enc_frames=min(self.enc_frames, 32) if self.enc_frames else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
        )


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate lazily so `import repro.configs.base` has no side effects
    if not _REGISTRY:
        from repro import configs  # noqa: F401  (imports register all)
        configs.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    if not _REGISTRY:
        from repro import configs
        configs.load_all()
    return dict(_REGISTRY)
