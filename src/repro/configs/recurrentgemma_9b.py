"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention 1:2
[arXiv:2402.19427].

38L with repeating (rec, rec, local-attn) pattern (26 recurrent + 12 local
attention layers), d_model=4096, 16 heads (kv=1 MQA) on the attention
layers, d_ff=12288, local window 2048, vocab=256000 — the 256k vocab is the
strongest coded-embedding case. O(1) recurrent state + windowed attention
=> runs long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    act="gelu",
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    coded_embedding=True,
    kv_banks=4,
))
