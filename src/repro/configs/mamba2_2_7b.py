"""mamba2-2.7b — attention-free SSD (state-space duality) [arXiv:2405.21060].

64L, d_model=2560, ssm_state=128, expand=2 (d_inner=5120, 80 heads of
headdim 64), vocab=50280, no MLP (d_ff=0). O(1) decode state => runs
long_500k. The paper's coding technique is inapplicable to the recurrent
state (read-modify-write every step, no idle banks) — see DESIGN.md §6;
the vocab embedding still uses the coded layout.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    coded_embedding=True,
))
