"""granite-20b (code) — llama-arch dense decoder, MQA (kv=1) [arXiv:2405.04324].

52L, d_model=6144, 48 heads (kv=1), d_ff=24576 (=4d, plain GELU MLP — the
non-gated form matches the 20B parameter count), vocab=49152.
kv=1 means the KV cache cannot shard over heads — the serving path shards
the KV *sequence* dimension over the model axis (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv=1,
    d_ff=24576,
    vocab=49152,
    mlp_gated=False,
    act="gelu",
    norm="layernorm",
    qkv_bias=True,
    kv_banks=8,
))
