"""olmoe-1b-7b — fine-grained sparse MoE [arXiv:2409.02060].

16L, d_model=2048, 16 heads (kv=16), d_ff=1024 per expert, 64 experts top-8,
vocab=50304.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    kv_banks=8,
))
