"""stablelm-12b — dense GQA decoder [hf:stabilityai/stablelm family].

40L, d_model=5120, 32 heads (GQA kv=8), d_ff=13824, vocab=100352 (coded
embedding candidate).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv=8,
    d_ff=13824,
    vocab=100352,
    coded_embedding=True,
    kv_banks=8,
))
