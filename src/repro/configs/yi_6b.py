"""yi-6b — llama-arch dense GQA decoder [arXiv:2403.04652].

32L, d_model=4096, 32 heads (GQA kv=4), d_ff=11008, vocab=64000.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=5_000_000.0,
    kv_banks=8,
))
