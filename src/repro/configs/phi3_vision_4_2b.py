"""phi-3-vision-4.2b — phi3-mini backbone + CLIP vision stub
[hf:microsoft/Phi-3-vision-128k-instruct].

32L, d_model=3072, 32 heads (kv=32 MHA), d_ff=8192, vocab=32064. The CLIP
frontend is a STUB: input_specs() provides precomputed patch embeddings
(B, 576, 3072) that occupy the sequence prefix.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32064,
    frontend="vision_stub",
    n_patches=576,
    kv_banks=8,
))
