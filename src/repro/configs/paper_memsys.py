"""The paper's own memory-system configuration (§III-B/§V): 8 data banks,
8 cores, queue depth 10, schemes I/II/III, alpha/r sweeps per Fig 18."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class MemSysConfig:
    scheme: str = "scheme_i"
    n_data: int = 8
    n_cores: int = 8
    n_rows: int = 512
    alpha: float = 1.0
    r: float = 0.05
    queue_depth: int = 10
    select_period: int = 256


PAPER_ALPHAS = (0.05, 0.1, 0.25, 0.5, 1.0)
PAPER_SCHEMES = ("scheme_i", "scheme_ii", "scheme_iii")
