"""Path+shape-driven sharding rules (FSDP over ``data`` × TP over ``model``;
``pod`` is DCN-level data parallelism).

Divisibility-aware: jit ``in_shardings`` require every sharded dim to divide
evenly by its mesh axes, and the 10 assigned architectures have heads/vocab/
widths that do not all divide a 16-way axis — every rule therefore passes
through ``_fits`` which falls back to replication on that dim. The dry-run
prints the chosen specs so a lost sharding opportunity is visible rather
than silent.

TP convention: column-parallel for up-projections (out dim on ``model``),
row-parallel for down-projections (in dim on ``model``) — activations inside
a block stay sharded on the hidden/f dim and only the block output needs an
all-reduce, GSPMD derives this from the param specs.

Embedding tables shard their vocab dim on ``model`` (this is the *coded
bank axis* — see repro.models.embedding); GSPMD serves the gather with a
masked partial-gather + all-reduce, never an all-gather of the table.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fits(dim: int, mesh, axis) -> Optional[Any]:
    return axis if (axis is not None and dim % _axis_size(mesh, axis) == 0) else None


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))))
    return "/".join(out)


# --------------------------------------------------------------------- params
def param_spec(name: str, shape, mesh, *, fsdp: bool = True,
               moe_ep: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    ``name`` is the '/'.joined key path; stacked per-layer leaves carry a
    leading L dim which is never sharded (scan carries need whole leaves).
    """
    d_ax = "data" if (fsdp and "data" in mesh.axis_names) else None
    m_ax = "model" if "model" in mesh.axis_names else None
    nd = len(shape)
    spec = [None] * nd
    leaf = name.rsplit("/", 1)[-1]

    if nd <= 1 or m_ax is None:
        return P(*spec)

    stacked = name.startswith(("blocks", "rec_blocks", "attn_blocks", "enc_blocks"))
    lo = 1 if stacked else 0          # first shardable dim
    if nd - lo < 1:
        return P(*spec)

    if leaf == "table":               # embed (Vp, D): vocab = coded bank axis
        spec[0] = _fits(shape[0], mesh, m_ax)
        return P(*spec)               # D replicated: avoids a data-axis
                                      # contraction conflict with batch-on-data
    if leaf == "banks":               # coded embed (NB, Vb, D)
        spec[1] = _fits(shape[1], mesh, m_ax)
        return P(*spec)
    if leaf == "lm_head":             # (D, Vp)
        spec[1] = _fits(shape[1], mesh, m_ax)
        return P(*spec)
    if leaf == "pos_embed":           # (S, D)
        spec[1] = _fits(shape[1], mesh, m_ax)
        return P(*spec)

    if nd - lo < 2:                   # stacked vectors (norms, biases, gates)
        return P(*spec)

    row_parallel = leaf in ("w_down", "wo", "out_proj", "w_out")
    if leaf in ("w_up", "w_gate", "w_down") and nd - lo == 3:   # MoE (E, D, F)
        if moe_ep:
            # expert parallelism: E over `model`. The dispatch/combine
            # einsums carry the e dim, so they shard too — with TP they are
            # REPLICATED across the model axis (the olmoe §Perf finding).
            spec[nd - 3] = _fits(shape[nd - 3], mesh, m_ax)
            return P(*spec)
        # TP inside each expert (baseline)
        i, o = (nd - 1, nd - 2) if row_parallel else (nd - 2, nd - 1)
        spec[o] = _fits(shape[o], mesh, m_ax)
        spec[i] = _fits(shape[i], mesh, d_ax)
        return P(*spec)

    i, o = (nd - 2, nd - 1)
    if row_parallel:
        spec[i] = _fits(shape[i], mesh, m_ax)
        spec[o] = _fits(shape[o], mesh, d_ax)
    else:                              # column-parallel (wq/wk/wv/w_up/in_proj…)
        spec[o] = _fits(shape[o], mesh, m_ax)
        spec[i] = _fits(shape[i], mesh, d_ax)
    return P(*spec)


def param_shardings(cfg: ModelConfig, abstract_params: Any, mesh,
                    *, fsdp: bool = True) -> Any:
    flat = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    treedef = jax.tree.structure(abstract_params)
    out = []
    for path, leaf in flat:
        spec = param_spec(_path_str(path), leaf.shape, mesh, fsdp=fsdp,
                          moe_ep=cfg.moe_ep)
        out.append(NamedSharding(mesh, spec))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------- opt state
def opt_shardings(param_sh: Any, mesh) -> Any:
    """Adam moments shard exactly like their parameters; step is replicated."""
    from repro.optim.adamw import OptState
    rep = NamedSharding(mesh, P())
    return OptState(step=rep, m=param_sh, v=param_sh)


# ------------------------------------------------------------------- inputs
def batch_spec(mesh, batch_size: int) -> P:
    """Shard the global-batch dim over (pod, data) — replicate if indivisible
    (long_500k has batch 1)."""
    axes = batch_axes(mesh)
    if axes and batch_size % _axis_size(mesh, axes) == 0:
        return P(axes)
    return P(None)


def data_shardings(mesh, batch: Any) -> Any:
    """Shardings for a host batch dict: dim 0 = global batch, rest replicated."""
    def one(x):
        return NamedSharding(mesh, batch_spec(mesh, x.shape[0]))
    return jax.tree.map(one, batch)


def cache_shardings(cfg: ModelConfig, abstract_cache: Any, mesh,
                    *, kv_variant: str = "auto") -> Any:
    """KV/state cache: batch dim over (pod, data); for KV leaves prefer head
    sharding on ``model``, else cache-seq sharding (context parallelism) —
    required e.g. for granite (kv=1) where heads cannot shard.

    ``kv_variant``:
      auto         — heads on model if divisible, else cache-seq (baseline)
      batch_model  — KV batch dim on (`pod`|`data`)×`model` (decode §Perf
                     variant: attention goes collective-free; activations
                     reshard around it)
    """
    baxes = batch_axes(mesh)
    m_ax = "model" if "model" in mesh.axis_names else None

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        if name == "pos":
            spec[0] = _fits(shape[0], mesh, baxes if baxes else None)
            return NamedSharding(mesh, P(*spec))
        # stacked cache leaves: (L, B, ...) — kv: (L,B,C,Hkv,hd);
        # ssm conv (L,B,K-1,C), state (L,B,H,P,N); rg conv (L,B,K-1,dr), h (L,B,dr)
        if name in ("k", "v", "xk", "xv") and nd == 5:
            if kv_variant == "batch_model":
                all_ax = tuple(baxes) + ((m_ax,) if m_ax else ())
                spec[1] = _fits(shape[1], mesh, all_ax)
                if spec[1] is None:
                    spec[1] = _fits(shape[1], mesh, m_ax)
                return NamedSharding(mesh, P(*spec))
            spec[1] = _fits(shape[1], mesh, baxes if baxes else None)
            if _fits(shape[3], mesh, m_ax):
                spec[3] = m_ax                      # heads
            else:
                spec[2] = _fits(shape[2], mesh, m_ax)  # cache seq (CP)
            return NamedSharding(mesh, P(*spec))
        if nd >= 2:
            spec[1] = _fits(shape[1], mesh, baxes if baxes else None)
        if nd >= 3:
            # last dim is a width (channels / state) — shard on model if it fits
            spec[nd - 1] = _fits(shape[nd - 1], mesh, m_ax)
        return NamedSharding(mesh, P(*spec))

    flat = jax.tree_util.tree_flatten_with_path(abstract_cache)[0]
    treedef = jax.tree.structure(abstract_cache)
    return jax.tree.unflatten(treedef, [one(p, l) for p, l in flat])


def describe(shardings: Any) -> str:
    lines = []
    for path, sh in jax.tree_util.tree_flatten_with_path(shardings)[0]:
        lines.append(f"  {_path_str(path):50s} {sh.spec}")
    return "\n".join(lines)
