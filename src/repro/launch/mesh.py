"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count`` before first jax init and then
calls this; tests import it with the default single device without side
effects.

Mesh layout (TPU v5e pods of 16×16 = 256 chips):
  single-pod:  (data=16, model=16)          — FSDP/batch × TP
  multi-pod:   (pod=2, data=16, model=16)   — pod = DCN data parallelism;
               within a pod, ICI FSDP × TP. The ``pod`` axis composes with
               ``data`` for the global batch dimension.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many real devices exist (tests/examples)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_sweep_mesh(n_devices: int = 0):
    """1-D mesh over local devices; ``repro.sweep.engine`` lays the sweep
    batch axis across it (data-parallel points, zero collectives)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("sweep",))


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the global-batch dimension."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
