import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
derive the roofline terms from the compiled artifact.

Two passes per cell:

  * **compile/memory pass** — the FULL config, layers under ``lax.scan``
    (unroll=1). Proves the sharding is coherent (SPMD partitioning succeeds),
    yields ``memory_analysis()`` (per-device bytes — proves it fits HBM).
  * **cost pass (secant)** — ``cost_analysis`` counts a scan body ONCE, not
    × trip-count, so per-layer cost is measured from two (three for hybrid)
    small fully-unrolled probe configs and extrapolated linearly in L:
    cost(L) = base + n_blocks(L)·per_block [+ n_rem·per_rem]. Exact because
    unrolled layers are cost-identical; validated against full unroll for
    whisper-tiny (4L) in tests/test_dryrun_probes.py.

Collective bytes are not in cost_analysis: we parse the partitioned HLO and
sum per-device wire bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (factors: AR=2×out, RS=1×in, AG/A2A/CP=1×out
— ring-algorithm estimates, documented in EXPERIMENTS.md).

CPU-backend caveat (recorded in every artifact): XLA CPU upcasts bf16
matmul operands to f32 (convert-before-gather), inflating HLO bytes and
collective bytes up to 2× vs the TPU lowering. FLOPs are unaffected.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding

from repro.axes import use_mesh
from repro.configs.base import ModelConfig, all_configs, get_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, ShapeSpec, applicable,
                                 default_q_chunk, input_specs)
from repro.models import lm
from repro.optim.adamw import OptConfig, abstract_opt
from repro.runtime import steps as steps_mod

# --------------------------------------------------------------- HW constants
PEAK_FLOPS = 197e12        # TPU v5e bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_COLL_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)"
)
_SHAPE_RE = re.compile(r"%?([\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: older
    releases return a list with one dict per program, newer ones return the
    dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device wire-byte estimate per collective kind (partitioned HLO)."""
    # name -> (dtype, dims) for operand-shape resolution (reduce-scatter)
    defs: Dict[str, Tuple[str, str]] = {}
    for m in _SHAPE_RE.finditer(hlo_text):
        defs[m.group(1)] = (m.group(2), m.group(3))

    out: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        name, dtype, dims, kind, operands = m.groups()
        obytes = _nbytes(dtype, dims)
        if kind == "all-reduce":
            wire = 2.0 * obytes
        elif kind == "reduce-scatter":
            wire = float(obytes)  # fallback: output bytes
            # operand may carry an inline shape, else resolve its name
            m_in = re.search(r"([a-z0-9]+)\[([\d,]*)\]", operands)
            if m_in:
                wire = float(_nbytes(m_in.group(1), m_in.group(2)))
            else:
                ops = [o.strip().split()[-1].lstrip("%")
                       for o in operands.split(",") if o.strip()]
                if ops and ops[0] in defs:
                    wire = float(_nbytes(*defs[ops[0]]))  # input ≈ ring wire
        else:  # all-gather / all-to-all / collective-permute
            wire = float(obytes)
        out[kind] = out.get(kind, 0.0) + wire
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": out, "count_by_kind": count,
            "total_bytes": sum(out.values())}


# ------------------------------------------------------------------ lowering
_abstract_opt = abstract_opt


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               unroll: int = 1, q_chunk: Optional[int] = None,
               chunk_unroll: int = 1, fsdp: bool = True, remat: bool = True,
               n_micro: int = 1, kv_variant: str = "auto"):
    """Lower one (cfg, shape) on mesh. Returns jax ``Lowered``."""
    if q_chunk is None:
        q_chunk = default_q_chunk(cfg, shape)
    abstract_params = lm.abstract_params(cfg, max_seq=shape.seq_len)
    p_sh = shd.param_shardings(cfg, abstract_params, mesh, fsdp=fsdp)
    specs = input_specs(cfg, shape)

    with use_mesh(mesh):
        if shape.kind == "train":
            opt_cfg = OptConfig()
            step = steps_mod.make_train_step(
                cfg, opt_cfg, unroll=unroll, remat=remat, q_chunk=q_chunk,
                chunk_unroll=chunk_unroll, n_micro=n_micro)
            o_sh = shd.opt_shardings(p_sh, mesh)
            b_sh = shd.data_shardings(mesh, specs["batch"])
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
            return fn.lower(abstract_params, _abstract_opt(abstract_params),
                            specs["batch"])
        if shape.kind == "prefill":
            step = steps_mod.make_prefill_step(
                cfg, unroll=unroll, q_chunk=q_chunk, chunk_unroll=chunk_unroll)
            b_sh = shd.data_shardings(mesh, specs["batch"])
            fn = jax.jit(step, in_shardings=(p_sh, b_sh))
            return fn.lower(abstract_params, specs["batch"])
        # decode
        step = steps_mod.make_serve_step(cfg, unroll=unroll)
        cache = specs["cache"]
        c_sh = shd.cache_shardings(cfg, cache, mesh, kv_variant=kv_variant)
        t_sh = NamedSharding(mesh, shd.batch_spec(mesh, shape.global_batch))
        fn = jax.jit(step, in_shardings=(p_sh, t_sh, c_sh), donate_argnums=(2,))
        return fn.lower(abstract_params, specs["token"], cache)


# ----------------------------------------------------------- secant cost fit
def _probe_layers(cfg: ModelConfig):
    if cfg.family == "hybrid":
        return (2, 3, 6)
    return (1, 2)


def _with_layers(cfg: ModelConfig, L: int) -> ModelConfig:
    return dataclasses.replace(cfg, name=f"{cfg.name}-probe{L}", n_layers=L)


def _reconstruct(cfg: ModelConfig, costs: Dict[int, float]) -> float:
    """Extrapolate a linear-in-depth cost to the full layer count."""
    if cfg.family == "hybrid":
        c2, c3, c6 = costs[2], costs[3], costs[6]
        sb = c6 - c3                      # per (rec,rec,attn) superblock
        base = c3 - sb
        rl = (c2 - base) / 2.0            # per remainder rec layer
        n_super, n_rem, _ = lm.hybrid_layout(cfg)
        return base + n_super * sb + n_rem * rl
    c1, c2 = costs[1], costs[2]
    pl = c2 - c1
    return c1 + (cfg.n_layers - 1) * pl


def cost_pass(cfg: ModelConfig, shape: ShapeSpec, mesh, *, fsdp: bool = True,
              remat: bool = True, q_chunk: Optional[int] = None,
              n_micro: int = 1, kv_variant: str = "auto") -> Dict[str, Any]:
    """Secant-extrapolated flops / bytes / collective bytes (per device)."""
    if q_chunk is None:
        q_chunk = default_q_chunk(cfg, shape)
    nc = (shape.seq_len // q_chunk) if (q_chunk and shape.kind != "decode") else 1
    metrics: Dict[int, Dict[str, float]] = {}
    for L in _probe_layers(cfg):
        pcfg = _with_layers(cfg, L)
        lowered = lower_cell(pcfg, shape, mesh, unroll=max(L, 1),
                             q_chunk=q_chunk, chunk_unroll=max(nc, 1),
                             fsdp=fsdp, remat=remat, n_micro=n_micro,
                             kv_variant=kv_variant)
        compiled = lowered.compile()
        ca = cost_analysis_dict(compiled)
        coll = collective_bytes(compiled.as_text())
        metrics[L] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_bytes"]),
        }
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        out[key] = max(_reconstruct(cfg, {L: m[key] for L, m in metrics.items()}),
                       0.0)
    out["probes"] = {str(L): m for L, m in metrics.items()}
    return out


# -------------------------------------------------------------------- driver
def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train (N = active params), 2·N·B decode."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: Optional[str] = None, fsdp: bool = True,
             remat: bool = True, q_chunk: Optional[int] = None,
             n_micro: int = 1, skip_cost: bool = False,
             tag: str = "", kv_variant: str = "auto",
             cfg_overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "multi_pod": multi_pod, "fsdp": fsdp, "n_micro": n_micro, "tag": tag,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        _emit(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.size)
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, unroll=1, q_chunk=q_chunk,
                         chunk_unroll=1, fsdp=fsdp, remat=remat,
                         n_micro=n_micro, kv_variant=kv_variant)
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    print(ma)   # proves it fits (per-device bytes)
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_bytes": int(ma.peak_memory_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    rec["memory"]["live_bytes"] = int(live)
    rec["fits_hbm_16g"] = bool(live < 16e9)
    ca = cost_analysis_dict(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    coll_full = collective_bytes(compiled.as_text())
    rec["scan_hlo"] = {
        "flops_scanbody_once": float(ca.get("flops", 0.0)),
        "coll_bytes_scanbody_once": float(coll_full["total_bytes"]),
        "coll_counts": coll_full["count_by_kind"],
    }

    if not skip_cost:
        cost = cost_pass(cfg, shape, mesh, fsdp=fsdp, remat=remat,
                         q_chunk=q_chunk, n_micro=n_micro,
                         kv_variant=kv_variant)
        rec["cost"] = cost
        mf = model_flops(cfg, shape)
        fl_dev = cost["flops"]
        by_dev = cost["bytes"]
        cb_dev = cost["coll_bytes"]
        t_comp = fl_dev / PEAK_FLOPS
        t_mem = by_dev / HBM_BW
        t_coll = cb_dev / ICI_BW
        dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
        rec["roofline"] = {
            "chips": n_chips,
            "flops_per_dev": fl_dev,
            "bytes_per_dev": by_dev,
            "coll_bytes_per_dev": cb_dev,
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dom[1],
            "bound_s": max(t_comp, t_mem, t_coll),
            "model_flops_total": mf,
            "model_flops_per_dev": mf / n_chips,
            "useful_flops_ratio": (mf / n_chips) / fl_dev if fl_dev else 0.0,
            "roofline_frac": (mf / n_chips / PEAK_FLOPS)
                             / max(t_comp, t_mem, t_coll)
                             if max(t_comp, t_mem, t_coll) > 0 else 0.0,
        }
    rec["status"] = "ok"
    _emit(rec, out_dir)
    return rec


def _emit(rec: Dict[str, Any], out_dir: Optional[str]):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{rec['tag']}" if rec.get("tag") else ""
        path = os.path.join(
            out_dir, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    status = rec.get("status")
    if status == "skipped":
        print(f"[dryrun] {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:10s} "
              f"SKIP ({rec['reason'][:60]})")
    else:
        r = rec.get("roofline", {})
        print(f"[dryrun] {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:10s} "
              f"OK compile={rec.get('compile_s')}s "
              f"peak={rec['memory']['peak_bytes']/1e9:.2f}GB "
              f"dom={r.get('dominant','-'):10s} "
              f"frac={r.get('roofline_frac', 0):.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--moe-ep", action="store_true")
    ap.add_argument("--attn-bf16", action="store_true")
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--rg-scan-bf16", action="store_true")
    ap.add_argument("--remat-policy", default="full", choices=("full", "dots"))
    ap.add_argument("--kv-variant", default="auto",
                    choices=("auto", "batch_model"))
    args = ap.parse_args()
    overrides = {}
    if args.moe_ep:
        overrides["moe_ep"] = True
    if args.attn_bf16:
        overrides["attn_av_bf16"] = True
    if args.moe_group:
        overrides["moe_group"] = args.moe_group
    if args.rg_scan_bf16:
        overrides["rg_scan_bf16"] = True
    if args.remat_policy != "full":
        overrides["remat_policy"] = args.remat_policy

    archs = sorted(all_configs()) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                             fsdp=not args.no_fsdp, remat=not args.no_remat,
                             q_chunk=args.q_chunk, n_micro=args.n_micro,
                             skip_cost=args.skip_cost, tag=args.tag,
                             kv_variant=args.kv_variant,
                             cfg_overrides=overrides or None)
                except Exception as e:  # noqa: BLE001 — report all cells
                    failures.append((arch, shape, mp, repr(e)[:200]))
                    print(f"[dryrun] {arch} {shape} mp={mp} FAIL: {e!r}"[:300])
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
