"""Assigned input shapes × per-arch input specs (ShapeDtypeStruct stand-ins —
weak-type-correct, shardable, zero allocation).

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step
  decode_32k   seq 32,768  global_batch 128   -> serve_step (KV at seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; sub-quadratic
               archs only (ssm / hybrid / SWA) — full-attention archs skip
               (no sub-quadratic path; DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch — no sub-quadratic path at "
                       "524k context (DESIGN.md §6)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for the (train/prefill) host batch."""
    b, s = shape.global_batch, shape.seq_len
    cd = jnp.dtype(cfg.compute_dtype)
    batch: Dict[str, Any] = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = _sds((b, cfg.enc_frames, cfg.d_model), cd)
    if cfg.frontend == "vision_stub":
        batch["patches"] = _sds((b, cfg.n_patches, cfg.d_model), cd)
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """Abstract KV/state cache for the decode shapes (no allocation)."""
    from repro.models import lm
    return jax.eval_shape(
        lambda: lm.cache_spec(cfg, shape.global_batch, shape.seq_len,
                              enc_frames=cfg.enc_frames)
    )


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """All inputs of the step function for this (arch × shape) cell."""
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_specs(cfg, shape)}
    return {
        "token": _sds((shape.global_batch,), jnp.int32),
        "cache": cache_specs(cfg, shape),
    }


def default_q_chunk(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Query-block size for full-sequence shapes (0 = unchunked attention).

    Materializing (B, H, S, S) scores at S=4096 is ~1 TB/device for the
    train_4k shapes — no production framework does that. The query-block
    streaming path bounds live scores to (B, H, q_chunk, S); 1k/2k blocks
    keep the MXU matmul dims ≥128-aligned."""
    if shape.kind == "decode" or shape.seq_len < 4_096:
        return 0
    return 1_024 if shape.seq_len <= 8_192 else 2_048
