"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --steps 200 \
      --batch 8 --seq 256 --reduced --ckpt /tmp/ckpt

``--reduced`` runs the CPU-sized variant of the arch (the full configs are
for the production mesh; this container has one device). On a real cluster
the same entry point runs with ``--mesh-data/--mesh-model`` spanning the
pod; the Trainer, sharding rules and checkpoint format are identical.
"""
from __future__ import annotations

import argparse


from repro.configs.base import get_config
from repro.launch.mesh import make_debug_mesh
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import FaultPlan, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject synthetic faults at these steps (recovery demo)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_debug_mesh(args.mesh_data, args.mesh_model)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt, global_batch=args.batch,
                     seq_len=args.seq, n_micro=args.n_micro)
    opt = OptConfig(lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 5))
    tr = Trainer(cfg, tc, mesh, opt)
    plan = FaultPlan(args.fail_at) if args.fail_at else None
    out = tr.run(fault_plan=plan)
    print(f"done: final_loss={out['final_loss']:.4f} "
          f"stragglers={out['stragglers']} events={out['events']}")


if __name__ == "__main__":
    main()
