"""Serving launcher: continuous batching demo over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import get_config
from repro.models import lm
from repro.runtime.server import Request, ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(cfg, jax.random.key(0), max_seq=args.max_seq)
    sc = ServeConfig(n_slots=args.slots, max_prompt=args.max_prompt,
                     max_seq=args.max_seq, max_new_tokens=args.max_new)
    srv = Server(cfg, sc, params)
    reqs = [Request(rid=i, prompt=[(7 * i + j) % max(cfg.vocab // 2, 2) + 1
                                   for j in range(5 + i % 7)])
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in reqs)
    for r in reqs[:4]:
        print(f"req {r.rid}: {r.out}")
    print(f"served {len(reqs)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, {srv.steps_run} decode steps)")


if __name__ == "__main__":
    main()
