"""Serving launcher: continuous batching demo over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --requests 8 --slots 4 --telemetry

Reports steady-state decode throughput (a warmup request triggers prefill +
decode compilation before the timed run, so tok/s no longer includes jit
time), per-request TTFT/ITL from the host-side lifecycle log, and — with
``--telemetry`` — the device serve-plane summary (read provenance, saved
port cycles, recode backlog) for the coded KV pool backend.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import get_config
from repro.models import lm
from repro.obs import serve as obs_serve
from repro.runtime.server import Request, ServeConfig, Server


def _mk_requests(cfg, n, base=0):
    return [Request(rid=base + i,
                    prompt=[(7 * (base + i) + j) % max(cfg.vocab // 2, 2) + 1
                            for j in range(5 + i % 7)])
            for i in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--uncoded", action="store_true",
                    help="uncoded KV pool (no parity arrays)")
    ap.add_argument("--telemetry", action="store_true",
                    help="device serve metric planes + summary")
    ap.add_argument("--page", type=int, default=0,
                    help="pool page size in tokens (0: config default)")
    ap.add_argument("--recode-budget", type=int, default=None,
                    help="parity rows recoded per step (default: all)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(cfg, jax.random.key(0), max_seq=args.max_seq)
    sc = ServeConfig(n_slots=args.slots, max_prompt=args.max_prompt,
                     max_seq=args.max_seq, max_new_tokens=args.max_new,
                     coded=not args.uncoded, telemetry=args.telemetry,
                     page=args.page, recode_budget=args.recode_budget)
    srv = Server(cfg, sc, params)

    # warmup: one request end to end compiles prefill + decode, so the timed
    # run below measures steady-state serving, not jit time.
    for r in _mk_requests(cfg, 1, base=10_000):
        srv.submit(r)
    srv.run_until_drained()
    warm_steps = srv.steps_run

    reqs = _mk_requests(cfg, args.requests)
    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in reqs)
    for r in reqs[:4]:
        print(f"req {r.rid}: {r.out}")
    backend = ("coded pool" if sc.coded else "uncoded pool") \
        if srv.pooled else "ring cache"
    rate = f"{n_tok / dt:.1f} tok/s" if dt > 0 else "n/a tok/s"
    print(f"served {len(reqs)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({rate} steady-state, {srv.steps_run - warm_steps} decode "
          f"steps, {backend})")
    spans = [s for s in srv.log.spans() if s["rid"] < 10_000]
    for s in spans:
        itl = s["inter_token_s"]
        mean_itl = 1e3 * sum(itl) / len(itl) if itl else 0.0
        print(f"  req {s['rid']}: wait {1e3 * s['admission_wait_s']:.1f} ms"
              f" ttft {1e3 * s['ttft_s']:.1f} ms"
              f" mean-itl {mean_itl:.1f} ms ({s['n_tokens']} tokens)")
    snap = srv.serve_snapshot()
    if snap is not None:
        print(obs_serve.format_summary(snap))


if __name__ == "__main__":
    main()
