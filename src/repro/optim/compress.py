"""Gradient compression for the data-parallel all-reduce.

int8 block-quantization with error feedback: each leaf is quantized to int8
with a per-block f32 scale before crossing the DP axis, cutting DP collective
bytes ~4× vs f32 (~2× vs bf16). The quantization residual is fed back into
the next step's gradient (error feedback), which keeps SGD/Adam convergence
(Seide et al., 1-bit SGD lineage).

Used by ``repro.runtime.trainer`` when ``grad_compress=True``; the dry-run
shows the all-reduce operand dtype shrink to s8 — that delta is recorded in
EXPERIMENTS.md §Perf as a collective-term optimization.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    n = x.size
    pad = (-n) % BLOCK
    flat = x.reshape(-1)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), n


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g -> (q int8 (nb, BLOCK), scale f32 (nb, 1))."""
    blocks, _ = _pad_to_block(g.astype(jnp.float32))
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= s
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def compress_tree(grads: Any):
    """Pytree → (list of (q, scale) per leaf, residual f32 pytree, treedef).

    Residual = g - dequantize(quantize(g)); feed it into the next step's
    gradient before compressing (error feedback)."""
    leaves, treedef = jax.tree.flatten(grads)
    comp_leaves, resid_leaves = [], []
    for g in leaves:
        q, s = compress_int8(g)
        deq = decompress_int8(q, s, g.shape, jnp.float32)
        comp_leaves.append((q, s))
        resid_leaves.append(g.astype(jnp.float32) - deq)
    return comp_leaves, jax.tree.unflatten(treedef, resid_leaves), treedef


def decompress_list(comp_leaves, shapes, dtypes, treedef) -> Any:
    return jax.tree.unflatten(
        treedef,
        [decompress_int8(q, s, sh, dt)
         for (q, s), sh, dt in zip(comp_leaves, shapes, dtypes)],
    )
