"""Pure-JAX optimizer stack: AdamW + schedules + clipping + compression."""
from repro.optim.adamw import (  # noqa: F401
    OptConfig,
    OptState,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from repro.optim.compress import (  # noqa: F401
    compress_int8,
    decompress_int8,
)
