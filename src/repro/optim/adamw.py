"""AdamW with decoupled weight decay, global-norm clipping and a cosine
schedule — as pure pytree functions (no optax dependency). Moments are
stored in f32 regardless of param dtype (mixed-precision master moments)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: Any             # f32 pytree like params
    v: Any             # f32 pytree like params


def cosine_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = (s - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def abstract_opt(abstract_params: Any) -> OptState:
    """ShapeDtypeStruct skeleton of the optimizer state (dry-run / restore)."""
    f32 = lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32)
    zeros = jax.tree.map(f32, abstract_params)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), m=zeros,
                    v=jax.tree.map(lambda x: x, zeros))


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/scalars (standard practice)."""
    keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    last = str(keys[-1]) if keys else ""
    return not any(s in last for s in ("scale", "bias", "A_log", "D", "dt_bias",
                                       "norm"))


def adamw_update(
    cfg: OptConfig, grads: Any, state: OptState, params: Any
) -> Tuple[Any, OptState, jnp.ndarray]:
    """Returns (new_params, new_state, grad_norm). Clips by global norm."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    gl = jax.tree.leaves(grads)
    ml = jax.tree.leaves(state.m)
    vl = jax.tree.leaves(state.v)
    out = [upd(path, p, g, m, v)
           for (path, p), g, m, v in zip(flat, gl, ml, vl)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), gnorm
