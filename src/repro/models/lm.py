"""Unified language model over all assigned architecture families.

Families:
  dense / moe — pre-norm decoder blocks (GQA attention + MLP/MoE), scanned
                over stacked per-layer params.
  ssm         — mamba2 SSD mixer blocks (attention-free).
  hybrid      — recurrentgemma: repeating (rec, rec, local-attn) pattern.
  audio/vlm   — whisper enc-dec (audio_stub frontend) / phi3+vision_stub;
                modality frontends provide precomputed embeddings.

Three entry points per the assigned shapes:
  loss_fn(cfg, params, batch)            — train_4k         (train_step)
  prefill(cfg, params, batch)            — prefill_32k      (serve prefill)
  decode_step(cfg, params, token, cache) — decode_32k/long_500k (serve decode)

Params are nested dicts with per-layer leaves stacked on axis 0; layer loops
are ``lax.scan`` with configurable ``unroll`` (full unroll for trip-count-
accurate dry-run cost analysis) and per-layer ``jax.checkpoint`` for train.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.axes import shard
from repro.configs.base import ModelConfig
from repro.models import layers as ly
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod
from repro.models.embedding import embed_init, embed_lookup, full_table

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _dtype(name: str):
    return jnp.dtype(name)


def _cast_params(params, cd):
    """Cast float param leaves to the compute dtype (mixed-precision matmuls).
    Numerically-sensitive scalars (A_log, lam, …) are re-upcast to f32 inside
    their modules."""
    return jax.tree.map(
        lambda a: a.astype(cd) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        params,
    )


def _stack(fn, key, n: int):
    """Stack n per-layer param trees on axis 0. n == 0 yields zero-length
    leading dims (NOT None) so scans/tree.maps stay total — hybrid probe
    configs can have zero attention layers."""
    ps = [fn(k) for k in jax.random.split(key, max(n, 1))]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    if n == 0:
        return jax.tree.map(lambda a: a[:0], stacked)
    return jax.tree.map(lambda a: a[:n], stacked)


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_superblocks, n_rem_rec, n_attn) for the repeating block pattern."""
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    per = len(pat)
    n_super = cfg.n_layers // per
    rem = cfg.n_layers - n_super * per
    # remainder layers follow the pattern prefix; only 'rec' prefixes occur
    n_rem_rec = sum(1 for b in pat[:rem] if b == "rec")
    n_attn = n_super * sum(1 for b in pat if b == "attn")
    return n_super, n_rem_rec, n_attn


# ======================================================================
# init
# ======================================================================
def init_params(cfg: ModelConfig, key, max_seq: int = 2048) -> Params:
    pd = _dtype(cfg.param_dtype)
    k_embed, k_blocks, k_head, k_enc, k_pos = jax.random.split(key, 5)
    params: Params = {
        "embed": embed_init(cfg, k_embed, pd),
        "final_norm": ly.norm_init(cfg, pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab_pad), pd)
            * cfg.d_model ** -0.5
        )
    if cfg.pos == "learned":
        params["pos_embed"] = (
            jax.random.normal(k_pos, (max_seq, cfg.d_model), pd) * 0.02
        )

    def dense_block(k):
        k1, k2 = jax.random.split(k)
        p = {"norm1": ly.norm_init(cfg, pd), "norm2": ly.norm_init(cfg, pd),
             "attn": ly.attn_init(cfg, k1, pd)}
        if cfg.family == "moe":
            p["moe"] = moe_mod.moe_init(cfg, k2, pd)
        else:
            p["mlp"] = ly.mlp_init(cfg, k2, pd)
        return p

    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = _stack(dense_block, k_blocks, cfg.n_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stack(
            lambda k: {"norm1": ly.norm_init(cfg, pd),
                       "ssm": ssm_mod.ssm_init(cfg, k, pd)},
            k_blocks, cfg.n_layers)
    elif cfg.family == "hybrid":
        n_super, n_rem_rec, n_attn = hybrid_layout(cfg)
        n_rec = cfg.n_layers - n_attn

        def rec_block(k):
            k1, k2 = jax.random.split(k)
            return {"norm1": ly.norm_init(cfg, pd), "norm2": ly.norm_init(cfg, pd),
                    "rglru": rg.rglru_init(cfg, k1, pd),
                    "mlp": ly.mlp_init(cfg, k2, pd)}

        k_rec, k_attn = jax.random.split(k_blocks)
        params["rec_blocks"] = _stack(rec_block, k_rec, n_rec)
        params["attn_blocks"] = _stack(dense_block, k_attn, n_attn)
    elif cfg.family == "audio":  # whisper enc-dec
        def enc_block(k):
            k1, k2 = jax.random.split(k)
            return {"norm1": ly.norm_init(cfg, pd), "norm2": ly.norm_init(cfg, pd),
                    "attn": ly.attn_init(cfg, k1, pd), "mlp": ly.mlp_init(cfg, k2, pd)}

        def dec_block(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"norm1": ly.norm_init(cfg, pd), "norm2": ly.norm_init(cfg, pd),
                    "norm3": ly.norm_init(cfg, pd),
                    "attn": ly.attn_init(cfg, k1, pd),
                    "xattn": ly.attn_init(cfg, k2, pd),
                    "mlp": ly.mlp_init(cfg, k3, pd)}

        params["enc_blocks"] = _stack(enc_block, k_enc, cfg.enc_layers)
        params["enc_final_norm"] = ly.norm_init(cfg, pd)
        params["blocks"] = _stack(dec_block, k_blocks, cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return params


def abstract_params(cfg: ModelConfig, max_seq: int = 2048):
    """Shape-only params for the dry-run (no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, max_seq), jax.random.key(0)
    )


# ======================================================================
# shared pieces
# ======================================================================
def _sinusoid(t: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def _embed_tokens(cfg, params, tokens, cd, offset=0):
    x = embed_lookup(cfg, params["embed"], tokens, cd)
    if cfg.pos == "learned":
        pos = offset + jnp.arange(tokens.shape[-1])
        x = x + params["pos_embed"][pos].astype(cd)
    elif cfg.pos == "sinusoidal":
        x = x + _sinusoid(tokens.shape[-1], cfg.d_model).astype(cd)
    # GSPMD replicates through table gathers — re-pin the batch sharding here
    # or every downstream activation is replicated (found the hard way; see
    # EXPERIMENTS.md §Perf iteration 0).
    return shard(x, "batch", None, None)


def _logits(cfg, params, x):
    """Project to the *padded* vocab (shardable over the model axis) and mask
    the padding ids to -inf so downstream softmax/argmax never pick them."""
    if cfg.tie_embeddings:
        head = full_table(cfg, params["embed"]).T
    else:
        head = params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.vocab_pad != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_pad) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return shard(logits, *(["batch"] + [None] * (logits.ndim - 2) + ["vocab"]))


def _dense_block_fwd(cfg, bp, x, positions, window, q_chunk=0, chunk_unroll=1):
    h = ly.apply_norm(cfg, bp["norm1"], x)
    x = x + ly.attention_block(cfg, bp["attn"], h, positions, window,
                               q_chunk=q_chunk, chunk_unroll=chunk_unroll)
    h = ly.apply_norm(cfg, bp["norm2"], x)
    if "moe" in bp:
        x = x + moe_mod.moe_block(cfg, bp["moe"], h)
    else:
        x = x + ly.mlp_block(cfg, bp["mlp"], h)
    return x


def _rec_block_fwd(cfg, bp, x):
    h = ly.apply_norm(cfg, bp["norm1"], x)
    x = x + rg.rglru_block(cfg, bp["rglru"], h)
    h = ly.apply_norm(cfg, bp["norm2"], x)
    return x + ly.mlp_block(cfg, bp["mlp"], h)


def _ssm_block_fwd(cfg, bp, x):
    h = ly.apply_norm(cfg, bp["norm1"], x)
    return x + ssm_mod.ssm_block(cfg, bp["ssm"], h)


def _remat(cfg, body, remat: bool):
    """Layer-scan remat wrapper. remat_policy="dots" saves matmul outputs
    and recomputes only elementwise chains in the bwd pass — for gate-heavy
    blocks (RG-LRU) this removes most of the recompute traffic at a small
    residency cost (§Perf)."""
    if not remat:
        return body
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(body, policy=pol)
    return jax.checkpoint(body)


# ======================================================================
# full-sequence forward (train / prefill backbone)
# ======================================================================
def backbone(cfg: ModelConfig, params: Params, x: jnp.ndarray,
             *, unroll: int = 1, remat: bool = True,
             enc: Optional[jnp.ndarray] = None,
             q_chunk: int = 0, chunk_unroll: int = 1) -> jnp.ndarray:
    """Run all blocks over x (B,S,D). ``enc`` is the encoder output for
    enc-dec decoders. ``q_chunk`` > 0 switches attention to the query-block
    streaming path (needed for the 32k shapes)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    if cfg.family in ("dense", "moe", "vlm"):
        def body(xc, bp):
            return _dense_block_fwd(cfg, bp, xc, positions, cfg.sliding_window,
                                    q_chunk, chunk_unroll), None
        f = _remat(cfg, body, remat)
        x, _ = jax.lax.scan(f, x, params["blocks"], unroll=unroll)
    elif cfg.family == "ssm":
        def body(xc, bp):
            return _ssm_block_fwd(cfg, bp, xc), None
        f = _remat(cfg, body, remat)
        x, _ = jax.lax.scan(f, x, params["blocks"], unroll=unroll)
    elif cfg.family == "hybrid":
        n_super, n_rem_rec, n_attn = hybrid_layout(cfg)
        rec = params["rec_blocks"]
        rec_main = jax.tree.map(lambda a: a[: 2 * n_super].reshape(n_super, 2, *a.shape[1:]), rec)
        rec_rem = jax.tree.map(lambda a: a[2 * n_super:], rec)

        def sbody(xc, bps):
            rp2, ap = bps
            xc = _rec_block_fwd(cfg, jax.tree.map(lambda a: a[0], rp2), xc)
            xc = _rec_block_fwd(cfg, jax.tree.map(lambda a: a[1], rp2), xc)
            xc = _dense_block_fwd(cfg, ap, xc, positions, cfg.local_window,
                                  q_chunk, chunk_unroll)
            return xc, None

        f = _remat(cfg, sbody, remat)
        x, _ = jax.lax.scan(f, x, (rec_main, params["attn_blocks"]), unroll=unroll)
        if n_rem_rec:
            def rbody(xc, bp):
                return _rec_block_fwd(cfg, bp, xc), None
            fr = _remat(cfg, rbody, remat)
            x, _ = jax.lax.scan(fr, x, rec_rem, unroll=unroll)
    elif cfg.family == "audio":
        def body(xc, bp):
            h = ly.apply_norm(cfg, bp["norm1"], xc)
            xc = xc + ly.attention_block(cfg, bp["attn"], h, positions, 0,
                                         q_chunk=q_chunk, chunk_unroll=chunk_unroll)
            h = ly.apply_norm(cfg, bp["norm2"], xc)
            xc = xc + ly.cross_attention_block(cfg, bp["xattn"], h, enc)
            h = ly.apply_norm(cfg, bp["norm3"], xc)
            return xc + ly.mlp_block(cfg, bp["mlp"], h), None
        f = _remat(cfg, body, remat)
        x, _ = jax.lax.scan(f, x, params["blocks"], unroll=unroll)
    else:
        raise ValueError(cfg.family)
    return ly.apply_norm(cfg, params["final_norm"], x)


def encode(cfg: ModelConfig, params: Params, frames: jnp.ndarray,
           *, unroll: int = 1, remat: bool = True) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, F, D)."""
    cd = _dtype(cfg.compute_dtype)
    x = frames.astype(cd) + _sinusoid(frames.shape[1], cfg.d_model).astype(cd)

    def body(xc, bp):
        h = ly.apply_norm(cfg, bp["norm1"], xc)
        b, t, _ = xc.shape
        q, k, v = ly.qkv_proj(cfg, bp["attn"], h)
        o = ly.mha(q, k, v, None).reshape(b, t, -1) @ bp["attn"]["wo"]
        xc = xc + o
        h = ly.apply_norm(cfg, bp["norm2"], xc)
        return xc + ly.mlp_block(cfg, bp["mlp"], h), None

    f = _remat(cfg, body, remat)
    x, _ = jax.lax.scan(f, x, params["enc_blocks"], unroll=unroll)
    return ly.apply_norm(cfg, params["enc_final_norm"], x)


def apply_frontend(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                   batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """vision_stub: overwrite the first n_patches positions with the
    precomputed patch embeddings (prefix-image layout)."""
    if cfg.frontend == "vision_stub" and "patches" in batch:
        p = batch["patches"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, p, (0, 0, 0))
    return x


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            *, unroll: int = 1, remat: bool = True,
            q_chunk: int = 0, chunk_unroll: int = 1) -> jnp.ndarray:
    """Full-sequence logits (B, S, V_pad) fp32."""
    cd = _dtype(cfg.compute_dtype)
    params = _cast_params(params, cd)
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens, cd)
    x = apply_frontend(cfg, params, x, batch)
    enc = None
    if cfg.is_encdec:
        enc = encode(cfg, params, batch["frames"], unroll=unroll, remat=remat)
    x = backbone(cfg, params, x, unroll=unroll, remat=remat, enc=enc,
                 q_chunk=q_chunk, chunk_unroll=chunk_unroll)
    return _logits(cfg, params, x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            *, unroll: int = 1, remat: bool = True,
            q_chunk: int = 0, chunk_unroll: int = 1) -> jnp.ndarray:
    """Next-token cross entropy, written to be *vocab-sharding friendly*:
    ``log_softmax`` + ``take_along_axis`` over a model-sharded vocab dim
    force GSPMD to all-gather the full (B,S,V) logits (~40 GB/device for the
    train_4k shapes). Instead we compute logsumexp + a where-masked pick —
    every intermediate stays V-sharded and only (B,S) arrays cross shards."""
    logits = forward(cfg, params, batch, unroll=unroll, remat=remat,
                     q_chunk=q_chunk, chunk_unroll=chunk_unroll)
    targets = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    iota = jnp.arange(cfg.vocab_pad)[None, None, :]
    pick = jnp.sum(jnp.where(iota == targets[..., None], lg, 0.0), axis=-1)
    return jnp.mean(lse - pick)


# ======================================================================
# serving: prefill + decode
# ======================================================================
def cache_spec(cfg: ModelConfig, batch: int, seq_len: int,
               enc_frames: int = 0) -> Dict[str, Any]:
    """Abstract cache shapes (used by init and by the dry-run input specs)."""
    cd = _dtype(cfg.compute_dtype)
    c: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    hd = cfg.head_dim

    def kv(n_layers, window):
        clen = min(seq_len, window) if window else seq_len
        return jnp.zeros((n_layers, batch, clen, cfg.n_kv, hd), cd)

    if cfg.family in ("dense", "moe", "vlm"):
        c["k"] = kv(cfg.n_layers, cfg.sliding_window)
        c["v"] = kv(cfg.n_layers, cfg.sliding_window)
    elif cfg.family == "ssm":
        di, nh, hp, n = ssm_mod.ssm_dims(cfg)
        c["ssm"] = ssm_mod.SSMCache(
            conv=jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, di + 2 * n), cd),
            state=jnp.zeros((cfg.n_layers, batch, nh, hp, n), jnp.float32),
        )
    elif cfg.family == "hybrid":
        n_super, n_rem_rec, n_attn = hybrid_layout(cfg)
        n_rec = cfg.n_layers - n_attn
        dr = cfg.d_model
        c["rg"] = rg.RGLRUCache(
            conv=jnp.zeros((n_rec, batch, rg._CONV_K - 1, dr), cd),
            h=jnp.zeros((n_rec, batch, dr), jnp.float32),
        )
        c["k"] = kv(n_attn, cfg.local_window)
        c["v"] = kv(n_attn, cfg.local_window)
    elif cfg.family == "audio":
        c["k"] = kv(cfg.n_layers, 0)
        c["v"] = kv(cfg.n_layers, 0)
        c["xk"] = jnp.zeros((cfg.n_layers, batch, enc_frames, cfg.n_kv, hd), cd)
        c["xv"] = jnp.zeros((cfg.n_layers, batch, enc_frames, cfg.n_kv, hd), cd)
    return c


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            *, unroll: int = 1, q_chunk: int = 0,
            chunk_unroll: int = 1,
            max_seq: Optional[int] = None) -> Tuple[jnp.ndarray, Cache]:
    """Process the prompt; return (last-token logits (B,V) f32, cache).

    ``max_seq`` sets the KV ring capacity (decode headroom). Default = the
    prompt length — callers that decode afterwards must pass a larger value
    or repack (the Server repacks; direct decode_step needs headroom here).
    """
    cd = _dtype(cfg.compute_dtype)
    params = _cast_params(params, cd)
    tokens = batch["tokens"]
    b, s = tokens.shape
    cap_full = max(max_seq or s, s)
    positions = jnp.arange(s)[None, :]
    x = _embed_tokens(cfg, params, tokens, cd)
    x = apply_frontend(cfg, params, x, batch)
    cache: Cache = {"pos": jnp.full((b,), s, jnp.int32)}

    def ring(full_kv, window):
        """(B,S,Hkv,dh) -> ring cache (B,C,Hkv,dh) with slot i%C semantics."""
        cap = min(cap_full, window) if window else cap_full
        c = min(s, cap)
        last = full_kv[:, s - c:]
        if c == s == cap:
            return last
        # place token j at slot j % cap
        idx = (jnp.arange(s - c, s)) % cap
        out = jnp.zeros((b, cap) + full_kv.shape[2:], full_kv.dtype)
        return out.at[:, idx].set(last)

    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        enc = None
        if cfg.is_encdec:
            enc = encode(cfg, params, batch["frames"], unroll=unroll, remat=False)

        if cfg.family == "hybrid":
            n_super, n_rem_rec, n_attn = hybrid_layout(cfg)
            rec = params["rec_blocks"]
            rec_main = jax.tree.map(
                lambda a: a[: 2 * n_super].reshape(n_super, 2, *a.shape[1:]), rec)
            rec_rem = jax.tree.map(lambda a: a[2 * n_super:], rec)

            def sbody(xc, bps):
                rp2, ap = bps
                rcaches = []
                for i in range(2):
                    rp = jax.tree.map(lambda a: a[i], rp2)
                    h = ly.apply_norm(cfg, rp["norm1"], xc)
                    o, rc = rg.rglru_block(cfg, rp["rglru"], h, return_cache=True)
                    xc = xc + o
                    h = ly.apply_norm(cfg, rp["norm2"], xc)
                    xc = xc + ly.mlp_block(cfg, rp["mlp"], h)
                    rcaches.append(rc)
                h = ly.apply_norm(cfg, ap["norm1"], xc)
                q, k, v = ly.qkv_proj(cfg, ap["attn"], h)
                q = ly.rope(q, positions, cfg.rope_theta)
                k = ly.rope(k, positions, cfg.rope_theta)
                if q_chunk and q_chunk < s:
                    o = ly.mha_chunked(q, k, v, window=cfg.local_window,
                                       q_chunk=q_chunk, unroll=chunk_unroll)
                else:
                    o = ly.mha(q, k, v, ly.causal_mask(s, s, 0, cfg.local_window))
                xc = xc + o.reshape(b, s, -1) @ ap["attn"]["wo"]
                h = ly.apply_norm(cfg, ap["norm2"], xc)
                xc = xc + ly.mlp_block(cfg, ap["mlp"], h)
                rc2 = jax.tree.map(lambda a, bb: jnp.stack([a, bb]), rcaches[0], rcaches[1])
                return xc, (rc2, ring(k, cfg.local_window), ring(v, cfg.local_window))

            x, (rc_main, ks, vs) = jax.lax.scan(sbody, x, (rec_main, params["attn_blocks"]),
                                                unroll=unroll)
            rc_main = jax.tree.map(
                lambda a: a.reshape(a.shape[0] * 2, *a.shape[2:]), rc_main)
            if n_rem_rec:
                def rbody(xc, rp):
                    h = ly.apply_norm(cfg, rp["norm1"], xc)
                    o, rc = rg.rglru_block(cfg, rp["rglru"], h, return_cache=True)
                    xc = xc + o
                    h = ly.apply_norm(cfg, rp["norm2"], xc)
                    return xc + ly.mlp_block(cfg, rp["mlp"], h), rc
                x, rc_rem_out = jax.lax.scan(rbody, x, rec_rem, unroll=unroll)
                cache["rg"] = jax.tree.map(
                    lambda a, bb: jnp.concatenate([a, bb], 0), rc_main, rc_rem_out)
            else:
                cache["rg"] = rc_main
            cache["k"], cache["v"] = ks, vs
        else:
            window = cfg.sliding_window

            def body(xc, bp):
                h = ly.apply_norm(cfg, bp["norm1"], xc)
                q, k, v = ly.qkv_proj(cfg, bp["attn"], h)
                if cfg.pos == "rope":
                    q = ly.rope(q, positions, cfg.rope_theta)
                    k = ly.rope(k, positions, cfg.rope_theta)
                if q_chunk and q_chunk < s:
                    o = ly.mha_chunked(q, k, v, window=window,
                                       q_chunk=q_chunk, unroll=chunk_unroll)
                else:
                    o = ly.mha(q, k, v, ly.causal_mask(s, s, 0, window))
                xc = xc + o.reshape(b, s, -1) @ bp["attn"]["wo"]
                ys = [ring(k, window), ring(v, window)]
                if cfg.is_encdec:
                    h = ly.apply_norm(cfg, bp["norm2"], xc)
                    xk = (enc @ bp["xattn"]["wk"]).reshape(b, -1, cfg.n_kv, cfg.head_dim)
                    xv = (enc @ bp["xattn"]["wv"]).reshape(b, -1, cfg.n_kv, cfg.head_dim)
                    if "bk" in bp["xattn"]:
                        xk = xk + bp["xattn"]["bk"].reshape(cfg.n_kv, cfg.head_dim)
                        xv = xv + bp["xattn"]["bv"].reshape(cfg.n_kv, cfg.head_dim)
                    h2 = ly.cross_attention_block(cfg, bp["xattn"], h, enc)
                    xc = xc + h2
                    h = ly.apply_norm(cfg, bp["norm3"], xc)
                    xc = xc + ly.mlp_block(cfg, bp["mlp"], h)
                    ys += [xk, xv]
                else:
                    h = ly.apply_norm(cfg, bp["norm2"], xc)
                    if "moe" in bp:
                        xc = xc + moe_mod.moe_block(cfg, bp["moe"], h)
                    else:
                        xc = xc + ly.mlp_block(cfg, bp["mlp"], h)
                return xc, tuple(ys)

            x, ys = jax.lax.scan(body, x, params["blocks"], unroll=unroll)
            cache["k"], cache["v"] = ys[0], ys[1]
            if cfg.is_encdec:
                cache["xk"], cache["xv"] = ys[2], ys[3]
    elif cfg.family == "ssm":
        def body(xc, bp):
            h = ly.apply_norm(cfg, bp["norm1"], xc)
            o, sc = ssm_mod.ssm_block(cfg, bp["ssm"], h, return_cache=True)
            return xc + o, sc
        x, sc = jax.lax.scan(body, x, params["blocks"], unroll=unroll)
        cache["ssm"] = sc
    else:
        raise ValueError(cfg.family)

    x = ly.apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x[:, -1:])[:, 0], cache


def decode_step(cfg: ModelConfig, params: Params, token: jnp.ndarray,
                cache: Cache, *, unroll: int = 1) -> Tuple[jnp.ndarray, Cache]:
    """One decode step. token (B,) int32 -> (logits (B,V) f32, cache')."""
    cd = _dtype(cfg.compute_dtype)
    params = _cast_params(params, cd)
    b = token.shape[0]
    pos = cache["pos"]
    x = embed_lookup(cfg, params["embed"], token[:, None], cd)
    if cfg.pos == "learned":
        mp = params["pos_embed"].shape[0]
        x = x + params["pos_embed"][jnp.minimum(pos, mp - 1)][:, None].astype(cd)
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        window = cfg.sliding_window

        def body(xc, bps):
            if cfg.is_encdec:
                bp, kc, vc, xkc, xvc = bps
            else:
                bp, kc, vc = bps
            h = ly.apply_norm(cfg, bp["norm1"], xc)
            o, kc, vc = ly.attention_decode(cfg, bp["attn"], h, pos, kc, vc, window)
            xc = xc + o
            if cfg.is_encdec:
                h = ly.apply_norm(cfg, bp["norm2"], xc)
                q = (h @ bp["xattn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
                if "bq" in bp["xattn"]:
                    q = q + bp["xattn"]["bq"].reshape(cfg.n_heads, cfg.head_dim)
                o = ly.mha(q, xkc, xvc, None)
                xc = xc + o.reshape(b, 1, -1) @ bp["xattn"]["wo"]
                h = ly.apply_norm(cfg, bp["norm3"], xc)
                xc = xc + ly.mlp_block(cfg, bp["mlp"], h)
                return xc, (kc, vc)
            h = ly.apply_norm(cfg, bp["norm2"], xc)
            if "moe" in bp:
                xc = xc + moe_mod.moe_block(cfg, bp["moe"], h)
            else:
                xc = xc + ly.mlp_block(cfg, bp["mlp"], h)
            return xc, (kc, vc)

        xs = (params["blocks"], cache["k"], cache["v"])
        if cfg.is_encdec:
            xs = xs + (cache["xk"], cache["xv"])
        x, (k_new, v_new) = jax.lax.scan(body, x, xs, unroll=unroll)
        new_cache["k"], new_cache["v"] = k_new, v_new
    elif cfg.family == "ssm":
        def body(xc, bps):
            bp, sc = bps
            h = ly.apply_norm(cfg, bp["norm1"], xc)
            o, sc = ssm_mod.ssm_decode(cfg, bp["ssm"], h, sc)
            return xc + o, sc
        x, sc = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]), unroll=unroll)
        new_cache["ssm"] = sc
    elif cfg.family == "hybrid":
        n_super, n_rem_rec, n_attn = hybrid_layout(cfg)
        rec = params["rec_blocks"]
        rgc = cache["rg"]
        rec_main = jax.tree.map(lambda a: a[: 2 * n_super].reshape(n_super, 2, *a.shape[1:]), rec)
        rgc_main = jax.tree.map(lambda a: a[: 2 * n_super].reshape(n_super, 2, *a.shape[1:]), rgc)

        def rec_step(xc, rp, rc):
            h = ly.apply_norm(cfg, rp["norm1"], xc)
            o, rc = rg.rglru_decode(cfg, rp["rglru"], h, rc)
            xc = xc + o
            h = ly.apply_norm(cfg, rp["norm2"], xc)
            return xc + ly.mlp_block(cfg, rp["mlp"], h), rc

        def sbody(xc, bps):
            rp2, rc2, ap, kc, vc = bps
            rcs = []
            for i in range(2):
                xc, rc = rec_step(xc, jax.tree.map(lambda a: a[i], rp2),
                                  jax.tree.map(lambda a: a[i], rc2))
                rcs.append(rc)
            h = ly.apply_norm(cfg, ap["norm1"], xc)
            o, kc, vc = ly.attention_decode(cfg, ap["attn"], h, pos, kc, vc,
                                            cfg.local_window)
            xc = xc + o
            h = ly.apply_norm(cfg, ap["norm2"], xc)
            xc = xc + ly.mlp_block(cfg, ap["mlp"], h)
            rc2 = jax.tree.map(lambda a, bb: jnp.stack([a, bb]), rcs[0], rcs[1])
            return xc, (rc2, kc, vc)

        x, (rc_main_new, k_new, v_new) = jax.lax.scan(
            sbody, x, (rec_main, rgc_main, params["attn_blocks"],
                       cache["k"], cache["v"]), unroll=unroll)
        rc_new = jax.tree.map(lambda a: a.reshape(a.shape[0] * 2, *a.shape[2:]),
                              rc_main_new)
        if n_rem_rec:
            rec_rem = jax.tree.map(lambda a: a[2 * n_super:], rec)
            rgc_rem = jax.tree.map(lambda a: a[2 * n_super:], rgc)

            def rbody(xc, bps):
                rp, rc = bps
                return rec_step(xc, rp, rc)
            x, rc_rem_new = jax.lax.scan(rbody, x, (rec_rem, rgc_rem), unroll=unroll)
            rc_new = jax.tree.map(lambda a, bb: jnp.concatenate([a, bb], 0),
                                  rc_new, rc_rem_new)
        new_cache["rg"] = rc_new
        new_cache["k"], new_cache["v"] = k_new, v_new
    else:
        raise ValueError(cfg.family)

    x = ly.apply_norm(cfg, params["final_norm"], x)
    new_cache["pos"] = pos + 1
    return _logits(cfg, params, x)[:, 0], new_cache


def decode_step_pooled(cfg: ModelConfig, kvcfg, params: Params,
                       token: jnp.ndarray, pool, tele, *, unroll: int = 1,
                       recode_budget: Optional[int] = None,
                       kernel: str = "reference"):
    """One decode step over the coded KV page pool (the serving path).

    token (B,) int32. ``pool`` is a ``runtime.kvbank.PooledKV`` whose
    page-table rows were assigned host-side at admission; ``tele`` is a
    ``repro.obs.serve.ServeTelemetry`` or ``None`` (metrics off — the
    compiled program is identical to a build that never traced telemetry).
    Returns ``(logits (B,V) f32, pool', tele')``.

    Appends go through the code-status table (touched parity rows stale),
    reads go through the shared ``plan_reads`` plan + the pool-indirected
    ``coded_kv_decode`` gather (``kernel`` picks the reference jnp gather or
    the bit-exact Pallas ``gather_pool_pallas`` datapath), and the ReCoding
    unit refreshes parity after the scan. With an unlimited recode budget on
    a coded pool, the encode is fused into the write path
    (``pool_write_layer_fused`` — parity is delta-maintained per append, no
    whole-pool re-read) which is bit-identical to write-then-full-recode;
    the status table evolves identically either way. Slots without a
    page-table row write via the bank sink and keep length 0; the server
    ignores their outputs.
    """
    from repro.kernels.coded_kv_decode import ops as ckd_ops
    from repro.obs import serve as obs_serve
    from repro.runtime import kvbank as kb

    assert cfg.family in ("dense", "moe", "vlm") and not cfg.is_encdec \
        and cfg.sliding_window == 0, \
        "pooled decode supports global-attention decoder families"
    cd = _dtype(cfg.compute_dtype)
    params = _cast_params(params, cd)
    b = token.shape[0]
    pos = pool.length
    active = (pool.page_table[:, 0] >= 0) & (pos > 0)
    x = embed_lookup(cfg, params["embed"], token[:, None], cd)
    if cfg.pos == "learned":
        mp = params["pos_embed"].shape[0]
        x = x + params["pos_embed"][jnp.minimum(pos, mp - 1)][:, None].astype(cd)

    widx = kb.pool_write_index(kvcfg, pool, active)
    pool = kb.pool_mark_stale(kvcfg, pool, widx)
    len_eff = pos + active.astype(jnp.int32)
    plan = kb.pool_plan(kvcfg, pool, length=len_eff)
    # encode-on-write when nothing rations the ReCoding unit (shape + host
    # config are compile-time)  # analysis: tracer-branch
    fused = recode_budget is None and pool.k_par.shape[1] > 0

    def body(xc, bps):
        bp, kbank, vbank, kpar, vpar = bps
        h = ly.apply_norm(cfg, bp["norm1"], xc)
        q, k, v = ly.qkv_proj(cfg, bp["attn"], h)
        if cfg.pos == "rope":
            q = ly.rope(q, pos[:, None], cfg.rope_theta)
            k = ly.rope(k, pos[:, None], cfg.rope_theta)
        if fused:
            kbank, vbank, kpar, vpar = kb.pool_write_layer_fused(
                kvcfg, kbank, vbank, kpar, vpar, widx, k[:, 0], v[:, 0])
        else:
            kbank, vbank = kb.pool_write_layer(kvcfg, kbank, vbank, widx,
                                               k[:, 0], v[:, 0])
        k_log, v_log = ckd_ops.gather_pool_layer(
            kbank, vbank, kpar, vpar, pool.page_table, plan.use_parity, cd,
            kernel=kernel)
        mask = jnp.arange(k_log.shape[1])[None, :] < len_eff[:, None]
        o = ly.mha(q, k_log, v_log, mask[:, None, None, None, :])
        xc = xc + o.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ bp["attn"]["wo"]
        h = ly.apply_norm(cfg, bp["norm2"], xc)
        if "moe" in bp:
            xc = xc + moe_mod.moe_block(cfg, bp["moe"], h)
        else:
            xc = xc + ly.mlp_block(cfg, bp["mlp"], h)
        return xc, (kbank, vbank, kpar, vpar) if fused else (kbank, vbank)

    x, ys = jax.lax.scan(
        body, x, (params["blocks"], pool.k_banks, pool.v_banks,
                  pool.k_par, pool.v_par), unroll=unroll)
    k_new, v_new = ys[0], ys[1]
    pool = pool._replace(k_banks=k_new, v_banks=v_new, length=len_eff)
    stale_before = jnp.sum((~pool.parity_fresh).astype(jnp.int32))
    if fused:
        kp_new, vp_new = ys[2], ys[3]
        # parity was delta-maintained per layer; refreshing the status table
        # IS the recode (bit-identical to the unfused full re-encode)
        pool = pool._replace(
            k_par=kp_new, v_par=vp_new,
            parity_fresh=jnp.ones_like(pool.parity_fresh))
        recoded = stale_before
    else:
        pool, recoded = kb.pool_recode(kvcfg, pool, budget=recode_budget)

    if tele is not None:
        needed, bank = kb.pool_read_sets(kvcfg, pool.page_table, len_eff)
        lat = kb.read_latencies(kvcfg, pool.page_table, len_eff,
                                plan.use_parity)
        tele = obs_serve.update_serve_telemetry(
            tele, load=plan.load, needed=needed, bank=bank,
            use_parity=plan.use_parity, latencies=lat,
            stale_before=stale_before, recoded=recoded,
            appended=jnp.sum((widx[0] < kvcfg.n_banks).astype(jnp.int32)),
            uncoded_cycles=plan.uncoded_cycles,
            coded_cycles=plan.coded_cycles)

    x = ly.apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x)[:, 0], pool, tele
