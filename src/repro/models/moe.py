"""Mixture-of-Experts block: top-k router + capacity-based einsum dispatch
(GShard/MaxText style — dense dispatch matrices so the computation shards
cleanly: experts over the ``model`` axis, token groups over ``data``).

The paper's coded-memory technique does NOT apply to expert weights (the
expert FFN is nonlinear in its inputs; an XOR parity of expert weights can't
serve a "degraded expert read") — hot-expert conflicts are a scheduling
problem only. See DESIGN.md §6.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.axes import shard
from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]


def moe_init(cfg: ModelConfig, key, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e), dtype) * d ** -0.5,
        "w_up": jax.random.normal(ks[1], (e, d, f), dtype) * d ** -0.5,
        "w_down": jax.random.normal(ks[2], (e, f, d), dtype) * f ** -0.5,
    }
    if cfg.mlp_gated:
        p["w_gate"] = jax.random.normal(ks[3], (e, d, f), dtype) * d ** -0.5
    return p


def moe_block(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, T, D) -> (B, T, D). Tokens are processed in groups of
    ``cfg.moe_group``; each group dispatches into per-expert capacity slots
    (overflow drops, standard GShard semantics)."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    xt = x.reshape(b * t, d)
    n = xt.shape[0]
    g = min(cfg.moe_group, n)
    assert n % g == 0, (n, g)
    ng = n // g
    cap = max(1, int(g * k * cfg.capacity_factor / e))
    xg = xt.reshape(ng, g, d)

    logits = jnp.einsum("ngd,de->nge", xg, p["router"]).astype(jnp.float32)
    gates, idx = jax.lax.top_k(logits, k)                    # (ng, g, k)
    gates = jax.nn.softmax(gates, axis=-1)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # (ng, g, k, e)
    # capacity slot per (token, choice): position among all assignments to e
    flat = onehot.reshape(ng, g * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                    # (ng, g*k, e)
    pos = pos.reshape(ng, g, k, e)
    keep = onehot * (pos < cap)
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    disp = jnp.einsum("ngke,ngkec->ngec", keep, slot_oh)     # (ng, g, e, cap)
    comb = jnp.einsum("ngke,ngkec,ngk->ngec", keep, slot_oh, gates)

    cd = x.dtype
    xin = jnp.einsum("ngec,ngd->necd", disp.astype(cd), xg)  # (ng, e, cap, d)
    if cfg.moe_ep:
        # expert parallelism: pin the e dim so the dispatch/combine einsums
        # shard with the expert weights instead of replicating (§Perf)
        xin = shard(xin, None, "experts", None, None)
    up = jnp.einsum("necd,edf->necf", xin, p["w_up"])
    if cfg.mlp_gated:
        h = act(jnp.einsum("necd,edf->necf", xin, p["w_gate"])) * up
    else:
        h = act(up)
    out_e = jnp.einsum("necf,efd->necd", h, p["w_down"])
    if cfg.moe_ep:
        out_e = shard(out_e, None, "experts", None, None)
    y = jnp.einsum("necd,ngec->ngd", out_e, comb.astype(cd))
    return y.reshape(b, t, d)
