"""Vocab embeddings — standard table or the paper's *coded banks*.

``CodedEmbedding`` is the paper's storage layout applied to a sharded vocab
table: rows are striped over ``NB`` banks (row v → bank ``v % NB``, bank row
``v // NB``); bank pairs ``(2g, 2g+1)`` carry an XOR parity bank. A batch of
token lookups is load-balanced by the read planner: lookups that land on an
over-subscribed bank are served as *degraded reads* (pair sibling ^ parity)
instead — idle banks supply the extra read ports, exactly Fig 3 of the paper.

The degraded path is bit-exact, so training uses a ``custom_vjp`` whose
forward runs the coded datapath (it stays visible in the lowered HLO) and
whose backward is the ordinary scatter-add into the bank layout.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.common import uint_view_dtype

Params = Dict[str, jnp.ndarray]


def embed_init(cfg: ModelConfig, key, dtype) -> Params:
    v, d = cfg.vocab_pad, cfg.d_model
    scale = d ** -0.5
    if not cfg.coded_embedding:
        return {"table": jax.random.normal(key, (v, d), dtype) * scale}
    nb = cfg.embed_banks
    vb = -(-v // nb)
    return {"banks": jax.random.normal(key, (nb, vb, d), dtype) * scale}


def _plan_use_parity(bank_of: jnp.ndarray, nb: int) -> jnp.ndarray:
    """Balance each bank's lookups between its own port and the parity path:
    the k-th lookup hitting a bank alternates direct/degraded (odd ranks go
    degraded). Vectorized read-pattern-builder round-robin for an embedding
    batch. Ranks are computed along the LAST axis only (per sequence), so the
    plan is batch-parallel — a cumsum across the global batch would break
    batch sharding for the whole downstream model (GSPMD cannot keep a dim
    sharded through a cross-shard cumsum)."""
    oh = jax.nn.one_hot(bank_of, nb, dtype=jnp.int32)       # (..., T, NB)
    rank = jnp.cumsum(oh, axis=-2) - oh                     # occurrences before t
    my_rank = jnp.take_along_axis(rank, bank_of[..., None], -1)[..., 0]
    return (my_rank % 2) == 1


def _coded_gather(banks: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    nb, vb, d = banks.shape
    u = uint_view_dtype(banks.dtype)
    banks_u = jax.lax.bitcast_convert_type(banks, u)
    par_u = banks_u[0::2] ^ banks_u[1::2]                   # (NB/2, Vb, D)
    bank_of = (tokens % nb).astype(jnp.int32)               # (..., T)
    brow = (tokens // nb).astype(jnp.int32)
    use_par = _plan_use_parity(bank_of, nb)
    sib = bank_of ^ 1
    grp = bank_of // 2
    direct = banks_u[bank_of, brow]
    degraded = banks_u[sib, brow] ^ par_u[grp, brow]
    out_u = jnp.where(use_par[..., None], degraded, direct)
    return jax.lax.bitcast_convert_type(out_u, banks.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _coded_lookup(shape, dtype_name, banks, tokens):
    return _coded_gather(banks, tokens)


def _coded_fwd(shape, dtype_name, banks, tokens):
    return _coded_gather(banks, tokens), tokens


def _coded_bwd(shape, dtype_name, tokens, g):
    nb, vb, d = shape
    dtype = jnp.dtype(dtype_name)
    zeros = jnp.zeros(shape, dtype)
    d_banks = zeros.at[(tokens % nb).astype(jnp.int32),
                       (tokens // nb).astype(jnp.int32)].add(g.astype(dtype))
    return d_banks, None


_coded_lookup.defvjp(_coded_fwd, _coded_bwd)


def coded_lookup(banks: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Coded-bank gather; bwd is the plain scatter-add into the bank layout."""
    return _coded_lookup(tuple(banks.shape), str(banks.dtype), banks, tokens)


def embed_lookup(cfg: ModelConfig, p: Params, tokens: jnp.ndarray,
                 dtype) -> jnp.ndarray:
    if cfg.coded_embedding:
        return coded_lookup(p["banks"], tokens).astype(dtype)
    return p["table"][tokens].astype(dtype)


def full_table(cfg: ModelConfig, p: Params) -> jnp.ndarray:
    """Reassemble (V_pad, D) logical table (for tied logit heads)."""
    if not cfg.coded_embedding:
        return p["table"]
    nb, vb, d = p["banks"].shape
    tbl = jnp.transpose(p["banks"], (1, 0, 2)).reshape(nb * vb, d)
    return tbl[: cfg.vocab_pad]
