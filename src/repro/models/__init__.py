"""Model zoo: unified LM over dense / MoE / SSM / hybrid / enc-dec families,
with the paper's coded-memory features (coded vocab embedding, banked KV)
as first-class options."""
