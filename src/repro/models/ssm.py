"""Mamba2 SSD (state-space duality) block — arXiv:2405.21060.

Chunked training form: within a chunk the recurrence is materialized as a
masked (semiseparable) attention-like matmul; across chunks a short scan
carries the (H, P, N) state. Decode carries (conv_state, ssm_state) and is
O(1) per token — the reason mamba2 runs the ``long_500k`` shape.

The paper's coded-memory technique does not apply to the SSM state (it is
read-modify-written by every token — there are no idle banks to decode
from); see DESIGN.md §6. The (large) vocab embedding still uses the coded
lookup when enabled.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]


def ssm_dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_headdim
    return di, nh, cfg.ssm_headdim, cfg.ssm_state


def ssm_init(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    di, nh, hp, n = ssm_dims(cfg)
    ks = jax.random.split(key, 3)
    proj_out = 2 * di + 2 * n + nh  # z, x, B, C, dt
    return {
        "in_proj": jax.random.normal(ks[0], (d, proj_out), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * n), dtype) * 0.1,
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) * di ** -0.5,
    }


def _split_proj(cfg, proj):
    di, nh, hp, n = ssm_dims(cfg)
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time. xbc (B,T,C), w (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + pad[:, i : i + xbc.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def _ssd_chunked(u, la, Bm, Cm, chunk):
    """u (B,T,H,P) inputs; la (B,T,H) log-decay ≤ 0; Bm/Cm (B,T,N).

    Returns y (B,T,H,P) and final state (B,H,P,N).
    """
    b, t, h, p = u.shape
    n = Bm.shape[-1]
    q = min(chunk, t)
    assert t % q == 0, (t, q)
    nc = t // q
    u = u.reshape(b, nc, q, h, p)
    la = la.reshape(b, nc, q, h).astype(jnp.float32)
    Bm = Bm.reshape(b, nc, q, n).astype(jnp.float32)
    Cm = Cm.reshape(b, nc, q, n).astype(jnp.float32)
    cum = jnp.cumsum(la, axis=2)                            # (B,nc,Q,H)
    total = cum[:, :, -1]                                   # (B,nc,H)

    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) (C_i·B_j) u_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)              # (B,nc,Q,Q)
    dec = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    w = jnp.where(tri[None, None, :, :, None], cb[..., None] * dec, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, u.astype(jnp.float32))

    # chunk state contribution: S_c = sum_j exp(total - cum_j) B_j u_j^T
    sdec = jnp.exp(total[:, :, None, :] - cum)              # (B,nc,Q,H)
    s_c = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", sdec, Bm, u.astype(jnp.float32))

    # scan chunk states: S_{c} = exp(total_c) S_{c-1} + S_c
    def body(s_prev, xs):
        tot_c, s_cc = xs                                   # (B,H), (B,H,N,P)
        s = jnp.exp(tot_c)[..., None, None] * s_prev + s_cc
        return s, s_prev

    tot_sw = jnp.moveaxis(total, 1, 0)                      # (nc,B,H)
    scc_sw = jnp.moveaxis(s_c, 1, 0)                        # (nc,B,H,N,P)
    s_final, s_prevs = jax.lax.scan(body, jnp.zeros((b, h, n, p), jnp.float32),
                                    (tot_sw, scc_sw))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                   # (B,nc,H,N,P)

    # inter-chunk: y_i += exp(cum_i) C_i · S_prev
    y_inter = jnp.einsum("bcih,bcin,bchnp->bcihp", jnp.exp(cum), Cm, s_prevs)
    y = (y_intra + y_inter).reshape(b, t, h, p)
    return y, jnp.moveaxis(s_final, -1, -2)                 # state (B,H,P,N)


def ssm_block(cfg: ModelConfig, p: Params, x: jnp.ndarray, chunk: int = 128,
              return_cache: bool = False):
    """Full-sequence SSD block (training / prefill). x (B,T,D)."""
    di, nh, hp, n = ssm_dims(cfg)
    proj = x @ p["in_proj"]
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xi, Bm, Cm = jnp.split(xbc, [di, di + n], axis=-1)
    b, t, _ = x.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a = -jnp.exp(p["A_log"])                                     # (H,)
    la = dt * a[None, None, :]
    u = xi.reshape(b, t, nh, hp).astype(jnp.float32) * dt[..., None]
    y, s_final = _ssd_chunked(u, la, Bm, Cm, chunk)
    y = y + p["D"][None, None, :, None] * xi.reshape(b, t, nh, hp).astype(jnp.float32)
    y = y.reshape(b, t, di).astype(x.dtype)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)).astype(x.dtype)
    y = y * p["norm_scale"]
    out = y @ p["out_proj"]
    if not return_cache:
        return out
    k = cfg.ssm_conv
    tail = xbc_raw[:, -(k - 1):] if t >= k - 1 else jnp.pad(
        xbc_raw, ((0, 0), (k - 1 - t, 0), (0, 0)))
    return out, SSMCache(conv=tail, state=s_final)


class SSMCache(NamedTuple):
    conv: jnp.ndarray   # (B, K-1, di + 2N)
    state: jnp.ndarray  # (B, H, P, N) f32


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    di, nh, hp, n = ssm_dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
        state=jnp.zeros((batch, nh, hp, n), jnp.float32),
    )


def ssm_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray, cache: SSMCache
               ) -> Tuple[jnp.ndarray, SSMCache]:
    """One-token step. x (B,1,D)."""
    di, nh, hp, n = ssm_dims(cfg)
    proj = x[:, 0] @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    hist = jnp.concatenate([cache.conv, xbc[:, None]], 1)   # (B,K,C)
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(conv)
    xi, Bm, Cm = jnp.split(xbc_t, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))                       # (B,H)
    u = xi.reshape(-1, nh, hp).astype(jnp.float32) * dt[..., None]
    s = cache.state * a[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", u, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", s, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xi.reshape(-1, nh, hp).astype(jnp.float32)
    y = y.reshape(-1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)).astype(x.dtype)
    y = y * p["norm_scale"]
    out = (y @ p["out_proj"])[:, None]
    return out, SSMCache(conv=hist[:, 1:], state=s)
