"""RG-LRU recurrent block (Griffin / RecurrentGemma — arXiv:2402.19427).

Real-Gated Linear Recurrent Unit with the Griffin residual-block structure:
two input projections (recurrent branch + GeLU gate branch), a short causal
conv on the recurrent branch, the diagonal gated recurrence

    r_t = σ(W_a x_t),  i_t = σ(W_x x_t),
    log a_t = -c · softplus(Λ) · r_t            (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

and an output projection after gating. Gates use Griffin's block-diagonal
weights. Training uses ``lax.associative_scan`` over time; decode carries
(conv_state, h) and is O(1)/token — with the local-attention layers' small
windows this is why recurrentgemma runs ``long_500k``.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]

_C = 8.0
_N_BLOCKS = 16
_CONV_K = 4


def rglru_init(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    dr = d  # lru width = d_model (recurrentgemma)
    nb = _N_BLOCKS if dr % _N_BLOCKS == 0 else 1
    bs = dr // nb
    ks = jax.random.split(key, 5)
    return {
        "w_y": jax.random.normal(ks[0], (d, dr), dtype) * d ** -0.5,
        "w_gate": jax.random.normal(ks[1], (d, dr), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[2], (_CONV_K, dr), dtype) * 0.1,
        "conv_b": jnp.zeros((dr,), dtype),
        "wa_blocks": jax.random.normal(ks[3], (nb, bs, bs), dtype) * bs ** -0.5,
        "wx_blocks": jax.random.normal(ks[4], (nb, bs, bs), dtype) * bs ** -0.5,
        "lam": jnp.full((dr,), 0.5, jnp.float32),
        "w_out": jax.random.normal(ks[0], (dr, d), dtype) * dr ** -0.5,
    }


def _block_linear(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Block-diagonal linear: w (nb, bs, bs), x (..., nb*bs)."""
    nb, bs, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, bs)
    return jnp.einsum("...nb,nbc->...nc", xs, w).reshape(*x.shape)


def _gates(p: Params, xr: jnp.ndarray):
    r = jax.nn.sigmoid(_block_linear(p["wa_blocks"], xr).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_linear(p["wx_blocks"], xr).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r            # (..., dr) ≤ 0
    a = jnp.exp(log_a)
    w_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, w_in * i * xr.astype(jnp.float32)


def _conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    pad = jnp.pad(x, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(_CONV_K):
        out = out + pad[:, i : i + x.shape[1]] * w[i]
    return out + b


def rglru_block(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                return_cache: bool = False):
    """Full-sequence recurrent block. x (B,T,D).

    ``cfg.rg_scan_bf16`` runs the associative scan on bf16 (a, w) — the scan
    levels dominate the layer's HBM traffic (log2(T) passes over two
    (B,T,dr) tensors, ×fwd/bwd/remat); a ∈ (0,1) products decay fast so the
    bf16 recurrence stays within ~1e-2 of f32 on the block output (§Perf,
    measured in tests/test_archs.py::test_rg_scan_bf16_close)."""
    xr0 = x @ p["w_y"]                                      # raw conv input
    xr = _conv(xr0, p["conv_w"], p["conv_b"])               # (B,T,dr)
    a, w = _gates(p, xr)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    if cfg.rg_scan_bf16:
        a = a.astype(jnp.bfloat16)
        w = w.astype(jnp.bfloat16)
    _, h = jax.lax.associative_scan(combine, (a, w), axis=1)
    hx = h.astype(x.dtype)
    gate = jax.nn.gelu(x @ p["w_gate"])
    out = (hx * gate) @ p["w_out"]
    if not return_cache:
        return out
    t = x.shape[1]
    tail = xr0[:, -(_CONV_K - 1):] if t >= _CONV_K - 1 else jnp.pad(
        xr0, ((0, 0), (_CONV_K - 1 - t, 0), (0, 0)))
    return out, RGLRUCache(conv=tail, h=h[:, -1].astype(jnp.float32))


class RGLRUCache(NamedTuple):
    conv: jnp.ndarray  # (B, K-1, dr)
    h: jnp.ndarray     # (B, dr) f32


def rglru_cache_init(cfg: ModelConfig, batch: int, dtype) -> RGLRUCache:
    dr = cfg.d_model
    return RGLRUCache(
        conv=jnp.zeros((batch, _CONV_K - 1, dr), dtype),
        h=jnp.zeros((batch, dr), jnp.float32),
    )


def rglru_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray, cache: RGLRUCache
                 ) -> Tuple[jnp.ndarray, RGLRUCache]:
    """One-token step. x (B,1,D)."""
    xr0 = x[:, 0] @ p["w_y"]                               # (B,dr)
    hist = jnp.concatenate([cache.conv, xr0[:, None]], 1)
    xr = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    a, w = _gates(p, xr)
    h = a * cache.h + w
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"])
    out = ((h.astype(x.dtype) * gate) @ p["w_out"])[:, None]
    return out, RGLRUCache(conv=hist[:, 1:], h=h)
