"""Core transformer layers: norms, RoPE, GQA attention (full / sliding /
local / cross), gated & plain MLPs. Pure functions over param pytrees;
parameters are plain nested dicts so they stack cleanly for scan-over-layers
and shard via path-based rules (repro.launch.sharding).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]


# ----------------------------------------------------------------- norms
def norm_init(cfg: ModelConfig, dtype) -> Params:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ RoPE
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (..., T, H, Dh), positions (..., T) -> rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention
def attn_init(cfg: ModelConfig, key, dtype, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, nh * hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, nkv * hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, nkv * hd), dtype) * s,
        "wo": jax.random.normal(k4, (nh * hd, d), dtype) * (nh * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def qkv_proj(cfg: ModelConfig, p: Params, x: jnp.ndarray):
    """x (B,T,D) -> q (B,T,H,dh), k/v (B,T,Hkv,dh)."""
    b, t, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.n_kv, cfg.head_dim)
    v = v.reshape(b, t, cfg.n_kv, cfg.head_dim)
    return q, k, v


def mha(
    q: jnp.ndarray,            # (B, Tq, H, dh)
    k: jnp.ndarray,            # (B, Tk, Hkv, dh)
    v: jnp.ndarray,            # (B, Tk, Hkv, dh)
    mask: Optional[jnp.ndarray],  # broadcastable to (B, H_kv, G, Tq, Tk) or None
    av_bf16: bool = False,
) -> jnp.ndarray:
    """Softmax numerics are always f32; ``av_bf16`` downcasts the softmax
    weights and V reads for the AV matmul (halves the largest memory streams
    — §Perf variant; max observed logit error ~1e-3 at bf16)."""
    b, tq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, tq, g, hkv, dh)
    logits = jnp.einsum("bqgkd,btkd->bkgqt", qf, k.astype(jnp.float32))
    logits = logits * (dh ** -0.5)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    if av_bf16:
        out = jnp.einsum("bkgqt,btkd->bqgkd", w.astype(jnp.bfloat16),
                         v.astype(jnp.bfloat16))
    else:
        out = jnp.einsum("bkgqt,btkd->bqgkd", w, v.astype(jnp.float32))
    return out.reshape(b, tq, h, dh).astype(q.dtype)


def mha_chunked(
    q: jnp.ndarray,            # (B, Tq, H, dh)
    k: jnp.ndarray,            # (B, Tk, Hkv, dh)
    v: jnp.ndarray,            # (B, Tk, Hkv, dh)
    *,
    window: int = 0,
    q_chunk: int = 1024,
    unroll: int = 1,
    av_bf16: bool = False,
) -> jnp.ndarray:
    """Causal attention computed in query blocks (lax.scan) so the logits
    working set is (B,·,q_chunk,Tk) instead of (B,·,Tq,Tk) — this is what
    makes the 32k prefill shapes fit HBM. Bit-identical math to ``mha`` with
    a causal(+window) mask."""
    b, tq, h, dh = q.shape
    tk = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    if q_chunk <= 0 or q_chunk >= tq:
        return mha(q, k, v, causal_mask(tq, tk, 0, window), av_bf16)
    assert tq % q_chunk == 0, (tq, q_chunk)
    nc = tq // q_chunk
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.bfloat16 if av_bf16 else jnp.float32)
    qc = q.astype(jnp.float32).reshape(b, nc, q_chunk, g, hkv, dh)
    qc = jnp.moveaxis(qc, 1, 0)                        # (nc, B, qc, g, hkv, dh)
    ki = jnp.arange(tk)[None, :]

    def body(c, qblk):
        qi = c * q_chunk + jnp.arange(q_chunk)[:, None]
        m = ki <= qi
        if window > 0:
            m = m & (ki > qi - window)
        logits = jnp.einsum("bqgkd,btkd->bkgqt", qblk, kf) * (dh ** -0.5)
        logits = jnp.where(m[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        if av_bf16:
            w = w.astype(jnp.bfloat16)
        out = jnp.einsum("bkgqt,btkd->bqgkd", w, vf)
        return c + 1, out

    _, outs = jax.lax.scan(body, jnp.int32(0), qc, unroll=unroll)
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, tq, h, dh)
    return outs.astype(q.dtype)


def causal_mask(tq: int, tk: int, offset: int = 0, window: int = 0) -> jnp.ndarray:
    """(1,1,1,Tq,Tk) causal (+optional sliding window) mask.

    ``offset`` is the absolute position of query 0 minus key 0 (for caches).
    """
    qi = jnp.arange(tq)[:, None] + offset
    ki = jnp.arange(tk)[None, :]
    m = ki <= qi
    if window > 0:
        m = m & (ki > qi - window)
    return m[None, None, None]


def attention_block(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, positions: jnp.ndarray,
    window: int = 0, q_chunk: int = 0, chunk_unroll: int = 1,
) -> jnp.ndarray:
    """Full-sequence self attention (training / prefill path)."""
    b, t, d = x.shape
    q, k, v = qkv_proj(cfg, p, x)
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if q_chunk and q_chunk < t:
        out = mha_chunked(q, k, v, window=window, q_chunk=q_chunk,
                          unroll=chunk_unroll, av_bf16=cfg.attn_av_bf16)
    else:
        out = mha(q, k, v, causal_mask(t, t, 0, window), cfg.attn_av_bf16)
    return out.reshape(b, t, cfg.n_heads * cfg.head_dim) @ p["wo"]


def attention_decode(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, pos: jnp.ndarray,
    k_cache: jnp.ndarray, v_cache: jnp.ndarray, window: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x (B,1,D); pos (B,) absolute positions;
    k/v_cache (B, C, Hkv, dh) where C = min(max_seq, window or max_seq).
    The cache is a ring buffer when windowed: slot = pos % C.
    Returns (out (B,1,D), k_cache', v_cache')."""
    b, _, d = x.shape
    c = k_cache.shape[1]
    q, k, v = qkv_proj(cfg, p, x)
    if cfg.pos == "rope":
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    slot = (pos % c).astype(jnp.int32)
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, slot].set(k[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v[:, 0])
    # valid keys: absolute index of cache slot s is reconstructed from pos
    sidx = jnp.arange(c)[None, :]                      # (1, C)
    abs_idx = jnp.where(
        sidx <= slot[:, None], pos[:, None] - (slot[:, None] - sidx),
        pos[:, None] - (slot[:, None] + c - sidx),
    )
    valid = (abs_idx >= 0) & (abs_idx <= pos[:, None])
    if window > 0:
        valid &= abs_idx > pos[:, None] - window
    mask = valid[:, None, None, None, :]               # (B,1,1,1,C)
    out = mha(q, k_cache, v_cache, mask)
    return out.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ p["wo"], k_cache, v_cache


def cross_attention_block(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                          enc: jnp.ndarray) -> jnp.ndarray:
    """Decoder cross-attention over encoder output (no RoPE, no mask)."""
    b, t, d = x.shape
    te = enc.shape[1]
    q = (x @ p["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = (enc @ p["wk"]).reshape(b, te, cfg.n_kv, cfg.head_dim)
    v = (enc @ p["wv"]).reshape(b, te, cfg.n_kv, cfg.head_dim)
    if "bq" in p:
        q = q + p["bq"].reshape(cfg.n_heads, cfg.head_dim)
        k = k + p["bk"].reshape(cfg.n_kv, cfg.head_dim)
        v = v + p["bv"].reshape(cfg.n_kv, cfg.head_dim)
    out = mha(q, k, v, None)
    return out.reshape(b, t, cfg.n_heads * cfg.head_dim) @ p["wo"]


# ------------------------------------------------------------------- MLP
def mlp_init(cfg: ModelConfig, key, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": jax.random.normal(ks[0], (d, f), dtype) * d ** -0.5,
        "w_down": jax.random.normal(ks[1], (f, d), dtype) * f ** -0.5,
    }
    if cfg.mlp_gated:
        p["w_gate"] = jax.random.normal(ks[2], (d, f), dtype) * d ** -0.5
    return p


def mlp_block(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    up = x @ p["w_up"]
    h = act(x @ p["w_gate"]) * up if cfg.mlp_gated else act(up)
    return h @ p["w_down"]
