"""Bounded rolling-window trace sources for streaming replay.

``stream_replay`` consumes a ``TraceSource``: per-core request streams with
*bounded random access* — each replay step stages a fixed-shape buffer of the
next ``chunk_len`` requests **per core**, starting at each core's own global
position (cores drain their streams at different rates, so the staging
window is ragged across cores). The source keeps only the columns between
the slowest core's position and the fastest core's position plus one stage
resident — memory is ``O(core spread + chunk_len)`` columns, independent of
total trace length.

Chunks are ingested lazily from an iterator with a double-buffered
background prefetch thread (the ``repro.data.pipeline.Prefetcher`` idiom):
the host half of the next chunk — file parsing, decompression, trace
synthesis — overlaps the device's replay of the current one. The *staging*
buffer itself cannot be prefetched exactly (its start positions depend on
how many requests the device consumed, which is only known after the step
returns), so the overlap lives at the ingestion layer where all the host
cost is.
"""
from __future__ import annotations

import queue
import threading
import time
import types
from typing import Iterable, Iterator, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.system import Trace

INT32_MAX = np.iinfo(np.int32).max


def _pull_retry(it: Iterator[Trace], retries: int,
                backoff: float) -> Optional[Trace]:
    """``next(it, None)`` with bounded retry on transient read errors.

    A flaky source (NFS hiccup, racing writer, transient decode failure)
    gets ``retries`` extra attempts with exponential backoff before the
    exception propagates. Only ``Exception`` retries — ``KeyboardInterrupt``
    and friends surface immediately — and generators are excluded by
    construction (a generator is dead after raising; retrying ``next()`` on
    one just yields ``StopIteration``, which would silently truncate the
    stream instead of failing it). The attempt budget is per pull, so a
    source that recovers resets its budget for the next chunk.
    """
    delay = backoff
    for attempt in range(retries + 1):
        try:
            return next(it, None)
        except Exception:
            if attempt == retries or isinstance(it, types.GeneratorType):
                raise
            time.sleep(delay)
            delay *= 2
    raise AssertionError("unreachable")


class _ChunkPrefetcher:
    """Pull Trace chunks from an iterator on a background thread (depth 2).

    An exception inside the iterator (parse error, I/O failure) is captured
    and re-raised from ``next()`` on the consumer thread — a failed ingest
    must fail the replay, not masquerade as a short stream. Transient
    errors optionally retry with bounded exponential backoff
    (``retries``/``backoff``) before the relay fires."""

    _SENTINEL = object()

    def __init__(self, it: Iterator[Trace], depth: int = 2,
                 retries: int = 0, backoff: float = 0.05):
        self._q: "queue.Queue" = queue.Queue(depth)
        self._err: Optional[BaseException] = None
        self._retries = int(retries)
        self._backoff = float(backoff)
        self._thread = threading.Thread(
            target=self._worker, args=(it,), daemon=True)
        self._thread.start()

    def _worker(self, it: Iterator[Trace]):
        try:
            while True:
                chunk = _pull_retry(it, self._retries, self._backoff)
                if chunk is None:
                    break
                self._q.put(chunk)
        except BaseException as e:              # noqa: BLE001 — relayed
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def next(self) -> Optional[Trace]:
        got = self._q.get()
        if got is self._SENTINEL and self._err is not None:
            raise self._err
        return None if got is self._SENTINEL else got


class TraceSource:
    """Rolling window over per-core request streams.

    Build with :meth:`from_trace` (in-memory, total length known up front)
    or :meth:`from_chunks` (lazy iterator of ``Trace`` chunks concatenated
    along the time axis; the total length is discovered when the iterator
    ends). All chunks must share ``n_cores``.
    """

    def __init__(self, chunks: Iterator[Trace], n_cores: Optional[int] = None,
                 prefetch: bool = True, retries: int = 0,
                 backoff: float = 0.05):
        self._fetch: Union[_ChunkPrefetcher, Iterator[Trace], None]
        it = iter(chunks)
        self._retries = int(retries)
        self._backoff = float(backoff)
        self._fetch = (_ChunkPrefetcher(it, retries=self._retries,
                                        backoff=self._backoff)
                       if prefetch else it)
        self.n_cores = n_cores
        self._buf: Optional[list] = None   # list of 5 (n_cores, W) np arrays
        self.base = 0                      # global index of buffer column 0
        self.total: Optional[int] = None   # per-core length once discovered

    # ----------------------------------------------------------- constructors
    @classmethod
    def from_trace(cls, trace: Trace) -> "TraceSource":
        src = cls(iter(()), prefetch=False)
        src._append(trace)
        src._fetch = None
        src.total = src._buffered_end()
        return src

    @classmethod
    def from_chunks(cls, chunks: Iterable[Trace], prefetch: bool = True,
                    retries: int = 0, backoff: float = 0.05) -> "TraceSource":
        """Lazy source over an iterator of ``Trace`` chunks.

        ``retries``/``backoff`` give each chunk pull a bounded
        exponential-backoff retry budget against transient read errors
        (see ``_pull_retry``); the default keeps the historical
        fail-on-first-error behavior."""
        return cls(iter(chunks), prefetch=prefetch, retries=retries,
                   backoff=backoff)

    # -------------------------------------------------------------- ingestion
    def _append(self, chunk: Trace):
        arrs = [np.asarray(x) for x in chunk]
        if self.n_cores is None:
            self.n_cores = arrs[0].shape[0]
        if arrs[0].shape[0] != self.n_cores:
            raise ValueError(
                f"chunk has {arrs[0].shape[0]} cores, stream has {self.n_cores}")
        if self._buf is None:
            self._buf = arrs
        else:
            self._buf = [np.concatenate([a, b], axis=1)
                         for a, b in zip(self._buf, arrs)]

    def _buffered_end(self) -> int:
        return self.base + (self._buf[0].shape[1] if self._buf is not None else 0)

    def _pull_one(self) -> bool:
        if self._fetch is None:
            return False
        chunk = (self._fetch.next() if isinstance(self._fetch, _ChunkPrefetcher)
                 else _pull_retry(self._fetch, self._retries, self._backoff))
        if chunk is None:
            self._fetch = None
            self.total = self._buffered_end()
            return False
        self._append(chunk)
        return True

    def _fill_to(self, upto: int):
        while self._buffered_end() < upto and self._pull_one():
            pass

    def _trim(self, min_pos: int):
        drop = min_pos - self.base
        if drop > 0 and self._buf is not None:
            self._buf = [a[:, drop:] for a in self._buf]
            self.base = min_pos

    # ---------------------------------------------------------------- staging
    def stage(self, positions: np.ndarray,
              chunk_len: int) -> Tuple[Trace, jnp.ndarray]:
        """Fixed-shape staging buffer for the next replay step.

        Returns ``(chunk, stream_end)``: ``chunk`` holds, for each core,
        its ``chunk_len`` requests starting at ``positions[core]`` (entries
        past the stream end are invalid idle cells that the replay never
        reaches — ``stream_end`` stops the pointer first); ``stream_end[c]``
        is the count of real staged requests when core ``c``'s stream ends
        inside this buffer, else INT32_MAX ("more data behind the buffer").
        """
        positions = np.asarray(positions, np.int64)
        self._fill_to(int(positions.max()) + chunk_len)
        self._trim(int(positions.min()))
        if self._buf is None:                       # empty stream
            if self.n_cores is None:
                raise ValueError("empty chunk stream with unknown n_cores")
            self._buf = [np.zeros((self.n_cores, 0), d) for d in
                         (np.int32, np.int32, bool, np.int32, bool)]
        width = self._buf[0].shape[1]
        idx = positions[:, None] + np.arange(chunk_len) - self.base
        inb = idx < width
        take = np.minimum(np.maximum(idx, 0), max(width - 1, 0))
        out = [np.take_along_axis(a, take, axis=1) if width else
               np.zeros((self.n_cores, chunk_len), a.dtype) for a in self._buf]
        out[4] = out[4] & inb                       # valid &= in-buffer
        if self.total is None:
            stream_end = np.full((self.n_cores,), INT32_MAX, np.int32)
        else:
            remaining = self.total - positions
            stream_end = np.where(remaining <= chunk_len, remaining,
                                  INT32_MAX).astype(np.int32)
        chunk = Trace(*(jnp.asarray(a) for a in out))
        return chunk, jnp.asarray(stream_end)

    def exhausted(self, positions: np.ndarray) -> bool:
        """True once every core's position has passed the stream end."""
        return (self.total is not None
                and bool((np.asarray(positions) >= self.total).all()))


def as_source(source) -> TraceSource:
    """Coerce a Trace, an iterable of Trace chunks, or a TraceSource."""
    if isinstance(source, TraceSource):
        return source
    if isinstance(source, Trace):
        return TraceSource.from_trace(source)
    return TraceSource.from_chunks(source)


def chunk_iter(trace: Trace, chunk_len: int) -> Iterator[Trace]:
    """Slice an in-memory trace into time-axis chunks (testing/benching)."""
    arrs = [np.asarray(x) for x in trace]
    T = arrs[0].shape[1]
    for off in range(0, T, chunk_len):
        yield Trace(*(jnp.asarray(a[:, off:off + chunk_len]) for a in arrs))
