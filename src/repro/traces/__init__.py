"""Streaming DRAM-trace substrate: chunked replay, ingestion, profiling.

The paper evaluates its coded memory controller on gem5/PARSEC DRAM traces
(§V); this package turns the cycle engine + sweep engine into something
that can chew through million-request real-world traces:

  stream   — ``stream_replay``: arbitrarily long traces as fixed-shape
             chunks with an explicit ``SimState`` carry, bit-identical to
             single-shot ``run()``; ``stream_replay_points`` composes the
             chunk axis with the sweep engine's point axis
  source   — bounded rolling-window ``TraceSource`` with background chunk
             prefetch (the ``repro.data.pipeline`` idiom)
  formats  — Ramulator / gem5 text parsers + the canonical ``.npz`` form,
             address mapping shared with ``repro.sim.trace``
  profiler — streaming locality statistics (Fig 15 band detection,
             read/write mix, burstiness) and the region-priors that
             warm-start the dynamic coding unit

Quickstart (see docs/traces.md):

    from repro.traces import stream_replay, load_trace, profile_trace
    trace = load_trace("app.trace", n_banks=8, n_rows=512)
    res = stream_replay(system, trace, chunk_len=4096)
    prof = profile_trace(trace, n_banks=8, n_rows=512)
    priors = prof.region_priors(system.p.region_size, system.p.n_regions)
"""
from repro.traces.formats import (  # noqa: F401
    TraceFormatError,
    count_requests,
    load_npz,
    load_trace,
    probe,
    requests_to_trace,
    save_npz,
    stream_file,
)
from repro.traces.profiler import (  # noqa: F401
    Band,
    TraceProfile,
    TraceProfiler,
    profile_trace,
)
from repro.traces.source import (  # noqa: F401
    TraceSource,
    as_source,
    chunk_iter,
)
from repro.traces.stream import (  # noqa: F401
    chunk_bound,
    stream_replay,
    stream_replay_points,
    strip_windows,
)
