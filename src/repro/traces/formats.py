"""External DRAM-trace ingestion: Ramulator / gem5 text formats + .npz.

Three on-disk forms feed the replay engines (all land in the same ``Trace``
pytree the cycle engine consumes):

* **Ramulator-style** (``.trace``): one request per line, ``<addr> <R|W>``
  (the order may be flipped; ``R/W/RD/WR/READ/WRITE`` accepted, addresses
  hex ``0x…`` or decimal). Comment lines (``#``) and blanks are skipped.
* **gem5-style** (``.gem5``/CSV): ``tick,cmd,addr[,size]`` rows as printed
  by gem5's packet-trace decode script, ``cmd`` ∈ {r, w} (case-insensitive;
  whitespace-separated variants accepted). Requests keep file order.
* **``.npz`` canonical**: the five ``Trace`` arrays (``bank``, ``row``,
  ``is_write``, ``data``, ``valid``; each ``(n_cores, T)``) saved verbatim —
  lossless round-trip, no re-mapping on load.

Byte addresses reduce to row addresses via ``addr // line_bytes`` then the
low-bit bank interleaving shared with the synthetic generators
(``repro.sim.trace.addr_to_bank_row``). A single-stream file is dealt
round-robin across cores in file order — request ``i`` goes to core
``i % n_cores`` at time slot ``i // n_cores`` — which preserves the
stream's banded locality per core.
"""
from __future__ import annotations

import os
from typing import Iterator, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.system import Trace
from repro.sim.trace import addr_to_bank_row

_READS = {"r", "rd", "read"}
_WRITES = {"w", "wr", "write"}


class TraceFormatError(ValueError):
    """A malformed on-disk trace. Every ingestion failure — truncated line,
    garbage token, wrong column count, corrupt/incomplete ``.npz`` — raises
    this single type, naming the file and (for text formats) the 1-based
    line, so replay harnesses can catch ingestion problems distinctly from
    programming errors. Subclasses ``ValueError`` for callers that predate
    it."""

    def __init__(self, path: str, line: Optional[int] = None,
                 detail: str = ""):
        loc = f"{path}:{line}" if line is not None else str(path)
        super().__init__(f"{loc}: {detail}")
        self.path = path
        self.line = line


def _parse_int(tok: str) -> Optional[int]:
    try:
        return int(tok, 16) if tok.lower().startswith("0x") else int(tok)
    except ValueError:
        return None


def _parse_op(tok: str) -> Optional[bool]:
    t = tok.lower()
    if t in _WRITES:
        return True
    if t in _READS:
        return False
    return None


def iter_ramulator(path: str) -> Iterator[Tuple[int, bool]]:
    """Lazily yield (addr, is_write) from a Ramulator-style text trace."""
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            toks = line.split("#", 1)[0].split()
            if not toks:
                continue
            addr = op = None
            for tok in toks:
                if op is None and (v := _parse_op(tok)) is not None:
                    op = v
                elif addr is None and (v := _parse_int(tok)) is not None:
                    addr = v
            if addr is None or op is None:
                raise TraceFormatError(
                    path, ln, f"expected '<addr> <R|W>', got {line!r}")
            yield addr, op


def iter_gem5(path: str) -> Iterator[Tuple[int, bool]]:
    """Lazily yield (addr, is_write) from a gem5-style ``tick,cmd,addr``
    trace (comma- or whitespace-separated; requests keep file order)."""
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            body = line.split("#", 1)[0].strip()
            if not body:
                continue
            toks = [t for t in body.replace(",", " ").split() if t]
            if len(toks) < 3:
                raise TraceFormatError(
                    path, ln, f"expected 'tick,cmd,addr[,size]', got {line!r}")
            tick, op, addr = (_parse_int(toks[0]), _parse_op(toks[1]),
                              _parse_int(toks[2]))
            if tick is None or op is None or addr is None:
                raise TraceFormatError(
                    path, ln, f"expected 'tick,cmd,addr[,size]', got {line!r}")
            yield addr, op


PARSERS = {"ramulator": iter_ramulator, "gem5": iter_gem5}


def _sniff_format(path: str) -> str:
    """Pick a text parser by extension, falling back to line shape."""
    ext = os.path.splitext(path)[1].lower()
    if ext in (".gem5", ".csv"):
        return "gem5"
    if ext == ".trace":
        return "ramulator"
    with open(path) as f:
        for line in f:
            body = line.split("#", 1)[0].strip()
            if body:
                return "gem5" if ("," in body or len(body.split()) >= 3) \
                    else "ramulator"
    return "ramulator"


def _payloads(addr: np.ndarray, seq: np.ndarray) -> np.ndarray:
    """Deterministic nonzero write payloads: external traces carry no data
    values, so synthesize them as a pure hash of (address, sequence) — the
    round-trip and replay results stay reproducible without a stored blob."""
    h = (addr.astype(np.uint64) * np.uint64(2654435761)
         + seq.astype(np.uint64) * np.uint64(97)) & np.uint64(0x3FFFFFFF)
    return (h | np.uint64(1)).astype(np.int32)


def requests_to_trace(addrs, is_write, *, n_cores: int = 8, n_banks: int = 8,
                      n_rows: int = 512, line_bytes: int = 1,
                      length: Optional[int] = None) -> Trace:
    """Deal a single request stream into the engine's per-core ``Trace``.

    ``line_bytes`` shifts byte addresses down to cache-line/row granularity
    before the low-bit bank interleaving (1 = addresses are already linear
    request addresses, the synthetic generators' convention). ``length``
    pads the per-core stream to a fixed T (default: just enough slots for
    every request, tail padded invalid); a length too small to hold every
    request raises — silently dropping the stream's tail would report
    results for a trace that was never replayed.
    """
    addrs = np.asarray(list(addrs) if not isinstance(addrs, np.ndarray)
                       else addrs, np.int64)
    is_write = np.asarray(list(is_write) if not isinstance(is_write, np.ndarray)
                          else is_write, bool)
    if addrs.shape != is_write.shape:
        raise ValueError("addrs and is_write must align")
    if line_bytes > 1:
        addrs = addrs // line_bytes
    n = addrs.size
    T = length if length is not None else -(-max(n, 1) // n_cores)
    if n > n_cores * T:
        raise ValueError(
            f"length={T} holds at most {n_cores * T} requests over "
            f"{n_cores} cores but the stream has {n} — size the point to "
            f"the file (length ≥ {-(-n // n_cores)}) or replay it chunked "
            f"via stream_file/stream_replay")
    bank = np.zeros((n_cores, T), np.int32)
    row = np.zeros((n_cores, T), np.int32)
    isw = np.zeros((n_cores, T), bool)
    data = np.zeros((n_cores, T), np.int32)
    valid = np.zeros((n_cores, T), bool)
    seq = np.arange(n, dtype=np.int64)
    core, t = seq % n_cores, seq // n_cores
    b, r = addr_to_bank_row(addrs, n_banks, n_rows)
    bank[core, t] = b
    row[core, t] = r
    isw[core, t] = is_write
    data[core, t] = _payloads(addrs, seq)
    valid[core, t] = True
    return Trace(bank=jnp.asarray(bank), row=jnp.asarray(row),
                 is_write=jnp.asarray(isw), data=jnp.asarray(data),
                 valid=jnp.asarray(valid))


def save_npz(path: str, trace: Trace) -> str:
    """Canonical on-disk form: the five Trace arrays, lossless."""
    np.savez_compressed(path, **{k: np.asarray(v)
                                 for k, v in zip(Trace._fields, trace)})
    return path


def load_npz(path: str) -> Trace:
    try:
        z = np.load(path)
    except OSError:
        raise
    except Exception as e:       # truncated zip, corrupt member, bad pickle
        raise TraceFormatError(path, None,
                               f"not a readable trace .npz ({e})") from e
    with z:
        missing = [k for k in Trace._fields if k not in z]
        if missing:
            raise TraceFormatError(path, None, "not a canonical trace .npz "
                                   f"(missing {missing})")
        try:
            return Trace(*(jnp.asarray(z[k]) for k in Trace._fields))
        except Exception as e:   # member present but corrupt/undecodable
            raise TraceFormatError(path, None,
                                   f"corrupt trace .npz ({e})") from e


def probe(path: str) -> Tuple[int, int]:
    """(n_cores, length) of an ``.npz`` trace without building the pytree —
    lets callers size their ``SweepPoint`` geometry to a file."""
    with np.load(path) as z:
        return tuple(int(d) for d in z["bank"].shape)


def count_requests(path: str, format: Optional[str] = None) -> int:
    """Number of requests in a text trace (one lazy parse, nothing
    materialized) — lets callers size a ``SweepPoint``'s per-core ``length``
    to a Ramulator/gem5 file the way ``probe`` does for ``.npz``."""
    fmt = format or _sniff_format(path)
    if fmt not in PARSERS:
        raise ValueError(f"unknown trace format {fmt!r}; have {sorted(PARSERS)}")
    return sum(1 for _ in PARSERS[fmt](path))


def load_trace(path: str, *, format: Optional[str] = None, n_cores: int = 8,
               n_banks: int = 8, n_rows: int = 512, line_bytes: int = 1,
               length: Optional[int] = None) -> Trace:
    """Load any supported on-disk trace into a ``Trace`` pytree.

    ``.npz`` loads verbatim (the mapping kwargs don't apply — the file
    already stores bank/row streams). Text formats parse lazily and deal
    round-robin across ``n_cores`` with the shared address mapping;
    ``format`` pins the parser ("ramulator" | "gem5"), default sniffed from
    the extension / first content line.
    """
    if path.endswith(".npz"):
        return load_npz(path)
    fmt = format or _sniff_format(path)
    if fmt not in PARSERS:
        raise ValueError(f"unknown trace format {fmt!r}; have {sorted(PARSERS)}")
    reqs = list(PARSERS[fmt](path))
    addrs = np.fromiter((a for a, _ in reqs), np.int64, len(reqs))
    is_w = np.fromiter((w for _, w in reqs), bool, len(reqs))
    return requests_to_trace(addrs, is_w, n_cores=n_cores, n_banks=n_banks,
                             n_rows=n_rows, line_bytes=line_bytes,
                             length=length)


def stream_file(path: str, chunk_len: int, *, format: Optional[str] = None,
                n_cores: int = 8, n_banks: int = 8, n_rows: int = 512,
                line_bytes: int = 1) -> Iterator[Trace]:
    """Lazily read a text trace as ``(n_cores, chunk_len)`` Trace chunks —
    the file never materializes whole; feed this to ``stream_replay`` (it
    prefetches parsing on a background thread). ``.npz`` falls back to
    slicing the loaded arrays."""
    if path.endswith(".npz"):
        from repro.traces.source import chunk_iter
        yield from chunk_iter(load_npz(path), chunk_len)
        return
    fmt = format or _sniff_format(path)
    it = PARSERS[fmt](path)
    per_chunk = n_cores * chunk_len
    base = 0
    while True:
        buf = []
        for req in it:
            buf.append(req)
            if len(buf) == per_chunk:
                break
        if not buf:
            return
        addrs = np.fromiter((a for a, _ in buf), np.int64, len(buf))
        is_w = np.fromiter((w for _, w in buf), bool, len(buf))
        # the tail chunk stays SHORT (ceil(n/n_cores) columns) rather than
        # padded to chunk_len: padding would append invalid idle columns
        # that exist only in the chunked representation — the replay would
        # walk them one cycle each and report a later completion cycle than
        # the same file loaded whole
        tr = requests_to_trace(addrs, is_w, n_cores=n_cores, n_banks=n_banks,
                               n_rows=n_rows, line_bytes=line_bytes,
                               length=-(-len(buf) // n_cores))
        if base:
            seq = np.arange(base, base + len(buf), dtype=np.int64)
            core, t = (seq - base) % n_cores, (seq - base) // n_cores
            a = addrs // line_bytes if line_bytes > 1 else addrs
            data = np.asarray(tr.data).copy()
            data[core, t] = _payloads(a, seq)
            tr = tr._replace(data=jnp.asarray(data))
        base += len(buf)
        yield tr
        if len(buf) < per_chunk:
            return
