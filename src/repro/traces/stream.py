"""Streaming trace replay: arbitrarily long traces, fixed device footprint.

``stream_replay`` threads an explicit ``SimState`` carry through successive
``CodedMemorySystem.run_chunk`` calls. Each step stages a fixed-shape
``(n_cores, chunk_len)`` buffer of the next requests *per core* (cores drain
at different rates; the staging window is ragged across cores) and runs
cycles until some core needs data beyond the buffer, the system quiesces,
or the per-chunk ``drain_bound`` budget runs out. Because the starvation
exit happens *between* cycles, every executed cycle sees exactly the
requests the single-shot program would — the replay is **bit-identical** to
``run()`` on the concatenated trace, for any chunk split (including chunk
length 1 and uneven tails; tests/test_traces.py proves it property-based).

One compiled program serves the whole stream: the chunk shape is the only
shape in the program, so device memory is constant in trace length.

``stream_replay_points`` composes the chunk axis with the sweep engine's
point axis: a shape-compatible batch of points replays chunked as ONE
vmapped device program, with per-point per-core staging windows.

Per-window latency stats ride along for free: each ``run_chunk`` return is
a window boundary, and the served-count/latency-sum deltas between
boundaries give the windowed critical-word read/write latency series that
``SimResult.window_read_latency`` / ``window_write_latency`` carry (the
scalar sums in ``MemState`` stay the only device-side accumulators).
"""
from __future__ import annotations

import functools
import json
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import TunableParams, wide_total
from repro.core.system import (CodedMemorySystem, SimResult, SimState,
                               drain_bound, quiescent, result_from_host)
from repro.traces.source import as_source

DEFAULT_CHUNK_LEN = 256


def strip_windows(res: SimResult) -> SimResult:
    """Drop the per-window series (for comparing streamed vs single-shot)."""
    return res._replace(window_read_latency=(), window_write_latency=())


def chunk_bound(system: CodedMemorySystem, chunk_len: int) -> int:
    """Per-chunk cycle budget: the shared ``drain_bound`` with the carried
    queue backlog — up to every read+write queue slot may still be occupied
    by the previous chunk's requests when a chunk starts."""
    backlog = 2 * system.p.n_data * system.p.queue_depth
    return drain_bound(system.n_cores, chunk_len, backlog=backlog)


def _window_stats(host_prev, host_now) -> Tuple[tuple, tuple]:
    """((n_reads, avg_read_lat[, hist]), (n_writes, avg_write_lat[, hist]))
    for one window. The histogram element — the per-window delta of the
    telemetry latency histograms (``repro.obs.planes``, log2 bins) — is
    present only when the system runs with ``MemParams.telemetry``; without
    it the window entries keep their pre-telemetry 2-tuple shape."""
    dr = int(host_now[0]) - int(host_prev[0])
    dw = int(host_now[1]) - int(host_prev[1])
    drl = wide_total(host_now[2]) - wide_total(host_prev[2])
    dwl = wide_total(host_now[3]) - wide_total(host_prev[3])
    wr: tuple = (dr, drl / max(dr, 1))
    ww: tuple = (dw, dwl / max(dw, 1))
    if len(host_now) > 4:
        wr += (tuple(int(a) - int(b)
                     for a, b in zip(np.asarray(host_now[4]).ravel(),
                                     np.asarray(host_prev[4]).ravel())),)
        ww += (tuple(int(a) - int(b)
                     for a, b in zip(np.asarray(host_now[5]).ravel(),
                                     np.asarray(host_prev[5]).ravel())),)
    return wr, ww


def _snapshot(st: SimState):
    m = st.mem
    base = (m.served_reads, m.served_writes, m.read_latency_sum,
            m.write_latency_sum)
    if m.tele is not None:
        base += (m.tele.lat_hist_read, m.tele.lat_hist_write)
    return base


def stream_replay(system: CodedMemorySystem, source,
                  chunk_len: int = DEFAULT_CHUNK_LEN,
                  tn: Optional[TunableParams] = None,
                  st: Optional[SimState] = None,
                  region_priors=None,
                  max_cycles: Optional[int] = None) -> SimResult:
    """Replay a (possibly longer-than-memory) trace through the cycle engine.

    ``source`` is anything ``repro.traces.source.as_source`` accepts: an
    in-memory ``Trace``, an iterable of ``Trace`` chunks, or a
    ``TraceSource``. Returns a ``SimResult`` bit-identical (modulo the
    window series) to single-shot ``run()`` on the concatenated trace.

    ``max_cycles`` optionally caps the total simulated cycles (the per-chunk
    budget already bounds each step); on a non-completing workload the
    replay stops once a whole chunk budget elapses with no request progress
    and reports ``completed=False``, like an exhausted single-shot bound.
    """
    src = as_source(source)
    tn = tn if tn is not None else system.tunables
    if st is None:
        st = system.init(tn, region_priors=region_priors)
    if src.n_cores is not None and src.n_cores != system.n_cores:
        raise ValueError(f"source has {src.n_cores} cores, "
                         f"system has {system.n_cores}")
    pos = np.zeros(system.n_cores, np.int64)
    bound = chunk_bound(system, chunk_len)
    win_r: List[tuple] = []
    win_w: List[tuple] = []
    prev = jax.device_get(_snapshot(st))
    prev_cycle = int(st.mem.cycle)
    while True:
        chunk, stream_end = src.stage(pos, chunk_len)
        st = st._replace(core_ptr=jnp.zeros_like(st.core_ptr))
        st = system.run_chunk(st, chunk, stream_end, bound, tn)
        ptr, quiet, cyc, *snap = jax.device_get(
            (st.core_ptr, quiescent(st), st.mem.cycle) + _snapshot(st))
        wr, ww = _window_stats(prev, snap)
        win_r.append(wr)
        win_w.append(ww)
        prev = snap
        moved = np.asarray(ptr, np.int64)
        pos += moved
        if src.exhausted(pos) and bool(quiet):
            break
        if not moved.any() and int(cyc) - prev_cycle >= bound:
            break                       # budget spent with zero progress:
                                        # the workload cannot complete
        if max_cycles is not None and int(cyc) >= max_cycles:
            break
        prev_cycle = int(cyc)
    res = system.summarize(st)
    return res._replace(window_read_latency=tuple(win_r),
                        window_write_latency=tuple(win_w))


# ------------------------------------------------------------ batched replay
# (no donate_argnums on the carry — see the note on
# CodedMemorySystem.run_chunk: fresh init states alias buffers across
# leaves, which donation rejects at runtime)
@functools.partial(jax.jit, static_argnums=(0, 4))
def _run_chunk_batch(system: CodedMemorySystem, st_b: SimState, trace_b,
                     stream_end_b, n_cycles: int,
                     tn_b: Optional[TunableParams] = None) -> SimState:
    """vmapped ``run_chunk``: the chunk axis composed with the point axis.

    The whole batch runs lock-step, so the loop exits as soon as ANY point
    starves (its staging buffer restages host-side and every point
    continues). Points that are already quiescent execute observable no-op
    cycles while others proceed — the same argument that makes the sweep
    engine's padding and early exit bit-identical per point.
    """
    vstep = jax.vmap(system.cycle_fn)
    tlen = trace_b.bank.shape[-1]

    def cond(carry):
        st, i = carry
        starved = jnp.any((st.core_ptr >= tlen) & (stream_end_b > tlen))
        return (i < n_cycles) & ~starved & ~jnp.all(quiescent(st))

    def body(carry):
        st, i = carry
        st, _ = vstep(st, trace_b, tn_b, stream_end_b)
        return st, i + 1

    st, _ = jax.lax.while_loop(cond, body, (st_b, jnp.int32(0)))
    return st


# -------------------------------------------------------- checkpointed carry
# The whole replay carry is three leaves: the batched SimState, the per-core
# stream positions, and the accumulated window series (encoded as a JSON
# byte array — ragged python tuples don't checkpoint as fixed-shape leaves).
# ``prev``/``prev_cycle`` are NOT saved: at a chunk boundary they are exactly
# ``_snapshot``/``mem.cycle`` of the carried state, so resume re-derives them.

def _wins_blob(win_r, win_w) -> np.ndarray:
    return np.frombuffer(json.dumps([win_r, win_w]).encode("utf-8"),
                         np.uint8).copy()


def _wins_unblob(arr) -> Tuple[List[List[tuple]], List[List[tuple]]]:
    def tup(x):
        return tuple(tup(e) for e in x) if isinstance(x, list) else x

    wr, ww = json.loads(bytes(np.asarray(arr, np.uint8).tobytes()).decode())
    return ([[tup(w) for w in pt] for pt in wr],
            [[tup(w) for w in pt] for pt in ww])


def stream_replay_points(points: Sequence, sources: Sequence,
                         chunk_len: int = DEFAULT_CHUNK_LEN,
                         region_priors: Optional[Sequence] = None,
                         max_cycles: Optional[int] = None,
                         shard: bool = True,
                         checkpoint_dir: Optional[str] = None,
                         checkpoint_every: int = 0,
                         resume: bool = False) -> List[SimResult]:
    """Chunked batched replay: one shape-compatible batch of sweep points,
    each with its own (arbitrarily long) trace source, as ONE device program.

    ``points`` must share a single static signature (one
    ``grid.partition`` batch — the caller splits mixed sweeps); ``sources``
    align 1:1. Results are bit-identical per point (modulo window series) to
    ``repro.sweep.run_points`` on the materialized traces.

    With more than one device (and ``shard``), the point axis is padded to a
    device-count multiple with replicas of the last real point — the same
    masked-dummy scheme as ``repro.sweep.engine._maybe_shard`` — and laid
    across the 1-D sweep mesh every chunk step. A replica stages the same
    buffer at the same position as its original, so it starves and quiesces
    exactly when the original does and never changes the lock-step exits;
    its rows are stripped from the results.

    With ``checkpoint_dir`` and ``checkpoint_every=N``, the replay carry
    (batched state + stream positions + window series) is checkpointed
    atomically every N chunks via ``repro.checkpoint`` (async writer; a
    killed run never leaves a readable half-checkpoint). ``resume=True``
    restores the latest committed checkpoint and continues — the final
    ``SimResult`` per point is bit-identical to the uninterrupted run
    (tests/test_traces.py kills a replay mid-stream and proves it). The
    caller re-supplies equivalent ``sources``; a lazy source only needs to
    replay forward to the restored positions. Resuming assumes the same
    point batch and device count (the padded point axis is part of the
    saved state).
    """
    from repro.sweep.engine import (_maybe_shard, _pad_points,
                                    _replicate_tail, stack_tunables,
                                    system_for)
    from repro.sweep.grid import batch_geometry_alloc, static_signature

    if len(sources) != len(points):
        raise ValueError("sources must align 1:1 with points")
    sigs = {static_signature(pt) for pt in points}
    if len(sigs) > 1:
        raise ValueError(
            f"stream_replay_points needs one shape-compatible batch, got "
            f"{len(sigs)} static signatures; split with repro.sweep.partition")
    srcs = [as_source(s) for s in sources]
    traced = len({pt.derived_slots()[:2] for pt in points}) > 1
    system = system_for(points[0], geometry_alloc=batch_geometry_alloc(points),
                        traced_geometry=traced)
    for b, src in enumerate(srcs):
        if src.n_cores is not None and src.n_cores != system.n_cores:
            raise ValueError(f"source for point [{b}] has {src.n_cores} "
                             f"cores, the batch has {system.n_cores}")
    n_pts = len(points)
    pad = _pad_points(n_pts) if shard else 0
    tn_b = stack_tunables(points, system.p.queue_depth)
    pri_b = None
    if region_priors is not None:
        from repro.sweep.engine import _stack_priors
        pri_b = _stack_priors(region_priors, n_pts)
    if pad:
        tn_b = _replicate_tail(tn_b, pad)
        if pri_b is not None:
            pri_b = _replicate_tail(pri_b, pad)
    st_b = (jax.vmap(system.init)(tn_b) if pri_b is None
            else jax.vmap(system.init)(tn_b, pri_b))
    if system.p.faults:
        # per-point fault schedules over the vmapped init's no-fault default
        # (vmap can't thread the host-side plans — same as engine.run_batch)
        from repro.sweep.engine import _stack_faults
        fault_b = _stack_faults(points, system.p)
        if pad:
            fault_b = _replicate_tail(fault_b, pad)
        st_b = st_b._replace(mem=st_b.mem._replace(fault=fault_b))
    pos = np.zeros((n_pts, system.n_cores), np.int64)
    bound = chunk_bound(system, chunk_len)
    win_r: List[List[tuple]] = [[] for _ in range(n_pts)]
    win_w: List[List[tuple]] = [[] for _ in range(n_pts)]
    ckpt = None
    step = 0
    if checkpoint_dir is not None and checkpoint_every > 0:
        from repro.checkpoint import (CheckpointManager, latest_step,
                                      restore)
        ckpt = CheckpointManager(checkpoint_dir, keep=2)
        last = latest_step(checkpoint_dir) if resume else None
        if last is not None:
            like = {"state": st_b, "pos": pos,
                    "wins": np.zeros(0, np.uint8)}
            tree = restore(checkpoint_dir, like, step=last)
            st_b = tree["state"]
            pos = np.asarray(tree["pos"], np.int64)
            win_r, win_w = _wins_unblob(tree["wins"])
            step = last
    elif resume:
        raise ValueError("resume=True needs checkpoint_dir and "
                         "checkpoint_every")
    prev = jax.device_get(_snapshot(st_b))
    prev_cycle = np.asarray(st_b.mem.cycle).copy()[:n_pts]
    while True:
        staged = [src.stage(pos[b], chunk_len) for b, src in enumerate(srcs)]
        trace_b = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *(s[0] for s in staged))
        stream_end_b = jnp.stack([s[1] for s in staged])
        if pad:
            trace_b = _replicate_tail(trace_b, pad)
            stream_end_b = _replicate_tail(stream_end_b, pad)
        st_b = st_b._replace(core_ptr=jnp.zeros_like(st_b.core_ptr))
        if shard:
            st_b, trace_b, stream_end_b, tn_b = _maybe_shard(
                (st_b, trace_b, stream_end_b, tn_b), n_pts + pad)
        st_b = _run_chunk_batch(system, st_b, trace_b, stream_end_b, bound,
                                tn_b)
        ptr, quiet, cyc, *snap = jax.device_get(
            (st_b.core_ptr, quiescent(st_b), st_b.mem.cycle)
            + _snapshot(st_b))
        for b in range(n_pts):
            wr, ww = _window_stats([x[b] for x in prev], [x[b] for x in snap])
            win_r[b].append(wr)
            win_w[b].append(ww)
        prev = snap
        moved = np.asarray(ptr, np.int64)[:n_pts]
        pos += moved
        step += 1
        if ckpt is not None and step % checkpoint_every == 0:
            ckpt.save_async(step, {"state": st_b, "pos": pos.copy(),
                                   "wins": _wins_blob(win_r, win_w)})
        if all(src.exhausted(pos[b]) for b, src in enumerate(srcs)) \
                and quiet.all():
            break
        cycles = np.asarray(cyc)[:n_pts]
        if not moved.any() and (cycles - prev_cycle >= bound).all():
            break
        if max_cycles is not None and int(cycles.max()) >= max_cycles:
            break
        prev_cycle = cycles.copy()
    if ckpt is not None:
        ckpt.wait()
    host = jax.device_get(st_b)
    out = []
    for b in range(n_pts):
        res = result_from_host(jax.tree.map(lambda x: x[b], host.mem),
                               host.done_cycle[b])
        out.append(res._replace(window_read_latency=tuple(win_r[b]),
                                window_write_latency=tuple(win_w[b])))
    return out
