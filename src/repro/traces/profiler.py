"""Streaming trace locality profiler (the paper's Fig 15 observation).

The paper's dynamic-coding results hinge on one empirical property of the
gem5/PARSEC traces: accesses "occupy consistent bands of sequential memory
addresses" (Fig 15) — persistent contiguous row intervals that a small
coded-region budget can cover. ``TraceProfiler`` measures exactly that,
streaming (chunk at a time, O(n_rows) state, never materializing the trace):

* per-bank / per-row access histograms and the read/write mix,
* **windowed band detection**: time is cut into fixed-size request windows;
  a coarse row-bin is *present* in a window when it receives at least one
  access, and a band is a maximal run of bins present in at least a
  ``min_persistence`` fraction of windows — "consistent" in the paper's
  sense, not merely hot in aggregate (a drifting hot spot paints many bins,
  each in few windows, and is rejected),
* burstiness: the Fano factor (variance/mean) of per-window per-bank
  request counts — >1 means requests clump onto banks in bursts (the
  conflict pattern multi-port memory exists for),
* ``region_priors``: the row histogram aggregated to dynamic-coding regions
  and ranked — the warm-start selection ``CodedMemorySystem.init`` /
  ``repro.sweep.run_points(region_priors=...)`` feed to the dynamic coding
  unit (``repro.core.dynamic.priors_layout``).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

import numpy as np

from repro.core.system import Trace


@dataclasses.dataclass(frozen=True)
class Band:
    """One detected address band, in row coordinates."""

    row_lo: int        # first row of the band (inclusive)
    row_hi: int        # last row of the band (inclusive)
    weight: float      # fraction of all accesses landing in the band
    persistence: float  # fraction of windows the band's bins were present in

    @property
    def center(self) -> float:
        return (self.row_lo + self.row_hi) / 2


@dataclasses.dataclass(frozen=True)
class TraceProfile:
    """Aggregated statistics; derived views are methods (host-side numpy)."""

    n_banks: int
    n_rows: int
    n_requests: int
    reads: int
    writes: int
    bank_hist: np.ndarray      # (n_banks,) int64
    row_hist: np.ndarray       # (n_rows,) int64
    n_windows: int
    window: int                # requests per window
    bin_rows: int              # rows per coarse presence bin
    presence: np.ndarray       # (n_bins,) int64 — windows each bin was hit in
    bank_window_mean: np.ndarray  # (n_banks,) per-window mean request count
    bank_window_var: np.ndarray   # (n_banks,) per-window variance

    # ------------------------------------------------------------------ mix
    @property
    def write_frac(self) -> float:
        return self.writes / max(self.n_requests, 1)

    @property
    def burstiness(self) -> float:
        """Mean per-bank Fano factor of windowed request counts (1 ≈
        Poisson; larger = bursty bank conflicts)."""
        mean = np.maximum(self.bank_window_mean, 1e-12)
        return float(np.mean(self.bank_window_var / mean))

    # ---------------------------------------------------------------- bands
    def bands(self, min_persistence: float = 0.5,
              min_weight: float = 0.02, max_gap_bins: int = 1) -> List[Band]:
        """Consistent address bands (Fig 15): maximal runs of coarse row
        bins present in ≥ ``min_persistence`` of windows, gaps up to
        ``max_gap_bins`` bridged, runs carrying < ``min_weight`` of total
        traffic dropped."""
        if self.n_windows == 0:
            return []
        frac = self.presence / self.n_windows
        consistent = frac >= min_persistence
        bands: List[Band] = []
        total = max(self.row_hist.sum(), 1)
        i, n = 0, consistent.size
        while i < n:
            if not consistent[i]:
                i += 1
                continue
            j = i
            gap = 0
            k = i + 1
            while k < n and gap <= max_gap_bins:
                if consistent[k]:
                    j, gap = k, 0
                else:
                    gap += 1
                k += 1
            lo = i * self.bin_rows
            hi = min((j + 1) * self.bin_rows, self.n_rows) - 1
            w = float(self.row_hist[lo:hi + 1].sum() / total)
            if w >= min_weight:
                bands.append(Band(lo, hi, w,
                                  float(frac[i:j + 1].mean())))
            i = j + 1
        return bands

    # ----------------------------------------------------------- region feed
    def region_priors(self, region_size: int, n_regions: int,
                      k: Optional[int] = None) -> np.ndarray:
        """Ranked hot regions for the dynamic coding unit: the row histogram
        aggregated per region (the same ``row // region_size`` binning the
        controller's ``access_count`` uses), hottest first, zero-traffic
        regions excluded, -1 padded to ``k`` entries."""
        counts = np.zeros(n_regions, np.int64)
        idx = np.arange(self.n_rows) // region_size
        np.add.at(counts, np.minimum(idx, n_regions - 1), self.row_hist)
        order = np.argsort(-counts, kind="stable")
        order = order[counts[order] > 0]
        if k is not None:
            out = np.full(k, -1, np.int32)
            out[:min(k, order.size)] = order[:min(k, order.size)]
            return out
        return order.astype(np.int32)


class TraceProfiler:
    """Streaming accumulator: feed chunks with ``update``, read a
    ``TraceProfile`` with ``profile`` at any point."""

    def __init__(self, n_banks: int, n_rows: int, window: int = 512,
                 bin_rows: Optional[int] = None):
        self.n_banks = n_banks
        self.n_rows = n_rows
        self.window = max(int(window), 1)
        # coarse presence bins: fine enough to resolve paper-width bands
        # (~3% of the row space), coarse enough that per-window presence
        # is dense inside a band
        self.bin_rows = bin_rows if bin_rows is not None else max(
            n_rows // 128, 1)
        self._n_bins = -(-n_rows // self.bin_rows)
        self.bank_hist = np.zeros(n_banks, np.int64)
        self.row_hist = np.zeros(n_rows, np.int64)
        self.reads = 0
        self.writes = 0
        self.n_requests = 0
        self.n_windows = 0
        self.presence = np.zeros(self._n_bins, np.int64)
        # windowed per-bank counts for burstiness (Welford over windows)
        self._bw_mean = np.zeros(n_banks)
        self._bw_m2 = np.zeros(n_banks)
        # carry of an incomplete window across update() calls
        self._pend_rows: List[np.ndarray] = []
        self._pend_banks: List[np.ndarray] = []
        self._pend_n = 0

    # ------------------------------------------------------------- streaming
    def update(self, chunk: Trace) -> "TraceProfiler":
        """Accumulate one chunk. Requests are taken in arrival order
        (time-major: all cores' cycle t before cycle t+1), matching the
        order the cycle engine's core arbiter consumes them."""
        bank = np.asarray(chunk.bank)
        row = np.asarray(chunk.row)
        isw = np.asarray(chunk.is_write)
        valid = np.asarray(chunk.valid)
        # time-major flatten, masked to real requests
        v = valid.T.reshape(-1)
        b = bank.T.reshape(-1)[v]
        r = row.T.reshape(-1)[v]
        w = isw.T.reshape(-1)[v]
        np.add.at(self.bank_hist, b, 1)
        np.add.at(self.row_hist, r, 1)
        self.writes += int(w.sum())
        self.reads += int(v.sum()) - int(w.sum())
        self.n_requests += int(v.sum())
        self._pend_rows.append(r)
        self._pend_banks.append(b)
        self._pend_n += r.size
        while self._pend_n >= self.window:
            rows = np.concatenate(self._pend_rows) if len(self._pend_rows) > 1 \
                else self._pend_rows[0]
            banks = np.concatenate(self._pend_banks) if len(self._pend_banks) > 1 \
                else self._pend_banks[0]
            self._consume_window(rows[:self.window], banks[:self.window])
            self._pend_rows = [rows[self.window:]]
            self._pend_banks = [banks[self.window:]]
            self._pend_n -= self.window
        return self

    def _consume_window(self, rows: np.ndarray, banks: np.ndarray):
        self.n_windows += 1
        bins = np.zeros(self._n_bins, bool)
        bins[rows // self.bin_rows] = True
        self.presence += bins
        counts = np.bincount(banks, minlength=self.n_banks).astype(float)
        d = counts - self._bw_mean
        self._bw_mean += d / self.n_windows
        self._bw_m2 += d * (counts - self._bw_mean)

    def profile(self) -> TraceProfile:
        var = (self._bw_m2 / max(self.n_windows - 1, 1)
               if self.n_windows > 1 else np.zeros(self.n_banks))
        return TraceProfile(
            n_banks=self.n_banks, n_rows=self.n_rows,
            n_requests=self.n_requests, reads=self.reads, writes=self.writes,
            bank_hist=self.bank_hist.copy(), row_hist=self.row_hist.copy(),
            n_windows=self.n_windows, window=self.window,
            bin_rows=self.bin_rows, presence=self.presence.copy(),
            bank_window_mean=self._bw_mean.copy(), bank_window_var=var)


def profile_trace(trace_or_chunks, n_banks: int, n_rows: int,
                  window: int = 512,
                  bin_rows: Optional[int] = None) -> TraceProfile:
    """One-call profiling of a Trace or an iterable of Trace chunks."""
    prof = TraceProfiler(n_banks, n_rows, window=window, bin_rows=bin_rows)
    chunks: Iterable[Trace] = ([trace_or_chunks]
                               if isinstance(trace_or_chunks, Trace)
                               else trace_or_chunks)
    for chunk in chunks:
        prof.update(chunk)
    return prof.profile()
