"""Fig 19 reproduction: the split-band augmentation (many narrow bands).
Paper claim: with many bands, matching the baseline-trace gains requires
larger α (more coded regions) or a larger memory partition coefficient r."""
from __future__ import annotations

from benchmarks.common import emit, table
from repro.sim.ramulator import simulate
from repro.sim.trace import TraceSpec, split_band_trace


def run(length: int = 96, n_rows: int = 320, seed: int = 0):
    spec = TraceSpec(n_cores=8, length=length, n_banks=8, n_rows=n_rows,
                     seed=seed, write_frac=0.3)
    trace = split_band_trace(spec, n_bands=8)
    n_cycles = int(length * 8 * 1.5) + 64
    base = simulate("uncoded", trace, n_rows, alpha=1.0, r=0.05,
                    n_cycles=n_cycles, select_period=64)
    rows = [{"scheme": "uncoded", "alpha": None, "r": None,
             "cycles": base.cycles, "reduction_%": 0.0, "switches": 0}]
    for r in (0.05, 0.125, 0.25):
        for a in (0.1, 0.25, 0.5, 1.0):
            res = simulate("scheme_i", trace, n_rows, alpha=a, r=r,
                           n_cycles=n_cycles, select_period=64)
            rows.append({
                "scheme": "scheme_i", "alpha": a, "r": r,
                "cycles": res.cycles,
                "reduction_%": round(100 * (1 - res.cycles / base.cycles), 1),
                "switches": res.switches,
            })
    print("\n== Fig 19: split-band trace — gains need larger α or r ==")
    print(table(rows, list(rows[0].keys())))
    emit("fig19_split", rows, {"length": length, "n_rows": n_rows})
    return rows


if __name__ == "__main__":
    run()
