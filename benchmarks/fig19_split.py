"""Fig 19 reproduction: the split-band augmentation (many narrow bands).
Paper claim: with many bands, matching the baseline-trace gains requires
larger α (more coded regions) or a larger memory partition coefficient r.

Runs through ``repro.sweep`` (the ``paper_fig19`` suite)."""
from __future__ import annotations

from benchmarks.common import emit, table
from repro.sweep import SweepPoint, run_sweep
from repro.sweep.workloads import paper_fig19


def run(length: int = 96, n_rows: int = 320, seed: int = 0):
    base = SweepPoint(n_rows=n_rows, length=length, n_cores=8, n_banks=8,
                      seed=seed, write_frac=0.3, select_period=64)
    pts = paper_fig19(base, rs=(0.05, 0.125, 0.25),
                      alphas=(0.1, 0.25, 0.5, 1.0))
    rs = run_sweep(pts)
    rows = []
    for row in rs.rows():
        uncoded = row["scheme"] == "uncoded"
        rows.append({
            "scheme": row["scheme"],
            "alpha": None if uncoded else row["alpha"],
            "r": None if uncoded else row["r"],
            "cycles": row["cycles"],
            "reduction_%": row.get("cycle_reduction_%", 0.0),
            "switches": 0 if uncoded else row["switches"],
        })
    print("\n== Fig 19: split-band trace — gains need larger α or r ==")
    print(table(rows, list(rows[0].keys())))
    emit("fig19_split", rows, {"length": length, "n_rows": n_rows})
    return rows


if __name__ == "__main__":
    run()
