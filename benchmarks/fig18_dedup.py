"""Fig 18 reproduction: CPU cycles + dynamic-coding region switches vs α on
a dedup-like banded trace (r=0.05), schemes I–III vs the uncoded baseline.

Runs through ``repro.sweep`` (the ``paper_fig18`` suite): one compiled
program per (scheme, α) shape instead of one jit trace per call, with
baseline normalization from the results store.

Paper validation targets (§V-C):
  * consistent large cycle reduction once α is sufficient (paper: 73–83%
    fewer cycles at r=0.05 on dedup; magnitude depends on trace density),
  * α=1.0 → zero region switches,
  * α=0.05 (one slot) → vacillation between the two hot bands ⇒ high
    switch count; α=0.1 (⌊α/r⌋=2 slots) → both bands coded ⇒ few switches.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, table
from repro.configs.paper_memsys import PAPER_ALPHAS, PAPER_SCHEMES
from repro.sweep import SweepPoint, run_sweep
from repro.sweep.workloads import paper_fig18


def run(length: int = 96, n_rows: int = 320, r: float = 0.05,
        alphas=PAPER_ALPHAS, schemes=PAPER_SCHEMES, seed: int = 0,
        select_period: int = 32):
    base = SweepPoint(trace="banded", n_rows=n_rows, length=length,
                      n_cores=8, n_banks=8, seed=seed, write_frac=0.3,
                      select_period=select_period)
    pts = paper_fig18(base, schemes=schemes, alphas=alphas, r=r)
    rs = run_sweep(pts)
    rows = []
    for row in rs.rows():
        uncoded = row["scheme"] == "uncoded"
        rows.append({
            "scheme": row["scheme"], "alpha": None if uncoded else row["alpha"],
            "cycles": row["cycles"],
            "reduction_%": row.get("cycle_reduction_%", 0.0),
            "switches": 0 if uncoded else row["switches"],
            "degraded": row["degraded_reads"], "parked": row["parked_writes"],
            "read_lat": round(row["avg_read_latency"], 2),
        })
    print("\n== Fig 18: dedup-like banded trace, cycles & switches vs α ==")
    print(table(rows, list(rows[0].keys())))
    emit("fig18_dedup", rows, {"r": r, "length": length, "n_rows": n_rows})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--length", type=int, default=96)
    args = ap.parse_args()
    run(length=args.length)
