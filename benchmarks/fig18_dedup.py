"""Fig 18 reproduction: CPU cycles + dynamic-coding region switches vs α on
a dedup-like banded trace (r=0.05), schemes I–III vs the uncoded baseline.

Paper validation targets (§V-C):
  * consistent large cycle reduction once α is sufficient (paper: 73–83%
    fewer cycles at r=0.05 on dedup; magnitude depends on trace density),
  * α=1.0 → zero region switches,
  * α=0.05 (one slot) → vacillation between the two hot bands ⇒ high
    switch count; α=0.1 (⌊α/r⌋=2 slots) → both bands coded ⇒ few switches.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, table
from repro.configs.paper_memsys import PAPER_ALPHAS, PAPER_SCHEMES
from repro.sim.ramulator import simulate
from repro.sim.trace import TraceSpec, banded_trace


def run(length: int = 96, n_rows: int = 320, r: float = 0.05,
        alphas=PAPER_ALPHAS, schemes=PAPER_SCHEMES, seed: int = 0,
        select_period: int = 32):
    spec = TraceSpec(n_cores=8, length=length, n_banks=8, n_rows=n_rows,
                     seed=seed, write_frac=0.3)
    trace = banded_trace(spec)
    n_cycles = int(length * 8 * 1.5) + 64
    base = simulate("uncoded", trace, n_rows, alpha=1.0, r=r,
                    n_cycles=n_cycles, select_period=select_period)
    rows = [{"scheme": "uncoded", "alpha": None, "cycles": base.cycles,
             "reduction_%": 0.0, "switches": 0, "degraded": 0,
             "parked": 0, "read_lat": round(base.avg_read_latency, 2)}]
    for scheme in schemes:
        for a in alphas:
            res = simulate(scheme, trace, n_rows, alpha=a, r=r,
                           n_cycles=n_cycles, select_period=select_period)
            rows.append({
                "scheme": scheme, "alpha": a, "cycles": res.cycles,
                "reduction_%": round(100 * (1 - res.cycles / base.cycles), 1),
                "switches": res.switches, "degraded": res.degraded_reads,
                "parked": res.parked_writes,
                "read_lat": round(res.avg_read_latency, 2),
            })
    print("\n== Fig 18: dedup-like banded trace, cycles & switches vs α ==")
    print(table(rows, list(rows[0].keys())))
    emit("fig18_dedup", rows, {"r": r, "length": length, "n_rows": n_rows})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--length", type=int, default=96)
    args = ap.parse_args()
    run(length=args.length)
