"""Looped-vs-batched sweep benchmark (the ``repro.sweep`` deliverable).

Evaluates one 16-point α×r design grid (2 α × 2 r × 2 traces × 2 seeds)
two ways:

  * **looped** — the pre-sweep-engine path: one ``repro.sim.ramulator
    .simulate`` call per point, each paying a fresh jit trace + compile +
    ``lax.scan`` launch (a fresh ``CodedMemorySystem`` per call, exactly as
    the figure benchmarks used to run);
  * **batched** — ``repro.sweep.engine``: α and r are masked axes, so the
    whole α×r grid shares one static shape — ONE compile + ONE vmapped
    scan (region/parity state allocated at the group-max geometry, each
    point's own geometry traced).

Reports wall-clock, simulated-cycles/second, the speedup (target ≥5×), and
verifies the per-point results are numerically identical.
"""
from __future__ import annotations

import argparse

from benchmarks.common import Timer, emit, table
from repro.sim.ramulator import simulate
from repro.sweep import SweepPoint, grid, partition, run_points
from repro.sweep.workloads import build_trace


def make_grid(length: int = 48, n_rows: int = 128) -> list:
    """16 shape-compatible points: an α×r grid (all sub-full-coverage, so
    the r and α axes both mask into ONE compiled program per scheme)."""
    base = SweepPoint(scheme="scheme_i", n_rows=n_rows,
                      n_cores=8, n_banks=8, length=length, write_frac=0.3,
                      select_period=32)
    return grid(base, alpha=(0.125, 0.25), r=(0.0625, 0.125),
                trace=("banded", "split"), seed=(0, 1))


def run(length: int = 48, n_rows: int = 128):
    pts = make_grid(length=length, n_rows=n_rows)
    n_batches = len(partition(pts))
    # the α×r acceptance bar: at most one program per (scheme, full-coverage)
    # group — this grid is one scheme, all sub-coverage, so exactly one
    assert n_batches == 1, f"α×r grid split into {n_batches} compiled programs"
    n_cycles = pts[0].resolved_cycles()
    traces = [build_trace(pt) for pt in pts]

    with Timer() as t_loop:
        looped = [simulate(pt.scheme, tr, pt.n_rows, alpha=pt.alpha, r=pt.r,
                           n_cycles=n_cycles, select_period=pt.select_period,
                           wq_hi=pt.wq_hi, wq_lo=pt.wq_lo,
                           queue_depth=pt.queue_depth)
                  for pt, tr in zip(pts, traces)]

    with Timer() as t_cold:
        batched = run_points(pts, traces=traces)
    with Timer() as t_warm:                      # compile amortized away
        batched2 = run_points(pts, traces=traces)

    mismatches = [i for i, (a, b) in enumerate(zip(looped, batched)) if a != b]
    assert batched == batched2, "batched path is nondeterministic"

    sim_cycles = len(pts) * n_cycles
    rows = [
        {"path": "looped (per-config jit)", "wall_s": round(t_loop.s, 2),
         "sim_cycles/s": round(sim_cycles / t_loop.s, 1), "speedup": 1.0},
        {"path": "batched (cold)", "wall_s": round(t_cold.s, 2),
         "sim_cycles/s": round(sim_cycles / t_cold.s, 1),
         "speedup": round(t_loop.s / t_cold.s, 2)},
        {"path": "batched (warm)", "wall_s": round(t_warm.s, 2),
         "sim_cycles/s": round(sim_cycles / t_warm.s, 1),
         "speedup": round(t_loop.s / t_warm.s, 2)},
    ]
    print(f"\n== bench_sweep: {len(pts)}-point grid, {n_cycles} cycles/point ==")
    print(table(rows, ["path", "wall_s", "sim_cycles/s", "speedup"]))
    ident = "IDENTICAL" if not mismatches else f"MISMATCH at {mismatches}"
    ok = not mismatches and t_loop.s / t_cold.s >= 5.0
    print(f"per-point results vs looped path: {ident}")
    print(f"cold speedup {t_loop.s / t_cold.s:.1f}x (target >=5x) -> "
          f"{'PASS' if ok else 'FAIL'}")
    emit("bench_sweep", rows, {
        "n_points": len(pts), "n_cycles": n_cycles, "identical": not mismatches,
        "n_compiled_programs": n_batches,
        "speedup_cold": t_loop.s / t_cold.s, "speedup_warm": t_loop.s / t_warm.s,
    })
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--length", type=int, default=48)
    ap.add_argument("--n-rows", type=int, default=128)
    args = ap.parse_args()
    ok = run(length=args.length, n_rows=args.n_rows)
    raise SystemExit(0 if ok else 1)
