"""End-to-end serving benchmark: coded vs uncoded KV pool under churn.

Drives the full request path — ``runtime.server.Server`` continuous
batching over the coded KV page pool — with a churned-placement workload
(seeded physical-page permutations mid-run, the free-list steady state
where bank conflicts appear) and reports:

* **steady-state decode throughput** (tokens/s, warmup wave compiles
  prefill + decode before the timed wave) for the coded and uncoded pool;
* **critical-word read latency** p50/p99/mean in port cycles, coded vs
  uncoded *on identical placement* — every latency is recomputed host-side
  by the ``repro.oracle.kvpool`` golden model (never read back from the
  device), and the device serve planes are cross-checked against the same
  oracle totals exactly before any number is reported;
* **telemetry overhead** (full runs): the metrics-on decode wall time must
  stay within 1.05x of metrics-off (the planes are a carry leaf, not a
  second program).

Gates: coded must serve the churned suite in strictly fewer summed port
cycles and strictly lower mean latency than uncoded (p99 no worse), and —
like ``bench_cycles`` — the steady-state throughput is regressed against
the checked-in ``BENCH_serve_throughput.json`` trajectory (``--min-frac``
floor, only a passing full run refreshes the repo-root baseline).
``--smoke`` shrinks the workload and skips the overhead gate (CI).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import REPO_ROOT, Timer, emit, table

BASELINE_PATH = os.path.join(REPO_ROOT, "BENCH_serve_throughput.json")
CHURN_EVERY = 2


def load_baseline():
    """Coded steady-state tokens/s from the checked-in trajectory blob, or
    None when absent. Like bench_cycles, deliberately not keyed on tier:
    the loose --min-frac floor absorbs the smoke/full workload gap."""
    if not os.path.exists(BASELINE_PATH):
        return None
    try:
        with open(BASELINE_PATH) as f:
            blob = json.load(f)
    except (OSError, ValueError):
        return None
    v = blob.get("headline", {}).get("tokens_per_s")
    return float(v) if v else None


def _requests(vocab: int, n: int, seed: int):
    from repro.runtime.server import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=[int(x) for x in
                            rng.integers(1, max(vocab // 2, 2),
                                         size=4 + i % 9)])
            for i in range(n)]


def _metrics_run(cfg, sc, params, reqs, seed):
    """Untimed oracle-instrumented run over the coded pool: collects every
    page read's critical-word latency (host recompute) for the coded plan
    AND for an uncoded plan on the identical churned placement, and proves
    the device serve planes equal the oracle totals exactly."""
    from repro.oracle import kvpool
    from repro.runtime.server import Server

    srv = Server(cfg, sc, params)
    assert srv.pooled and sc.coded and sc.telemetry
    churn_rng = np.random.default_rng(seed)
    totals = kvpool.plane_totals(srv.kvcfg.n_banks)
    lat_coded: list = []
    lat_uncoded: list = []
    for r in reqs:
        srv.submit(r)
    step = 0
    while True:
        srv._admit()
        if not any(s is not None for s in srv.slots):
            break
        if step and step % CHURN_EVERY == 0:
            srv.permute_pool(churn_rng.permutation(srv.kvcfg.pool_pages))
        pool = srv.cache["pool"]
        pt = np.asarray(pool.page_table)
        ln = np.asarray(pool.length)
        fresh = np.asarray(pool.parity_fresh)
        active = (pt[:, 0] >= 0) & (ln > 0)
        exp = kvpool.expected_step(srv.kvcfg.n_banks, srv.kvcfg.page, pt,
                                   ln, fresh, active, sc.recode_budget)
        totals.add(exp)
        lat_coded.extend(exp.latencies[exp.latencies > 0].tolist())
        len_eff = ln + active.astype(ln.dtype)
        lat_u = kvpool.read_latencies(srv.kvcfg.n_banks, srv.kvcfg.page,
                                      pt, len_eff,
                                      np.zeros_like(exp.use_parity))
        lat_uncoded.extend(lat_u[lat_u > 0].tolist())
        srv.step_decode()
        step += 1
    snap = srv.serve_snapshot()
    snap.check_against(totals)          # exact or AssertionError
    return totals, np.asarray(lat_coded), np.asarray(lat_uncoded)


def _timed_run(cfg, sc, params, reqs, seed):
    """Steady-state wall-clock tokens/s: a warmup wave triggers every
    compile (prefill, decode, install, permute), then the measured wave
    runs the same churn schedule as the metrics run."""
    from repro.runtime.server import Request, Server

    srv = Server(cfg, sc, params)
    warm = [Request(rid=10_000 + i, prompt=[3, 1, 4, 1, 5])
            for i in range(2)]
    for r in warm:
        srv.submit(r)
    srv.run_until_drained()
    srv.permute_pool(np.arange(srv.kvcfg.pool_pages))   # compile permute

    churn_rng = np.random.default_rng(seed)
    for r in reqs:
        srv.submit(r)
    step = 0
    t0 = time.perf_counter()
    while True:
        srv._admit()
        if not any(s is not None for s in srv.slots):
            break
        if step and step % CHURN_EVERY == 0:
            srv.permute_pool(churn_rng.permutation(srv.kvcfg.pool_pages))
        srv.step_decode()
        step += 1
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in reqs)
    return n_tok, dt


def _kernel_identity(cfg, base, params, make_reqs, seed) -> bool:
    """Serve the same workload through the reference pool gather and the
    Pallas ``gather_pool_pallas`` datapath (``ServeConfig.kernel``): the
    kernel is bit-exact by design, so every served token must match."""
    from repro.runtime.server import ServeConfig
    outs = {}
    for kern in ("reference", "pallas"):
        rs = make_reqs()
        _timed_run(cfg, ServeConfig(**base, coded=True, kernel=kern),
                   params, rs, seed)
        outs[kern] = [r.out for r in rs]
    return outs["reference"] == outs["pallas"]


def run(smoke: bool = False, min_frac: float = 0.3, seed: int = 0):
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.runtime.server import ServeConfig

    cfg = dataclasses.replace(get_config("qwen2.5-3b").reduced(), kv_page=4)
    n_req = 6 if smoke else 16
    base = dict(n_slots=4, max_prompt=16, max_seq=64,
                max_new_tokens=6 if smoke else 16)
    params = lm.init_params(cfg, jax.random.key(seed), max_seq=base["max_seq"])
    reqs = lambda: _requests(cfg.vocab, n_req, seed)  # noqa: E731

    totals, lat_c, lat_u = _metrics_run(
        cfg, ServeConfig(**base, coded=True, telemetry=True), params,
        reqs(), seed)
    p50_c, p99_c = np.percentile(lat_c, [50, 99])
    p50_u, p99_u = np.percentile(lat_u, [50, 99])

    with Timer() as t_coded:
        tok_c, dt_c = _timed_run(cfg, ServeConfig(**base, coded=True),
                                 params, reqs(), seed)
    with Timer() as t_unc:
        tok_u, dt_u = _timed_run(cfg, ServeConfig(**base, coded=False),
                                 params, reqs(), seed)
    tput_c = tok_c / dt_c
    tput_u = tok_u / dt_u

    overhead = None
    if not smoke:
        _, dt_tele = _timed_run(
            cfg, ServeConfig(**base, coded=True, telemetry=True), params,
            reqs(), seed)
        overhead = dt_tele / dt_c

    rows = [
        {"backend": "coded", "tokens": tok_c, "wall_s": round(dt_c, 3),
         "tokens_per_s": round(tput_c, 1),
         "lat_p50": float(p50_c), "lat_p99": float(p99_c),
         "lat_mean": round(float(lat_c.mean()), 3),
         "port_cycles": totals.coded_cycles,
         "degraded_reads": int(totals.read_mode_bank[:, 1].sum())},
        {"backend": "uncoded", "tokens": tok_u, "wall_s": round(dt_u, 3),
         "tokens_per_s": round(tput_u, 1),
         "lat_p50": float(p50_u), "lat_p99": float(p99_u),
         "lat_mean": round(float(lat_u.mean()), 3),
         "port_cycles": totals.uncoded_cycles, "degraded_reads": 0},
    ]
    print(f"\n== bench_serve: {n_req} requests, "
          f"{base['max_new_tokens']} new tokens, churn every "
          f"{CHURN_EVERY} steps{' [smoke]' if smoke else ''} ==")
    print(table(rows, list(rows[0].keys())))

    kernel_same = _kernel_identity(cfg, base, params, reqs, seed)
    print(f"pallas pool-gather kernel vs reference gather: token-"
          f"{'identical -> PASS' if kernel_same else 'DIVERGENT -> FAIL'}")

    coded_wins = (totals.coded_cycles < totals.uncoded_cycles
                  and float(lat_c.mean()) < float(lat_u.mean())
                  and p99_c <= p99_u)
    print(f"coded vs uncoded on churned placement: "
          f"{totals.coded_cycles} vs {totals.uncoded_cycles} port cycles, "
          f"mean lat {lat_c.mean():.3f} vs {lat_u.mean():.3f} "
          f"-> {'PASS' if coded_wins else 'FAIL'}")
    ok = coded_wins and kernel_same
    if overhead is not None:
        tele_ok = overhead <= 1.05
        print(f"telemetry-on overhead {overhead:.3f}x (gate 1.05x) "
              f"-> {'PASS' if tele_ok else 'FAIL'}")
        ok = ok and tele_ok

    baseline = load_baseline()
    regressed = False
    if baseline is None:
        print("no checked-in throughput baseline — recording trajectory "
              "only")
    else:
        frac = tput_c / baseline
        regressed = frac < min_frac
        print(f"coded steady-state {tput_c:.1f} tok/s vs checked-in "
              f"baseline {baseline:.1f} ({frac:.2f}x, floor {min_frac:g}x)"
              f" -> {'FAIL' if regressed else 'PASS'}")
    ok = ok and not regressed
    emit("BENCH_serve_throughput", rows, {
        "n_requests": n_req, "max_new_tokens": base["max_new_tokens"],
        "n_slots": base["n_slots"], "page": 4, "n_banks": cfg.kv_banks,
        "churn_every": CHURN_EVERY, "smoke": smoke,
        "baseline_tokens_per_s": baseline, "min_frac": min_frac,
        "coded_wins": coded_wins, "kernel_identity": kernel_same,
        "regressed": regressed,
        "telemetry_overhead": overhead,
    }, root=not smoke and ok,
        headline={"tokens_per_s": round(tput_c, 1),
                  "lat_p99_coded": float(p99_c),
                  "lat_p99_uncoded": float(p99_u)},
        timings={"coded_s": t_coded.s, "uncoded_s": t_unc.s})
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload, no overhead gate (CI)")
    ap.add_argument("--min-frac", type=float, default=0.3,
                    help="fail below this fraction of the checked-in "
                         "steady-state tokens/s baseline")
    args = ap.parse_args()
    raise SystemExit(0 if run(smoke=args.smoke, min_frac=args.min_frac)
                     else 1)
