"""Roofline report: aggregates the dry-run JSON artifacts
(experiments/dryrun/*.json) into the EXPERIMENTS.md §Roofline table.

Run the cells first:
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
then:
  PYTHONPATH=src python -m benchmarks.roofline_report
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, table

DRY_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(mesh: str = "pod16x16", tag: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRY_DIR, f"*_{mesh}*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag or rec["mesh"] != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "skip", "why": rec["reason"][:40]})
            continue
        r = rec.get("roofline", {})
        m = rec.get("memory", {})
        if not r:  # --skip-cost artifact (multi-pod pass): compile-proof only
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "compile✓",
                         "live_GB": round(m.get("live_bytes", 0) / 1e9, 2)})
            continue
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "t_comp_s": round(r.get("t_compute_s", 0), 3),
            "t_mem_s": round(r.get("t_memory_s", 0), 3),
            "t_coll_s": round(r.get("t_collective_s", 0), 3),
            "dominant": r.get("dominant"),
            "useful_ratio": round(r.get("useful_flops_ratio", 0), 3),
            "roofline_frac": round(r.get("roofline_frac", 0), 4),
            "live_GB": round(m.get("live_bytes", 0) / 1e9, 2),
        })
    return rows


def run(mesh: str = "pod16x16", tag: str = ""):
    rows = load(mesh, tag)
    if not rows:
        print(f"(no dry-run artifacts for mesh={mesh} tag={tag!r} — run "
              f"python -m repro.launch.dryrun --all first)")
        return []
    print(f"\n== Roofline terms per (arch × shape), mesh={mesh} "
          f"{('tag=' + tag) if tag else ''} ==")
    cols = ["arch", "shape", "status", "t_comp_s", "t_mem_s", "t_coll_s",
            "dominant", "useful_ratio", "roofline_frac", "live_GB"]
    print(table(rows, cols))
    ok = [r for r in rows if r["status"] == "ok" and "roofline_frac" in r]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_frac"] or 1)
        coll = max(ok, key=lambda r: r["t_coll_s"] or 0)
        print(f"\nworst roofline fraction : {worst['arch']} × {worst['shape']}"
              f" ({worst['roofline_frac']})")
        print(f"most collective-bound   : {coll['arch']} × {coll['shape']}"
              f" (t_coll={coll['t_coll_s']}s)")
    emit(f"roofline_{mesh}{('_' + tag) if tag else ''}", rows)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    run(args.mesh, args.tag)
