"""Pallas kernel microbenchmarks: bytes/s + %-of-roofline per kernel.

Times each of the repo's Pallas datapath kernels on a fixed workload —
``xor_encode`` (parity encode), ``xor_gather`` (coded row gather),
``coded_kv_decode`` (banked flash decode) and ``pool_gather`` (the serving
pool gather) — and reports effective memory bandwidth from *analytic* byte
counts (bytes each kernel must move for its workload, not device counters,
so the number is comparable across backends and interpret mode).

The roofline reference is a measured same-process copy bandwidth
(jit ``x + 1`` over a comparably sized array): ``pct_roofline`` is the
kernel's effective bytes/s over that copy ceiling. On CPU the kernels run
in the Pallas interpreter (``interpret=None`` backend resolution,
docs/kernels.md), so absolute numbers are small — the gate is therefore a
*trajectory* gate like ``bench_serve``: per-kernel bytes/s regressed
against the checked-in ``BENCH_kernels.json`` headline with a loose
``--min-frac`` floor that absorbs machine noise but catches a kernel
falling off a cliff (e.g. a revived scalar request loop). Only a passing
full run refreshes the repo-root baseline. ``--smoke`` shrinks workloads
for hardware-free CI.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import REPO_ROOT, Timer, emit, table

BASELINE_PATH = os.path.join(REPO_ROOT, "BENCH_kernels.json")


def load_baseline():
    """{kernel: bytes_per_s} from the checked-in trajectory blob, or None.
    Not keyed on tier — the loose --min-frac floor absorbs the smoke/full
    workload gap (same contract as bench_serve/bench_cycles)."""
    if not os.path.exists(BASELINE_PATH):
        return None
    try:
        with open(BASELINE_PATH) as f:
            blob = json.load(f)
    except (OSError, ValueError):
        return None
    head = blob.get("headline", {})
    out = {k[: -len("_bytes_per_s")]: float(v)
           for k, v in head.items() if k.endswith("_bytes_per_s") and v}
    return out or None


def _time_best(fn, *args, reps: int = 3) -> float:
    """Best-of-``reps`` warm wall seconds; first call compiles."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _copy_roofline(nbytes: int) -> float:
    """Measured streaming bandwidth (bytes/s) of jit ``x + 1`` over an
    ``nbytes`` uint32 array — the same-process memory ceiling the kernels
    are scored against (read + write counted)."""
    n = max(nbytes // 4, 1024)
    x = jnp.arange(n, dtype=jnp.uint32)
    f = jax.jit(lambda a: a + jnp.uint32(1))
    dt = _time_best(f, x)
    return 2 * x.nbytes / dt


def _case_xor_encode(smoke: bool):
    from repro.kernels.xor_encode import ops
    nd, n_par, w = 8, 4, 256
    rows = 128 if smoke else 512
    sz = 4
    rng = np.random.default_rng(0)
    banks = jnp.asarray(rng.integers(0, 2**32, (nd, rows, w), dtype=np.uint32))
    members = [[2 * g, 2 * g + 1] for g in range(n_par)]

    def f():
        return ops.encode_parities(banks, members, block_rows=128)

    dt = _time_best(f)
    nbytes = (nd + n_par) * rows * w * sz
    return "xor_encode", nbytes, dt


def _case_xor_gather(smoke: bool):
    from repro.kernels.xor_gather.kernel import gather_decode_pallas
    nd, n_par, w = 8, 4, 256
    rows = 128 if smoke else 256
    n = 16 if smoke else 64
    rb, bt = 8, 128
    sz = 4
    rng = np.random.default_rng(1)
    banks = jnp.asarray(rng.integers(0, 2**32, (nd, rows, w), dtype=np.uint32))
    pars = jnp.asarray(rng.integers(0, 2**32, (n_par, rows, w),
                                    dtype=np.uint32))
    bank = jnp.asarray(rng.integers(0, nd, n), jnp.int32)
    row = jnp.asarray(rng.integers(0, rows, n), jnp.int32)
    mode = jnp.ones((n,), jnp.int32)            # all direct reads
    zero = jnp.zeros((n,), jnp.int32)
    neg = jnp.full((n,), -1, jnp.int32)

    def f():
        return gather_decode_pallas(banks, pars, bank, row, mode, zero,
                                    zero, neg, neg,
                                    req_block=rb, row_block=bt)

    dt = _time_best(f)
    tiles = -(-n // rb)
    nbytes = tiles * (nd + n_par) * rows * w * sz + n * w * sz
    return "xor_gather", nbytes, dt


def _case_coded_kv_decode(smoke: bool):
    from repro.kernels.coded_kv_decode import ops
    b, nb, page, hkv, d, g = 2, 4, 8, 2, 64, 2
    t_len = nb * page * (1 if smoke else 4)
    rng = np.random.default_rng(2)
    k = jnp.asarray(rng.normal(size=(b, t_len, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t_len, hkv, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, g * hkv, d)), jnp.float32)
    kb, vb, kp, vp, n_pages = ops.pack_kv_banks(k, v, nb, page)
    upar = jnp.zeros((b, n_pages), jnp.int32)
    slen = jnp.full((b,), t_len, jnp.int32)

    def f():
        return ops.coded_kv_decode(q, kb, vb, kp, vp, upar, slen)

    dt = _time_best(f)
    sz = 4
    nbytes = 2 * b * (kb.shape[1] + kp.shape[1]) * kb.shape[2] \
        * page * hkv * d * sz + q.nbytes + q.nbytes
    return "coded_kv_decode", nbytes, dt


def _case_pool_gather(smoke: bool):
    from repro.kernels.coded_kv_decode.kernel import gather_pool_pallas
    nb, slots, pg, hkv, d = 8, 8 if smoke else 32, 4, 2, 64
    b, mp = 4, 8 if smoke else 16
    sz = 4
    rng = np.random.default_rng(3)
    shape = (nb, slots, pg, hkv, d)
    kb = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    vb = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    kp = kb.reshape((nb // 2, 2) + shape[1:])[:, 0] \
        ^ kb.reshape((nb // 2, 2) + shape[1:])[:, 1]
    vp = vb.reshape((nb // 2, 2) + shape[1:])[:, 0] \
        ^ vb.reshape((nb // 2, 2) + shape[1:])[:, 1]
    pt = jnp.asarray(rng.permutation(nb * slots)[: b * mp].reshape(b, mp),
                     jnp.int32)
    upar = jnp.asarray(rng.integers(0, 2, (b, mp)), jnp.int32)

    def f():
        return gather_pool_pallas(kb, vb, kp, vp, pt, upar)

    dt = _time_best(f)
    # each grid step loads direct + sibling + parity pages (k and v) and
    # writes one reconstructed page pair
    nbytes = b * mp * (6 + 2) * pg * hkv * d * sz
    return "pool_gather", nbytes, dt


CASES = (_case_xor_encode, _case_xor_gather, _case_coded_kv_decode,
         _case_pool_gather)


def run(smoke: bool = False, min_frac: float = 0.3):
    results = []
    with Timer() as t_all:
        for case in CASES:
            results.append(case(smoke))
    roof = _copy_roofline(max(nb for _, nb, _ in results))

    rows = []
    for name, nbytes, dt in results:
        bps = nbytes / dt
        rows.append({"kernel": name, "bytes": nbytes,
                     "wall_s": round(dt, 6),
                     "bytes_per_s": round(bps, 1),
                     "pct_roofline": round(100 * bps / roof, 2)})
    print(f"\n== bench_kernels{' [smoke]' if smoke else ''}: "
          f"copy roofline {roof / 1e9:.2f} GB/s ==")
    print(table(rows, list(rows[0].keys())))

    baseline = load_baseline()
    ok = True
    if baseline is None:
        print("no checked-in kernel baseline — recording trajectory only")
    else:
        for r in rows:
            base = baseline.get(r["kernel"])
            if not base:
                continue
            frac = r["bytes_per_s"] / base
            good = frac >= min_frac
            ok = ok and good
            print(f"{r['kernel']}: {r['bytes_per_s'] / 1e6:.2f} MB/s vs "
                  f"baseline {base / 1e6:.2f} ({frac:.2f}x, floor "
                  f"{min_frac:g}x) -> {'PASS' if good else 'FAIL'}")

    headline = {f"{r['kernel']}_bytes_per_s": r["bytes_per_s"]
                for r in rows}
    headline["copy_roofline_bytes_per_s"] = round(roof, 1)
    emit("BENCH_kernels", rows, {
        "smoke": smoke, "min_frac": min_frac,
        "baseline": baseline, "regressed": not ok,
        "backend": jax.default_backend(),
    }, root=not smoke and ok, headline=headline,
        timings={"total_s": t_all.s})
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workloads (hardware-free CI)")
    ap.add_argument("--min-frac", type=float, default=0.3,
                    help="fail below this fraction of the checked-in "
                         "per-kernel bytes/s baseline")
    args = ap.parse_args()
    raise SystemExit(0 if run(smoke=args.smoke, min_frac=args.min_frac)
                     else 1)
