"""§III-B scheme-comparison table: rate, storage overhead, locality,
best/worst reads per cycle — the paper's analytical claims, measured from
the actual code tables and pattern builder, plus end-to-end cycles on a
shared uniform worst-case trace via the batched ``repro.sweep`` engine."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, table
from repro.core import controller as ctl
from repro.core.codes import get_tables
from repro.core.state import make_params
from repro.sweep import SweepPoint, run_points


def _measure_best_case(name: str) -> int:
    """Serve the paper's §III-B best-case request mix, measure reads/cycle."""
    t = get_tables(name, n_data=9 if name == "scheme_iii" else 8)
    p = make_params(t, n_rows=64, alpha=1.0, r=0.25)
    jt = ctl.jtables(t)
    if name == "scheme_iii":
        banks = [0, 0, 0, 0, 1, 2, 3, 4, 5]
        rows = [1, 2, 3, 4, 1, 2, 3, 4, 1]
    else:
        banks = [0, 1, 2, 3, 0, 1, 2, 3, 2, 3, 0, 1]
        rows = [1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 4, 4]
    n = len(banks)
    plan = ctl.build_read_pattern(
        p, jt, jnp.asarray(banks, jnp.int32), jnp.asarray(rows, jnp.int32),
        jnp.arange(n, dtype=jnp.int32), jnp.ones((n,), bool),
        jnp.zeros((p.n_ports + 1,), bool),
        jnp.zeros((p.n_data, p.n_rows), jnp.int32),
        jnp.ones((p.n_parities, p.n_slots * p.region_size), bool),
        jnp.arange(p.n_regions, dtype=jnp.int32),
    )
    return int(plan.n_served)


def run(alpha: float = 0.25):
    schemes = ("uncoded", "replication_2", "replication_4",
               "scheme_i", "scheme_ii", "scheme_iii")
    # end-to-end worst-case column: every scheme on the same uniform trace,
    # one batched engine call per static shape (n_data differs for III)
    pts = [SweepPoint(scheme=name, n_data=9 if name == "scheme_iii" else 8,
                      n_rows=64, alpha=1.0, r=0.25, trace="uniform",
                      n_cores=4, length=32, seed=0)
           for name in schemes]
    uniform_cycles = {name: res.cycles
                      for name, res in zip(schemes, run_points(pts))}
    rows = []
    for name in schemes:
        nd = 9 if name == "scheme_iii" else 8
        t = get_tables(name, n_data=nd)
        s = t.scheme
        rows.append({
            "scheme": name,
            "data_banks": s.n_data,
            "parity_banks(phys)": s.n_phys,
            "rate(α=1)": round(s.rate(1.0), 4),
            f"rate(α={alpha})": round(s.rate(alpha), 4),
            "locality": s.locality(),
            "reads/bank": int(t.opt_n.min()) + 1 if s.n_parities else 1,
            "best_case_served": _measure_best_case(name)
            if name.startswith("scheme") else None,
            "uniform_cycles": uniform_cycles[name],
        })
    print("\n== Scheme comparison (paper §III-B) ==")
    print(table(rows, list(rows[0].keys())))
    emit("tab_schemes", rows, {"alpha": alpha})
    return rows


if __name__ == "__main__":
    run()
