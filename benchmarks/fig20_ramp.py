"""Fig 20 reproduction: the linear-ramp augmentation (drifting hot bands).
Paper claim: the dynamic coding unit struggles to track a constantly moving
primary access region — gains shrink vs the static-band case and switch
counts rise with drift.

Runs through ``repro.sweep`` (the ``paper_fig20`` suite)."""
from __future__ import annotations

from benchmarks.common import emit, table
from repro.sweep import SweepPoint, run_sweep
from repro.sweep.workloads import drift_label, paper_fig20

_NAMES = {0.0: "static", 0.25: "ramp_slow", 1.0: "ramp_fast"}


def run(length: int = 96, n_rows: int = 320, seed: int = 0):
    base = SweepPoint(n_rows=n_rows, length=length, n_cores=8, n_banks=8,
                      seed=seed, write_frac=0.3, select_period=64, r=0.05)
    drifts = (0.0, 0.25, 1.0)
    pts = paper_fig20(base, drifts=drifts, alphas=(0.1, 0.25))
    rs = run_sweep(pts)
    rows = []
    for drift in drifts:
        label = drift_label(drift)
        uncoded = rs.one(scheme="uncoded", label=label).result
        for rec in rs.by(scheme="scheme_i", label=label):
            rows.append({
                "trace": _NAMES[drift], "alpha": rec.point.alpha,
                "uncoded_cycles": uncoded.cycles,
                "coded_cycles": rec.result.cycles,
                "reduction_%": round(
                    100 * (1 - rec.result.cycles / uncoded.cycles), 1),
                "switches": rec.result.switches,
            })
    print("\n== Fig 20: ramp trace — drifting bands defeat dynamic coding ==")
    print(table(rows, list(rows[0].keys())))
    emit("fig20_ramp", rows, {"length": length, "n_rows": n_rows})
    return rows


if __name__ == "__main__":
    run()
