"""Fig 20 reproduction: the linear-ramp augmentation (drifting hot bands).
Paper claim: the dynamic coding unit struggles to track a constantly moving
primary access region — gains shrink vs the static-band case and switch
counts rise with drift."""
from __future__ import annotations

from benchmarks.common import emit, table
from repro.sim.ramulator import simulate
from repro.sim.trace import TraceSpec, banded_trace, ramp_trace


def run(length: int = 96, n_rows: int = 320, seed: int = 0):
    spec = TraceSpec(n_cores=8, length=length, n_banks=8, n_rows=n_rows,
                     seed=seed, write_frac=0.3)
    n_cycles = int(length * 8 * 1.5) + 64
    rows = []
    for name, drift in (("static", 0.0), ("ramp_slow", 0.25),
                        ("ramp_fast", 1.0)):
        space = spec.n_banks * spec.n_rows
        if drift == 0.0:
            trace = banded_trace(spec)
        else:
            trace = ramp_trace(spec, drift_total=space * drift)
        base = simulate("uncoded", trace, n_rows, alpha=1.0, r=0.05,
                        n_cycles=n_cycles, select_period=64)
        for a in (0.1, 0.25):
            res = simulate("scheme_i", trace, n_rows, alpha=a, r=0.05,
                           n_cycles=n_cycles, select_period=64)
            rows.append({
                "trace": name, "alpha": a,
                "uncoded_cycles": base.cycles, "coded_cycles": res.cycles,
                "reduction_%": round(100 * (1 - res.cycles / base.cycles), 1),
                "switches": res.switches,
            })
    print("\n== Fig 20: ramp trace — drifting bands defeat dynamic coding ==")
    print(table(rows, list(rows[0].keys())))
    emit("fig20_ramp", rows, {"length": length, "n_rows": n_rows})
    return rows


if __name__ == "__main__":
    run()
