"""Serving-side benchmark of the paper's technique: coded banked KV cache
port-cycle latency vs an uncoded banked cache, swept over context length.

This is the TPU adaptation of the paper's latency claim (DESIGN.md §3): KV
pages striped over single-ported banks; a coded cache serves a decode
step's page reads in fewer serialized bank cycles."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, table
from repro.runtime import kvbank as kb


def _churned_state(cfg, lengths, seed, churn=0.9):
    """Pool state after serving churn: requests have come and gone, so live
    pages sit wherever the free list pointed at their allocation time. We
    model the steady state by sampling each live page's physical id without
    replacement (uniform residual placement), which matches a long
    alloc/free history. churn=0 degenerates to fresh arrival order."""
    rng = np.random.default_rng(seed)
    b = len(lengths)
    n_live = sum(-(-L // cfg.page) for L in lengths)
    if churn > 0:
        phys_ids = rng.choice(cfg.pool_pages, size=n_live, replace=False)
    else:
        phys_ids = np.arange(n_live)
    table = np.full((b, cfg.max_pages), -1, np.int64)
    c = 0
    for i, L in enumerate(lengths):
        np_i = -(-L // cfg.page)
        table[i, :np_i] = phys_ids[c:c + np_i]
        c += np_i
    st = kb.init_state(cfg, b, 1, 8, jnp.bfloat16)
    return st._replace(page_table=jnp.asarray(table, jnp.int32),
                       length=jnp.asarray(lengths, jnp.int32))


def run():
    """Continuous-batch decode over a shared paged KV pool. After serving
    churn, live pages are scattered over the banks (free-list placement), so
    per-step bank loads are binomially imbalanced — the paper's bank
    conflicts. Parity pairs serve the overflow of the hot bank of each pair
    (degraded reads). ``fresh_arrival`` is the zero-churn baseline where
    round-robin allocation self-balances (the paper's worst case — shown
    for honesty: coding buys nothing there)."""
    rng = np.random.default_rng(0)
    rows = []
    cases = [
        ("churn_skew", (8, 16), [2048, 1024, 512, 256, 128, 128, 64, 64], 0.9, 0),
        ("churn_uniform", (8, 16), [1024] * 8, 0.9, 1),
        ("churn_heavy", (8, 16), [4096, 256, 128, 128, 64, 64, 32, 32], 0.9, 2),
        ("churn_4banks", (4, 32), [4096, 512, 256, 64], 0.9, 3),
        ("fresh_arrival", (8, 16), [2048, 1024, 512, 256, 128, 128, 64, 64],
         0.0, 4),
    ]
    for name, (n_banks, page), lengths, churn, seed in cases:
        mp = max(max(lengths) // page + 1, n_banks)
        pool = ((sum(lengths) // page * 2) // n_banks + 2) * n_banks
        cfg = kb.KVBankConfig(n_banks=n_banks, page=page, pool_pages=pool,
                              max_pages=mp)
        st = _churned_state(cfg, lengths, seed, churn)
        plan = kb.plan_reads(cfg, st)
        un, co = int(plan.uncoded_cycles), int(plan.coded_cycles)
        rows.append({
            "case": name, "banks": n_banks, "page": page,
            "batch": len(lengths), "max_ctx": max(lengths),
            "uncoded_port_cycles": un, "coded_port_cycles": co,
            "speedup": round(un / max(co, 1), 2),
            "degraded_reads": int(plan.use_parity.sum()),
            "storage_overhead": "50%",   # pairwise parity: NB/2 extra banks
        })
    print("\n== Coded KV-bank decode port-cycles (TPU serving adaptation) ==")
    print(table(rows, list(rows[0].keys())))
    emit("bench_kvbank", rows)
    return rows


if __name__ == "__main__":
    run()
