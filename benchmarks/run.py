"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs every paper-anchored harness (one per table/figure) plus the TPU
serving adaptations, then prints the roofline aggregation if dry-run
artifacts exist. Use ``--fast`` for the reduced CI-sized sweep."""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    t0 = time.time()

    from benchmarks import (bench_cycles, bench_embedding, bench_kernels,
                            bench_kvbank, bench_serve, bench_stream,
                            bench_sweep, fig18_dedup, fig19_split,
                            fig20_ramp, fig_faults, roofline_report,
                            tab_schemes)

    tab_schemes.run()
    fig18_dedup.run(length=48 if args.fast else 96)
    fig19_split.run(length=48 if args.fast else 96)
    fig20_ramp.run(length=48 if args.fast else 96)
    fig_faults.run(smoke=args.fast)
    bench_sweep.run(length=32 if args.fast else 48)
    bench_cycles.run(smoke=args.fast)
    bench_stream.run(smoke=args.fast)
    bench_kvbank.run()
    bench_kernels.run(smoke=args.fast)
    bench_serve.run(smoke=args.fast)
    bench_embedding.run()
    roofline_report.run("pod16x16")
    roofline_report.run("pod2x16x16")

    # the per-commit perf trajectory collects root-level BENCH_*.json files;
    # mirror the bench artifacts there so the trajectory actually records
    from benchmarks.common import mirror_bench_to_root
    for path in mirror_bench_to_root():
        print(f"perf artifact -> {path}")
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
