"""Cycle-throughput benchmark: absolute scheduler regression vs trajectory.

Evaluates the 16-point ``bench_sweep`` α×r grid (2 α × 2 r × 2 trace
generators × 2 seeds — one masked compiled program) through the looped
(``sim.ramulator.simulate``, one compile per point) and batched
(``repro.sweep``) pipelines, with a warm repeat of the batched path where
compile cost is amortized away. Per-point results must be identical across
pipelines and across repeats (the engine-equivalence contract; *semantic*
correctness is anchored to the NumPy golden model by
tests/test_conformance.py, not here).

Since the reference scheduler's retirement there is no second implementation
to race, so the gate is the **absolute warm-batched throughput** regressed
against the checked-in perf trajectory: the previous commit's repo-root
``BENCH_cycle_throughput.json`` records warm ``sim_cycles/s``, and this run
fails if it falls below ``--min-frac`` of that baseline (default 0.3 —
deliberately loose on purpose: the trajectory file travels across machines
AND the ``--smoke`` grid differs from the full grid, while warm throughput
is a per-cycle rate that varies far less than 0.3× across either; the
trajectory plot, not the gate, is the precision instrument). Emits
``experiments/bench/BENCH_cycle_throughput.json``; only a *passing full*
run refreshes the repo-root baseline copy — a smoke run must not replace
the full trajectory, and a regressed run must not ratchet the floor down
to its own regressed number.

``--smoke`` shrinks the grid and skips the looped pipeline — CI runs it on
every push and gates against the checked-in (full-run) baseline.
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import REPO_ROOT, Timer, emit, profile_trace, table
from repro.sim.ramulator import simulate
from repro.sweep import run_points
from repro.sweep.engine import clear_caches
from benchmarks.bench_sweep import make_grid

BASELINE_PATH = os.path.join(REPO_ROOT, "BENCH_cycle_throughput.json")


def _sim_cycles(results) -> int:
    return sum(r.cycles for r in results)


def load_baseline():
    """Warm-batched sim_cycles/s from the checked-in trajectory file, or
    None when absent/unreadable. Deliberately not keyed on grid shape or
    tier: the checked-in baseline is always a full run and the smoke gate
    compares against it too (the loose ``--min-frac`` floor absorbs the
    cross-grid difference — without this, CI's smoke step could never arm)."""
    if not os.path.exists(BASELINE_PATH):
        return None
    try:
        with open(BASELINE_PATH) as f:
            blob = json.load(f)
    except (OSError, ValueError):
        return None
    for row in blob.get("rows", []):
        # current schema has one warm-batched row; the pre-retirement schema
        # carried a scheduler column — take its vectorized row
        if (row.get("path") == "batched (warm)"
                and row.get("scheduler", "vectorized") == "vectorized"):
            return float(row["sim_cycles/s"])
    return None


def run(length: int = 48, n_rows: int = 128, smoke: bool = False,
        min_frac: float = 0.3, profile: bool = False):
    if smoke:
        length, n_rows = 16, 64
    baseline = load_baseline()
    pts = make_grid(length=length, n_rows=n_rows)
    rows = []
    looped = None
    traces = None
    if not smoke:
        from repro.sweep.workloads import build_trace
        traces = [build_trace(pt) for pt in pts]
        with Timer() as t_loop:
            looped = [simulate(pt.scheme, tr, pt.n_rows, alpha=pt.alpha,
                               r=pt.r, n_cycles=pt.resolved_cycles(),
                               select_period=pt.select_period,
                               wq_hi=pt.wq_hi, wq_lo=pt.wq_lo,
                               queue_depth=pt.queue_depth)
                      for pt, tr in zip(pts, traces)]
        rows.append({"path": "looped", "wall_s": round(t_loop.s, 2),
                     "sim_cycles/s": round(_sim_cycles(looped) / t_loop.s, 1)})
    with Timer() as t_cold:
        batched = run_points(pts, traces=traces)
    with profile_trace("bench_cycles_warm", enabled=profile):
        with Timer() as t_warm:
            batched2 = run_points(pts, traces=traces)
    assert batched == batched2, "batched path is nondeterministic"
    identical = looped is None or batched == looped
    warm_tput = _sim_cycles(batched) / t_warm.s
    rows.append({"path": "batched (cold)", "wall_s": round(t_cold.s, 2),
                 "sim_cycles/s": round(_sim_cycles(batched) / t_cold.s, 1)})
    rows.append({"path": "batched (warm)", "wall_s": round(t_warm.s, 2),
                 "sim_cycles/s": round(warm_tput, 1)})

    print(f"\n== bench_cycles: {len(pts)}-point grid, length={length}, "
          f"n_rows={n_rows}{' [smoke]' if smoke else ''} ==")
    print(table(rows, ["path", "wall_s", "sim_cycles/s"]))
    ident = "IDENTICAL" if identical else "MISMATCH"
    print(f"per-point results across paths/repeats: {ident}")
    regressed = False
    if baseline is None:
        print("no comparable checked-in baseline — recording trajectory only")
    else:
        frac = warm_tput / baseline
        regressed = frac < min_frac
        print(f"warm batched {warm_tput:.1f} sim_cycles/s vs checked-in "
              f"baseline {baseline:.1f} ({frac:.2f}x, floor {min_frac:g}x) "
              f"-> {'FAIL' if regressed else 'PASS'}")
    # the repo-root copy IS the checked-in regression baseline — only a
    # PASSING FULL run may refresh it: a smoke run would replace the full
    # trajectory with an incomparable grid, and a regressed run would
    # ratchet the floor down to its own regressed number before exiting
    # nonzero (self-disarming the gate on the next run)
    emit("BENCH_cycle_throughput", rows, {
        "n_points": len(pts), "length": length, "n_rows": n_rows,
        "smoke": smoke, "identical": identical,
        "baseline_sim_cycles_per_s": baseline, "min_frac": min_frac,
        "regressed": regressed,
    }, root=not smoke and identical and not regressed,
        headline={"warm_sim_cycles_per_s": round(warm_tput, 1)},
        timings={"cold_s": t_cold.s, "warm_s": t_warm.s})
    return identical and not regressed


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--length", type=int, default=48)
    ap.add_argument("--n-rows", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid, batched-only (CI)")
    ap.add_argument("--min-frac", type=float, default=0.3,
                    help="fail below this fraction of the checked-in "
                         "warm-batched baseline")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the warm run in jax.profiler.trace "
                         "(writes experiments/profiles/)")
    args = ap.parse_args()
    clear_caches()
    ok = run(length=args.length, n_rows=args.n_rows, smoke=args.smoke,
             min_frac=args.min_frac, profile=args.profile)
    raise SystemExit(0 if ok else 1)
