"""Cycle-throughput benchmark: reference vs vectorized scheduler.

Evaluates the 16-point ``bench_sweep`` α×r grid (2 α × 2 r × 2 trace
generators × 2 seeds — one masked compiled program per scheduler) through
four pipelines:

  * scheduler ∈ {reference, vectorized} — the sequential greedy loops vs the
    compacted work-proportional builders (see docs/performance.md);
  * path ∈ {looped, batched} — one ``simulate`` compile+scan per point vs
    the ``repro.sweep`` engine's single vmapped program (batched also gets a
    warm repeat, where compile cost is amortized away).

Per-point results must be identical across all four (the scheduler
equivalence contract, enforced here and in tests/test_scheduler_equiv.py).
Reports simulated cycles/second and the vectorized-over-reference speedup;
the headline number is warm batched (the production configuration). Emits
``experiments/bench/BENCH_cycle_throughput.json`` plus a repo-root copy
(the per-commit perf trajectory collects root-level ``BENCH_*.json``).

``--smoke`` shrinks the grid and skips the looped pipelines — CI runs it on
every push and fails if the vectorized scheduler is slower than the
reference (speedup < 1).

Gate calibration: the full-run bar is 1.5× (was 3×). The r-mask refactor
left the vectorized warm path at its previous absolute throughput but made
the *reference* batched program ~2.5× faster (same executed cycle counts,
bit-identical per-point results — a compiler-level layout/fusion change),
so the ratio compressed from ~3.4× to ~2.4× without any vectorized
regression. The per-commit trajectory metric is the absolute warm batched
``sim_cycles/s``, recorded in the JSON.
"""
from __future__ import annotations

import argparse

from benchmarks.common import Timer, emit, table
from repro.sim.ramulator import simulate
from repro.sweep import run_points
from repro.sweep.engine import clear_caches
from benchmarks.bench_sweep import make_grid

SCHEDULERS = ("reference", "vectorized")


def _points(scheduler: str, length: int, n_rows: int):
    return [pt.replace(scheduler=scheduler)
            for pt in make_grid(length=length, n_rows=n_rows)]


def _sim_cycles(results) -> int:
    return sum(r.cycles for r in results)


def run(length: int = 48, n_rows: int = 128, smoke: bool = False,
        target: float = 1.5):
    if smoke:
        length, n_rows, target = 16, 64, 1.0
    rows = []
    results = {}
    wall = {}
    for sched in SCHEDULERS:
        pts = _points(sched, length, n_rows)
        traces = None
        if not smoke:
            from repro.sweep.workloads import build_trace
            traces = [build_trace(pt) for pt in pts]
            with Timer() as t_loop:
                looped = [simulate(pt.scheme, tr, pt.n_rows, alpha=pt.alpha,
                                   r=pt.r, n_cycles=pt.resolved_cycles(),
                                   select_period=pt.select_period,
                                   wq_hi=pt.wq_hi, wq_lo=pt.wq_lo,
                                   queue_depth=pt.queue_depth,
                                   scheduler=pt.scheduler)
                          for pt, tr in zip(pts, traces)]
            results[(sched, "looped")] = looped
            rows.append({"scheduler": sched, "path": "looped",
                         "wall_s": round(t_loop.s, 2),
                         "sim_cycles/s": round(_sim_cycles(looped) / t_loop.s, 1)})
        with Timer() as t_cold:
            batched = run_points(pts, traces=traces)
        with Timer() as t_warm:
            batched2 = run_points(pts, traces=traces)
        assert batched == batched2, "batched path is nondeterministic"
        results[(sched, "batched")] = batched
        wall[sched] = t_warm.s
        rows.append({"scheduler": sched, "path": "batched (cold)",
                     "wall_s": round(t_cold.s, 2),
                     "sim_cycles/s": round(_sim_cycles(batched) / t_cold.s, 1)})
        rows.append({"scheduler": sched, "path": "batched (warm)",
                     "wall_s": round(t_warm.s, 2),
                     "sim_cycles/s": round(_sim_cycles(batched) / t_warm.s, 1)})

    # scheduler equivalence: every pipeline returns the same per-point stats
    base = results[("reference", "batched")]
    identical = all(res == base for res in results.values())
    speedup = wall["reference"] / wall["vectorized"]
    for r in rows:
        if r["scheduler"] == "vectorized" and r["path"] == "batched (warm)":
            r["speedup_vs_reference"] = round(speedup, 2)

    n_pts = len(make_grid(length=length, n_rows=n_rows))
    print(f"\n== bench_cycles: {n_pts}-point grid, length={length}, "
          f"n_rows={n_rows}{' [smoke]' if smoke else ''} ==")
    print(table(rows, ["scheduler", "path", "wall_s", "sim_cycles/s",
                       "speedup_vs_reference"]))
    ident = "IDENTICAL" if identical else "MISMATCH"
    ok = identical and speedup >= target
    print(f"per-point results across schedulers/paths: {ident}")
    print(f"vectorized vs reference (batched warm): {speedup:.1f}x "
          f"(target >={target:g}x) -> {'PASS' if ok else 'FAIL'}")
    emit("BENCH_cycle_throughput", rows, {
        "n_points": n_pts, "length": length, "n_rows": n_rows,
        "smoke": smoke, "identical": identical,
        "speedup_vectorized_vs_reference": speedup, "target": target,
    }, root=True)
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--length", type=int, default=48)
    ap.add_argument("--n-rows", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid, batched-only, pass bar at 1x (CI)")
    ap.add_argument("--target", type=float, default=1.5)
    args = ap.parse_args()
    clear_caches()
    ok = run(length=args.length, n_rows=args.n_rows, smoke=args.smoke,
             target=args.target)
    raise SystemExit(0 if ok else 1)
