"""Render the §Dry-run and §Roofline tables in EXPERIMENTS.md from the
dry-run JSON artifacts (replaces the <!-- DRYRUN_TABLE --> and
<!-- ROOFLINE_TABLE --> markers)."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun")


def _load(mesh, tag=""):
    out = {}
    for p in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r["mesh"] == mesh and r.get("tag", "") == tag:
            out[(r["arch"], r["shape"])] = r
    return out


def dryrun_table() -> str:
    single = _load("pod16x16")
    multi = _load("pod2x16x16")
    lines = [
        "| arch | shape | 16×16 compile | peak live (GB/dev) | fits 16G | "
        "2×16×16 compile | coll counts (scan body) |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(single):
        s, m = single[key], multi.get(key)
        if s.get("status") == "skipped":
            lines.append(f"| {key[0]} | {key[1]} | skip | — | — | skip | "
                         f"{s['reason'][:48]} |")
            continue
        cc = s["scan_hlo"]["coll_counts"]
        ccs = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in sorted(cc.items()))
        mc = f"{m['compile_s']}s" if m and m.get("status") == "ok" else "—"
        lines.append(
            f"| {key[0]} | {key[1]} | {s['compile_s']}s "
            f"| {s['memory']['live_bytes']/1e9:.2f} "
            f"| {'✓' if s['fits_hbm_16g'] else '✗'} "
            f"| {mc} | {ccs} |")
    return "\n".join(lines)


def roofline_table() -> str:
    single = _load("pod16x16")
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
        "MODEL/HLO flops | roofline frac | one-line diagnosis |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    diag = {
        "memory": "activation/score traffic (see §Perf notes)",
        "collective": "per-layer cross-shard reductions",
        "compute": "matmul-bound (good)",
    }
    for key in sorted(single):
        s = single[key]
        if s.get("status") == "skipped":
            continue
        r = s.get("roofline")
        if not r:
            continue
        lines.append(
            f"| {key[0]} | {key[1]} | {r['t_compute_s']:.3f} "
            f"| {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} "
            f"| {r['dominant']} | {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_frac']:.4f} | {diag.get(r['dominant'], '')} |")
    return "\n".join(lines)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables rendered")


if __name__ == "__main__":
    main()
