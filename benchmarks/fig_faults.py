"""Degraded-availability gate: erasure-coded serving under dead banks.

Runs fig18/19/20-shaped workloads (banded / split-band / drifting-ramp
traces) at full coverage (α=1.0, r=0.25) with one data bank erased from
cycle 0 in every parity group, and renders the availability contrast the
fault model exists to demonstrate:

  * **scheme_i / scheme_iii** must serve **100% of reads** (zero unserved,
    zero lost writes) — every request to the dead bank routes through a
    parity option or parks into parity; the dead bank shows up only as
    ``fault_degraded_reads`` and ``dead_bank_cycles``.
  * **uncoded** has no redundancy: the dead bank's requests are permanently
    unserved (fail-fast dropped) — the row that shows what the coding buys.

Full coverage matters: a dynamically-coded point (α < 1) legitimately drops
reads of a bank that dies before its regions are coded, so the 100% gate is
stated — like the paper's availability claim — for pre-coded geometry.

The gate is enforced, not just printed: any coded row with unserved reads
(or any uncoded row without them) exits nonzero, so CI fails on an
availability regression. ``--smoke`` shrinks the geometry for the fast
tier.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import emit, table
from repro.sweep import SweepPoint, run_sweep

CODED = ("scheme_i", "scheme_iii")
ALPHA, R = 1.0, 0.25           # full coverage: every region pre-coded


def dead_banks(scheme: str) -> tuple:
    """One dead data bank per parity group (union-find over shared
    parities); the uncoded contrast kills bank 0."""
    from repro.core.codes import get_tables

    t = get_tables(scheme)
    if not t.scheme.members:
        return (0,)
    parent = list(range(t.n_data))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for ms in t.scheme.members:
        for m in ms[1:]:
            parent[find(m)] = find(ms[0])
    return tuple(sorted({find(b) for b in range(t.n_data)}))


def _suite_points(suite: str, scheme: str, *, n_rows: int, length: int,
                  seed: int) -> list:
    from repro.core.codes import get_tables
    from repro.sweep.workloads import drift_label

    nd = get_tables(scheme).n_data
    spec = tuple(("bank", b, 0) for b in dead_banks(scheme))
    base = SweepPoint(scheme=scheme, alpha=ALPHA, r=R, n_rows=n_rows,
                      n_cores=8, n_banks=nd, n_data=nd, length=length,
                      seed=seed, write_frac=0.3, select_period=32,
                      faults=spec, suite=f"fig_faults/{suite}")
    if suite == "fig18":            # dedup-like banded trace
        return [base.replace(trace="banded")]
    if suite == "fig19":            # split-band augmentation
        return [base.replace(trace="split",
                             trace_kwargs=(("n_bands", 8),))]
    if suite == "fig20":            # drifting-ramp bands
        drift = 0.25
        return [base.replace(trace="ramp", label=drift_label(drift),
                             trace_kwargs=(("drift_total",
                                            nd * n_rows * drift),))]
    raise ValueError(suite)


def run(n_rows: int = 128, length: int = 96, seed: int = 0,
        smoke: bool = False):
    if smoke:
        n_rows, length = 64, 48
    pts = []
    for suite in ("fig18", "fig19", "fig20"):
        for scheme in CODED + ("uncoded",):
            pts += _suite_points(suite, scheme, n_rows=n_rows,
                                 length=length, seed=seed)
    rs = run_sweep(pts)
    rows, violations = [], []
    for rec in rs:
        pt, res = rec.point, rec.result
        reads = res.served_reads + res.unserved_reads
        avail = 100.0 * res.served_reads / max(reads, 1)
        rows.append({
            "suite": pt.suite.split("/")[1], "scheme": pt.scheme,
            "dead_banks": ",".join(str(b) for b in dead_banks(pt.scheme)),
            "reads_served": res.served_reads,
            "unserved": res.unserved_reads,
            "lost_writes": res.lost_writes,
            "degraded_fault": res.fault_degraded_reads,
            "dead_cycles": res.dead_bank_cycles,
            "availability_%": round(avail, 2),
        })
        if pt.scheme in CODED and (res.unserved_reads or res.lost_writes):
            violations.append(
                f"{pt.suite} {pt.scheme}: {res.unserved_reads} unserved / "
                f"{res.lost_writes} lost writes (must be 0)")
        if pt.scheme == "uncoded" and res.unserved_reads == 0:
            violations.append(
                f"{pt.suite} uncoded: 0 unserved reads with a dead bank — "
                "the contrast row lost its contrast")
    print("\n== Fault gate: availability with dead banks "
          f"(α={ALPHA}, r={R}) ==")
    print(table(rows, list(rows[0].keys())))
    emit("fig_faults", rows, {"alpha": ALPHA, "r": R, "n_rows": n_rows,
                              "length": length, "smoke": smoke})
    if violations:
        print("\nAVAILABILITY GATE FAILED:")
        for v in violations:
            print(f"  - {v}")
        sys.exit(1)
    print("\navailability gate OK: coded schemes served every read; "
          "uncoded did not")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-rows", type=int, default=128)
    ap.add_argument("--length", type=int, default=96)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(n_rows=args.n_rows, length=args.length, smoke=args.smoke)
