"""Shared benchmark utilities: result table formatting + JSON artifacts."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def emit(name: str, rows: List[Dict[str, Any]], meta: Dict[str, Any] = None):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump({"name": name, "meta": meta or {}, "rows": rows}, f,
                  indent=1, default=float)
    return path


def table(rows: List[Dict[str, Any]], cols: List[str]) -> str:
    if not rows:
        return "(empty)"
    widths = {c: max(len(c), max(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(
        " | ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols) for r in rows
    )
    return f"{head}\n{sep}\n{body}"


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
