"""Shared benchmark utilities: result table formatting + JSON artifacts."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
ART_DIR = os.path.join(REPO_ROOT, "experiments", "bench")


def emit(name: str, rows: List[Dict[str, Any]], meta: Dict[str, Any] = None,
         root: bool = False):
    """Write ``experiments/bench/<name>.json``; with ``root=True`` also a
    repo-root copy (the per-commit perf trajectory collects root-level
    ``BENCH_*.json`` files — without the copy it records nothing)."""
    os.makedirs(ART_DIR, exist_ok=True)
    blob = {"name": name, "meta": meta or {}, "rows": rows}
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(blob, f, indent=1, default=float)
    if root:
        with open(os.path.join(REPO_ROOT, f"{name}.json"), "w") as f:
            json.dump(blob, f, indent=1, default=float)
    return path


def mirror_bench_to_root():
    """Copy every ``experiments/bench/BENCH_*.json`` to the repo root (the
    trajectory contract: perf artifacts live at the root, named BENCH_*)."""
    import glob
    import shutil
    copied = []
    for src in sorted(glob.glob(os.path.join(ART_DIR, "BENCH_*.json"))):
        dst = os.path.join(REPO_ROOT, os.path.basename(src))
        shutil.copyfile(src, dst)
        copied.append(dst)
    return copied


def table(rows: List[Dict[str, Any]], cols: List[str]) -> str:
    if not rows:
        return "(empty)"
    widths = {c: max(len(c), max(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(
        " | ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols) for r in rows
    )
    return f"{head}\n{sep}\n{body}"


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
