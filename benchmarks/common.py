"""Shared benchmark utilities: result table formatting + JSON artifacts.

Artifact contract (docs/observability.md):

* every blob carries a ``manifest`` block (``repro.obs.runlog``): git SHA,
  device topology, versions, argv — ``scripts/check_bench_manifests.py``
  fails CI when a root ``BENCH_*.json`` lacks one;
* root-level ``BENCH_*.json`` files keep a ``history`` list — one
  ``{ts, git_sha, headline}`` entry per emitting run, appended (never
  overwritten) so the perf trajectory survives re-runs on one commit tree;
* ``profile_trace`` wraps a benchmark's warm region in
  ``jax.profiler.trace`` for the ``--profile`` flags.
"""
from __future__ import annotations

import contextlib
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
ART_DIR = os.path.join(REPO_ROOT, "experiments", "bench")
PROFILE_DIR = os.path.join(REPO_ROOT, "experiments", "profiles")
HISTORY_CAP = 500   # root history entries kept (newest last)


def _runlog():
    """Lazy ``repro.obs.runlog`` import — benchmarks run as scripts, so
    ``src`` may not be on the path yet."""
    try:
        from repro.obs import runlog
    except ImportError:
        sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
        from repro.obs import runlog
    return runlog


def emit(name: str, rows: List[Dict[str, Any]],
         meta: Optional[Dict[str, Any]] = None, root: bool = False,
         headline: Optional[Dict[str, Any]] = None,
         timings: Optional[Dict[str, float]] = None):
    """Write ``experiments/bench/<name>.json``; with ``root=True`` also
    merge into the repo-root copy (the per-commit perf trajectory collects
    root-level ``BENCH_*.json`` files — without it it records nothing).

    ``headline`` is the one-line summary recorded in the root ``history``
    (e.g. ``{"warm_tput": 1.2e6}``); ``timings`` lands in the manifest."""
    os.makedirs(ART_DIR, exist_ok=True)
    manifest = _runlog().run_manifest(timings=timings)
    blob = {"name": name, "meta": meta or {}, "manifest": manifest,
            "headline": headline or {}, "rows": rows}
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(blob, f, indent=1, default=float)
    if root:
        _write_root(name, blob)
    return path


def _load_history(path: str) -> List[Dict[str, Any]]:
    try:
        with open(path) as f:
            hist = json.load(f).get("history", [])
        return hist if isinstance(hist, list) else []
    except (OSError, ValueError):
        return []


def _write_root(name: str, blob: Dict[str, Any]) -> str:
    """Replace the root blob's rows but APPEND to its run history.

    The history entry is keyed by the manifest's ``created_unix`` so
    mirroring an already-rooted blob (``mirror_bench_to_root`` after an
    ``emit(root=True)``) dedups instead of double-counting the run."""
    path = os.path.join(REPO_ROOT, f"{name}.json")
    history = _load_history(path)
    man = blob.get("manifest", {})
    entry = {"ts": man.get("created_unix"), "git_sha": man.get("git_sha"),
             "headline": blob.get("headline") or {}}
    if not any(h.get("ts") == entry["ts"] for h in history):
        history.append(entry)
    history = history[-HISTORY_CAP:]
    with open(path, "w") as f:
        json.dump({**blob, "history": history}, f, indent=1, default=float)
    return path


def mirror_bench_to_root():
    """Merge every ``experiments/bench/BENCH_*.json`` into the repo root
    (the trajectory contract: perf artifacts live at the root, named
    BENCH_*). Root ``history`` is preserved and appended to, never
    clobbered — this used to be a plain copy, which erased it."""
    import glob
    merged = []
    for src in sorted(glob.glob(os.path.join(ART_DIR, "BENCH_*.json"))):
        with open(src) as f:
            blob = json.load(f)
        name = os.path.splitext(os.path.basename(src))[0]
        merged.append(_write_root(name, blob))
    return merged


@contextlib.contextmanager
def profile_trace(name: str, enabled: bool = True):
    """Wrap a benchmark region in ``jax.profiler.trace`` when ``enabled``.

    Yields the profile directory (``experiments/profiles/<name>-<stamp>``)
    or None when disabled — so call sites stay one ``with`` either way."""
    if not enabled:
        yield None
        return
    import jax
    out = os.path.join(PROFILE_DIR, f"{name}-{time.strftime('%Y%m%d-%H%M%S')}")
    os.makedirs(out, exist_ok=True)
    with jax.profiler.trace(out):
        yield out
    print(f"profile written to {out}")


def table(rows: List[Dict[str, Any]], cols: List[str]) -> str:
    if not rows:
        return "(empty)"
    widths = {c: max(len(c), max(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(
        " | ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols) for r in rows
    )
    return f"{head}\n{sep}\n{body}"


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
