"""Coded-embedding load-balance benchmark: bank port-cycles per lookup batch
for plain striping vs the coded (degraded-read) planner, under uniform and
Zipf-skewed token mixes.

The paper's Fig 3 story on the vocab table: a batch whose hot rows
concentrate on one bank serializes on that bank's port; the parity path
serves every second conflicting lookup from the pair sibling + parity."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, table
from repro.models.embedding import _plan_use_parity


def _port_cycles(bank_of: np.ndarray, use_par: np.ndarray, nb: int):
    """Serialized port cycles to serve one batch of lookups."""
    direct = np.zeros(nb, np.int64)
    parity = np.zeros(nb // 2, np.int64)
    sib = np.zeros(nb, np.int64)
    for b, up in zip(bank_of, use_par):
        if up:
            parity[b // 2] += 1
            sib[b ^ 1] += 1
        else:
            direct[b] += 1
    return max((direct + sib).max(), parity.max())


def run(nb: int = 8, batch: int = 4096, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    for dist, make in (
        ("uniform", lambda: rng.integers(0, 1 << 16, batch)),
        ("zipf1.2", lambda: rng.zipf(1.2, batch) - 1),
        ("zipf1.05", lambda: rng.zipf(1.05, batch) - 1),
        ("hot_bank", lambda: rng.integers(0, 1 << 12, batch) * nb),  # bank 0
    ):
        toks = make()
        bank_of = (toks % nb).astype(np.int32)
        use_par = np.asarray(_plan_use_parity(jnp.asarray(bank_of), nb))
        un = _port_cycles(bank_of, np.zeros_like(use_par), nb)
        co = _port_cycles(bank_of, use_par, nb)
        rows.append({
            "distribution": dist, "batch": batch,
            "uncoded_port_cycles": int(un), "coded_port_cycles": int(co),
            "speedup": round(un / max(co, 1), 2),
            "degraded_frac": round(float(use_par.mean()), 3),
        })
    print("\n== Coded vocab-embedding lookup balance ==")
    print(table(rows, list(rows[0].keys())))
    emit("bench_embedding", rows)
    return rows


if __name__ == "__main__":
    run()
