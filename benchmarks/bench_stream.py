"""Streamed-replay throughput: requests/sec vs single-shot on a long trace.

Replays one banded trace through (a) single-shot ``run()`` — the whole trace
materialized as one device array, the path whose device footprint grows with
trace length — and (b) ``repro.traces.stream.stream_replay`` with a fixed
``chunk_len`` staging buffer. Streamed results must be bit-identical to
single-shot (the chunked-replay contract, enforced here and in
tests/test_traces.py); the interesting number is the streaming overhead —
host staging + the per-chunk device round trip — which is what a
longer-than-memory trace costs over the (impossible) single-shot ideal.

Emits ``experiments/bench/BENCH_stream_throughput.json`` plus a repo-root
copy (the per-commit perf trajectory collects root-level ``BENCH_*.json``).

``--smoke`` shrinks the trace for CI and fails only on a result mismatch;
the full run also fails if streaming drops below ``--floor`` of single-shot
throughput. ``--requests N`` scales the trace (the nightly million-request
soak lives in tests/test_traces.py::test_stream_million_requests).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Timer, emit, profile_trace, table


def run(length: int = 2048, chunk_len: int = 256, n_cores: int = 8,
        smoke: bool = False, floor: float = 0.25, profile: bool = False):
    if smoke:
        length, chunk_len = 128, 32
    from repro.core.codes import get_tables
    from repro.core.state import make_params, make_tunables
    from repro.core.system import CodedMemorySystem, drain_bound
    from repro.sim.trace import TraceSpec, banded_trace
    from repro.traces import chunk_iter, stream_replay, strip_windows

    n_banks, n_rows = 8, 512
    spec = TraceSpec(n_cores=n_cores, length=length, n_banks=n_banks,
                     n_rows=n_rows, seed=0)
    trace = banded_trace(spec)
    n_requests = int(np.asarray(trace.valid).sum())
    t = get_tables("scheme_i")
    p = make_params(t, n_rows=n_rows, alpha=0.25, r=0.05)
    sys_ = CodedMemorySystem(t, p, n_cores=n_cores,
                             tunables=make_tunables(select_period=256))
    bound = drain_bound(n_cores, length)

    rows = []
    with Timer() as t_cold:
        single = sys_.run(trace, bound)
    with Timer() as t_single:
        single = sys_.run(trace, bound)
    rows.append({"path": "single-shot (warm)", "wall_s": round(t_single.s, 2),
                 "requests/s": round(n_requests / t_single.s, 1)})

    with Timer() as t_scold:
        streamed = stream_replay(sys_, trace, chunk_len=chunk_len)
    with profile_trace("bench_stream_warm", enabled=profile):
        with Timer() as t_stream:
            streamed = stream_replay(sys_, trace, chunk_len=chunk_len)
    rows.append({"path": f"streamed chunk={chunk_len} (warm)",
                 "wall_s": round(t_stream.s, 2),
                 "requests/s": round(n_requests / t_stream.s, 1)})
    with Timer() as t_chunks:
        streamed2 = stream_replay(sys_, chunk_iter(trace, chunk_len),
                                  chunk_len=chunk_len)
    rows.append({"path": "streamed chunked-source (warm)",
                 "wall_s": round(t_chunks.s, 2),
                 "requests/s": round(n_requests / t_chunks.s, 1)})

    identical = (strip_windows(streamed) == single
                 and strip_windows(streamed2) == single)
    ratio = t_single.s / t_stream.s
    print(f"\n== bench_stream: {n_requests} requests, length={length}, "
          f"chunk_len={chunk_len}{' [smoke]' if smoke else ''} ==")
    print(table(rows, ["path", "wall_s", "requests/s"]))
    ident = "IDENTICAL" if identical else "MISMATCH"
    print(f"streamed vs single-shot results: {ident}")
    print(f"streamed throughput = {ratio:.2f}x single-shot "
          f"(floor {floor:g}x{' waived in smoke' if smoke else ''})")
    ok = identical and (smoke or ratio >= floor)
    emit("BENCH_stream_throughput", rows, {
        "n_requests": n_requests, "length": length, "chunk_len": chunk_len,
        "n_cores": n_cores, "smoke": smoke, "identical": identical,
        "streamed_vs_single_shot": ratio, "floor": floor,
        "cold_single_s": t_cold.s, "cold_streamed_s": t_scold.s,
        "windows": len(streamed.window_read_latency),
    }, root=True,
        headline={"streamed_requests_per_s": round(n_requests / t_stream.s, 1),
                  "streamed_vs_single_shot": round(ratio, 3)},
        timings={"single_warm_s": t_single.s, "streamed_warm_s": t_stream.s})
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--length", type=int, default=2048,
                    help="trace length per core")
    ap.add_argument("--chunk-len", type=int, default=256)
    ap.add_argument("--n-cores", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace, identity check only (CI)")
    ap.add_argument("--floor", type=float, default=0.25,
                    help="min streamed/single-shot throughput ratio")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the warm streamed run in jax.profiler.trace "
                         "(writes experiments/profiles/)")
    args = ap.parse_args()
    ok = run(length=args.length, chunk_len=args.chunk_len,
             n_cores=args.n_cores, smoke=args.smoke, floor=args.floor,
             profile=args.profile)
    raise SystemExit(0 if ok else 1)
