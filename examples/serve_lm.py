"""Serving example: continuous batching + the coded banked KV cache.

Part 1 serves a stream of requests through the Server (prefill → batched
decode slots → drain). Part 2 shows the paper's technique on the KV store
directly: pages striped over single-port banks, parity banks turning bank
conflicts into parallel degraded reads — with the port-cycle counts printed.

  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import lm
from repro.runtime import kvbank as kb
from repro.runtime.server import Request, ServeConfig, Server


def serve_demo():
    cfg = get_config("yi-6b").reduced()
    params = lm.init_params(cfg, jax.random.key(0), max_seq=256)
    sc = ServeConfig(n_slots=4, max_prompt=32, max_seq=128, max_new_tokens=16)
    srv = Server(cfg, sc, params)
    reqs = [Request(rid=i, prompt=[(3 * i + j) % 200 + 1 for j in range(4 + i % 5)])
            for i in range(10)]
    t0 = time.time()
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in reqs)
    print(f"[server] {len(reqs)} requests, {n_tok} tokens, "
          f"{srv.steps_run} batched decode steps, {n_tok/dt:.0f} tok/s (CPU)")
    assert all(r.done for r in reqs)


def kvbank_demo():
    """A continuous batch over a CHURNED paged KV pool (hours of serving:
    pages freed and reallocated wherever the free list points). Live pages
    scatter over the banks, so per-step bank loads are imbalanced — the
    paper's bank conflict. Parity banks serve the hot banks' overflow via
    degraded reads."""
    import numpy as np
    rng = np.random.default_rng(1)
    lengths = [2048, 1024, 512, 256, 128, 64, 32, 16]
    b = len(lengths)
    cfg = kb.KVBankConfig(n_banks=8, page=16, pool_pages=640, max_pages=160)
    st = kb.init_state(cfg, batch=b, n_kv=2, head_dim=32, dtype=jnp.bfloat16)
    n_live = sum(-(-L // cfg.page) for L in lengths)
    phys = rng.choice(cfg.pool_pages, n_live, replace=False)
    table = np.full((b, cfg.max_pages), -1, np.int64)
    c = 0
    for i, L in enumerate(lengths):
        npg = -(-L // cfg.page)
        table[i, :npg] = phys[c:c + npg]
        c += npg
    st = st._replace(page_table=jnp.asarray(table, jnp.int32),
                     length=jnp.asarray(lengths, jnp.int32))
    st = kb.recode(cfg, st)                 # ReCoding unit: fresh parities
    plan = kb.plan_reads(cfg, st)
    un, co = int(plan.uncoded_cycles), int(plan.coded_cycles)
    print(f"[kvbank] batch={b} churned pool over {cfg.n_banks} banks: "
          f"uncoded={un} port-cycles, coded={co} port-cycles "
          f"({un/co:.2f}x, {int(plan.use_parity.sum())} degraded page reads)")
    assert co < un


if __name__ == "__main__":
    serve_demo()
    kvbank_demo()
    print("OK")
