"""Quickstart: the paper's coded memory system in ~40 lines.

Builds a Scheme-I coded memory over 8 single-port banks, runs a dedup-like
multi-core trace through the controller, and compares against the uncoded
baseline — the in-miniature version of the paper's Fig 18 experiment.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim.ramulator import compare_schemes, cycle_reduction
from repro.sim.trace import TraceSpec, banded_trace


def main():
    # 8 cores hammering 2 hot address bands (PARSEC dedup structure, Fig 15)
    spec = TraceSpec(n_cores=8, length=64, n_banks=8, n_rows=256,
                     write_frac=0.3, seed=0)
    trace = banded_trace(spec)

    results = compare_schemes(
        trace, n_rows=256, alpha=1.0, r=0.25, n_cycles=512,
        schemes=("uncoded", "scheme_i", "scheme_ii", "scheme_iii"),
    )
    base = results["uncoded"]
    print(f"{'scheme':12s} {'cycles':>7s} {'reduction':>10s} {'degraded':>9s} "
          f"{'parked':>7s} {'read lat':>9s}")
    for name, res in results.items():
        red = cycle_reduction(base, res)
        print(f"{name:12s} {res.cycles:7d} {100*red:9.1f}% "
              f"{res.degraded_reads:9d} {res.parked_writes:7d} "
              f"{res.avg_read_latency:9.2f}")
    assert results["scheme_i"].cycles < base.cycles, "coding must win here"
    print("\ncoded memory served the same workload in fewer memory cycles —")
    print("idle banks + XOR parities acted as extra read/write ports.")


if __name__ == "__main__":
    main()
