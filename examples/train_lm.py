"""End-to-end training driver: a ~100M-param qwen-family model (coded vocab
embedding enabled) trained for a few hundred steps on the synthetic Markov
stream, with checkpointing, an injected fault + automatic recovery, and a
learning-curve printout.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import get_config
from repro.launch.mesh import make_debug_mesh
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import FaultPlan, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    # ~100M params: 12L × d512 × ff2048, 32k vocab (coded embedding banks)
    cfg = dataclasses.replace(
        get_config("qwen2.5-3b"),
        name="qwen-100m", n_layers=12, d_model=512, n_heads=8, n_kv=2,
        head_dim=64, d_ff=2048, vocab=32_000, coded_embedding=True,
    )
    n = cfg.n_params()
    print(f"model: {cfg.name} ({n/1e6:.0f}M params, coded vocab embedding)")

    tc = TrainConfig(steps=args.steps, log_every=20, ckpt_every=100,
                     ckpt_dir=args.ckpt, global_batch=args.batch,
                     seq_len=args.seq, remat=True)
    opt = OptConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps)
    tr = Trainer(cfg, tc, make_debug_mesh(1, 1), opt)

    # inject a fault mid-run to demo checkpoint/restart recovery
    out = tr.run(fault_plan=FaultPlan([args.steps // 2 + 7]))
    losses = [m["loss"] for m in tr.metrics_log]
    print(f"\nloss: start={losses[0]:.3f} "
          f"mid={losses[len(losses)//2]:.3f} final={losses[-1]:.3f}")
    print(f"events: {out['events']}")
    assert losses[-1] < losses[0] - 0.5, "model should learn the Markov chain"
    print("OK — loss dropped through a fault + restore cycle.")


if __name__ == "__main__":
    main()
