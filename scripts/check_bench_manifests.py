#!/usr/bin/env python3
"""CI gate: every root ``BENCH_*.json`` must carry a run manifest.

The perf-trajectory files at the repo root are only useful if each blob
says what produced it (commit, devices, versions — the ``manifest`` block
``benchmarks/common.emit`` attaches, schema in docs/observability.md).
This check fails when any root ``BENCH_*.json`` is missing the block or
the block lacks a ``git_sha``, so a regression in ``emit`` (or a
hand-edited artifact) cannot silently strip provenance from the trajectory.

Usage: ``python scripts/check_bench_manifests.py [repo_root]`` — exits 1
listing offenders. Importable: ``check(repo_root) -> list[str]``.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import List

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def check(repo_root: str = REPO_ROOT) -> List[str]:
    """Return one human-readable problem per offending root BENCH blob."""
    problems: List[str] = []
    paths = sorted(glob.glob(os.path.join(repo_root, "BENCH_*.json")))
    if not paths:
        return [f"no BENCH_*.json files at {repo_root} (trajectory empty?)"]
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                blob = json.load(f)
        except (OSError, ValueError) as e:
            problems.append(f"{name}: unreadable ({e})")
            continue
        man = blob.get("manifest")
        if not isinstance(man, dict):
            problems.append(f"{name}: missing 'manifest' block "
                            "(benchmarks/common.emit attaches it)")
        elif not man.get("git_sha"):
            problems.append(f"{name}: manifest has no 'git_sha'")
        if not isinstance(blob.get("history"), list):
            problems.append(f"{name}: missing 'history' list "
                            "(root blobs append one entry per run)")
    return problems


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = argv[0] if argv else REPO_ROOT
    problems = check(root)
    if problems:
        print("bench manifest check FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    n = len(glob.glob(os.path.join(root, "BENCH_*.json")))
    print(f"bench manifest check passed ({n} root BENCH blobs)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
