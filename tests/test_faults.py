"""repro.faults: compile gating, plan grammar, chaos conformance against
the NumPy golden model, the erasure-tolerance matrix, online rebuild, and
the degraded-availability gate.

The contract mirrors the telemetry one (docs/observability.md): with
``MemParams.faults`` off the ``MemState.fault`` leaf is ``None`` and the
compiled program is bit-identical to one built before faults existed; with
it on, every fault rule (fail-fast drops, degraded serving, sticky parked
writes, rebuild sweep) is re-derived independently by ``repro.oracle`` and
checked for bit equality on every state leaf under randomized fault storms.
"""
import numpy as np
import pytest

import jax

from conftest import assert_state_matches_oracle, oracle_twin, rand_trace
from repro.core.codes import get_tables
from repro.core.state import make_params, make_tunables
from repro.core.system import CodedMemorySystem, drain_bound
from repro.faults import FaultPlan, FaultState, plan_from_spec
from repro.traces.stream import strip_windows

SCHEMES = ["scheme_i", "scheme_ii", "scheme_iii", "replication_2", "uncoded"]


def _system(scheme="scheme_i", n_rows=32, alpha=1.0, r=0.25, n_cores=3,
            faults=True, **kw):
    t = get_tables(scheme)
    p = make_params(t, n_rows=n_rows, alpha=alpha, r=r, recode_cap=8,
                    faults=faults, **kw)
    return CodedMemorySystem(t, p, n_cores=n_cores,
                             tunables=make_tunables(select_period=16))


# one compiled faulted system per scheme under chaos (shared jit caches)
_CHAOS_SYS = {
    "scheme_i": _system("scheme_i", telemetry=True),
    "scheme_iii": _system("scheme_iii", alpha=0.25, r=0.125, telemetry=True),
}


# ------------------------------------------------------------- plan grammar
def test_plan_grammar():
    plan = plan_from_spec((("bank", 2, 5, 60), ("bank", 0, 3),
                           ("stutter", 1, 7, 3), ("stutter", 9, 5)),
                          n_data=8, n_ports=20)
    assert plan.bank_faults == ((2, 5, 60), (0, 3, -1))
    assert plan.stutters == ((1, 7, 3), (9, 5, 0))
    fail, rec, per, ph = plan.schedule_arrays()
    NEVER = np.iinfo(np.int32).max
    assert fail[2] == 5 and rec[2] == 60
    assert fail[0] == 3 and rec[0] == NEVER
    assert (fail[[1, 3, 4, 5, 6, 7]] == NEVER).all()
    assert per[1] == 7 and ph[1] == 3 and per[9] == 5 and ph[9] == 0
    assert plan_from_spec((), 8, 20) is None
    assert plan_from_spec(None, 8, 20) is None


def test_plan_validation():
    with pytest.raises(ValueError, match="out of range"):
        FaultPlan(8, 20, bank_faults=((8, 0, -1),))
    with pytest.raises(ValueError, match="recover_at"):
        FaultPlan(8, 20, bank_faults=((1, 10, 5),))
    with pytest.raises(ValueError, match="twice"):
        FaultPlan(8, 20, bank_faults=((1, 0, -1), (1, 4, -1)))
    with pytest.raises(ValueError, match="phase"):
        FaultPlan(8, 20, stutters=((0, 4, 4),))
    with pytest.raises(ValueError, match="unknown fault spec"):
        plan_from_spec((("flood", 1, 2),), 8, 20)
    with pytest.raises(ValueError, match="faults"):
        _system(faults=False).init(
            fault_plan=FaultPlan(8, 20, bank_faults=((0, 0, -1),)))


# -------------------------------------------------------- compile gating
def test_faults_off_state_has_no_fault_leaf():
    """Faults off ⇒ ``MemState.fault`` is None: the pytree flattens to the
    same leaves as before the subsystem existed, so every pre-fault
    compiled program (and checkpoint) stays valid bit for bit."""
    sys_off = _system(faults=False)
    st = sys_off.init()
    assert st.mem.fault is None
    assert not any("fault" in jax.tree_util.keystr(path)
                   for path, _ in
                   jax.tree_util.tree_flatten_with_path(st)[0])
    # faults on with no plan: the leaf carries the never-fails schedule
    st_on = _system(faults=True).init()
    assert isinstance(st_on.mem.fault, FaultState)


def test_faults_compiled_in_but_quiet_is_inert():
    """A faults-on program running the no-fault schedule produces exactly
    the faults-off results — the hooks are value-transparent when nothing
    fails, not just absent when compiled out."""
    rng = np.random.default_rng(21)
    trace = rand_trace(rng, 3, 12, 8, 32)
    cycles = drain_bound(3, 12)
    res_off = _system(faults=False).run(trace, cycles)
    res_on = _system(faults=True).run(trace, cycles)
    assert res_off == res_on
    assert res_on.unserved_reads == 0 and res_on.lost_writes == 0
    assert res_on.fault_degraded_reads == 0 and res_on.dead_bank_cycles == 0


def test_faults_off_sweep_partitioning(sweep_compile_count):
    """``faults=()`` points batch exactly as before (one program per static
    signature; the empty spec adds no partition), and a faulted point is a
    genuinely different program."""
    from repro.sweep import SweepPoint, grid, partition, run_points

    base = SweepPoint(scheme="scheme_i", alpha=1.0, r=0.25, n_rows=32,
                      n_cores=3, n_banks=8, length=10, select_period=16,
                      recode_cap=8)
    pts = grid(base, seed=(0, 1, 2))
    assert len(partition(pts)) == 1
    faulted = base.replace(faults=(("bank", 0, 0),))
    assert len(partition(pts + [faulted])) == 2
    before = sweep_compile_count()
    run_points(pts)
    assert sweep_compile_count() - before == 1


def test_faults_off_stream_replay_identity():
    """fig18-style point, faults off: chunked stream replay still equals
    the single-shot engine bit for bit (the fault plumbing in the chunk
    driver is inert without a plan)."""
    from repro.sweep import SweepPoint, run_points
    from repro.sweep.workloads import build_trace
    from repro.traces.stream import stream_replay_points

    pts = [SweepPoint(scheme="scheme_i", alpha=0.25, r=0.05, n_rows=32,
                      n_cores=3, n_banks=8, length=10, select_period=16,
                      recode_cap=8, seed=s) for s in (0, 1)]
    traces = [build_trace(pt) for pt in pts]
    want = run_points(pts, traces=traces)
    got = stream_replay_points(pts, traces, chunk_len=4)
    assert [strip_windows(g) for g in got] == want


# ------------------------------------------------------- chaos conformance
def _storm_plan(rng, sys_):
    """A randomized fault storm: 1-2 bank erasures (possibly recovering),
    0-2 port stutters."""
    n_data, n_ports = sys_.p.n_data, sys_.tables.n_ports
    banks = rng.permutation(n_data)[: rng.integers(1, 3)]
    spec = []
    for b in banks:
        fail = int(rng.integers(0, 40))
        rec = int(rng.integers(fail + 1, fail + 60)) \
            if rng.random() < 0.6 else -1
        spec.append(("bank", int(b), fail, rec))
    for q in rng.permutation(n_ports)[: rng.integers(0, 3)]:
        per = int(rng.integers(2, 9))
        spec.append(("stutter", int(q), per, int(rng.integers(0, per))))
    return tuple(spec)


def check_storm_conformance(seed, scheme):
    """Lockstep production vs oracle under a random fault storm: every
    array/scalar/telemetry/fault leaf bit-equal at several horizons, and
    the SimResult availability aggregates equal both the fault leaf and
    the telemetry planes."""
    from repro.obs.planes import snapshot

    sys_ = _CHAOS_SYS[scheme]
    om = oracle_twin(sys_)
    rng = np.random.default_rng(seed)
    spec = _storm_plan(rng, sys_)
    plan = plan_from_spec(spec, sys_.p.n_data, sys_.tables.n_ports)
    trace = rand_trace(rng, sys_.n_cores, 12, sys_.p.n_data, sys_.p.n_rows,
                       write_frac=0.45)
    st = sys_.init(fault_plan=plan)
    ost = om.init_state(fault_plan=plan)
    tr_np = tuple(np.asarray(x) for x in trace)
    label = f"{scheme} seed={seed} spec={spec}"
    for cyc in range(120):
        st, _ = sys_.cycle_fn(st, trace)
        om.cycle(ost, tr_np)
        if cyc in (20, 60):
            assert_state_matches_oracle(st, ost, f"{label} @{cyc}")
    assert_state_matches_oracle(st, ost, label)

    res = sys_.summarize(st)
    assert strip_windows(res) == om.result(ost), label
    # aggregates: SimResult == fault leaf == telemetry planes
    f = jax.device_get(st.mem.fault)
    assert res.unserved_reads == int(f.unserved_reads)
    assert res.lost_writes == int(f.lost_writes)
    assert res.fault_degraded_reads == int(f.fault_degraded)
    assert res.dead_bank_cycles == int(np.asarray(f.dead_cycles,
                                                  np.int64).sum())
    snap = snapshot(st)
    assert snap.fault_degraded_reads() == res.fault_degraded_reads
    assert snap.dead_bank_cycles() == res.dead_bank_cycles
    assert snap.degraded_reads() == res.degraded_reads


@pytest.mark.parametrize("scheme", ["scheme_i", "scheme_iii"])
def test_fault_storm_conformance_seeded(scheme):
    """Deterministic chaos anchor (runs with or without hypothesis)."""
    check_storm_conformance(101, scheme)
    check_storm_conformance(102, scheme)


try:
    from hypothesis import given, settings, strategies as hst

    @settings(max_examples=6, deadline=None)
    @given(hst.integers(0, 2**31 - 1),
           hst.sampled_from(["scheme_i", "scheme_iii"]))
    def test_fault_storm_conformance_hypothesis(seed, scheme):
        """Property: for ANY fault storm the production scheduler equals
        the golden model on every state leaf, bit for bit."""
        check_storm_conformance(seed, scheme)
except ImportError:                                       # pragma: no cover
    pass  # the seeded anchor above still runs


# -------------------------------------------------- erasure-tolerance matrix
def _brute_force_recoverable(scheme, lost, rng):
    """Independent value-level check: materialize random bank values and
    the scheme's parity values, then try to reconstruct every lost bank
    from surviving banks + parities under the controller's single-decode
    serving rule. Returns True iff every lost bank's value comes back
    exactly."""
    lost = set(lost)
    vals = rng.integers(1, 1 << 30, scheme.n_data)
    parities = [int(np.bitwise_xor.reduce(vals[list(ms)]))
                for ms in scheme.members]
    for b in lost:
        ok = False
        for j, ms in enumerate(scheme.members):
            if b not in ms or (set(ms) - {b}) & lost:
                continue
            sibs = [m for m in ms if m != b]
            decoded = parities[j]
            for s in sibs:
                decoded ^= int(vals[s])
            if decoded == int(vals[b]):
                ok = True
                break
        if not ok:
            return False
    return True


@pytest.mark.parametrize("scheme", SCHEMES)
def test_erasure_tolerance_matrix(scheme):
    """Exhaustive single- and double-bank-loss matrix per scheme, three
    ways: ``CodeScheme.erasure_tolerance`` must agree loss-set by loss-set
    with (a) the GF(2) analysis certificate proved from the members matrix
    alone (``repro.analysis.schemes``) and (b) a brute-force value-level
    XOR decoder that shares no code with either. The certificate carries
    the full servable-set lists, so it replaces the old second brute-force
    sweep — one value-level decode per loss set remains as the independent
    ground truth."""
    import itertools

    from repro.analysis import schemes as anl

    s = get_tables(scheme).scheme
    rng = np.random.default_rng(33)
    tol = s.erasure_tolerance(max_losses=2)
    cert = anl.load_certificates()["schemes"][scheme]
    for k in (1, 2):
        want = tuple(
            lost for lost in itertools.combinations(range(s.n_data), k)
            if _brute_force_recoverable(s, lost, rng))
        assert tol[k] == want, (scheme, k)
        certified = tuple(tuple(lost)
                          for lost in cert["serving_tolerance"][str(k)])
        assert certified == want, (scheme, k)


def test_erasure_tolerance_expected_shapes():
    """Spot-checks from the paper's structure: every pairwise-parity scheme
    survives any single data-bank loss; uncoded survives none."""
    import math

    for name in ("scheme_i", "scheme_ii", "scheme_iii", "replication_2"):
        s = get_tables(name).scheme
        tol = s.erasure_tolerance(1)
        assert len(tol[1]) == s.n_data, name
    s1 = get_tables("scheme_i").scheme
    assert len(s1.erasure_tolerance(2)[2]) == math.comb(s1.n_data, 2)
    un = get_tables("uncoded").scheme
    assert un.erasure_tolerance(2) == {1: (), 2: ()}


# ------------------------------------------------------------ online rebuild
def test_online_rebuild_relatches_bank():
    """A failed bank that recovers is rebuilt through the recode ring and
    rejoins: the ``rebuilt`` latch sets, dead-cycle accrual stops, and no
    read is ever lost (scheme_i, full coverage)."""
    sys_ = _CHAOS_SYS["scheme_i"]
    plan = plan_from_spec((("bank", 2, 5, 30),), sys_.p.n_data,
                          sys_.tables.n_ports)
    rng = np.random.default_rng(5)
    trace = rand_trace(rng, sys_.n_cores, 12, sys_.p.n_data, sys_.p.n_rows)
    st = sys_.init(fault_plan=plan)
    for _ in range(400):
        st, _ = sys_.cycle_fn(st, trace)
    f = jax.device_get(st.mem.fault)
    assert bool(np.asarray(f.rebuilt)[2]), "rebuild latch never set"
    res = sys_.summarize(st)
    assert res.unserved_reads == 0 and res.lost_writes == 0
    dead = int(np.asarray(f.dead_cycles, np.int64).sum())
    assert 0 < dead < 400, dead   # down for a while, then back


def test_permanent_failure_still_quiesces():
    """A never-recovering bank must not wedge the run: with full coverage
    every request still completes (served degraded or dropped counted) and
    the system reaches its quiescent fixed point."""
    sys_ = _CHAOS_SYS["scheme_i"]
    plan = plan_from_spec((("bank", 0, 0),), sys_.p.n_data,
                          sys_.tables.n_ports)
    rng = np.random.default_rng(6)
    trace = rand_trace(rng, sys_.n_cores, 12, sys_.p.n_data, sys_.p.n_rows)
    res = sys_.run(trace, drain_bound(sys_.n_cores, 12), fault_plan=plan)
    assert res.completed
    assert res.unserved_reads == 0        # scheme_i serves through parity
    assert res.dead_bank_cycles > 0


# ----------------------------------------------- degraded-availability gate
@pytest.mark.parametrize("scheme", ["scheme_i", "scheme_iii"])
def test_degraded_availability_gate_coded(scheme):
    """One dead bank per parity group, full coverage: the coded schemes
    serve 100% of reads (zero unserved, zero lost writes) on a fig18-style
    suite — the paper's availability claim, enforced."""
    from repro.sweep import SweepPoint, run_points

    t = get_tables(scheme)
    pts = [SweepPoint(scheme=scheme, alpha=1.0, r=0.25, n_rows=32,
                      n_cores=3, n_banks=t.n_data, n_data=t.n_data,
                      length=12, select_period=16, recode_cap=8, seed=s,
                      faults=(("bank", 0, 0),)) for s in (0, 1)]
    for res in run_points(pts):
        assert res.unserved_reads == 0, scheme
        assert res.lost_writes == 0, scheme
        assert res.dead_bank_cycles > 0
        assert res.served_reads > 0


def test_degraded_availability_gate_uncoded():
    """The same dead bank with no redundancy permanently drops that bank's
    requests — the contrast row the fig_faults gate renders."""
    from repro.sweep import SweepPoint, run_points

    pts = [SweepPoint(scheme="uncoded", alpha=1.0, r=0.25, n_rows=32,
                      n_cores=3, n_banks=8, length=12, select_period=16,
                      recode_cap=8, seed=s, faults=(("bank", 0, 0),))
           for s in (0, 1)]
    rs = run_points(pts)
    assert sum(r.unserved_reads for r in rs) > 0
    assert all(r.fault_degraded_reads == 0 for r in rs)   # nothing to decode


# ------------------------------------------------------------ batched plans
def test_sweep_batches_different_fault_plans():
    """Different fault *schedules* share one compiled program (the schedule
    is carry data); each point's batched result equals its single-shot
    faulted run."""
    from repro.sweep import SweepPoint, partition, run_points

    specs = [(("bank", 0, 0),), (("bank", 3, 8, 40),),
             (("bank", 1, 2), ("stutter", 2, 5, 1))]
    pts = [SweepPoint(scheme="scheme_i", alpha=1.0, r=0.25, n_rows=32,
                      n_cores=3, n_banks=8, length=10, select_period=16,
                      recode_cap=8, seed=i, faults=sp)
           for i, sp in enumerate(specs)]
    assert len(partition(pts)) == 1
    got = run_points(pts)
    from repro.sweep.workloads import build_trace
    for pt, res in zip(pts, got):
        sys_ = _system("scheme_i", faults=True)
        plan = plan_from_spec(pt.faults, sys_.p.n_data, sys_.tables.n_ports)
        tn = make_tunables(queue_depth=sys_.p.queue_depth,
                           select_period=pt.select_period,
                           wq_hi=pt.wq_hi, wq_lo=pt.wq_lo)
        want = sys_.run(build_trace(pt), pt.resolved_cycles(), tn=tn,
                        fault_plan=plan)
        assert strip_windows(res) == strip_windows(want), pt.faults
