"""Pallas kernel tests: shape/dtype sweeps against the pure-jnp oracles
(interpret=True — the kernel body executes on CPU; BlockSpecs target TPU)."""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guard as anl_guard
from repro.core import controller as ctl
from repro.core.codes import get_tables
from repro.kernels.coded_kv_decode import ops as kv_ops
from repro.kernels.coded_kv_decode import ref as kv_ref
from repro.kernels.xor_encode import ops as enc_ops
from repro.kernels.xor_encode import ref as enc_ref
from repro.kernels.xor_gather import ops as g_ops
from repro.kernels.xor_gather import ref as g_ref


def _no_recompiles(name, budget=1):
    """Bound the kernel compiles of a region (no-op when this jax version
    lacks jit cache introspection — the value assertions still run)."""
    if anl_guard.available(name):
        return anl_guard.recompile_guard(name, max_compiles=budget)
    return contextlib.nullcontext()


# ------------------------------------------------------------- xor_encode
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32, jnp.uint16,
                                   jnp.int32])
@pytest.mark.parametrize("rows,width", [(16, 128), (32, 256), (8, 384)])
@pytest.mark.parametrize("scheme", ["scheme_i", "scheme_iii"])
def test_xor_encode_sweep(dtype, rows, width, scheme):
    t = get_tables(scheme, n_data=t_nd(scheme))
    key = jax.random.key(hash((rows, width)) % (2**31))
    if jnp.issubdtype(dtype, jnp.floating):
        banks = jax.random.normal(key, (t.n_data, rows, width), dtype)
    else:
        banks = jax.random.randint(key, (t.n_data, rows, width), 0, 1 << 15
                                   ).astype(dtype)
    # one program per shape class: a second call with fresh values (same
    # shapes) must hit the jit cache, not recompile
    with _no_recompiles("kernels.xor_encode", budget=1):
        out = enc_ops.encode_parities(banks, t.par_members, block_rows=8)
        out2 = enc_ops.encode_parities(jnp.roll(banks, 1, axis=1),
                                       t.par_members, block_rows=8)
    banks_u = banks
    if jnp.issubdtype(dtype, jnp.floating):
        from repro.kernels.common import uint_view_dtype
        banks_u = jax.lax.bitcast_convert_type(banks, uint_view_dtype(dtype))
    ref = enc_ref.encode_parities_ref(banks_u, jnp.asarray(t.par_members))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    ref2 = enc_ref.encode_parities_ref(jnp.roll(banks_u, 1, axis=1),
                                       jnp.asarray(t.par_members))
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref2))


def t_nd(scheme):
    return 9 if scheme == "scheme_iii" else 8


# ------------------------------------------------------------- xor_gather
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("n_req", [4, 16, 30])
def test_xor_gather_modes(dtype, n_req):
    """Random mix of direct / degraded / redirect / unserved requests."""
    t = get_tables("scheme_i")
    rows, width = 16, 128
    key = jax.random.key(n_req)
    banks = jax.random.normal(key, (8, rows, width), dtype)
    par = enc_ops.encode_parities(banks, t.par_members, block_rows=8)

    rng = np.random.default_rng(n_req)
    bank = rng.integers(0, 8, n_req).astype(np.int32)
    row = rng.integers(0, rows, n_req).astype(np.int32)
    mode = np.full(n_req, ctl.MODE_DIRECT, np.int32)
    par_col = np.zeros(n_req, np.int32)
    sib0 = np.full(n_req, -1, np.int32)
    sib1 = np.full(n_req, -1, np.int32)
    for i in range(n_req):
        c = rng.random()
        if c < 0.4:                        # degraded via a random option
            k = rng.integers(0, int(t.opt_n[bank[i]]))
            mode[i] = ctl.MODE_OPT0 + k
            par_col[i] = t.opt_parity[bank[i], k]
            sib0[i] = t.opt_sibs[bank[i], k, 0]
            sib1[i] = t.opt_sibs[bank[i], k, 1]
        elif c < 0.5:
            mode[i] = ctl.MODE_UNSERVED
    cols = g_ops.PlanColumns(*(jnp.asarray(a) for a in
                               (bank, row, mode, par_col, row, sib0, sib1)))
    with _no_recompiles("kernels.xor_gather", budget=1):
        out = g_ops.gather_decode(banks, par, cols, req_block=8,
                                  value_dtype=dtype)
    from repro.kernels.common import uint_view_dtype
    u = uint_view_dtype(dtype)
    ref = g_ref.gather_decode_ref(
        jax.lax.bitcast_convert_type(banks, u), par,
        cols.bank, cols.row, cols.mode, cols.par, cols.prow, cols.sib0,
        cols.sib1)
    ref = jax.lax.bitcast_convert_type(ref, dtype)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # degraded reads reconstruct the *logical* row bit-exactly
    for i in range(n_req):
        if ctl.MODE_OPT0 <= mode[i] < ctl.MODE_REDIRECT:
            np.testing.assert_array_equal(
                np.asarray(out[i]), np.asarray(banks[bank[i], row[i]]))


# --------------------------------------------------------- coded_kv_decode
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("t_len,h,hkv,d", [(128, 4, 2, 32), (256, 8, 2, 64),
                                           (64, 4, 4, 128)])
def test_coded_kv_decode_sweep(dtype, t_len, h, hkv, d):
    nb, page = 4, t_len // 8
    b = 2
    k = jax.random.normal(jax.random.key(1), (b, t_len, hkv, d), dtype)
    v = jax.random.normal(jax.random.key(2), (b, t_len, hkv, d), dtype)
    q = jax.random.normal(jax.random.key(3), (b, h, d), dtype)
    ku, vu, kp, vp, n_pages = kv_ops.pack_kv_banks(k, v, nb, page)
    seq = jnp.asarray([t_len, t_len // 2], jnp.int32)
    use_par = jax.random.bernoulli(jax.random.key(4), 0.5, (b, n_pages))
    with _no_recompiles("kernels.coded_kv_decode", budget=1):
        out = kv_ops.coded_kv_decode(q, ku, vu, kp, vp, use_par, seq)
    ref = kv_ref.decode_attention_ref(q, k, v, seq)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_coded_kv_parity_mix_invariance():
    """The answer must not depend on WHICH pages use the parity path."""
    dtype = jnp.bfloat16
    b, t_len, h, hkv, d = 1, 128, 4, 2, 32
    nb, page = 4, 16
    k = jax.random.normal(jax.random.key(5), (b, t_len, hkv, d), dtype)
    v = jax.random.normal(jax.random.key(6), (b, t_len, hkv, d), dtype)
    q = jax.random.normal(jax.random.key(7), (b, h, d), dtype)
    ku, vu, kp, vp, n_pages = kv_ops.pack_kv_banks(k, v, nb, page)
    seq = jnp.asarray([t_len], jnp.int32)
    outs = []
    # the parity mask is carry data, not a compile key: all three mixes
    # must run through at most one compiled program
    with _no_recompiles("kernels.coded_kv_decode", budget=1):
        for seed in range(3):
            up = jax.random.bernoulli(jax.random.key(seed), 0.5, (b, n_pages))
            outs.append(np.asarray(
                kv_ops.coded_kv_decode(q, ku, vu, kp, vp, up, seq),
                np.float32))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
