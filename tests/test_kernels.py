"""Pallas kernel tests: shape/dtype sweeps against the pure-jnp oracles
(interpret=True — the kernel body executes on CPU; BlockSpecs target TPU)."""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guard as anl_guard
from repro.core import controller as ctl
from repro.core.codes import get_tables
from repro.kernels.coded_kv_decode import ops as kv_ops
from repro.kernels.coded_kv_decode import ref as kv_ref
from repro.kernels.xor_encode import ops as enc_ops
from repro.kernels.xor_encode import ref as enc_ref
from repro.kernels.xor_gather import ops as g_ops
from repro.kernels.xor_gather import ref as g_ref


def _no_recompiles(name, budget=1):
    """Bound the kernel compiles of a region (no-op when this jax version
    lacks jit cache introspection — the value assertions still run)."""
    if anl_guard.available(name):
        return anl_guard.recompile_guard(name, max_compiles=budget)
    return contextlib.nullcontext()


# ------------------------------------------------------------- xor_encode
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32, jnp.uint16,
                                   jnp.int32])
@pytest.mark.parametrize("rows,width", [(16, 128), (32, 256), (8, 384)])
@pytest.mark.parametrize("scheme", ["scheme_i", "scheme_iii"])
def test_xor_encode_sweep(dtype, rows, width, scheme):
    t = get_tables(scheme, n_data=t_nd(scheme))
    key = jax.random.key(hash((rows, width)) % (2**31))
    if jnp.issubdtype(dtype, jnp.floating):
        banks = jax.random.normal(key, (t.n_data, rows, width), dtype)
    else:
        banks = jax.random.randint(key, (t.n_data, rows, width), 0, 1 << 15
                                   ).astype(dtype)
    # one program per shape class: a second call with fresh values (same
    # shapes) must hit the jit cache, not recompile
    with _no_recompiles("kernels.xor_encode", budget=1):
        out = enc_ops.encode_parities(banks, t.par_members, block_rows=8)
        out2 = enc_ops.encode_parities(jnp.roll(banks, 1, axis=1),
                                       t.par_members, block_rows=8)
    banks_u = banks
    if jnp.issubdtype(dtype, jnp.floating):
        from repro.kernels.common import uint_view_dtype
        banks_u = jax.lax.bitcast_convert_type(banks, uint_view_dtype(dtype))
    ref = enc_ref.encode_parities_ref(banks_u, jnp.asarray(t.par_members))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    ref2 = enc_ref.encode_parities_ref(jnp.roll(banks_u, 1, axis=1),
                                       jnp.asarray(t.par_members))
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref2))


def t_nd(scheme):
    return 9 if scheme == "scheme_iii" else 8


# ------------------------------------------------------------- xor_gather
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("n_req", [4, 16, 30])
def test_xor_gather_modes(dtype, n_req):
    """Random mix of direct / degraded / redirect / unserved requests."""
    t = get_tables("scheme_i")
    rows, width = 16, 128
    key = jax.random.key(n_req)
    banks = jax.random.normal(key, (8, rows, width), dtype)
    par = enc_ops.encode_parities(banks, t.par_members, block_rows=8)

    rng = np.random.default_rng(n_req)
    bank = rng.integers(0, 8, n_req).astype(np.int32)
    row = rng.integers(0, rows, n_req).astype(np.int32)
    mode = np.full(n_req, ctl.MODE_DIRECT, np.int32)
    par_col = np.zeros(n_req, np.int32)
    sib0 = np.full(n_req, -1, np.int32)
    sib1 = np.full(n_req, -1, np.int32)
    for i in range(n_req):
        c = rng.random()
        if c < 0.4:                        # degraded via a random option
            k = rng.integers(0, int(t.opt_n[bank[i]]))
            mode[i] = ctl.MODE_OPT0 + k
            par_col[i] = t.opt_parity[bank[i], k]
            sib0[i] = t.opt_sibs[bank[i], k, 0]
            sib1[i] = t.opt_sibs[bank[i], k, 1]
        elif c < 0.5:
            mode[i] = ctl.MODE_UNSERVED
    cols = g_ops.PlanColumns(*(jnp.asarray(a) for a in
                               (bank, row, mode, par_col, row, sib0, sib1)))
    with _no_recompiles("kernels.xor_gather", budget=1):
        out = g_ops.gather_decode(banks, par, cols, req_block=8,
                                  value_dtype=dtype)
    from repro.kernels.common import uint_view_dtype
    u = uint_view_dtype(dtype)
    ref = g_ref.gather_decode_ref(
        jax.lax.bitcast_convert_type(banks, u), par,
        cols.bank, cols.row, cols.mode, cols.par, cols.prow, cols.sib0,
        cols.sib1)
    ref = jax.lax.bitcast_convert_type(ref, dtype)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # degraded reads reconstruct the *logical* row bit-exactly
    for i in range(n_req):
        if ctl.MODE_OPT0 <= mode[i] < ctl.MODE_REDIRECT:
            np.testing.assert_array_equal(
                np.asarray(out[i]), np.asarray(banks[bank[i], row[i]]))


# --------------------------------------------------------- coded_kv_decode
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("t_len,h,hkv,d", [(128, 4, 2, 32), (256, 8, 2, 64),
                                           (64, 4, 4, 128)])
def test_coded_kv_decode_sweep(dtype, t_len, h, hkv, d):
    nb, page = 4, t_len // 8
    b = 2
    k = jax.random.normal(jax.random.key(1), (b, t_len, hkv, d), dtype)
    v = jax.random.normal(jax.random.key(2), (b, t_len, hkv, d), dtype)
    q = jax.random.normal(jax.random.key(3), (b, h, d), dtype)
    ku, vu, kp, vp, n_pages = kv_ops.pack_kv_banks(k, v, nb, page)
    seq = jnp.asarray([t_len, t_len // 2], jnp.int32)
    use_par = jax.random.bernoulli(jax.random.key(4), 0.5, (b, n_pages))
    with _no_recompiles("kernels.coded_kv_decode", budget=1):
        out = kv_ops.coded_kv_decode(q, ku, vu, kp, vp, use_par, seq)
    ref = kv_ref.decode_attention_ref(q, k, v, seq)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_xor_gather_empty_plan():
    """Regression: an N=0 plan used to divide by zero sizing the request
    grid. Every public entry point must return an empty (0, W) result."""
    from repro.kernels.xor_gather.kernel import gather_decode_pallas
    banks = jnp.zeros((8, 16, 128), jnp.uint32)
    par = jnp.zeros((4, 16, 128), jnp.uint32)
    empty = jnp.zeros((0,), jnp.int32)
    out = gather_decode_pallas(banks, par, *([empty] * 7), interpret=True)
    assert out.shape == (0, 128) and out.dtype == jnp.uint32
    cols = g_ops.PlanColumns(*([empty] * 7))
    out2 = g_ops.gather_decode(banks, par, cols, interpret=True,
                               value_dtype=jnp.float32)
    assert out2.shape == (0, 128) and out2.dtype == jnp.float32


@pytest.mark.parametrize("n_req", [1, 5, 13])
def test_xor_gather_ragged_requests_direct(n_req):
    """Regression: the pallas wrapper itself (not just gather_decode) must
    accept any N — it used to assert on N % req_block != 0. The -1 pad rows
    select nothing and are stripped from the result."""
    from repro.kernels.xor_gather.kernel import gather_decode_pallas
    rng = np.random.default_rng(n_req)
    banks = jnp.asarray(rng.integers(0, 2**32, (8, 16, 128),
                                     dtype=np.uint32))
    par = jnp.asarray(rng.integers(0, 2**32, (4, 16, 128), dtype=np.uint32))
    bank = jnp.asarray(rng.integers(0, 8, n_req), jnp.int32)
    row = jnp.asarray(rng.integers(0, 16, n_req), jnp.int32)
    mode = jnp.ones((n_req,), jnp.int32)
    zero = jnp.zeros((n_req,), jnp.int32)
    neg = jnp.full((n_req,), -1, jnp.int32)
    out = gather_decode_pallas(banks, par, bank, row, mode, zero, zero,
                               neg, neg, req_block=8, interpret=True)
    assert out.shape == (n_req, 128)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(banks[bank, row]))


def test_resolve_interpret_backend_policy():
    from repro.kernels.common import resolve_interpret
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    expect = jax.default_backend() != "tpu"
    assert resolve_interpret(None) is expect
    assert resolve_interpret() is expect


def _count_eqns(jaxpr) -> int:
    n = len(jaxpr.eqns)
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):          # ClosedJaxpr
                n += _count_eqns(v.jaxpr)
            elif hasattr(v, "eqns"):         # raw Jaxpr
                n += _count_eqns(v)
    return n


def test_kv_decode_compile_size_independent_of_pages():
    """The page walk is a fori_loop, not a Python unroll: the traced
    program must have the same equation count for 8 and 32 pages."""
    from repro.kernels.coded_kv_decode.kernel import coded_kv_decode_pallas

    def trace(n_slots):
        b, nb, page, hkv, d, h = 1, 4, 8, 2, 32, 4
        shape = (b, nb, n_slots, page, hkv, d)
        pshape = (b, nb // 2, n_slots, page, hkv, d)
        n_pages = nb * n_slots
        jx = jax.make_jaxpr(
            lambda q, kb, vb, kp, vp, up, sl: coded_kv_decode_pallas(
                q, kb, vb, kp, vp, up, sl, interpret=True))(
            jnp.zeros((b, h, d), jnp.float32),
            jnp.zeros(shape, jnp.uint32), jnp.zeros(shape, jnp.uint32),
            jnp.zeros(pshape, jnp.uint32), jnp.zeros(pshape, jnp.uint32),
            jnp.zeros((b, n_pages), jnp.int32),
            jnp.zeros((b,), jnp.int32))
        return _count_eqns(jx.jaxpr)

    assert trace(2) == trace(8)


# -------------------------------------------------------------- pool gather
@pytest.mark.parametrize("coded", [True, False])
def test_pool_gather_pallas_matches_reference(coded):
    """The serving-pool Pallas gather is bit-exact vs the jnp reference on
    randomized plans (mixed direct/degraded, unallocated -1 pages)."""
    nb, slots, pg, hkv, d = 4, 4, 2, 2, 32
    b, mp = 3, 6
    ng = nb // 2 if coded else 0
    rng = np.random.default_rng(7 + coded)
    kb = jnp.asarray(rng.integers(0, 2**32, (nb, slots, pg, hkv, d),
                                  dtype=np.uint32))
    vb = jnp.asarray(rng.integers(0, 2**32, (nb, slots, pg, hkv, d),
                                  dtype=np.uint32))
    kp = (kb[0::2] ^ kb[1::2])[:ng]
    vp = (vb[0::2] ^ vb[1::2])[:ng]
    pt = np.full((b, mp), -1, np.int32)
    flat = rng.permutation(nb * slots)[: b * mp - 4]      # leave some -1
    pt.reshape(-1)[: flat.size] = flat
    pt = jnp.asarray(pt)
    upar = jnp.asarray(rng.integers(0, 2, (b, mp)).astype(bool) if coded
                       else np.zeros((b, mp), bool))
    with _no_recompiles("kernels.pool_gather", budget=1):
        got_k, got_v = kv_ops.gather_pool_layer(
            kb, vb, kp, vp, pt, upar, jnp.float32, kernel="pallas",
            interpret=True)
    ref_k, ref_v = kv_ops.gather_pool_layer(kb, vb, kp, vp, pt, upar,
                                            jnp.float32)
    np.testing.assert_array_equal(np.asarray(got_k).view(np.uint32),
                                  np.asarray(ref_k).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(got_v).view(np.uint32),
                                  np.asarray(ref_v).view(np.uint32))


def test_coded_kv_parity_mix_invariance():
    """The answer must not depend on WHICH pages use the parity path."""
    dtype = jnp.bfloat16
    b, t_len, h, hkv, d = 1, 128, 4, 2, 32
    nb, page = 4, 16
    k = jax.random.normal(jax.random.key(5), (b, t_len, hkv, d), dtype)
    v = jax.random.normal(jax.random.key(6), (b, t_len, hkv, d), dtype)
    q = jax.random.normal(jax.random.key(7), (b, h, d), dtype)
    ku, vu, kp, vp, n_pages = kv_ops.pack_kv_banks(k, v, nb, page)
    seq = jnp.asarray([t_len], jnp.int32)
    outs = []
    # the parity mask is carry data, not a compile key: all three mixes
    # must run through at most one compiled program
    with _no_recompiles("kernels.coded_kv_decode", budget=1):
        for seed in range(3):
            up = jax.random.bernoulli(jax.random.key(seed), 0.5, (b, n_pages))
            outs.append(np.asarray(
                kv_ops.coded_kv_decode(q, ku, vu, kp, vp, up, seq),
                np.float32))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
