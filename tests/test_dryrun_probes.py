"""Dry-run machinery tests that DON'T need 512 devices: the secant cost
extrapolation is validated against a full unroll on a 1×1 mesh, and the
collective-bytes HLO parser against hand-built collectives.

The full 40-cell × 2-mesh dry-run runs via
``python -m repro.launch.dryrun --all --both-meshes`` (EXPERIMENTS.md §Dry-run);
a single reduced-scale multi-device cell is exercised here in a subprocess
(so the forced device count cannot leak into this process's jax)."""
import os
import subprocess
import sys

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_secant_matches_full_unroll():
    """cost(L) extrapolated from L∈{1,2} == measured full unroll at L=4
    (whisper-tiny decoder is cost-linear in depth)."""
    from repro.configs.base import get_config
    from repro.launch.dryrun import (_reconstruct, _with_layers,
                                     cost_analysis_dict, lower_cell)
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.shapes import ShapeSpec

    cfg = get_config("whisper-tiny")
    import dataclasses
    cfg = dataclasses.replace(cfg.reduced(), enc_layers=1, enc_frames=16)
    shape = ShapeSpec("tiny_train", "train", 64, 4)
    mesh = make_debug_mesh(1, 1)

    costs = {}
    for L in (1, 2, 4):
        pcfg = _with_layers(cfg, L)
        lowered = lower_cell(pcfg, shape, mesh, unroll=L, q_chunk=0)
        costs[L] = float(cost_analysis_dict(lowered.compile())
                         .get("flops", 0.0))
    want = costs[4]
    got = _reconstruct(dataclasses.replace(cfg, n_layers=4),
                       {1: costs[1], 2: costs[2]})
    assert abs(got - want) / want < 0.02, (got, want)


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %p0 = f32[2048]{0} parameter(0)
  %ag = f32[4096]{0} all-gather(f32[2048]{0} %p0), replica_groups={{0,1}}
  %ar = bf16[1024]{0} all-reduce(bf16[1024]{0} %x), to_apply=%sum
  %rs = f32[512]{0} reduce-scatter(f32[2048]{0} %p0), dimensions={0}
  %cp = f32[256]{0} collective-permute(f32[256]{0} %y)
"""
    out = collective_bytes(hlo)
    by = out["bytes_by_kind"]
    assert by["all-gather"] == 4096 * 4
    assert by["all-reduce"] == 2 * 1024 * 2
    assert by["reduce-scatter"] == 2048 * 4      # input bytes
    assert by["collective-permute"] == 256 * 4
    assert out["count_by_kind"]["all-gather"] == 1


def test_applicability_rules():
    from repro.configs.base import get_config
    from repro.launch.shapes import SHAPES, applicable
    long = SHAPES["long_500k"]
    for arch in ("qwen2.5-3b", "granite-20b", "yi-6b", "whisper-tiny",
                 "stablelm-12b", "olmoe-1b-7b", "phi-3-vision-4.2b"):
        ok, why = applicable(get_config(arch), long)
        assert not ok and "sub-quadratic" in why
    for arch in ("mamba2-2.7b", "recurrentgemma-9b", "mixtral-8x7b"):
        ok, _ = applicable(get_config(arch), long)
        assert ok
    for arch in ("qwen2.5-3b", "whisper-tiny"):
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = applicable(get_config(arch), SHAPES[s])
            assert ok


def test_input_specs_no_allocation():
    from repro.configs.base import all_configs
    from repro.launch.shapes import SHAPES, applicable, input_specs
    for name, cfg in all_configs().items():
        for sname, shape in SHAPES.items():
            if not applicable(cfg, shape)[0]:
                continue
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct), (name, sname)


@pytest.mark.slow
def test_multidevice_cell_subprocess(tmp_path):
    """One reduced cell on a forced 8-device (2×4) mesh in a subprocess —
    proves the sharding rules hold on a real multi-device partitioning."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
from repro.configs.base import get_config
from repro.launch.dryrun import cost_analysis_dict, lower_cell
from repro.launch.shapes import ShapeSpec

mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ("qwen2.5-3b", "mamba2-2.7b"):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              d_model=256, n_heads=8, n_kv=4 if arch=="qwen2.5-3b" else 0,
                              head_dim=32, d_ff=512, vocab=1024)
    shape = ShapeSpec("t", "train", 128, 8)
    lowered = lower_cell(cfg, shape, mesh, unroll=1, q_chunk=0)
    c = lowered.compile()
    assert cost_analysis_dict(c).get("flops", 0) > 0
    print(arch, "OK")
print("SUBPROCESS_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr
