"""Differential conformance: the NumPy golden model is the ground truth.

The production scheduler (``repro.core``, vectorized jax) is checked against
``repro.oracle`` — an independent, sequential, pure-NumPy re-derivation of
the paper's cycle semantics that shares no code (not even the scheme
tables) with the system under test. Four layers, each asserting **bit
equality**, not statistical closeness:

1. *tables* — the independently derived code schemes agree;
2. *plans* — randomized controller states produce identical read/write
   plans and recode outcomes (hypothesis-driven when installed, seeded
   NumPy fallback otherwise);
3. *workloads* — full simulations agree on every state leaf, every
   statistic, and the per-cycle read datapath;
4. *streams & masked geometry* — the chunked replay driver and the sweep
   engine's padded α×r batching agree with the oracle run at each point's
   exact geometry (at least one masked grid point per scheme).

See docs/testing.md for the contract and how to evolve the scheduler
without reintroducing a second jax implementation.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_state_matches_oracle, oracle_twin, rand_trace

from repro.core import controller as ctl
from repro.core.codes import get_tables
from repro.core.recoding import recode_step as jax_recode_step
from repro.core.state import make_params, make_tunables
from repro.core.system import CodedMemorySystem, drain_bound
from repro import oracle
from repro.oracle import (OracleMemorySystem, OracleParams, build_read_plan,
                          build_write_plan, oracle_scheme)
from repro.oracle import recode_step as oracle_recode_step

SCHEMES = ["scheme_i", "scheme_ii", "scheme_iii", "replication_2", "uncoded"]

_read_jax = jax.jit(ctl.build_read_pattern, static_argnums=0)
_write_jax = jax.jit(ctl.build_write_pattern, static_argnums=0)
_recode_jax = jax.jit(jax_recode_step, static_argnums=0)


# ------------------------------------------------------------------- tables
@pytest.mark.parametrize("scheme", SCHEMES + ["replication_4"])
def test_oracle_tables_match_core(scheme):
    """The oracle's independently derived scheme tables agree with the
    production ones — members, physical packing, port ids and per-bank
    serving options. (Divergence here would invalidate every other layer.)"""
    from repro.analysis import schemes as anl

    t = get_tables(scheme)
    o = oracle_scheme(scheme, t.n_data)
    assert o.n_data == t.n_data
    assert o.n_parities == len(t.scheme.members)
    assert o.n_ports == t.n_ports
    # hash both derivations against the checked-in certificate; on
    # divergence, name the scheme and the first differing parity instead
    # of failing with a bare tuple assert
    cert_hash = anl.load_certificates()["schemes"][scheme]["table_sha256"]
    core_hash = anl.table_hash(t.scheme.members, t.scheme.phys)
    oracle_hash = anl.table_hash(o.members, o.phys)
    if not (core_hash == oracle_hash == cert_hash):
        diff = anl.diff_tables(scheme, t.scheme.members, t.scheme.phys,
                               o.members, o.phys)
        raise AssertionError(
            f"{scheme}: table derivations diverge (core={core_hash[:12]} "
            f"oracle={oracle_hash[:12]} certificate={cert_hash[:12]}):\n"
            + "\n".join(diff or ["(tables equal — certificate is stale: run "
                                 "python -m repro.analysis "
                                 "--write-certificates)"]))
    assert tuple(o.members) == tuple(t.scheme.members)
    assert tuple(o.phys) == tuple(t.scheme.phys)
    for j in range(o.n_parities):
        assert o.par_port(j) == int(t.par_port[j])
    for b in range(o.n_data):
        opts = o.options(b)
        assert len(opts) == int(t.opt_n[b])
        for k, (j, sibs) in enumerate(opts):
            assert j == int(t.opt_parity[b, k])
            want = tuple(int(s) for s in t.opt_sibs[b, k] if s >= 0)
            assert sibs == want


def test_mode_numbering_contract():
    """Plan `mode` values are compared elementwise across implementations,
    so the action numbering is a shared contract, re-derived on both
    sides."""
    assert (oracle.MODE_FROM_SYM, oracle.MODE_DIRECT, oracle.MODE_OPT0,
            oracle.MODE_REDIRECT, oracle.MODE_UNSERVED) == (
        ctl.MODE_FROM_SYM, ctl.MODE_DIRECT, ctl.MODE_OPT0, ctl.MODE_REDIRECT,
        ctl.MODE_UNSERVED)
    assert (oracle.WMODE_DIRECT, oracle.WMODE_PARK0, oracle.WMODE_UNSERVED
            ) == (ctl.WMODE_DIRECT, ctl.WMODE_PARK0, ctl.WMODE_UNSERVED)


# ---------------------------------------------------------- randomized plans
@functools.lru_cache(maxsize=None)
def _geom(scheme, n_rows=16, alpha=1.0, r=0.25, rc_cap=8):
    t = get_tables(scheme)
    p = make_params(t, n_rows=n_rows, alpha=alpha, r=r, recode_cap=rc_cap)
    op = OracleParams.derive(n_rows, alpha, r, n_data=t.n_data,
                             recode_cap=rc_cap)
    om = OracleMemorySystem(scheme, op, n_cores=4)
    return t, p, ctl.jtables(t), om


def _rand_mem(rng, t, p, n_rows):
    """Random reachable controller state: freshness points only at real
    logical parities (a bank with no parities can never be parked), the
    recode ring fill includes FULL, the region map is a random partial
    injection."""
    nb = p.n_data
    n_logical = len(t.scheme.members)
    fresh = np.asarray(
        rng.integers(0, n_logical + 1, (nb, n_rows))
        * (rng.random((nb, n_rows)) < 0.25), np.int32)
    pv = rng.random((p.n_parities, p.n_slots * p.region_size)) < 0.7
    rslot = np.full(p.n_regions, -1, np.int32)
    slots = rng.permutation(p.n_slots)
    regs = rng.permutation(p.n_regions)
    k = rng.integers(0, min(p.n_slots, p.n_regions) + 1)
    rslot[regs[:k]] = slots[:k]
    cap = p.recode_cap
    fill = int(rng.integers(0, cap + 1))
    rcv = np.zeros(cap, bool)
    rcv[rng.permutation(cap)[:fill]] = True
    rcb = np.where(rcv, rng.integers(0, nb, cap), -1).astype(np.int32)
    rcr = np.where(rcv, rng.integers(0, n_rows, cap), -1).astype(np.int32)
    parked = rng.integers(0, 3, p.n_regions).astype(np.int32)
    return fresh, pv, rslot, parked, rcb, rcr, rcv


def _rand_cands(rng, p, n_rows, n=24):
    cb = rng.integers(0, p.n_data, n).astype(np.int32)
    ci = rng.integers(0, n_rows, n).astype(np.int32)
    ca = rng.integers(0, 50, n).astype(np.int32)   # age ties likely
    cv = rng.random(n) < 0.8
    pb = np.append(rng.random(p.n_ports) < 0.3, False)
    return cb, ci, ca, cv, pb


def _assert_plans_equal(got, want, label):
    """jax plan pytree vs oracle plan namedtuple, matched by field name."""
    for name in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            err_msg=f"{label}: field {name!r}")


def check_plan_conformance(scheme, seed):
    n_rows = 16
    t, p, jt, om = _geom(scheme)
    rng = np.random.default_rng(seed)
    fresh, pv, rslot, parked, rcb, rcr, rcv = _rand_mem(rng, t, p, n_rows)
    cb, ci, ca, cv, pb = _rand_cands(rng, p, n_rows)
    got = _read_jax(p, jt, *map(jnp.asarray,
                                (cb, ci, ca, cv, pb, fresh, pv, rslot)))
    want = build_read_plan(om, cb, ci, ca, cv, pb, fresh, pv, rslot)
    _assert_plans_equal(got, want, f"ReadPlan {scheme} seed={seed}")
    got = _write_jax(p, jt, *map(jnp.asarray,
                                 (cb, ci, ca, cv, pb, fresh, pv, rslot,
                                  parked, rcb, rcr, rcv)))
    want = build_write_plan(om, cb, ci, ca, cv, pb, fresh, pv, rslot,
                            parked, rcb, rcr, rcv)
    _assert_plans_equal(got, want, f"WritePlan {scheme} seed={seed}")


def check_recode_conformance(scheme, seed):
    n_rows = 16
    t, p, jt, om = _geom(scheme)
    rng = np.random.default_rng(seed)
    fresh, pv, rslot, parked, rcb, rcr, rcv = _rand_mem(rng, t, p, n_rows)
    pb = np.append(rng.random(p.n_ports) < 0.3, False)
    banks = rng.integers(0, 1 << 20, (p.n_data, n_rows)).astype(np.int32)
    pdata = rng.integers(0, 1 << 20, pv.shape).astype(np.int32)
    got = _recode_jax(p, jt, *map(jnp.asarray,
                                  (pb, fresh, pv, parked, rcb, rcr, rcv,
                                   rslot, banks, pdata)))
    want = oracle_recode_step(om, pb, fresh, pv, parked, rcb, rcr, rcv,
                              rslot, banks, pdata)
    _assert_plans_equal(got, want, f"RecodeOut {scheme} seed={seed}")


@pytest.mark.parametrize("scheme", SCHEMES)
def test_plan_conformance_random_states(scheme):
    """Read/write plans are bit-identical to the golden model across random
    queue/port/freshness/parity/ring states (incl. full recode rings)."""
    for seed in range(6):
        check_plan_conformance(scheme, seed)


@pytest.mark.parametrize("scheme", ["scheme_i", "scheme_iii"])
def test_recode_conformance_random_states(scheme):
    for seed in range(6):
        check_recode_conformance(scheme, 1000 + seed)


# ------------------------------------------------------------ full workloads
def _system(scheme, n_rows=32, alpha=0.25, r=0.125, n_cores=4,
            select_period=16, **kw):
    t = get_tables(scheme)
    p = make_params(t, n_rows=n_rows, alpha=alpha, r=r, recode_cap=8, **kw)
    tn = make_tunables(queue_depth=p.queue_depth, select_period=select_period)
    return CodedMemorySystem(t, p, n_cores=n_cores, tunables=tn)


def check_workload_conformance(scheme, alpha, r, seed, write_frac=0.45):
    sys_ = _system(scheme, alpha=alpha, r=r)
    om = oracle_twin(sys_)
    rng = np.random.default_rng(seed)
    trace = rand_trace(rng, 4, 20, sys_.p.n_data, 32, write_frac=write_frac)
    n_cycles = 96
    st, _ = sys_._run(sys_.init(), trace, n_cycles)
    ost = om.run(trace, n_cycles)
    assert_state_matches_oracle(
        st, ost, f"{scheme} α={alpha} r={r} seed={seed}")
    from repro.traces.stream import strip_windows
    assert strip_windows(sys_.summarize(st)) == om.result(ost)


@pytest.mark.parametrize("scheme,alpha,r", [
    ("scheme_i", 1.0, 0.25),
    ("scheme_i", 0.25, 0.125),      # dynamic coding engaged
    ("uncoded", 1.0, 0.25),
    ("replication_2", 0.25, 0.125),
    pytest.param("scheme_ii", 0.5, 0.125, marks=pytest.mark.slow),
    pytest.param("scheme_iii", 1.0, 0.25, marks=pytest.mark.slow),
])
def test_full_workload_conformance(scheme, alpha, r):
    """End-to-end: every state leaf and every statistic of a full simulation
    equals the golden model's, write-heavy mixes included."""
    check_workload_conformance(scheme, alpha, r, seed=7)
    check_workload_conformance(scheme, alpha, r, seed=8, write_frac=0.7)


def test_per_cycle_datapath_conformance():
    """Cycle-by-cycle CycleOut equality: which reads are served, from where,
    and the exact values the XOR-decode datapath returns — not just final
    state. Catches compensating errors that cancel by drain time."""
    sys_ = _system("scheme_i", alpha=0.25, r=0.125)
    om = oracle_twin(sys_)
    rng = np.random.default_rng(3)
    trace = rand_trace(rng, 4, 16, sys_.p.n_data, 32)
    st = sys_.init()
    ost = om.init_state()
    tr_np = tuple(np.asarray(x) for x in trace)
    for cyc in range(64):
        st, out = sys_.cycle_fn(st, trace)
        oout = om.cycle(ost, tr_np)
        for name in ("r_served", "r_bank", "r_row", "r_value", "n_served"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out, name)), getattr(oout, name),
                err_msg=f"cycle {cyc}: {name}")
    assert_state_matches_oracle(st, ost, "per-cycle run")


# ------------------------------------------------------------ chunked streams
def _split_trace(trace, cuts):
    """Cut a trace into time-axis chunks at the given offsets."""
    from repro.core.system import Trace
    arrs = [np.asarray(x) for x in trace]
    T = arrs[0].shape[1]
    prev = 0
    out = []
    for c in list(cuts) + [T]:
        if c > prev:
            out.append(Trace(*(jnp.asarray(a[:, prev:c]) for a in arrs)))
            prev = c
    return out


def check_stream_conformance(seed, chunk_len, cuts):
    from repro.traces import stream_replay, strip_windows
    sys_ = _system("scheme_i", alpha=0.25, r=0.125, n_cores=3)
    om = oracle_twin(sys_)
    rng = np.random.default_rng(seed)
    tlen = 10
    trace = rand_trace(rng, 3, tlen, sys_.p.n_data, 32)
    got = stream_replay(sys_, _split_trace(trace, sorted(cuts)),
                        chunk_len=chunk_len)
    ost = om.run(trace, drain_bound(3, tlen), stop_when_quiescent=True)
    assert strip_windows(got) == om.result(ost), (seed, chunk_len, cuts)


@pytest.mark.parametrize("chunk_len,cuts", [
    (1, ()), (3, (2, 5)), (10, (1, 2, 3, 4, 9)), (14, (5,)),
])
def test_chunked_stream_matches_oracle(chunk_len, cuts):
    """Arbitrary staging lengths × arbitrary source splits: the chunked
    replay equals the golden model on the concatenated stream — the oracle
    (which has no notion of chunks) anchors split-invariance."""
    check_stream_conformance(5, chunk_len, cuts)


# --------------------------------------------------------- masked α×r points
@pytest.mark.parametrize("scheme", SCHEMES)
def test_masked_geometry_grid_matches_oracle(scheme):
    """An α×r grid runs as ONE padded-geometry program per scheme (the
    engine's r-mask batching); every point must equal the oracle run at the
    point's own exact geometry — the masked grid point per scheme the
    conformance contract requires."""
    from repro.sweep import SweepPoint, grid, partition, run_points
    from repro.sweep.workloads import build_trace
    from repro.traces.stream import strip_windows

    t = get_tables(scheme)
    base = SweepPoint(scheme=scheme, n_rows=32, n_cores=3,
                      n_banks=t.n_data, n_data=t.n_data, length=10,
                      select_period=16, recode_cap=8)
    pts = grid(base, alpha=(0.25, 0.5), r=(0.125, 0.25))
    assert len({pt.derived_slots() for pt in pts}) > 1   # genuinely masked
    assert len(partition(pts)) == 1                      # one padded program
    got = run_points(pts)
    for pt, res in zip(pts, got):
        op = OracleParams.derive(pt.n_rows, pt.alpha, pt.r,
                                 n_data=pt.n_data, recode_cap=pt.recode_cap,
                                 select_period=pt.select_period,
                                 wq_hi=pt.wq_hi, wq_lo=pt.wq_lo,
                                 queue_depth=pt.queue_depth)
        om = OracleMemorySystem(scheme, op, n_cores=pt.n_cores)
        ost = om.run(build_trace(pt), pt.resolved_cycles(),
                     stop_when_quiescent=True)
        assert strip_windows(res) == om.result(ost), pt


# ----------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=25)
    @given(st.integers(0, 2**31 - 1), st.sampled_from(SCHEMES))
    def test_plan_conformance_hypothesis(seed, scheme):
        check_plan_conformance(scheme, seed)

    @settings(max_examples=10)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from(["scheme_i", "scheme_iii"]))
    def test_recode_conformance_hypothesis(seed, scheme):
        check_recode_conformance(scheme, seed)

    @settings(max_examples=6)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from([("scheme_i", 0.25, 0.125), ("scheme_i", 1.0, 0.25),
                            ("uncoded", 1.0, 0.25)]),
           st.floats(0.2, 0.8))
    def test_workload_conformance_hypothesis(seed, cfg, write_frac):
        scheme, alpha, r = cfg
        check_workload_conformance(scheme, alpha, r, seed,
                                   write_frac=write_frac)

    @settings(max_examples=10)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from([1, 2, 3, 5, 7, 10, 13]),
           st.lists(st.integers(1, 9), max_size=4, unique=True))
    def test_chunked_stream_conformance_hypothesis(seed, chunk_len, cuts):
        """Random traces × random source splits × random staging lengths:
        streamed replay == the golden model, stats and latencies exact."""
        check_stream_conformance(seed, chunk_len, cuts)
