"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single device; only launch/dryrun.py forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
