"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single device; only launch/dryrun.py forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# ---------------------------------------------------------------- hypothesis
# CI runs the property-based suites derandomized (fixed seed, no deadline):
# conformance failures must be reproducible from the log, and CI machines
# make wall-clock deadlines flaky. Locally the default profile keeps random
# exploration but still drops the deadline (jit compiles dominate first
# calls). Select explicitly with HYPOTHESIS_PROFILE=ci|dev.
try:
    from hypothesis import settings as _hsettings

    _hsettings.register_profile("ci", derandomize=True, deadline=None,
                                print_blob=True)
    _hsettings.register_profile("dev", deadline=None)
    _hsettings.load_profile(os.environ.get(
        "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))
except ImportError:                                       # pragma: no cover
    pass  # hypothesis is an optional dev dependency; seeded fallbacks run

# Default geometries for coded-memory-system tests. The cycle engine is
# compile-dominated on CPU, so tests should share these small shapes (and
# thereby jit caches) rather than inventing their own: n_rows/lengths large
# enough to exercise multi-region dynamic coding, small enough that the fast
# tier stays fast. Heavier sweeps belong behind ``-m slow``.
SMALL_N_ROWS = 64
SMALL_TRACE_LEN = 32


def rand_trace(rng, n_cores, length, n_banks, n_rows, write_frac=0.45):
    """Seeded random request streams — the shared test-trace builder
    (import as ``from conftest import rand_trace``)."""
    from repro.core.system import Trace
    return Trace(
        bank=jnp.asarray(rng.integers(0, n_banks, (n_cores, length)), jnp.int32),
        row=jnp.asarray(rng.integers(0, n_rows, (n_cores, length)), jnp.int32),
        is_write=jnp.asarray(rng.random((n_cores, length)) < write_frac),
        data=jnp.asarray(rng.integers(1, 1 << 20, (n_cores, length)), jnp.int32),
        valid=jnp.asarray(rng.random((n_cores, length)) < 0.9),
    )


# ------------------------------------------------------------------- oracle
# Helpers shared by the conformance suites (tests/test_conformance.py,
# tests/test_scheduler_equiv.py): build the NumPy golden-model twin of a
# production system and assert full state equality against it.

def oracle_twin(system):
    """The ``repro.oracle`` golden model configured like ``system`` (a
    ``CodedMemorySystem``): same allocation, same active geometry, same
    tunables. The oracle derives its own scheme tables from the name."""
    from repro.oracle import OracleMemorySystem, OracleParams

    p, tn = system.p, system.tunables
    int32_max = np.iinfo(np.int32).max

    def active(v, alloc):
        v = int(v)
        return alloc if v == int32_max else min(v, alloc)

    op = OracleParams(
        n_data=p.n_data, n_rows=p.n_rows, region_size=p.region_size,
        n_regions=p.n_regions, n_slots=p.n_slots, n_active=p.n_active,
        queue_depth=p.queue_depth, recode_cap=p.recode_cap,
        recode_budget=p.recode_budget, coalesce=p.coalesce,
        encode_rows_per_cycle=p.encode_rows_per_cycle,
        region_size_active=active(tn.region_size_active, p.region_size),
        n_regions_active=active(tn.n_regions_active, p.n_regions),
        n_slots_active=active(tn.n_slots_active, p.n_active),
        select_period=int(tn.select_period), wq_hi=int(tn.wq_hi),
        wq_lo=int(tn.wq_lo), telemetry=p.telemetry, faults=p.faults)
    return OracleMemorySystem(system.tables.scheme.name, op,
                              n_cores=system.n_cores)


_ORACLE_ARRAY_FIELDS = (
    "fresh_loc", "parity_valid", "region_slot", "slot_region",
    "access_count", "parked_count", "rc_bank", "rc_row", "rc_valid",
    "rq_row", "rq_age", "rq_valid", "wq_row", "wq_age", "wq_valid",
    "wq_data", "banks_data", "parity_data", "golden")
_ORACLE_SCALAR_FIELDS = (
    "enc_region", "enc_remaining", "enc_slot", "switches", "write_mode",
    "cycle", "served_reads", "served_writes", "degraded_reads",
    "parked_writes", "rc_dropped")
_ORACLE_WIDE_FIELDS = ("read_latency_sum", "write_latency_sum",
                       "stall_cycles")


def assert_state_matches_oracle(st, ost, label=""):
    """Every leaf of a SimState equals the golden model's: the memory
    arrays bit for bit (including stale queue/ring contents — retired slots
    keep identical residue in both models), the scalars exactly, the wide
    (lo, hi) counters as integers."""
    from repro.core.state import wide_total

    host = jax.device_get(st)
    m = host.mem
    for name in _ORACLE_ARRAY_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(m, name)), getattr(ost, name),
            err_msg=f"{label}: field {name!r}")
    for name in _ORACLE_SCALAR_FIELDS:
        assert int(getattr(m, name)) == int(getattr(ost, name)), \
            f"{label}: field {name!r}"
    for name in _ORACLE_WIDE_FIELDS:
        assert wide_total(getattr(m, name)) == getattr(ost, name), \
            f"{label}: field {name!r}"
    np.testing.assert_array_equal(np.asarray(host.core_ptr), ost.core_ptr,
                                  err_msg=f"{label}: core_ptr")
    assert int(host.done_cycle) == ost.done_cycle, f"{label}: done_cycle"
    # telemetry planes (repro.obs): both models carry them or neither does;
    # each plane must match the oracle's independent derivation exactly
    assert (m.tele is None) == (ost.tele is None), \
        f"{label}: telemetry presence mismatch"
    if m.tele is not None:
        from repro.obs.planes import Telemetry

        for name in Telemetry._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(m.tele, name)).astype(np.int64),
                np.asarray(getattr(ost.tele, name)),
                err_msg=f"{label}: tele.{name}")
    # fault leaf (repro.faults): schedule + progress, compared field by
    # field against the oracle's independent re-derivation
    assert (m.fault is None) == (ost.fault is None), \
        f"{label}: fault presence mismatch"
    if m.fault is not None:
        from repro.faults.plan import FaultState

        for name in FaultState._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(m.fault, name)).astype(np.int64),
                np.asarray(getattr(ost.fault, name)).astype(np.int64),
                err_msg=f"{label}: fault.{name}")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def small_geom():
    """(n_rows, trace_length) for quick end-to-end memory-system tests."""
    return SMALL_N_ROWS, SMALL_TRACE_LEN


@pytest.fixture
def sweep_compile_count():
    """Callable returning how many device programs the sweep engine has
    compiled so far (the jit cache size of its batched scan). Take a delta
    around ``run_points`` to assert the compile count of a grid."""
    from repro.analysis import guard

    if not guard.available("sweep"):
        # private jax API; don't fail unrelated tests on a jax upgrade
        pytest.skip("jit._cache_size() not available in this jax version")
    return lambda: guard.cache_size("sweep")


@pytest.fixture
def compile_guard():
    """The generalized recompile guard (``repro.analysis.recompile_guard``)
    with the availability skip applied: yields the context-manager factory.

        with compile_guard("kernels.xor_encode", max_compiles=1):
            ...   # region may compile at most one new program

    Targets are ``repro.analysis.guard.GUARDED`` names or jitted
    callables; ``g.compiles()``/``g.deltas()`` give exact counts."""
    from repro.analysis import guard

    if not guard.available("sweep"):
        pytest.skip("jit._cache_size() not available in this jax version")
    return guard.recompile_guard
