"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single device; only launch/dryrun.py forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Default geometries for coded-memory-system tests. The cycle engine is
# compile-dominated on CPU, so tests should share these small shapes (and
# thereby jit caches) rather than inventing their own: n_rows/lengths large
# enough to exercise multi-region dynamic coding, small enough that the fast
# tier stays fast. Heavier sweeps belong behind ``-m slow``.
SMALL_N_ROWS = 64
SMALL_TRACE_LEN = 32


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def small_geom():
    """(n_rows, trace_length) for quick end-to-end memory-system tests."""
    return SMALL_N_ROWS, SMALL_TRACE_LEN
