"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single device; only launch/dryrun.py forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Default geometries for coded-memory-system tests. The cycle engine is
# compile-dominated on CPU, so tests should share these small shapes (and
# thereby jit caches) rather than inventing their own: n_rows/lengths large
# enough to exercise multi-region dynamic coding, small enough that the fast
# tier stays fast. Heavier sweeps belong behind ``-m slow``.
SMALL_N_ROWS = 64
SMALL_TRACE_LEN = 32


def rand_trace(rng, n_cores, length, n_banks, n_rows, write_frac=0.45):
    """Seeded random request streams — the shared test-trace builder
    (import as ``from conftest import rand_trace``)."""
    from repro.core.system import Trace
    return Trace(
        bank=jnp.asarray(rng.integers(0, n_banks, (n_cores, length)), jnp.int32),
        row=jnp.asarray(rng.integers(0, n_rows, (n_cores, length)), jnp.int32),
        is_write=jnp.asarray(rng.random((n_cores, length)) < write_frac),
        data=jnp.asarray(rng.integers(1, 1 << 20, (n_cores, length)), jnp.int32),
        valid=jnp.asarray(rng.random((n_cores, length)) < 0.9),
    )


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def small_geom():
    """(n_rows, trace_length) for quick end-to-end memory-system tests."""
    return SMALL_N_ROWS, SMALL_TRACE_LEN


@pytest.fixture
def sweep_compile_count():
    """Callable returning how many device programs the sweep engine has
    compiled so far (the jit cache size of its batched scan). Take a delta
    around ``run_points`` to assert the compile count of a grid."""
    from repro.sweep import engine

    if not hasattr(engine._scan_batch, "_cache_size"):
        # private jax API; don't fail unrelated tests on a jax upgrade
        pytest.skip("jit._cache_size() not available in this jax version")
    return lambda: engine._scan_batch._cache_size()
