"""repro.traces: chunked replay must be bit-identical to single-shot run(),
ingestion formats must round-trip, the profiler must recover the synthetic
generators' band structure, and its region-priors must never hurt."""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import rand_trace
from repro.core.codes import get_tables
from repro.core.state import make_params, make_tunables
from repro.core.system import CodedMemorySystem, Trace, drain_bound
from repro.sim.trace import TraceSpec, addr_to_bank_row, banded_trace
from repro.traces import (TraceSource, chunk_iter, load_npz, load_trace,
                          profile_trace, requests_to_trace, save_npz,
                          stream_file, stream_replay, stream_replay_points,
                          strip_windows)
from repro.traces.formats import iter_gem5, iter_ramulator

DATA = os.path.join(os.path.dirname(__file__), "data")

N_ROWS, N_CORES, TLEN = 32, 3, 10


def _system(alpha=0.25, r=0.125):
    t = get_tables("scheme_i")
    p = make_params(t, n_rows=N_ROWS, alpha=alpha, r=r, recode_cap=8)
    return CodedMemorySystem(t, p, n_cores=N_CORES,
                             tunables=make_tunables(select_period=8))


# one shared system (= one jit cache) for the whole module
_SYS = _system()


def _split(trace: Trace, cuts):
    """Cut a trace into chunks at the given time offsets."""
    arrs = [np.asarray(x) for x in trace]
    T = arrs[0].shape[1]
    prev = 0
    for c in list(cuts) + [T]:
        if c > prev:
            yield Trace(*(jnp.asarray(a[:, prev:c]) for a in arrs))
            prev = c


# ------------------------------------------------------------ chunked replay
@pytest.mark.parametrize("chunk_len", [1, 3, 10, 14])
def test_stream_replay_bit_identical(chunk_len):
    """Any staging chunk length — including 1 and tails longer than the
    trace — replays bit-identically to single-shot run()."""
    sys_ = _SYS
    rng = np.random.default_rng(5)
    trace = rand_trace(rng, N_CORES, TLEN, sys_.p.n_data, N_ROWS)
    single = sys_.run(trace, drain_bound(N_CORES, TLEN))
    got = stream_replay(sys_, trace, chunk_len=chunk_len)
    assert strip_windows(got) == single


def test_stream_replay_source_splits_invisible(compile_guard):
    """The rolling-window source normalizes arbitrary ingest chunking: the
    same staging length over differently-split sources is identical — and
    shares one compiled chunk program (ingest chunking must never reach
    the compile key)."""
    sys_ = _SYS
    rng = np.random.default_rng(9)
    trace = rand_trace(rng, N_CORES, TLEN, sys_.p.n_data, N_ROWS)
    single = sys_.run(trace, drain_bound(N_CORES, TLEN))
    splits = ([2], [1, 2, 3, 4, 9], [5], [])
    with compile_guard("stream", max_compiles=None) as g:
        got = stream_replay(sys_, _split(trace, splits[0]), chunk_len=4)
        assert strip_windows(got) == single, splits[0]
        first = g.compiles()
        for cuts in splits[1:]:
            got = stream_replay(sys_, _split(trace, cuts), chunk_len=4)
            assert strip_windows(got) == single, cuts
    assert g.compiles() == first, "ingest split leaked into the compile key"


def test_stream_replay_window_stats_account_for_all_latency():
    """The per-window latency series partitions the scalar sums exactly."""
    sys_ = _SYS
    rng = np.random.default_rng(3)
    trace = rand_trace(rng, N_CORES, TLEN, sys_.p.n_data, N_ROWS)
    res = stream_replay(sys_, trace, chunk_len=3)
    n_r = sum(n for n, _ in res.window_read_latency)
    n_w = sum(n for n, _ in res.window_write_latency)
    assert n_r == res.served_reads and n_w == res.served_writes
    tot_r = sum(n * avg for n, avg in res.window_read_latency)
    assert tot_r == pytest.approx(res.avg_read_latency * max(n_r, 1))


def test_window_deltas_sum_to_totals_fig18_workload():
    """On a fig18-style point (banded trace, coded scheme, telemetry on)
    the per-window series partitions every run total exactly: served
    counts, latency sums, and — with the planes enabled — the per-window
    log2 latency-histogram deltas, whose mass equals each window's count
    and whose sum equals the final histogram."""
    from repro.obs.planes import HIST_BINS, snapshot
    from repro.sweep.engine import system_for
    from repro.sweep.workloads import build_trace
    from repro.sweep import SweepPoint
    pt = SweepPoint(scheme="scheme_i", trace="banded", alpha=0.25, r=0.05,
                    n_rows=64, length=32, select_period=16, telemetry=True)
    sys_ = system_for(pt)
    res = stream_replay(sys_, build_trace(pt), chunk_len=8,
                        tn=sys_.tunables)
    assert len(res.window_read_latency) > 1, "need multiple windows"
    for series, total, avg in (
            (res.window_read_latency, res.served_reads,
             res.avg_read_latency),
            (res.window_write_latency, res.served_writes,
             res.avg_write_latency)):
        assert sum(w[0] for w in series) == total
        assert sum(w[0] * w[1] for w in series) \
            == pytest.approx(avg * max(total, 1))
        # telemetry windows carry the histogram delta as a 3rd element
        hists = np.array([w[2] for w in series])
        assert hists.shape[1] == HIST_BINS
        assert (hists >= 0).all()
        np.testing.assert_array_equal(hists.sum(axis=1),
                                      [w[0] for w in series])
    # ... and the window deltas reassemble the final device-side planes
    trace = build_trace(pt)
    st, _ = sys_._run(sys_.init(), trace,
                      drain_bound(sys_.n_cores, trace.bank.shape[1]))
    snap = snapshot(st)
    np.testing.assert_array_equal(
        np.array([w[2] for w in res.window_read_latency]).sum(axis=0),
        snap.lat_hist_read)
    np.testing.assert_array_equal(
        np.array([w[2] for w in res.window_write_latency]).sum(axis=0),
        snap.lat_hist_write)


def test_stream_replay_batched_matches_engine():
    """The chunk axis composes with the engine's point axis: a whole
    shape-compatible batch streams as one vmapped program, per-point
    bit-identical to the batched single-shot engine."""
    from repro.sweep import SweepPoint, grid, run_points
    from repro.sweep.workloads import build_trace
    base = SweepPoint(scheme="scheme_i", alpha=0.25, r=0.125, n_rows=N_ROWS,
                      n_cores=N_CORES, n_banks=8, length=TLEN,
                      select_period=16)
    pts = grid(base, alpha=(0.25, 0.5), seed=(0, 1))
    traces = [build_trace(pt) for pt in pts]
    want = run_points(pts, traces=traces)
    got = stream_replay_points(pts, traces, chunk_len=4)
    assert [strip_windows(g) for g in got] == want


@pytest.mark.slow
@pytest.mark.timeout(600)   # two full compiles on a forced 4-device host
def test_stream_points_padded_sharding_multidevice_subprocess():
    """Multi-device chunked replay: a streamed batch whose size does NOT
    divide the device count is padded with masked replica points, sharded
    across a forced 4-device host every chunk step, and returns the same
    per-point results as the unsharded single-shot engine (replicas
    stripped)."""
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
assert len(jax.devices()) == 4
from repro.sweep import SweepPoint, grid, run_points
from repro.sweep.engine import clear_caches
from repro.traces import stream_replay_points, strip_windows

BASE = SweepPoint(scheme="scheme_i", alpha=0.25, r=0.125, n_rows=32,
                  n_cores=3, n_banks=8, length=10, select_period=16)
pts = grid(BASE, alpha=(0.25, 0.5), r=(0.125, 0.25), seed=(0, 1))[:6]
assert len(pts) % 4 != 0          # forces the pad-to-device-multiple path
from repro.sweep.workloads import build_trace
traces = [build_trace(pt) for pt in pts]
streamed = stream_replay_points(pts, traces, chunk_len=4, shard=True)
clear_caches()                    # fresh program, no sharding
want = run_points(pts, traces=traces, shard=False)
assert len(streamed) == len(pts)
for i, (a, b) in enumerate(zip(streamed, want)):
    assert strip_windows(a) == b, (i, a, b)
print("STREAM_SHARDED_OK")
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "STREAM_SHARDED_OK" in out.stdout, out.stdout + out.stderr


# -------------------------------------------------------- hypothesis variant
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from([1, 2, 3, 5, 7, 10, 13]),
           st.lists(st.integers(1, TLEN - 1), max_size=4, unique=True))
    def test_stream_replay_random_splits_hypothesis(seed, chunk_len, cuts):
        """Random traces × random source splits × random staging lengths:
        streamed == single-shot, bit for bit (the oracle-anchored variant
        lives in tests/test_conformance.py)."""
        sys_ = _SYS
        rng = np.random.default_rng(seed)
        trace = rand_trace(rng, N_CORES, TLEN, sys_.p.n_data, N_ROWS)
        single = sys_.run(trace, drain_bound(N_CORES, TLEN))
        got = stream_replay(sys_, _split(trace, sorted(cuts)),
                            chunk_len=chunk_len)
        assert strip_windows(got) == single


# ------------------------------------------------------------------ source
def test_trace_source_rolling_window_trims():
    """The rolling window holds only (spread + stage) columns: staging at
    advanced positions drops the consumed prefix."""
    rng = np.random.default_rng(1)
    trace = rand_trace(rng, 2, 64, 4, 16)
    src = TraceSource.from_chunks(chunk_iter(trace, 8), prefetch=False)
    src.stage(np.array([0, 0]), 4)
    assert src.base == 0
    src.stage(np.array([40, 42]), 4)
    assert src.base == 40                     # consumed columns were dropped
    buffered = src._buf[0].shape[1]
    assert buffered <= 16                     # spread (2) + stage, chunk-rounded
    # staging is position-exact despite the trim
    chunk, se = src.stage(np.array([40, 42]), 4)
    np.testing.assert_array_equal(np.asarray(chunk.row)[0],
                                  np.asarray(trace.row)[0, 40:44])
    np.testing.assert_array_equal(np.asarray(chunk.row)[1],
                                  np.asarray(trace.row)[1, 42:46])


def test_trace_source_prefetch_propagates_ingest_errors():
    """A failed ingest must fail the replay, not masquerade as a short
    stream (the background prefetch thread relays its exception)."""
    def bad_chunks():
        rng = np.random.default_rng(0)
        yield rand_trace(rng, 2, 4, 4, 16)
        raise ValueError("malformed line 17")

    src = TraceSource.from_chunks(bad_chunks(), prefetch=True)
    with pytest.raises(ValueError, match="malformed line 17"):
        src.stage(np.array([0, 0]), 64)   # needs data past the first chunk


def test_requests_to_trace_refuses_truncation():
    """A too-small ``length`` must raise, not silently drop the stream's
    tail and report results for a trace that never fully replayed."""
    with pytest.raises(ValueError, match="stream has 10"):
        requests_to_trace(np.arange(10), np.zeros(10, bool), n_cores=2,
                          length=3)
    from repro.sweep.workloads import build_trace, file_point
    # and a file: sweep point whose length is too small names the point
    lines = "".join(f"{i} R\n" for i in range(40))
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "big.trace")
        with open(path, "w") as f:
            f.write(lines)
        pt = file_point(os.path.join(DATA, "tiny_trace.npz")).replace(
            trace=f"file:{path}", length=2, n_cores=2, suite="s")
        with pytest.raises(ValueError) as ei:
            build_trace(pt, index=7)
        assert "[7]" in str(ei.value) and "stream has 40" in str(ei.value)


def test_file_point_rejects_mismatched_geometry(tmp_path):
    """An .npz mapped for a different memory geometry must fail loudly —
    inside jit the out-of-range rows would clamp and corrupt results."""
    from repro.sweep.workloads import build_trace, file_point
    rng = np.random.default_rng(0)
    path = save_npz(os.path.join(tmp_path, "big.npz"),
                    rand_trace(rng, 2, 6, 8, 512))   # rows up to 511
    pt = file_point(path, n_rows=64, n_banks=8)      # but a 64-row system
    with pytest.raises(ValueError, match="different memory geometry"):
        build_trace(pt)


def test_trace_source_stream_end_marks_tails():
    rng = np.random.default_rng(2)
    trace = rand_trace(rng, 2, 10, 4, 16)
    src = TraceSource.from_trace(trace)
    _, se = src.stage(np.array([0, 8]), 4)
    se = np.asarray(se)
    assert se[0] > 4          # more data behind the buffer
    assert se[1] == 2         # stream ends inside: 2 staged requests remain
    assert not src.exhausted(np.array([10, 9]))
    assert src.exhausted(np.array([10, 10]))


# ------------------------------------------------------------------ formats
def test_ramulator_fixture_golden():
    reqs = list(iter_ramulator(os.path.join(DATA, "tiny_ramulator.trace")))
    assert reqs == [(0, False), (5, True), (17, False), (3, True),
                    (9, False), (12, False)]
    tr = requests_to_trace(*zip(*reqs), n_cores=2, n_banks=4, n_rows=8)
    bank, row = addr_to_bank_row(np.array([0, 5, 17, 3, 9, 12]), 4, 8)
    # round-robin deal: request i -> core i % 2, slot i // 2
    np.testing.assert_array_equal(np.asarray(tr.bank),
                                  bank.reshape(3, 2).T)
    np.testing.assert_array_equal(np.asarray(tr.row),
                                  row.reshape(3, 2).T)
    np.testing.assert_array_equal(np.asarray(tr.is_write),
                                  [[False, False, False], [True, True, False]])
    assert np.asarray(tr.valid).all()


def test_gem5_fixture_golden():
    reqs = list(iter_gem5(os.path.join(DATA, "tiny_gem5.gem5")))
    assert reqs == [(0x000, False), (0x040, True), (0x080, False),
                    (0x100, True), (0x140, False)]
    tr = load_trace(os.path.join(DATA, "tiny_gem5.gem5"), n_cores=1,
                    n_banks=4, n_rows=8, line_bytes=64)
    np.testing.assert_array_equal(np.asarray(tr.bank), [[0, 1, 2, 0, 1]])
    np.testing.assert_array_equal(np.asarray(tr.row), [[0, 0, 0, 1, 1]])
    np.testing.assert_array_equal(np.asarray(tr.is_write),
                                  [[False, True, False, True, False]])


def test_npz_fixture_roundtrip_and_replay():
    """The canonical .npz form is lossless, and an ingested file replays
    through the batched sweep engine exactly like its in-memory original."""
    path = os.path.join(DATA, "tiny_trace.npz")
    tr = load_npz(path)
    spec = TraceSpec(n_cores=4, length=12, n_banks=8, n_rows=64, seed=7)
    want = banded_trace(spec)
    for a, b in zip(tr, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_npz_save_load_roundtrip(tmp_path):
    rng = np.random.default_rng(4)
    trace = rand_trace(rng, 3, 9, 8, 32)
    path = save_npz(os.path.join(tmp_path, "t.npz"), trace)
    back = load_npz(path)
    for a, b in zip(back, trace):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_file_matches_whole_file_load(tmp_path):
    """Lazy chunked file reading deals requests and synthesizes payloads
    exactly like a whole-file load — chunk boundaries are invisible."""
    lines = [f"{16 * i + (i % 5)} {'W' if i % 3 == 0 else 'R'}\n"
             for i in range(23)]
    path = os.path.join(tmp_path, "long.trace")
    with open(path, "w") as f:
        f.writelines(lines)
    whole = load_trace(path, n_cores=2, n_banks=4, n_rows=32)
    for chunk_len in (4, 9):       # 9: short tail chunk (23 = 18 + 5 reqs)
        chunks = list(stream_file(path, chunk_len, n_cores=2, n_banks=4,
                                  n_rows=32))
        cat = [np.concatenate([np.asarray(getattr(c, f)) for c in chunks],
                              axis=1) for f in Trace._fields]
        # the tail chunk is SHORT, not padded: the concatenation must equal
        # the whole-file load column for column (a padded tail would append
        # idle columns that delay the replay's completion cycle)
        for name, a, b in zip(Trace._fields, cat, whole):
            np.testing.assert_array_equal(a, np.asarray(b),
                                          err_msg=f"{name} chunk={chunk_len}")


def test_file_point_rides_sweep_engine():
    """A file: point flows through partition/batch/replay like any other."""
    from repro.sim.ramulator import simulate
    from repro.sweep import run_points
    from repro.sweep.workloads import build_trace, file_point
    path = os.path.join(DATA, "tiny_trace.npz")
    pt = file_point(path, alpha=0.25, r=0.125, n_rows=64, select_period=16)
    assert (pt.n_cores, pt.length) == (4, 12)
    tr = build_trace(pt)
    got = run_points([pt])[0]
    want = simulate(pt.scheme, tr, pt.n_rows, alpha=pt.alpha, r=pt.r,
                    n_cycles=pt.resolved_cycles(),
                    select_period=pt.select_period)
    assert got == want


def test_build_trace_error_names_point():
    """The error path names the failing point (suite + index), not just the
    unknown key — chunked file-backed sweeps are unattributable otherwise."""
    from repro.sweep import run_points
    from repro.sweep.workloads import build_trace, suite
    pts = suite("trace_zoo")
    bad = pts[2].replace(trace="no_such_generator")
    with pytest.raises(KeyError) as ei:
        build_trace(bad, index=2)
    msg = str(ei.value)
    assert "trace_zoo" in msg and "[2]" in msg and "no_such_generator" in msg
    with pytest.raises(FileNotFoundError) as ei2:
        run_points([pts[0].replace(trace="file:/does/not/exist.npz")])
    assert "trace_zoo" in str(ei2.value) and "[0]" in str(ei2.value)


# ----------------------------------------------------------------- profiler
def test_profiler_recovers_generator_bands():
    """Fig 15 reproduction: band detection on ``banded_trace`` recovers the
    generator's band count and extents."""
    n_banks, n_rows = 8, 512
    spec = TraceSpec(n_cores=8, length=400, n_banks=n_banks, n_rows=n_rows,
                     seed=0)
    trace = banded_trace(spec, n_bands=2)
    prof = profile_trace(trace, n_banks=n_banks, n_rows=n_rows, window=256)
    bands = prof.bands()
    assert len(bands) == 2
    space = n_banks * n_rows
    width_rows = max(space // 32, n_banks * 4) // n_banks
    tol = 2 * prof.bin_rows
    for i, band in enumerate(bands):
        center = (i + 0.5) * space / 2 / n_banks   # generator band center
        assert abs(band.center - center) <= tol
        assert abs((band.row_hi - band.row_lo + 1) - width_rows) <= 2 * tol
        assert band.persistence >= 0.5
    assert sum(b.weight for b in bands) > 0.9      # bands carry the traffic
    # profile basics ride along
    assert prof.n_requests == int(np.asarray(trace.valid).sum())
    assert 0.15 < prof.write_frac < 0.45


def test_profiler_streaming_equals_one_shot():
    """Chunked accumulation is the same profile as one-shot (windows are
    request-aligned, so chunk boundaries are invisible)."""
    spec = TraceSpec(n_cores=4, length=200, n_banks=8, n_rows=128, seed=1)
    trace = banded_trace(spec)
    one = profile_trace(trace, 8, 128, window=64)
    chunked = profile_trace(chunk_iter(trace, 17), 8, 128, window=64)
    assert one.n_windows == chunked.n_windows
    np.testing.assert_array_equal(one.row_hist, chunked.row_hist)
    np.testing.assert_array_equal(one.presence, chunked.presence)
    np.testing.assert_allclose(one.bank_window_var, chunked.bank_window_var)


def test_profiler_empty_trace():
    """A trace with no valid requests: zero counts, no windows, no bands,
    all-padding priors, and a defined (zero) Fano factor — not NaNs."""
    rng = np.random.default_rng(0)
    trace = rand_trace(rng, 2, 8, 4, 16)._replace(
        valid=jnp.zeros((2, 8), bool), is_write=jnp.zeros((2, 8), bool))
    prof = profile_trace(trace, n_banks=4, n_rows=16, window=4)
    assert prof.n_requests == prof.reads == prof.writes == 0
    assert prof.n_windows == 0
    assert prof.bank_hist.sum() == 0 and prof.row_hist.sum() == 0
    assert prof.bands() == []
    assert prof.write_frac == 0.0
    assert prof.burstiness == 0.0
    np.testing.assert_array_equal(prof.region_priors(4, 4, k=3),
                                  [-1, -1, -1])


def test_profiler_single_bank_trace():
    """Every request on one bank: the histogram concentrates, the hot bank's
    windowed counts are constant (zero variance ⇒ Fano 0 per bank), and
    band detection still sees the row band."""
    rng = np.random.default_rng(1)
    n_banks, n_rows, T = 4, 64, 32
    trace = rand_trace(rng, 2, T, 1, n_rows)._replace(
        bank=jnp.full((2, T), 2, jnp.int32),
        row=jnp.asarray(rng.integers(8, 16, (2, T)), jnp.int32),
        valid=jnp.ones((2, T), bool))
    prof = profile_trace(trace, n_banks=n_banks, n_rows=n_rows, window=16)
    assert prof.bank_hist[2] == prof.n_requests == 2 * T
    assert prof.bank_hist.sum() == prof.bank_hist[2]
    # full 16-request windows always hold 16 bank-2 requests: variance 0
    assert prof.bank_window_var[2] == 0.0
    assert prof.burstiness == 0.0
    bands = prof.bands(min_weight=0.5)
    assert len(bands) == 1
    assert bands[0].row_lo >= 8 - prof.bin_rows
    assert bands[0].row_hi <= 15 + prof.bin_rows


def test_profiler_window_larger_than_trace():
    """A window that never fills leaves the presence statistics empty —
    band detection must report no bands rather than divide by zero, while
    the aggregate histograms still accumulate."""
    rng = np.random.default_rng(2)
    trace = rand_trace(rng, 2, 10, 4, 32)
    prof = profile_trace(trace, n_banks=4, n_rows=32, window=512)
    assert prof.n_windows == 0
    assert prof.n_requests > 0
    assert prof.row_hist.sum() == prof.n_requests
    assert prof.bands() == []
    assert prof.burstiness == 0.0
    # priors need no windows — they rank the aggregate row histogram
    pri = prof.region_priors(8, 4)
    assert pri.size > 0


def test_profiler_all_writes_mix():
    """A pure-write stream: the mix saturates at 1.0 and the read counter
    stays zero (windowing, bands and priors are operation-agnostic)."""
    rng = np.random.default_rng(3)
    T = 24
    trace = rand_trace(rng, 2, T, 4, 32)._replace(
        is_write=jnp.ones((2, T), bool), valid=jnp.ones((2, T), bool))
    prof = profile_trace(trace, n_banks=4, n_rows=32, window=8)
    assert prof.write_frac == 1.0
    assert prof.reads == 0 and prof.writes == prof.n_requests == 2 * T
    assert prof.n_windows == (2 * T) // 8


def test_region_priors_rank_hot_regions():
    spec = TraceSpec(n_cores=8, length=300, n_banks=8, n_rows=256, seed=2)
    trace = banded_trace(spec, n_bands=2)
    prof = profile_trace(trace, 8, 256, window=128)
    rs = 13                                        # r=0.05 over 256 rows
    n_regions = -(-256 // rs)
    pri = prof.region_priors(rs, n_regions, k=4)
    assert pri.shape == (4,)
    counts = np.zeros(n_regions, np.int64)
    np.add.at(counts, np.arange(256) // rs, prof.row_hist)
    ranked = np.argsort(-counts, kind="stable")
    np.testing.assert_array_equal(pri, ranked[:4])
    # hot regions must carry real traffic
    assert counts[pri[0]] > counts.mean()


@pytest.mark.parametrize("suite_name,kw", [
    ("paper_fig18", dict(schemes=("scheme_i",), alphas=(0.1, 0.25))),
])
def test_region_priors_never_increase_stalls_fast(suite_name, kw):
    _check_priors_no_stall_regression(suite_name, kw)


@pytest.mark.slow
@pytest.mark.parametrize("suite_name,kw", [
    ("paper_fig18", {}),
    ("paper_fig19", {}),
    ("paper_fig20", {}),
])
def test_region_priors_never_increase_stalls(suite_name, kw):
    _check_priors_no_stall_regression(suite_name, kw)


def _check_priors_no_stall_regression(suite_name, kw):
    """Seeding the dynamic unit with profiled region-priors must never cost
    stall cycles vs a cold start on the paper-figure suites."""
    from repro.sweep import SweepPoint, run_points
    from repro.sweep.workloads import build_trace, suite
    base_pt = SweepPoint(n_rows=64, n_cores=8, n_banks=8, length=48,
                         select_period=32)
    pts = suite(suite_name, base_pt, **kw)
    traces = [build_trace(pt) for pt in pts]
    priors = []
    for pt, tr in zip(pts, traces):
        prof = profile_trace(tr, n_banks=pt.n_banks, n_rows=pt.n_rows,
                             window=64)
        rs, nr, ns = pt.derived_slots()
        priors.append(prof.region_priors(rs, nr, k=max(ns, 1)))
    cold = run_points(pts, traces=traces)
    seeded = run_points(pts, traces=traces, region_priors=priors)
    cold_stalls = sum(r.stall_cycles for r in cold)
    seeded_stalls = sum(r.stall_cycles for r in seeded)
    assert seeded_stalls <= cold_stalls, (suite_name, seeded_stalls,
                                          cold_stalls)


# --------------------------------------------------------------- drain bound
def test_drain_bound_single_helper():
    """One helper, one derivation: the looped driver's default budget IS
    drain_bound, and the chunked budget only adds the carried backlog."""
    from repro.sim.ramulator import default_n_cycles
    from repro.traces.stream import chunk_bound
    rng = np.random.default_rng(0)
    trace = rand_trace(rng, 3, 10, 8, 32)
    assert default_n_cycles(trace) == drain_bound(3, 10)
    sys_ = _SYS
    backlog = 2 * sys_.p.n_data * sys_.p.queue_depth
    assert chunk_bound(sys_, 16) == drain_bound(sys_.n_cores, 16,
                                                backlog=backlog)
    assert drain_bound(3, 10, backlog=5) > drain_bound(3, 10)


# ------------------------------------------------------------- slow soak
@pytest.mark.slow
@pytest.mark.timeout(3600)
def test_stream_million_requests():
    """A ≥1M-request trace replays through stream_replay under a fixed
    per-chunk device footprint, completes, and serves every request."""
    from repro.sim.trace import uniform_trace
    n_cores, chunk_cols, n_chunks = 8, 2048, 62
    n_banks, n_rows = 8, 512
    total = n_cores * chunk_cols * n_chunks
    assert total >= 1_000_000

    def chunks():
        for i in range(n_chunks):
            spec = TraceSpec(n_cores=n_cores, length=chunk_cols,
                             n_banks=n_banks, n_rows=n_rows, seed=1000 + i)
            yield uniform_trace(spec)

    t = get_tables("scheme_i")
    p = make_params(t, n_rows=n_rows, alpha=1.0, r=0.05)
    sys_ = CodedMemorySystem(t, p, n_cores=n_cores)
    res = stream_replay(sys_, chunks(), chunk_len=chunk_cols)
    assert res.completed
    assert res.served_reads + res.served_writes == total
    assert len(res.window_read_latency) >= n_chunks


# ------------------------------------------- flaky sources & retry/backoff
class _FlakyChunks:
    """Re-pullable iterator (NOT a generator) that fails transiently:
    ``fail_on[i] = n`` makes the pull of chunk ``i`` raise n times before
    succeeding — the shape of an NFS hiccup or racing writer."""

    def __init__(self, chunks, fail_on):
        self.chunks, self.i, self.fails = list(chunks), 0, dict(fail_on)

    def __iter__(self):
        return self

    def __next__(self):
        if self.fails.get(self.i, 0) > 0:
            self.fails[self.i] -= 1
            raise OSError(f"transient read error at chunk {self.i}")
        if self.i >= len(self.chunks):
            raise StopIteration
        c = self.chunks[self.i]
        self.i += 1
        return c


def _drain_source(src, n_cores, chunk_len=4):
    """Stage a source to exhaustion, returning the staged bank columns."""
    pos = np.zeros(n_cores, np.int64)
    out = []
    while not src.exhausted(pos):
        chunk, _ = src.stage(pos, chunk_len)
        out.append(np.asarray(chunk.bank))
        pos += chunk_len
    return out


@pytest.mark.parametrize("prefetch", [False, True])
def test_flaky_source_retries_then_streams_identically(prefetch):
    """Transient read errors inside the retry budget are invisible: the
    staged stream equals the in-memory trace, chunk for chunk."""
    rng = np.random.default_rng(11)
    trace = rand_trace(rng, N_CORES, 20, 8, N_ROWS)
    chunks = list(chunk_iter(trace, 4))
    src = TraceSource.from_chunks(
        _FlakyChunks(chunks, fail_on={1: 2, 3: 1}), prefetch=prefetch,
        retries=3, backoff=0.001)
    got = _drain_source(src, N_CORES)
    want = _drain_source(TraceSource.from_chunks(iter(chunks),
                                                 prefetch=False), N_CORES)
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("prefetch", [False, True])
def test_flaky_source_exhausted_retries_raises(prefetch):
    """Once the bounded retry budget is spent the original exception
    surfaces (on the consumer thread) — never a silently short stream."""
    rng = np.random.default_rng(12)
    chunks = list(chunk_iter(rand_trace(rng, 2, 12, 4, 16), 4))
    src = TraceSource.from_chunks(_FlakyChunks(chunks, fail_on={1: 99}),
                                  prefetch=prefetch, retries=2,
                                  backoff=0.001)
    with pytest.raises(OSError, match="transient read error at chunk 1"):
        _drain_source(src, 2)


def test_generator_sources_never_retry():
    """A generator is dead after raising — retrying ``next()`` on one
    yields StopIteration, i.e. a silently truncated stream. The retry
    helper must therefore re-raise generator errors immediately even with
    budget left."""
    from repro.traces.source import _pull_retry

    rng = np.random.default_rng(13)
    chunk = rand_trace(rng, 2, 4, 4, 16)

    def gen():
        yield chunk
        raise OSError("boom")

    it = gen()
    assert _pull_retry(it, 5, 0.001) is chunk
    with pytest.raises(OSError, match="boom"):
        _pull_retry(it, 5, 0.001)


# --------------------------------------------- malformed on-disk traces
def test_trace_format_error_names_file_and_line(tmp_path):
    from repro.traces import TraceFormatError

    p = tmp_path / "bad.trace"
    p.write_text("0x100 R\n0x200\n")
    with pytest.raises(TraceFormatError, match=r"bad\.trace:2"):
        list(iter_ramulator(str(p)))
    g = tmp_path / "bad.gem5"
    g.write_text("100,r,0x40\n101,w\n")
    with pytest.raises(TraceFormatError, match=r"bad\.gem5:2"):
        list(iter_gem5(str(g)))
    # TraceFormatError subclasses ValueError: pre-existing handlers keep
    # catching ingestion failures
    assert issubclass(TraceFormatError, ValueError)


_GARBAGE_LINES = {
    "ramulator": [
        "0x",                  # truncated address
        "R",                   # op with no address
        "deadbeef Q",          # neither token parses
        "0x10 0x20",           # two addresses, no op
        "\x00\x01\x02",        # binary junk
        "W W W",               # ops with no address
        "12 34",               # two addresses (decimal), no op
    ],
    "gem5": [
        "0x",                  # one column
        "R",                   # one column
        "1,r",                 # missing the address column
        "tick r 0x40",         # non-numeric tick
        "deadbeef Q",          # two columns, neither parses
        "\x00\x01\x02",        # binary junk
        "W W W",               # non-numeric tick and address
        "1,z,0x40",            # unknown command token
    ],
}


def test_malformed_text_traces_fuzz(tmp_path):
    """Truncated / garbage / wrong-arity lines spliced into otherwise-valid
    Ramulator and gem5 traces always raise TraceFormatError pointing at the
    exact file:line — never a different exception type, never silent
    acceptance."""
    from repro.traces import TraceFormatError

    rng = np.random.default_rng(7)
    good = {"ramulator": [f"0x{rng.integers(0, 1 << 20):x} "
                          f"{'R' if rng.random() < 0.5 else 'W'}"
                          for _ in range(8)],
            "gem5": [f"{i},{'r' if rng.random() < 0.5 else 'w'},"
                     f"0x{rng.integers(0, 1 << 20):x}" for i in range(8)]}
    parsers = {"ramulator": iter_ramulator, "gem5": iter_gem5}
    ext = {"ramulator": ".trace", "gem5": ".gem5"}
    for fmt in ("ramulator", "gem5"):
        for trial, bad in enumerate(_GARBAGE_LINES[fmt]):
            lines = list(good[fmt])
            at = int(rng.integers(0, len(lines) + 1))
            lines.insert(at, bad)
            path = tmp_path / f"{fmt}_{trial}{ext[fmt]}"
            path.write_text("\n".join(lines) + "\n")
            with pytest.raises(TraceFormatError) as ei:
                list(parsers[fmt](str(path)))
            assert ei.value.path == str(path), (fmt, bad)
            assert ei.value.line == at + 1, (fmt, bad)


def test_malformed_npz_traces_fuzz(tmp_path):
    """The third format: corrupt, truncated, and wrong-keyed .npz files all
    raise TraceFormatError naming the file."""
    from repro.traces import TraceFormatError

    garbage = tmp_path / "garbage.npz"
    garbage.write_bytes(b"\x13\x37 not a zip archive")
    with pytest.raises(TraceFormatError, match="garbage"):
        load_npz(str(garbage))

    wrong = tmp_path / "wrong.npz"
    np.savez(str(wrong), bank=np.zeros((2, 2), np.int32))
    with pytest.raises(TraceFormatError, match="missing"):
        load_npz(str(wrong))

    rng = np.random.default_rng(8)
    whole = tmp_path / "ok.npz"
    save_npz(str(whole), rand_trace(rng, 2, 6, 4, 16))
    blob = whole.read_bytes()
    for frac in (0.2, 0.6, 0.95):          # truncate at several depths
        cut = tmp_path / f"cut_{frac}.npz"
        cut.write_bytes(blob[: int(len(blob) * frac)])
        with pytest.raises(TraceFormatError):
            load_npz(str(cut))
    # and load_trace routes .npz through the same guarded loader
    with pytest.raises(TraceFormatError):
        load_trace(str(garbage))


# --------------------------------------------- checkpointed stream replay
def test_stream_replay_points_kill_and_resume(tmp_path, compile_guard):
    """A replay killed mid-stream resumes from its last committed
    checkpoint bit-identically: the final per-point SimResults (window
    series included) equal the uninterrupted run's. Checkpointing and
    resuming must also reuse the uninterrupted run's compiled chunk
    program — restored carries may not drift in structure or dtype."""
    from repro.checkpoint import latest_step
    from repro.sweep import SweepPoint
    from repro.sweep.workloads import build_trace

    base = SweepPoint(scheme="scheme_i", alpha=0.25, r=0.125, n_rows=N_ROWS,
                      n_cores=N_CORES, n_banks=8, length=TLEN,
                      select_period=16)
    pts = [base.replace(seed=s) for s in (0, 1)]
    traces = [build_trace(pt) for pt in pts]
    ckdir = str(tmp_path / "ck")

    with compile_guard("stream", max_compiles=None) as g:
        want = stream_replay_points(pts, traces, chunk_len=4)
        first = g.compiles()

        # "kill": stop mid-stream after checkpoints have committed
        stream_replay_points(pts, traces, chunk_len=4, checkpoint_dir=ckdir,
                             checkpoint_every=1, max_cycles=8)
        assert latest_step(ckdir) is not None   # a committed step exists
        got = stream_replay_points(pts, traces, chunk_len=4,
                                   checkpoint_dir=ckdir, checkpoint_every=1,
                                   resume=True)
    assert got == want
    assert g.compiles() == first, \
        "checkpoint/resume recompiled the chunk program (carry drift)"

    # resume without a checkpoint directory is a configuration error
    with pytest.raises(ValueError, match="resume"):
        stream_replay_points(pts, traces, chunk_len=4, resume=True)
