"""Runtime tests: fault-tolerant trainer (bit-deterministic recovery),
continuous-batching server, coded KV bank serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import lm
from repro.runtime import kvbank as kb
from repro.runtime.server import Request, ServeConfig, Server
from repro.runtime.trainer import FaultPlan, TrainConfig, Trainer


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


def _tc(tmp, **kw):
    base = dict(steps=12, log_every=100, ckpt_every=5, ckpt_dir=tmp,
                global_batch=4, seq_len=32)
    base.update(kw)
    return TrainConfig(**base)


def test_fault_recovery_is_bit_deterministic(tmp_path, mesh):
    """A crash + restore-from-checkpoint run reaches the SAME final loss as
    an uninterrupted run (pure-function data pipeline + deterministic jit)."""
    cfg = get_config("yi-6b").reduced()
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    t1 = Trainer(cfg, _tc(d1), mesh)
    out1 = t1.run()
    t2 = Trainer(cfg, _tc(d2), mesh)
    out2 = t2.run(fault_plan=FaultPlan([7]))
    assert any("recovering" in e for e in out2["events"])
    assert out1["final_loss"] == pytest.approx(out2["final_loss"], abs=1e-6)


def test_loss_decreases_over_training(tmp_path, mesh):
    cfg = get_config("qwen2.5-3b").reduced()
    tc = _tc(str(tmp_path / "c"), steps=40, ckpt_every=0, global_batch=8)
    tr = Trainer(cfg, tc, mesh)
    out = tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_microbatch_equivalence(tmp_path, mesh):
    """Gradient accumulation (n_micro=2) ≈ single-shot on the same batch."""
    cfg = get_config("yi-6b").reduced()
    t1 = Trainer(cfg, _tc(str(tmp_path / "m1"), steps=3, ckpt_every=0), mesh)
    o1 = t1.run()
    t2 = Trainer(cfg, _tc(str(tmp_path / "m2"), steps=3, ckpt_every=0,
                          n_micro=2), mesh)
    o2 = t2.run()
    assert o1["final_loss"] == pytest.approx(o2["final_loss"], rel=2e-2)


def test_straggler_detection(tmp_path, mesh, monkeypatch):
    cfg = get_config("yi-6b").reduced()
    tr = Trainer(cfg, _tc(str(tmp_path / "s"), steps=8, ckpt_every=0), mesh)
    orig = tr.train_step
    calls = {"n": 0}

    def slow_step(*a):
        calls["n"] += 1
        if calls["n"] == 6:
            import time
            time.sleep(1.0)                 # synthetic straggler
        return orig(*a)

    tr.train_step = slow_step
    out = tr.run()
    assert out["stragglers"] >= 1
    assert any("straggler" in e for e in out["events"])


# ------------------------------------------------------------------- server
def test_server_continuous_batching():
    cfg = get_config("qwen2.5-3b").reduced()
    params = lm.init_params(cfg, jax.random.key(0), max_seq=128)
    sc = ServeConfig(n_slots=2, max_prompt=16, max_seq=64, max_new_tokens=6)
    srv = Server(cfg, sc, params)
    reqs = [Request(rid=i, prompt=[1 + i, 2 + i, 3 + i]) for i in range(5)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == sc.max_new_tokens for r in reqs)
    # more requests than slots => batching actually interleaved
    assert srv.steps_run < sum(len(r.out) for r in reqs)


def test_server_snapshot_recovery():
    cfg = get_config("yi-6b").reduced()
    params = lm.init_params(cfg, jax.random.key(0), max_seq=128)
    sc = ServeConfig(n_slots=2, max_prompt=16, max_seq=64, max_new_tokens=8)
    srv = Server(cfg, sc, params)
    for i in range(2):
        srv.submit(Request(rid=i, prompt=[5, 6, 7]))
    srv.step()
    srv.step()
    snap = srv.snapshot()
    cont = [list(r.out) if r else None for r in srv.slots]
    # simulate node replacement
    srv2 = Server(cfg, sc, params)
    srv2.restore_snapshot(snap)
    srv2.step()
    srv.step()
    t_a = np.asarray(srv.tokens)
    t_b = np.asarray(srv2.tokens)
    np.testing.assert_array_equal(t_a, t_b)   # identical continuation


# ------------------------------------------------------------------ kv bank
def _grow(cfg, lengths, n_kv=1, hd=8):
    b = len(lengths)
    st = kb.init_state(cfg, b, n_kv, hd, jnp.bfloat16)
    k = jnp.ones((b, n_kv, hd), jnp.bfloat16)
    for t in range(max(lengths)):
        active = jnp.asarray([t < L for L in lengths])
        st = kb.append_token(cfg, st, k, k, active=active)
    return st


def test_kvbank_cycles_improve_under_conflict():
    """A churned pool (free-list placement after serving turnover) loads
    banks unevenly — the paper's bank conflict; the coded planner must beat
    the uncoded port count. A lone fresh sequence stripes evenly — no idle
    ports, the paper's worst case — coded == uncoded."""
    cfg = kb.KVBankConfig(n_banks=4, page=4, pool_pages=64, max_pages=32)
    st = _grow(cfg, [80, 16, 16, 16])
    # churned placement with a deterministic hot bank: the long sequence's
    # pages mostly landed where bank-0 pages were freed (phys ≡ 0 mod 4)
    table = np.array(st.page_table)     # writable copy
    hot = [4 * i for i in range(12)]            # 12 pages on bank 0
    rest = [4 * i + 1 + (i % 3) for i in range(8)]   # spread over banks 1-3
    table[0, :20] = hot + rest
    for s_, base in ((1, 32), (2, 44), (3, 56)):
        table[s_, :4] = [base + j for j in range(4)]  # striped small seqs
    st = st._replace(page_table=jnp.asarray(table))
    st = kb.recode(cfg, st)
    plan = kb.plan_reads(cfg, st)
    assert int(plan.coded_cycles) < int(plan.uncoded_cycles)

    stb = _grow(cfg, [64])                      # lone sequence: even striping
    stb = kb.recode(cfg, stb)
    planb = kb.plan_reads(cfg, stb)
    assert int(planb.coded_cycles) == int(planb.uncoded_cycles)


def test_pool_recode_row_gather_matches_masked_reference():
    """Budgeted pool_recode now gathers only the taken rows' member banks;
    the result must stay bit-identical to the historical full-recompute +
    mask formulation for every budget (incl. 0 and over-budget)."""
    cfg = kb.KVBankConfig(n_banks=4, page=2, pool_pages=16, max_pages=8)
    rng = np.random.default_rng(11)
    pool = kb.pool_init(cfg, 2, 2, 1, 8, jnp.bfloat16)
    shape = pool.k_banks.shape
    pool = pool._replace(
        k_banks=jnp.asarray(rng.integers(0, 2**16, shape, dtype=np.uint16)),
        v_banks=jnp.asarray(rng.integers(0, 2**16, shape, dtype=np.uint16)),
        parity_fresh=jnp.asarray(rng.integers(0, 2, pool.parity_fresh.shape)
                                 .astype(bool)))
    full_k = pool.k_banks[:, 0::2] ^ pool.k_banks[:, 1::2]
    stale = ~np.asarray(pool.parity_fresh)
    order = np.cumsum(stale.reshape(-1)).reshape(stale.shape)
    for budget in (0, 1, 3, 100):
        got, n = kb.pool_recode(cfg, pool, budget=budget)
        take = stale & (order <= budget)
        assert int(n) == int(take.sum())
        ref_k = np.where(take[None, ..., None, None, None],
                         np.asarray(full_k), np.asarray(pool.k_par))
        np.testing.assert_array_equal(np.asarray(got.k_par), ref_k)
        np.testing.assert_array_equal(np.asarray(got.parity_fresh),
                                      ~stale | take)


def test_pool_write_fused_keeps_parity_consistent():
    """Encode-on-write: the fused layer write must land the same bank bits
    as the plain write AND leave parity equal to a full re-encode —
    including when pair-sibling lanes hit the same parity element (the
    cross-pass collision case) and when a lane is the inactive sink."""
    cfg = kb.KVBankConfig(n_banks=4, page=4, pool_pages=16, max_pages=4)
    rng = np.random.default_rng(5)
    nb, slots, pg = 4, 4, 4
    shape = (nb, slots, pg, 2, 8)
    kbank = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    vbank = jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
    kpar = kbank[0::2] ^ kbank[1::2]
    vpar = vbank[0::2] ^ vbank[1::2]
    # lanes 0/1: sibling banks, same slot, same in_page (parity collision);
    # lane 2: unrelated; lane 3: inactive sink
    bank = jnp.asarray([0, 1, 2, nb], jnp.int32)
    slot = jnp.asarray([1, 1, 3, 0], jnp.int32)
    in_page = jnp.asarray([2, 2, 0, 0], jnp.int32)
    k_new = jnp.asarray(rng.integers(0, 2**32, (4, 2, 8), dtype=np.uint32))
    v_new = jnp.asarray(rng.integers(0, 2**32, (4, 2, 8), dtype=np.uint32))
    widx = (bank, slot, in_page)
    k2, v2, kp2, vp2 = kb.pool_write_layer_fused(
        cfg, kbank, vbank, kpar, vpar, widx, k_new, v_new)
    k2u, v2u = kb.pool_write_layer(cfg, kbank, vbank, widx, k_new, v_new)
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(k2u))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v2u))
    np.testing.assert_array_equal(np.asarray(kp2),
                                  np.asarray(k2u[0::2] ^ k2u[1::2]))
    np.testing.assert_array_equal(np.asarray(vp2),
                                  np.asarray(v2u[0::2] ^ v2u[1::2]))


def test_pool_install_fused_matches_recode():
    """Fused-encode install must leave parity equal to install + full
    re-encode, with the same status-table evolution."""
    cfg = kb.KVBankConfig(n_banks=4, page=2, pool_pages=16, max_pages=8)
    rng = np.random.default_rng(9)
    pool = kb.pool_init(cfg, 2, 2, 1, 8, jnp.float32)
    pt = np.full((2, 8), -1, np.int32)
    pt[0, :5] = [3, 4, 0, 1, 9]     # includes a sibling pair (0, 1)
    pool = pool._replace(page_table=jnp.asarray(pt))
    k_seq = jnp.asarray(rng.normal(size=(2, 10, 1, 8)), jnp.float32)
    v_seq = jnp.asarray(rng.normal(size=(2, 10, 1, 8)), jnp.float32)
    fused = kb.pool_install(cfg, pool, jnp.int32(0), k_seq, v_seq,
                            fuse_encode=True)
    plain = kb.pool_install(cfg, pool, jnp.int32(0), k_seq, v_seq)
    plain_full, _ = kb.pool_recode(cfg, plain, budget=None)
    np.testing.assert_array_equal(np.asarray(fused.k_banks),
                                  np.asarray(plain.k_banks))
    np.testing.assert_array_equal(np.asarray(fused.k_par),
                                  np.asarray(plain_full.k_par))
    np.testing.assert_array_equal(np.asarray(fused.v_par),
                                  np.asarray(plain_full.v_par))
    np.testing.assert_array_equal(np.asarray(fused.parity_fresh),
                                  np.asarray(plain.parity_fresh))


def test_kvbank_stale_parity_never_used():
    cfg = kb.KVBankConfig(n_banks=4, page=4, pool_pages=32, max_pages=16)
    st = _grow(cfg, [40, 8])                    # NO recode → parities stale
    plan = kb.plan_reads(cfg, st)
    fresh = np.asarray(st.parity_fresh)
    phys = np.maximum(np.asarray(st.page_table), 0)
    page_fresh = fresh[(phys % 4) // 2, phys // 4]
    used = np.asarray(plan.use_parity)
    assert not (used & ~page_fresh).any()
    # reconstruction still exact (falls back to direct reads)
    k_log, _ = kb.gather_kv(cfg, st, plan, jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(k_log[0, :40], np.float32),
                                  np.ones((40, 1, 8), np.float32))
    np.testing.assert_array_equal(np.asarray(k_log[1, :8], np.float32),
                                  np.ones((8, 1, 8), np.float32))
