"""Serving request-path tests: coded KV pool decode datapath, serve metric
planes vs the kvpool oracle (exact), placement-churn invariance, mid-stream
node replacement, and the pooled-vs-ring bit-identity anchor."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import lm
from repro.obs.report import drive_serve_with_oracle
from repro.oracle import kvpool
from repro.runtime import kvbank as kb
from repro.runtime.server import Request, ServeConfig, Server


@pytest.fixture(scope="module")
def cfg():
    # page 4 divides max_seq, so the pooled gather covers the same logical
    # positions as the ring cache (the bit-identity anchor below)
    return dataclasses.replace(get_config("qwen2.5-3b").reduced(), kv_page=4)


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init_params(cfg, jax.random.key(0), max_seq=48)


def _sc(**kw):
    base = dict(n_slots=3, max_prompt=8, max_seq=24, max_new_tokens=5)
    base.update(kw)
    return ServeConfig(**base)


def _reqs(cfg, n=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=[int(x) for x in
                                   rng.integers(1, cfg.vocab // 2,
                                                size=3 + i % 4)])
            for i in range(n)]


def _serve(cfg, params, sc, reqs, permute_seed=None):
    srv = Server(cfg, sc, params)
    for r in reqs:
        srv.submit(r)
    rng = np.random.default_rng(permute_seed)
    for step in range(200):
        srv._admit()
        if not any(s is not None for s in srv.slots):
            break
        if permute_seed is not None and step % 2 == 1:
            srv.permute_pool(rng.permutation(srv.kvcfg.pool_pages))
        srv.step_decode()
    return srv


# ------------------------------------------------------------ bit identity
def test_coded_equals_uncoded_tokens(cfg, params):
    """XOR parity is exact: the coded pool serves bit-identical tokens to
    the uncoded pool on the same workload."""
    reqs_c = _reqs(cfg)
    _serve(cfg, params, _sc(coded=True), reqs_c)
    reqs_u = _reqs(cfg)
    _serve(cfg, params, _sc(coded=False), reqs_u)
    assert [r.out for r in reqs_c] == [r.out for r in reqs_u]


def test_pooled_equals_ring_tokens(cfg, params):
    """The pooled decode datapath reproduces the ring-cache decode exactly
    (same logical KV in position order, same attention): disabling banks
    (kv_banks=0 -> ring backend) must not change a single token."""
    reqs_p = _reqs(cfg)
    srv_p = _serve(cfg, params, _sc(), reqs_p)
    assert srv_p.pooled
    cfg_ring = dataclasses.replace(cfg, kv_banks=0)
    reqs_r = _reqs(cfg)
    srv_r = _serve(cfg_ring, params, _sc(), reqs_r)
    assert not srv_r.pooled
    assert [r.out for r in reqs_p] == [r.out for r in reqs_r]


def test_permute_pool_is_invariant(cfg, params):
    """Physical placement churn (page permutation mid-run) never changes
    decode output — only where pages live, not what they hold."""
    reqs_a = _reqs(cfg)
    _serve(cfg, params, _sc(), reqs_a)
    reqs_b = _reqs(cfg)
    _serve(cfg, params, _sc(), reqs_b, permute_seed=7)
    assert [r.out for r in reqs_a] == [r.out for r in reqs_b]


def test_telemetry_is_observer_only(cfg, params):
    """Serve metric planes must not perturb decode: telemetry on/off give
    bit-identical tokens."""
    reqs_a = _reqs(cfg)
    _serve(cfg, params, _sc(telemetry=False), reqs_a)
    reqs_b = _reqs(cfg)
    _serve(cfg, params, _sc(telemetry=True), reqs_b)
    assert [r.out for r in reqs_a] == [r.out for r in reqs_b]


def test_pallas_kernel_serves_identical_tokens(cfg, params):
    """The Pallas pool-gather datapath (``ServeConfig.kernel="pallas"``) is
    bit-exact vs the reference gather, so every served token must match —
    on the fused encode-on-write path (default) and on the budgeted
    (unfused) recode path, with placement churn in the mix."""
    reqs_a = _reqs(cfg)
    _serve(cfg, params, _sc(kernel="reference"), reqs_a, permute_seed=3)
    reqs_b = _reqs(cfg)
    _serve(cfg, params, _sc(kernel="pallas"), reqs_b, permute_seed=3)
    assert [r.out for r in reqs_a] == [r.out for r in reqs_b]
    reqs_c = _reqs(cfg)
    _serve(cfg, params, _sc(kernel="reference", recode_budget=2), reqs_c)
    reqs_d = _reqs(cfg)
    _serve(cfg, params, _sc(kernel="pallas", recode_budget=2), reqs_d)
    assert [r.out for r in reqs_c] == [r.out for r in reqs_d]


# ------------------------------------------------------- planes vs oracle
def test_serve_planes_match_oracle_exactly(cfg, params):
    """Every device serve-plane counter equals the pure-NumPy kvpool
    recompute, exactly (checked field-by-field inside check_against)."""
    srv = Server(cfg, _sc(telemetry=True), params)
    totals = drive_serve_with_oracle(srv, _reqs(cfg, n=6),
                                     churn_every=2,
                                     churn_rng=np.random.default_rng(3))
    snap = srv.serve_snapshot()
    snap.check_against(totals)
    assert snap.decode_steps > 0 and snap.served_pages > 0
    assert snap.direct_reads + snap.degraded_reads == snap.served_pages


def test_recode_budget_minus_one_never_degrades(cfg, params):
    """With the ReCoding unit off (budget=-1) parity goes permanently
    stale, so the planner must never issue a degraded read — stale parity
    is never consumed."""
    # no churn here: permute_pool legitimately rebuilds parity as part of
    # moving the data it protects
    srv = Server(cfg, _sc(telemetry=True, recode_budget=-1), params)
    totals = drive_serve_with_oracle(srv, _reqs(cfg, n=6))
    snap = srv.serve_snapshot()
    snap.check_against(totals)
    assert snap.recoded_rows == 0
    # all parity rows that ever hosted a write stay stale; no degraded read
    # may have touched them
    assert snap.degraded_reads == 0
    assert snap.coded_cycles == snap.uncoded_cycles


# -------------------------------------------------- device plan vs oracle
def test_plan_and_latencies_match_oracle_on_random_tables():
    """plan_reads / read_latencies (device) vs the sequential oracle walk
    on random page tables: same degraded-read choices, same per-read
    critical-word latency, and max latency == planned port cycles."""
    rng = np.random.default_rng(0)
    cfgk = kb.KVBankConfig(n_banks=8, page=4, pool_pages=64, max_pages=6)
    for trial in range(8):
        b = int(rng.integers(2, 6))
        length = rng.integers(0, cfgk.max_pages * cfgk.page, size=b)
        n_pages = [kvpool.ceil_div(int(L), cfgk.page) for L in length]
        phys = rng.choice(cfgk.pool_pages, size=sum(n_pages), replace=False)
        table = np.full((b, cfgk.max_pages), -1, np.int64)
        c = 0
        for i, np_i in enumerate(n_pages):
            table[i, :np_i] = phys[c:c + np_i]
            c += np_i
        fresh = rng.random((cfgk.n_banks // 2,
                            cfgk.pool_pages // cfgk.n_banks)) < 0.8
        pt = jnp.asarray(table, jnp.int32)
        ln = jnp.asarray(length, jnp.int32)
        plan = kb._plan_from_tables(cfgk, pt, ln, jnp.asarray(fresh))
        exp = kvpool.plan_reads(cfgk.n_banks, cfgk.page, table, length,
                                fresh)
        np.testing.assert_array_equal(np.asarray(plan.use_parity),
                                      exp["use_parity"])
        np.testing.assert_array_equal(np.asarray(plan.load), exp["load"])
        assert int(plan.uncoded_cycles) == exp["uncoded_cycles"]
        assert int(plan.coded_cycles) == exp["coded_cycles"]
        lat = np.asarray(kb.read_latencies(cfgk, pt, ln, plan.use_parity))
        lat_exp = kvpool.read_latencies(cfgk.n_banks, cfgk.page, table,
                                        length, exp["use_parity"])
        np.testing.assert_array_equal(lat, lat_exp)
        if lat.max() > 0:
            # the plan's makespan is exactly the slowest critical word
            assert lat.max() == exp["coded_cycles"]


# ------------------------------------------------- mid-stream replacement
def test_node_replacement_midstream(cfg, params):
    """Snapshot a serving node mid-decode (pool + planes + page
    accounting), restore into a fresh Server, and finish on both: decode
    output and every telemetry counter stay bit-identical."""
    sc = _sc(telemetry=True)
    srv_a = Server(cfg, sc, params)
    for r in _reqs(cfg, n=5):
        srv_a.submit(r)
    for _ in range(3):
        srv_a.step()
    snap = srv_a.snapshot()
    queue_a = [(r.rid, list(r.prompt), list(r.out)) for r in srv_a.queue]

    srv_b = Server(cfg, sc, params)
    srv_b.restore_snapshot(snap)
    srv_b.queue = [Request(rid=q[0], prompt=q[1], out=q[2])
                   for q in queue_a]

    for srv in (srv_a, srv_b):
        for _ in range(200):
            srv.step()
            if not srv.queue and all(s is None for s in srv.slots):
                break
    # both nodes drained; compare the full device state and planes
    ca = jax.tree.map(np.asarray, srv_a.cache)
    cb = jax.tree.map(np.asarray, srv_b.cache)
    for a_leaf, b_leaf in zip(jax.tree.leaves(ca), jax.tree.leaves(cb)):
        np.testing.assert_array_equal(a_leaf, b_leaf)
    np.testing.assert_array_equal(np.asarray(srv_a.tokens),
                                  np.asarray(srv_b.tokens))
    sa, sb = srv_a.serve_snapshot(), srv_b.serve_snapshot()
    assert sa.as_dict().keys() == sb.as_dict().keys()
    for k, v in sa.as_dict().items():
        np.testing.assert_array_equal(v, sb.as_dict()[k])
    assert srv_a.free_pages == srv_b.free_pages


# ---------------------------------------------------------- lifecycle log
def test_servelog_spans_and_trace(tmp_path):
    """Host lifecycle spans: TTFT/ITL derived from an injectable clock, and
    the Chrome-trace export carries queue + slot rows."""
    from repro.obs import serve as obs_serve

    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    log = obs_serve.ServeLog(clock=clock)
    log.submit(0)            # t=1
    log.admit(0, slot=1, prompt_len=4)   # t=2
    log.prefill_done(0)      # t=3
    log.token(0)             # t=4
    log.token(0)             # t=5
    log.finish(0)            # t=6
    (span,) = log.spans()
    assert span["admission_wait_s"] == 1.0
    assert span["ttft_s"] == 2.0
    assert span["inter_token_s"] == [1.0, 1.0]
    assert span["n_tokens"] == 3    # prefill's first token + 2 decode
    s = log.summary()
    assert s["ttft_p50_s"] == 2.0

    path = str(tmp_path / "trace.json")
    log.export_chrome_trace(path, manifest={"k": "v"})
    import json
    blob = json.load(open(path))
    names = {e.get("name") for e in blob["traceEvents"]}
    assert "queued req 0" in names and "req 0" in names
    assert "first token req 0" in names
    assert blob["otherData"]["manifest"] == {"k": "v"}
