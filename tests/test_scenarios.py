"""Checked-in scenario pack: real-format trace excerpts as a sweep suite.

``tests/data/scenarios/`` ships small gem5-/Ramulator-style excerpts with
the paper's banded access structure (dedup-like persistent bands, vips-like
column-major bank hammering). The ``scenario_pack`` workloads suite turns a
folder of such files into sweep points; these tests pin the registration,
the profiler's reading of each scenario, and a conformance smoke against
the NumPy golden model per scenario point.
"""
import os

import numpy as np
import pytest
from conftest import oracle_twin

from repro.sweep import partition, run_points
from repro.sweep.grid import SweepPoint
from repro.sweep.workloads import SUITES, build_trace, suite
from repro.traces import count_requests, profile_trace, stream_file
from repro.traces.stream import strip_windows

SCEN_DIR = os.path.join(os.path.dirname(__file__), "data", "scenarios")
SCEN_FILES = sorted(f for f in os.listdir(SCEN_DIR)
                    if f.endswith((".trace", ".gem5")))

BASE = SweepPoint(scheme="scheme_i", n_rows=64, n_cores=4, n_banks=8,
                  alpha=0.25, r=0.05, select_period=32, recode_cap=16)


def _pack():
    return suite("scenario_pack", BASE, directory=SCEN_DIR)


def test_scenario_pack_registered_and_sized():
    """The pack is a first-class SUITES entry: every checked-in excerpt
    becomes a file: point sized to its own request count, stamped with the
    suite name and labeled with the file stem."""
    assert "scenario_pack" in SUITES
    pts = _pack()
    assert len(pts) == len(SCEN_FILES)
    assert {pt.label for pt in pts} == {os.path.splitext(f)[0]
                                        for f in SCEN_FILES}
    for pt in pts:
        assert pt.suite == "scenario_pack"
        path = pt.trace[len("file:"):]
        n = count_requests(path)
        assert pt.length == -(-n // pt.n_cores)
        tr = build_trace(pt)
        assert tuple(tr.bank.shape) == (pt.n_cores, pt.length)
        assert int(np.asarray(tr.valid).sum()) == n


def test_scenario_pack_needs_directory():
    with pytest.raises(ValueError, match="directory"):
        suite("scenario_pack", BASE)


@pytest.mark.parametrize("fname", SCEN_FILES)
def test_scenario_profiler_smoke(fname):
    """The locality profiler reads each scenario the way Fig 15 reads the
    PARSEC traces: streamed, with a plausible read/write mix, detectable
    persistent bands carrying most of the traffic, and in-range ranked
    region priors."""
    path = os.path.join(SCEN_DIR, fname)
    n = count_requests(path)
    prof = profile_trace(
        stream_file(path, 32, n_cores=BASE.n_cores, n_banks=BASE.n_banks,
                    n_rows=BASE.n_rows, line_bytes=64),
        n_banks=BASE.n_banks, n_rows=BASE.n_rows, window=64)
    assert prof.n_requests == n
    assert 0.0 < prof.write_frac < 0.5          # both excerpts are read-heavy
    bands = prof.bands(min_persistence=0.5, min_weight=0.05)
    assert bands, "scenario should show persistent address bands"
    assert sum(b.weight for b in bands) > 0.5   # bands carry the traffic
    rs, nr, ns = BASE.derived_slots()
    priors = prof.region_priors(rs, nr, k=max(ns, 1))
    assert priors.shape == (max(ns, 1),)
    live = priors[priors >= 0]
    assert live.size > 0 and live.max() < nr
    assert live.size == np.unique(live).size    # ranked ids are distinct


def test_scenario_conformance_smoke():
    """Every scenario point replays through the batched engine identically
    to the golden model — the oracle anchors the checked-in pack, not a
    second jax implementation."""
    pts = _pack()
    results = run_points(pts)
    for pt, res in zip(pts, results):
        assert res.completed, pt.label
        assert res.served_reads + res.served_writes > 0
        sys_ = _point_system(pt)
        om = oracle_twin(sys_)
        ost = om.run(build_trace(pt), pt.resolved_cycles(),
                     stop_when_quiescent=True)
        assert strip_windows(res) == om.result(ost), pt.label


def _point_system(pt):
    from repro.core.codes import get_tables
    from repro.core.state import make_params, make_tunables
    from repro.core.system import CodedMemorySystem
    t = get_tables(pt.scheme, n_data=pt.n_data)
    p = make_params(t, n_rows=pt.n_rows, alpha=pt.alpha, r=pt.r,
                    queue_depth=pt.queue_depth, recode_cap=pt.recode_cap,
                    max_syms=pt.max_syms,
                    encode_rows_per_cycle=pt.encode_rows_per_cycle,
                    recode_budget=pt.recode_budget, coalesce=pt.coalesce)
    tn = make_tunables(queue_depth=p.queue_depth,
                       select_period=pt.select_period,
                       wq_hi=pt.wq_hi, wq_lo=pt.wq_lo)
    return CodedMemorySystem(t, p, n_cores=pt.n_cores, tunables=tn)


def test_scenario_points_batch_together():
    """Same memory geometry, different files: the pack's points share one
    static signature only when their lengths agree — mixed lengths still
    partition cleanly and reassemble in order."""
    pts = _pack()
    batches = partition(pts)
    assert sum(len(b) for b in batches) == len(pts)
    lengths = {pt.length for pt in pts}
    assert len(batches) == len(lengths)
