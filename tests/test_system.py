"""End-to-end coded-memory-system tests: memory-order correctness (every
served read returns the currently committed value), throughput vs the
uncoded baseline, and paper-claim regressions on small traces."""
import numpy as np
import pytest

from conftest import rand_trace

from repro.core.codes import get_tables
from repro.core.state import make_params
from repro.core.system import CodedMemorySystem
from repro.sim.ramulator import compare_schemes, simulate
from repro.sim.trace import TraceSpec, banded_trace


def _mk_system(scheme="scheme_i", n_rows=64, alpha=1.0, r=0.25, n_cores=4):
    t = get_tables(scheme)
    p = make_params(t, n_rows=n_rows, alpha=alpha, r=r)
    return CodedMemorySystem(t, p, n_cores=n_cores)


def _rand_trace(n_cores, T, n_rows, seed=0, write_frac=0.4):
    return rand_trace(np.random.default_rng(seed), n_cores, T, 8, n_rows,
                      write_frac=write_frac)


@pytest.mark.parametrize("scheme", ["scheme_i", "scheme_ii", "scheme_iii"])
def test_reads_return_committed_values(scheme):
    """The datapath invariant: every served read equals the golden value
    (last committed write, zero-init) at serve time — across direct,
    degraded, redirect and chained-decode paths."""
    sys = _mk_system(scheme)
    trace = _rand_trace(4, 24, 64, seed=1)
    st = sys.init()
    checked = 0
    for _ in range(96):
        golden_before = np.asarray(st.mem.golden)
        st, out = sys.cycle_fn(st, trace)
        served = np.asarray(out.r_served)
        if served.any():
            b = np.asarray(out.r_bank)[served]
            i = np.asarray(out.r_row)[served]
            v = np.asarray(out.r_value)[served]
            np.testing.assert_array_equal(v, golden_before[b, i])
            checked += served.sum()
        if int(st.done_cycle) >= 0:
            break
    assert checked > 10                      # the test actually exercised reads
    assert int(st.done_cycle) >= 0           # workload drained


def test_coded_beats_uncoded_on_banded_trace(small_geom):
    n_rows, length = small_geom
    spec = TraceSpec(n_cores=8, length=length, n_banks=8, n_rows=n_rows, seed=0)
    trace = banded_trace(spec)
    res = compare_schemes(trace, n_rows, alpha=1.0, r=0.25, n_cycles=160,
                          schemes=("uncoded", "scheme_i"))
    assert res["uncoded"].completed and res["scheme_i"].completed
    assert res["scheme_i"].cycles < res["uncoded"].cycles
    assert res["scheme_i"].degraded_reads > 0
    assert res["scheme_i"].avg_read_latency <= res["uncoded"].avg_read_latency


def test_uncoded_never_uses_parity():
    spec = TraceSpec(n_cores=4, length=32, n_rows=64, seed=2)
    trace = banded_trace(spec)
    res = simulate("uncoded", trace, 64, alpha=1.0, r=0.25, n_cycles=256)
    assert res.degraded_reads == 0
    assert res.parked_writes == 0


def test_replication_baseline_runs():
    spec = TraceSpec(n_cores=4, length=32, n_rows=64, seed=3)
    trace = banded_trace(spec)
    res = simulate("replication_2", trace, 64, alpha=1.0, r=0.25, n_cycles=256)
    assert res.completed
    assert res.degraded_reads >= 0           # duplicates count as parity opts


def test_dynamic_coding_switches():
    """Shallow parities (α<1): hot regions get encoded; switches happen."""
    spec = TraceSpec(n_cores=8, length=48, n_rows=128, seed=4, write_frac=0.1)
    trace = banded_trace(spec)
    res = simulate("scheme_i", trace, 128, alpha=0.25, r=0.125,
                   select_period=32, n_cycles=256)
    assert res.completed
    assert res.switches >= 1                 # dynamic encoder engaged
    res_full = simulate("scheme_i", trace, 128, alpha=1.0, r=0.125,
                        select_period=32, n_cycles=256)
    assert res_full.switches == 0            # α=1: full coverage, no switching


def test_recode_backlog_drains():
    """After the trace drains, idle cycles let the ReCoding unit catch up."""
    sys = _mk_system("scheme_i", n_rows=64)
    trace = _rand_trace(4, 16, 64, seed=5, write_frac=0.8)
    st = sys.init()
    for _ in range(160):
        st, _ = sys.cycle_fn(st, trace)
    assert int(st.done_cycle) >= 0
    assert int(st.mem.rc_valid.sum()) == 0
    # all parities of covered regions are valid again after recode
    assert bool(st.mem.parity_valid.all())
    # and parity contents match the XOR of their members (full consistency)
    t = sys.tables
    banks = np.asarray(st.mem.banks_data)
    pdata = np.asarray(st.mem.parity_data)
    rslot = np.asarray(st.mem.region_slot)
    rs = sys.p.region_size
    for j, members in enumerate(t.scheme.members):
        for i in range(sys.p.n_rows):
            slot = rslot[i // rs]
            if slot < 0:
                continue
            pr = slot * rs + i % rs
            want = 0
            for m in members:
                want ^= int(banks[m, i])
            assert int(pdata[j, pr]) == want, (j, i)
