"""Code scheme tests against the paper's §III-B claims."""
import numpy as np
import pytest

from repro.core.codes import (get_tables, replication, scheme_i,
                              scheme_ii, scheme_iii, uncoded)


def test_scheme_i_structure():
    s = scheme_i(8)
    assert s.n_parities == 12                    # 2 groups × C(4,2)
    assert s.n_phys == 12                        # one shallow bank each
    assert s.locality() == 2
    # rate 2/(2+3α) — paper §III-B1
    for a in (0.05, 0.25, 1.0):
        assert s.rate(a) == pytest.approx(2 / (2 + 3 * a))
    # every data bank appears in exactly 3 pairwise parities
    for b in range(8):
        assert sum(b in m for m in s.members) == 3


def test_scheme_ii_structure():
    s = scheme_ii(8)
    assert s.n_parities == 20                    # 12 pairs + 8 duplicates
    assert s.n_phys == 10                        # packed 2-per-physical-bank
    for a in (0.05, 0.25, 1.0):
        assert s.rate(a) == pytest.approx(2 / (2 + 5 * a))
    # each data bank: 3 pairs + 1 duplicate = 4 non-direct options -> 5 reads
    for b in range(8):
        assert sum(b in m for m in s.members) == 4
    # physical packing: every physical bank hosts exactly 2 logical halves
    counts = np.bincount(np.asarray(s.phys))
    assert (counts == 2).all()


def test_scheme_iii_structure():
    s = scheme_iii(9)
    assert s.n_parities == 9                     # 3 rows + 3 cols + 3 diags
    assert s.locality() == 3
    for a in (0.05, 0.25, 1.0):
        assert s.rate(a) == pytest.approx(1 / (1 + a))
    # every bank is covered by exactly one row, one col, one diagonal
    for b in range(9):
        assert sum(b in m for m in s.members) == 3
    # 8-bank variant (paper Remark 5) just drops bank 8 from members
    s8 = scheme_iii(8)
    assert all(8 not in m for m in s8.members)
    assert s8.n_parities == 9


def test_replication_baseline():
    s = replication(8, copies=4)                 # r·(w+1) = 2·(1+1) per group
    assert s.n_parities == 24                    # 3 extra copies × 8 banks
    assert s.locality() == 1
    assert uncoded(8).n_ports == 8


def test_tables_consistency():
    for name in ("scheme_i", "scheme_ii", "scheme_iii"):
        t = get_tables(name)
        nd = t.n_data
        # every option references a parity that actually contains the bank
        for b in range(nd):
            for k in range(int(t.opt_n[b])):
                j = int(t.opt_parity[b, k])
                members = [m for m in t.par_members[j] if m >= 0]
                assert b in members
                sibs = [m for m in t.opt_sibs[b, k] if m >= 0]
                assert sorted(sibs + [b]) == sorted(members)
        # port ids are valid
        assert (t.par_port[: t.n_parities] >= nd).all()
        assert (t.par_port[: t.n_parities] < t.n_ports).all()


def test_simultaneous_read_capacity():
    """§III-B: reads/bank/cycle = 1 direct + n options (I:4, II:5, III:4).

    The option *count* alone is not enough — two options packed onto one
    physical parity bank share its port (Scheme II). The certificate's
    ``read_degree_min`` is the proven port-disjoint capacity; both it and
    the option count must equal the paper's claim."""
    from repro.analysis import schemes as anl

    cert = anl.load_certificates()
    for name, per_bank in (("scheme_i", 4), ("scheme_ii", 5), ("scheme_iii", 4)):
        t = get_tables(name)
        assert int(t.opt_n.min()) + 1 == per_bank, name
        assert cert["schemes"][name]["read_degree_min"] == per_bank, name


# -------------------------------------------------------------- certificates
def test_scheme_certificates_current_and_claims_proven():
    """The GF(2) analysis layer is clean: every scheme in SCHEMES has a
    checked-in certificate matching the live tables, delivers its DECLARED
    erasure-tolerance/read-degree/locality claims, and the padded parity
    addressing is alias-free. A scheme edit without
    ``python -m repro.analysis --write-certificates`` fails here with the
    divergent scheme named."""
    from repro.analysis import schemes as anl

    findings = anl.run()
    assert not findings, "\n".join(str(f) for f in findings)


def test_certificates_cover_all_schemes():
    from repro.analysis import schemes as anl
    from repro.core.codes import SCHEMES

    cert = anl.load_certificates()
    # Core schemes plus the serving pool's pairwise layout (a certified
    # Scheme-I subcode — see analysis.schemes.check_pool_subcode).
    assert sorted(cert["schemes"]) == sorted([*SCHEMES, "kv_pool"])
    for name, entry in cert["schemes"].items():
        assert name in anl.DECLARED
        assert entry["full_tolerance_k"] == anl.DECLARED[name]["full_k"]


def test_candidate_scheme_admission_gate():
    """An under-tolerant candidate (e.g. a future LVT/ILVT table with a
    hole) is rejected by the claims verifier before it ever reaches the
    simulator: dropping one pair from scheme_i loses double-loss coverage
    and the verifier names the first unservable loss set."""
    from repro.analysis import schemes as anl

    t = get_tables("scheme_i")
    members = [ms for ms in t.scheme.members if ms not in ((0, 2), (0, 3))]
    phys = list(range(len(members)))
    entry = anl.analyze_scheme("candidate", members=members, phys=phys,
                               n_data=8)
    findings = anl.verify_scheme_claims(
        "candidate", entry,
        declared={"full_k": 2, "read_degree": 4, "locality": 2})
    rules = {f.rule for f in findings}
    # bank 0's only remaining option is the (0, 1) pair, so losing {0, 1}
    # together is unservable and bank 0's port-disjoint capacity is 2
    assert "scheme-under-tolerant" in rules
    assert "scheme-read-degree" in rules
