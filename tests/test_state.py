"""State-layer regressions: tunable clamping, wide (64-bit) statistics
accumulators, and the α < r zero-slot geometry — the bugs the α×r sweeps
exposed."""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import rand_trace

from repro.core.codes import get_tables
from repro.core.state import (derive_geometry, make_params, make_tunables,
                              wide_add, wide_total, wide_zero)
from repro.core.system import CodedMemorySystem


# ------------------------------------------------------- hysteresis clamping
def test_make_tunables_clamps_crossed_thresholds():
    """wq_lo must never exceed wq_hi: crossed thresholds would flap
    write_mode every cycle (enter at occupancy >= hi, stay only while
    occupancy > lo > hi — no stable state)."""
    tn = make_tunables(queue_depth=10, wq_hi=2, wq_lo=8)
    assert int(tn.wq_lo) <= int(tn.wq_hi)
    # wq_hi itself is still clamped into the queue
    tn = make_tunables(queue_depth=4, wq_hi=99, wq_lo=99)
    assert int(tn.wq_hi) == 3 and int(tn.wq_lo) <= 3


def test_crossed_thresholds_simulate_like_clamped():
    """A crossed-threshold sweep point runs exactly like its clamped
    equivalent (the clamp is the semantics, not a new behaviour)."""
    from repro.sim.ramulator import simulate
    rng = np.random.default_rng(3)
    trace = rand_trace(rng, 4, 16, 8, 32, write_frac=0.7)
    crossed = simulate("scheme_i", trace, 32, alpha=0.25, r=0.125,
                       n_cycles=128, wq_hi=2, wq_lo=8)
    clamped = simulate("scheme_i", trace, 32, alpha=0.25, r=0.125,
                       n_cycles=128, wq_hi=2, wq_lo=2)
    assert crossed == clamped
    assert crossed.completed


# ------------------------------------------------------------- wide counters
def test_wide_add_crosses_32bit_boundary():
    acc = wide_zero()
    assert acc.dtype == jnp.uint32          # explicit, x64-flag independent
    step = (1 << 31) - 1
    for _ in range(4):                      # 4 * (2^31 - 1) > 2^32
        acc = wide_add(acc, jnp.int32(step))
    assert wide_total(acc) == 4 * step
    assert wide_total(acc) > (1 << 32)


def test_latency_sums_do_not_overflow_int32():
    """Latency/stat accumulators pre-loaded near the int32 boundary keep
    counting exactly past 2^31 (the old int32 fields wrapped negative)."""
    t = get_tables("uncoded")          # no parity paths: same-bank requests
    p = make_params(t, n_rows=32, alpha=1.0, r=0.25)   # serialize, latency ≥ 1
    sys = CodedMemorySystem(t, p, n_cores=4)
    rng = np.random.default_rng(9)
    trace = rand_trace(rng, 4, 12, 2, 32, write_frac=0.5)  # 2 banks: contention
    base = (1 << 31) - 1                    # one increment from the boundary
    near = jnp.asarray([np.uint32(base), np.uint32(0)])
    st = sys.init()
    st = st._replace(mem=st.mem._replace(read_latency_sum=near,
                                         write_latency_sum=near,
                                         stall_cycles=near))
    for _ in range(96):
        st, _ = sys.cycle_fn(st, trace)
        if int(st.done_cycle) >= 0:
            break
    res = sys.summarize(st)
    sr, sw = int(st.mem.served_reads), int(st.mem.served_writes)
    assert sr > 0 and sw > 0
    # queued writes always wait ≥1 cycle for the drain hysteresis, so both
    # latency totals crossed 2^31 — exactly where the old int32 wrapped
    assert wide_total(st.mem.read_latency_sum) > (1 << 31)
    assert wide_total(st.mem.write_latency_sum) > (1 << 31)
    assert wide_total(st.mem.stall_cycles) >= base  # monotone, no wrap
    assert res.avg_read_latency > 0 and res.avg_write_latency > 0


# ------------------------------------------------------------ α < r geometry
def test_derive_geometry_alpha_below_r_is_zero_slots():
    rs, nr, ns = derive_geometry(320, alpha=0.02, r=0.05)
    assert (rs, nr) == (16, 20)
    assert ns == 0                           # no free parity slot granted
    # boundary: α == r still earns exactly one slot
    assert derive_geometry(320, alpha=0.05, r=0.05)[2] == 1


def test_alpha_below_r_runs_uncoded():
    """⌊α/r⌋ = 0: the system must behave exactly like an uncoded memory —
    no degraded reads, no parked writes, no region switches — instead of
    silently granting a free parity slot."""
    from repro.sim.ramulator import simulate
    t = get_tables("scheme_i")
    p = make_params(t, n_rows=32, alpha=0.05, r=0.25)
    assert p.n_active == 0 and p.n_slots == 1   # storage floor only
    rng = np.random.default_rng(5)
    trace = rand_trace(rng, 4, 16, 8, 32, write_frac=0.5)
    res = simulate("scheme_i", trace, 32, alpha=0.05, r=0.25, n_cycles=128,
                   select_period=8)
    assert res.completed
    assert res.degraded_reads == 0
    assert res.parked_writes == 0
    assert res.switches == 0


def test_non_traced_system_rejects_stray_geometry_actives():
    """Explicit region-geometry actives on a system built without
    ``traced_geometry=True`` would be silently ignored — init must reject
    them instead of simulating a hybrid configuration."""
    from repro.core.state import init_state
    t = get_tables("scheme_i")
    p = make_params(t, n_rows=32, alpha=0.25, r=0.125)  # static geometry
    tn = make_tunables(queue_depth=10, region_size_active=2,
                       n_regions_active=16)
    with pytest.raises(ValueError, match="traced_geometry"):
        init_state(p, tn)
    # matching (or default-sentinel) actives are fine
    init_state(p, make_tunables(queue_depth=10))
    rs, nr, _ = derive_geometry(32, 0.25, 0.125)
    init_state(p, make_tunables(queue_depth=10, region_size_active=rs,
                                n_regions_active=nr))


def test_make_params_rejects_undersized_allocs():
    t = get_tables("scheme_i")
    with pytest.raises(ValueError):
        make_params(t, n_rows=32, alpha=0.5, r=0.125, n_slots_alloc=1)
    with pytest.raises(ValueError):
        make_params(t, n_rows=32, alpha=0.5, r=0.125, region_size_alloc=2)
    with pytest.raises(ValueError):
        make_params(t, n_rows=32, alpha=0.5, r=0.125, n_regions_alloc=4)
    with pytest.raises(ValueError):             # alloc flips coverage status
        make_params(t, n_rows=32, alpha=0.5, r=0.125, n_slots_alloc=64)
