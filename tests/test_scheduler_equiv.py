"""Vectorized-vs-reference scheduler equivalence (the PR-2 contract).

The compacted-walk builders in ``repro.core.controller`` (and the vectorized
arbiter / write-commit / recode paths behind ``scheduler="vectorized"``) must
produce **bit-identical** plans and simulation states vs the sequential
reference implementations, across random queue states, port-busy vectors,
freshness/parity configurations and recode-ring fills — including full rings
(the rc-drop path). Randomized here with seeded NumPy so the suite runs
without optional deps; a hypothesis-driven variant engages when the package
is installed (requirements-dev.txt).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import rand_trace

# this suite IS the deprecated reference scheduler's soak harness: it builds
# scheduler="reference" systems on purpose, so it opts in to the warning
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

from repro.core import controller as ctl
from repro.core import controller_ref as ctl_ref
from repro.core.codes import get_tables
from repro.core.recoding import recode_step, recode_step_ref
from repro.core.state import derive_geometry, make_params, make_tunables
from repro.core.system import CodedMemorySystem

SCHEMES = ["scheme_i", "scheme_ii", "scheme_iii", "replication_2", "uncoded"]

_read_vec = jax.jit(ctl.build_read_pattern, static_argnums=0)
_read_ref = jax.jit(ctl_ref.build_read_pattern_ref, static_argnums=0)
_write_vec = jax.jit(ctl.build_write_pattern, static_argnums=0)
_write_ref = jax.jit(ctl_ref.build_write_pattern_ref, static_argnums=0)
_recode_vec = jax.jit(recode_step, static_argnums=0)
_recode_ref = jax.jit(recode_step_ref, static_argnums=0)


@functools.lru_cache(maxsize=None)
def _geom(scheme, n_rows=16, alpha=1.0, r=0.25, rc_cap=8):
    t = get_tables(scheme)
    p = make_params(t, n_rows=n_rows, alpha=alpha, r=r, recode_cap=rc_cap)
    return t, p, ctl.jtables(t)


def _rand_mem(rng, p, n_rows):
    """Random freshness / parity-validity / region-map / ring state."""
    nb = p.n_data
    fresh = jnp.asarray(
        rng.integers(0, p.n_parities + 1, (nb, n_rows))
        * (rng.random((nb, n_rows)) < 0.25), jnp.int32)
    pv = jnp.asarray(
        rng.random((p.n_parities, p.n_slots * p.region_size)) < 0.7)
    rslot = np.full(p.n_regions, -1, np.int32)
    slots = rng.permutation(p.n_slots)
    regs = rng.permutation(p.n_regions)
    k = rng.integers(0, min(p.n_slots, p.n_regions) + 1)
    rslot[regs[:k]] = slots[:k]
    cap = p.recode_cap
    fill = int(rng.integers(0, cap + 1))       # includes a FULL ring
    rcv = np.zeros(cap, bool)
    rcv[rng.permutation(cap)[:fill]] = True
    rcb = np.where(rcv, rng.integers(0, nb, cap), -1).astype(np.int32)
    rcr = np.where(rcv, rng.integers(0, n_rows, cap), -1).astype(np.int32)
    parked = jnp.asarray(rng.integers(0, 3, p.n_regions), jnp.int32)
    return (fresh, pv, jnp.asarray(rslot), parked, jnp.asarray(rcb),
            jnp.asarray(rcr), jnp.asarray(rcv))


def _rand_cands(rng, p, n_rows, n=24):
    cb = jnp.asarray(rng.integers(0, p.n_data, n), jnp.int32)
    ci = jnp.asarray(rng.integers(0, n_rows, n), jnp.int32)
    ca = jnp.asarray(rng.integers(0, 50, n), jnp.int32)   # age ties likely
    cv = jnp.asarray(rng.random(n) < 0.8)
    pb = jnp.asarray(np.append(rng.random(p.n_ports) < 0.3, False))
    return cb, ci, ca, cv, pb


def _assert_trees_equal(got, want, label):
    for name, x, y in zip(want._fields, got, want):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{label}: field {name!r}")


def _check_one(scheme, seed):
    n_rows = 16
    t, p, jt = _geom(scheme)
    rng = np.random.default_rng(seed)
    fresh, pv, rslot, parked, rcb, rcr, rcv = _rand_mem(rng, p, n_rows)
    cb, ci, ca, cv, pb = _rand_cands(rng, p, n_rows)
    rp = _read_vec(p, jt, cb, ci, ca, cv, pb, fresh, pv, rslot)
    rr = _read_ref(p, jt, cb, ci, ca, cv, pb, fresh, pv, rslot)
    _assert_trees_equal(rp, rr, f"ReadPlan {scheme} seed={seed}")
    wp = _write_vec(p, jt, cb, ci, ca, cv, pb, fresh, pv, rslot,
                    parked, rcb, rcr, rcv)
    wr = _write_ref(p, jt, cb, ci, ca, cv, pb, fresh, pv, rslot,
                    parked, rcb, rcr, rcv)
    _assert_trees_equal(wp, wr, f"WritePlan {scheme} seed={seed}")


@pytest.mark.parametrize("scheme", SCHEMES)
def test_plan_equivalence_random_states(scheme):
    """Read and write plans are bit-identical to the reference across random
    queue/port/freshness/parity/ring states (incl. full recode rings)."""
    for seed in range(6):
        _check_one(scheme, seed)


@pytest.mark.parametrize("scheme", ["scheme_i", "scheme_iii"])
def test_recode_step_equivalence_random_states(scheme):
    n_rows = 16
    t, p, jt = _geom(scheme)
    for seed in range(6):
        rng = np.random.default_rng(1000 + seed)
        fresh, pv, rslot, parked, rcb, rcr, rcv = _rand_mem(rng, p, n_rows)
        pb = jnp.asarray(
            np.append(rng.random(p.n_ports) < 0.3, False))
        banks = jnp.asarray(
            rng.integers(0, 1 << 20, (p.n_data, n_rows)), jnp.int32)
        pdata = jnp.asarray(
            rng.integers(0, 1 << 20, pv.shape), jnp.int32)
        a = _recode_vec(p, jt, pb, fresh, pv, parked, rcb, rcr, rcv, rslot,
                        banks, pdata)
        b = _recode_ref(p, jt, pb, fresh, pv, parked, rcb, rcr, rcv, rslot,
                        banks, pdata)
        _assert_trees_equal(a, b, f"RecodeOut {scheme} seed={seed}")


def test_rc_dropped_counted_when_ring_full():
    """A direct write to a coded region with a FULL recode ring must count the
    lost parity-refresh (satellite: no silent drops) — in both builders."""
    t, p, jt = _geom("scheme_i", rc_cap=4)
    n_rows = 16
    full = jnp.ones((p.recode_cap,), bool)
    rcb = jnp.arange(p.recode_cap, dtype=jnp.int32) % p.n_data
    rcr = jnp.full((p.recode_cap,), 15, jnp.int32)   # no dup with row 0
    fresh = jnp.zeros((p.n_data, n_rows), jnp.int32)
    pv = jnp.ones((p.n_parities, p.n_slots * p.region_size), bool)
    rslot = jnp.arange(p.n_regions, dtype=jnp.int32)
    args = (jnp.asarray([0], jnp.int32), jnp.asarray([0], jnp.int32),
            jnp.asarray([0], jnp.int32), jnp.asarray([True]),
            jnp.zeros((p.n_ports + 1,), bool), fresh, pv, rslot,
            jnp.zeros((p.n_regions,), jnp.int32), rcb, rcr, full)
    for fn in (_write_vec, _write_ref):
        plan = fn(p, jt, *args)
        assert bool(plan.served[0])                  # the write itself lands
        assert int(plan.mode[0]) == ctl.WMODE_DIRECT  # park needs ring space
        assert int(plan.n_rc_dropped) == 1           # ...and the refresh is lost
        assert int(plan.rc_valid.sum()) == p.recode_cap


def _run_state(scheme, scheduler, trace, n_cycles, **kw):
    t = get_tables(scheme)
    p = make_params(t, n_rows=32, alpha=kw.pop("alpha", 1.0),
                    r=kw.pop("r", 0.25), recode_cap=8,
                    scheduler=scheduler, **kw)
    sys = CodedMemorySystem(t, p, n_cores=trace.bank.shape[0])
    st, _ = sys._run(sys.init(), trace, n_cycles)
    return sys, st


@pytest.mark.parametrize("scheme,alpha,r", [
    ("scheme_i", 1.0, 0.25),
    ("scheme_i", 0.25, 0.125),     # dynamic coding engaged
    ("uncoded", 1.0, 0.25),
    pytest.param("scheme_iii", 1.0, 0.25, marks=pytest.mark.slow),
])
def test_end_to_end_state_equivalence(scheme, alpha, r):
    """Full simulations (arbiter + builders + commit + recode + dynamic) agree
    on every field of the final state, not just summary stats."""
    rng = np.random.default_rng(7)
    trace = rand_trace(rng, 4, 20, min(8, get_tables(scheme).n_data), 32)
    _, st_v = _run_state(scheme, "vectorized", trace, 96, alpha=alpha, r=r)
    _, st_r = _run_state(scheme, "reference", trace, 96, alpha=alpha, r=r)
    leaves_v, treedef_v = jax.tree.flatten(st_v)
    leaves_r, _ = jax.tree.flatten(st_r)
    names = [str(k) for k in range(len(leaves_v))]
    for name, lv, lr in zip(names, leaves_v, leaves_r):
        np.testing.assert_array_equal(
            np.asarray(lv), np.asarray(lr),
            err_msg=f"{scheme} α={alpha} r={r}: leaf {name}")


@pytest.mark.parametrize("scheduler", ["vectorized", "reference"])
@pytest.mark.parametrize("alpha,r", [
    (0.25, 0.125),     # sub-coverage: dynamic coding engaged
    (1.0, 0.125),      # full coverage: static identity map
    (0.05, 0.25),      # α < r: explicit 0-slot uncoded point
])
def test_padded_geometry_matches_exact_allocation(scheduler, alpha, r):
    """The r-mask contract at the system level: a program whose region and
    parity state is over-allocated (padded region_size / n_regions /
    n_slots) but runs at the point's traced active geometry must produce
    the same SimResult as the exactly-allocated program — for both
    schedulers."""
    n_rows = 32
    rng = np.random.default_rng(11)
    t = get_tables("scheme_i")
    trace = rand_trace(rng, 4, 16, t.n_data, n_rows)
    rs, nr, ns = derive_geometry(n_rows, alpha, r)
    full = ns >= nr

    exact_p = make_params(t, n_rows=n_rows, alpha=alpha, r=r, recode_cap=8,
                          scheduler=scheduler)
    exact = CodedMemorySystem(t, exact_p, n_cores=4).run(trace, 96)

    # pad every geometry axis past the derived values (a full-coverage
    # allocation must keep n_slots == n_regions to stay full-coverage)
    pad_nr = nr + 3
    pad_ns = pad_nr if full else ns + 2
    padded_p = make_params(t, n_rows=n_rows, alpha=alpha, r=r, recode_cap=8,
                           scheduler=scheduler, region_size_alloc=rs + 5,
                           n_regions_alloc=pad_nr, n_slots_alloc=pad_ns,
                           traced_geometry=True)
    tn = make_tunables(queue_depth=padded_p.queue_depth,
                       n_slots_active=ns, region_size_active=rs,
                       n_regions_active=nr)
    padded = CodedMemorySystem(t, padded_p, n_cores=4,
                               tunables=tn).run(trace, 96)
    assert padded == exact


# ---------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from(SCHEMES))
    def test_plan_equivalence_hypothesis(seed, scheme):
        _check_one(scheme, seed)
